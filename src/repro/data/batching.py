"""EdgeSOS-sampled training batches: the paper's data plane feeding the LM.

Each incoming window of sequences (tagged with a data stratum) is
stratified-sampled at the current QoS fraction; kept sequences compact
into a fixed-size training batch with Horvitz-Thompson weights so the
weighted loss is an unbiased estimate of the full-stream loss (paper eq 3
applied to the loss), and per-stratum counts ride along for the
error-bound telemetry (eqs 5-10).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import sampling
from ..models.transformer import Batch
from .tokens import TokenBatch


def edgesos_batch(
    key,
    window: TokenBatch,
    fraction: float,
    num_strata: int,
    out_batch: int,
    method: str = "srs",
) -> Batch:
    """Sample a window of sequences down to a fixed ``out_batch``.

    Kept sequences are compacted to the front; unfilled slots carry zero
    weight (masked out of the loss and the telemetry).
    """
    ns = num_strata + 1
    sidx = jnp.asarray(window.stratum, jnp.int32)
    res = sampling.edgesos(key, sidx, ns, fraction, method=method)
    valid, toks, tgts, strat, w = sampling.compact(
        res.mask,
        out_batch,
        jnp.asarray(window.tokens),
        jnp.asarray(window.targets),
        sidx,
        res.weight * jnp.asarray(window.weight),
    )
    B, L = toks.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    return Batch(
        tokens=toks,
        targets=jnp.where(valid[:, None], tgts, -1),
        positions=positions,
        seq_weight=jnp.where(valid, w, 0.0),
        stratum=jnp.where(valid, strat, num_strata),
        stratum_counts=res.counts,
    )


def full_batch(window: TokenBatch, num_strata: int) -> Batch:
    """Unsampled batch (fraction = 1 baseline)."""
    sidx = jnp.asarray(window.stratum, jnp.int32)
    counts = sampling.stratum_counts(sidx, num_strata + 1)
    B, L = window.tokens.shape
    return Batch(
        tokens=jnp.asarray(window.tokens),
        targets=jnp.asarray(window.targets),
        positions=jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L)),
        seq_weight=jnp.asarray(window.weight),
        stratum=sidx,
        stratum_counts=counts,
    )
