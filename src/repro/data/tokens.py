"""Stratified token streams: the paper's technique as an LM data layer.

Integration story (DESIGN.md §Integration): in a multi-pod trainer each
data-parallel shard plays the role of an *edge node* ingesting a local
shard of the corpus stream.  Documents carry a stratum tag (here: the geo
cell of their source; in general any domain bucket).  EdgeSOS subsamples
each shard's window per-stratum — synchronization-free — and emits
fixed-shape batches with Horvitz-Thompson weights, so the trainer computes
an *unbiased* loss estimate of the full stream at a fraction of the data
cost, with the same error-bound machinery (eqs 6-10) reporting a CI on the
loss.  The QoS controller can then trade data volume against loss-estimate
precision mid-run.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenBatch:
    tokens: np.ndarray  # (B, L) int32
    targets: np.ndarray  # (B, L) int32 (next-token)
    stratum: np.ndarray  # (B,) int32 source stratum of each sequence
    weight: np.ndarray  # (B,) f32 HT weight (1.0 when unsampled)


class StratifiedTokenStream:
    """Synthetic token stream whose unigram statistics vary by stratum.

    Each stratum has its own token distribution (a shifted Zipf), so the
    per-stratum loss differs and stratified sampling measurably reduces the
    variance of the loss estimate vs uniform subsampling — mirroring the
    paper's SRS-vs-stratified comparison on a training signal.
    """

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        num_strata: int = 16,
        stratum_probs: np.ndarray | None = None,
        seed: int = 0,
    ):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.num_strata = num_strata
        rng = np.random.default_rng(seed)
        if stratum_probs is None:
            raw = 1.0 / np.arange(1, num_strata + 1) ** 1.2  # skewed strata
            stratum_probs = raw / raw.sum()
        self.stratum_probs = stratum_probs
        self._offsets = rng.integers(0, vocab_size, num_strata)
        base = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
        self._base = base / base.sum()
        self._seed = seed

    def batches(self, batch_size: int, num_batches: int) -> Iterator[TokenBatch]:
        rng = np.random.default_rng(self._seed + 1)
        for _ in range(num_batches):
            strata = rng.choice(self.num_strata, batch_size, p=self.stratum_probs)
            toks = rng.choice(self.vocab_size, (batch_size, self.seq_len + 1), p=self._base)
            toks = (toks + self._offsets[strata][:, None]) % self.vocab_size
            yield TokenBatch(
                tokens=toks[:, :-1].astype(np.int32),
                targets=toks[:, 1:].astype(np.int32),
                stratum=strata.astype(np.int32),
                weight=np.ones(batch_size, np.float32),
            )
