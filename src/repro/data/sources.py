"""Arrival-process simulators: paced and bursty pane sources for the runtime.

A :class:`~repro.core.runtime.StreamRuntime` consumes any iterable of
``WindowBatch`` panes; the window iterators over ``streams.py`` generators
already provide the *content*.  These wrappers add the *arrival process* —
the paper's §5.2.4 observation that edge traffic is bursty, not paced — by
sleeping between yields on the producer thread:

  * :class:`PacedSource` — near-constant inter-arrival delay with optional
    seeded jitter: models a steady sensor feed, and is the honest baseline
    for the synchronous-vs-pipelined benchmark (both drivers experience the
    same arrival schedule).
  * :class:`BurstySource` — panes arrive in back-to-back bursts separated
    by idle gaps: models the taxi-fleet rush that saturates the ingest
    queue and exercises backpressure/shedding.

Delays are drawn once, up front, from a seeded ``numpy`` generator, so a
given ``(seed, n)`` always produces the same schedule.  This module lives in
``data/`` (not ``core/``) deliberately: host RNG is banned from the core
import closure (edgelint EDG001), and the runtime never imports it.
"""

from __future__ import annotations

import time
from typing import Iterable, Iterator, Sequence

import numpy as np


def _materialize(panes: Iterable) -> list:
    return list(panes)


class PacedSource:
    """Yield ``panes`` with a (jittered) constant inter-arrival delay.

    ``jitter`` is the relative half-width of a uniform perturbation:
    delay_i ~ U[(1-jitter), (1+jitter)] * mean_delay_s, seeded.
    ``repeat`` cycles the pane list that many times (schedule stays
    deterministic — delays are drawn for the full repeated length).
    """

    def __init__(
        self,
        panes: Sequence | Iterable,
        mean_delay_s: float,
        jitter: float = 0.0,
        seed: int = 0,
        repeat: int = 1,
    ):
        self.panes = _materialize(panes) * int(repeat)
        rng = np.random.default_rng(seed)
        lo, hi = 1.0 - jitter, 1.0 + jitter
        self.delays = mean_delay_s * rng.uniform(lo, hi, size=len(self.panes))

    def __iter__(self) -> Iterator:
        for pane, delay in zip(self.panes, self.delays):
            if delay > 0:
                time.sleep(float(delay))
            yield pane


class BurstySource:
    """Yield ``panes`` in bursts: ``burst`` back-to-back panes, then an idle
    gap of ``gap_s`` (jittered, seeded).  With a gap shorter than the
    per-burst compute time this reliably saturates a bounded ingest queue.
    """

    def __init__(
        self,
        panes: Sequence | Iterable,
        burst: int = 4,
        gap_s: float = 0.01,
        jitter: float = 0.5,
        seed: int = 0,
        repeat: int = 1,
    ):
        if burst < 1:
            raise ValueError(f"burst must be >= 1; got {burst}")
        self.panes = _materialize(panes) * int(repeat)
        self.burst = int(burst)
        n_gaps = (len(self.panes) + self.burst - 1) // self.burst
        rng = np.random.default_rng(seed)
        lo, hi = 1.0 - jitter, 1.0 + jitter
        self.gaps = gap_s * rng.uniform(lo, hi, size=max(n_gaps, 1))

    def __iter__(self) -> Iterator:
        for i, pane in enumerate(self.panes):
            if i and i % self.burst == 0:
                gap = self.gaps[i // self.burst - 1]
                if gap > 0:
                    time.sleep(float(gap))
            yield pane
