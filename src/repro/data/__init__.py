"""Data substrate: synthetic geo-referenced streams + LM token streams."""

from .streams import chicago_aq_stream, shenzhen_taxi_stream
from .tokens import StratifiedTokenStream

__all__ = ["chicago_aq_stream", "shenzhen_taxi_stream", "StratifiedTokenStream"]
