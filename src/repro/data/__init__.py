"""Data substrate: synthetic geo-referenced streams + LM token streams."""

from .sources import BurstySource, PacedSource
from .streams import chicago_aq_stream, shenzhen_taxi_stream
from .tokens import StratifiedTokenStream

__all__ = [
    "BurstySource",
    "PacedSource",
    "chicago_aq_stream",
    "shenzhen_taxi_stream",
    "StratifiedTokenStream",
]
