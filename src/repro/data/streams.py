"""Synthetic generators matching the paper's two evaluation datasets.

The paper evaluates on (1) Shenzhen electric-taxi GPS trajectories
(~664 vehicles, ~1.16M tuples: id, ts, lat, lon, speed) and (2) Chicago
hyperlocal air quality from Project Eclipse (~130K tuples: id, ts, lat,
lon, PM2.5).  Neither ships with this repo, so we generate streams with the
same statistical shape:

  * mobility — vehicles random-walk inside the Shenzhen bbox with strong
    spatial structure: a few dense "downtown" attractors (slow speeds, heavy
    traffic) and sparse outskirts (fast, few tuples).  Spatially-correlated
    value field => stratified sampling has signal to exploit.
  * air quality — fixed sensors, heavily clustered placement (spatial skew
    is the point of the Chicago dataset), PM2.5 = smooth spatial field +
    temporal drift + heteroscedastic noise.

Generators yield dict chunks (sensor_id, timestamp, lat, lon, value, plus a
second named value column per workload — mobility carries ``occupancy``,
air quality carries ``temperature``) so they plug straight into
core.windows, and multi-column ``Query`` aggregates have real signal to
chew on.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

from ..core.stratify import CHICAGO_BBOX, SHENZHEN_BBOX


def _attractors(rng, bbox, k):
    (lat_lo, lat_hi), (lon_lo, lon_hi) = bbox
    lats = rng.uniform(lat_lo + 0.1 * (lat_hi - lat_lo), lat_hi - 0.1 * (lat_hi - lat_lo), k)
    lons = rng.uniform(lon_lo + 0.1 * (lon_hi - lon_lo), lon_hi - 0.1 * (lon_hi - lon_lo), k)
    return np.stack([lats, lons], axis=1)


def shenzhen_taxi_stream(
    num_vehicles: int = 664,
    chunk_size: int = 20_000,
    num_chunks: int = 60,
    seed: int = 0,
    bbox=SHENZHEN_BBOX,
) -> Iterator[dict]:
    """Mobility stream: ~num_chunks * chunk_size tuples of (id,ts,lat,lon,speed)."""
    rng = np.random.default_rng(seed)
    (lat_lo, lat_hi), (lon_lo, lon_hi) = bbox
    centers = _attractors(rng, bbox, 5)
    # each vehicle orbits a home attractor; 70% of vehicles in the top-2
    home = rng.choice(len(centers), num_vehicles, p=[0.45, 0.25, 0.15, 0.10, 0.05])
    pos = centers[home] + rng.normal(0, 0.02, (num_vehicles, 2))
    t = 0.0
    for _ in range(num_chunks):
        ids = rng.integers(0, num_vehicles, chunk_size)
        # random walk + pull toward home attractor
        step = rng.normal(0, 0.004, (chunk_size, 2))
        pull = (centers[home[ids]] - pos[ids]) * 0.05
        pos_ids = pos[ids] + step + pull
        pos_ids[:, 0] = np.clip(pos_ids[:, 0], lat_lo, lat_hi)
        pos_ids[:, 1] = np.clip(pos_ids[:, 1], lon_lo, lon_hi)
        pos[ids] = pos_ids
        # speed: slow near attractors (congestion), faster outside; spatially
        # smooth with vehicle-level noise.
        d = np.min(
            np.linalg.norm(pos_ids[:, None, :] - centers[None, :, :], axis=-1), axis=1
        )
        speed = 12.0 + 55.0 * np.tanh(d / 0.08) + rng.normal(0, 4.0, chunk_size)
        speed = np.clip(speed, 0.0, 120.0)
        # occupancy: taxis near attractors are likelier to carry a fare —
        # anti-correlated with speed, spatially smooth (a second column for
        # multi-aggregate queries).
        occupancy = np.clip(
            0.85 - 0.6 * np.tanh(d / 0.08) + rng.normal(0, 0.08, chunk_size), 0.0, 1.0
        )
        ts = t + np.sort(rng.uniform(0, 60.0, chunk_size))
        t += 60.0
        yield dict(
            sensor_id=ids.astype(np.int32),
            timestamp=ts,
            lat=pos_ids[:, 0].astype(np.float32),
            lon=pos_ids[:, 1].astype(np.float32),
            value=speed.astype(np.float32),
            occupancy=occupancy.astype(np.float32),
        )


def chicago_aq_stream(
    num_sensors: int = 120,
    chunk_size: int = 10_000,
    num_chunks: int = 13,
    seed: int = 1,
    bbox=CHICAGO_BBOX,
) -> Iterator[dict]:
    """Air-quality stream: clustered fixed sensors, smooth PM2.5 field."""
    rng = np.random.default_rng(seed)
    (lat_lo, lat_hi), (lon_lo, lon_hi) = bbox
    clusters = _attractors(rng, bbox, 4)
    which = rng.choice(len(clusters), num_sensors, p=[0.5, 0.3, 0.15, 0.05])
    sensor_pos = clusters[which] + rng.normal(0, 0.015, (num_sensors, 2))
    sensor_pos[:, 0] = np.clip(sensor_pos[:, 0], lat_lo, lat_hi)
    sensor_pos[:, 1] = np.clip(sensor_pos[:, 1], lon_lo, lon_hi)
    # smooth spatial PM2.5 baseline per sensor
    base = (
        18.0
        + 14.0 * np.sin((sensor_pos[:, 0] - lat_lo) / (lat_hi - lat_lo) * np.pi)
        + 9.0 * np.cos((sensor_pos[:, 1] - lon_lo) / (lon_hi - lon_lo) * 2 * np.pi)
    )
    t = 0.0
    for c in range(num_chunks):
        ids = rng.integers(0, num_sensors, chunk_size)
        drift = 4.0 * np.sin(2 * np.pi * (t / 86_400.0))  # diurnal cycle
        pm = base[ids] + drift + rng.gamma(2.0, 1.5, chunk_size) - 3.0
        pm = np.clip(pm, 0.5, 150.0)
        # temperature: lakefront gradient + diurnal swing + sensor noise (a
        # second column so one window answers PM2.5 and temperature queries).
        temp = (
            22.0
            - 6.0 * (sensor_pos[ids, 1] - lon_lo) / (lon_hi - lon_lo)
            + 5.0 * np.sin(2 * np.pi * (t / 86_400.0) - np.pi / 3)
            + rng.normal(0, 0.8, chunk_size)
        )
        ts = t + np.sort(rng.uniform(0, 600.0, chunk_size))
        t += 600.0
        yield dict(
            sensor_id=ids.astype(np.int32),
            timestamp=ts,
            lat=sensor_pos[ids, 0].astype(np.float32),
            lon=sensor_pos[ids, 1].astype(np.float32),
            value=pm.astype(np.float32),
            temperature=temp.astype(np.float32),
        )


def materialize(stream: Iterator[dict]) -> dict:
    """Concatenate a finite stream into one dict of arrays (for baselines)."""
    chunks = list(stream)
    return {k: np.concatenate([c[k] for c in chunks]) for k in chunks[0]}
