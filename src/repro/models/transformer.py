"""Decoder-only LM assembly for all decoder families.

One module covers: dense GQA transformers (mistral/deepseek/internlm2/
qwen1.5), VLM backbones (qwen2-vl, M-RoPE + embeddings-in), MoE
(granite/olmoe), SSM-only (xlstm's sibling path), xLSTM stacks, and the
Zamba2 hybrid (Mamba-2 + shared attention block).

Structure notes:
  * homogeneous stacks scan over layers (stacked params, one compiled layer
    body, jax.checkpoint remat policy from cfg.remat);
  * heterogeneous stacks (xLSTM's 7:1 mLSTM:sLSTM, Zamba2's shared-attn
    every N mamba layers) run a python loop over *groups*, scanning within
    each group — HLO stays small (one loop body per block type);
  * activations carry logical sharding constraints at layer boundaries so
    the saved scan carries can be sequence-sharded (SP) on big meshes.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..sharding.logical import constrain, mesh_axis_size
from .base import ModelConfig, ParamSpec, stack_specs, tree_slice
from . import layers as L
from . import moe as M
from . import ssm as S
from . import xlstm as X


class Batch(NamedTuple):
    """Training batch. ``tokens`` is int32 ids or f32 embeddings (B,S,d)
    for embeddings-in modality stubs; EdgeSOS fields drive the weighted
    loss + stratified telemetry (paper integration)."""

    tokens: jnp.ndarray
    targets: jnp.ndarray
    positions: jnp.ndarray  # (B,S) or (3,B,S) for M-RoPE
    seq_weight: jnp.ndarray  # (B,) Horvitz-Thompson weights (1.0 = unsampled)
    stratum: jnp.ndarray  # (B,) data stratum id for telemetry
    stratum_counts: jnp.ndarray  # (num_strata+1,) window population N_k


def _remat(fn, cfg: ModelConfig):
    remat = getattr(cfg, "remat", "full")
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if remat == "offload":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=["residual"],
                offload_src="device",
                offload_dst="pinned_host",
            ),
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _dense_layer_specs(cfg: ModelConfig) -> dict:
    spec = {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        spec["moe"] = M.moe_specs(cfg)
    else:
        spec["mlp"] = L.mlp_specs(cfg)
    return spec


def _mamba_layer_specs(cfg: ModelConfig) -> dict:
    return {"ln": L.rmsnorm_spec(cfg.d_model), "mamba": S.mamba2_specs(cfg)}


def param_specs(cfg: ModelConfig) -> dict:
    specs: dict[str, Any] = {"embedding": L.embedding_specs(cfg)}
    specs["final_norm"] = L.rmsnorm_spec(cfg.d_model)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        layer = _dense_layer_specs(cfg)
        specs["layers"] = jax.tree.map(
            lambda s: stack_specs(s, cfg.num_layers),
            layer,
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
    elif fam == "xlstm":
        n_groups, n_m = _xlstm_layout(cfg)
        specs["mlstm"] = jax.tree.map(
            lambda s: stack_specs(s, n_groups * n_m),
            X.mlstm_specs(cfg),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        if cfg.slstm_every > 0:
            specs["slstm"] = jax.tree.map(
                lambda s: stack_specs(s, n_groups),
                X.slstm_specs(cfg),
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
    elif fam == "hybrid":
        specs["mamba"] = jax.tree.map(
            lambda s: stack_specs(s, cfg.num_layers),
            _mamba_layer_specs(cfg),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
        # one shared attention+MLP block, reused at every cadence point
        specs["shared"] = {
            "ln1": L.rmsnorm_spec(cfg.d_model),
            "attn": L.attention_specs(cfg),
            "ln2": L.rmsnorm_spec(cfg.d_model),
            "mlp": L.mlp_specs(cfg),
        }
    elif fam == "ssm":
        specs["layers"] = jax.tree.map(
            lambda s: stack_specs(s, cfg.num_layers),
            _mamba_layer_specs(cfg),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        )
    else:
        raise ValueError(f"unknown family {fam}")
    return specs


def _xlstm_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(num_groups, mlstm_per_group). slstm_every=8 -> groups of 7 mLSTM + 1 sLSTM."""
    if cfg.slstm_every <= 0:
        return 1, cfg.num_layers
    assert cfg.num_layers % cfg.slstm_every == 0, (cfg.num_layers, cfg.slstm_every)
    return cfg.num_layers // cfg.slstm_every, cfg.slstm_every - 1


def _hybrid_groups(cfg: ModelConfig) -> list[tuple[int, int]]:
    """[(start, end)] mamba layer ranges; shared attn runs after each group."""
    n, k = cfg.num_layers, cfg.shared_attn_every
    if k <= 0:
        return [(0, n)]
    return [(s, min(s + k, n)) for s in range(0, n, k)]


# ---------------------------------------------------------------------------
# Forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def _dense_layer_fwd(p: dict, x: jnp.ndarray, cfg: ModelConfig, positions) -> tuple[jnp.ndarray, dict]:
    aux: dict = {}
    h = L.self_attention(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, positions,
                         window=cfg.attention_window)
    x = x + h
    xn = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        h, aux = M.moe_ffn(p["moe"], xn, cfg)
    else:
        h = L.mlp(p["mlp"], xn, cfg)
    x = x + h
    x = constrain(x, ("batch", "seq_sp", "act_embed"))
    return x, aux


def _mamba_layer_fwd(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = x + S.mamba2_forward(p["mamba"], L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg)
    return constrain(x, ("batch", "seq_sp", "act_embed"))


def _scan(body, x, stacked_params, cfg: ModelConfig):
    wrapped = _remat(body, cfg)

    def scan_body(carry, p):
        out, aux = wrapped(p, carry)
        return out, aux

    x, auxs = jax.lax.scan(scan_body, x, stacked_params)
    return x, auxs


def forward(params: dict, cfg: ModelConfig, tokens, positions) -> tuple[jnp.ndarray, dict]:
    """Token ids (or stub embeddings) -> final hidden states. Returns aux."""
    if cfg.embeddings_in:
        x = tokens.astype(cfg.dtype)
    else:
        x = L.embed_tokens(params["embedding"], tokens, cfg)
    x = constrain(x, ("batch", "seq_sp", "act_embed"))
    aux_out: dict = {}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        x, auxs = _scan(
            lambda p, h: _dense_layer_fwd(p, h, cfg, positions), x, params["layers"], cfg
        )
        if auxs:
            aux_out = {k: jnp.mean(v) for k, v in auxs.items()}
    elif fam == "ssm":
        x, _ = _scan(lambda p, h: (_mamba_layer_fwd(p, h, cfg), {}), x, params["layers"], cfg)
    elif fam == "xlstm":
        n_groups, n_m = _xlstm_layout(cfg)
        ml_fwd = _remat(lambda p, h: (X.mlstm_forward(p, h, cfg), {}), cfg)
        for g in range(n_groups):
            grp = tree_slice(params["mlstm"], g * n_m, (g + 1) * n_m)
            x, _ = jax.lax.scan(lambda c, p: ml_fwd(p, c), x, grp)
            if cfg.slstm_every > 0:
                sp = tree_slice(params["slstm"], g, g + 1)
                sp = jax.tree.map(lambda a: a[0], sp)
                x = _remat(lambda p, h: X.slstm_forward(p, h, cfg), cfg)(sp, x)
            x = constrain(x, ("batch", "seq_sp", "act_embed"))
    elif fam == "hybrid":
        mb_fwd = _remat(lambda p, h: (_mamba_layer_fwd(p, h, cfg), {}), cfg)
        sh_fwd = _remat(
            lambda p, h: _dense_layer_fwd(p, h, cfg.replace(family="dense"), positions)[0], cfg
        )
        for start, end in _hybrid_groups(cfg):
            grp = tree_slice(params["mamba"], start, end)
            x, _ = jax.lax.scan(lambda c, p: mb_fwd(p, c), x, grp)
            x = sh_fwd(params["shared"], x)
    else:
        raise ValueError(fam)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_out


def loss_fn(params: dict, cfg: ModelConfig, batch: Batch):
    """Weighted CE + MoE aux + stratified loss telemetry (paper eqs 4-10)."""
    from ..core import estimators  # local import to avoid cycles

    hidden, aux = forward(params, cfg, batch.tokens, batch.positions)
    logits = L.logits_fn(params["embedding"], hidden, cfg)
    logits = constrain(logits, ("batch", "seq_sp", "act_vocab"))
    tok_mask = (batch.targets >= 0).astype(jnp.float32)
    loss, per_seq = L.weighted_ce(logits, jnp.maximum(batch.targets, 0), batch.seq_weight, tok_mask)
    total = loss
    metrics = {"ce_loss": loss, **aux}
    if "moe_aux_loss" in aux:
        total = total + 0.01 * aux["moe_aux_loss"]
    # stratified loss estimate with error bounds over the data strata
    ns = cfg.data_num_strata + 1
    sampled = batch.seq_weight > 0
    stats = estimators.sample_stats(per_seq, batch.stratum, sampled, ns, counts=batch.stratum_counts)
    est = estimators.estimate(stats)
    metrics["stratified_loss_mean"] = est.mean
    metrics["stratified_loss_moe"] = est.moe
    metrics["stratified_loss_re"] = est.relative_error
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    """Per-family decode state.

    dense/moe/vlm: kv caches stacked over layers (L,B,T,K,dh).
    ssm/xlstm/hybrid: recurrent states (see family modules); hybrid also
    carries windowed KV caches for the shared attention block invocations.
    """

    data: Any
    pos: jnp.ndarray  # scalar int32: tokens already consumed


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> DecodeState:
    fam = cfg.family
    K, dh = cfg.num_kv_heads, cfg.dh
    if fam in ("dense", "moe", "vlm"):
        kv = {
            "k": jnp.zeros((cfg.num_layers, batch, max_len, K, dh), cfg.dtype),
            "v": jnp.zeros((cfg.num_layers, batch, max_len, K, dh), cfg.dtype),
        }
        return DecodeState(data=kv, pos=jnp.int32(0))
    if fam == "ssm":
        states = [S.mamba2_init_state(cfg, batch) for _ in range(cfg.num_layers)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        return DecodeState(data=stacked, pos=jnp.int32(0))
    if fam == "xlstm":
        n_groups, n_m = _xlstm_layout(cfg)
        ml = [X.mlstm_init_state(cfg, batch) for _ in range(n_groups * n_m)]
        data = {"mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *ml)}
        if cfg.slstm_every > 0:
            sl = [X.slstm_init_state(cfg, batch) for _ in range(n_groups)]
            data["slstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sl)
        return DecodeState(data=data, pos=jnp.int32(0))
    if fam == "hybrid":
        groups = _hybrid_groups(cfg)
        mm = [S.mamba2_init_state(cfg, batch) for _ in range(cfg.num_layers)]
        win = cfg.attention_window or max_len
        kv = {
            "k": jnp.zeros((len(groups), batch, min(win, max_len), K, dh), cfg.dtype),
            "v": jnp.zeros((len(groups), batch, min(win, max_len), K, dh), cfg.dtype),
        }
        return DecodeState(
            data={"mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mm), "shared_kv": kv},
            pos=jnp.int32(0),
        )
    raise ValueError(fam)


def _attn_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig, k_cache, v_cache, pos):
    """One-token attention for a single layer given its cache slices."""
    B = x.shape[0]
    q, k, v = L.attention_qkv(p, x[:, None, :], cfg)
    T = k_cache.shape[1]
    write_at = jnp.minimum(pos, T - 1) if cfg.attention_window else pos
    positions = jnp.broadcast_to(pos, (B, 1))
    if cfg.mrope_sections:
        q = L.apply_mrope(q, jnp.broadcast_to(pos, (3, B, 1)), cfg.mrope_sections, cfg.rope_theta)
        k = L.apply_mrope(k, jnp.broadcast_to(pos, (3, B, 1)), cfg.mrope_sections, cfg.rope_theta)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    if cfg.attention_window and cfg.attention_window < 10**9:
        # ring-buffer windowed cache (positions folded mod window)
        write_at = jnp.mod(pos, k_cache.shape[1])
        length = jnp.minimum(pos + 1, k_cache.shape[1])
    else:
        write_at = pos
        length = pos + 1
    tp = mesh_axis_size("model")
    if tp > 1 and cfg.num_kv_heads % tp != 0 and cfg.num_heads % tp == 0:
        # sequence-sharded cache layout -> distributed flash-decode with the
        # cache update fused inside the shard_map (GSPMD's update on a
        # sharded dim gathers the whole cache otherwise)
        o, k_cache, v_cache = L.sharded_decode_attention(
            q, k_cache, v_cache, length, k_new=k, v_new=v, write_at=write_at
        )
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), write_at, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), write_at, axis=1)
        o = L.decode_attention(q, k_cache, v_cache, length)
    return L.attention_out(p, o, cfg), k_cache, v_cache


def decode_step(params: dict, cfg: ModelConfig, state: DecodeState, tokens: jnp.ndarray):
    """One decode step for the whole batch. tokens: (B,) ids or (B,d) embeds."""
    fam = cfg.family
    pos = state.pos
    if cfg.embeddings_in:
        x = tokens.astype(cfg.dtype)
    else:
        x = jnp.take(params["embedding"]["tok"].astype(cfg.dtype), tokens, axis=0)
    if fam in ("dense", "moe", "vlm"):

        def body(carry, xs):
            h = carry
            p, kc, vc = xs
            # barrier: stops XLA:CPU from keeping a hoisted f32 shadow copy
            # of the whole stacked cache across loop iterations
            kc, vc = jax.lax.optimization_barrier((kc, vc))
            a, kc, vc = _attn_decode(p["attn"], L.rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, kc, vc, pos)
            h = h + a[:, 0, :]
            hn = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f, _ = M.moe_ffn(p["moe"], hn[:, None, :], cfg)
                h = h + f[:, 0, :]
            else:
                h = h + L.mlp(p["mlp"], hn[:, None, :], cfg)[:, 0, :]
            return h, jax.lax.optimization_barrier((kc, vc))

        x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], state.data["k"], state.data["v"]))
        new_state = DecodeState(data={"k": k_new, "v": v_new}, pos=pos + 1)
    elif fam == "ssm":

        def body(carry, xs):
            h = carry
            p, st = xs
            out, st2 = S.mamba2_step(p["mamba"], st, L.rmsnorm(h, p["ln"], cfg.norm_eps), cfg)
            return h + out, st2

        x, st_new = jax.lax.scan(body, x, (params["layers"], state.data))
        new_state = DecodeState(data=st_new, pos=pos + 1)
    elif fam == "xlstm":
        n_groups, n_m = _xlstm_layout(cfg)

        def ml_body(carry, xs):
            p, st = xs
            out, st2 = X.mlstm_block_step(p, st, carry, cfg)
            return out, st2

        new_ml, new_sl = [], []
        for g in range(n_groups):
            grp_p = tree_slice(params["mlstm"], g * n_m, (g + 1) * n_m)
            grp_s = tree_slice(state.data["mlstm"], g * n_m, (g + 1) * n_m)
            x, ml_s = jax.lax.scan(ml_body, x, (grp_p, grp_s))
            new_ml.append(ml_s)
            if cfg.slstm_every > 0:
                sp = jax.tree.map(lambda a: a[g], params["slstm"])
                ss = jax.tree.map(lambda a: a[g], state.data["slstm"])
                x, sl_s = X.slstm_block_step(sp, ss, x, cfg)
                new_sl.append(sl_s)
        data = {"mlstm": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_ml)}
        if new_sl:
            data["slstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_sl)
        new_state = DecodeState(data=data, pos=pos + 1)
    elif fam == "hybrid":
        groups = _hybrid_groups(cfg)

        def mb_body(carry, xs):
            p, st = xs
            out, st2 = S.mamba2_step(p["mamba"], st, L.rmsnorm(carry, p["ln"], cfg.norm_eps), cfg)
            return carry + out, st2

        sh = params["shared"]
        new_mamba, new_k, new_v = [], [], []
        for gi, (start, end) in enumerate(groups):
            grp_p = tree_slice(params["mamba"], start, end)
            grp_s = tree_slice(state.data["mamba"], start, end)
            x, st2 = jax.lax.scan(mb_body, x, (grp_p, grp_s))
            new_mamba.append(st2)
            kc = state.data["shared_kv"]["k"][gi]
            vc = state.data["shared_kv"]["v"][gi]
            a, kc, vc = _attn_decode(sh["attn"], L.rmsnorm(x, sh["ln1"], cfg.norm_eps), cfg, kc, vc, pos)
            x = x + a[:, 0, :]
            x = x + L.mlp(sh["mlp"], L.rmsnorm(x, sh["ln2"], cfg.norm_eps)[:, None, :], cfg)[:, 0, :]
            new_k.append(kc)
            new_v.append(vc)
        data = {
            "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_mamba),
            "shared_kv": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
        }
        new_state = DecodeState(data=data, pos=pos + 1)
    else:
        raise ValueError(fam)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.logits_fn(params["embedding"], x[:, None, :], cfg)[:, 0, :]
    return logits, new_state


def prefill(params: dict, cfg: ModelConfig, tokens, positions, max_len: int | None = None):
    """Run the trunk over a prompt, returning (last-token logits, DecodeState).

    Only attention families need a materialized KV cache; recurrent families
    re-run their chunked forward and keep the final state (cheap relative to
    the trunk).  For attention families we recompute k/v projections from
    the hidden states — one extra (S,d)x(d,K*dh) GEMM per layer, traded for
    not threading caches through the scanned trunk.
    """
    fam = cfg.family
    B, Sq = tokens.shape[:2]
    max_len = max_len or Sq
    if fam in ("dense", "moe", "vlm"):
        # capture per-layer k/v by scanning with ys
        if cfg.embeddings_in:
            x = tokens.astype(cfg.dtype)
        else:
            x = L.embed_tokens(params["embedding"], tokens, cfg)
        x = constrain(x, ("batch", "seq_sp", "act_embed"))

        def body(carry, p):
            h = carry
            hn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
            q, k, v = L.attention_qkv(p["attn"], hn, cfg)
            if cfg.mrope_sections:
                qr = L.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
                kr = L.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
            else:
                pos2d = positions if positions.ndim == 2 else positions[0]
                qr = L.apply_rope(q, pos2d, cfg.rope_theta)
                kr = L.apply_rope(k, pos2d, cfg.rope_theta)
            # compute attention in the TP layout (q heads @ model, kv
            # replicated when kv %% tp != 0) — without this the cache's
            # seq@model constraint back-propagates into kr/v and GSPMD
            # all-gathers the full probability tensor (24 GiB/chip measured
            # on mistral prefill; §Perf iteration 9).  Skipped when heads
            # don't divide the model axis (pinning replication is worse).
            if cfg.num_heads % max(mesh_axis_size("model"), 1) == 0:
                qr = constrain(qr, ("batch", None, "act_heads", None))
                ka = constrain(kr, ("batch", None, "kv_heads", None))
                va = constrain(v, ("batch", None, "kv_heads", None))
            else:
                ka, va = kr, v
            o = L.chunked_causal_attention(qr, ka, va, q_chunk=min(cfg.chunk_size * 4, Sq),
                                           window=cfg.attention_window)
            h = h + L.attention_out(p["attn"], o, cfg)
            hn2 = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                f, _ = M.moe_ffn(p["moe"], hn2, cfg)
            else:
                f = L.mlp(p["mlp"], hn2, cfg)
            h = h + f
            h = constrain(h, ("batch", "seq_sp", "act_embed"))
            # cache layout: shard KV heads over the model axis when they
            # divide it, else shard the sequence dim (flash-decode layout)
            if cfg.num_kv_heads % max(mesh_axis_size("model"), 1) == 0:
                cache_axes = ("batch", None, "kv_heads", None)
            else:
                cache_axes = ("batch", "cache_seq", None, None)
            kc = constrain(kr.astype(cfg.dtype), cache_axes)
            vc = constrain(v.astype(cfg.dtype), cache_axes)
            return h, (kc, vc)

        body = _remat(body, cfg)
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        if max_len > Sq:
            pad = ((0, 0), (0, 0), (0, max_len - Sq), (0, 0), (0, 0))
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        state = DecodeState(data={"k": ks, "v": vs}, pos=jnp.int32(Sq))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.logits_fn(params["embedding"], x[:, -1:, :], cfg)[:, 0, :]
        return logits, state
    # recurrent families: run forward for logits; states via scan would need
    # the final chunk states — supported by rerunning per family if needed.
    hidden, _ = forward(params, cfg, tokens, positions)
    logits = L.logits_fn(params["embedding"], hidden[:, -1:, :], cfg)[:, 0, :]
    state = init_decode_state(cfg, B, max_len)
    return logits, DecodeState(data=state.data, pos=jnp.int32(Sq))
