"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is gated linear attention with exponential input gates and a
normalizer state — it reuses ``chunked_gla(normalize=True)`` with the input
gate folded into k.  Exponential gates are clamped (<= 8 in log space)
instead of carrying the paper's running-max stabilizer; with the normalizer
division the outputs match the reference recurrence to fp32 tolerance on
realistic gate ranges (tested), and the chunked math stays a pair of
MXU-friendly (C x C) matmuls.

sLSTM is the scalar-memory recurrence; we use the input-conditioned variant
(gates do not read h_{t-1}) so the whole layer is one associative scan —
O(log S) depth on TPU instead of an S-step serial loop.  This is the main
TPU adaptation for this architecture (documented in DESIGN.md): the exact
h-feedback variant has a serial dependence with no parallel form.

Blocks follow xLSTM-1.3B structure: pre-norm residual blocks with internal
up/down projection (no separate FFN; d_ff = 0 in the config).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import ModelConfig, ParamSpec
from .layers import rmsnorm
from .linear_attention import chunked_gla, gla_step, slstm_scan, slstm_step

I_CLAMP = 8.0


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_up = 2 * d  # xLSTM projection factor 2
    H = cfg.num_heads
    dk = d_up // H
    return {
        "norm": ParamSpec((d,), jnp.float32, (None,), init="ones"),
        "up": ParamSpec((d, 2 * d_up), cfg.param_dtype, ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_width, d_up), cfg.param_dtype, ("conv", "act_mlp")),
        "conv_b": ParamSpec((d_up,), cfg.param_dtype, ("act_mlp",), init="zeros"),
        # block-diagonal per-head projections (xLSTM's BlockLinear): each
        # head projects its own d_up/H slice — H x dk x dk, not d_up x d_up
        "wq": ParamSpec((H, dk, dk), cfg.param_dtype, ("heads", "head_dim", None)),
        "wk": ParamSpec((H, dk, dk), cfg.param_dtype, ("heads", "head_dim", None)),
        "wv": ParamSpec((H, dk, dk), cfg.param_dtype, ("heads", "head_dim", None)),
        "w_igate": ParamSpec((d_up, H), jnp.float32, ("mlp", "heads"), init="zeros"),
        "w_fgate": ParamSpec((d_up, H), jnp.float32, ("mlp", "heads"), init="zeros"),
        "b_igate": ParamSpec((H,), jnp.float32, (None,), init="zeros"),
        "b_fgate": ParamSpec((H,), jnp.float32, (None,), init="ones"),
        "out_norm": ParamSpec((d_up,), jnp.float32, (None,), init="ones"),
        "down": ParamSpec((d_up, d), cfg.param_dtype, ("mlp", "embed"), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(out + b)


def _mlstm_qkv_gates(p: dict, xm: jnp.ndarray, xc: jnp.ndarray, cfg: ModelConfig):
    """Shared between forward and step: q,k,v + log gates from conv/raw path."""
    d_up = xm.shape[-1]
    H = cfg.num_heads
    dk = d_up // H
    dt = cfg.dtype
    xc_h = xc.reshape(xc.shape[:-1] + (H, dk))
    xm_h = xm.reshape(xm.shape[:-1] + (H, dk))
    q = jnp.einsum("...hk,hkd->...hd", xc_h, p["wq"].astype(dt))
    k = jnp.einsum("...hk,hkd->...hd", xc_h, p["wk"].astype(dt)) / (dk**0.5)
    v = jnp.einsum("...hk,hkd->...hd", xm_h, p["wv"].astype(dt))
    i_logit = jnp.einsum("...k,kh->...h", xc.astype(jnp.float32), p["w_igate"]) + p["b_igate"]
    f_logit = jnp.einsum("...k,kh->...h", xc.astype(jnp.float32), p["w_fgate"]) + p["b_fgate"]
    log_f = jax.nn.log_sigmoid(f_logit)
    i_gate = jnp.exp(jnp.minimum(i_logit, I_CLAMP))
    k = k * i_gate[..., None].astype(dt)  # fold input gate into keys
    return q, k, v, log_f


def mlstm_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, S, d = x.shape
    d_up = 2 * d
    dt = cfg.dtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", xn, p["up"].astype(dt))
    xm, z = proj[..., :d_up], proj[..., d_up:]
    xc = _causal_conv(xm, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    q, k, v, log_f = _mlstm_qkv_gates(p, xm, xc, cfg)
    h, _ = chunked_gla(q, k, v, log_f, chunk_size=cfg.chunk_size, normalize=True)
    h = h.reshape(B, S, d_up)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + jnp.einsum("bsk,kd->bsd", h, p["down"].astype(dt))


class MLSTMState(NamedTuple):
    s: jnp.ndarray  # (B, H, dk, dk+1) matrix memory + normalizer column
    conv: jnp.ndarray  # (B, W-1, d_up)


def mlstm_init_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    d_up = 2 * cfg.d_model
    dk = d_up // cfg.num_heads
    return MLSTMState(
        s=jnp.zeros((batch, cfg.num_heads, dk, dk + 1), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_up), cfg.dtype),
    )


def mlstm_block_step(p: dict, state: MLSTMState, x: jnp.ndarray, cfg: ModelConfig):
    B, d = x.shape
    d_up = 2 * d
    dt = cfg.dtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bd,dk->bk", xn, p["up"].astype(dt))
    xm, z = proj[..., :d_up], proj[..., d_up:]
    hist = jnp.concatenate([state.conv, xm[:, None, :]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(dt)) + p["conv_b"].astype(dt))
    q, k, v, log_f = _mlstm_qkv_gates(p, xm, xc, cfg)
    h, s = gla_step(state.s, q, k, v, log_f, normalize=True)
    h = h.reshape(B, d_up)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = x + jnp.einsum("bk,kd->bd", h, p["down"].astype(dt))
    return out, MLSTMState(s=s, conv=hist[:, 1:, :])


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    f_up = (4 * d) // 3
    return {
        "norm": ParamSpec((d,), jnp.float32, (None,), init="ones"),
        "conv_w": ParamSpec((cfg.conv_width, d), cfg.param_dtype, ("conv", "act_mlp")),
        "conv_b": ParamSpec((d,), cfg.param_dtype, ("act_mlp",), init="zeros"),
        "w_gates": ParamSpec((d, 4, H, dh), cfg.param_dtype, ("embed", None, "heads", "head_dim")),
        "b_gates": ParamSpec((4, H, dh), cfg.param_dtype, (None, "heads", "head_dim"), init="zeros"),
        "out_norm": ParamSpec((d,), jnp.float32, (None,), init="ones"),
        # gated FFN with 4/3 projection factor (xLSTM paper)
        "ffn_gate": ParamSpec((d, f_up), cfg.param_dtype, ("embed", "mlp")),
        "ffn_up": ParamSpec((d, f_up), cfg.param_dtype, ("embed", "mlp")),
        "ffn_down": ParamSpec((f_up, d), cfg.param_dtype, ("mlp", "embed"), init="scaled"),
    }


def _slstm_gates(p: dict, xc: jnp.ndarray, cfg: ModelConfig):
    g = jnp.einsum("...d,dghk->...ghk", xc, p["w_gates"].astype(cfg.dtype)) + p["b_gates"].astype(cfg.dtype)
    i_l = jnp.mean(g[..., 0, :, :], axis=-1).astype(jnp.float32)  # (…, H)
    f_l = jnp.mean(g[..., 1, :, :], axis=-1).astype(jnp.float32)
    z = g[..., 2, :, :]
    o = g[..., 3, :, :]
    return f_l, i_l, z, o


def slstm_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, S, d = x.shape
    H = cfg.num_heads
    dt = cfg.dtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    xc = _causal_conv(xn, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    f_l, i_l, z, o = _slstm_gates(p, xc, cfg)
    h = slstm_scan(f_l, i_l, z, o, I_CLAMP)  # (B,S,H,dh)
    h = h.reshape(B, S, d)
    x = x + rmsnorm(h, p["out_norm"], cfg.norm_eps)
    # gated FFN
    xn2 = rmsnorm(x, p["norm"], cfg.norm_eps)
    gate = jnp.einsum("bsd,df->bsf", xn2, p["ffn_gate"].astype(dt))
    up = jnp.einsum("bsd,df->bsf", xn2, p["ffn_up"].astype(dt))
    return x + jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, p["ffn_down"].astype(dt))


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, H, dh)
    n: jnp.ndarray  # (B, H, 1)
    conv: jnp.ndarray  # (B, W-1, d)


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    H = cfg.num_heads
    return SLSTMState(
        c=jnp.zeros((batch, H, d // H), jnp.float32),
        n=jnp.zeros((batch, H, 1), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, d), cfg.dtype),
    )


def slstm_block_step(p: dict, state: SLSTMState, x: jnp.ndarray, cfg: ModelConfig):
    B, d = x.shape
    dt = cfg.dtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    hist = jnp.concatenate([state.conv, xn[:, None, :]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(dt)) + p["conv_b"].astype(dt))
    f_l, i_l, z, o = _slstm_gates(p, xc, cfg)
    h, (c, n) = slstm_step((state.c, state.n), f_l, i_l, z, o, I_CLAMP)
    h = h.reshape(B, d)
    x = x + rmsnorm(h, p["out_norm"], cfg.norm_eps)
    xn2 = rmsnorm(x, p["norm"], cfg.norm_eps)
    gate = jnp.einsum("bd,df->bf", xn2, p["ffn_gate"].astype(dt))
    up = jnp.einsum("bd,df->bf", xn2, p["ffn_up"].astype(dt))
    out = x + jnp.einsum("bf,fd->bd", jax.nn.silu(gate) * up, p["ffn_down"].astype(dt))
    return out, SLSTMState(c=c, n=n, conv=hist[:, 1:, :])
