"""Mixture-of-Experts FFN with shuffle-free expert parallelism.

Dispatch is the same rank-in-group primitive EdgeSOS uses for within-stratum
sampling (one stable sort + segment offsets): "experts" are strata and
capacity clipping is per-stratum allocation.

Distribution (the paper's routing idea applied to EP): activations are
data-sharded and *replicated over the model axis*, so each model shard can
gather the assignments of its own experts locally — no token all-to-all at
all.  Each shard computes its experts' contributions to all local tokens and
a single psum over the model axis combines them.  Under shard_map this is
explicit and GSPMD cannot de-optimize it into gathers (the naive jit
lowering of scatter-based dispatch replicated the (E*C, d) buffer and blew
past HBM — see EXPERIMENTS.md §Perf for the before/after).

Two sharding modes, picked by divisibility:
  * E %% tp == 0  -> experts sharded over "model" (true EP; olmoe 64/16);
  * otherwise     -> experts replicated, per-expert FFN dim sharded over
                     "model" (granite: 40 experts, d_ff 512 -> 32/shard);
                     the down-projection contraction makes the same psum
                     combine partial results.

Compiled FLOPs are ~ k * cf * (dense cost): proportional to *active*
experts, keeping MODEL_FLOPS/HLO_FLOPs honest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding.compat import compat_shard_map

from ..sharding.logical import active_rules
from .base import ModelConfig, ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamSpec((d, E), jnp.float32, ("embed", None)),
        "w_gate": ParamSpec((E, d, f), cfg.param_dtype, ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec((E, d, f), cfg.param_dtype, ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec((E, f, d), cfg.param_dtype, ("experts", "expert_mlp", "embed"), init="scaled"),
    }


def _capacity(num_tokens: int, num_experts: int, cfg: ModelConfig) -> int:
    k, cf = cfg.num_experts_per_tok, cfg.moe_capacity_factor
    c = int((num_tokens * k * cf) / num_experts) + 1
    return max(8, ((c + 7) // 8) * 8)  # pad for lane alignment


def _route(xf: jnp.ndarray, router: jnp.ndarray, k: int):
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def _dispatch_compute(xf, top_e, top_p, wg, wu, wd, num_slots: int, C: int, dtype):
    """Sort-based capacity dispatch over ``num_slots`` (local) experts.

    top_e holds *local* expert ids in [0, num_slots); ids == num_slots are
    foreign (another shard's expert) and fall into the drop slot.
    """
    T, d = xf.shape
    k = top_e.shape[-1]
    a_expert = top_e.reshape(-1)
    a_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    a_w = top_p.reshape(-1).astype(dtype)
    order = jnp.argsort(a_expert, stable=True)
    e_sorted = a_expert[order]
    counts = jax.ops.segment_sum(jnp.ones((T * k,), jnp.int32), a_expert, num_segments=num_slots + 1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
    keep = (rank_sorted < C) & (e_sorted < num_slots)
    slot = jnp.where(keep, e_sorted * C + jnp.minimum(rank_sorted, C - 1), num_slots * C)
    tok_sorted = a_token[order]
    xb = jnp.zeros((num_slots * C + 1, d), dtype).at[slot].set(xf[tok_sorted].astype(dtype), mode="drop")
    xe = xb[: num_slots * C].reshape(num_slots, C, d)
    gate = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dtype))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))
    yb = ye.reshape(num_slots * C, d)
    y_sorted = jnp.where(keep[:, None], yb[jnp.minimum(slot, num_slots * C - 1)], 0.0)
    contrib = y_sorted * a_w[order][:, None]
    out = jnp.zeros((T, d), dtype).at[tok_sorted].add(contrib)
    dropped = jnp.sum(jnp.maximum(counts[:num_slots] - C, 0))
    return out, dropped


def _moe_local(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """Single-shard path (no mesh): dispatch over all experts."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(T, E, cfg)
    xf = x.reshape(T, d)
    probs, top_p, top_e = _route(xf, p["router"], k)
    out, dropped = _dispatch_compute(
        xf, top_e, top_p, p["w_gate"], p["w_up"], p["w_down"], E, C, cfg.dtype
    )
    me = jnp.mean(probs, axis=0)
    ce = jax.ops.segment_sum(jnp.ones((T * k,), jnp.float32), top_e.reshape(-1), num_segments=E)
    ce = ce / jnp.maximum(jnp.sum(ce), 1.0)
    aux_loss = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), {
        "moe_aux_loss": aux_loss,
        "moe_drop_rate": dropped / jnp.maximum(T * k, 1),
    }


def _moe_sharded(p: dict, x: jnp.ndarray, cfg: ModelConfig, rules):
    mesh = rules.mesh
    tp = mesh.shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    all_axes = dp_axes + (("model",) if tp > 1 else ())
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    ep = tp > 1 and E % tp == 0
    bspec = P(dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None), None, None)

    def local_fn(router, wg, wu, wd, xl):
        B, S, d = xl.shape
        T = B * S
        xf = xl.reshape(T, d)
        probs, top_p, top_e = _route(xf, router, k)
        if ep:
            e_loc = E // tp
            idx = jax.lax.axis_index("model")
            lo = idx * e_loc
            mine = (top_e >= lo) & (top_e < lo + e_loc)
            local_ids = jnp.where(mine, top_e - lo, e_loc)
            C = _capacity(T, E, cfg)
            out, dropped = _dispatch_compute(xf, local_ids, top_p, wg, wu, wd, e_loc, C, cfg.dtype)
        else:
            C = _capacity(T, E, cfg)
            out, dropped = _dispatch_compute(xf, top_e, top_p, wg, wu, wd, E, C, cfg.dtype)
        if tp > 1:
            out = jax.lax.psum(out, "model")
            dropped = jax.lax.psum(dropped, "model") if ep else dropped
        me = jnp.mean(probs, axis=0)
        ce = jax.ops.segment_sum(
            jnp.ones((T * k,), jnp.float32), top_e.reshape(-1), num_segments=E
        )
        ce = ce / jnp.maximum(jnp.sum(ce), 1.0)
        aux_loss = E * jnp.sum(me * ce)
        if all_axes:
            aux_loss = jax.lax.pmean(aux_loss, all_axes)
            drop_rate = jax.lax.pmean(dropped / jnp.maximum(T * k, 1), all_axes)
        else:
            drop_rate = dropped / jnp.maximum(T * k, 1)
        return out.reshape(B, S, d), aux_loss, drop_rate

    if ep:
        wspec_g = P("model", None, None)
        wspec_d = P("model", None, None)
    else:
        wspec_g = P(None, None, "model")
        wspec_d = P(None, "model", None)
    mapped = compat_shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(None, None), wspec_g, wspec_g, wspec_d, bspec),
        out_specs=(bspec, P(), P()),
        check_vma=False,
    )
    out, aux_loss, drop_rate = mapped(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return out, {"moe_aux_loss": aux_loss, "moe_drop_rate": drop_rate}


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B, S, d), aux metrics dict)."""
    rules = active_rules()
    if rules is None or rules.mesh is None:
        return _moe_local(p, x, cfg)
    return _moe_sharded(p, x, cfg, rules)
