"""Model zoo: family dispatch over the assigned architectures."""

from __future__ import annotations

from . import encdec, transformer
from .base import ModelConfig, ParamSpec, abstract_params, init_params, spec_axes


def param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.param_specs(cfg)
    return transformer.param_specs(cfg)


def loss_fn(params, cfg: ModelConfig, batch):
    if cfg.family == "encdec":
        return encdec.loss_fn(params, cfg, batch)
    return transformer.loss_fn(params, cfg, batch)


def decode_step(params, cfg: ModelConfig, state, tokens):
    if cfg.family == "encdec":
        return encdec.decode_step(params, cfg, state, tokens)
    return transformer.decode_step(params, cfg, state, tokens)


__all__ = [
    "ModelConfig",
    "ParamSpec",
    "abstract_params",
    "decode_step",
    "encdec",
    "init_params",
    "loss_fn",
    "param_specs",
    "spec_axes",
    "transformer",
]
