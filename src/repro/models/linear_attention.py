"""Chunked gated linear attention: the shared engine for Mamba-2 and mLSTM.

Both are instances of the recurrence (per head)

    S_t = exp(g_t) * S_{t-1} + k_t v_t^T        (dk x dv matrix state)
    y_t = S_t^T q_t

with per-step scalar log-decay g_t <= 0.  The chunked form computes, for
chunk-local cumulative decays d_t = sum_{tau<=t} g_tau:

    intra: y_t += sum_{j<=t} exp(d_t - d_j) (q_t . k_j) v_j   (C x C block)
    inter: y_t += exp(d_t) S_prev^T q_t
    state: S_new = exp(d_C) S_prev + sum_j exp(d_C - d_j) k_j v_j^T

All decay factors are <= 1 (g <= 0), so the chunked math is stable in bf16
activations with f32 decay accumulators.  The chunk size trades the
quadratic intra-chunk block against the sequential inter-chunk scan — a TPU
tiling knob (MXU-friendly C x C blocks) rather than a GPU warp trick.

The O(1)-state ``step`` form drives long-context decode (long_500k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _chunk_cumsum(g: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """(B, S, H) -> (B, NC, C, H) within-chunk inclusive cumsum (f32)."""
    B, S, H = g.shape
    gc = g.reshape(B, S // chunk, chunk, H).astype(jnp.float32)
    return jnp.cumsum(gc, axis=2)


def chunked_gla(
    q: jnp.ndarray,  # (B, S, H, dk)
    k: jnp.ndarray,  # (B, S, H, dk)
    v: jnp.ndarray,  # (B, S, H, dv)
    log_decay: jnp.ndarray,  # (B, S, H) f32, <= 0
    *,
    chunk_size: int = 256,
    initial_state: jnp.ndarray | None = None,  # (B, H, dk, dv)
    normalize: bool = False,
):
    """Returns (y (B,S,H,dv), final_state (B,H,dk,dv[+1 if normalize])).

    normalize=True appends a ones-column to v so the state also accumulates
    the normalizer n_t = sum decayed k_j; outputs are y/max(|q.n|, 1)
    (mLSTM-style stabilization — see models/xlstm.py).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    C = min(chunk_size, S)
    assert S % C == 0, (S, C)
    NC = S // C
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
        dv_t = dv + 1
    else:
        dv_t = dv

    d = _chunk_cumsum(log_decay, C)  # (B, NC, C, H)
    total = d[:, :, -1, :]  # (B, NC, H)

    qc = q.reshape(B, NC, C, H, dk)
    kc = k.reshape(B, NC, C, H, dk)
    vc = v.reshape(B, NC, C, H, dv_t)

    # ---- intra-chunk (parallel over chunks) -------------------------------
    # A[t, j] = (q_t . k_j) * exp(d_t - d_j) for j <= t
    scores = jnp.einsum("bnthd,bnjhd->bnhtj", qc, kc, preferred_element_type=jnp.float32)
    decay_tj = d[:, :, :, None, :].transpose(0, 1, 4, 2, 3) - d[:, :, None, :, :].transpose(0, 1, 4, 2, 3)
    # decay_tj: (B, NC, H, C_t, C_j) = d_t - d_j
    causal = jnp.tril(jnp.ones((C, C), bool))
    A = jnp.where(causal, scores * jnp.exp(jnp.minimum(decay_tj, 0.0)), 0.0)
    y_intra = jnp.einsum("bnhtj,bnjhd->bnthd", A.astype(v.dtype), vc)

    # ---- chunk state deltas ------------------------------------------------
    # decay from step j to end of chunk: exp(d_C - d_j)
    tail = jnp.exp((total[:, :, None, :] - d))  # (B, NC, C, H)
    dS = jnp.einsum("bnjhd,bnjhe->bnhde", kc * tail[..., None].astype(k.dtype), vc)

    # ---- inter-chunk scan (sequential over NC) -----------------------------
    if initial_state is None:
        S0 = jnp.zeros((B, H, dk, dv_t), jnp.float32)
    else:
        S0 = initial_state.astype(jnp.float32)

    def scan_body(S_prev, xs):
        dS_c, total_c = xs  # (B,H,dk,dv_t), (B,H)
        S_pre = S_prev  # state visible to this chunk
        S_next = jnp.exp(total_c)[..., None, None] * S_prev + dS_c.astype(jnp.float32)
        return S_next, S_pre

    dS_sw = jnp.moveaxis(dS, 1, 0)  # (NC, B, H, dk, dv_t)
    total_sw = jnp.moveaxis(total, 1, 0)  # (NC, B, H)
    S_final, S_prevs = jax.lax.scan(scan_body, S0, (dS_sw, total_sw))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (B, NC, H, dk, dv_t)

    # ---- inter-chunk contribution ------------------------------------------
    q_decayed = qc * jnp.exp(d)[..., None].astype(q.dtype)
    y_inter = jnp.einsum("bnthd,bnhde->bnthe", q_decayed, S_prevs.astype(q.dtype))

    y = (y_intra + y_inter).reshape(B, S, H, dv_t)
    if normalize:
        num, den = y[..., :dv], y[..., dv]
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return y.astype(v.dtype), S_final


def gla_step(
    state: jnp.ndarray,  # (B, H, dk, dv)
    q: jnp.ndarray,  # (B, H, dk)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, H, dv)
    log_decay: jnp.ndarray,  # (B, H)
    *,
    normalize: bool = False,
):
    """One recurrent step (decode path; O(1) state, no KV cache)."""
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    state = jnp.exp(log_decay.astype(jnp.float32))[..., None, None] * state + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    if normalize:
        dv = v.shape[-1] - 1
        y = y[..., :dv] / jnp.maximum(jnp.abs(y[..., dv]), 1.0)[..., None]
    return y.astype(v.dtype), state


def slstm_scan(
    f_logit: jnp.ndarray,  # (B, S, H) forget gate pre-activation
    i_logit: jnp.ndarray,  # (B, S, H) input gate pre-activation (exp-gated)
    z: jnp.ndarray,  # (B, S, H, dh) cell input
    o: jnp.ndarray,  # (B, S, H, dh) output gate (post-sigmoid applied here)
    i_clamp: float = 8.0,
):
    """Parallel sLSTM-style scalar recurrence via associative scan.

    c_t = f_t c_{t-1} + i_t z_t ;  n_t = f_t n_{t-1} + i_t ;
    h_t = sigmoid(o_t) * c_t / max(n_t, 1)
    with f = sigmoid(f_logit), i = exp(min(i_logit, clamp)).

    Note: the literal sLSTM feeds h_{t-1} back into the gates (non-
    associative).  We use the input-conditioned variant so the recurrence is
    a first-order linear scan — a TPU-friendly re-derivation; see DESIGN.md.
    """
    f = jax.nn.sigmoid(f_logit.astype(jnp.float32))[..., None]
    i = jnp.exp(jnp.minimum(i_logit.astype(jnp.float32), i_clamp))[..., None]
    zi = i * jnp.tanh(z.astype(jnp.float32))
    ni = jnp.broadcast_to(i, z.shape[:-1] + (1,))

    def combine(a, b):
        (fa, ca) = a
        (fb, cb) = b
        return (fa * fb, fb * ca + cb)

    # stack cell and normalizer as extra channel
    cn = jnp.concatenate([zi, ni], axis=-1)
    fs = jnp.broadcast_to(f, cn.shape)
    _, cn_t = jax.lax.associative_scan(combine, (fs, cn), axis=1)
    c_t, n_t = cn_t[..., :-1], cn_t[..., -1:]
    h = jax.nn.sigmoid(o.astype(jnp.float32)) * c_t / jnp.maximum(n_t, 1.0)
    return h.astype(z.dtype)


def slstm_step(state, f_logit, i_logit, z, o, i_clamp: float = 8.0):
    """One sLSTM step; state = (c (B,H,dh), n (B,H,1))."""
    c, n = state
    f = jax.nn.sigmoid(f_logit.astype(jnp.float32))[..., None]
    i = jnp.exp(jnp.minimum(i_logit.astype(jnp.float32), i_clamp))[..., None]
    c = f * c + i * jnp.tanh(z.astype(jnp.float32))
    n = f * n + i
    h = jax.nn.sigmoid(o.astype(jnp.float32)) * c / jnp.maximum(n, 1.0)
    return h.astype(z.dtype), (c, n)
