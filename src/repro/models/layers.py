"""Shared neural layers: norms, rotary embeddings, attention, MLP, losses.

Attention is implemented in a chunked-causal form (static unroll over query
chunks, each attending to its exact causal prefix) so that:
  * peak memory is one (q_chunk x prefix) score block, never (S x S);
  * HLO FLOPs match the causal optimum (no masked-away wasted half), which
    keeps the roofline "useful compute" ratio honest;
  * a sliding-window variant falls out by bounding the prefix slice.
The same entry point later swaps in the Pallas flash kernel on TPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding.compat import compat_shard_map

from .base import ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), jnp.float32, (None,), init="ones")


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions: jnp.ndarray, dh: int, theta: float) -> jnp.ndarray:
    """positions (..., S) -> angles (..., S, dh//2), f32."""
    half = dh // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S). Half-split (LLaMA) convention."""
    dh = x.shape[-1]
    ang = _rope_angles(positions, dh, theta)  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, sections: tuple[int, ...], theta: float
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position streams.
    sections: per-stream share of the rotary half-dim (sum == dh//2).
    """
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,) which stream drives each rotary dim
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos_sel = jnp.take(positions, sec_id, axis=0)  # (half, B, S)
    ang = jnp.moveaxis(pos_sel, 0, -1).astype(jnp.float32) * inv_freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _sdpa_block(q, k, v, *, causal_offset: int | None, scale: float):
    """One (q_block x kv_prefix) attention block, f32 softmax.

    q: (B, Q, H, dh); k/v: (B, T, K, dh) with H = K * G (GQA).
    causal_offset: absolute position of q[0] minus position of k[0];
      None -> no causal mask (full prefix is visible).
    """
    B, Q, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Q, K, G, dh)
    # bf16 operands, f32 accumulate (MXU-native; also prevents XLA:CPU from
    # materializing f32 copies of the operands)
    scores = jnp.einsum("bqkgd,btkd->bkgqt", qg, k, preferred_element_type=jnp.float32) * scale
    if causal_offset is not None:
        qpos = jnp.arange(Q)[:, None] + causal_offset
        kpos = jnp.arange(T)[None, :]
        mask = qpos >= kpos
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", w, v)
    return out.reshape(B, Q, H, dh)


def chunked_causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_chunk: int = 1024,
    window: int = 0,
) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, chunked over queries.

    Static unroll: chunk i attends to the exact prefix slice it can see, so
    compiled FLOPs equal the causal optimum and peak memory is one block.
    """
    B, S, H, dh = q.shape
    scale = 1.0 / (dh**0.5)
    if S <= q_chunk:
        return _sdpa_block(q, k, v, causal_offset=0, scale=scale)
    assert S % q_chunk == 0, (S, q_chunk)
    outs = []
    for i in range(S // q_chunk):
        q_start = i * q_chunk
        kv_end = q_start + q_chunk
        kv_start = 0 if window <= 0 else max(0, kv_end - window - q_chunk)
        qi = jax.lax.slice_in_dim(q, q_start, q_start + q_chunk, axis=1)
        ki = jax.lax.slice_in_dim(k, kv_start, kv_end, axis=1)
        vi = jax.lax.slice_in_dim(v, kv_start, kv_end, axis=1)
        outs.append(_sdpa_block(qi, ki, vi, causal_offset=q_start - kv_start, scale=scale))
    return jnp.concatenate(outs, axis=1)


def chunked_full_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, q_chunk: int = 1024
) -> jnp.ndarray:
    """Bidirectional attention chunked over queries (encoder / cross-attn)."""
    B, S, H, dh = q.shape
    scale = 1.0 / (dh**0.5)
    if S <= q_chunk:
        return _sdpa_block(q, k, v, causal_offset=None, scale=scale)
    assert S % q_chunk == 0, (S, q_chunk)
    outs = []
    for i in range(S // q_chunk):
        qi = jax.lax.slice_in_dim(q, i * q_chunk, (i + 1) * q_chunk, axis=1)
        outs.append(_sdpa_block(qi, k, v, causal_offset=None, scale=scale))
    return jnp.concatenate(outs, axis=1)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray, length) -> jnp.ndarray:
    """Single-token attention against a cache.

    q: (B, 1, H, dh); caches: (B, T, K, dh); length: (B,) or scalar valid len.
    """
    B, _, H, dh = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / (dh**0.5)
    qg = q.reshape(B, K, G, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache, preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(T)[None, :] < jnp.reshape(jnp.asarray(length), (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v_cache)
    return out.reshape(B, 1, H, dh)


def sharded_decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    length,
    k_new: jnp.ndarray | None = None,
    v_new: jnp.ndarray | None = None,
    write_at=None,
):
    """Flash-decode over a *sequence-sharded* cache (GQA kv < model axis).

    Each model shard computes attention over its local cache chunk plus a
    local log-sum-exp; partials combine with one psum (max-shifted), so the
    cache is never all-gathered.  The naive GSPMD lowering gathers
    B_local x T x K x dh per layer — see EXPERIMENTS.md §Perf iteration 6.

    When (k_new, v_new, write_at) are given, the cache update also happens
    *inside* the shard_map: only the shard owning the write position
    touches its chunk, and the updated cache is returned seq-sharded —
    GSPMD's dynamic-update-slice on a sharded dim would otherwise gather/
    re-scatter the whole cache (§Perf iteration 8).  Returns
    (out, k_cache', v_cache') in that case, else just out.

    q heads are model-sharded (from the head-sharded projections); every
    shard holds all K kv heads for its sequence chunk, so head-group
    lookups stay local.
    """
    from ..sharding.logical import active_rules

    rules = active_rules()
    mesh = rules.mesh if rules is not None else None
    fused_update = k_new is not None
    if mesh is None or mesh.shape.get("model", 1) <= 1:
        if fused_update:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), write_at, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), write_at, axis=1)
            return decode_attention(q, k_cache, v_cache, length), k_cache, v_cache
        return decode_attention(q, k_cache, v_cache, length)
    tp = mesh.shape["model"]
    B, _, H, dh = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    if T % tp != 0:
        if fused_update:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), write_at, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), write_at, axis=1)
            return decode_attention(q, k_cache, v_cache, length), k_cache, v_cache
        return decode_attention(q, k_cache, v_cache, length)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if B % max(1, _prod(mesh.shape[a] for a in dp)) != 0:
        bspec = None
    G = H // K
    scale = 1.0 / (dh**0.5)

    def local(qh, kc, vc, kn, vn, ln, wa):
        # qh: (B, 1, H_loc, dh); kc/vc: (B, T_loc, K, dh); kn/vn: (B,1,K,dh)
        t_loc = kc.shape[1]
        off = jax.lax.axis_index("model") * t_loc
        if kn is not None:
            # write lands in exactly one shard's chunk
            local_wa = jnp.clip(wa - off, 0, t_loc - 1)
            mine = (wa >= off) & (wa < off + t_loc)
            kc = jnp.where(
                mine,
                jax.lax.dynamic_update_slice_in_dim(kc, kn.astype(kc.dtype), local_wa, axis=1),
                kc,
            )
            vc = jnp.where(
                mine,
                jax.lax.dynamic_update_slice_in_dim(vc, vn.astype(vc.dtype), local_wa, axis=1),
                vc,
            )
        # q is replicated across the model axis (it's one token — tiny);
        # every shard computes ALL heads over ITS sequence chunk, so the
        # LSE-combine psum below is exact.  Sharding heads too would leave
        # each shard a diagonal (heads_i x chunk_i) block — wrong.
        kv_of_head = jnp.arange(qh.shape[2]) // G  # (H,)
        ksel = jnp.take(kc, kv_of_head, axis=2)  # (B, T_loc, h_loc, dh)
        vsel = jnp.take(vc, kv_of_head, axis=2)
        s = jnp.einsum("bhd,bthd->bht", qh[:, 0], ksel, preferred_element_type=jnp.float32) * scale
        pos = off + jnp.arange(t_loc)[None, None, :]
        s = jnp.where(pos < jnp.reshape(jnp.asarray(ln), (-1, 1, 1)), s, -1e30)
        m_loc = jnp.max(s, axis=-1)  # (B, h_loc)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bht,bthd->bhd", p.astype(vsel.dtype), vsel)
        l_glob = jax.lax.psum(l_loc, "model")
        o_glob = jax.lax.psum(o_loc.astype(jnp.float32), "model")
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        out = out.astype(vc.dtype)[:, None]  # (B, 1, h_loc, dh)
        if kn is not None:
            return out, kc, vc
        return out

    qspec = P(bspec, None, None, None)  # replicated over model (see local)
    cspec = P(bspec, "model", None, None)
    if fused_update:
        mapped = compat_shard_map(
            lambda qh, kc, vc, kn, vn, ln, wa: local(qh, kc, vc, kn, vn, ln, wa),
            mesh=mesh,
            in_specs=(qspec, cspec, cspec, P(bspec, None, None, None), P(bspec, None, None, None), P(), P()),
            out_specs=(qspec, cspec, cspec),
            check_vma=False,
        )
        return mapped(q, k_cache, v_cache, k_new, v_new, jnp.asarray(length), jnp.asarray(write_at))
    mapped = compat_shard_map(
        lambda qh, kc, vc, ln: local(qh, kc, vc, None, None, ln, None),
        mesh=mesh,
        in_specs=(qspec, cspec, cspec, P()),
        out_specs=qspec,
        check_vma=False,
    )
    return mapped(q, k_cache, v_cache, jnp.asarray(length))


def _prod(it):
    n = 1
    for x in it:
        n *= x
    return n


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, T, K, dh)
    v: jnp.ndarray  # (B, T, K, dh)
    pos: jnp.ndarray  # scalar int32 — tokens already in cache


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray) -> KVCache:
    """Append k/v (B, n, K, dh) at cache.pos (same pos across batch)."""
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), cache.pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), cache.pos, axis=1)
    return KVCache(k=k, v=v, pos=cache.pos + k_new.shape[1])


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + attention)
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, d_model: int | None = None) -> dict:
    d = d_model or cfg.d_model
    dh, H, K = cfg.dh, cfg.num_heads, cfg.num_kv_heads
    spec = {
        "wq": ParamSpec((d, H, dh), cfg.param_dtype, ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, K, dh), cfg.param_dtype, ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, K, dh), cfg.param_dtype, ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, dh, d), cfg.param_dtype, ("heads", "head_dim", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((H, dh), cfg.param_dtype, ("heads", "head_dim"), init="zeros")
        spec["bk"] = ParamSpec((K, dh), cfg.param_dtype, ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = ParamSpec((K, dh), cfg.param_dtype, ("kv_heads", "head_dim"), init="zeros")
    return spec


def attention_qkv(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    from ..sharding.logical import constrain

    dt = cfg.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    # Megatron TP: inside the block, heads are model-sharded and seq is
    # gathered — without this, SP seq-sharding propagates into the matmuls
    # and GSPMD replicates the weights instead (measured: f32 full-weight
    # all-gathers; §Perf iteration 3).  Only when heads divide the model
    # axis: an explicit constraint whose dim doesn't divide would PIN
    # replication, which regressed granite (24 heads on 16) to 205 GiB.
    from ..sharding.logical import mesh_axis_size

    if cfg.num_heads % max(mesh_axis_size("model"), 1) == 0:
        q = constrain(q, ("batch", None, "act_heads", None))
        k = constrain(k, ("batch", None, "act_heads", None))
        v = constrain(v, ("batch", None, "act_heads", None))
    return q, k, v


def attention_out(p: dict, o: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.dtype))


def self_attention(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    """Full-sequence self-attention (train / prefill)."""
    q, k, v = attention_qkv(p, x, cfg)
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)
    if causal:
        o = chunked_causal_attention(q, k, v, q_chunk=min(cfg.chunk_size * 4, q.shape[1]), window=window)
    else:
        o = chunked_full_attention(q, k, v, q_chunk=min(cfg.chunk_size * 4, q.shape[1]))
    return attention_out(p, o, cfg)


def cross_attention_specs(cfg: ModelConfig) -> dict:
    return attention_specs(cfg)


def cross_attention(p: dict, x: jnp.ndarray, memory: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = cfg.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(dt))
    o = chunked_full_attention(q, k, v, q_chunk=1024)
    return attention_out(p, o, cfg)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None, gated: bool = True) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    spec = {
        "w_up": ParamSpec((d, f), cfg.param_dtype, ("embed", "mlp")),
        "w_down": ParamSpec((f, d), cfg.param_dtype, ("mlp", "embed"), init="scaled"),
    }
    if gated:
        spec["w_gate"] = ParamSpec((d, f), cfg.param_dtype, ("embed", "mlp"))
    return spec


def mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    from ..sharding.logical import constrain, mesh_axis_size

    dt = cfg.dtype
    d_ff = p["w_up"].shape[-1]
    tp_ok = d_ff % max(mesh_axis_size("model"), 1) == 0
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if tp_ok:
        up = constrain(up, ("batch", None, "act_mlp"))
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        if tp_ok:
            gate = constrain(gate, ("batch", None, "act_mlp"))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


def embedding_specs(cfg: ModelConfig) -> dict:
    spec = {"tok": ParamSpec((cfg.padded_vocab, cfg.d_model), cfg.param_dtype, ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        spec["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab), cfg.param_dtype, ("embed", "vocab"))
    return spec


def embed_tokens(p: dict, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return jnp.take(p["tok"].astype(cfg.dtype), tokens, axis=0)


def logits_fn(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = p["tok"].astype(cfg.dtype).T
    else:
        w = p["unembed"].astype(cfg.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, -1e30, logits)
    return logits


def weighted_ce(
    logits: jnp.ndarray,
    targets: jnp.ndarray,
    seq_weight: jnp.ndarray | None = None,
    token_mask: jnp.ndarray | None = None,
):
    """Cross-entropy with EdgeSOS Horvitz-Thompson sequence weights.

    logits (B, S, V) / targets (B, S) / seq_weight (B,) / token_mask (B, S).
    Returns (loss, per_seq_ce) where loss is the HT-weighted mean so the
    estimate is unbiased for the *unsampled* stream (paper eq 3 applied to
    the training loss), and per_seq_ce feeds the stratified telemetry.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = lse - tgt  # (B, S)
    if token_mask is None:
        token_mask = jnp.ones_like(ce, dtype=jnp.float32)
    else:
        token_mask = token_mask.astype(jnp.float32)
    per_seq = jnp.sum(ce * token_mask, axis=-1) / jnp.maximum(jnp.sum(token_mask, axis=-1), 1.0)
    if seq_weight is None:
        seq_weight = jnp.ones(ce.shape[0], jnp.float32)
    denom = jnp.maximum(jnp.sum(seq_weight * jnp.sum(token_mask, -1)), 1.0)
    loss = jnp.sum(seq_weight[:, None] * ce * token_mask) / denom
    return loss, per_seq
