"""Encoder-decoder backbone (seamless-m4t-large-v2 text/speech stack).

The modality frontend is a stub per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_src, d) to the encoder.  The decoder is
a standard causal transformer with cross-attention; decode carries a self
KV cache plus per-layer cross K/V computed once from the encoder output.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..sharding.logical import constrain
from .base import ModelConfig, ParamSpec, stack_specs
from . import layers as L


class EncDecBatch(NamedTuple):
    src_embeds: jnp.ndarray  # (B, S_src, d) modality-stub embeddings
    tgt_tokens: jnp.ndarray  # (B, S_tgt)
    targets: jnp.ndarray  # (B, S_tgt)
    src_positions: jnp.ndarray  # (B, S_src)
    tgt_positions: jnp.ndarray  # (B, S_tgt)
    seq_weight: jnp.ndarray  # (B,)
    stratum: jnp.ndarray  # (B,)
    stratum_counts: jnp.ndarray


def _enc_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_specs(cfg, gated=False),
    }


def _dec_layer_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "self_attn": L.attention_specs(cfg),
        "ln_x": L.rmsnorm_spec(cfg.d_model),
        "cross_attn": L.cross_attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_specs(cfg, gated=False),
    }


def param_specs(cfg: ModelConfig) -> dict:
    enc_l = cfg.encoder_layers or cfg.num_layers
    dec_l = cfg.decoder_layers or cfg.num_layers
    return {
        "embedding": L.embedding_specs(cfg),
        "encoder": jax.tree.map(
            lambda s: stack_specs(s, enc_l), _enc_layer_specs(cfg),
            is_leaf=lambda x: isinstance(x, ParamSpec)),
        "decoder": jax.tree.map(
            lambda s: stack_specs(s, dec_l), _dec_layer_specs(cfg),
            is_leaf=lambda x: isinstance(x, ParamSpec)),
        "enc_norm": L.rmsnorm_spec(cfg.d_model),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }


def _remat(fn, cfg):
    from .transformer import _remat as r

    return r(fn, cfg)


def encode(params: dict, cfg: ModelConfig, src_embeds, src_positions) -> jnp.ndarray:
    x = src_embeds.astype(cfg.dtype)
    x = constrain(x, ("batch", "seq_sp", "act_embed"))

    def body(carry, p):
        h = carry
        hn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
        h = h + L.self_attention(p["attn"], hn, cfg, src_positions, causal=False)
        h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
        return constrain(h, ("batch", "seq_sp", "act_embed")), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["encoder"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode_trunk(params: dict, cfg: ModelConfig, tgt_tokens, tgt_positions, memory) -> jnp.ndarray:
    x = L.embed_tokens(params["embedding"], tgt_tokens, cfg)
    x = constrain(x, ("batch", "seq_sp", "act_embed"))

    def body(carry, p):
        h = carry
        h = h + L.self_attention(p["self_attn"], L.rmsnorm(h, p["ln1"], cfg.norm_eps), cfg, tgt_positions)
        h = h + L.cross_attention(p["cross_attn"], L.rmsnorm(h, p["ln_x"], cfg.norm_eps), memory, cfg)
        h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg)
        return constrain(h, ("batch", "seq_sp", "act_embed")), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["decoder"])
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(params: dict, cfg: ModelConfig, batch: EncDecBatch):
    from ..core import estimators

    memory = encode(params, cfg, batch.src_embeds, batch.src_positions)
    hidden = decode_trunk(params, cfg, batch.tgt_tokens, batch.tgt_positions, memory)
    logits = L.logits_fn(params["embedding"], hidden, cfg)
    tok_mask = (batch.targets >= 0).astype(jnp.float32)
    loss, per_seq = L.weighted_ce(logits, jnp.maximum(batch.targets, 0), batch.seq_weight, tok_mask)
    ns = cfg.data_num_strata + 1
    stats = estimators.sample_stats(per_seq, batch.stratum, batch.seq_weight > 0, ns,
                                    counts=batch.stratum_counts)
    est = estimators.estimate(stats)
    return loss, {
        "ce_loss": loss,
        "stratified_loss_mean": est.mean,
        "stratified_loss_moe": est.moe,
        "stratified_loss_re": est.relative_error,
    }


class EncDecState(NamedTuple):
    self_k: jnp.ndarray  # (L, B, T, K, dh)
    self_v: jnp.ndarray
    cross_k: jnp.ndarray  # (L, B, S_src, K, dh) — computed once
    cross_v: jnp.ndarray
    pos: jnp.ndarray


def init_decode_state(params: dict, cfg: ModelConfig, memory: jnp.ndarray, max_len: int) -> EncDecState:
    """Precompute cross K/V from encoder memory; allocate self cache."""
    dec_l = cfg.decoder_layers or cfg.num_layers
    B = memory.shape[0]
    K, dh = cfg.num_kv_heads, cfg.dh
    dt = cfg.dtype

    def per_layer(p):
        k = jnp.einsum("bsd,dhk->bshk", memory, p["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", memory, p["cross_attn"]["wv"].astype(dt))
        return k, v

    ck, cv = jax.lax.map(per_layer, params["decoder"])
    return EncDecState(
        self_k=jnp.zeros((dec_l, B, max_len, K, dh), dt),
        self_v=jnp.zeros((dec_l, B, max_len, K, dh), dt),
        cross_k=ck.astype(dt),
        cross_v=cv.astype(dt),
        pos=jnp.int32(0),
    )


def decode_step(params: dict, cfg: ModelConfig, state: EncDecState, tokens: jnp.ndarray):
    """One decoder token against cached self/cross K/V."""
    pos = state.pos
    B = tokens.shape[0]
    x = jnp.take(params["embedding"]["tok"].astype(cfg.dtype), tokens, axis=0)

    def body(carry, xs):
        h = carry
        p, sk, sv, ck, cv = xs
        hn = L.rmsnorm(h, p["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(p["self_attn"], hn[:, None, :], cfg)
        q = L.apply_rope(q, jnp.broadcast_to(pos, (B, 1)), cfg.rope_theta)
        k = L.apply_rope(k, jnp.broadcast_to(pos, (B, 1)), cfg.rope_theta)
        sk = jax.lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), pos, axis=1)
        sv = jax.lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), pos, axis=1)
        o = L.decode_attention(q, sk, sv, pos + 1)
        h = h + L.attention_out(p["self_attn"], o, cfg)[:, 0, :]
        # cross attention against precomputed memory K/V
        hx = L.rmsnorm(h, p["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bd,dhk->bhk", hx, p["cross_attn"]["wq"].astype(cfg.dtype))[:, None]
        ox = L.decode_attention(qx, ck, cv, ck.shape[1])
        h = h + L.attention_out(p["cross_attn"], ox, cfg)[:, 0, :]
        h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps)[:, None, :], cfg)[:, 0, :]
        return h, (sk, sv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], state.self_k, state.self_v, state.cross_k, state.cross_v)
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.logits_fn(params["embedding"], x[:, None, :], cfg)[:, 0, :]
    return logits, EncDecState(
        self_k=nk, self_v=nv, cross_k=state.cross_k, cross_v=state.cross_v, pos=pos + 1
    )
