"""Mamba-2 (SSD) block on the chunked gated-linear-attention engine.

Mapping onto chunked_gla (per head h of head_dim P, state size N):
    g_t = dt_t * (-exp(A_log_h))          (scalar log-decay, <= 0)
    k_t = B_t   (shape N, shared within a group, GQA-style)
    v_t = dt_t * x_t                      (shape P)
    q_t = C_t   (shape N)
so S_t is the (N x P) SSD state and y_t = C_t . S_t, plus the D*x skip.

Decode uses the O(1) recurrent ``gla_step`` + a (conv_width-1) rolling
buffer for the causal depthwise conv — no KV cache, which is what makes
long_500k decodable at batch 1 (the assignment's sub-quadratic cell).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import ModelConfig, ParamSpec
from .layers import rmsnorm
from .linear_attention import chunked_gla, gla_step


def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.d_inner
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = cfg.ssm_heads
    conv_ch = d_in + 2 * G * N
    return {
        "in_proj": ParamSpec(
            (d, 2 * d_in + 2 * G * N + H), cfg.param_dtype, ("embed", "act_mlp")
        ),
        "conv_w": ParamSpec((cfg.conv_width, conv_ch), cfg.param_dtype, ("conv", "act_mlp")),
        "conv_b": ParamSpec((conv_ch,), cfg.param_dtype, ("act_mlp",), init="zeros"),
        "A_log": ParamSpec((H,), jnp.float32, (None,), init="zeros"),
        "D": ParamSpec((H,), jnp.float32, (None,), init="ones"),
        "dt_bias": ParamSpec((H,), jnp.float32, (None,), init="zeros"),
        "norm": ParamSpec((d_in,), jnp.float32, (None,), init="ones"),
        "out_proj": ParamSpec((d_in, d), cfg.param_dtype, ("mlp", "embed"), init="scaled"),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    d_in, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : 2 * d_in + 2 * G * N]
    dt = zxbcdt[..., 2 * d_in + 2 * G * N :]
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq: xBC (B,S,Ch), w (W,Ch)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out + b)


def mamba2_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence forward (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = cfg.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dt_))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xs = xBC[..., : cfg.d_inner].reshape(B, S, H, P)
    Bmat = xBC[..., cfg.d_inner : cfg.d_inner + G * N].reshape(B, S, G, N)
    Cmat = xBC[..., cfg.d_inner + G * N :].reshape(B, S, G, N)
    rep = H // G
    k = jnp.repeat(Bmat, rep, axis=2)  # (B,S,H,N)
    q = jnp.repeat(Cmat, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    log_decay = -jnp.exp(p["A_log"]) * dt  # <= 0
    v = xs * dt[..., None].astype(dt_)
    y, _ = chunked_gla(q, k, v, log_decay, chunk_size=cfg.chunk_size)
    y = y + p["D"].astype(dt_)[None, None, :, None] * xs
    y = y.reshape(B, S, cfg.d_inner)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))


class MambaState(NamedTuple):
    ssm: jnp.ndarray  # (B, H, N, P) f32
    conv: jnp.ndarray  # (B, W-1, Ch) rolling conv buffer


def mamba2_init_state(cfg: ModelConfig, batch: int) -> MambaState:
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * G * N
    return MambaState(
        ssm=jnp.zeros((batch, cfg.ssm_heads, N, cfg.ssm_head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_ch), cfg.dtype),
    )


def mamba2_step(p: dict, state: MambaState, x: jnp.ndarray, cfg: ModelConfig):
    """One decode token. x: (B, d) -> (y (B, d), state')."""
    B, _ = x.shape
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = cfg.dtype
    zxbcdt = jnp.einsum("bd,dk->bk", x, p["in_proj"].astype(dt_))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    # rolling conv buffer: state.conv holds the previous W-1 inputs
    W = cfg.conv_width
    w = p["conv_w"].astype(dt_)
    hist = jnp.concatenate([state.conv, xBC[:, None, :]], axis=1)  # (B, W, Ch)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(dt_))
    new_conv = hist[:, 1:, :]
    xs = conv_out[..., : cfg.d_inner].reshape(B, H, P)
    Bmat = conv_out[..., cfg.d_inner : cfg.d_inner + G * N].reshape(B, G, N)
    Cmat = conv_out[..., cfg.d_inner + G * N :].reshape(B, G, N)
    rep = H // G
    k = jnp.repeat(Bmat, rep, axis=1)
    q = jnp.repeat(Cmat, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    log_decay = -jnp.exp(p["A_log"]) * dt
    v = xs * dt[..., None].astype(dt_)
    y, ssm = gla_step(state.ssm, q, k, v, log_decay)
    y = y + p["D"].astype(dt_)[None, :, None] * xs
    y = y.reshape(B, cfg.d_inner)
    y = rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"].astype(dt_))
    return out, MambaState(ssm=ssm, conv=new_conv)
