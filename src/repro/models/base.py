"""Model substrate foundations: configs, parameter specs, initialization.

Parameters are plain pytrees (nested dicts of arrays).  Every leaf is
described by a :class:`ParamSpec` carrying shape, dtype, *logical axes* and
an initializer tag; the sharding layer maps logical axes to mesh axes, and
the dry-run materializes specs as ShapeDtypeStructs without allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    dtype: Any
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | embed | scaled


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Superset config covering the ten assigned architectures."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    conv_width: int = 4
    # --- hybrid (zamba2): shared attention block cadence ---
    shared_attn_every: int = 0
    # --- xLSTM ---
    slstm_every: int = 0  # 1-in-N layers is sLSTM; 0 -> no sLSTM
    # --- enc-dec ---
    encoder_layers: int = 0
    decoder_layers: int = 0
    # --- modality stubs (vlm/audio): inputs are precomputed embeddings ---
    embeddings_in: bool = False
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) rotary split
    # --- long-context handling ---
    attention_window: int = 0  # 0 = full causal; >0 = sliding window
    # --- numerics / structure ---
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    vocab_round: int = 256  # pad vocab for TP divisibility + lane alignment
    chunk_size: int = 256  # chunked linear attention / blockwise attn chunk
    remat: str = "full"  # none | full | dots | offload (activation ckpt policy)
    # --- data-layer (paper integration) ---
    data_num_strata: int = 64  # strata slots for stratified loss telemetry

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_round)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initialization from specs
# ---------------------------------------------------------------------------


def _init_leaf(key, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * 0.02).astype(spec.dtype)
    # fan-in scaled normal for projections; last-but-one axis group = fan_in
    fan_in = spec.shape[0] if len(spec.shape) == 1 else int(jnp.prod(jnp.array(spec.shape[:-1])))
    if len(spec.shape) >= 2:
        fan_in = 1
        for d in spec.shape[:-1]:
            fan_in *= d
    scale = 1.0 / max(fan_in, 1) ** 0.5
    if spec.init == "scaled":  # residual-out projections: extra depth scaling
        scale = scale * 0.5
    return (jax.random.normal(key, spec.shape) * scale).astype(spec.dtype)


def init_params(key, specs) -> Any:
    """Materialize a spec pytree into real parameters (small configs)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs) -> Any:
    """Spec pytree -> ShapeDtypeStruct pytree (dry-run, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def spec_axes(specs) -> Any:
    """Spec pytree -> logical-axes pytree (consumed by the sharding layer)."""
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def stack_specs(spec: ParamSpec, n: int, axis_name: str | None = "layers") -> ParamSpec:
    """Prepend a stacking dimension (scan-over-layers parameter layout)."""
    return ParamSpec(
        shape=(n,) + spec.shape, dtype=spec.dtype, axes=(axis_name,) + spec.axes, init=spec.init
    )


def tree_slice(params, start: int, end: int):
    """Static slice of stacked (scan) parameters along the leading axis."""
    return jax.tree.map(lambda x: x[start:end], params)
