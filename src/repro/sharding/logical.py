"""Logical-axis sharding: one rule table maps model code to any mesh.

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "batch", ...).  A :class:`LogicalRules` instance maps
those to mesh axes, with divisibility-aware fallback: if a dimension does
not divide evenly over its mesh axes the rule degrades to replication for
that dimension (e.g. 40 experts on a 16-way model axis, or 8 KV heads on a
16-way axis).  This keeps every (arch x shape x mesh) cell lowerable while
letting well-shaped dims take the fast path.

Parallelism mapping (see DESIGN.md):
  batch        -> ("pod", "data")   pure DP across pods, DP within a pod
  embed/layers -> "data"            FSDP (params + optimizer state)
  heads/mlp/vocab/experts -> "model" TP / EP
  seq_sp       -> "model"           sequence parallelism for saved
                                     activations between layers
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# The mesh-axis vocabulary — the single source of truth every collective
# axis-name literal in the tree must be drawn from (edgelint EDG005), and
# the fallback axis set when rules are built without a mesh.
MESH_AXIS_NAMES = ("pod", "data", "model")


@dataclasses.dataclass(frozen=True)
class LogicalRules:
    rules: dict[str, tuple[str, ...]]
    mesh: Mesh | None = None

    def mesh_axes(self, name: str | None) -> tuple[str, ...]:
        if name is None:
            return ()
        return self.rules.get(name, ())

    def _axis_size(self, axes: tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def spec(self, logical_axes: tuple[str | None, ...], dims: tuple[int, ...] | None = None) -> P:
        """PartitionSpec for logical axes; replicates non-divisible dims."""
        out: list[Any] = []
        used: set[str] = set()
        for i, name in enumerate(logical_axes):
            axes = tuple(a for a in self.mesh_axes(name) if a not in used)
            if not axes:
                out.append(None)
                continue
            if dims is not None and self.mesh is not None:
                size = self._axis_size(axes)
                if size <= 1 or dims[i] % size != 0:
                    # try progressively shorter prefixes of the rule
                    while axes and (dims[i] % self._axis_size(axes) != 0):
                        axes = axes[:-1]
                    if not axes:
                        out.append(None)
                        continue
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        return P(*out)

    def sharding(self, logical_axes: tuple[str | None, ...], dims: tuple[int, ...] | None = None):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, dims))


def default_rules(mesh: Mesh | None = None, *, sequence_parallel: bool = False) -> LogicalRules:
    axis_names = set(mesh.axis_names) if mesh is not None else set(MESH_AXIS_NAMES)
    dp: tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axis_names)
    tp: tuple[str, ...] = ("model",) if "model" in axis_names else ()
    fsdp: tuple[str, ...] = ("data",) if "data" in axis_names else ()
    rules = {
        # activations
        "batch": dp,
        "seq": (),
        "seq_sp": tp if sequence_parallel else (),
        "act_embed": (),
        "act_heads": tp,
        "act_mlp": tp,
        "act_vocab": tp,
        "act_experts": tp,
        "act_state": (),
        # params
        "embed": fsdp,
        "layers": (),
        "heads": tp,
        "kv_heads": tp,
        "head_dim": (),
        "mlp": tp,
        "vocab": tp,
        "experts": tp,
        "expert_mlp": (),
        "conv": (),
        "state": (),
        "cache_seq": tp,
    }
    return LogicalRules(rules=rules, mesh=mesh)


_local = threading.local()


def active_rules() -> LogicalRules | None:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: LogicalRules):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def constrain(x, logical_axes: tuple[str | None, ...]):
    """Annotate an activation with logical axes (no-op outside a mesh)."""
    rules = active_rules()
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(logical_axes, tuple(x.shape)))
    )


def mesh_axis_size(axis: str) -> int:
    """Size of a mesh axis under the active rules (1 when unmeshed)."""
    rules = active_rules()
    if rules is None or rules.mesh is None or axis not in rules.mesh.axis_names:
        return 1
    return rules.mesh.shape[axis]


def spec_for(rules: LogicalRules, axes_tree, shape_tree):
    """Map (logical axes pytree, ShapeDtypeStruct pytree) -> PartitionSpecs."""
    return jax.tree.map(
        lambda axes, sds: rules.spec(axes, tuple(sds.shape)),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def param_sharding(rules: LogicalRules, axes_tree, shape_tree):
    return jax.tree.map(
        lambda axes, sds: rules.sharding(axes, tuple(sds.shape)),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def activation_rules(axes: tuple[str | None, ...]):
    """Convenience alias used by model code: ('batch','seq',...)."""
    return axes
