"""Version-compat wrappers for mesh/shard_map APIs that moved across jax
releases.  Dependency-free (only jax), so every layer may import it."""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """`jax.make_mesh` across versions: `axis_types` appeared in newer jax;
    older releases build an (implicitly Auto) mesh without it."""
    if hasattr(jax.sharding, "AxisType"):
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def compat_shard_map(f, mesh, in_specs, out_specs, check_vma=False):
    """`shard_map` across versions: top-level `jax.shard_map(check_vma=...)`
    on newer jax, `jax.experimental.shard_map.shard_map(check_rep=...)` on
    older releases."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    return legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
