"""Sharding substrate: logical axis rules -> mesh PartitionSpecs."""

from .logical import (
    MESH_AXIS_NAMES,
    LogicalRules,
    activation_rules,
    active_rules,
    constrain,
    default_rules,
    param_sharding,
    spec_for,
    use_rules,
)

__all__ = [
    "LogicalRules",
    "MESH_AXIS_NAMES",
    "activation_rules",
    "active_rules",
    "constrain",
    "default_rules",
    "param_sharding",
    "spec_for",
    "use_rules",
]
