"""Sharding substrate: logical axis rules -> mesh PartitionSpecs."""

from .logical import (
    LogicalRules,
    activation_rules,
    active_rules,
    constrain,
    default_rules,
    param_sharding,
    spec_for,
    use_rules,
)

__all__ = [
    "LogicalRules",
    "activation_rules",
    "active_rules",
    "constrain",
    "default_rules",
    "param_sharding",
    "spec_for",
    "use_rules",
]
