"""Spatial-aware data distribution (paper contribution #2).

The paper creates one Kafka topic per *neighborhood* (coarse geohash) so
that Spark executors receive pre-partitioned data and aggregation needs no
shuffle.  JAX mapping: "topics" become mesh shards; the router is a static
``neighborhood -> shard`` plan, and the "publish" step is a deterministic
all_to_all exchange (or, in pre-aggregated mode, nothing at all — partial
stats psum directly).

The measurable claim carried over from the paper: with spatial routing the
cloud-side aggregation is shuffle-free (collective bytes O(S) instead of
O(window)), which shows up directly in the dry-run collective-byte counts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from .stratify import StratumTable


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RoutePlan:
    """Static routing plan: which shard owns each neighborhood/stratum.

    dest_of_neighborhood: (num_neighborhoods + 1,) int32 (last = overflow).
    dest_of_stratum: (S + 1,) int32 — composed through the stratum table's
      O(1) neighborhood gather so the hot path is a single index lookup.
    num_shards: static shard count on the consumer ("cloud") side.
    """

    dest_of_neighborhood: jnp.ndarray
    dest_of_stratum: jnp.ndarray
    num_shards: int = dataclasses.field(metadata=dict(static=True))

    def route_stratum(self, stratum_idx: jnp.ndarray) -> jnp.ndarray:
        return self.dest_of_stratum[stratum_idx]


def contiguous_plan(table: StratumTable, num_shards: int) -> RoutePlan:
    """Assign spatially-contiguous neighborhood ranges to shards.

    Geohash/Morton order is locality preserving, so contiguous ranges of
    neighborhood ids are spatially coherent — the analogue of the paper's
    "each neighborhood is served by one edge node".
    """
    nn = table.num_neighborhoods + 1
    ids = np.arange(nn, dtype=np.int64)
    dest_n = ((ids * num_shards) // nn).astype(np.int32)
    dest_s = dest_n[np.asarray(table.neighborhood)]
    return RoutePlan(
        dest_of_neighborhood=jnp.asarray(dest_n),
        dest_of_stratum=jnp.asarray(dest_s),
        num_shards=num_shards,
    )


def balanced_plan(
    table: StratumTable, num_shards: int, load_per_neighborhood: np.ndarray
) -> RoutePlan:
    """Greedy load-balanced plan from observed per-neighborhood loads.

    Beyond-paper: the paper assumes one neighborhood per edge node; at pod
    scale neighborhood loads are highly skewed (Zipf-like city density), so
    we pack neighborhoods onto shards longest-processing-time-first.
    """
    nn = table.num_neighborhoods + 1
    load = np.zeros(nn, dtype=np.float64)
    load[: len(load_per_neighborhood)] = np.asarray(load_per_neighborhood, dtype=np.float64)[:nn]
    order = np.argsort(-load)
    shard_load = np.zeros(num_shards, dtype=np.float64)
    dest_n = np.zeros(nn, dtype=np.int32)
    for nb in order:
        tgt = int(np.argmin(shard_load))
        dest_n[nb] = tgt
        shard_load[tgt] += load[nb]
    dest_s = dest_n[np.asarray(table.neighborhood)]
    return RoutePlan(
        dest_of_neighborhood=jnp.asarray(dest_n),
        dest_of_stratum=jnp.asarray(dest_s),
        num_shards=num_shards,
    )


def route_counts(plan: RoutePlan, stratum_idx: jnp.ndarray) -> jnp.ndarray:
    """Per-destination tuple counts for one window (load/collective model)."""
    dest = plan.route_stratum(stratum_idx)
    return jax.ops.segment_sum(
        jnp.ones_like(dest, dtype=jnp.int32), dest, num_segments=plan.num_shards
    )


def exchange(
    plan: RoutePlan,
    stratum_idx: jnp.ndarray,
    payload: jnp.ndarray,
    axis_name: str,
    capacity: int,
):
    """Deterministic routed exchange under shard_map (the "publish" step).

    Each shard sorts its kept tuples by destination, pads each destination
    slice to ``capacity`` and performs one all_to_all.  Returns
    (valid, stratum_idx_rx, payload_rx) with leading dim
    ``num_shards * capacity`` on every shard.  Tuples beyond capacity are
    dropped and counted (the paper's Kafka producer has the same bounded
    -buffer semantics); choose capacity from route_counts percentiles.
    """
    num_shards = plan.num_shards
    dest = plan.route_stratum(stratum_idx)
    order = jnp.argsort(dest, stable=True)
    dest_sorted = dest[order]
    s_sorted = stratum_idx[order]
    p_sorted = payload[order]
    counts = jax.ops.segment_sum(
        jnp.ones_like(dest, dtype=jnp.int32), dest, num_segments=num_shards
    )
    starts = jnp.cumsum(counts) - counts
    # position of each sorted tuple inside its destination block; tuples
    # beyond capacity scatter into a dump slot (never into real slots)
    pos_in_dest = jnp.arange(dest.shape[0], dtype=jnp.int32) - starts[dest_sorted]
    keep = pos_in_dest < capacity
    slot = jnp.where(
        keep, dest_sorted * capacity + pos_in_dest, num_shards * capacity
    )
    buf_s = jnp.full((num_shards * capacity + 1,), -1, dtype=s_sorted.dtype)
    buf_p = jnp.zeros((num_shards * capacity + 1,) + p_sorted.shape[1:], p_sorted.dtype)
    buf_s = buf_s.at[slot].set(s_sorted, mode="drop")
    buf_p = buf_p.at[slot].set(p_sorted, mode="drop")
    buf_s = buf_s[:-1]
    buf_p = buf_p[:-1]
    valid = buf_s >= 0
    dropped = jnp.sum(jnp.maximum(counts - capacity, 0))
    # one all_to_all moves each destination block to its owner shard
    rx_s = jax.lax.all_to_all(
        buf_s.reshape(num_shards, capacity), axis_name, split_axis=0, concat_axis=0
    ).reshape(-1)
    rx_p = jax.lax.all_to_all(
        buf_p.reshape((num_shards, capacity) + buf_p.shape[1:]),
        axis_name,
        split_axis=0,
        concat_axis=0,
    ).reshape((-1,) + buf_p.shape[2:])
    rx_valid = jax.lax.all_to_all(
        valid.reshape(num_shards, capacity), axis_name, split_axis=0, concat_axis=0
    ).reshape(-1)
    return rx_valid, rx_s, rx_p, dropped
