"""Continuous-query sessions: registered QuerySets over shared sampling passes.

The paper's system answers *many concurrent* continuous queries over the
same geospatial stream, each with its own SLO.  One-shot ``execute`` calls
re-stratify and re-sample the window once per query; a
:class:`StreamSession` amortizes that work across the whole registered
workload (the StreamApprox / ApproxIoT observation that edge-side
approximate analytics wins by sharing one sampling pass):

  * ``register(query, slo=..., window=...)`` any number of declarative
    :class:`~.query.Query` specs, each with an optional pane-based
    :class:`~.windows.WindowSpec` (tumbling / sliding / hopping).
  * Each ``step(key, pane)`` partitions the registered set into *fusion
    groups* — queries whose plans share a sampling signature
    (:func:`~.query.fusion_key`: method, mode, ROI) and therefore draw
    identical sampling decisions — fuses each group
    (:func:`~.query.fuse`), and runs **one** stratify+EdgeSOS pass and one
    collective per group.  Per-query ``finalize`` then carves each query's
    estimates out of the shared merged ``ColumnStats``.
  * Sliding/hopping windows fall out of the mergeable-accumulator design:
    the edge reduces each *pane* (stride-sized sub-window) to per-stratum
    registry pytrees (``{column: {kind: state}}`` — moments, extrema,
    quantile sketches, any registered accumulator); the session keeps a
    ring of panes per query and merges them cloud-side
    (:func:`~.estimators.merge_accs_panes`, one vectorized pass per kind)
    into each window's answer without re-touching raw tuples.
  * Per-query QoS runs through a vectorized feedback controller state (one
    fraction per registered query, :func:`~.feedback.update_vector`).
  * **Per-query fraction refinement**: when a preagg fusion group's member
    fractions diverge (or a Bernoulli group's ROIs differ), the group runs
    the *refined* edge program (:func:`~.pipeline._fused_edge_program`):
    one shared stratify + randomness draw, thinned per member to its own
    fraction by nested Horvitz-Thompson subsampling (shared SRS ranks /
    shared Bernoulli uniforms, deterministic in the step key).  Each
    member's estimates, error bounds, and downstream volume then reflect
    its *own* effective fraction — a 10%-fraction query fused with an 80%
    one pays 10% downstream — instead of free-riding the group max.
  * **Checkpoint/restore**: ``checkpoint()`` snapshots every registration's
    pane ring, controller slice, and the session drop/uplink counters to a
    versioned pytree (:mod:`.checkpoint`); ``restore()`` into a freshly
    registered session resumes mid-window bit-identically.

Correctness contract (property-tested): with every query at the same
fraction, a session step's estimates are elementwise-identical (same PRNG
key) to running each query through ``pipeline.execute`` independently, in
both ``preagg`` and ``raw`` modes — fusion changes the *cost*, never the
answer.  With divergent per-query fractions, refined preagg members are
*still* elementwise-identical to independent ``execute`` at their own
fraction (the nested subsample IS the sample their independent draw would
produce); raw-mode groups keep the group-max behavior, so their per-query
error is never worse than requested.

``EdgeCloudPipeline.run_stream`` is a thin shim over a single-query session.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import estimators, feedback
from . import query as aqp
from .feedback import SLO, ControllerState
from .query import FusedPlan, Plan, Query, QueryResult, fuse, fusion_key
from .windows import WindowSpec


class _Pane(NamedTuple):
    """One pane's contribution to a registered query's window ring.

    ``n_sampled`` is this *member's* realized sample of the pane — the
    refined (nested-subsampled / ROI-masked) size when the group ran the
    refined pass, the shared group sample otherwise.
    """

    stats: dict  # column -> {kind: state} registry pytree (query's columns)
    n_sampled: jnp.ndarray
    n_valid: jnp.ndarray
    n_overflow: jnp.ndarray
    n_truncated: jnp.ndarray
    n_dropped: int
    comm_bytes: int


@dataclasses.dataclass
class Registration:
    """Handle for one registered continuous query (returned by ``register``).

    Carries the query's lowered plan, pane ring, and its slice of the
    session's controller state (``fraction``/``re_ema``/``steps``).
    ``slo=None`` means no QoS: the fraction stays fixed.
    """

    qid: int
    query: Query
    slo: SLO | None
    window: WindowSpec
    plan: Plan
    qos_key: str | None  # agg key driving QoS; None holds the fraction
    fraction: float
    re_ema: float = 0.0
    steps: int = 0
    panes_seen: int = 0
    ring: list = dataclasses.field(default_factory=list)
    # running count of tuples *this* query's samples kept (device-lazy);
    # under refinement a low-fraction member accumulates its own, smaller,
    # nested sample here instead of the group max's
    downstream_tuples: int | jnp.ndarray = 0

    @property
    def qos_active(self) -> bool:
        return self.slo is not None and self.qos_key is not None

    @property
    def downstream_bytes(self) -> int:
        """Downstream volume this query's samples cost so far: realized
        kept tuples x the plan's per-tuple layout (see
        :func:`~.query.downstream_tuple_bytes`).  Reading this syncs the
        device-lazy tuple counter."""
        return int(self.downstream_tuples) * aqp.downstream_tuple_bytes(self.plan)


class SessionStep(NamedTuple):
    """Outcome of feeding one pane to the session.

    results: qid -> QueryResult for queries whose window emitted this pane
      (a query with stride s emits every s panes; others are absent).
    fractions: qid -> post-update controller fraction, for every
      registration.
    comm_bytes: total edge->cloud payload of this pane's shared passes (one
      per fusion group — the fused uplink cost of the whole QuerySet).
    n_dropped: tuples shed before this pane reached the device (bounded
      time windows, ingest-queue backpressure, load shedding).
    pane_index: 0-based index of the pane within the session.
    drop_causes: cause -> tuple-count breakdown of ``n_dropped`` (causes:
      ``late`` / ``queue_full`` / ``shed``; uncaused legacy counts land in
      ``late``).  Do not mutate — it may be the class-level default.
    """

    results: dict
    fractions: dict
    comm_bytes: int
    n_dropped: int
    pane_index: int
    drop_causes: dict = {}


class StreamSession:
    """Continuous-query engine over an :class:`~.pipeline.EdgeCloudPipeline`.

    Typical use::

        sess = StreamSession(pipe)
        speed = sess.register(Query(aggs=(AggSpec("mean", "value"),)),
                              slo=SLO(target_relative_error=0.05))
        occ = sess.register(Query(aggs=(AggSpec("mean", "occupancy"),)),
                            window=WindowSpec("sliding", size=4))
        for step in sess.run(pane_windows(stream, pane_tuples=20_000), key=key):
            if speed.qid in step.results:
                ...  # step.results[speed.qid].estimates["mean_value"]

    All registered queries that share a sampling signature are served by one
    stratify+EdgeSOS pass and one collective per pane.
    """

    def __init__(self, pipeline, *, sharded: bool = False, initial_fraction: float = 0.8):
        self.pipe = pipeline
        self.sharded = sharded
        self.initial_fraction = float(initial_fraction)
        self.pane_index = 0
        self.total_comm_bytes = 0
        self.total_dropped = 0
        self.total_dropped_by_cause: dict = {}
        self.total_passes = 0  # edge passes run (one per fusion group per pane)
        self._regs: dict[int, Registration] = {}
        self._next_qid = 0
        self._fused: dict[tuple[Query, ...], FusedPlan] = {}
        # jitted emit paths cache on the *pipeline* (like _passes): plan and
        # table both derive from the pipe, so a fresh session over a warmed
        # pipe pays zero first-pane compiles — the contract
        # benchmarks/ingest_throughput.py's warm-up relies on
        self._finalizers: dict[tuple[Query, int], callable] = pipeline._finalizers
        self._slo_stack: feedback.StackedSLO | None = None
        self._slo_sig: tuple | None = None

    # -- registration --------------------------------------------------------

    def register(
        self,
        query: Query,
        *,
        slo: SLO | None = None,
        window: WindowSpec | None = None,
        initial_fraction: float | None = None,
    ) -> Registration:
        """Register a continuous query; returns its handle.

        ``slo=None`` disables QoS for this query (fixed fraction).  The
        query joins the session's fusion groups from the next ``step``.
        """
        window = window or WindowSpec()
        plan = self.pipe.plan(query)
        # the first *error-bounded* aggregate drives QoS: sum/mean (eq 5-10
        # CIs) and, since the bounds subsystem, var and p<q> quantiles —
        # but only while their bootstrap is enabled (replicates > 0;
        # disabled bounds report zero-width RE 0, which would collapse the
        # fraction).  min/max report only conservative one-sided
        # order-statistic bounds and count is exact — neither drives the
        # controller.
        boot_on = query.bootstrap_replicates > 0

        def _drives(a) -> bool:
            if a.kind in ("sum", "mean"):
                return True
            return boot_on and (
                a.kind == "var" or aqp.quantile_of(a.kind) is not None
            )

        qos_key = next((a.key for a in query.aggs if _drives(a)), None)
        reg = Registration(
            qid=self._next_qid,
            query=query,
            slo=slo,
            window=window,
            plan=plan,
            qos_key=qos_key,
            fraction=float(initial_fraction if initial_fraction is not None else self.initial_fraction),
        )
        self._next_qid += 1
        self._regs[reg.qid] = reg
        return reg

    def unregister(self, reg: Registration) -> None:
        """Drop a registered query (its pane ring is discarded)."""
        self._regs.pop(reg.qid, None)

    @property
    def registrations(self) -> tuple[Registration, ...]:
        return tuple(self._regs.values())

    def controller_state(self, reg: Registration) -> ControllerState:
        """This registration's slice of the vectorized controller state."""
        return ControllerState(
            fraction=jnp.float32(reg.fraction),
            re_ema=jnp.float32(reg.re_ema),
            steps=jnp.int32(reg.steps),
        )

    # -- fusion machinery ----------------------------------------------------

    def _groups(self) -> list[list[Registration]]:
        """Partition registrations into fusable groups (signature equality),
        preserving registration order within and across groups."""
        groups: dict[tuple, list[Registration]] = {}
        for reg in self._regs.values():
            groups.setdefault(fusion_key(reg.plan), []).append(reg)
        return list(groups.values())

    def _fused_plan(self, members: list[Registration]) -> FusedPlan:
        sig = tuple(r.query for r in members)
        fused = self._fused.get(sig)
        if fused is None:
            fused = fuse([r.plan for r in members])
            self._fused[sig] = fused
        return fused

    def _analytic_comm(self, fused: FusedPlan, n_rows: int) -> int:
        """Per-shard uplink bytes of one shared pass, computed host-side.

        Mirrors ``_edge_program``'s analytic accounting (it is a static
        property of the plan, not of the data) so the hot loop never blocks
        on the device just to read back a constant.
        """
        plan = fused.shared
        if plan.query.mode == "raw":
            cap = self.pipe.config.raw_capacity
            if cap is None:
                shards = 1
                if self.sharded:
                    shape = self.pipe.mesh.shape
                    shards = math.prod(shape[a] for a in self.pipe.axis_names)
                cap = n_rows // shards
            return aqp.raw_bytes(plan, cap)
        return aqp.preagg_bytes(plan, self.pipe.table.num_slots)

    def _finalize_fn(self, reg: Registration, num_panes: int):
        """Jitted cloud-side emit: merge ``num_panes`` pane accumulators
        (vectorized pane-merge; pass-through when the window is one pane,
        preserving bit-compatibility with ``execute``) and finalize."""
        key = (reg.query, num_panes)
        fn = self._finalizers.get(key)
        if fn is not None:
            return fn
        plan, table = reg.plan, self.pipe.table

        if num_panes == 1:

            def run(stats, bkey):
                return aqp.finalize(plan, table, stats, key=bkey), stats

        else:

            def run(stacked, bkey):
                merged = {
                    c: estimators.merge_accs_panes(stacked[c]) for c in plan.columns
                }
                return aqp.finalize(plan, table, merged, key=bkey), merged

        fn = jax.jit(run)
        self._finalizers[key] = fn
        return fn

    def _emit(self, reg: Registration, key) -> QueryResult:
        """Assemble this query's window from its pane ring and finalize.

        ``key`` (the step key) seeds the bootstrap error bounds: a
        one-pane window finalizes with the same key as the shared pass, so
        session bounds are bit-identical to an independent ``execute``."""
        panes = reg.ring
        if len(panes) == 1:
            estimates, stats = self._finalize_fn(reg, 1)(panes[0].stats, key)
        else:
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *[p.stats for p in panes]
            )
            estimates, stats = self._finalize_fn(reg, len(panes))(stacked, key)
        n_sampled = panes[0].n_sampled
        n_valid = panes[0].n_valid
        n_overflow = panes[0].n_overflow
        n_truncated = panes[0].n_truncated
        for p in panes[1:]:
            n_sampled = n_sampled + p.n_sampled
            n_valid = n_valid + p.n_valid
            n_overflow = n_overflow + p.n_overflow
            n_truncated = n_truncated + p.n_truncated
        return QueryResult(
            estimates=estimates,
            stats=stats,
            n_sampled=n_sampled,
            n_valid=n_valid,
            n_overflow=n_overflow,
            n_truncated=n_truncated,
            # uplink spent on this window's span: one shared pass per pane
            comm_bytes=jnp.int32(sum(p.comm_bytes for p in panes)),
            # window-level drop accounting: tuples the window's panes shed
            # upstream (survives checkpoint/restore — the ring carries it)
            n_dropped=sum(p.n_dropped for p in panes),
        )

    # -- the continuous loop -------------------------------------------------

    @staticmethod
    def _refines(fused: FusedPlan, fractions: list[float]) -> bool:
        """Host-side choice of edge program for one group this pane.

        The *shared* pass (one union accumulation at the group-max
        fraction, bit-compatible with the pre-refinement session) serves
        single members and uniform-fraction same-ROI groups; the *refined*
        per-member pass serves divergent-fraction preagg groups and
        cross-ROI Bernoulli groups (which the shared pass cannot express).
        Raw-mode groups always share: their compacted uplink buffer is one
        ROI-filtered sample at the group max.  Neyman groups always share
        too — refined thinning would need per-stratum stddev threading to
        preserve the variance-optimal allocation.
        """
        if len(fused.members) < 2:
            return False
        if fused.cross_roi:
            return True
        if fused.mode != "preagg" or fused.shared.query.method == "neyman":
            return False
        return len(set(fractions)) > 1

    def step(self, key, pane) -> SessionStep:
        """Feed one pane through every fusion group and emit due windows.

        Every group's pass uses ``key`` directly (not folded), so a
        single-group session reproduces ``execute(query, key, ...)`` exactly.
        """
        if not self._regs:
            raise ValueError("step() on a session with no registered queries")
        n_dropped = int(getattr(pane, "n_dropped", 0))
        drop_causes = dict(getattr(pane, "drop_causes", None) or {})
        uncaused = n_dropped - sum(drop_causes.values())
        if uncaused > 0:  # legacy producers: window-level sheds count as late
            drop_causes["late"] = drop_causes.get("late", 0) + uncaused
        emitted: dict[int, QueryResult] = {}
        comm_total = 0
        for members in self._groups():
            fused = self._fused_plan(members)
            fractions = [r.fraction for r in members]
            lat, lon, cols, valid = self.pipe._window_arrays(pane, fused.shared)
            if self._refines(fused, fractions):
                fn = self.pipe._refined_pass_fn(fused, self.sharded)
                outs, _ = fn(
                    key, lat, lon, cols, valid, jnp.asarray(fractions, jnp.float32)
                )
                comm = aqp.refined_preagg_bytes(fused, self.pipe.table.num_slots)
                zero = jnp.int32(0)  # refined pass is preagg-only: no buffer
                per_member = [(st, ns, nv, no, zero) for st, ns, nv, no in outs]
            else:
                fn = self.pipe._pass_fn(fused.shared, self.sharded)
                stats, n_sampled, n_valid, n_overflow, n_truncated, _ = fn(
                    key, lat, lon, cols, valid, jnp.float32(max(fractions))
                )
                # analytic, host-side: avoid syncing on the device pass here
                comm = self._analytic_comm(fused, lat.shape[0])
                per_member = []
                for reg in members:
                    kinds_map = reg.plan.column_kind_map
                    # carve this query's columns *and* accumulator kinds
                    # out of the shared pass's union states
                    carved = {
                        c: {k: stats[c][k] for k in kinds_map[c]}
                        for c in reg.plan.columns
                    }
                    per_member.append(
                        (carved, n_sampled, n_valid, n_overflow, n_truncated)
                    )
            comm_total += comm
            self.total_passes += 1
            for reg, (stats_m, n_s, n_v, n_o, n_t) in zip(members, per_member):
                reg.ring.append(
                    _Pane(
                        stats=stats_m,
                        n_sampled=n_s,
                        n_valid=n_v,
                        n_overflow=n_o,
                        n_truncated=n_t,
                        n_dropped=n_dropped,
                        comm_bytes=comm,
                    )
                )
                del reg.ring[: -reg.window.size]
                reg.panes_seen += 1
                reg.downstream_tuples = reg.downstream_tuples + n_s
                if reg.panes_seen % reg.window.stride == 0:
                    emitted[reg.qid] = self._emit(reg, key)
        self._update_controllers(emitted)
        self.pane_index += 1
        self.total_comm_bytes += comm_total
        self.total_dropped += n_dropped
        for cause, n in drop_causes.items():
            self.total_dropped_by_cause[cause] = (
                self.total_dropped_by_cause.get(cause, 0) + n
            )
        return SessionStep(
            results=emitted,
            fractions={r.qid: r.fraction for r in self._regs.values()},
            comm_bytes=comm_total,
            n_dropped=n_dropped,
            pane_index=self.pane_index - 1,
            drop_causes=drop_causes,
        )

    def run(self, panes, key=None) -> list[SessionStep]:
        """Drive the session over an iterator of panes (one key per pane)."""
        key = key if key is not None else jax.random.key(0)  # edgelint: ignore[EDG001] fixed default seed for driverless runs
        history = []
        for pane in panes:
            key, sub = jax.random.split(key)
            history.append(self.step(sub, pane))
        return history

    # -- fault tolerance -----------------------------------------------------

    def checkpoint(self, path=None, keep_last: int | None = None) -> dict:
        """Snapshot the session's resumable state (pane rings, controller
        slices, drop/uplink counters) to a versioned pytree; ``path`` also
        persists it as an ``.npz`` (see :mod:`.checkpoint`).  O(S · columns)
        floats per open pane — cheap enough to take every pane.

        ``keep_last=K`` rotates the K most recent on-disk snapshots
        (``path``, ``path.1``, ...) instead of overwriting in place."""
        from . import checkpoint as ckpt  # sits above session

        snap = ckpt.snapshot(self)
        if path is not None:
            ckpt.save(snap, path, keep_last=keep_last)
        return snap

    def restore(self, snapshot) -> "StreamSession":
        """Load a snapshot (dict or ``.npz`` path) into this session.

        The session must have re-registered the *same* queries in the same
        order (validated against stored fingerprints); rings, fractions,
        EMA state, and drop counters resume exactly where the snapshot was
        taken, so subsequent steps are bit-identical to a session that
        never restarted (given the same per-pane keys)."""
        from . import checkpoint as ckpt

        ckpt.restore(self, snapshot)
        return self

    # -- vectorized QoS ------------------------------------------------------

    def _stacked_slos(self, regs: list[Registration]) -> feedback.StackedSLO:
        sig = tuple((r.qid, r.slo) for r in regs)
        if sig != self._slo_sig:
            self._slo_stack = feedback.stack_slos([r.slo or SLO() for r in regs])
            self._slo_sig = sig
        return self._slo_stack

    @staticmethod
    def _observed_re(reg: Registration, res: QueryResult) -> jnp.ndarray:
        """The scalar RE driving this query's controller entry: its first
        error-bounded aggregate (sum/mean/var/quantile); grouped queries
        report the worst group with a finite RE (all-empty or unidentified
        groups -> inf, which holds the fraction)."""
        rel = jnp.asarray(res.estimates[reg.qos_key].relative_error)
        if rel.ndim:
            finite = jnp.isfinite(rel)
            rel = jnp.where(jnp.any(finite), jnp.max(jnp.where(finite, rel, 0.0)), jnp.inf)
        return rel

    def _update_controllers(self, emitted: dict[int, QueryResult]) -> None:
        """One vectorized controller step over all registrations; only
        queries that emitted an error-bounded result this pane advance."""
        regs = list(self._regs.values())
        active = [r.qos_active and r.qid in emitted for r in regs]
        if not any(active):
            return
        state = ControllerState(
            fraction=jnp.asarray([r.fraction for r in regs], jnp.float32),
            re_ema=jnp.asarray([r.re_ema for r in regs], jnp.float32),
            steps=jnp.asarray([r.steps for r in regs], jnp.int32),
        )
        re_obs = jnp.stack(
            [
                self._observed_re(r, emitted[r.qid]).astype(jnp.float32)
                if on
                else jnp.float32(0.0)
                for r, on in zip(regs, active)
            ]
        )
        n_valid = jnp.stack(
            [
                emitted[r.qid].n_valid.astype(jnp.float32) if on else jnp.float32(1.0)
                for r, on in zip(regs, active)
            ]
        )
        new = feedback.update_vector(
            state, re_obs, n_valid, self._stacked_slos(regs), jnp.asarray(active)
        )
        frac = jax.device_get(new.fraction)
        ema = jax.device_get(new.re_ema)
        for i, reg in enumerate(regs):
            if active[i]:
                reg.fraction = float(frac[i])
                reg.re_ema = float(ema[i])
                reg.steps += 1
