"""Continuous-query sessions: registered QuerySets over shared sampling passes.

The paper's system answers *many concurrent* continuous queries over the
same geospatial stream, each with its own SLO.  One-shot ``execute`` calls
re-stratify and re-sample the window once per query; a
:class:`StreamSession` amortizes that work across the whole registered
workload (the StreamApprox / ApproxIoT observation that edge-side
approximate analytics wins by sharing one sampling pass):

  * ``register(query, slo=..., window=...)`` any number of declarative
    :class:`~.query.Query` specs, each with an optional pane-based
    :class:`~.windows.WindowSpec` (tumbling / sliding / hopping).
  * Registrations are partitioned **incrementally** into *fusion groups* —
    queries whose plans share a sampling signature
    (:func:`~.query.fusion_key`: method, mode, ROI) and therefore draw
    identical sampling decisions.  ``register`` inserts into (or creates)
    exactly one group; ``unregister`` removes from (or dissolves) one —
    the rest of the partition, its fused plans, and its compiled edge
    programs are untouched, so a register/unregister storm over thousands
    of tenants never replans the world.  Every admission decision lands in
    ``plan_log`` (a :class:`PlanDecision` audit trail).
  * Each ``step(key, pane)`` runs **one** stratify+EdgeSOS pass and one
    collective per fusion group (:func:`~.query.fuse`).  Due windows then
    emit through a **batched finalize**: queries sharing a *finalize
    signature* (:func:`~.query.finalize_signature` — aggregates, grouping,
    confidence, column layout; ROI/method/mode drop out) are stacked on a
    leading axis and carved out of the shared merged ``ColumnStats`` by one
    jitted ``vmap`` dispatch per signature — one compiled program per
    signature, not per query, with bit-parity to the per-query path.
    ``step.results`` materializes per-query views lazily on access, so a
    pane serving thousands of registered dashboards pays O(signatures)
    dispatches, and only the results actually read pay slicing.
  * Sliding/hopping windows fall out of the mergeable-accumulator design:
    the edge reduces each *pane* (stride-sized sub-window) to per-stratum
    registry pytrees (``{column: {kind: state}}`` — moments, extrema,
    quantile sketches, any registered accumulator); the session keeps a
    ring of panes per query and merges them cloud-side
    (:func:`~.estimators.merge_accs_panes`, one vectorized pass per kind)
    into each window's answer without re-touching raw tuples.
  * Per-query QoS runs through a vectorized feedback controller: the whole
    tenant population's ``(fraction, re_ema, steps)`` mirrors stack into
    ``(Q,)`` arrays (:func:`~.feedback.stack_states`), batched relative
    errors scatter in per signature batch
    (:func:`~.feedback.scatter_observations`), and one
    :func:`~.feedback.update_vector` call advances every controller.
  * **Per-query fraction refinement**: when a preagg fusion group's member
    fractions diverge (or a Bernoulli group's ROIs differ), the group runs
    the *refined* edge program (:func:`~.pipeline._fused_edge_program`):
    one shared stratify + randomness draw, thinned per member to its own
    fraction by nested Horvitz-Thompson subsampling (shared SRS ranks /
    shared Bernoulli uniforms, deterministic in the step key).  Each
    member's estimates, error bounds, and downstream volume then reflect
    its *own* effective fraction — a 10%-fraction query fused with an 80%
    one pays 10% downstream — instead of free-riding the group max.
  * **Checkpoint/restore**: ``checkpoint()`` snapshots every registration's
    pane ring, controller slice, and the session drop/uplink counters to a
    versioned pytree (:mod:`.checkpoint`); ``restore()`` into a freshly
    registered session resumes mid-window bit-identically.
  * ``emit_all(key)`` is the pull-based serving read: finalize every
    registration's *current* window on demand (batched, no pane advance) —
    the path a fleet of polling dashboards hits between panes.

Compiled-program caches live on the :class:`~.pipeline.EdgeCloudPipeline`
(passes keyed by plan value, finalizes keyed by finalize signature), so
churning tenants that re-register structurally-seen queries recompile
nothing; the pipeline's ``cache_stats`` counters make that a testable,
benchmark-gated contract.

Correctness contract (property-tested): with every query at the same
fraction, a session step's estimates are elementwise-identical (same PRNG
key) to running each query through ``pipeline.execute`` independently, in
both ``preagg`` and ``raw`` modes — fusion changes the *cost*, never the
answer; batching finalize across a signature changes the *dispatch count*,
never the answer.  With divergent per-query fractions, refined preagg
members are *still* elementwise-identical to independent ``execute`` at
their own fraction (the nested subsample IS the sample their independent
draw would produce); raw-mode groups keep the group-max behavior, so their
per-query error is never worse than requested.  And the incremental
planner is equivalent to full replanning: after any register/unregister
sequence the group partition, fused plans, and subsequent estimates match
a fresh session registering the survivors in order.

``EdgeCloudPipeline.run_stream`` is a thin shim over a single-query session.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import codec as wirecodec
from . import feedback
from . import query as aqp
from .feedback import SLO, ControllerState
from .query import (
    FusedPlan,
    Plan,
    Query,
    QueryResult,
    finalize_signature,
    fuse,
    fusion_key,
)
from .windows import WindowSpec


class _Pane(NamedTuple):
    """One pane's contribution to a registered query's window ring.

    ``n_sampled`` is this *member's* realized sample of the pane — the
    refined (nested-subsampled / ROI-masked) size when the group ran the
    refined pass, the shared group sample otherwise.
    """

    stats: dict  # column -> {kind: state} registry pytree (query's columns)
    n_sampled: jnp.ndarray
    n_valid: jnp.ndarray
    n_overflow: jnp.ndarray
    n_truncated: jnp.ndarray
    n_dropped: int
    comm_bytes: int


@dataclasses.dataclass
class Registration:
    """Handle for one registered continuous query (returned by ``register``).

    Carries the query's lowered plan, pane ring, and its slice of the
    session's controller state (``fraction``/``re_ema``/``steps``).
    ``slo=None`` means no QoS: the fraction stays fixed.
    """

    qid: int
    query: Query
    slo: SLO | None
    window: WindowSpec
    plan: Plan
    qos_key: str | None  # agg key driving QoS; None holds the fraction
    fraction: float
    re_ema: float = 0.0
    steps: int = 0
    panes_seen: int = 0
    ring: list = dataclasses.field(default_factory=list)
    # running count of tuples *this* query's samples kept (device-lazy);
    # under refinement a low-fraction member accumulates its own, smaller,
    # nested sample here instead of the group max's
    downstream_tuples: int | jnp.ndarray = 0
    # uplink bytes shipped for this query since its previous window emit
    # (host int, exact past 2^31).  Emitted windows report *this* — bytes
    # newly shipped — rather than re-summing every overlapped pane, so
    # sliding/hopping window comm totals over a span add up to the
    # session's actual uplink instead of multiply-counting shared panes.
    pending_comm: int = 0

    @property
    def qos_active(self) -> bool:
        return self.slo is not None and self.qos_key is not None

    @property
    def downstream_bytes(self) -> int:
        """Downstream volume this query's samples cost so far: realized
        kept tuples x the plan's per-tuple layout (see
        :func:`~.query.downstream_tuple_bytes`).  Reading this syncs the
        device-lazy tuple counter."""
        return int(self.downstream_tuples) * aqp.downstream_tuple_bytes(self.plan)


class PlanDecision(NamedTuple):
    """One entry of the session's admission/planning audit trail.

    ``outcome`` is what the incremental planner did to the partition:
    ``new-group`` (first member of a fresh fusion signature), ``joined``
    (inserted into an existing group), ``left`` (removed, group survives),
    or ``dissolved`` (last member removed, group deleted).  ``group_size``
    is the member count *after* the decision.
    """

    seq: int
    action: str  # "register" | "unregister"
    qid: int
    group_key: tuple  # fusion_key of the affected group
    outcome: str  # "new-group" | "joined" | "left" | "dissolved"
    group_size: int


class _FusionGroup:
    """One fusion-signature partition cell, maintained incrementally.

    Owns its member list (registration order), the lazily re-fused carrier
    plan, and memoized references to the pipeline's compiled edge programs
    — so the per-pane hot loop never re-hashes O(members) plan tuples to
    look them up, and a membership change invalidates exactly this group.
    """

    __slots__ = ("key", "members", "_fused", "_pass_fn", "_refined_fn", "_codec")

    def __init__(self, key: tuple):
        self.key = key
        self.members: list[Registration] = []
        self._fused: FusedPlan | None = None
        self._pass_fn = None
        self._refined_fn = None
        # per-stream uplink codec instances ("shared" / member qid -> codec);
        # membership changes drop them so stateful (delta) streams re-open
        # with a keyframe instead of diffing against a differently-shaped
        # previous frame
        self._codec: dict = {}

    def invalidate(self) -> None:
        self._fused = None
        self._pass_fn = None
        self._refined_fn = None
        self._codec = {}

    def fused_plan(self) -> FusedPlan:
        if self._fused is None:
            self._fused = fuse([r.plan for r in self.members])
        return self._fused


class _EmitBatch(NamedTuple):
    """One batched finalize dispatch: ``regs`` queries sharing a finalize
    signature and pane count, their stacked estimates/stats (leading axis
    ``>= len(regs)``, padded rows repeat row 0), and per-member window
    counters ``(n_sampled, n_valid, n_overflow, n_truncated, comm_bytes,
    n_dropped)``."""

    regs: tuple
    estimates: dict  # agg key -> AggEstimate with batch-leading leaves
    stats: dict  # column -> {kind: state} with batch-leading leaves
    counters: tuple


_PENDING = object()


def _carve_result(batch: _EmitBatch, i: int) -> QueryResult:
    """Materialize member ``i``'s :class:`QueryResult` view of a batch."""
    estimates = {
        k: aqp.AggEstimate(*(x[i] for x in est))
        for k, est in batch.estimates.items()
    }
    stats = jax.tree.map(lambda x: x[i], batch.stats)
    n_s, n_v, n_o, n_t, comm, dropped = batch.counters[i]
    return QueryResult(
        estimates=estimates,
        stats=stats,
        n_sampled=n_s,
        n_valid=n_v,
        n_overflow=n_o,
        n_truncated=n_t,
        # host int, never a jnp.int32: cumulative uplink past 2^31 bytes
        # must not wrap negative on long streams
        comm_bytes=comm,
        n_dropped=dropped,
    )


class _LazyResults(dict):
    """``qid -> QueryResult`` mapping over batched finalize output.

    Batch members materialize (slice their rows out of the stacked
    estimates/stats) only on access — iteration, ``values()``, ``items()``,
    ``get`` and ``[]`` all materialize; membership/len/``keys()`` never do.
    A pane that served thousands of registrations therefore pays per-query
    slicing only for the results something actually reads.
    """

    def __init__(self):
        super().__init__()
        self._sources: dict[int, tuple[_EmitBatch, int]] = {}
        self._batches: list[_EmitBatch] = []

    def _add(self, qid: int, batch: _EmitBatch, row: int) -> None:
        dict.__setitem__(self, qid, _PENDING)
        self._sources[qid] = (batch, row)

    def __getitem__(self, qid):
        v = dict.__getitem__(self, qid)
        if v is _PENDING:
            batch, row = self._sources.pop(qid)
            v = _carve_result(batch, row)
            dict.__setitem__(self, qid, v)
        return v

    def get(self, qid, default=None):
        return self[qid] if qid in self else default

    def values(self):  # noqa: D102 - dict interface
        return [self[q] for q in self]

    def items(self):  # noqa: D102 - dict interface
        return [(q, self[q]) for q in self]


class SessionStep(NamedTuple):
    """Outcome of feeding one pane to the session.

    results: qid -> QueryResult for queries whose window emitted this pane
      (a query with stride s emits every s panes; others are absent).
      Batched-finalize members materialize lazily on access
      (:class:`_LazyResults`).
    fractions: qid -> post-update controller fraction, for every
      registration.
    comm_bytes: total edge->cloud payload of this pane's shared passes (one
      per fusion group — the fused uplink cost of the whole QuerySet).
      The analytic dense model by default; the *measured* encoded frame
      bytes when ``PipelineConfig.uplink_codec`` is set.  Always a host
      int — cumulative totals stay exact past 2^31.
    n_dropped: tuples shed before this pane reached the device (bounded
      time windows, ingest-queue backpressure, load shedding).
    pane_index: 0-based index of the pane within the session.
    drop_causes: cause -> tuple-count breakdown of ``n_dropped`` (causes:
      ``late`` / ``queue_full`` / ``shed``; uncaused legacy counts land in
      ``late``).  Do not mutate — it may be the class-level default.
    """

    results: dict
    fractions: dict
    comm_bytes: int
    n_dropped: int
    pane_index: int
    drop_causes: dict = {}


class StreamSession:
    """Continuous-query engine over an :class:`~.pipeline.EdgeCloudPipeline`.

    Typical use::

        sess = StreamSession(pipe)
        speed = sess.register(Query(aggs=(AggSpec("mean", "value"),)),
                              slo=SLO(target_relative_error=0.05))
        occ = sess.register(Query(aggs=(AggSpec("mean", "occupancy"),)),
                            window=WindowSpec("sliding", size=4))
        for step in sess.run(pane_windows(stream, pane_tuples=20_000), key=key):
            if speed.qid in step.results:
                ...  # step.results[speed.qid].estimates["mean_value"]

    All registered queries that share a sampling signature are served by one
    stratify+EdgeSOS pass and one collective per pane; all due queries that
    share a finalize signature emit through one vmapped finalize dispatch
    (``batched_finalize=False`` falls back to the per-query emit loop —
    the A/B ``benchmarks/multitenant_bench.py`` gates).
    """

    def __init__(
        self,
        pipeline,
        *,
        sharded: bool = False,
        initial_fraction: float = 0.8,
        batched_finalize: bool = True,
    ):
        self.pipe = pipeline
        self.sharded = sharded
        self.initial_fraction = float(initial_fraction)
        self.batched_finalize = bool(batched_finalize)
        self.pane_index = 0
        self.total_comm_bytes = 0
        self.total_dropped = 0
        self.total_dropped_by_cause: dict = {}
        self.total_passes = 0  # edge passes run (one per fusion group per pane)
        self._regs: dict[int, Registration] = {}
        self._next_qid = 0
        # incremental fusion partition: fusion_key -> group, insertion order
        self._fusion_groups: dict[tuple, _FusionGroup] = {}
        self._reg_group: dict[int, _FusionGroup] = {}
        self.plan_log: list[PlanDecision] = []
        # jitted emit paths cache on the *pipeline* (like _passes): plan and
        # table both derive from the pipe, so a fresh session over a warmed
        # pipe pays zero first-pane compiles — the contract
        # benchmarks/ingest_throughput.py's warm-up relies on
        self._finalizers = pipeline._finalizers
        # controller layout (qid -> row, stacked SLOs) memo; dirtied by
        # membership changes, rebuilt lazily at the next controller update
        self._rows: dict[int, int] = {}
        self._slo_stack: feedback.StackedSLO | None = None
        self._ctrl_dirty = True

    # -- registration --------------------------------------------------------

    def register(
        self,
        query: Query,
        *,
        slo: SLO | None = None,
        window: WindowSpec | None = None,
        initial_fraction: float | None = None,
    ) -> Registration:
        """Register a continuous query; returns its handle.

        ``slo=None`` disables QoS for this query (fixed fraction).  The
        query joins the session's fusion groups from the next ``step``.
        Admission is incremental: only the one fusion group whose sampling
        signature the plan carries is (lazily) re-fused; every other
        group's fused plan and compiled programs are untouched.
        """
        window = window or WindowSpec()
        plan = self.pipe.plan(query)
        # the first *error-bounded* aggregate drives QoS: sum/mean (eq 5-10
        # CIs) and, since the bounds subsystem, var and p<q> quantiles —
        # but only while their bootstrap is enabled (replicates > 0;
        # disabled bounds report zero-width RE 0, which would collapse the
        # fraction).  min/max report only conservative one-sided
        # order-statistic bounds and count is exact — neither drives the
        # controller.
        boot_on = query.bootstrap_replicates > 0

        def _drives(a) -> bool:
            if a.kind in ("sum", "mean"):
                return True
            return boot_on and (
                a.kind == "var" or aqp.quantile_of(a.kind) is not None
            )

        qos_key = next((a.key for a in query.aggs if _drives(a)), None)
        reg = Registration(
            qid=self._next_qid,
            query=query,
            slo=slo,
            window=window,
            plan=plan,
            qos_key=qos_key,
            fraction=float(initial_fraction if initial_fraction is not None else self.initial_fraction),
        )
        self._next_qid += 1
        self._regs[reg.qid] = reg
        gkey = fusion_key(plan)
        grp = self._fusion_groups.get(gkey)
        outcome = "joined" if grp is not None else "new-group"
        if grp is None:
            grp = _FusionGroup(gkey)
            self._fusion_groups[gkey] = grp
        grp.members.append(reg)
        grp.invalidate()
        self._reg_group[reg.qid] = grp
        self._log_decision("register", reg.qid, gkey, outcome, len(grp.members))
        self._ctrl_dirty = True
        return reg

    def unregister(self, reg: Registration) -> None:
        """Drop a registered query (its pane ring is discarded).

        Removal is incremental: the member leaves its one fusion group
        (which dissolves when emptied); no other group replans.
        """
        if self._regs.pop(reg.qid, None) is None:
            return
        grp = self._reg_group.pop(reg.qid)
        grp.members.remove(reg)
        grp.invalidate()
        if not grp.members:
            del self._fusion_groups[grp.key]
            outcome = "dissolved"
        else:
            outcome = "left"
        self._log_decision("unregister", reg.qid, grp.key, outcome, len(grp.members))
        self._ctrl_dirty = True

    def _log_decision(
        self, action: str, qid: int, gkey: tuple, outcome: str, size: int
    ) -> None:
        self.plan_log.append(
            PlanDecision(
                seq=len(self.plan_log),
                action=action,
                qid=qid,
                group_key=gkey,
                outcome=outcome,
                group_size=size,
            )
        )

    @property
    def registrations(self) -> tuple[Registration, ...]:
        return tuple(self._regs.values())

    def controller_state(self, reg: Registration) -> ControllerState:
        """This registration's slice of the vectorized controller state."""
        return ControllerState(
            fraction=jnp.float32(reg.fraction),
            re_ema=jnp.float32(reg.re_ema),
            steps=jnp.int32(reg.steps),
        )

    # -- fusion machinery ----------------------------------------------------

    def _groups(self) -> list[list[Registration]]:
        """The fusion partition as member lists (compatibility view over the
        incremental group structure): registration order within groups,
        group-creation order across them."""
        return [list(g.members) for g in self._fusion_groups.values()]

    def _analytic_comm(self, fused: FusedPlan, n_rows: int) -> int:
        """Per-shard uplink bytes of one shared pass, computed host-side.

        Mirrors ``_edge_program``'s analytic accounting (it is a static
        property of the plan, not of the data) so the hot loop never blocks
        on the device just to read back a constant.
        """
        plan = fused.shared
        if plan.query.mode == "raw":
            cap = self.pipe.config.raw_capacity
            if cap is None:
                shards = 1
                if self.sharded:
                    shape = self.pipe.mesh.shape
                    shards = math.prod(shape[a] for a in self.pipe.axis_names)
                cap = n_rows // shards
            return aqp.raw_bytes(plan, cap)
        return aqp.preagg_bytes(plan, self.pipe.table.num_slots)

    def _codec_ship(self, grp: _FusionGroup, slot, stats) -> tuple[dict, int]:
        """Ship one uplink stream's registry states through the configured
        wire codec (see :mod:`.codec`): returns the *decoded* states the
        cloud tier consolidates plus the frame's measured encoded bytes —
        the byte accounting truth that replaces :meth:`_analytic_comm`'s
        dense model when ``PipelineConfig.uplink_codec`` is set.

        ``slot`` names the stream within the group (``"shared"`` for the
        union pass, the member qid for refined per-member frames); stateful
        codecs (delta) keep per-stream DPCM state here, dropped on any
        membership change (group invalidation) and on ``restore`` so those
        boundaries re-open with a keyframe.  Encoding is the pane loop's
        one deliberate device sync: the uplink serialization boundary
        itself, where states become wire bytes by definition.
        """
        stream = grp._codec.get(slot)
        if stream is None:
            stream = grp._codec[slot] = self.pipe.codec_spec.for_stream()
        return wirecodec.roundtrip(stream, stats)

    def _window_counters(self, reg: Registration) -> tuple:
        """This query's window-level counters, summed over its pane ring
        (device-lazy adds; host ints for the byte/drop accounting).

        ``comm`` is the bytes *newly shipped* for this query since its
        previous emit (``pending_comm``), not a re-sum of every pane in
        the ring: a sliding window re-reads panes it already paid for, so
        summing the overlap would report more uplink over a span than the
        session actually spent.  Tumbling windows are unchanged (every
        pane is new).  Read non-destructively — ``emit_all``'s serving
        reads must not consume the counter; ``step`` resets it only after
        a scheduled emit."""
        panes = reg.ring
        n_sampled = panes[0].n_sampled
        n_valid = panes[0].n_valid
        n_overflow = panes[0].n_overflow
        n_truncated = panes[0].n_truncated
        for p in panes[1:]:
            n_sampled = n_sampled + p.n_sampled
            n_valid = n_valid + p.n_valid
            n_overflow = n_overflow + p.n_overflow
            n_truncated = n_truncated + p.n_truncated
        comm = reg.pending_comm
        dropped = sum(p.n_dropped for p in panes)
        return (n_sampled, n_valid, n_overflow, n_truncated, comm, dropped)

    def _window_stats(self, reg: Registration):
        """The ring's stats, stacked on a leading pane axis when the window
        spans multiple panes (pass-through for one pane, preserving
        bit-compatibility with ``execute``)."""
        panes = reg.ring
        if len(panes) == 1:
            return panes[0].stats
        return jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0), *[p.stats for p in panes]
        )

    def _emit(self, reg: Registration, key) -> QueryResult:
        """Assemble this query's window from its pane ring and finalize.

        ``key`` (the step key) seeds the bootstrap error bounds: a
        one-pane window finalizes with the same key as the shared pass, so
        session bounds are bit-identical to an independent ``execute``."""
        fn = self.pipe.finalize_fn(reg.plan, len(reg.ring))
        estimates, stats = fn(self._window_stats(reg), key)
        n_sampled, n_valid, n_overflow, n_truncated, comm, dropped = (
            self._window_counters(reg)
        )
        return QueryResult(
            estimates=estimates,
            stats=stats,
            n_sampled=n_sampled,
            n_valid=n_valid,
            n_overflow=n_overflow,
            n_truncated=n_truncated,
            # uplink newly spent since this query's previous emit (host
            # int — exact past 2^31; see _window_counters)
            comm_bytes=comm,
            # window-level drop accounting: tuples the window's panes shed
            # upstream (survives checkpoint/restore — the ring carries it)
            n_dropped=dropped,
        )

    def _emit_batch(self, regs: list, num_panes: int, key) -> _EmitBatch:
        """One vmapped finalize over a finalize-signature batch: member
        window stats stacked on a leading axis *inside* the jitted program
        (padded to the next power of two by repeating row 0, so churning
        batch widths step through O(log Q) compiled programs), key
        broadcast — each row computes exactly its singleton finalize."""
        member_stats = [self._window_stats(reg) for reg in regs]
        b = len(regs)
        b_pad = 1 << (b - 1).bit_length()
        member_stats = member_stats + [member_stats[0]] * (b_pad - b)
        fn = self.pipe.batched_finalize_fn(regs[0].plan, num_panes, b_pad)
        estimates, stats = fn(member_stats, key)
        counters = tuple(self._window_counters(reg) for reg in regs)
        return _EmitBatch(
            regs=tuple(regs), estimates=estimates, stats=stats, counters=counters
        )

    def _emit_due(self, due: list, key, out: _LazyResults):
        """Emit every due registration into ``out``.

        Batches due queries by ``(finalize_signature, ring length)`` and
        emits each multi-member batch through one vmapped dispatch;
        singleton batches (and ``batched_finalize=False`` sessions) take
        the per-query path.  Returns ``(singles, batches)`` for the
        controller update: materialized ``(reg, result)`` pairs and the
        :class:`_EmitBatch` list (whose relative-error vectors feed the
        controller without materializing per-query views).
        """
        singles: list[tuple] = []
        batch_list = out._batches  # the serving read's stacked-output view
        if not self.batched_finalize:
            for reg in due:
                res = self._emit(reg, key)
                out[reg.qid] = res
                singles.append((reg, res))
            return singles, batch_list
        partition: dict[tuple, list] = {}
        for reg in due:
            bkey = (finalize_signature(reg.plan), len(reg.ring))
            partition.setdefault(bkey, []).append(reg)
        computed: dict[tuple, tuple] = {}
        for reg in due:
            bkey = (finalize_signature(reg.plan), len(reg.ring))
            members = partition[bkey]
            if len(members) == 1:
                res = self._emit(reg, key)
                out[reg.qid] = res
                singles.append((reg, res))
                continue
            entry = computed.get(bkey)
            if entry is None:
                batch = self._emit_batch(members, bkey[1], key)
                rows = {m.qid: i for i, m in enumerate(members)}
                entry = computed[bkey] = (batch, rows)
                batch_list.append(batch)
            out._add(reg.qid, entry[0], entry[1][reg.qid])
        return singles, batch_list

    def emit_all(self, key) -> _LazyResults:
        """Finalize every registration's *current* window on demand — the
        pull-based serving read a polling consumer hits between panes.

        Does not advance panes, windows, or controllers; registrations
        with empty rings (never stepped) are absent.  Batched exactly like
        ``step``'s due-window emit, so Q concurrent dashboards cost
        O(finalize signatures) dispatches, not O(Q)."""
        out = _LazyResults()
        due = [r for r in self._regs.values() if r.ring]
        self._emit_due(due, key, out)
        return out

    # -- the continuous loop -------------------------------------------------

    @staticmethod
    def _refines(fused: FusedPlan, fractions: list[float]) -> bool:
        """Host-side choice of edge program for one group this pane.

        The *shared* pass (one union accumulation at the group-max
        fraction, bit-compatible with the pre-refinement session) serves
        single members and uniform-fraction same-ROI groups; the *refined*
        per-member pass serves divergent-fraction preagg groups and
        cross-ROI Bernoulli groups (which the shared pass cannot express).
        Raw-mode groups always share: their compacted uplink buffer is one
        ROI-filtered sample at the group max.  Neyman groups always share
        too — refined thinning would need per-stratum stddev threading to
        preserve the variance-optimal allocation.
        """
        if len(fused.members) < 2:
            return False
        if fused.cross_roi:
            return True
        if fused.mode != "preagg" or fused.shared.query.method == "neyman":
            return False
        return len(set(fractions)) > 1

    def step(self, key, pane) -> SessionStep:
        """Feed one pane through every fusion group and emit due windows.

        Every group's pass uses ``key`` directly (not folded), so a
        single-group session reproduces ``execute(query, key, ...)`` exactly.
        """
        if not self._regs:
            raise ValueError("step() on a session with no registered queries")
        n_dropped = int(getattr(pane, "n_dropped", 0))
        drop_causes = dict(getattr(pane, "drop_causes", None) or {})
        uncaused = n_dropped - sum(drop_causes.values())
        if uncaused > 0:  # legacy producers: window-level sheds count as late
            drop_causes["late"] = drop_causes.get("late", 0) + uncaused
        emitted = _LazyResults()
        due: list[Registration] = []
        comm_total = 0
        for grp in list(self._fusion_groups.values()):
            members = grp.members
            fused = grp.fused_plan()
            fractions = [r.fraction for r in members]
            lat, lon, cols, valid = self.pipe._window_arrays(pane, fused.shared)
            if self._refines(fused, fractions):
                fn = grp._refined_fn
                if fn is None:
                    fn = grp._refined_fn = self.pipe._refined_pass_fn(
                        fused, self.sharded
                    )
                outs, _ = fn(
                    key, lat, lon, cols, valid, jnp.asarray(fractions, jnp.float32)
                )
                zero = jnp.int32(0)  # refined pass is preagg-only: no buffer
                if self.pipe.codec_spec is not None:
                    # refined passes ship one encoded frame per member (each
                    # member's thinned states are its own uplink stream)
                    shipped = [
                        self._codec_ship(grp, reg.qid, st)
                        for reg, (st, _ns, _nv, _no) in zip(members, outs)
                    ]
                    comm = sum(nb for _st, nb in shipped)
                    per_member = [
                        (st, ns, nv, no, zero, nb)
                        for (st, nb), (_st, ns, nv, no) in zip(shipped, outs)
                    ]
                else:
                    comm = aqp.refined_preagg_bytes(fused, self.pipe.table.num_slots)
                    per_member = [
                        (st, ns, nv, no, zero, comm) for st, ns, nv, no in outs
                    ]
            else:
                fn = grp._pass_fn
                if fn is None:
                    fn = grp._pass_fn = self.pipe._pass_fn(fused.shared, self.sharded)
                stats, n_sampled, n_valid, n_overflow, n_truncated, _ = fn(
                    key, lat, lon, cols, valid, jnp.float32(max(fractions))
                )
                if self.pipe.codec_spec is not None and fused.mode == "preagg":
                    # one encoded union frame serves the whole group; the
                    # members below carve the *decoded* states, so their
                    # estimates reflect exactly what crossed the wire
                    stats, comm = self._codec_ship(grp, "shared", stats)
                else:
                    # analytic, host-side: avoid syncing on the device pass
                    comm = self._analytic_comm(fused, lat.shape[0])
                per_member = []
                for reg in members:
                    kinds_map = reg.plan.column_kind_map
                    # carve this query's columns *and* accumulator kinds
                    # out of the shared pass's union states
                    carved = {
                        c: {k: stats[c][k] for k in kinds_map[c]}
                        for c in reg.plan.columns
                    }
                    per_member.append(
                        (carved, n_sampled, n_valid, n_overflow, n_truncated, comm)
                    )
            comm_total += comm
            self.total_passes += 1
            for reg, (stats_m, n_s, n_v, n_o, n_t, comm_m) in zip(members, per_member):
                reg.ring.append(
                    _Pane(
                        stats=stats_m,
                        n_sampled=n_s,
                        n_valid=n_v,
                        n_overflow=n_o,
                        n_truncated=n_t,
                        n_dropped=n_dropped,
                        comm_bytes=comm_m,
                    )
                )
                del reg.ring[: -reg.window.size]
                reg.panes_seen += 1
                reg.pending_comm += comm_m
                reg.downstream_tuples = reg.downstream_tuples + n_s
                if reg.panes_seen % reg.window.stride == 0:
                    due.append(reg)
        singles, batches = self._emit_due(due, key, emitted)
        for reg in due:  # emitted windows consumed their newly-shipped bytes
            reg.pending_comm = 0
        self._update_controllers(singles, batches)
        self.pane_index += 1
        self.total_comm_bytes += comm_total
        self.total_dropped += n_dropped
        for cause, n in drop_causes.items():
            self.total_dropped_by_cause[cause] = (
                self.total_dropped_by_cause.get(cause, 0) + n
            )
        return SessionStep(
            results=emitted,
            fractions={r.qid: r.fraction for r in self._regs.values()},
            comm_bytes=comm_total,
            n_dropped=n_dropped,
            pane_index=self.pane_index - 1,
            drop_causes=drop_causes,
        )

    def run(self, panes, key=None) -> list[SessionStep]:
        """Drive the session over an iterator of panes (one key per pane)."""
        key = key if key is not None else jax.random.key(0)  # edgelint: ignore[EDG001] fixed default seed for driverless runs
        history = []
        for pane in panes:
            key, sub = jax.random.split(key)
            history.append(self.step(sub, pane))
        return history

    # -- fault tolerance -----------------------------------------------------

    def checkpoint(self, path=None, keep_last: int | None = None) -> dict:
        """Snapshot the session's resumable state (pane rings, controller
        slices, drop/uplink counters) to a versioned pytree; ``path`` also
        persists it as an ``.npz`` (see :mod:`.checkpoint`).  O(S · columns)
        floats per open pane — cheap enough to take every pane.

        ``keep_last=K`` rotates the K most recent on-disk snapshots
        (``path``, ``path.1``, ...) instead of overwriting in place."""
        from . import checkpoint as ckpt  # sits above session

        snap = ckpt.snapshot(self)
        if path is not None:
            ckpt.save(snap, path, keep_last=keep_last)
        return snap

    def restore(self, snapshot) -> "StreamSession":
        """Load a snapshot (dict or ``.npz`` path) into this session.

        The session must have re-registered the *same* queries in the same
        order (validated against stored fingerprints); rings, fractions,
        EMA state, and drop counters resume exactly where the snapshot was
        taken, so subsequent steps are bit-identical to a session that
        never restarted (given the same per-pane keys)."""
        from . import checkpoint as ckpt

        ckpt.restore(self, snapshot)
        # controller arrays re-stack from the restored host mirrors at the
        # next update; layout (rows / SLO stack) is membership-keyed and
        # membership did not change, but re-deriving it is cheap and safe.
        # (ckpt.restore itself drops stateful uplink codec streams, so the
        # first pane after any restore path ships a keyframe.)
        self._ctrl_dirty = True
        return self

    # -- vectorized QoS ------------------------------------------------------

    def _controller_layout(self) -> tuple[dict, feedback.StackedSLO]:
        """Memoized (qid -> row) map + stacked SLO parameters for the
        current registration set; rebuilt only after membership changes."""
        if self._ctrl_dirty:
            regs = list(self._regs.values())
            self._rows = {r.qid: i for i, r in enumerate(regs)}
            self._slo_stack = feedback.stack_slos([r.slo or SLO() for r in regs])
            self._ctrl_dirty = False
        return self._rows, self._slo_stack

    @staticmethod
    def _observed_re(reg: Registration, res: QueryResult) -> jnp.ndarray:
        """The scalar RE driving this query's controller entry: its first
        error-bounded aggregate (sum/mean/var/quantile); grouped queries
        report the worst group with a finite RE (all-empty or unidentified
        groups -> inf, which holds the fraction)."""
        rel = jnp.asarray(res.estimates[reg.qos_key].relative_error)
        if rel.ndim:
            finite = jnp.isfinite(rel)
            rel = jnp.where(jnp.any(finite), jnp.max(jnp.where(finite, rel, 0.0)), jnp.inf)
        return rel

    @staticmethod
    def _observed_re_batch(qos_key: str, batch: _EmitBatch) -> jnp.ndarray:
        """Vectorized :meth:`_observed_re` over a batch: the per-row RE
        vector (grouped queries reduce their group axis per row)."""
        rel = jnp.asarray(batch.estimates[qos_key].relative_error)
        if rel.ndim > 1:
            finite = jnp.isfinite(rel)
            rel = jnp.where(
                jnp.any(finite, axis=-1),
                jnp.max(jnp.where(finite, rel, 0.0), axis=-1),
                jnp.inf,
            )
        return rel

    def _update_controllers(self, singles: list, batches: list) -> None:
        """One vectorized controller step over all registrations; only
        queries that emitted an error-bounded result this pane advance.

        Batched emissions feed their stacked relative-error vectors in
        directly (one segment per batch, no per-query materialization);
        singleton emissions stack into one extra segment.  The whole
        population then advances through a single
        :func:`~.feedback.update_vector` call.
        """
        rows = None
        segments = []
        active_rows: list[int] = []
        s_rows, s_re, s_nv = [], [], []
        for reg, res in singles:
            if not reg.qos_active:
                continue
            if rows is None:
                rows, slo_stack = self._controller_layout()
            s_rows.append(rows[reg.qid])
            s_re.append(self._observed_re(reg, res).astype(jnp.float32))
            s_nv.append(res.n_valid.astype(jnp.float32))
        if s_rows:
            segments.append((s_rows, jnp.stack(s_re), jnp.stack(s_nv)))
            active_rows.extend(s_rows)
        for batch in batches:
            qos_key = batch.regs[0].qos_key
            act = [i for i, r in enumerate(batch.regs) if r.qos_active]
            if qos_key is None or not act:
                continue
            if rows is None:
                rows, slo_stack = self._controller_layout()
            rel = self._observed_re_batch(qos_key, batch)
            idx = jnp.asarray(act, jnp.int32)
            b_rows = [rows[batch.regs[i].qid] for i in act]
            n_valid = jnp.stack(
                [batch.counters[i][1] for i in act]
            ).astype(jnp.float32)
            segments.append((b_rows, rel[idx].astype(jnp.float32), n_valid))
            active_rows.extend(b_rows)
        if not active_rows:
            return
        regs = list(self._regs.values())
        state = feedback.stack_states(
            (r.fraction, r.re_ema, r.steps) for r in regs
        )
        re_obs, n_obs = feedback.scatter_observations(len(regs), segments)
        active = [False] * len(regs)
        for i in active_rows:
            active[i] = True
        new = feedback.update_vector(state, re_obs, n_obs, slo_stack, jnp.asarray(active))
        frac = jax.device_get(new.fraction)
        ema = jax.device_get(new.re_ema)
        for i, reg in enumerate(regs):
            if active[i]:
                reg.fraction = float(frac[i])
                reg.re_ema = float(ema[i])
                reg.steps += 1
