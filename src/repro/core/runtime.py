"""Async pane-pipelined streaming driver: the execution layer over sessions.

The paper's latency claim (§5.2) hinges on the edge node *overlapping* its
three per-pane phases — arrival, host→device staging, fused edge compute —
instead of summing them.  A bare :class:`~.session.StreamSession` is
synchronous pane-at-a-time: ``step`` is async-dispatch-friendly (it never
blocks on the device), but whoever drives it still interleaves ingest and
compute on one thread.  :class:`StreamRuntime` is that driver done right:

  * a **producer thread** pulls panes from a pluggable :class:`Source`
    (any iterable of ``WindowBatch`` — the ``data/streams.py`` generators
    via ``pane_windows``, or a bursty simulator in tests) into a
    :class:`~.qdisc.BoundedPaneQueue`;
  * the **pane loop** double-buffers staging: pane k+1 is ``jax.device_put``
    while pane k's fused edge program runs — and *never* calls
    ``block_until_ready`` / ``.item()`` / ``device_get`` (edgelint EDG002
    polices ``run``/``process``/``_consume``/``_dispatch`` un-suppressed);
    the only blocking sync lives in :meth:`_retire`, which waits on a pane
    that is ``max_inflight`` dispatches old — i.e. almost always already
    finished — to bound the in-flight window and timestamp completions;
  * **backpressure** sheds at the queue (drop-newest/drop-oldest) and the
    shed tuples flow into the existing accounting chain: they are attached
    to the next admitted pane's ``n_dropped``/``drop_causes`` and surface in
    ``QueryResult.n_dropped`` and the session's ``total_dropped_by_cause``;
  * **event-driven sampling** (:class:`~.feedback.EventPolicy`): watched
    registrations decay to an idle fraction while their per-stratum means
    are stable and snap to a hot fraction on a shift or heartbeat — the
    change score is computed lazily on-device and read back one pane late
    (:meth:`_read_score`), so quiet regions cost ~nothing and the readback
    never stalls the dispatch stream;
  * **load shedding**: when queue depth crosses ``shed_highwater`` the
    runtime scales every registration's fraction by ``shed_fraction_scale``
    (floored at ``shed_min_fraction``) and optionally decimates arrivals
    (deterministic 1-in-k, cause ``shed``); it restores fractions when the
    queue falls below ``shed_lowwater`` — degrade, never crash;
  * **drain-then-snapshot checkpointing**: :meth:`checkpoint` first
    processes every queued/staged pane, then snapshots the session, so a
    restore resumes bit-identically to an uninterrupted run even when the
    ingest queue was non-empty at snapshot time;
  * :class:`RuntimeStats` observability: per-pane ingest/stage/dispatch
    latency histograms + percentiles, queue high-water mark, drops by
    cause, and overlap efficiency (compute-busy wall fraction) — consumed
    by ``benchmarks/ingest_throughput.py`` and gated in CI.

Determinism: the runtime derives pane k's PRNG key as
``jax.random.fold_in(root_key, k)`` (the checkpoint-replay discipline), so
with a lossless queue policy (``"block"``) its estimates are bit-identical
to a synchronous ``session.step`` loop over the same panes.  The clock is
injectable (``RuntimeConfig.clock``) and everything else is
arrival-order-deterministic — no RNG, keeping the core closure EDG001-clean.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Callable, Iterator, Protocol, runtime_checkable

import jax
import numpy as np

from . import feedback
from .feedback import EventPolicy, EventState
from .qdisc import BoundedPaneQueue, DropLedger
from .windows import WindowBatch


@runtime_checkable
class Source(Protocol):
    """Anything the producer thread can iterate for panes.

    The existing window iterators (``pane_windows``/``count_windows``/
    ``time_windows`` over ``data/streams.py`` generators) already satisfy
    this; ``data/sources.py`` adds paced/bursty arrival simulators for
    tests and benchmarks.
    """

    def __iter__(self) -> Iterator[WindowBatch]: ...


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the driver; defaults favor throughput with bounded memory.

    ``clock`` is an injectable monotonic timer (tests freeze it); the
    default is the uncalled ``time.perf_counter`` reference — the runtime
    itself never reads a wall clock except through this hook.
    """

    queue_capacity: int = 8
    policy: str = "drop-newest"  # see qdisc.QUEUE_POLICIES
    max_inflight: int = 2  # dispatched-but-unretired panes kept in flight
    stage_flush_s: float = 0.002  # max time a staged pane waits for a successor
    load_shedding: bool = False  # opt-in: degrade fractions under saturation
    shed_highwater: float = 0.75  # queue fill ratio entering shed mode
    shed_lowwater: float = 0.25  # queue fill ratio leaving shed mode
    shed_fraction_scale: float = 0.5  # fraction multiplier while shedding
    shed_min_fraction: float = 0.05
    shed_decimate: int = 0  # while shedding admit 1 of every k panes (0=off)
    clock: Callable[[], float] = time.perf_counter


@dataclasses.dataclass
class _Arrival:
    """A pane plus its producer-side timestamps, as queued.

    Exposes ``size``/``drop_causes`` so the queue's drop accounting reads
    through to the wrapped pane.
    """

    pane: WindowBatch
    t_enqueue: float
    ingest_s: float  # producer time spent obtaining this pane from the source

    @property
    def size(self) -> int:
        return getattr(self.pane, "size", 0)

    @property
    def drop_causes(self) -> dict:
        return getattr(self.pane, "drop_causes", {}) or {}


@dataclasses.dataclass
class _Staged:
    arrival: _Arrival
    pane: WindowBatch  # columns already on device (jax.device_put issued)
    t_dequeue: float
    t_staged: float


@dataclasses.dataclass
class _InFlight:
    pane_index: int
    arrival: _Arrival
    t_dequeue: float
    t_staged: float
    t_dispatch: float
    t_dispatched: float
    markers: object  # pytree whose leaves complete when the pane is done


@dataclasses.dataclass
class PaneTiming:
    """Completed-pane timing record (all seconds, runtime clock)."""

    pane_index: int
    ingest_s: float  # producer: source iteration time for this pane
    queue_wait_s: float  # enqueue -> dequeue
    stage_s: float  # dequeue -> device_put issued
    dispatch_s: float  # session.step host time (async dispatch cost)
    latency_s: float  # enqueue -> retired (end-to-end pane latency)
    t_dispatch: float
    t_retired: float


_HIST_EDGES_MS = tuple(0.25 * 2.0**k for k in range(16))  # 0.25ms .. ~8.2s


def _histogram_ms(values_s) -> dict:
    """Log-bucketed latency histogram: upper-edge-ms -> count (+inf tail)."""
    counts = {f"{edge:g}": 0 for edge in _HIST_EDGES_MS}
    counts["inf"] = 0
    for v in values_s:
        ms = v * 1e3
        for edge in _HIST_EDGES_MS:
            if ms <= edge:
                counts[f"{edge:g}"] += 1
                break
        else:
            counts["inf"] += 1
    return counts


def _percentiles(values_s) -> dict:
    if not values_s:
        return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0, "max_ms": 0.0}
    arr = np.asarray(values_s, np.float64) * 1e3
    return {
        "p50_ms": float(np.percentile(arr, 50)),
        "p95_ms": float(np.percentile(arr, 95)),
        "p99_ms": float(np.percentile(arr, 99)),
        "max_ms": float(arr.max()),
    }


@dataclasses.dataclass
class RuntimeStats:
    """Observability snapshot of one runtime (see :meth:`StreamRuntime.stats`).

    ``overlap_efficiency`` is compute-busy wall fraction: the union of the
    in-flight intervals [dispatch, retire] over the span from first dispatch
    to last retire — 1.0 means the device never waited on ingest.
    """

    panes_processed: int
    panes_enqueued: int
    tuples_processed: int
    queue_depth_high_water: int
    dropped_tuples_by_cause: dict
    dropped_panes_by_cause: dict
    shed_panes: int
    overlap_efficiency: float
    wall_s: float
    ingest: dict
    queue_wait: dict
    stage: dict
    dispatch: dict
    pane_latency: dict
    histograms: dict
    # pipeline compiled-program cache counters (per jit family hit/miss plus
    # the aggregate compile_count) — the multi-tenant churn contract's
    # observability surface; empty when the session exposes no pipeline
    compile_cache: dict = dataclasses.field(default_factory=dict)
    # uplink byte accounting: cumulative comm_bytes (measured encoded bytes
    # when an uplink codec is configured, the analytic dense model otherwise)
    # plus the codec fingerprint the figure was measured under (None = dense)
    uplink: dict = dataclasses.field(default_factory=dict)

    @property
    def dropped_tuples(self) -> int:
        return sum(self.dropped_tuples_by_cause.values())


def _overlap_efficiency(timings) -> float:
    """Union of [t_dispatch, t_retired] intervals / overall wall."""
    if not timings:
        return 0.0
    spans = sorted((t.t_dispatch, t.t_retired) for t in timings)
    wall = max(hi for _, hi in spans) - spans[0][0]
    if wall <= 0.0:
        return 1.0
    busy = 0.0
    cur_lo, cur_hi = spans[0]
    for lo, hi in spans[1:]:
        if lo > cur_hi:
            busy += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    busy += cur_hi - cur_lo
    return busy / wall


class StreamRuntime:
    """Producer/consumer driver running a :class:`~.session.StreamSession`.

    Typical use::

        sess = StreamSession(pipe)
        sess.register(query, window=WindowSpec())
        rt = StreamRuntime(sess, key=jax.random.key(0),
                           config=RuntimeConfig(policy="drop-oldest"))
        history = rt.run(pane_windows(stream, pane_tuples=20_000))
        rt.stats().pane_latency["p99_ms"], rt.stats().dropped_tuples_by_cause

    Incremental (single-threaded, deterministic) use::

        rt.offer(pane)          # enqueue without a producer thread
        rt.process()            # consume whatever is queued, no waiting
        rt.drain()              # flush staged + retire everything in flight
        rt.checkpoint(path)     # drain-then-snapshot
    """

    def __init__(self, session, key=None, config: RuntimeConfig | None = None):
        self.session = session
        self.config = config or RuntimeConfig()
        self.queue = BoundedPaneQueue(self.config.queue_capacity, self.config.policy)
        self._clock = self.config.clock
        self._root_key = key
        self._history: list = []
        self._timings: list[PaneTiming] = []
        self._inflight: collections.deque[_InFlight] = collections.deque()
        self._staged: _Staged | None = None
        self._producer: threading.Thread | None = None
        self._watches: dict[int, tuple] = {}  # qid -> (reg, policy, column, state)
        self._pending_scores: list = []  # (reg, lazy score, matured-at pane)
        self._prev_means: dict[int, object] = {}  # qid -> last pane's mean vector
        self._shed_saved: dict[int, float] | None = None
        self.shed_panes = 0
        self._n_tuples = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    # -- event-driven sampling ----------------------------------------------

    def watch(self, reg, policy: EventPolicy | None = None, column: str | None = None):
        """Enable heartbeat + change-triggered fraction control for ``reg``.

        ``column`` defaults to the plan's first column; its per-stratum
        moment means drive the change score.  Incompatible with an SLO on
        the same registration only in the sense that both write
        ``reg.fraction`` — last writer (the SLO controller runs inside
        ``session.step``, the event hook just before the *next* dispatch)
        wins; in practice watched queries are registered without an SLO.
        """
        column = column or reg.plan.columns[0]
        self._watches[reg.qid] = (reg, policy or EventPolicy(), column, EventState())
        return self

    def _queue_events(self, _step) -> None:
        """After a dispatch: enqueue lazy change scores for watched regs.

        The score compares this pane's per-stratum moment means to the
        previous pane's — both device-resident; nothing syncs here.
        """
        for qid, (reg, policy, column, state) in self._watches.items():
            if not reg.ring:
                continue
            stats = reg.ring[-1].stats.get(column)
            moments = stats.get("moments") if stats else None
            if moments is None:
                continue
            prev = self._prev_means.get(qid)
            self._prev_means[qid] = moments.mean
            if prev is not None:
                score = feedback.change_score(prev, moments.mean)
                self._pending_scores.append((reg, policy, state, score))

    def _read_score(self, score) -> float:
        """The event loop's single sync point, one pane late by design: the
        score was dispatched a full pane ago and is all but guaranteed
        materialized, so this readback does not stall the stream."""
        return float(jax.device_get(score))

    def _apply_events(self) -> None:
        """Before the next dispatch: apply matured (pane-old) scores."""
        pending, self._pending_scores = self._pending_scores, []
        for reg, policy, state, score in pending:
            reg.fraction = feedback.event_fraction(
                state, self._read_score(score), reg.fraction, policy
            )

    # -- load shedding -------------------------------------------------------

    def _maybe_shed(self) -> None:
        cfg = self.config
        if not cfg.load_shedding:
            return
        depth = self.queue.depth
        hi = math.ceil(cfg.shed_highwater * self.queue.capacity)
        lo = math.floor(cfg.shed_lowwater * self.queue.capacity)
        if self._shed_saved is None and depth >= hi:
            self._shed_saved = {}
            for reg in self.session.registrations:
                self._shed_saved[reg.qid] = reg.fraction
                reg.fraction = max(
                    cfg.shed_min_fraction, reg.fraction * cfg.shed_fraction_scale
                )
            if cfg.shed_decimate > 1:
                self.queue.set_decimation(cfg.shed_decimate)
        elif self._shed_saved is not None and depth <= lo:
            for reg in self.session.registrations:
                saved = self._shed_saved.get(reg.qid)
                if saved is not None:
                    # never leave a fraction *below* its pre-shed value on
                    # account of shedding; controllers may have moved it up
                    reg.fraction = max(reg.fraction, saved)
            self._shed_saved = None
            self.queue.set_decimation(0)
        if self._shed_saved is not None:
            self.shed_panes += 1

    @property
    def shedding(self) -> bool:
        return self._shed_saved is not None

    # -- producer ------------------------------------------------------------

    def offer(self, pane, timeout: float | None = None) -> bool:
        """Enqueue one pane (producer side); returns True iff admitted."""
        t = self._clock()
        return self.queue.put(_Arrival(pane, t, 0.0), timeout=timeout)

    def _pump(self, source: Source) -> None:
        clock = self._clock
        t_prev = clock()
        try:
            for pane in source:
                t = clock()
                self.queue.put(_Arrival(pane, t, t - t_prev))
                t_prev = clock()
        except RuntimeError:
            return  # queue closed under us: consumer stopped early
        finally:
            if not self.queue.closed:
                self.queue.close()

    # -- the pane loop (EDG002-policed: no host syncs here) ------------------

    def run(self, source: Source, key=None, max_panes: int | None = None) -> list:
        """Drive the session over ``source`` with a producer thread; returns
        the accumulated ``SessionStep`` history (also at ``self.history``)."""
        if key is not None:
            self._root_key = key
        if self._root_key is None:
            raise ValueError("StreamRuntime needs a PRNG key (constructor or run(key=...))")
        self._producer = threading.Thread(
            target=self._pump, args=(source,), name="stream-runtime-pump", daemon=True
        )
        self._producer.start()
        try:
            self._consume(wait=True, max_panes=max_panes)
        finally:
            if not self.queue.closed:
                self.queue.close()  # early stop: unblock + terminate the producer
            self._producer.join()
            self._producer = None
            self.flush()
            self._retire_all()
        return self._history

    def process(self, max_panes: int | None = None) -> list:
        """Consume panes already queued via :meth:`offer`, without waiting.

        Leaves up to ``max_inflight`` panes un-retired (pipelined); call
        :meth:`drain` for a full barrier.  Returns steps emitted this call.
        """
        before = len(self._history)
        self._consume(wait=False, max_panes=max_panes)
        return self._history[before:]

    def _consume(self, wait: bool, max_panes: int | None = None) -> None:
        clock = self._clock
        n = 0
        while max_panes is None or n < max_panes:
            if wait:
                timeout = self.config.stage_flush_s if self._staged is not None else None
            else:
                timeout = 0.0
            arrival = self.queue.get(timeout=timeout)
            if arrival is None:
                if not wait or self.queue.closed:
                    break
                # get() timed out with a pane staged and no successor in
                # sight: flush it rather than trade latency for overlap
                self.flush()
                continue
            t_deq = clock()
            staged = self._stage(arrival, t_deq)
            if self._staged is not None:
                # double buffer: dispatch pane k while pane k+1's H2D
                # transfer (issued above) proceeds asynchronously
                self._dispatch(self._staged)
            self._staged = staged
            n += 1
        if not wait:
            self.flush()

    def _stage(self, arrival: _Arrival, t_dequeue: float) -> _Staged:
        """Issue the pane's host→device transfers (async on real backends)."""
        pane = arrival.pane
        staged = dataclasses.replace(
            pane,
            lat=jax.device_put(pane.lat),
            lon=jax.device_put(pane.lon),
            value=jax.device_put(pane.value),
            valid=jax.device_put(pane.valid),
            extra={k: jax.device_put(v) for k, v in pane.extra.items()},
        )
        return _Staged(arrival, staged, t_dequeue, self._clock())

    def _dispatch(self, staged: _Staged) -> None:
        """Feed one staged pane to the session — pure async dispatch."""
        arrival, pane = staged.arrival, staged.pane
        ledger = self.queue.take_drops()
        if ledger:
            pane = self._attach_drops(pane, ledger)
        self._apply_events()
        self._maybe_shed()
        key = jax.random.fold_in(self._root_key, self.session.pane_index)
        t0 = self._clock()
        step = self.session.step(key, pane)
        t1 = self._clock()
        if self._t_first is None:
            self._t_first = t0
        self._n_tuples += arrival.size
        self._queue_events(step)
        self._history.append(step)
        self._inflight.append(
            _InFlight(
                pane_index=step.pane_index,
                arrival=arrival,
                t_dequeue=staged.t_dequeue,
                t_staged=staged.t_staged,
                t_dispatch=t0,
                t_dispatched=t1,
                markers=self._markers(step),
            )
        )
        while len(self._inflight) > self.config.max_inflight:
            self._retire(self._inflight.popleft())

    def flush(self) -> None:
        """Dispatch the currently staged pane, if any."""
        if self._staged is not None:
            staged, self._staged = self._staged, None
            self._dispatch(staged)

    def _markers(self, step) -> object:
        """Device values that complete exactly when this pane's work does:
        every registration's freshest ring state plus any emitted results."""
        rings = [reg.ring[-1].stats for reg in self.session.registrations if reg.ring]
        emitted = [r.estimates for r in step.results.values()]
        return (rings, emitted)

    @staticmethod
    def _attach_drops(pane: WindowBatch, ledger: DropLedger) -> WindowBatch:
        """Fold queue-side drops into the pane's accounting fields so they
        ride the existing chain (pane -> ring -> QueryResult -> session)."""
        causes = dict(getattr(pane, "drop_causes", {}) or {})
        for cause, n in ledger.tuples.items():
            causes[cause] = causes.get(cause, 0) + n
        return dataclasses.replace(
            pane,
            n_dropped=int(getattr(pane, "n_dropped", 0)) + ledger.total_tuples,
            drop_causes=causes,
        )

    # -- retirement: the one blocking boundary, outside the pane loop --------

    def _retire(self, entry: _InFlight) -> None:
        """Wait for a pane ``max_inflight`` dispatches old and record its
        timing.  This is the runtime's only ``block_until_ready`` — it
        bounds device memory in flight and timestamps completion, and by
        construction the pane is (nearly) always already done."""
        jax.block_until_ready(entry.markers)
        t = self._clock()
        self._t_last = t
        self._timings.append(
            PaneTiming(
                pane_index=entry.pane_index,
                ingest_s=entry.arrival.ingest_s,
                queue_wait_s=entry.t_dequeue - entry.arrival.t_enqueue,
                stage_s=entry.t_staged - entry.t_dequeue,
                dispatch_s=entry.t_dispatched - entry.t_dispatch,
                latency_s=t - entry.arrival.t_enqueue,
                t_dispatch=entry.t_dispatch,
                t_retired=t,
            )
        )

    def _retire_all(self) -> None:
        while self._inflight:
            self._retire(self._inflight.popleft())

    # -- drain / checkpoint --------------------------------------------------

    def drain(self) -> list:
        """Process everything queued *now*, flush the staged pane, and
        retire all in-flight work (a full pipeline barrier).  Bounded: panes
        admitted after entry are left for the next call."""
        budget = self.queue.depth + (1 if self._staged is not None else 0)
        steps = self.process(max_panes=budget) if budget else []
        self.flush()
        self._retire_all()
        return steps

    def checkpoint(self, path=None, keep_last: int | None = None) -> dict:
        """Drain-then-snapshot: queued and staged panes are fully processed
        before the session snapshot is taken, so restoring it and replaying
        the *remaining* source panes (fold_in key discipline) is
        bit-identical to a run that never stopped."""
        self.drain()
        return self.session.checkpoint(path, keep_last=keep_last)

    # -- observability -------------------------------------------------------

    @property
    def history(self) -> list:
        return self._history

    def stats(self) -> RuntimeStats:
        timings = self._timings
        wall = (
            (self._t_last - self._t_first)
            if self._t_first is not None and self._t_last is not None
            else 0.0
        )
        series = {
            "ingest": [t.ingest_s for t in timings],
            "queue_wait": [t.queue_wait_s for t in timings],
            "stage": [t.stage_s for t in timings],
            "dispatch": [t.dispatch_s for t in timings],
            "pane_latency": [t.latency_s for t in timings],
        }
        return RuntimeStats(
            panes_processed=len(self._history),
            panes_enqueued=self.queue.total_put,
            tuples_processed=self._n_tuples,
            queue_depth_high_water=self.queue.high_water,
            dropped_tuples_by_cause=dict(self.queue.ledger.tuples),
            dropped_panes_by_cause=dict(self.queue.ledger.panes),
            shed_panes=self.shed_panes,
            overlap_efficiency=_overlap_efficiency(timings),
            wall_s=wall,
            ingest=_percentiles(series["ingest"]),
            queue_wait=_percentiles(series["queue_wait"]),
            stage=_percentiles(series["stage"]),
            dispatch=_percentiles(series["dispatch"]),
            pane_latency=_percentiles(series["pane_latency"]),
            histograms={k: _histogram_ms(v) for k, v in series.items()},
            compile_cache=(
                pipe.cache_snapshot()
                if (pipe := getattr(self.session, "pipe", None)) is not None
                and hasattr(pipe, "cache_snapshot")
                else {}
            ),
            uplink={
                "total_comm_bytes": int(
                    getattr(self.session, "total_comm_bytes", 0)
                ),
                "uplink_codec": (
                    spec.fingerprint()
                    if (
                        spec := getattr(
                            getattr(self.session, "pipe", None),
                            "codec_spec",
                            None,
                        )
                    )
                    is not None
                    else None
                ),
            },
        )
