"""Pane checkpoint/restore: restart-safe continuous-query sessions.

Edge nodes fail and restart; the paper's continuous queries must not lose
their open sliding windows when they do.  A :class:`~.session.StreamSession`
is resumable from a tiny snapshot because every window is assembled from
*mergeable per-stratum accumulator states* — the pane rings are
O(S · columns) floats per pane, the controller slice is three scalars per
query, and nothing else in the session is stateful.  This module
serializes exactly that:

  * per registration: the pane ring (each pane's ``{column: {kind:
    state}}`` registry pytree + its counters), the controller slice
    (``fraction``/``re_ema``/``steps``), ``panes_seen`` (window emission
    phase), the downstream-volume counter, and ``pending_comm`` (uplink
    bytes shipped since the last window emit);
  * per session: ``pane_index``, the ``total_comm_bytes`` /
    ``total_dropped`` / ``total_passes`` diagnostics — so
    ``WindowBatch.n_dropped`` accounting survives a restore boundary —
    and the uplink codec fingerprint (restoring under a *different* wire
    format would silently change what the resumed stream's byte
    accounting means, so a mismatch is rejected like a query-fingerprint
    mismatch).  Byte counters are Python ints end to end: a long stream's
    cumulative uplink crosses 2^31 and must round-trip exactly.

Snapshots are **versioned** plain dicts of numpy arrays and Python
scalars (no pickling): :func:`save` / :func:`load` round-trip them through
a single ``.npz`` file whose scalar schema rides in an embedded JSON
header.  Restoration is **bit-exact**: f32 ring leaves round-trip
losslessly through numpy, controller floats through JSON's shortest-repr
floats, so a restored session's subsequent estimates, intervals, and drop
accounting are bit-identical to a session that never restarted (given the
same per-pane PRNG keys — key discipline stays with the driver).

Queries themselves are *not* serialized (they are code): the restoring
process re-registers the same queries in the same order, and
:func:`restore` validates each registration against a stored fingerprint
of its query + window spec before touching any state.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np

import jax.numpy as jnp

from . import estimators

SNAPSHOT_VERSION = 1

_COUNTER_FIELDS = ("n_sampled", "n_valid", "n_overflow", "n_truncated")


def _fingerprint(reg) -> str:
    """Stable identity of a registration: its query spec + window shape.

    ``Query``/``WindowSpec`` are frozen dataclasses of primitives, so their
    reprs are deterministic across processes; the plan is derived from the
    query, so it needs no fingerprint of its own.
    """
    return f"{reg.query!r}|{reg.window!r}"


def _ring_structure(plan):
    """The treedef a registration's pane stats must match (dict keys are
    flattened in sorted order by jax, so leaf order is canonical)."""
    kinds_map = plan.column_kind_map
    template = {c: estimators.accs_template(kinds_map[c]) for c in plan.columns}
    return jax.tree.structure(template)


def snapshot(sess) -> dict:
    """Capture a session's resumable state as a versioned pytree of numpy
    arrays + Python scalars (see module docstring for the schema)."""
    regs = []
    for reg in sess.registrations:
        ring = []
        for p in reg.ring:
            ring.append(
                {
                    "leaves": [np.asarray(x) for x in jax.tree.leaves(p.stats)],
                    "counters": {
                        f: int(getattr(p, f)) for f in _COUNTER_FIELDS
                    },
                    "n_dropped": int(p.n_dropped),
                    "comm_bytes": int(p.comm_bytes),
                }
            )
        regs.append(
            {
                "fingerprint": _fingerprint(reg),
                "fraction": float(reg.fraction),
                "re_ema": float(reg.re_ema),
                "steps": int(reg.steps),
                "panes_seen": int(reg.panes_seen),
                "downstream_tuples": int(reg.downstream_tuples),
                # additive (still version 1): bytes shipped since the last
                # emit; absent in older snapshots (reconstructed on restore)
                "pending_comm": int(reg.pending_comm),
                "ring": ring,
            }
        )
    codec_spec = getattr(sess.pipe, "codec_spec", None)
    return {
        "version": SNAPSHOT_VERSION,
        "pane_index": int(sess.pane_index),
        "total_comm_bytes": int(sess.total_comm_bytes),
        # additive (still version 1): the uplink wire-format fingerprint
        # this session's byte accounting was measured under
        "uplink_codec": None if codec_spec is None else codec_spec.fingerprint(),
        "total_dropped": int(sess.total_dropped),
        # additive (still version 1): cause -> tuples breakdown of
        # total_dropped; absent in pre-runtime snapshots, restored as {}
        "total_dropped_by_cause": {
            str(k): int(v)
            for k, v in getattr(sess, "total_dropped_by_cause", {}).items()
        },
        "total_passes": int(sess.total_passes),
        "registrations": regs,
    }


def restore(sess, snap) -> None:
    """Load ``snap`` (a snapshot dict or an ``.npz`` path) into ``sess``.

    ``sess`` must carry the same registrations, in the same order, as the
    session the snapshot was taken from (fingerprint-validated).  Raises
    ``ValueError`` on a version, registration, or ring-shape mismatch
    before mutating any state.
    """
    from .session import _Pane  # session imports checkpoint lazily

    if not isinstance(snap, dict):
        snap = load(snap)
    version = snap.get("version")
    if version != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported session snapshot version {version!r}; this build "
            f"reads version {SNAPSHOT_VERSION}"
        )
    codec_spec = getattr(sess.pipe, "codec_spec", None)
    current_codec = None if codec_spec is None else codec_spec.fingerprint()
    if "uplink_codec" in snap and snap["uplink_codec"] != current_codec:
        raise ValueError(
            f"snapshot was taken under uplink codec "
            f"{snap['uplink_codec']!r} but the session is configured with "
            f"{current_codec!r}; byte accounting is not comparable across "
            f"wire formats — restore with the matching PipelineConfig"
        )
    regs = list(sess.registrations)
    stored = snap["registrations"]
    if len(regs) != len(stored):
        raise ValueError(
            f"snapshot holds {len(stored)} registrations but the session has "
            f"{len(regs)}; re-register the original query set before restoring"
        )
    rebuilt = []
    for reg, rec in zip(regs, stored):
        fp = _fingerprint(reg)
        if rec["fingerprint"] != fp:
            raise ValueError(
                f"registration {reg.qid} does not match the snapshot: "
                f"expected {rec['fingerprint']}, session has {fp}"
            )
        structure = _ring_structure(reg.plan)
        ring = []
        for p in rec["ring"]:
            if len(p["leaves"]) != structure.num_leaves:
                raise ValueError(
                    f"registration {reg.qid}: pane has {len(p['leaves'])} "
                    f"state leaves, plan expects {structure.num_leaves}"
                )
            stats = jax.tree.unflatten(
                structure, [jnp.asarray(x) for x in p["leaves"]]
            )
            ring.append(
                _Pane(
                    stats=stats,
                    n_dropped=int(p["n_dropped"]),
                    comm_bytes=int(p["comm_bytes"]),
                    **{f: jnp.int32(p["counters"][f]) for f in _COUNTER_FIELDS},
                )
            )
        rebuilt.append(ring)
    # validation passed for every registration: commit
    for reg, rec, ring in zip(regs, stored, rebuilt):
        reg.fraction = float(rec["fraction"])
        reg.re_ema = float(rec["re_ema"])
        reg.steps = int(rec["steps"])
        reg.panes_seen = int(rec["panes_seen"])
        reg.downstream_tuples = int(rec["downstream_tuples"])
        reg.ring = ring
        if "pending_comm" in rec:
            reg.pending_comm = int(rec["pending_comm"])
        else:
            # older snapshot: reconstruct "bytes shipped since the last
            # emit" from the ring — the panes arrived after the previous
            # window boundary are the last panes_seen % stride of the ring
            since_emit = min(
                int(rec["panes_seen"]) % max(reg.window.stride, 1), len(ring)
            )
            reg.pending_comm = sum(
                int(p.comm_bytes) for p in ring[len(ring) - since_emit:]
            ) if since_emit else 0
    sess.pane_index = int(snap["pane_index"])
    sess.total_comm_bytes = int(snap["total_comm_bytes"])
    sess.total_dropped = int(snap["total_dropped"])
    sess.total_dropped_by_cause = {
        str(k): int(v) for k, v in snap.get("total_dropped_by_cause", {}).items()
    }
    sess.total_passes = int(snap["total_passes"])
    # stateful uplink codecs (delta) lose their cross-pane reference frame
    # at any restore boundary — cleared here rather than in
    # StreamSession.restore so a direct module-level restore() gets the
    # same guarantee: the first pane after restore ships a keyframe
    # (still lossless, just larger) instead of diffing against a frame
    # the restored stream never saw
    for grp in getattr(sess, "_fusion_groups", {}).values():
        grp._codec = {}


def rotation_path(path, age: int) -> str:
    """The on-disk name of the ``age``-panes-old snapshot of ``path``:
    ``path`` itself for age 0, ``path.1`` (previous), ``path.2``, ..."""
    return str(path) if age == 0 else f"{path}.{age}"


def _rotate(path, keep_last: int) -> None:
    """Shift the retained history one slot: ``path`` -> ``path.1`` -> ...
    dropping anything at or beyond ``keep_last`` (each shift is its own
    ``os.replace``, so a crash mid-rotation loses at most the oldest
    retained snapshots — never the newest good one)."""
    age = 1
    while os.path.exists(rotation_path(path, age)):
        age += 1
    for old in range(age, keep_last - 1, -1):  # prune beyond the new budget
        stale = rotation_path(path, old)
        if os.path.exists(stale):
            os.remove(stale)
    for old in range(min(age, keep_last - 1), 0, -1):
        src = rotation_path(path, old - 1)
        if os.path.exists(src):
            os.replace(src, rotation_path(path, old))


def save(snap: dict, path, keep_last: int | None = None) -> None:
    """Persist a snapshot as one ``.npz``: ring leaves as arrays, every
    scalar in an embedded JSON header (no pickling anywhere).

    The write is **atomic** (temp file + ``os.replace``): checkpointing
    every pane over the same path must never truncate the last good
    snapshot if the node dies mid-write — that crash is exactly the event
    this module exists to survive.

    ``keep_last=K`` retains a rotation of the K most recent snapshots:
    before writing, the existing ``path`` is shifted to ``path.1``,
    ``path.1`` to ``path.2``, ... and anything older than K−1 shifts is
    pruned (see :func:`rotation_path`).  A corrupted newest snapshot —
    e.g. external truncation after a successful write — can then be
    recovered by loading ``rotation_path(path, 1)`` and replaying one more
    pane.  ``keep_last=None`` (default) keeps the single-file behavior."""
    if keep_last is not None:
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1; got {keep_last}")
        _rotate(path, keep_last)
    arrays: dict[str, np.ndarray] = {}
    meta = {k: v for k, v in snap.items() if k != "registrations"}
    meta_regs = []
    for i, rec in enumerate(snap["registrations"]):
        ring_meta = []
        for j, p in enumerate(rec["ring"]):
            for k, leaf in enumerate(p["leaves"]):
                arrays[f"r{i}.p{j}.l{k}"] = np.asarray(leaf)
            ring_meta.append(
                {
                    "num_leaves": len(p["leaves"]),
                    "counters": p["counters"],
                    "n_dropped": p["n_dropped"],
                    "comm_bytes": p["comm_bytes"],
                }
            )
        meta_regs.append({**{k: v for k, v in rec.items() if k != "ring"}, "ring": ring_meta})
    meta["registrations"] = meta_regs
    arrays["__meta__"] = np.frombuffer(json.dumps(meta).encode("utf-8"), np.uint8)
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **arrays)
    os.replace(tmp, path)


def load(path) -> dict:
    """Read a snapshot written by :func:`save` back into its dict form."""
    with np.load(path) as npz:
        meta = json.loads(bytes(npz["__meta__"].tobytes()).decode("utf-8"))
        regs = []
        for i, rec in enumerate(meta["registrations"]):
            ring = []
            for j, p in enumerate(rec["ring"]):
                ring.append(
                    {
                        "leaves": [
                            npz[f"r{i}.p{j}.l{k}"] for k in range(p["num_leaves"])
                        ],
                        "counters": p["counters"],
                        "n_dropped": p["n_dropped"],
                        "comm_bytes": p["comm_bytes"],
                    }
                )
            regs.append({**{k: v for k, v in rec.items() if k != "ring"}, "ring": ring})
    return {**{k: v for k, v in meta.items() if k != "registrations"}, "registrations": regs}
