"""Queue disciplines for the streaming runtime: bounded ingest with
first-class drop accounting.

The paper's edge nodes sit behind bursty producers (§5.2.4): arrival rate
routinely exceeds compute rate, and the system-level question is not *whether*
to drop but *which tuples, counted where*.  :class:`BoundedPaneQueue` is the
single admission point between a :class:`~.runtime.StreamRuntime`'s producer
thread and its pane loop:

  * ``policy="block"``       producer waits for space — lossless, used when
                             bit-identity with the synchronous driver matters
                             (tests, drains, replay);
  * ``policy="drop-newest"`` arriving pane is shed when full (tail drop —
                             favors in-flight work, the paper's Kafka-producer
                             behavior under burst);
  * ``policy="drop-oldest"`` head-of-line pane is evicted to admit the
                             arrival (favors freshness — recency-biased
                             dashboards).

Every shed pane is recorded in a :class:`DropLedger` keyed by *cause*
(``queue_full`` for policy drops, ``shed`` for load-shedding decimation) and
counted in *tuples*, the same unit as ``WindowBatch.n_dropped`` — plus any
upstream drops the evicted pane was itself carrying (``late`` from bounded
time windows), so no loss ever silently vanishes from the accounting chain
``WindowBatch.n_dropped`` -> ``QueryResult.n_dropped`` -> session diagnostics.
The runtime attaches the pending ledger to the next admitted pane.

Everything here is host-side stdlib (deque + condition variable): no RNG, no
clock reads — the queue is deterministic given the put/get interleaving, and
EDG001-clean inside the core import closure.
"""

from __future__ import annotations

import collections
import dataclasses
import threading

QUEUE_POLICIES = ("block", "drop-newest", "drop-oldest")

# canonical drop causes flowing through WindowBatch.drop_causes
CAUSE_LATE = "late"  # bounded-buffer window overflow (windows.time_windows)
CAUSE_QUEUE_FULL = "queue_full"  # backpressure policy drop at the ingest queue
CAUSE_SHED = "shed"  # load-shedding decimation under saturation


@dataclasses.dataclass
class DropLedger:
    """Tuples (and panes) shed, keyed by cause; mergeable and summable."""

    tuples: dict = dataclasses.field(default_factory=dict)
    panes: dict = dataclasses.field(default_factory=dict)

    def add(self, cause: str, n_tuples: int, n_panes: int = 1) -> None:
        self.tuples[cause] = self.tuples.get(cause, 0) + int(n_tuples)
        self.panes[cause] = self.panes.get(cause, 0) + int(n_panes)

    def merge_causes(self, causes: dict) -> None:
        """Fold an upstream ``WindowBatch.drop_causes`` dict into the ledger
        (tuple counts only — those drops never formed panes here)."""
        for cause, n in (causes or {}).items():
            self.tuples[cause] = self.tuples.get(cause, 0) + int(n)

    @property
    def total_tuples(self) -> int:
        return sum(self.tuples.values())

    def __bool__(self) -> bool:
        return bool(self.tuples or self.panes)


def _pane_tuples(pane) -> int:
    """Valid-tuple count of a pane, host-side (numpy mask sum)."""
    size = getattr(pane, "size", None)
    return int(size) if size is not None else 0


class BoundedPaneQueue:
    """Thread-safe bounded FIFO of panes with drop-accounted admission.

    ``put`` is called from the producer thread, ``get`` from the runtime's
    pane loop.  Shedding (both policy drops and decimation) happens at
    admission so a saturated queue costs the producer O(1) — the paper's
    design point that backpressure must be cheaper than the work it sheds.
    """

    def __init__(self, capacity: int = 8, policy: str = "drop-newest"):
        if capacity < 1:
            raise ValueError(f"queue capacity must be >= 1; got {capacity}")
        if policy not in QUEUE_POLICIES:
            raise ValueError(f"policy must be one of {QUEUE_POLICIES}; got {policy!r}")
        self.capacity = int(capacity)
        self.policy = policy
        self._items: collections.deque = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self._pending = DropLedger()  # drops awaiting attachment to a pane
        self._decimate = 0  # shed mode: admit 1 of every k arrivals (0 = off)
        self._arrivals = 0
        self.high_water = 0  # max depth ever observed
        self.total_put = 0  # panes admitted
        self.ledger = DropLedger()  # lifetime drops (monotonic; for stats)

    # -- producer side -------------------------------------------------------

    def put(self, pane, timeout: float | None = None) -> bool:
        """Offer a pane; returns True iff *this* pane was admitted.

        Under ``drop-oldest`` the arrival is admitted by evicting the head;
        under ``drop-newest`` a full queue sheds the arrival; under
        ``block`` the call waits for space (or ``timeout``).  Decimation
        (see :meth:`set_decimation`) sheds ahead of the policy check.
        """
        with self._cond:
            if self._closed:
                raise RuntimeError("put() on a closed BoundedPaneQueue")
            self._arrivals += 1
            if self._decimate > 1 and (self._arrivals - 1) % self._decimate:
                self._drop(pane, CAUSE_SHED)
                return False
            if len(self._items) >= self.capacity:
                if self.policy == "drop-newest":
                    self._drop(pane, CAUSE_QUEUE_FULL)
                    return False
                if self.policy == "drop-oldest":
                    self._drop(self._items.popleft(), CAUSE_QUEUE_FULL)
                else:  # block
                    ok = self._cond.wait_for(
                        lambda: len(self._items) < self.capacity or self._closed,
                        timeout=timeout,
                    )
                    if self._closed:
                        raise RuntimeError("put() on a closed BoundedPaneQueue")
                    if not ok:
                        self._drop(pane, CAUSE_QUEUE_FULL)
                        return False
            self._items.append(pane)
            self.total_put += 1
            self.high_water = max(self.high_water, len(self._items))
            self._cond.notify_all()
            return True

    def _drop(self, pane, cause: str) -> None:
        n = _pane_tuples(pane)
        self._pending.add(cause, n)
        self.ledger.add(cause, n)
        # the shed pane's own upstream drops must not vanish with it
        upstream = getattr(pane, "drop_causes", None) or {}
        self._pending.merge_causes(upstream)
        self.ledger.merge_causes(upstream)

    def close(self) -> None:
        """No more puts; pending gets drain the queue then return None."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    # -- consumer side -------------------------------------------------------

    def get(self, timeout: float | None = None):
        """Next pane in FIFO order; None once closed *and* drained (or on
        timeout)."""
        with self._cond:
            self._cond.wait_for(
                lambda: self._items or self._closed, timeout=timeout
            )
            if not self._items:
                return None
            pane = self._items.popleft()
            self._cond.notify_all()
            return pane

    def take_drops(self) -> DropLedger:
        """Drain the pending drop ledger (drops not yet attached to a pane).
        The runtime calls this after each successful ``get`` and folds the
        result into that pane's ``n_dropped``/``drop_causes``."""
        with self._cond:
            out, self._pending = self._pending, DropLedger()
            return out

    # -- control / observability --------------------------------------------

    def set_decimation(self, k: int) -> None:
        """Load-shedding decimation: admit 1 of every ``k`` arrivals
        (``k <= 1`` disables).  Deterministic counter-based thinning — no
        RNG in the core closure."""
        with self._cond:
            self._decimate = int(k)

    @property
    def depth(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def closed(self) -> bool:
        return self._closed
