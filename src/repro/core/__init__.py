"""EdgeApproxGeo core: the paper's contribution as a composable JAX query engine.

Layers (bottom-up):
  geohash     — Morton-coded geohash encode/decode (pure integer JAX)
  stratify    — stratum tables (regular geohash grid + neighborhood map)
  sampling    — EdgeSOS decentralized stratified sampling (Algorithm 1)
  estimators  — mergeable per-stratum accumulators (StratumStats moments +
                ColumnStats extrema) and stratified SUM/MEAN + variance/CI/
                MoE/RE (eqs 1-10)
  routing     — spatial-aware data distribution (topic-per-neighborhood)
  feedback    — QoS loop adapting the sampling fraction to SLOs
  windows     — tumbling count/time windows with named value columns, plus
                pane-based ``WindowSpec`` (tumbling/sliding/hopping) shapes
  query       — the declarative AQP layer: ``Query``/``AggSpec`` specs
                (sum|mean|count|min|max|var over named columns, optional
                stratum/neighborhood group-by and bbox/geohash-prefix ROI)
                lowered by ``query.lower`` into an edge partial-aggregation
                program plus a cloud consolidation/finalize step; ``fuse``
                unions lowered plans into one shared edge pass
  pipeline    — the engine executing lowered plans (Algorithm 2): edge
                sample -> mergeable accumulators -> collective -> cloud
                finalize, in pre-aggregated or raw transmission mode
  session     — the continuous-query engine: ``StreamSession`` registers
                any number of queries (each with an SLO and WindowSpec),
                serves each fusion group with one sampling pass per pane
                (nested HT subsampling refines the shared sample to each
                member's own fraction; differing-ROI Bernoulli queries
                fuse cross-signature), and merges pane accumulators into
                sliding/hopping windows
  checkpoint  — pane checkpoint/restore: versioned session snapshots
                (rings + controller slices + drop counters) that resume a
                restarted session mid-window bit-identically
  qdisc       — bounded ingest queues (block/drop-newest/drop-oldest) with
                per-cause drop ledgers feeding the n_dropped accounting
  runtime     — the async execution layer: ``StreamRuntime`` runs a
                producer thread + double-buffered staging + sync-free pane
                dispatch over a session, with event-driven sampling, load
                shedding, drain-then-snapshot checkpoints, and
                ``RuntimeStats`` latency/overlap observability

Typical use::

    table = make_table(*SHENZHEN_BBOX, precision=6)
    pipe = EdgeCloudPipeline(table)
    q = Query(
        aggs=(AggSpec("mean", "value"), AggSpec("max", "value"),
              AggSpec("count", "value")),
        group_by="neighborhood",
    )
    result = pipe.execute(q, jax.random.key(0), window, fraction=0.8)
    result.estimates["mean_value"].value  # (num_neighborhoods,) with MoE

The legacy ``pipe.process_window(...)`` single-estimate API remains as a
shim over the canonical ``SUM/MEAN(value)`` query.
"""

from . import bounds, checkpoint, estimators, feedback, geohash, qdisc, query, routing, runtime, sampling, session, stratify, windows
from .estimators import (
    Accumulator,
    ColumnStats,
    Estimate,
    Extrema,
    QuantileSketch,
    StratumStats,
    accumulate_column,
    accumulator,
    column_stats,
    estimate,
    guarded_s2,
    merge_accs,
    merge_accs_panes,
    merge_column_stats,
    merge_column_stats_panes,
    merge_stats,
    psum_accs,
    psum_column_stats,
    psum_stats,
    register_accumulator,
    sample_stats,
    sketch_quantile,
)
from .feedback import SLO, ControllerState, EventPolicy, StackedSLO
from .pipeline import EdgeCloudPipeline, PipelineConfig, WindowResult, edge_sample
from .query import AggEstimate, AggSpec, FusedPlan, Plan, Query, QueryResult, fuse, fusion_key, lower
from .qdisc import BoundedPaneQueue
from .routing import RoutePlan, balanced_plan, contiguous_plan
from .runtime import RuntimeConfig, RuntimeStats, Source, StreamRuntime
from .sampling import SampleResult, compact, edgesos
from .session import Registration, SessionStep, StreamSession
from .stratify import CHICAGO_BBOX, SHENZHEN_BBOX, StratumTable, make_table, make_table_from_codes
from .windows import WindowBatch, WindowSpec, pane_windows

__all__ = [
    "Accumulator",
    "AggEstimate",
    "AggSpec",
    "BoundedPaneQueue",
    "CHICAGO_BBOX",
    "ColumnStats",
    "Extrema",
    "QuantileSketch",
    "ControllerState",
    "EdgeCloudPipeline",
    "Estimate",
    "EventPolicy",
    "FusedPlan",
    "PipelineConfig",
    "Plan",
    "Query",
    "QueryResult",
    "Registration",
    "RoutePlan",
    "RuntimeConfig",
    "RuntimeStats",
    "SHENZHEN_BBOX",
    "SLO",
    "SampleResult",
    "SessionStep",
    "Source",
    "StackedSLO",
    "StratumStats",
    "StratumTable",
    "StreamRuntime",
    "StreamSession",
    "WindowBatch",
    "WindowResult",
    "WindowSpec",
    "accumulate_column",
    "accumulator",
    "balanced_plan",
    "bounds",
    "checkpoint",
    "column_stats",
    "compact",
    "contiguous_plan",
    "edge_sample",
    "edgesos",
    "estimate",
    "estimators",
    "feedback",
    "fuse",
    "fusion_key",
    "geohash",
    "guarded_s2",
    "lower",
    "make_table",
    "make_table_from_codes",
    "merge_accs",
    "merge_accs_panes",
    "merge_column_stats",
    "merge_column_stats_panes",
    "merge_stats",
    "pane_windows",
    "psum_accs",
    "psum_column_stats",
    "psum_stats",
    "register_accumulator",
    "sketch_quantile",
    "qdisc",
    "query",
    "routing",
    "runtime",
    "sample_stats",
    "sampling",
    "session",
    "stratify",
    "windows",
]
