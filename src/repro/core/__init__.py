"""EdgeApproxGeo core: the paper's contribution as composable JAX modules.

Layers (bottom-up):
  geohash     — Morton-coded geohash encode/decode (pure integer JAX)
  stratify    — stratum tables (regular geohash grid + neighborhood map)
  sampling    — EdgeSOS decentralized stratified sampling (Algorithm 1)
  estimators  — stratified SUM/MEAN + variance/CI/MoE/RE (eqs 1-10)
  routing     — spatial-aware data distribution (topic-per-neighborhood)
  feedback    — QoS loop adapting the sampling fraction to SLOs
  windows     — tumbling count/time windows
  pipeline    — Algorithm 2: edge sample -> collective -> cloud estimate
"""

from . import estimators, feedback, geohash, routing, sampling, stratify, windows
from .estimators import Estimate, StratumStats, estimate, merge_stats, psum_stats, sample_stats
from .feedback import SLO, ControllerState
from .pipeline import EdgeCloudPipeline, PipelineConfig, WindowResult, edge_sample
from .routing import RoutePlan, balanced_plan, contiguous_plan
from .sampling import SampleResult, compact, edgesos
from .stratify import CHICAGO_BBOX, SHENZHEN_BBOX, StratumTable, make_table, make_table_from_codes

__all__ = [
    "CHICAGO_BBOX",
    "ControllerState",
    "EdgeCloudPipeline",
    "Estimate",
    "PipelineConfig",
    "RoutePlan",
    "SHENZHEN_BBOX",
    "SLO",
    "SampleResult",
    "StratumStats",
    "StratumTable",
    "WindowResult",
    "balanced_plan",
    "compact",
    "contiguous_plan",
    "edge_sample",
    "edgesos",
    "estimate",
    "estimators",
    "feedback",
    "geohash",
    "make_table",
    "make_table_from_codes",
    "merge_stats",
    "psum_stats",
    "routing",
    "sample_stats",
    "sampling",
    "stratify",
    "windows",
]
