"""Geohash encoding/decoding as pure JAX integer ops.

The paper stratifies geospatial streams by Geohash cell (precision 5/6).  A
classic string-geohash implementation is branchy and hash-map driven (the
paper's Rust edge binary uses FxHash lookups); on TPU we instead represent a
geohash as its raw Morton code (bit-interleaved quantized lat/lon), which is
a handful of VPU integer ops — no strings, no hashing, fully vectorizable.

Bit layout (standard geohash): ``5 * precision`` bits, alternating starting
with longitude at the MSB.  For odd total bit-width the longitude gets the
extra bit.

TPU adaptation: codes are uint32 (precision <= 6 -> 30 bits).  The TPU VPU
has no fast 64-bit integer path and the paper never goes beyond precision 6,
so 32-bit Morton codes are both sufficient and one-cycle-per-op.

String conversion (base32) is provided host-side (NumPy) for interop and
tests against reference geohash implementations.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

LAT_MIN, LAT_MAX = -90.0, 90.0
LON_MIN, LON_MAX = -180.0, 180.0

BASE32 = "0123456789bcdefghjkmnpqrstuvwxyz"
_BASE32_INV = {c: i for i, c in enumerate(BASE32)}

MAX_PRECISION = 6  # 30 bits; uint32 codes (TPU-native integer width)


def split_bits(precision: int) -> tuple[int, int]:
    """(lon_bits, lat_bits) for a geohash of ``precision`` characters."""
    total = 5 * precision
    return (total + 1) // 2, total // 2


def _u32(x: int) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def _part1by1(x: jnp.ndarray) -> jnp.ndarray:
    """Spread the low 16 bits of ``x`` to even bit positions (Morton)."""
    x = x.astype(jnp.uint32) & _u32(0x0000FFFF)
    x = (x | (x << 8)) & _u32(0x00FF00FF)
    x = (x | (x << 4)) & _u32(0x0F0F0F0F)
    x = (x | (x << 2)) & _u32(0x33333333)
    x = (x | (x << 1)) & _u32(0x55555555)
    return x


def _compact1by1(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`_part1by1` (gather even bit positions)."""
    x = x.astype(jnp.uint32) & _u32(0x55555555)
    x = (x | (x >> 1)) & _u32(0x33333333)
    x = (x | (x >> 2)) & _u32(0x0F0F0F0F)
    x = (x | (x >> 4)) & _u32(0x00FF00FF)
    x = (x | (x >> 8)) & _u32(0x0000FFFF)
    return x


def quantize(lat: jnp.ndarray, lon: jnp.ndarray, precision: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize lat/lon to their per-axis cell indices at ``precision``.

    Single-multiply form (precomputed f32 reciprocal scale) so the device
    kernel and this reference round identically; points within one f32 ulp
    of a cell edge may still land in the adjacent cell — harmless for
    stratification and tolerated by the tests.
    """
    lon_bits, lat_bits = split_bits(precision)
    lat_scale = np.float32((1 << lat_bits) / (LAT_MAX - LAT_MIN))
    lon_scale = np.float32((1 << lon_bits) / (LON_MAX - LON_MIN))
    lat_i = jnp.clip(((lat - LAT_MIN) * lat_scale).astype(jnp.int32), 0, (1 << lat_bits) - 1)
    lon_i = jnp.clip(((lon - LON_MIN) * lon_scale).astype(jnp.int32), 0, (1 << lon_bits) - 1)
    return lon_i.astype(jnp.uint32), lat_i.astype(jnp.uint32)


def interleave(lon_idx: jnp.ndarray, lat_idx: jnp.ndarray, precision: int) -> jnp.ndarray:
    """Morton-interleave per-axis cell indices into a geohash code."""
    total = 5 * precision
    if total % 2 == 0:
        # MSB (odd positions) = lon, even positions = lat.
        return (_part1by1(lon_idx) << _u32(1)) | _part1by1(lat_idx)
    # odd width: lon on even positions (incl. MSB), lat on odd.
    return _part1by1(lon_idx) | (_part1by1(lat_idx) << _u32(1))


def deinterleave(code: jnp.ndarray, precision: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inverse of :func:`interleave` -> (lon_idx, lat_idx)."""
    code = jnp.asarray(code).astype(jnp.uint32)
    total = 5 * precision
    if total % 2 == 0:
        lon = _compact1by1(code >> _u32(1))
        lat = _compact1by1(code)
    else:
        lon = _compact1by1(code)
        lat = _compact1by1(code >> _u32(1))
    return lon, lat


def encode(lat: jnp.ndarray, lon: jnp.ndarray, precision: int) -> jnp.ndarray:
    """Encode coordinates to uint32 geohash codes. Vectorized, jit-safe."""
    if not 1 <= precision <= MAX_PRECISION:
        raise ValueError(f"precision must be in [1, {MAX_PRECISION}], got {precision}")
    lon_i, lat_i = quantize(jnp.asarray(lat), jnp.asarray(lon), precision)
    return interleave(lon_i, lat_i, precision)


def decode(code: jnp.ndarray, precision: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Decode codes to (lat, lon) cell centers."""
    lon_bits, lat_bits = split_bits(precision)
    lon_i, lat_i = deinterleave(jnp.asarray(code), precision)
    lat = LAT_MIN + (lat_i.astype(jnp.float32) + 0.5) * ((LAT_MAX - LAT_MIN) / (1 << lat_bits))
    lon = LON_MIN + (lon_i.astype(jnp.float32) + 0.5) * ((LON_MAX - LON_MIN) / (1 << lon_bits))
    return lat, lon


def cell_size_deg(precision: int) -> tuple[float, float]:
    """(lat_extent, lon_extent) in degrees of one cell at ``precision``."""
    lon_bits, lat_bits = split_bits(precision)
    return (LAT_MAX - LAT_MIN) / (1 << lat_bits), (LON_MAX - LON_MIN) / (1 << lon_bits)


def parent(code: jnp.ndarray, precision: int, parent_precision: int) -> jnp.ndarray:
    """Truncate a geohash code to a coarser precision (prefix property).

    Geohash strings nest by prefix; in Morton space that is a right shift by
    ``5 * (precision - parent_precision)`` bits.  This is the O(1)
    'inverted hashmap' of the paper: neighborhood lookup as one shift.
    """
    if parent_precision > precision:
        raise ValueError("parent_precision must be <= precision")
    shift = _u32(5 * (precision - parent_precision))
    return jnp.asarray(code).astype(jnp.uint32) >> shift


# ---------------------------------------------------------------------------
# Host-side string interop (NumPy; not for the hot path).
# ---------------------------------------------------------------------------


def to_strings(codes, precision: int) -> list[str]:
    codes = np.asarray(codes, dtype=np.uint64)
    out = []
    for c in codes.reshape(-1):
        c = int(c)
        chars = []
        for i in range(precision):
            shift = 5 * (precision - 1 - i)
            chars.append(BASE32[(c >> shift) & 0x1F])
        out.append("".join(chars))
    return out


def from_strings(strings) -> np.ndarray:
    out = np.zeros(len(strings), dtype=np.uint64)
    for j, s in enumerate(strings):
        c = 0
        for ch in s:
            c = (c << 5) | _BASE32_INV[ch]
        out[j] = c
    return out


def encode_host(lat: float, lon: float, precision: int) -> str:
    """Reference host-side encoder (bisection, textbook algorithm)."""
    lat_lo, lat_hi = LAT_MIN, LAT_MAX
    lon_lo, lon_hi = LON_MIN, LON_MAX
    bits = []
    is_lon = True
    while len(bits) < 5 * precision:
        if is_lon:
            mid = (lon_lo + lon_hi) / 2
            if lon >= mid:
                bits.append(1)
                lon_lo = mid
            else:
                bits.append(0)
                lon_hi = mid
        else:
            mid = (lat_lo + lat_hi) / 2
            if lat >= mid:
                bits.append(1)
                lat_lo = mid
            else:
                bits.append(0)
                lat_hi = mid
        is_lon = not is_lon
    code = 0
    for b in bits:
        code = (code << 1) | b
    return to_strings(np.array([code], dtype=np.uint64), precision)[0]
