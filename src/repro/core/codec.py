"""Uplink wire-format codecs: sparse + quantized sufficient statistics.

The paper's bandwidth argument is that the edge ships *sufficient
statistics*, not tuples — yet the dense preagg payload still ships every
``(S+1)``-float row of every accumulator kind, including all
``SKETCH_NUM_BINS`` sketch bins per column per stratum, even when a pane
touched three strata out of thousands.  This module is the wire-format
layer between edge partial-aggregation and cloud consolidation: it
flattens the registry's ``{column: {kind: state}}`` pytrees into a
canonical row list (via each kind's ``payload_flatten`` hook), packs the
rows into buffers + a tiny header, and measures the bytes that would
actually cross the uplink — the *measured truth* the session and runtime
byte accounting now report, with :func:`~.query.preagg_bytes` demoted to
the analytic dense *model*.

Codecs (composable through :func:`resolve_codec` specs):

* :class:`SparseCodec` (``"sparse"``) — lossless.  Per row, a packed
  stratum-occupancy bitmap (an entry whose f32 *bit pattern* differs from
  the row's merge identity marks its stratum occupied) gates a
  gather-compaction of the occupied rows; wide sketch rows additionally
  compact their bin columns through a second bitmap.  Decode scatters
  back into identity-filled arrays — bit-exact, down to the sign of zero
  and NaN payloads (occupancy compares bits, not float equality, which
  would drop a stored ``-0.0`` as identity ``0.0``).
* :class:`TopKSketchCodec` (``"topk<k>"``) — lossy, totals-exact.  Sketch
  bin rows keep their top-k bins verbatim and spread the (integer)
  residual count uniformly over the remaining bins of the occupied
  ``[lo, hi]`` index range, so per-stratum totals are preserved *exactly*
  — Horvitz-Thompson expansion and quantile inversion stay sound, only
  within-range bin placement blurs.  Every non-sketch row rides the
  sparse path unchanged.
* :class:`QuantizeCodec` (``"quantize16"`` / ``"quantize8"``) — lossy,
  counts-exact.  Rows whose kind declared ``quantize_ok`` (value moments,
  extrema) quantize to int16/int8 against a per-row scale shipped on the
  wire; ``n`` / ``total`` / sketch-bin rows stay exact f32 — they drive
  fpc and every error bound.  The declared per-row error bound is
  ``scale / 2`` (round-to-nearest); ±inf/NaN ride dedicated sentinels.
* :class:`DeltaCodec` (``"delta"``) — lossless, stateful.  Cross-pane
  DPCM: each pane ships the XOR of its rows' f32 bit patterns against the
  previous pane's reconstruction, sparse-coded (unchanged strata XOR to
  zero and cost a bitmap bit).  XOR — not arithmetic ``cur - prev`` — is
  deliberate: the f32 difference of two f32 values is generally not
  representable in f32, so arithmetic DPCM could not honor the bit-exact
  contract; XOR residuals always invert exactly.  The inner coder's
  bitwise occupancy matters doubly here: an exact sign flip of a value
  XORs to the ``-0.0`` bit pattern, which a float occupancy test would
  silently drop, desynchronizing both ends of the stream.  A keyframe
  (plain sparse frame) opens every stream and follows any schema change
  (membership churn, restore).

Byte accounting: ``EncodedPayload.nbytes`` counts the packed buffers plus
a small per-row control word and frame preamble.  The row *schema*
(column/kind/name/shape/identity) is a static property of the registered
plan — negotiated once at registration like the stratum table itself —
and is not charged per pane.

Everything here is host-side numpy by design: encoded shapes are
data-dependent (that is the whole point), so this layer cannot live under
``jit`` — it is the serialization boundary where device states become
wire bytes, the one place in the pane loop where a device sync is the
semantics, not an accident.
"""

from __future__ import annotations

import re
from typing import NamedTuple

import numpy as np

import jax.numpy as jnp

from . import estimators

# accounting model: 8-byte frame preamble (codec id + frame kind + row
# count), one 4-byte control word per row (tag + buffer count)
_PREAMBLE_BYTES = 8
_ROW_CONTROL_BYTES = 4


class Row(NamedTuple):
    """One wire row of a flattened payload (see ``payload_flatten``)."""

    column: str
    kind: str
    name: str
    array: np.ndarray  # (S+1,) or (S+1, K) float32, stratum axis leading
    quantize_ok: bool
    identity: float


class SchemaRow(NamedTuple):
    """Static per-row metadata (negotiated at registration, not charged)."""

    column: str
    kind: str
    name: str
    shape: tuple
    quantize_ok: bool
    identity: float


class EncodedPayload(NamedTuple):
    """One pane's packed uplink frame: buffers + header.

    ``entries`` holds one ``(tag, meta, nbuf)`` control tuple per schema
    row; ``buffers`` is the flat buffer sequence the rows consume in
    order.  ``frame`` distinguishes delta frames from keyframes.
    """

    codec: str
    frame: str  # "raw" | "key" | "delta"
    schema: tuple  # tuple[SchemaRow, ...] — static, uncharged
    entries: tuple  # tuple[(tag, meta, nbuf), ...]
    buffers: tuple  # tuple[np.ndarray, ...]

    @property
    def nbytes(self) -> int:
        """Measured wire bytes of this frame (buffers + control words)."""
        return (
            _PREAMBLE_BYTES
            + _ROW_CONTROL_BYTES * len(self.entries)
            + sum(int(b.nbytes) for b in self.buffers)
        )


def flatten_stats(stats: dict) -> list[Row]:
    """Canonical wire rows of a ``{column: {kind: state}}`` registry tree
    (column/kind insertion order, each kind's ``payload_flatten`` order)."""
    rows: list[Row] = []
    for col, kinds in stats.items():
        for kind, state in kinds.items():
            acc = estimators.accumulator(kind)
            for name, arr, q_ok, ident in acc.payload_flatten(state):
                rows.append(
                    Row(
                        column=col,
                        kind=kind,
                        name=name,
                        array=np.asarray(arr, np.float32),
                        quantize_ok=bool(q_ok),
                        identity=float(ident),
                    )
                )
    return rows


def unflatten_stats(rows: list[Row]) -> dict:
    """Inverse of :func:`flatten_stats`: decoded rows back to the registry
    ``{column: {kind: state}}`` tree (each kind's ``payload_unflatten``)."""
    grouped: dict[tuple, dict] = {}
    for r in rows:
        grouped.setdefault((r.column, r.kind), {})[r.name] = jnp.asarray(r.array)
    stats: dict = {}
    for (col, kind), named in grouped.items():
        stats.setdefault(col, {})[kind] = estimators.accumulator(
            kind
        ).payload_unflatten(named)
    return stats


def roundtrip(codec: "UplinkCodec", stats: dict) -> tuple[dict, int]:
    """Ship a registry tree through ``codec`` and back: the uplink
    boundary.  Returns ``(decoded_stats, measured_wire_bytes)`` — the
    decoded tree is what the cloud tier consolidates (bit-identical to
    ``stats`` for lossless codecs), the byte count is the frame's
    :attr:`EncodedPayload.nbytes`."""
    payload = codec.encode(flatten_stats(stats))
    return unflatten_stats(codec.decode(payload)), payload.nbytes


def _bits(a) -> np.ndarray:
    """The f32 bit patterns of ``a`` (shape-preserving uint32 view)."""
    return np.ascontiguousarray(a, np.float32).view(np.uint32)


def _occupied(flat: np.ndarray, identity: float) -> np.ndarray:
    """Boolean occupancy along axis 0, compared on f32 *bit patterns*: an
    entry is occupied iff its bits differ from the identity's.  Bitwise —
    not float — equality is load-bearing three ways: NaN payloads register
    occupied, a ``-0.0`` entry differs from a ``+0.0`` identity (lossless
    codecs round-trip the sign of zero), and a delta frame's
    ``0x80000000`` XOR residual — an exact sign flip of the underlying
    value, e.g. ``wsum`` crossing ``x`` to ``-x`` or ``min`` going
    ``+inf`` to ``-inf`` — ships instead of being dropped as
    ``-0.0 == 0.0``, which would silently desynchronize the DPCM stream."""
    return np.any(_bits(flat) != _bits(np.float32(identity)), axis=1)


class UplinkCodec:
    """Protocol of one wire codec.  Stateless unless noted; a stateful
    codec (delta) returns a fresh instance from :meth:`for_stream` so
    every (fusion group, member) stream carries its own DPCM state."""

    name: str = "?"
    lossless: bool = True

    def fingerprint(self) -> str:
        """Stable config identity (checkpoint-validated across restarts)."""
        return self.name

    def for_stream(self) -> "UplinkCodec":
        """A codec instance for one independent uplink stream."""
        return self

    def reset(self) -> None:
        """Drop any cross-pane state (next frame is a keyframe)."""

    def encode(self, rows: list[Row]) -> EncodedPayload:
        raise NotImplementedError

    def decode(self, payload: EncodedPayload) -> list[Row]:
        raise NotImplementedError


class SparseCodec(UplinkCodec):
    """Empty-stratum / empty-bin skipping: bitmap + gather-compaction."""

    name = "sparse"
    lossless = True

    def encode(self, rows: list[Row]) -> EncodedPayload:
        schema = []
        entries = []
        buffers: list[np.ndarray] = []
        for row in rows:
            schema.append(
                SchemaRow(
                    row.column, row.kind, row.name, tuple(row.array.shape),
                    row.quantize_ok, row.identity,
                )
            )
            tag, meta, bufs = self._encode_row(row)
            entries.append((tag, meta, len(bufs)))
            buffers.extend(bufs)
        return EncodedPayload(
            codec=self.name,
            frame="raw",
            schema=tuple(schema),
            entries=tuple(entries),
            buffers=tuple(buffers),
        )

    def decode(self, payload: EncodedPayload) -> list[Row]:
        rows: list[Row] = []
        pos = 0
        for srow, (tag, meta, nbuf) in zip(payload.schema, payload.entries):
            bufs = payload.buffers[pos : pos + nbuf]
            pos += nbuf
            arr = self._decode_row(srow, tag, meta, iter(bufs))
            rows.append(
                Row(
                    column=srow.column, kind=srow.kind, name=srow.name,
                    array=arr, quantize_ok=srow.quantize_ok,
                    identity=srow.identity,
                )
            )
        return rows

    # -- per-row packing (subclass hook points) ------------------------------

    def _encode_row(self, row: Row):
        flat = row.array.reshape(row.array.shape[0], -1)
        occ = _occupied(flat, row.identity)
        if not occ.any():
            return "empty", None, []
        bufs = [np.packbits(occ)]
        sub = flat[occ]
        if sub.shape[1] > 1:
            colocc = _occupied(np.ascontiguousarray(sub.T), row.identity)
            bufs.append(np.packbits(colocc))
            sub = np.ascontiguousarray(sub[:, colocc])
            tag = "grid"
        else:
            tag = "vec"
        meta = self._encode_values(row, sub, bufs)
        return tag, meta, bufs

    def _decode_row(self, srow: SchemaRow, tag: str, meta, bufs) -> np.ndarray:
        shape = srow.shape
        width = int(np.prod(shape[1:], dtype=np.int64)) if len(shape) > 1 else 1
        flat = np.full((shape[0], width), np.float32(srow.identity), np.float32)
        if tag == "empty":
            return flat.reshape(shape)
        occ = np.unpackbits(next(bufs), count=shape[0]).astype(bool)
        n_occ = int(occ.sum())
        if tag == "grid":
            colocc = np.unpackbits(next(bufs), count=width).astype(bool)
            sub = self._decode_values(srow, meta, bufs, (n_occ, int(colocc.sum())))
            block = np.full((n_occ, width), np.float32(srow.identity), np.float32)
            block[:, colocc] = sub
            flat[occ] = block
        else:
            flat[occ] = self._decode_values(srow, meta, bufs, (n_occ, 1))
        return flat.reshape(shape)

    def _encode_values(self, row: Row, sub: np.ndarray, bufs: list):
        bufs.append(np.ascontiguousarray(sub, np.float32).reshape(-1))
        return None

    def _decode_values(self, srow: SchemaRow, meta, bufs, shape) -> np.ndarray:
        return np.asarray(next(bufs), np.float32).reshape(shape)


class TopKSketchCodec(SparseCodec):
    """Top-k + uniform residual spread for sketch bin rows (totals exact).

    Residuals distribute as *integers* (``base`` per bin, the remainder
    spread one-each from the range start): bin counts are integer-valued
    f32, so per-stratum totals — the masses HT expansion and quantile
    inversion read — survive the lossy pass with zero float drift.
    """

    lossless = False

    def __init__(self, k: int = 16):
        if k < 1:
            raise ValueError(f"topk codec needs k >= 1; got {k}")
        self.k = int(k)
        self.name = f"topk{self.k}"

    def _encode_row(self, row: Row):
        wide = row.array.ndim == 2 and row.array.shape[1] > 1
        if not (row.kind == "sketch" and row.name == "bins" and wide):
            return super()._encode_row(row)
        arr = row.array
        # float — not bitwise — occupancy on purpose: this path is lossy
        # and indexes bins via flatnonzero (which reads -0.0 as empty), so
        # a row of zero-mass bins must count as unoccupied here
        with np.errstate(invalid="ignore"):
            occ = np.any(arr != 0.0, axis=1)
        if not occ.any():
            return "empty", None, []
        ranges, idx_parts, val_parts, residuals = [], [], [], []
        for v in arr[occ]:
            nz = np.flatnonzero(v)
            lo, hi = int(nz[0]), int(nz[-1])
            k_use = min(self.k, len(nz))
            by_mass = nz[np.argsort(-v[nz], kind="stable")]
            top = np.sort(by_mass[:k_use])
            topv = v[top]
            residual = float(
                np.sum(v[nz], dtype=np.float64) - np.sum(topv, dtype=np.float64)
            )
            ranges.append((lo, hi, k_use))
            idx_parts.append(top.astype(np.int16))
            val_parts.append(topv.astype(np.float32))
            residuals.append(residual)
        bufs = [
            np.packbits(occ),
            np.asarray(ranges, np.uint16).reshape(-1),
            np.concatenate(idx_parts),
            np.concatenate(val_parts),
            np.asarray(residuals, np.float32),
        ]
        return "topk", None, bufs

    def _decode_row(self, srow: SchemaRow, tag: str, meta, bufs) -> np.ndarray:
        if tag != "topk":
            return super()._decode_row(srow, tag, meta, bufs)
        shape = srow.shape
        out = np.zeros(shape, np.float32)
        occ = np.unpackbits(next(bufs), count=shape[0]).astype(bool)
        n_occ = int(occ.sum())
        ranges = np.asarray(next(bufs), np.uint16).reshape(n_occ, 3)
        idx = np.asarray(next(bufs), np.int16)
        vals = np.asarray(next(bufs), np.float32)
        residuals = np.asarray(next(bufs), np.float32)
        rows = np.flatnonzero(occ)
        pos = 0
        for r, (lo, hi, k_use), residual in zip(rows, ranges, residuals):
            lo, hi, k_use = int(lo), int(hi), int(k_use)
            top = idx[pos : pos + k_use].astype(np.int64)
            out[r, top] = vals[pos : pos + k_use]
            pos += k_use
            rest = np.ones(hi - lo + 1, bool)
            rest[top - lo] = False
            rest_idx = lo + np.flatnonzero(rest)
            m = len(rest_idx)
            if m:
                base, rem = divmod(int(round(float(residual))), m)
                spread = np.full(m, base, np.float32)
                spread[:rem] += 1.0
                out[r, rest_idx] = spread
        return out


# quantization grids: symmetric integer range + dedicated sentinels for
# the non-finite lattice values extrema rows legitimately carry
_QUANT = {
    16: {"dtype": np.int16, "qmax": 32764, "pos_inf": 32767, "neg_inf": -32768, "nan": -32767},
    8: {"dtype": np.int8, "qmax": 124, "pos_inf": 127, "neg_inf": -128, "nan": -127},
}


class QuantizeCodec(SparseCodec):
    """Per-row scaled int16/int8 quantization of value rows; count rows
    (``quantize_ok=False``) ride the sparse f32 path exactly."""

    lossless = False

    def __init__(self, bits: int = 16):
        if bits not in _QUANT:
            raise ValueError(f"quantize codec supports bits in {sorted(_QUANT)}; got {bits}")
        self.bits = int(bits)
        self.name = f"quantize{self.bits}"

    def _encode_values(self, row: Row, sub: np.ndarray, bufs: list):
        if not row.quantize_ok:
            return super()._encode_values(row, sub, bufs)
        g = _QUANT[self.bits]
        finite = np.isfinite(sub)
        amax = float(np.max(np.abs(sub[finite]))) if finite.any() else 0.0
        # quantize against the exact f32 value the decoder will read off
        # the wire, or the declared half-step bound would not survive the
        # f64 -> f32 scale rounding; qmax sits below the dtype max with
        # enough headroom that the f32 rounding cannot push rint past it.
        # Floored at the smallest normal f32: a subnormal amax can
        # underflow amax/qmax to 0 in f32 (divide-by-zero, everything
        # clips to qmax and decodes to 0, the declared bound scale/2 = 0)
        # or leave it subnormal; the floor keeps the division normal,
        # rint(sub/scale) inside the clip range, and the half-step bound
        # intact — with scale = tiny, |sub| <= amax <= qmax*tiny
        tiny = float(np.finfo(np.float32).tiny)
        scale = max(float(np.float32(amax / g["qmax"])), tiny) if amax > 0 else 1.0
        with np.errstate(invalid="ignore"):
            q = np.clip(np.rint(sub / scale), -g["qmax"], g["qmax"])
        q = np.where(np.isnan(q), 0, q).astype(g["dtype"])
        q[sub == np.inf] = g["pos_inf"]
        q[sub == -np.inf] = g["neg_inf"]
        q[np.isnan(sub)] = g["nan"]
        bufs.append(np.ascontiguousarray(q).reshape(-1))
        # the per-row scale crosses the wire (one f32), so it is charged
        bufs.append(np.asarray([scale], np.float32))
        # declared reconstruction bound: round-to-nearest half-step
        return ("quant", self.bits, 0.5 * scale)

    def _decode_values(self, srow: SchemaRow, meta, bufs, shape) -> np.ndarray:
        if not (isinstance(meta, tuple) and meta and meta[0] == "quant"):
            return super()._decode_values(srow, meta, bufs, shape)
        g = _QUANT[self.bits]
        q = np.asarray(next(bufs), g["dtype"]).reshape(shape)
        scale = float(np.asarray(next(bufs), np.float32)[0])
        # f64 product, single f32 rounding at the end: reconstruction
        # error stays within the declared half-step plus one result ulp
        out = (q.astype(np.float64) * scale).astype(np.float32)
        out[q == g["pos_inf"]] = np.inf
        out[q == g["neg_inf"]] = -np.inf
        out[q == g["nan"]] = np.nan
        return out


class DeltaCodec(UplinkCodec):
    """Cross-pane XOR DPCM over a sparse inner coder (lossless, stateful).

    The encoder tracks the decoder's reconstruction (identical here: the
    inner path is lossless), so both ends advance in lockstep; the first
    frame of a stream — and the first after any schema change — is a
    keyframe.  Encode and decode keep *separate* previous-frame mirrors,
    so one instance can serve both ends of a loopback uplink without the
    encoder's state update corrupting the decoder's reference frame.
    """

    name = "delta:sparse"
    lossless = True

    def __init__(self):
        self._inner = SparseCodec()
        self._enc_prev: list[np.ndarray] | None = None
        self._dec_prev: list[np.ndarray] | None = None

    def for_stream(self) -> "DeltaCodec":
        return DeltaCodec()

    def reset(self) -> None:
        self._enc_prev = None
        self._dec_prev = None

    @staticmethod
    def _matches(prev: list[np.ndarray], rows: list[Row]) -> bool:
        return len(prev) == len(rows) and all(
            p.shape == r.array.shape for p, r in zip(prev, rows)
        )

    def encode(self, rows: list[Row]) -> EncodedPayload:
        cur = [np.ascontiguousarray(r.array, np.float32) for r in rows]
        prev = self._enc_prev
        if prev is None or not self._matches(prev, rows):
            payload = self._inner.encode(rows)._replace(codec=self.name, frame="key")
        else:
            xrows = [
                r._replace(
                    array=(_bits(c) ^ _bits(p)).view(np.float32),
                    quantize_ok=False,
                    identity=0.0,
                )
                for r, c, p in zip(rows, cur, prev)
            ]
            payload = self._inner.encode(xrows)._replace(
                codec=self.name, frame="delta"
            )
        self._enc_prev = cur
        return payload

    def decode(self, payload: EncodedPayload) -> list[Row]:
        rows = self._inner.decode(payload)
        if payload.frame == "delta":
            if self._dec_prev is None or not self._matches(self._dec_prev, rows):
                raise ValueError(
                    "delta frame received with no matching reference frame; "
                    "the stream must open (and reopen after any schema "
                    "change) with a keyframe"
                )
            rows = [
                r._replace(array=(_bits(r.array) ^ _bits(p)).view(np.float32))
                for r, p in zip(rows, self._dec_prev)
            ]
        self._dec_prev = [np.ascontiguousarray(r.array, np.float32) for r in rows]
        return rows


_SPEC_HELP = (
    "'sparse', 'topk<k>' (e.g. 'topk16'), 'quantize16', 'quantize8', "
    "'delta' (alias 'delta:sparse'), or an UplinkCodec instance"
)


def resolve_codec(spec) -> UplinkCodec | None:
    """Resolve a ``PipelineConfig.uplink_codec`` spec to a codec.

    ``None`` keeps the dense analytic uplink (codec off).  String specs
    keep the frozen config hashable; see ``_SPEC_HELP`` for the grammar.
    """
    if spec is None:
        return None
    if isinstance(spec, UplinkCodec):
        return spec
    if isinstance(spec, str):
        s = spec.strip().lower()
        if s == "sparse":
            return SparseCodec()
        if s in ("delta", "delta:sparse"):
            return DeltaCodec()
        m = re.fullmatch(r"topk(\d+)", s)
        if m:
            return TopKSketchCodec(int(m.group(1)))
        m = re.fullmatch(r"quantize(8|16)", s)
        if m:
            return QuantizeCodec(int(m.group(1)))
    raise ValueError(f"unknown uplink codec spec {spec!r}; expected {_SPEC_HELP}")
