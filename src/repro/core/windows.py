"""Window semantics for the streaming pipeline (paper §3.4, §5.2.4).

The paper processes continuous queries over *tumbling* windows and observes
(design implication #2) that count-triggered windows keep per-batch compute
constant under bursty traffic.  Both triggers are provided; windows are
host-side iterators yielding fixed-shape arrays (count windows) or padded
arrays with a validity mask (time windows), so every device step is a single
compiled program.

Sliding and hopping windows are *pane-based* (the classic panes / stream
"slicing" decomposition): the stream is cut into stride-sized sub-windows
("panes"), each pane is reduced once to mergeable per-stratum accumulators,
and a window's answer is the merge of its panes — no tuple is ever touched
twice.  :class:`WindowSpec` declares the shape of a registered continuous
query's window in pane units; the pane *content* is whatever the tumbling
iterators below yield (see :func:`pane_windows`), and the merge lives in
``session.StreamSession`` / ``estimators.merge_column_stats_panes``.

Windows carry *multiple named value columns* for the query layer: stream
chunks may include any number of extra numeric keys beyond the canonical
``sensor_id/timestamp/lat/lon/value`` (e.g. mobility speed + occupancy, air
quality PM2.5 + temperature).  Extra keys ride in ``WindowBatch.extra`` and
are addressable from ``Query`` aggregates via ``WindowBatch.columns``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

CANONICAL_KEYS = ("sensor_id", "timestamp", "lat", "lon", "value")

WINDOW_KINDS = ("tumbling", "sliding", "hopping")


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Pane-based window shape of a registered continuous query.

    ``size`` and ``stride`` are measured in *panes* — the unit batches a
    :class:`~.session.StreamSession` consumes (one ``WindowBatch`` per
    ``step``).  A query's window covers the last ``size`` panes and a result
    is emitted every ``stride`` panes:

      tumbling  stride == size (consecutive disjoint windows; the default,
                ``WindowSpec()`` is the classic one-pane tumbling window)
      sliding   stride == 1 (a result after every pane, windows overlap)
      hopping   1 <= stride <= size (general overlapping hop)

    ``stride`` may be omitted: it defaults to ``size`` for tumbling and to
    ``1`` for sliding; hopping requires it explicitly.
    """

    kind: str = "tumbling"
    size: int = 1
    stride: int | None = None

    def __post_init__(self):
        if self.kind not in WINDOW_KINDS:
            raise ValueError(f"window kind must be one of {WINDOW_KINDS}; got {self.kind!r}")
        if int(self.size) < 1:
            raise ValueError(f"window size must be >= 1 pane; got {self.size}")
        object.__setattr__(self, "size", int(self.size))
        stride = self.stride
        if stride is None:
            if self.kind == "hopping":
                raise ValueError("hopping WindowSpec requires an explicit stride")
            stride = self.size if self.kind == "tumbling" else 1
        stride = int(stride)
        if self.kind == "tumbling" and stride != self.size:
            raise ValueError(f"tumbling windows need stride == size; got {stride} != {self.size}")
        if self.kind == "sliding" and stride != 1:
            raise ValueError(f"sliding windows need stride == 1; got {stride}")
        if not 1 <= stride <= self.size:
            raise ValueError(
                f"stride must be in [1, size={self.size}] (stride > size would skip panes); got {stride}"
            )
        object.__setattr__(self, "stride", stride)


@dataclasses.dataclass(frozen=True)
class WindowBatch:
    """One window (or pane) of tuples, fixed shape (N,) + validity mask.

    ``n_dropped`` counts tuples that arrived for this window but were shed
    before it reached the device; ``drop_causes`` breaks that count down by
    *why* (cause -> tuples).  Producers tag their own cause:

      ``late``        bounded-buffer capacity overflow in :func:`time_windows`
      ``queue_full``  ingest-queue backpressure (:mod:`.qdisc` policies)
      ``shed``        load-shedding decimation under queue saturation

    Count-triggered windows report an explicit ``n_dropped=0`` / empty
    ``drop_causes`` (never "missing"), so downstream accounting can always
    sum across sources and causes.
    """

    sensor_id: np.ndarray
    timestamp: np.ndarray
    lat: np.ndarray
    lon: np.ndarray
    value: np.ndarray
    valid: np.ndarray
    extra: dict = dataclasses.field(default_factory=dict)
    n_dropped: int = 0
    drop_causes: dict = dataclasses.field(default_factory=dict)

    @property
    def size(self) -> int:
        return int(self.valid.sum())

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def columns(self) -> dict:
        """All named value columns: the primary ``value`` plus extras."""
        return {"value": self.value, **self.extra}


def _pad(arr: np.ndarray, capacity: int) -> np.ndarray:
    out = np.zeros((capacity,) + arr.shape[1:], dtype=arr.dtype)
    out[: len(arr)] = arr
    return out


def _make_batch(
    cat: dict,
    valid: np.ndarray,
    pad_to: int | None = None,
    n_dropped: int = 0,
    cause: str = "late",
) -> WindowBatch:
    def col(k):
        a = cat[k]
        return _pad(a, pad_to) if pad_to is not None else a

    extra = {k: col(k) for k in cat if k not in CANONICAL_KEYS}
    return WindowBatch(
        sensor_id=col("sensor_id"),
        timestamp=col("timestamp"),
        lat=col("lat"),
        lon=col("lon"),
        value=col("value"),
        valid=valid,
        extra=extra,
        n_dropped=n_dropped,
        drop_causes={cause: n_dropped} if n_dropped else {},
    )


def _check_keys(buf: dict, chunk: dict) -> None:
    """Every chunk must carry the same column set as the first one; a drift
    would otherwise silently drop (new key) or crash on (missing key) data."""
    if buf.keys() != chunk.keys():
        raise ValueError(
            f"stream chunk keys {sorted(chunk)} differ from the first "
            f"chunk's {sorted(buf)}; columns must be consistent across chunks"
        )


def count_windows(stream: Iterator[dict], window_size: int) -> Iterator[WindowBatch]:
    """Count-triggered tumbling windows: exactly ``window_size`` tuples each.

    ``stream`` yields dict chunks with keys sensor_id/timestamp/lat/lon/value
    plus any number of extra value columns (carried into ``extra``); the key
    set must be identical across chunks.
    """
    buf: dict[str, list[np.ndarray]] | None = None
    have = 0
    for chunk in stream:
        if buf is None:
            buf = {k: [] for k in chunk}
        _check_keys(buf, chunk)
        n = len(chunk["lat"])
        for k in buf:
            buf[k].append(np.asarray(chunk[k]))
        have += n
        while have >= window_size:
            cat = {k: np.concatenate(v) for k, v in buf.items()}
            head = {k: v[:window_size] for k, v in cat.items()}
            rest = {k: v[window_size:] for k, v in cat.items()}
            for k in buf:
                buf[k] = [rest[k]]
            have -= window_size
            # count windows never shed: report an explicit zero (not a
            # missing field) so drop accounting sums cleanly across sources
            yield _make_batch(head, np.ones(window_size, dtype=bool), n_dropped=0)


def time_windows(
    stream: Iterator[dict], window_seconds: float, capacity: int
) -> Iterator[WindowBatch]:
    """Time-triggered tumbling windows padded to a static ``capacity``.

    Tuples beyond capacity are dropped (bounded-buffer semantics, like the
    paper's Kafka producer under burst) and counted: each emitted batch's
    ``n_dropped`` is the number its window shed, so downstream diagnostics
    (e.g. ``StreamSession`` step reports) can account for the loss.
    """
    buf: dict[str, list] | None = None
    t_edge: float | None = None
    for chunk in stream:
        if buf is None:
            buf = {k: [] for k in chunk}
        _check_keys(buf, chunk)
        ts = np.asarray(chunk["timestamp"], dtype=np.float64)
        if t_edge is None and len(ts):
            t_edge = float(ts[0]) + window_seconds
        lo = 0
        while t_edge is not None and len(ts) and ts[-1] >= t_edge:
            cut = int(np.searchsorted(ts, t_edge, side="left"))
            for k in buf:
                buf[k].append(np.asarray(chunk[k])[lo:cut])
            cat = {k: np.concatenate(v) if v else np.zeros(0) for k, v in buf.items()}
            size = min(len(cat["lat"]), capacity)
            head = {k: v[:size] for k, v in cat.items()}
            yield _make_batch(
                head, np.arange(capacity) < size, pad_to=capacity,
                n_dropped=len(cat["lat"]) - size,
            )
            for k in buf:
                buf[k] = []
            lo = cut
            t_edge += window_seconds
        for k in buf:
            arr = np.asarray(chunk[k])[lo:]
            if len(arr):
                buf[k].append(arr)
    if buf is not None and any(len(v) for v in buf.values()):
        cat = {k: (np.concatenate(v) if v else np.zeros(0)) for k, v in buf.items()}
        size = min(len(cat["lat"]), capacity)
        if size:
            head = {k: v[:size] for k, v in cat.items()}
            yield _make_batch(
                head, np.arange(capacity) < size, pad_to=capacity,
                n_dropped=len(cat["lat"]) - size,
            )


def pane_windows(
    stream: Iterator[dict],
    pane_tuples: int | None = None,
    pane_seconds: float | None = None,
    capacity: int | None = None,
) -> Iterator[WindowBatch]:
    """Cut a stream into panes — the arrival unit of a ``StreamSession``.

    A pane is just a tumbling window of one *stride* worth of data: pass
    either ``pane_tuples`` (count trigger, fixed-shape panes) or
    ``pane_seconds`` + ``capacity`` (time trigger, padded panes).  Feed the
    resulting iterator to ``StreamSession.run``; registered queries with
    sliding/hopping :class:`WindowSpec` assemble their windows by merging
    pane accumulators, never re-reading these tuples.
    """
    if (pane_tuples is None) == (pane_seconds is None):
        raise ValueError("pass exactly one of pane_tuples / pane_seconds")
    if pane_tuples is not None:
        return count_windows(stream, pane_tuples)
    if capacity is None:
        raise ValueError("time-triggered panes need a static capacity")
    return time_windows(stream, pane_seconds, capacity)
