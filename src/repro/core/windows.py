"""Window semantics for the streaming pipeline (paper §3.4, §5.2.4).

The paper processes continuous queries over *tumbling* windows and observes
(design implication #2) that count-triggered windows keep per-batch compute
constant under bursty traffic.  Both triggers are provided; windows are
host-side iterators yielding fixed-shape arrays (count windows) or padded
arrays with a validity mask (time windows), so every device step is a single
compiled program.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class WindowBatch:
    """One window of tuples, fixed shape (N,) + validity mask."""

    sensor_id: np.ndarray
    timestamp: np.ndarray
    lat: np.ndarray
    lon: np.ndarray
    value: np.ndarray
    valid: np.ndarray

    @property
    def size(self) -> int:
        return int(self.valid.sum())

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])


def _pad(arr: np.ndarray, capacity: int) -> np.ndarray:
    out = np.zeros((capacity,) + arr.shape[1:], dtype=arr.dtype)
    out[: len(arr)] = arr
    return out


def count_windows(stream: Iterator[dict], window_size: int) -> Iterator[WindowBatch]:
    """Count-triggered tumbling windows: exactly ``window_size`` tuples each.

    ``stream`` yields dict chunks with keys sensor_id/timestamp/lat/lon/value.
    """
    buf: dict[str, list[np.ndarray]] = {k: [] for k in ("sensor_id", "timestamp", "lat", "lon", "value")}
    have = 0
    for chunk in stream:
        n = len(chunk["lat"])
        for k in buf:
            buf[k].append(np.asarray(chunk[k]))
        have += n
        while have >= window_size:
            cat = {k: np.concatenate(v) for k, v in buf.items()}
            head = {k: v[:window_size] for k, v in cat.items()}
            rest = {k: v[window_size:] for k, v in cat.items()}
            for k in buf:
                buf[k] = [rest[k]]
            have -= window_size
            yield WindowBatch(
                sensor_id=head["sensor_id"],
                timestamp=head["timestamp"],
                lat=head["lat"],
                lon=head["lon"],
                value=head["value"],
                valid=np.ones(window_size, dtype=bool),
            )


def time_windows(
    stream: Iterator[dict], window_seconds: float, capacity: int
) -> Iterator[WindowBatch]:
    """Time-triggered tumbling windows padded to a static ``capacity``.

    Tuples beyond capacity are dropped with a warning count (bounded-buffer
    semantics, like the paper's Kafka producer under burst).
    """
    buf: dict[str, list] = {k: [] for k in ("sensor_id", "timestamp", "lat", "lon", "value")}
    t_edge: float | None = None
    for chunk in stream:
        ts = np.asarray(chunk["timestamp"], dtype=np.float64)
        if t_edge is None and len(ts):
            t_edge = float(ts[0]) + window_seconds
        lo = 0
        while t_edge is not None and len(ts) and ts[-1] >= t_edge:
            cut = int(np.searchsorted(ts, t_edge, side="left"))
            for k in buf:
                buf[k].append(np.asarray(chunk[k])[lo:cut] if k == "timestamp" else np.asarray(chunk[k])[lo:cut])
            cat = {k: np.concatenate(v) if v else np.zeros(0) for k, v in buf.items()}
            size = min(len(cat["lat"]), capacity)
            yield WindowBatch(
                sensor_id=_pad(cat["sensor_id"][:size], capacity),
                timestamp=_pad(cat["timestamp"][:size], capacity),
                lat=_pad(cat["lat"][:size], capacity),
                lon=_pad(cat["lon"][:size], capacity),
                value=_pad(cat["value"][:size], capacity),
                valid=np.arange(capacity) < size,
            )
            for k in buf:
                buf[k] = []
            lo = cut
            t_edge += window_seconds
        for k in buf:
            arr = np.asarray(chunk[k])[lo:]
            if len(arr):
                buf[k].append(arr)
    if any(len(v) for v in buf.values()):
        cat = {k: (np.concatenate(v) if v else np.zeros(0)) for k, v in buf.items()}
        size = min(len(cat["lat"]), capacity)
        if size:
            yield WindowBatch(
                sensor_id=_pad(cat["sensor_id"][:size], capacity),
                timestamp=_pad(cat["timestamp"][:size], capacity),
                lat=_pad(cat["lat"][:size], capacity),
                lon=_pad(cat["lon"][:size], capacity),
                value=_pad(cat["value"][:size], capacity),
                valid=np.arange(capacity) < size,
            )
