"""Stratified estimators with rigorous error bounds (paper §3.5–3.6).

Implements equations (1)–(10): per-stratum sample statistics, the
stratified SUM/MEAN estimators, the variance of those estimators with
finite-population correction, and normal-approximation confidence
intervals / margin of error / relative error.

This module also hosts the **accumulator registry** — the pluggable layer
the query engine reduces windows into.  An :class:`Accumulator` is a named
kind of mergeable per-stratum summary (``accumulate / merge / merge_panes /
psum / zero_overflow / interval`` — the last derives sampling-error CIs
from the merged state, see :mod:`.bounds`); the built-in citizens are

  * ``moments``  — the eq 4 sample moments (:class:`StratumStats`), exact
    Chan-et-al. merges; backs sum/mean/count/var,
  * ``extrema``  — per-stratum min/max lattices; backs min/max,
  * ``sketch``   — a mergeable fixed-size log-domain quantile histogram
    (DDSketch-style); backs the ``p50``/``p99`` quantile aggregates.

Each column a query references carries a *dict of accumulator states*
(``{"moments": ..., "extrema": ...}``) chosen by plan lowering; the dict is
a plain pytree, so it jits, shard_maps, stacks into pane rings, and crosses
collectives untouched.  New aggregate families plug in by registering an
accumulator kind — no pipeline/session/collective code changes.

Two aggregation modes mirror the paper's two edge->cloud transmission modes:

  * raw mode — the "cloud" groups raw sampled tuples by stratum and applies
    the formulas on the full (masked) arrays;
  * pre-aggregated mode — each edge shard reduces its window to per-stratum
    moments ``(n_k, sum_k, M2_k)`` and only those are combined across shards
    (``psum`` over the data axes).  This is the bandwidth-saving mode; the
    combination rule is exact (parallel-variance / Chan et al. decomposition),
    so both modes return identical estimates — a property we test.

Numerics: per-stratum second moments are computed *centered* (two-pass)
inside a shard, and the cross-shard merge uses the mean-shift decomposition,
avoiding the catastrophic cancellation of naive sum-of-squares in f32.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.scipy.special import ndtri


class StratumStats(NamedTuple):
    """Mergeable per-stratum sample moments; shapes all (S+1,).

    n: realized sample size n_k (float for psum-friendliness)
    total: population size N_k of the window(s)
    wsum:  Σ y over sampled tuples of stratum k
    m2:    Σ (y - ȳ_k)^2 over sampled tuples (centered second moment)
    mean:  ȳ_k (carried so merges can re-center without re-reading data)
    """

    n: jnp.ndarray
    total: jnp.ndarray
    wsum: jnp.ndarray
    m2: jnp.ndarray
    mean: jnp.ndarray


class ColumnStats(NamedTuple):
    """Generalized mergeable per-stratum accumulator for one value column.

    Extends :class:`StratumStats` (whose five moments cover sum/mean/count/var
    via the Chan-et-al. parallel merge) with per-stratum sample extrema so
    ``min``/``max`` aggregates also merge *exactly* across shards.  Empty
    strata carry ``+inf``/``-inf`` sentinels, the identities of min/max, so
    every field is a segment-reduction with an exact associative combine:
    additive (n/total/wsum), mean-shift (m2), or lattice (min/max).

    This is the edge-side payload of the query layer's pre-aggregated
    transmission mode: one ColumnStats per referenced column per shard.
    """

    n: jnp.ndarray
    total: jnp.ndarray
    wsum: jnp.ndarray
    m2: jnp.ndarray
    mean: jnp.ndarray
    min: jnp.ndarray
    max: jnp.ndarray

    @property
    def base(self) -> "StratumStats":
        """The moment-only view (drop extrema) for the eq 5-10 estimators."""
        return StratumStats(n=self.n, total=self.total, wsum=self.wsum, m2=self.m2, mean=self.mean)


class Estimate(NamedTuple):
    """Global stratified estimate with uncertainty (eqs 5–10)."""

    sum: jnp.ndarray
    mean: jnp.ndarray
    var_sum: jnp.ndarray
    var_mean: jnp.ndarray
    moe: jnp.ndarray
    relative_error: jnp.ndarray
    ci_low: jnp.ndarray
    ci_high: jnp.ndarray
    n_total: jnp.ndarray
    population: jnp.ndarray


def sample_stats(
    values: jnp.ndarray,
    stratum_idx: jnp.ndarray,
    mask: jnp.ndarray,
    num_slots: int,
    counts: jnp.ndarray | None = None,
) -> StratumStats:
    """Per-stratum moments of the *sampled* tuples (eq 4), two-pass centered.

    ``counts`` are the population sizes N_k; when None they are recomputed
    from ``stratum_idx`` (all tuples of the window, sampled or not).
    """
    values = values.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    if counts is None:
        counts = jax.ops.segment_sum(
            jnp.ones_like(stratum_idx, dtype=jnp.int32), stratum_idx, num_segments=num_slots
        )
    n = jax.ops.segment_sum(m, stratum_idx, num_segments=num_slots)
    wsum = jax.ops.segment_sum(m * values, stratum_idx, num_segments=num_slots)
    mean = jnp.where(n > 0, wsum / jnp.maximum(n, 1.0), 0.0)
    centered = values - mean[stratum_idx]
    m2 = jax.ops.segment_sum(m * centered * centered, stratum_idx, num_segments=num_slots)
    return StratumStats(n=n, total=counts.astype(jnp.float32), wsum=wsum, m2=m2, mean=mean)


def merge_stats(a: StratumStats, b: StratumStats) -> StratumStats:
    """Exact pairwise merge (Chan et al. parallel-variance update)."""
    n = a.n + b.n
    total = a.total + b.total
    wsum = a.wsum + b.wsum
    mean = jnp.where(n > 0, wsum / jnp.maximum(n, 1.0), 0.0)
    delta = b.mean - a.mean
    m2 = a.m2 + b.m2 + delta * delta * jnp.where(n > 0, a.n * b.n / jnp.maximum(n, 1.0), 0.0)
    return StratumStats(n=n, total=total, wsum=wsum, m2=m2, mean=mean)


def merge_all(stats: Sequence[StratumStats]) -> StratumStats:
    out = stats[0]
    for s in stats[1:]:
        out = merge_stats(out, s)
    return out


def psum_stats(stats: StratumStats, axis_names) -> StratumStats:
    """Cross-shard combine with a single additive collective.

    Uses the mean-shift decomposition
        M2_g = Σ_s M2_s + Σ_s n_s ȳ_s² − n_g ȳ_g²
    so one ``psum`` of 4 (S+1)-vectors suffices — this is the paper's
    "pre-aggregated statistics transmission" mapped onto the interconnect:
    collective bytes are O(S), independent of window size.
    """
    n = jax.lax.psum(stats.n, axis_names)
    total = jax.lax.psum(stats.total, axis_names)
    wsum = jax.lax.psum(stats.wsum, axis_names)
    raw2 = jax.lax.psum(stats.m2 + stats.n * stats.mean * stats.mean, axis_names)
    mean = jnp.where(n > 0, wsum / jnp.maximum(n, 1.0), 0.0)
    m2 = jnp.maximum(raw2 - n * mean * mean, 0.0)
    return StratumStats(n=n, total=total, wsum=wsum, m2=m2, mean=mean)


def merge_stats_panes(stacked: StratumStats) -> StratumStats:
    """Vectorized multi-way moment merge over a leading pane axis.

    Input fields are (P, S+1): P pane accumulators of the same stratum
    table.  One mean-shift pass merges all panes at once —
        M2 = Σ_p (M2_p + n_p ȳ_p²) − n ȳ²
    (the :func:`psum_stats` decomposition applied on a local axis) — instead
    of P−1 sequential :func:`merge_stats` folds.
    """
    n = jnp.sum(stacked.n, axis=0)
    total = jnp.sum(stacked.total, axis=0)
    wsum = jnp.sum(stacked.wsum, axis=0)
    raw2 = jnp.sum(stacked.m2 + stacked.n * stacked.mean * stacked.mean, axis=0)
    mean = jnp.where(n > 0, wsum / jnp.maximum(n, 1.0), 0.0)
    m2 = jnp.maximum(raw2 - n * mean * mean, 0.0)
    return StratumStats(n=n, total=total, wsum=wsum, m2=m2, mean=mean)


def stats_from_raw_moments(
    count: jnp.ndarray, s1: jnp.ndarray, s2: jnp.ndarray, counts: jnp.ndarray
) -> StratumStats:
    """Raw per-stratum sums {n, Σy, Σy²} -> the centered StratumStats form.

    This is the adapter between the fused edge-reduce kernel (which emits
    raw power sums — the matmul-friendly form) and the mean-shift moment
    representation the estimators consume.  The centering ``m2 = Σy² − nȳ²``
    is the one fp32-cancellation step of the kernel path; the segment-ops
    backend centers two-pass and is the parity oracle (documented tolerance
    in the backend parity suite).
    """
    n = count.astype(jnp.float32)
    mean = jnp.where(n > 0, s1 / jnp.maximum(n, 1.0), 0.0)
    m2 = jnp.maximum(s2 - n * mean * mean, 0.0)
    return StratumStats(n=n, total=counts.astype(jnp.float32), wsum=s1, m2=m2, mean=mean)


def zero_overflow_stats(stats: StratumStats) -> StratumStats:
    """Neutralize the overflow slot (additive fields -> 0) so it drops out
    of estimation; the canonical implementation shared by pipeline shims
    and the query layer."""
    keep = jnp.arange(stats.n.shape[0]) < (stats.n.shape[0] - 1)

    def z(x):
        return jnp.where(keep, x, 0.0)

    return StratumStats(n=z(stats.n), total=z(stats.total), wsum=z(stats.wsum), m2=z(stats.m2), mean=z(stats.mean))


def column_stats(
    values: jnp.ndarray,
    stratum_idx: jnp.ndarray,
    mask: jnp.ndarray,
    num_slots: int,
    counts: jnp.ndarray | None = None,
    extrema: bool = True,
) -> ColumnStats:
    """Per-stratum generalized accumulator of the sampled tuples of one column.

    Moments come from :func:`sample_stats` (identical ops, so estimates built
    from ``.base`` match the legacy path bit-for-bit); extrema are masked
    segment min/max with ``±inf`` identities on empty strata.  Pass
    ``extrema=False`` when no aggregate reads min/max — the fields are then
    filled with their identities without running the segment reductions.
    """
    base = sample_stats(values, stratum_idx, mask, num_slots, counts=counts)
    ext = (
        EXTREMA.accumulate(values, stratum_idx, mask, num_slots)
        if extrema
        else EXTREMA.identity(num_slots)
    )
    return ColumnStats(
        n=base.n, total=base.total, wsum=base.wsum, m2=base.m2, mean=base.mean,
        min=ext.min, max=ext.max,
    )


def merge_column_stats(a: ColumnStats, b: ColumnStats) -> ColumnStats:
    """Exact pairwise merge: Chan et al. for moments, lattice for extrema."""
    base = merge_stats(a.base, b.base)
    return ColumnStats(
        n=base.n, total=base.total, wsum=base.wsum, m2=base.m2, mean=base.mean,
        min=jnp.minimum(a.min, b.min), max=jnp.maximum(a.max, b.max),
    )


def merge_all_columns(stats: Sequence[ColumnStats]) -> ColumnStats:
    out = stats[0]
    for s in stats[1:]:
        out = merge_column_stats(out, s)
    return out


def stack_column_stats(stats: Sequence[ColumnStats]) -> ColumnStats:
    """Stack accumulators along a new leading pane axis: (P, S+1) fields."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *stats)


def merge_column_stats_panes(stacked: ColumnStats) -> ColumnStats:
    """Vectorized multi-way merge over a leading pane axis.

    Input fields are (P, S+1): P pane accumulators of the same stratum
    table, merged in one mean-shift pass (:func:`merge_stats_panes`) instead
    of P−1 sequential :func:`merge_column_stats` folds.  This is the
    cloud-side pane merge of sliding/hopping windows: a window's answer is
    assembled from its panes' accumulators without re-touching raw tuples.
    """
    base = merge_stats_panes(stacked.base)
    return ColumnStats(
        n=base.n, total=base.total, wsum=base.wsum, m2=base.m2, mean=base.mean,
        min=jnp.min(stacked.min, axis=0), max=jnp.max(stacked.max, axis=0),
    )


def psum_column_stats(
    stats: ColumnStats, axis_names, shared: ColumnStats | None = None,
    extrema: bool = True,
) -> ColumnStats:
    """Cross-shard combine: psum of the moment vectors (mean-shift
    decomposition, see :func:`psum_stats`) plus a pmin/pmax pair for the
    extrema — O(S) collective bytes per column.

    Columns accumulated from the same sample share identical ``n``/``total``
    vectors; pass an already-combined column as ``shared`` to reuse them and
    skip their redundant psums (2 fewer collective vectors per extra column).
    ``extrema=False`` skips the pmin/pmax collectives for columns no min/max
    aggregate reads (the identity-filled fields pass through unchanged).
    """
    base = MOMENTS.psum(stats.base, axis_names, shared=shared.base if shared is not None else None)
    return ColumnStats(
        n=base.n, total=base.total, wsum=base.wsum, m2=base.m2, mean=base.mean,
        min=jax.lax.pmin(stats.min, axis_names) if extrema else stats.min,
        max=jax.lax.pmax(stats.max, axis_names) if extrema else stats.max,
    )


def z_value(confidence: float) -> jnp.ndarray:
    """Upper alpha/2 normal quantile, e.g. 1.96 for 95%."""
    alpha = 1.0 - confidence
    return ndtri(1.0 - alpha / 2.0).astype(jnp.float32)


def guarded_s2(
    n: jnp.ndarray,
    total: jnp.ndarray,
    m2: jnp.ndarray,
    grp: jnp.ndarray | None = None,
    num_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-stratum sample variance with the lonely-singleton guard.

    A stratum sampled at ``n_k == 1`` while under-sampled (``n_k < N_k``)
    has an *unidentified* variance; plugging in its recorded ``m2 == 0``
    silently reports zero sampling error (false certainty that collapses
    the SLO feedback loop).  Following the survey-statistics lonely-PSU
    "average" adjustment, such strata borrow the mean ``s²`` of the
    identified (``n_k >= 2``) strata of their group.  Returns
    ``(s2_eff, unidentified)`` where ``unidentified`` flags groups whose
    variance *no* stratum identifies — their half-width must be reported
    as infinite, which the feedback controller treats as "hold the
    fraction" (graceful degradation instead of a poisoned update).
    """
    s2 = jnp.where(n > 1, m2 / jnp.maximum(n - 1.0, 1.0), 0.0)
    active = (n > 0) & (total > 0)
    known = active & (n > 1)
    lonely = active & (n < 2) & (n < total)

    def reduce(x):
        if grp is None:
            return jnp.sum(x)
        return jax.ops.segment_sum(x, grp, num_segments=num_groups + 1)[:num_groups]

    cnt = reduce(known.astype(jnp.float32))
    s2_bar = reduce(jnp.where(known, s2, 0.0)) / jnp.maximum(cnt, 1.0)
    s2_bar_k = s2_bar if grp is None else s2_bar_at(s2_bar, grp)
    s2_eff = jnp.where(lonely, s2_bar_k, s2)
    unidentified = (reduce(lonely.astype(jnp.float32)) > 0) & (cnt == 0)
    return s2_eff, unidentified


def s2_bar_at(s2_bar_g: jnp.ndarray, grp: jnp.ndarray) -> jnp.ndarray:
    """Gather per-group imputed s² back to strata (overflow slot -> 0)."""
    padded = jnp.concatenate([s2_bar_g, jnp.zeros((1,), s2_bar_g.dtype)])
    return padded[jnp.clip(grp, 0, s2_bar_g.shape[0])]


def estimate(stats: StratumStats, confidence: float = 0.95) -> Estimate:
    """Equations (5)–(10) from merged per-stratum statistics.

    The MEAN is normalized by the *covered* population Σ_{k: n_k>0} N_k
    (a ratio estimator): strata whose allocation rounded to zero samples
    (tiny N_k at low fractions — the paper's "neighborhoods with too few
    data points" caveat) would otherwise bias the mean toward zero.  Under
    full coverage this equals the textbook eq 5 exactly.

    Under-sampled singleton strata (``n_k == 1 < N_k``) carry the
    :func:`guarded_s2` lonely-PSU adjustment: they borrow the average s²
    of identified strata instead of contributing false-zero variance; if
    *no* stratum identifies a variance the half-width is infinite.
    """
    n = stats.n
    N = stats.total
    active = (n > 0) & (N > 0)
    mean_k = stats.mean
    # s_k^2 (eq 4) with the singleton guard; full-population strata stay
    # exact via the fpc term regardless.
    s2_k, unidentified = guarded_s2(n, N, stats.m2)
    sum_hat = jnp.sum(jnp.where(active, N * mean_k, 0.0))  # eq 5
    population = jnp.sum(N)
    covered = jnp.sum(jnp.where(active, N, 0.0))
    mean_hat = sum_hat / jnp.maximum(covered, 1.0)  # eq 5 (ratio form)
    fpc = jnp.where(N > 0, 1.0 - n / jnp.maximum(N, 1.0), 0.0)
    var_sum = jnp.sum(jnp.where(active, N * N * fpc * s2_k / jnp.maximum(n, 1.0), 0.0))  # eq 6
    var_sum = jnp.where(unidentified, jnp.inf, var_sum)
    var_mean = var_sum / jnp.maximum(covered, 1.0) ** 2  # eq 7
    z = z_value(confidence)
    moe = z * jnp.sqrt(jnp.maximum(var_mean, 0.0))  # eq 9
    rel = jnp.where(jnp.abs(mean_hat) > 0, moe / jnp.maximum(jnp.abs(mean_hat), 1e-30), jnp.inf)  # eq 10
    return Estimate(
        sum=sum_hat,
        mean=mean_hat,
        var_sum=var_sum,
        var_mean=var_mean,
        moe=moe,
        relative_error=rel,
        ci_low=mean_hat - moe,
        ci_high=mean_hat + moe,
        n_total=jnp.sum(n),
        population=population,
    )


def substream_sums(stats_per_substream: Sequence[StratumStats]) -> jnp.ndarray:
    """Equations (1)–(2): per-substream estimated sums t̂_s and their total.

    Each element is one edge node's local stats; t̂_s = Σ_k N_{s,k} ȳ_{s,k}.
    Returns the vector of t̂_s (the global SUM is their sum, eq 2 — equal to
    ``estimate(merge_all(...)).sum`` when strata don't overlap; when they do,
    the weighted form of eq 3 is what merge_all computes).
    """
    return jnp.stack([jnp.sum(s.total * s.mean) for s in stats_per_substream])


def per_stratum_means(stats: StratumStats, confidence: float = 0.95):
    """Per-stratum mean and CI half-width (for heatmaps / per-cell queries).

    A stratum is its own group here, so no lonely-singleton imputation is
    possible: under-sampled strata with ``n_k < 2`` report an *infinite*
    half-width instead of the false-zero a singleton's ``m2 == 0`` would
    plug in (fully sampled strata stay exact: fpc == 0)."""
    s2_k = jnp.where(stats.n > 1, stats.m2 / jnp.maximum(stats.n - 1.0, 1.0), 0.0)
    fpc = jnp.where(stats.total > 0, 1.0 - stats.n / jnp.maximum(stats.total, 1.0), 0.0)
    var_k = fpc * s2_k / jnp.maximum(stats.n, 1.0)
    identified = (stats.n > 1) | ((stats.n > 0) & (stats.n >= stats.total))
    var_k = jnp.where(identified, var_k, jnp.inf)
    moe_k = z_value(confidence) * jnp.sqrt(jnp.maximum(var_k, 0.0))
    return stats.mean, moe_k


def weighted_estimate(
    values: jnp.ndarray, weight: jnp.ndarray, population: jnp.ndarray
) -> jnp.ndarray:
    """Horvitz-Thompson mean from (value, weight) pairs — one-liner used by
    the LM training integration (weights from SampleResult)."""
    return jnp.sum(values * weight) / jnp.maximum(population, 1.0)


# ---------------------------------------------------------------------------
# Accumulator registry: pluggable mergeable per-stratum summary kinds
# ---------------------------------------------------------------------------


class Extrema(NamedTuple):
    """Per-stratum sample extrema lattice; shapes (S+1,), ±inf identities."""

    min: jnp.ndarray
    max: jnp.ndarray


class QuantileSketch(NamedTuple):
    """Mergeable fixed-size per-stratum quantile histogram.

    ``bins`` is (S+1, SKETCH_NUM_BINS) f32: per-stratum counts of *sampled*
    tuples over a fixed log-domain bin layout (DDSketch-style, see
    :func:`sketch_bin_index`).  Because the layout is a global constant, the
    merge is plain addition — bins psum across shards, sum across panes, and
    compose associatively/commutatively by construction.  Counts are
    unweighted on the edge; finalize expands stratum k's row by the
    Horvitz-Thompson factor N_k/n_k (constant within a stratum for SRS,
    Bernoulli, and Neyman draws), which is exactly per-tuple HT weighting.
    """

    bins: jnp.ndarray


# Sketch bin layout (global constants — the mergeability precondition).
# Geometric bins over magnitude: relative accuracy alpha = tanh(LOG_GAMMA/2)
# ~ 4%, covering magnitudes MIN_MAG .. MIN_MAG*e^(B*LOG_GAMMA) (~8.9 decades:
# 1e-4 .. ~8e4); magnitudes outside clamp to the edge bins.  Layout, in
# ascending value order: B negative-magnitude bins (reversed), one zero bin,
# B positive-magnitude bins.
SKETCH_BINS_PER_SIDE = 256
SKETCH_LOG_GAMMA = 0.08
SKETCH_MIN_MAG = 1e-4
SKETCH_NUM_BINS = 2 * SKETCH_BINS_PER_SIDE + 1


def sketch_bin_index(values: jnp.ndarray) -> jnp.ndarray:
    """Value -> bin index in [0, SKETCH_NUM_BINS): the fixed log layout."""
    v = values.astype(jnp.float32)
    mag = jnp.abs(v)
    k = jnp.floor(jnp.log(jnp.maximum(mag, SKETCH_MIN_MAG) / SKETCH_MIN_MAG) / SKETCH_LOG_GAMMA)
    k = jnp.clip(k, 0, SKETCH_BINS_PER_SIDE - 1).astype(jnp.int32)
    zero = SKETCH_BINS_PER_SIDE  # index of the |v| <= MIN_MAG bin
    idx = jnp.where(v > SKETCH_MIN_MAG, zero + 1 + k, jnp.where(v < -SKETCH_MIN_MAG, zero - 1 - k, zero))
    return idx.astype(jnp.int32)


def sketch_bin_values() -> jnp.ndarray:
    """(SKETCH_NUM_BINS,) representative value per bin (geometric mid)."""
    k = jnp.arange(SKETCH_BINS_PER_SIDE, dtype=jnp.float32)
    rep = SKETCH_MIN_MAG * jnp.exp((k + 0.5) * SKETCH_LOG_GAMMA)
    return jnp.concatenate([-rep[::-1], jnp.zeros((1,), jnp.float32), rep])


def sketch_bin_edges() -> jnp.ndarray:
    """(SKETCH_NUM_BINS + 1,) ascending bin boundaries of the fixed layout.

    Bin ``i`` covers ``[edges[i], edges[i+1]]``; the zero bin spans
    ``[-MIN_MAG, MIN_MAG]`` and the outermost edges clamp the layout range.
    """
    k = jnp.arange(SKETCH_BINS_PER_SIDE + 1, dtype=jnp.float32)
    pos = SKETCH_MIN_MAG * jnp.exp(k * SKETCH_LOG_GAMMA)
    return jnp.concatenate([-pos[::-1], pos])


def sketch_quantile(weighted_bins: jnp.ndarray, q: float) -> jnp.ndarray:
    """Invert a (..., SKETCH_NUM_BINS) weighted histogram at quantile ``q``.

    Continuous inversion: finds the first bin whose cumulative mass reaches
    ``q`` of the total and interpolates linearly between that bin's edges by
    the within-bin mass fraction; NaN where the histogram is empty (an
    empty group has no ``p50`` — a confident-looking 0 there masquerades as
    data, and the query layer's ``n == 0`` guard turns the NaN into the
    standard no-evidence report of ``relative_error = inf``).  The
    continuity matters beyond accuracy — it is what lets the bootstrap in
    :mod:`.bounds` resolve sampling error *finer than one bin* (a
    representative-value inversion would quantize replicate quantiles to the
    bin grid and collapse narrow CIs to zero width).  Works batched over
    leading group/replicate dimensions.
    """
    total = jnp.sum(weighted_bins, axis=-1, keepdims=True)
    cdf = jnp.cumsum(weighted_bins, axis=-1)
    target = jnp.maximum(jnp.asarray(q, jnp.float32) * total, 1e-30)
    idx = jnp.argmax(cdf >= target, axis=-1)
    c_cur = jnp.take_along_axis(cdf, idx[..., None], axis=-1)[..., 0]
    c_prev = jnp.where(
        idx > 0,
        jnp.take_along_axis(cdf, jnp.maximum(idx - 1, 0)[..., None], axis=-1)[..., 0],
        0.0,
    )
    frac = jnp.clip(
        (target[..., 0] - c_prev) / jnp.maximum(c_cur - c_prev, 1e-30), 0.0, 1.0
    )
    edges = sketch_bin_edges()
    lo_e = edges[idx]
    hi_e = edges[idx + 1]
    val = lo_e + frac * (hi_e - lo_e)
    return jnp.where(total[..., 0] > 0, val, jnp.nan)


class Accumulator:
    """Protocol of one registry citizen: a named mergeable summary kind.

    State is any pytree of (S+1,)-leading arrays.  Laws the engine relies on
    (property-tested): ``merge`` is associative + commutative with
    ``accumulate`` on an empty window as identity; ``merge_panes`` equals a
    sequential merge fold; ``psum`` equals merging all shards' states;
    ``zero_overflow`` removes the out-of-region slot from estimation.
    """

    kind: str = "?"

    def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
        """Reduce one window's sampled tuples of a column to a state."""
        raise NotImplementedError

    def merge(self, a, b):
        """Exact pairwise combine of two states."""
        raise NotImplementedError

    def merge_panes(self, stacked):
        """Vectorized multi-way merge over a leading pane axis."""
        raise NotImplementedError

    def psum(self, state, axis_names, shared=None):
        """Cross-shard combine via collectives (``shared`` is an optional
        already-combined moments state for n/total reuse)."""
        raise NotImplementedError

    def zero_overflow(self, state):
        """Neutralize the overflow slot (merge identities there)."""
        raise NotImplementedError

    def payload_vectors(self) -> int:
        """(S+1)-float vectors this kind adds to one column's preagg uplink
        payload (excluding the n/total pair, shipped once per pass)."""
        raise NotImplementedError

    def payload_flatten(self, state):
        """The wire-format view of a state: ordered ``(name, array,
        quantize_ok, identity)`` rows, each array ``(S+1,)`` or
        ``(S+1, K)`` with the stratum axis leading.

        ``quantize_ok`` marks value rows a lossy codec may quantize;
        count/population rows must declare ``False`` — they drive fpc and
        error bounds and stay exact on the wire.  ``identity`` is the
        scalar a codec may skip (the row's merge identity: 0 for additive
        rows, ±inf for extrema lattices), so empty strata compress to a
        bitmap bit.  Contract: ``payload_unflatten`` over these rows must
        rebuild the state bit-exactly (see :mod:`.codec`)."""
        raise NotImplementedError

    def payload_unflatten(self, rows):
        """Rebuild a state from a ``{name: array}`` mapping of decoded
        :meth:`payload_flatten` rows.  Must be the bit-exact inverse on
        untouched rows; derived leaves (e.g. the moments ``mean``) are
        recomputed rather than shipped."""
        raise NotImplementedError

    def template(self):
        """Structure-only state (for shard_map out_specs trees)."""
        raise NotImplementedError

    def interval(
        self,
        state,
        agg_kind: str,
        moments: "StratumStats",
        *,
        q: float | None = None,
        confidence: float = 0.95,
        key=None,
        replicates: int = 0,
        grp=None,
        num_groups: int = 1,
        **aux,
    ):
        """Sampling-error CI ``(lo, hi)`` for aggregate ``agg_kind``
        finalized from this state, or ``None`` when the kind carries no
        bound logic (the engine falls back to a zero-width interval).

        ``moments`` is the column's merged moment state — the
        ``(n_k, N_k)`` expansion factors every bound needs (and the
        mean/s² rows the bootstrap resamples).  ``key`` seeds the
        bootstrap deterministically; ``replicates == 0`` disables
        resampling-based bounds.  ``aux`` carries kind-specific extras the
        engine forwards uniformly (e.g. ``sketch``/``center`` for the
        moments kind) — implementations must tolerate and ignore extras
        they don't use, so ``finalize`` can call any registered kind
        through one signature.  Registered kinds own their bound logic
        (see :mod:`.bounds`), and new kinds inherit the contract by
        overriding this hook.
        """
        return None


class MomentsAccumulator(Accumulator):
    """Eq 4 sample moments (:class:`StratumStats`), exact Chan merges."""

    kind = "moments"

    def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
        return sample_stats(values, stratum_idx, mask, num_slots, counts=counts)

    def from_kernel_rows(self, count, s1, s2, counts):
        """Optional kernel hook: adapt fused-kernel raw power-sum rows
        (kept count, Σy, Σy²; population ``counts``) to the state this
        accumulator's merges/finalize consume.  Not part of the registry
        protocol — only kinds a kernel emits rows for implement it."""
        return stats_from_raw_moments(count, s1, s2, counts)

    def merge(self, a, b):
        return merge_stats(a, b)

    def merge_panes(self, stacked):
        return merge_stats_panes(stacked)

    def psum(self, state, axis_names, shared=None):
        if shared is None:
            return psum_stats(state, axis_names)
        # columns accumulated from the same sample share n/total: reuse the
        # combined vectors and psum only this column's wsum/raw2 pair
        n, total = shared.n, shared.total
        wsum = jax.lax.psum(state.wsum, axis_names)
        raw2 = jax.lax.psum(state.m2 + state.n * state.mean * state.mean, axis_names)
        mean = jnp.where(n > 0, wsum / jnp.maximum(n, 1.0), 0.0)
        m2 = jnp.maximum(raw2 - n * mean * mean, 0.0)
        return StratumStats(n=n, total=total, wsum=wsum, m2=m2, mean=mean)

    def zero_overflow(self, state):
        return zero_overflow_stats(state)

    def payload_vectors(self) -> int:
        return 2  # wsum + raw second moment (mean/m2 derived cloud-side)

    def payload_flatten(self, state):
        # n/total are count rows (exact on the wire — fpc and every bound
        # reads them); wsum/m2 are the value moments.  m2 ships *directly*
        # rather than as the psum-style raw2 = m2 + n·mean²: recovering m2
        # from raw2 cancels catastrophically when n·mean² >> m2, so the
        # raw2 form could not honor the bit-exact unflatten contract.
        return (
            ("n", state.n, False, 0.0),
            ("total", state.total, False, 0.0),
            ("wsum", state.wsum, True, 0.0),
            ("m2", state.m2, True, 0.0),
        )

    def payload_unflatten(self, rows):
        n, total, wsum, m2 = rows["n"], rows["total"], rows["wsum"], rows["m2"]
        # mean is derived exactly as every producer derives it, so a
        # lossless round-trip reproduces it bitwise
        mean = jnp.where(n > 0, wsum / jnp.maximum(n, 1.0), 0.0)
        return StratumStats(n=n, total=total, wsum=wsum, m2=m2, mean=mean)

    def template(self):
        return StratumStats(*(0,) * 5)

    def interval(self, state, agg_kind, moments, *, q=None, confidence=0.95,
                 key=None, replicates=0, grp=None, num_groups=1, sketch=None,
                 center=None, **aux):
        """``var``: stratified parametric bootstrap over the moment rows
        (singleton-guarded s², see :func:`guarded_s2`).

        When the column *already ships* a quantile sketch (``sketch`` is
        its state and ``center`` the plug-in point estimate), two free
        sharpenings kick in with zero extra uplink: the sketch's
        per-stratum kurtosis widens the s² spread beyond normal theory,
        and a second, fully nonparametric CI is bootstrapped from the
        collapsed bin replicates — the reported interval is the
        conservative union of both channels.  Without a sketch the
        normal-theory moment bootstrap stands alone (documented to
        under-cover extremely heavy-tailed columns).
        """
        if agg_kind != "var" or key is None or replicates <= 0:
            return None
        from . import bounds  # deferred: bounds builds on this module

        s2_eff, unidentified = guarded_s2(
            state.n, state.total, state.m2, grp=grp, num_groups=num_groups
        )
        kurtosis = None
        if sketch is not None:
            kurtosis = bounds.sketch_kurtosis(sketch.bins, state.n)
        k_mom, k_sk = jax.random.split(key)
        lo, hi = bounds.var_interval(
            k_mom, state.n, state.total, state.mean, s2_eff, confidence,
            replicates, grp=grp, num_groups=num_groups, unidentified=unidentified,
            kurtosis=kurtosis,
        )
        if sketch is not None and center is not None:
            lo_s, hi_s = bounds.var_sketch_interval(
                k_sk, sketch.bins, state.n, state.total, confidence, replicates,
                center, grp=grp, num_groups=num_groups,
            )
            lo = jnp.minimum(lo, lo_s)
            hi = jnp.maximum(hi, hi_s)
        return lo, hi


class ExtremaAccumulator(Accumulator):
    """Per-stratum min/max lattices with ±inf identities."""

    kind = "extrema"

    def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
        v = values.astype(jnp.float32)
        return Extrema(
            min=jax.ops.segment_min(jnp.where(mask, v, jnp.inf), stratum_idx, num_segments=num_slots),
            max=jax.ops.segment_max(jnp.where(mask, v, -jnp.inf), stratum_idx, num_segments=num_slots),
        )

    def from_kernel_rows(self, mins, maxs) -> Extrema:
        """Optional kernel hook: wrap fused-kernel extrema rows (±inf
        identities where a stratum kept nothing)."""
        return Extrema(min=mins, max=maxs)

    def identity(self, num_slots: int) -> Extrema:
        return Extrema(
            min=jnp.full((num_slots,), jnp.inf, jnp.float32),
            max=jnp.full((num_slots,), -jnp.inf, jnp.float32),
        )

    def merge(self, a, b):
        return Extrema(min=jnp.minimum(a.min, b.min), max=jnp.maximum(a.max, b.max))

    def merge_panes(self, stacked):
        return Extrema(min=jnp.min(stacked.min, axis=0), max=jnp.max(stacked.max, axis=0))

    def psum(self, state, axis_names, shared=None):
        return Extrema(
            min=jax.lax.pmin(state.min, axis_names), max=jax.lax.pmax(state.max, axis_names)
        )

    def zero_overflow(self, state):
        keep = jnp.arange(state.min.shape[0]) < (state.min.shape[0] - 1)
        return Extrema(
            min=jnp.where(keep, state.min, jnp.inf), max=jnp.where(keep, state.max, -jnp.inf)
        )

    def payload_vectors(self) -> int:
        return 2  # min + max

    def payload_flatten(self, state):
        # identities are the lattice units: a stratum that kept nothing
        # holds (+inf, -inf) and costs one bitmap bit on the wire
        return (
            ("min", state.min, True, float("inf")),
            ("max", state.max, True, float("-inf")),
        )

    def payload_unflatten(self, rows):
        return Extrema(min=rows["min"], max=rows["max"])

    def template(self):
        return Extrema(*(0,) * 2)

    def interval(self, state, agg_kind, moments, *, q=None, confidence=0.95,
                 key=None, replicates=0, grp=None, num_groups=1, **aux):
        """``min``/``max``: closed-form order-statistic + Cantelli bounds
        from the rank slack of per-stratum sampling fractions (no
        resampling; deterministic)."""
        if agg_kind not in ("min", "max"):
            return None
        from . import bounds  # deferred: bounds builds on this module

        s2 = jnp.where(
            moments.n > 1, moments.m2 / jnp.maximum(moments.n - 1.0, 1.0), 0.0
        )
        ext = state.max if agg_kind == "max" else state.min
        return bounds.extrema_interval(
            agg_kind, ext, moments.n, moments.total, moments.mean, s2,
            confidence, grp=grp, num_groups=num_groups,
        )


class QuantileSketchAccumulator(Accumulator):
    """DDSketch-style mergeable log-histogram (see :class:`QuantileSketch`)."""

    kind = "sketch"

    def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
        b = sketch_bin_index(values)
        flat = stratum_idx.astype(jnp.int32) * SKETCH_NUM_BINS + b
        bins = jax.ops.segment_sum(
            mask.astype(jnp.float32), flat, num_segments=num_slots * SKETCH_NUM_BINS
        )
        return QuantileSketch(bins=bins.reshape(num_slots, SKETCH_NUM_BINS))

    def from_kernel_rows(self, bins) -> QuantileSketch:
        """Optional kernel hook: wrap fused-kernel (S, NUM_BINS) sketch
        rows — the binning already happened inside the kernel (the fused
        backend's single-traversal contract), so this is shape adoption,
        not re-binning."""
        return QuantileSketch(bins=bins)

    def merge(self, a, b):
        return QuantileSketch(bins=a.bins + b.bins)

    def merge_panes(self, stacked):
        return QuantileSketch(bins=jnp.sum(stacked.bins, axis=0))

    def psum(self, state, axis_names, shared=None):
        return QuantileSketch(bins=jax.lax.psum(state.bins, axis_names))

    def zero_overflow(self, state):
        keep = jnp.arange(state.bins.shape[0]) < (state.bins.shape[0] - 1)
        return QuantileSketch(bins=jnp.where(keep[:, None], state.bins, 0.0))

    def payload_vectors(self) -> int:
        return SKETCH_NUM_BINS

    def payload_flatten(self, state):
        # bin rows are integer-valued counts: HT expansion and quantile
        # inversion read them as masses, so they never quantize (top-k +
        # residual is the sanctioned lossy path — it preserves totals)
        return (("bins", state.bins, False, 0.0),)

    def payload_unflatten(self, rows):
        return QuantileSketch(bins=rows["bins"])

    def template(self):
        return QuantileSketch(bins=0)

    def interval(self, state, agg_kind, moments, *, q=None, confidence=0.95,
                 key=None, replicates=0, grp=None, num_groups=1, **aux):
        """``p<q>``: stratified multinomial bootstrap over the sketch bin
        rows (Poissonized + CLT-collapsed, see :mod:`.bounds`)."""
        if q is None or key is None or replicates <= 0:
            return None
        from . import bounds  # deferred: bounds builds on this module

        return bounds.quantile_interval(
            key, state.bins, moments.n, moments.total, q, confidence,
            replicates, grp=grp, num_groups=num_groups,
        )


ACCUMULATORS: dict[str, Accumulator] = {}


def register_accumulator(acc: Accumulator) -> Accumulator:
    """Add (or replace) a registry citizen; returns it for chaining."""
    ACCUMULATORS[acc.kind] = acc
    return acc


MOMENTS = register_accumulator(MomentsAccumulator())
EXTREMA = register_accumulator(ExtremaAccumulator())
SKETCH = register_accumulator(QuantileSketchAccumulator())


def accumulator(kind: str) -> Accumulator:
    acc = ACCUMULATORS.get(kind)
    if acc is None:
        raise KeyError(
            f"unknown accumulator kind {kind!r}; registered: {sorted(ACCUMULATORS)}"
        )
    return acc


# -- column-level operations over {kind: state} dicts ------------------------


def accumulate_column(
    kinds: Sequence[str],
    values: jnp.ndarray,
    stratum_idx: jnp.ndarray,
    mask: jnp.ndarray,
    num_slots: int,
    counts: jnp.ndarray | None = None,
) -> dict:
    """One column's registry states for the requested accumulator kinds."""
    return {
        k: accumulator(k).accumulate(values, stratum_idx, mask, num_slots, counts=counts)
        for k in kinds
    }


def merge_accs(a: dict, b: dict) -> dict:
    return {k: accumulator(k).merge(a[k], b[k]) for k in a}


def merge_accs_panes(stacked: dict) -> dict:
    """Vectorized pane merge of one column's stacked states (leading P axis)."""
    return {k: accumulator(k).merge_panes(s) for k, s in stacked.items()}


def psum_accs(accs: dict, axis_names, shared: StratumStats | None = None) -> dict:
    """Cross-shard combine of one column's states; pass an already-combined
    moments state as ``shared`` to skip the redundant n/total psums."""
    return {
        k: accumulator(k).psum(s, axis_names, shared=shared if k == "moments" else None)
        for k, s in accs.items()
    }


def zero_overflow_accs(accs: dict) -> dict:
    return {k: accumulator(k).zero_overflow(s) for k, s in accs.items()}


def accs_template(kinds: Sequence[str]) -> dict:
    return {k: accumulator(k).template() for k in kinds}
