"""QoS feedback loop: adapt the sampling fraction to SLOs (paper §3.4/3.6.4).

The paper's loop: if observed relative error (RE) exceeds the continuous
query's SLO, raise the sampling fraction for subsequent windows; a cost
function also maps a latency budget to a fraction ceiling.

We implement an *analytic* controller instead of a fixed-step heuristic.
Under proportional allocation, Var(MEAN) ≈ ((1-f)/f) * V / N where
V = Σ W_k s_k² is (approximately) fraction-independent.  Hence
RE² ∝ (1-f)/f, and the fraction that exactly meets a target RE_t from an
observation (f, RE) is

    (1-f')/f' = (RE_t / RE)² (1-f)/f   =>   f' = 1 / (1 + r·(1-f)/f)

with r = (RE_t/RE)².  An EMA on RE plus min/max clamps give stability; a
token-budget ceiling implements the latency half of the SLO (EdgeSOS cost is
dominated by window size, not kept fraction — paper §5.2.2 — so latency maps
to a ceiling on *downstream* volume f·N, not on sampling cost).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SLO:
    """Continuous-query service level objectives."""

    target_relative_error: float = 0.10
    max_downstream_tuples: int | None = None  # latency budget proxy
    min_fraction: float = 0.05
    max_fraction: float = 1.0
    ema: float = 0.5  # smoothing on observed RE
    deadband: float = 0.05  # relative deadband around the target


class ControllerState(NamedTuple):
    fraction: jnp.ndarray  # current sampling fraction (scalar f32)
    re_ema: jnp.ndarray  # smoothed observed relative error
    steps: jnp.ndarray  # windows processed


def init_state(fraction: float = 0.8) -> ControllerState:
    return ControllerState(
        fraction=jnp.float32(fraction),
        re_ema=jnp.float32(0.0),
        steps=jnp.int32(0),
    )


def update(
    state: ControllerState,
    observed_re: jnp.ndarray,
    window_size: jnp.ndarray,
    slo: SLO,
) -> ControllerState:
    """One controller step after a window's estimate is produced.

    ``observed_re`` is whatever error-bounded aggregate drives the query —
    the eq 10 RE for sum/mean or a bootstrap-CI RE for var/quantiles.
    Non-finite observations (inf from an unidentified-variance window, NaN
    from a degenerate upstream) are mapped to the target so they *hold*
    the fraction instead of poisoning the EMA."""
    re = jnp.where(
        jnp.isfinite(observed_re) & (observed_re >= 0),
        observed_re,
        slo.target_relative_error,
    )
    re_ema = jnp.where(
        state.steps == 0, re, slo.ema * re + (1.0 - slo.ema) * state.re_ema
    )
    f = state.fraction
    tgt = jnp.float32(slo.target_relative_error)
    r = jnp.square(tgt / jnp.maximum(re_ema, 1e-9))
    odds = (1.0 - f) / jnp.maximum(f, 1e-6)
    f_new = 1.0 / (1.0 + r * odds)
    # deadband: don't thrash when RE is already within ±deadband of target
    in_band = jnp.abs(re_ema - tgt) <= slo.deadband * tgt
    f_new = jnp.where(in_band, f, f_new)
    # latency budget: cap downstream volume f·N
    if slo.max_downstream_tuples is not None:
        f_cap = jnp.float32(slo.max_downstream_tuples) / jnp.maximum(
            window_size.astype(jnp.float32), 1.0
        )
        f_new = jnp.minimum(f_new, f_cap)
    f_new = jnp.clip(f_new, slo.min_fraction, slo.max_fraction)
    return ControllerState(fraction=f_new, re_ema=re_ema, steps=state.steps + 1)


class StackedSLO(NamedTuple):
    """Per-query SLO parameters stacked into (Q,) arrays for the vectorized
    controller of a ``StreamSession`` (``max_downstream_tuples=None`` maps
    to ``+inf`` so the cap term is a no-op elementwise)."""

    target: jnp.ndarray
    cap: jnp.ndarray
    min_fraction: jnp.ndarray
    max_fraction: jnp.ndarray
    ema: jnp.ndarray
    deadband: jnp.ndarray


def stack_slos(slos) -> StackedSLO:
    """Stack a sequence of :class:`SLO` into a :class:`StackedSLO`."""
    slos = list(slos)
    return StackedSLO(
        target=jnp.asarray([s.target_relative_error for s in slos], jnp.float32),
        cap=jnp.asarray(
            [jnp.inf if s.max_downstream_tuples is None else float(s.max_downstream_tuples) for s in slos],
            jnp.float32,
        ),
        min_fraction=jnp.asarray([s.min_fraction for s in slos], jnp.float32),
        max_fraction=jnp.asarray([s.max_fraction for s in slos], jnp.float32),
        ema=jnp.asarray([s.ema for s in slos], jnp.float32),
        deadband=jnp.asarray([s.deadband for s in slos], jnp.float32),
    )


def init_vector_state(fractions) -> ControllerState:
    """Vector controller state: one fraction per registered query."""
    f = jnp.asarray(fractions, jnp.float32)
    return ControllerState(
        fraction=f,
        re_ema=jnp.zeros_like(f),
        steps=jnp.zeros(f.shape, jnp.int32),
    )


def stack_states(entries) -> ControllerState:
    """Stack per-registration ``(fraction, re_ema, steps)`` host mirrors
    into one ``(Q,)`` :class:`ControllerState`.

    This is the serving-scale form of :func:`init_vector_state`: a
    ``StreamSession`` keeps float mirrors on each registration (external
    policies — event-driven sampling, checkpoint restore — write them
    directly) and stacks the whole tenant population into arrays right
    before the single :func:`update_vector` call per pane, so a thousand
    controllers cost three ``asarray`` builds and ~15 device ops total
    instead of O(Q) per-query dispatches.
    """
    entries = list(entries)
    return ControllerState(
        fraction=jnp.asarray([e[0] for e in entries], jnp.float32),
        re_ema=jnp.asarray([e[1] for e in entries], jnp.float32),
        steps=jnp.asarray([e[2] for e in entries], jnp.int32),
    )


def scatter_observations(num: int, segments) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Dense ``(Q,)`` ``(re_obs, window_size)`` vectors from sparse per-batch
    observation segments.

    ``segments`` is an iterable of ``(rows, re_vec, n_vec)`` — integer row
    indices plus same-length observation vectors (a batched finalize emits
    one vector per signature batch; singleton emissions stack into one
    extra segment).  Rows not covered by any segment hold the
    :func:`update_vector` masked-entry conventions (``re=0``, ``n=1``), so
    the result can feed ``update_vector`` with ``active`` marking exactly
    the covered rows.
    """
    re_obs = jnp.zeros((num,), jnp.float32)
    n_obs = jnp.ones((num,), jnp.float32)
    for rows, re_vec, n_vec in segments:
        idx = jnp.asarray(rows, jnp.int32)
        re_obs = re_obs.at[idx].set(jnp.asarray(re_vec, jnp.float32))
        n_obs = n_obs.at[idx].set(jnp.asarray(n_vec, jnp.float32))
    return re_obs, n_obs


def update_vector(
    state: ControllerState,
    observed_re: jnp.ndarray,
    window_size: jnp.ndarray,
    slo: StackedSLO,
    active: jnp.ndarray | None = None,
) -> ControllerState:
    """Elementwise controller step for a vector of registered queries.

    Identical math to :func:`update`, broadcast over the query axis; entries
    where ``active`` is False (queries that emitted no result this pane, or
    that have no error-bounded aggregate) keep their state unchanged and do
    not advance ``steps``.  Since the bounds subsystem, var- and
    quantile-driven members feed their observed bootstrap-CI RE through
    here like sum/mean members do; non-finite/NaN observations map to the
    target (hold) instead of poisoning the EMA.  The latency budget caps
    each query's downstream volume ``f·N`` independently (``cap=inf``
    disables it elementwise).

    Under per-query fraction refinement (nested subsampling in the session
    layer) each entry's observed RE comes from its *own* effective
    fraction rather than the fusion-group max, so the controller's
    ``RE² ∝ (1-f)/f`` model sees consistent (f, RE) pairs and divergent
    members converge to their own targets instead of free-riding the
    group's tightest SLO.
    """
    re = jnp.where(
        jnp.isfinite(observed_re) & (observed_re >= 0), observed_re, slo.target
    )
    re_ema = jnp.where(state.steps == 0, re, slo.ema * re + (1.0 - slo.ema) * state.re_ema)
    f = state.fraction
    r = jnp.square(slo.target / jnp.maximum(re_ema, 1e-9))
    odds = (1.0 - f) / jnp.maximum(f, 1e-6)
    f_new = 1.0 / (1.0 + r * odds)
    in_band = jnp.abs(re_ema - slo.target) <= slo.deadband * slo.target
    f_new = jnp.where(in_band, f, f_new)
    f_cap = slo.cap / jnp.maximum(window_size.astype(jnp.float32), 1.0)
    f_new = jnp.minimum(f_new, f_cap)
    f_new = jnp.clip(f_new, slo.min_fraction, slo.max_fraction)
    if active is None:
        active = jnp.ones(f.shape, bool)
    return ControllerState(
        fraction=jnp.where(active, f_new, state.fraction),
        re_ema=jnp.where(active, re_ema, state.re_ema),
        steps=state.steps + active.astype(jnp.int32),
    )


# -- event-driven sampling (runtime layer) -----------------------------------
#
# The SLO controller above closes the loop on *observed error*; the hooks
# below close it on *change*.  A StreamRuntime watches a registration's
# per-stratum means pane-over-pane: while the stream is quiet the fraction
# decays toward an idle floor (quiet regions cost ~nothing), a distribution
# shift or a periodic heartbeat boosts it back to a hot fraction so the
# estimator re-converges before the SLO loop would even notice.  The score
# is computed lazily on-device (no sync in the pane loop); the runtime reads
# it back one pane late via a non-pane-loop helper.


@dataclasses.dataclass(frozen=True)
class EventPolicy:
    """Heartbeat + change-trigger policy for one watched registration.

    ``change_threshold`` is a max relative per-stratum mean shift between
    consecutive panes; crossing it (or ``heartbeat_panes`` elapsing without
    a probe) boosts the fraction to ``hot_fraction``.  Quiet panes decay the
    fraction by ``idle_decay`` down to ``idle_fraction``.
    """

    heartbeat_panes: int = 8
    change_threshold: float = 0.25
    hot_fraction: float = 0.8
    idle_fraction: float = 0.1
    idle_decay: float = 0.7


@dataclasses.dataclass
class EventState:
    """Host-side per-registration event bookkeeping (checkpoint-free: it
    re-warms in one heartbeat interval after a restore)."""

    since_heartbeat: int = 0
    quiet_panes: int = 0
    hot_panes: int = 0


def change_score(prev_mean: jnp.ndarray, mean: jnp.ndarray) -> jnp.ndarray:
    """Lazy scalar: max relative per-stratum mean shift between two panes.

    Strata that are empty/non-finite in either pane are ignored; if *no*
    stratum is comparable the score is ``inf`` — an unobservable stream
    must fail hot (sample), never idle blind.
    """
    prev = jnp.asarray(prev_mean, jnp.float32).ravel()
    cur = jnp.asarray(mean, jnp.float32).ravel()
    ok = jnp.isfinite(prev) & jnp.isfinite(cur)
    denom = jnp.maximum(jnp.abs(prev), 1e-9)
    rel = jnp.where(ok, jnp.abs(cur - prev) / denom, 0.0)
    return jnp.where(jnp.any(ok), jnp.max(rel), jnp.inf)


def event_fraction(
    state: EventState, score: float, fraction: float, policy: EventPolicy
) -> float:
    """One host-side event-policy step; mutates ``state``, returns the new
    fraction.  ``score`` is a plain float (the runtime reads the lazy
    :func:`change_score` back off-device one pane late)."""
    state.since_heartbeat += 1
    hot = (not math.isfinite(score)) or score >= policy.change_threshold
    if hot or state.since_heartbeat >= policy.heartbeat_panes:
        state.since_heartbeat = 0
        state.quiet_panes = 0
        state.hot_panes += 1
        return float(policy.hot_fraction)
    state.quiet_panes += 1
    return float(max(policy.idle_fraction, fraction * policy.idle_decay))


def fraction_for_target(
    variance_per_unit: jnp.ndarray,
    population: jnp.ndarray,
    mean: jnp.ndarray,
    slo: SLO,
    z: float = 1.96,
) -> jnp.ndarray:
    """Feed-forward solve (paper's ``fractionCalc``): the fraction whose
    predicted RE equals the target, given V = Σ W_k s_k² estimates.

        RE² = z² ((1-f)/f) V / (N mean²)  =>  f = 1 / (1 + N (RE_t mean / z)² / V)
    """
    tgt = slo.target_relative_error
    denom = jnp.maximum(variance_per_unit, 1e-30)
    a = population * jnp.square(tgt * mean / z) / denom
    f = 1.0 / (1.0 + a)
    return jnp.clip(f, slo.min_fraction, slo.max_fraction)
