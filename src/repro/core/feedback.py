"""QoS feedback loop: adapt the sampling fraction to SLOs (paper §3.4/3.6.4).

The paper's loop: if observed relative error (RE) exceeds the continuous
query's SLO, raise the sampling fraction for subsequent windows; a cost
function also maps a latency budget to a fraction ceiling.

We implement an *analytic* controller instead of a fixed-step heuristic.
Under proportional allocation, Var(MEAN) ≈ ((1-f)/f) * V / N where
V = Σ W_k s_k² is (approximately) fraction-independent.  Hence
RE² ∝ (1-f)/f, and the fraction that exactly meets a target RE_t from an
observation (f, RE) is

    (1-f')/f' = (RE_t / RE)² (1-f)/f   =>   f' = 1 / (1 + r·(1-f)/f)

with r = (RE_t/RE)².  An EMA on RE plus min/max clamps give stability; a
token-budget ceiling implements the latency half of the SLO (EdgeSOS cost is
dominated by window size, not kept fraction — paper §5.2.2 — so latency maps
to a ceiling on *downstream* volume f·N, not on sampling cost).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SLO:
    """Continuous-query service level objectives."""

    target_relative_error: float = 0.10
    max_downstream_tuples: int | None = None  # latency budget proxy
    min_fraction: float = 0.05
    max_fraction: float = 1.0
    ema: float = 0.5  # smoothing on observed RE
    deadband: float = 0.05  # relative deadband around the target


class ControllerState(NamedTuple):
    fraction: jnp.ndarray  # current sampling fraction (scalar f32)
    re_ema: jnp.ndarray  # smoothed observed relative error
    steps: jnp.ndarray  # windows processed


def init_state(fraction: float = 0.8) -> ControllerState:
    return ControllerState(
        fraction=jnp.float32(fraction),
        re_ema=jnp.float32(0.0),
        steps=jnp.int32(0),
    )


def update(
    state: ControllerState,
    observed_re: jnp.ndarray,
    window_size: jnp.ndarray,
    slo: SLO,
) -> ControllerState:
    """One controller step after a window's estimate is produced."""
    re = jnp.where(jnp.isfinite(observed_re), observed_re, slo.target_relative_error)
    re_ema = jnp.where(
        state.steps == 0, re, slo.ema * re + (1.0 - slo.ema) * state.re_ema
    )
    f = state.fraction
    tgt = jnp.float32(slo.target_relative_error)
    r = jnp.square(tgt / jnp.maximum(re_ema, 1e-9))
    odds = (1.0 - f) / jnp.maximum(f, 1e-6)
    f_new = 1.0 / (1.0 + r * odds)
    # deadband: don't thrash when RE is already within ±deadband of target
    in_band = jnp.abs(re_ema - tgt) <= slo.deadband * tgt
    f_new = jnp.where(in_band, f, f_new)
    # latency budget: cap downstream volume f·N
    if slo.max_downstream_tuples is not None:
        f_cap = jnp.float32(slo.max_downstream_tuples) / jnp.maximum(
            window_size.astype(jnp.float32), 1.0
        )
        f_new = jnp.minimum(f_new, f_cap)
    f_new = jnp.clip(f_new, slo.min_fraction, slo.max_fraction)
    return ControllerState(fraction=f_new, re_ema=re_ema, steps=state.steps + 1)


def fraction_for_target(
    variance_per_unit: jnp.ndarray,
    population: jnp.ndarray,
    mean: jnp.ndarray,
    slo: SLO,
    z: float = 1.96,
) -> jnp.ndarray:
    """Feed-forward solve (paper's ``fractionCalc``): the fraction whose
    predicted RE equals the target, given V = Σ W_k s_k² estimates.

        RE² = z² ((1-f)/f) V / (N mean²)  =>  f = 1 / (1 + N (RE_t mean / z)² / V)
    """
    tgt = slo.target_relative_error
    denom = jnp.maximum(variance_per_unit, 1e-30)
    a = population * jnp.square(tgt * mean / z) / denom
    f = 1.0 / (1.0 + a)
    return jnp.clip(f, slo.min_fraction, slo.max_fraction)
