"""Error-bounds subsystem: CIs for every accumulator kind, cloud-side only.

The paper's headline claim is *error-bounded* approximation, yet classic
stratified theory (eqs 5-10 in :mod:`.estimators`) only covers linear
statistics (SUM/MEAN).  This module closes the gap for the remaining
aggregate families — ``var``, quantiles (``p<q>``), and ``min``/``max`` —
by deriving sampling-error intervals **from the mergeable sufficient
statistics already shipped to the cloud**: per-stratum moment rows
``(n_k, N_k, ȳ_k, s²_k)`` and per-stratum sketch bin counts.  No extra
uplink bytes; everything here runs after the collective.

Three bound families, one per accumulator kind:

``var``     — **stratified parametric bootstrap over moment rows.**  Within
              stratum k the CLT gives ``ȳ*_k ~ N(ȳ_k, (1-f_k) s²_k / n_k)``
              and ``s²*_k`` resamples log-normally with relative variance
              ``(κ_k-1)(1-f_k)/(n_k-1)`` (κ from :func:`sketch_kurtosis`
              when the column already ships a sketch, normal-theory κ = 3
              otherwise); each replicate re-evaluates the plug-in
              population variance from the resampled rows.  Shapes are
              ``(R, S+1)`` — broadcast over replicates and strata,
              jit-friendly, microseconds on CPU.  When a sketch is shipped
              the reported interval is the conservative union with the
              fully nonparametric :func:`var_sketch_interval` channel.

``p<q>``    — **stratified multinomial bootstrap over sketch bins,
              Poissonized and collapsed across strata.**  Resampling the
              ``n_k`` sampled tuples of stratum k over its bin row is
              multinomial; Poissonizing makes bins independent
              (``c*_kb ~ Poisson(c_kb)``), and because finalize only reads
              the *weighted sum across strata*, the CLT collapses the
              whole stratum axis exactly:

                  Σ_k w_k Pois(c_kb)  ≈  N( Σ_k w_k c_kb,
                                            Σ_k w_k² (1-f_k) c_kb )

              with ``w_k = N_k/n_k`` the Horvitz-Thompson expansion and
              ``(1-f_k)`` the per-stratum finite-population correction.
              Each replicate perturbs the weighted histogram with one
              ``(R, ..., B)`` draw — third-moment-matched via the
              Wilson-Hilferty transform and pseudo-count-smoothed (see
              :func:`collapsed_replicates`) so sparse heavy-tail bins keep
              nominal coverage — and re-inverts the CDF.  The collapse is
              what makes 200-replicate bootstraps affordable per pane
              (a direct per-bin Poisson sampler is ~2000× slower on CPU).

``min/max`` — **order-statistic rank bounds + Cantelli.**  Under
              per-stratum SRS at fraction f_k, the probability that the
              ``m`` most extreme population values all evade the sample is
              ``≤ (1-f_k)^m``; hence with confidence c at most
              ``m_k = ⌈ln(1-c)/ln(1-f_k)⌉`` unsampled values of stratum k
              exceed the sample max (and symmetrically for min), clipped
              to the ``N_k - n_k`` unsampled tuples.  Cantelli's one-sided
              inequality converts the rank slack into a value bound: at
              most ``N_k s²/(s² + d²)`` values lie above ``ȳ_k + d``, so
              ``d_k = s_k·√(N_k/m_k − 1)`` bounds the overshoot.  Fully
              sampled strata (m_k = 0) get zero-width bounds; strata too
              thin to estimate spread (n_k < 2, under-sampled) are
              honestly unbounded (±inf).

All three shrink to zero width at fraction 1 (the fpc/rank terms vanish),
are deterministic in the PRNG key, and are continuous in the merged
statistics — so preagg/raw modes and fused sessions produce matching
bounds for the same sample (property-tested).

Every family reads the sampling fraction *only* through the realized
per-stratum ``(n_k, N_k)`` rows, never through a nominal fraction knob —
so when a fused session refines a member's shared sample down to its own
fraction (nested HT subsampling, see :mod:`.session`), the member's
intervals automatically reflect its **effective** fraction: a 10%-fraction
member fused with an 80% one reports honest 10% widths, which widen
monotonically as the refined fraction shrinks (property-tested in
``tests/test_subsampling.py``).

Grouped queries reuse the same code paths: every function takes an
optional ``grp`` stratum→group index (overflow slot mapping to a discarded
trailing group) and a static ``num_groups``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .estimators import sketch_bin_values, sketch_quantile

DEFAULT_REPLICATES = 200


def _gsum(x: jnp.ndarray, grp: jnp.ndarray, num_groups: int) -> jnp.ndarray:
    """Segment-sum strata into groups along the last axis (overflow group
    dropped); works batched over arbitrary leading axes."""
    moved = jnp.moveaxis(x, -1, 0)
    out = jax.ops.segment_sum(moved, grp, num_segments=num_groups + 1)[:num_groups]
    return jnp.moveaxis(out, 0, -1)


def _reduce(x: jnp.ndarray, grp: jnp.ndarray | None, num_groups: int) -> jnp.ndarray:
    return jnp.sum(x, axis=-1) if grp is None else _gsum(x, grp, num_groups)


def percentile_interval(
    reps: jnp.ndarray, confidence: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(lo, hi) percentile-bootstrap interval over the leading replicate axis."""
    alpha = (1.0 - confidence) / 2.0
    qs = jnp.asarray([alpha, 1.0 - alpha], jnp.float32)
    lo_hi = jnp.quantile(reps, qs, axis=0)
    return lo_hi[0], lo_hi[1]


def sketch_kurtosis(bins: jnp.ndarray, n: jnp.ndarray) -> jnp.ndarray:
    """Per-stratum kurtosis ``κ̂_k = m4/m2²`` estimated from sketch bin rows.

    The sampling variance of a stratum's s² is ``≈ (κ-1) σ⁴ / n`` — the
    normal-theory ``κ = 3`` badly under-covers heavy-tailed streams, and
    the moment rows themselves carry no fourth-moment information.  When
    the column *already ships* a quantile sketch, its binned distribution
    estimates κ for free (the ~4% bin resolution is negligible against
    κ's dynamic range); strata too thin to estimate (n < 8) fall back to
    the normal value.  Clipped to [1.5, 1e4] for numeric sanity.
    """
    vals = sketch_bin_values()
    cnt = jnp.sum(bins, axis=-1)
    mean = jnp.sum(bins * vals, axis=-1) / jnp.maximum(cnt, 1.0)
    d = vals - mean[..., None]
    m2 = jnp.sum(bins * d * d, axis=-1) / jnp.maximum(cnt, 1.0)
    m4 = jnp.sum(bins * d * d * d * d, axis=-1) / jnp.maximum(cnt, 1.0)
    kappa = m4 / jnp.maximum(m2 * m2, 1e-30)
    return jnp.where((n >= 8) & (m2 > 0), jnp.clip(kappa, 1.5, 1e4), 3.0)


def moment_replicates(
    key,
    n: jnp.ndarray,
    total: jnp.ndarray,
    mean: jnp.ndarray,
    s2: jnp.ndarray,
    replicates: int,
    kurtosis: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(R, S+1) parametric-bootstrap draws of per-stratum (mean, s²) rows.

    Strata with ``n_k == 0`` draw no mean spread; strata with ``n_k < 2``
    draw no s² spread (callers decide how to guard their contribution —
    see :func:`~.estimators.guarded_s2`).  Both spreads carry the
    finite-population correction ``(1 - f_k)`` so fully sampled strata are
    reproduced exactly.  ``kurtosis`` sets the s² spread
    ``Var(s²) ≈ (κ-1) s⁴ / n`` per stratum; ``None`` assumes normal tails
    (κ = 3) — pass :func:`sketch_kurtosis` when the column ships a sketch.
    """
    f = jnp.where(total > 0, n / jnp.maximum(total, 1.0), 1.0)
    fpc = jnp.maximum(1.0 - f, 0.0)
    kappa = jnp.asarray(3.0, jnp.float32) if kurtosis is None else kurtosis
    k1, k2 = jax.random.split(key)
    shape = (replicates,) + mean.shape
    e1 = jax.random.normal(k1, shape)
    e2 = jax.random.normal(k2, shape)
    se_mean = jnp.where(n > 0, jnp.sqrt(fpc * s2 / jnp.maximum(n, 1.0)), 0.0)
    mean_r = mean + se_mean * e1
    # s² resamples log-normally (moment-matched): the sampling distribution
    # of a variance is right-skewed — a symmetric normal clips its upper
    # tail and under-covers; the multiplicative form also keeps s²* >= 0
    # and degenerates to exactly s² at full fraction.
    rel_sd = jnp.where(
        n > 1,
        jnp.sqrt(jnp.maximum(kappa - 1.0, 0.0) * fpc / jnp.maximum(n - 1.0, 1.0)),
        0.0,
    )
    sig = jnp.sqrt(jnp.log1p(rel_sd * rel_sd))
    s2_r = s2 * jnp.exp(sig * e2 - 0.5 * sig * sig)
    return mean_r, s2_r


def var_interval(
    key,
    n: jnp.ndarray,
    total: jnp.ndarray,
    mean: jnp.ndarray,
    s2: jnp.ndarray,
    confidence: float,
    replicates: int,
    grp: jnp.ndarray | None = None,
    num_groups: int = 1,
    unidentified: jnp.ndarray | None = None,
    kurtosis: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bootstrap CI for the plug-in population variance, per group.

    ``s2`` should already be singleton-guarded (imputed) so lonely strata
    contribute borrowed spread instead of false-zero; ``unidentified``
    marks groups whose variance no stratum identifies — their interval is
    ``[0, inf)``.  ``kurtosis`` (see :func:`sketch_kurtosis`) sharpens the
    s² resampling spread beyond normal theory for heavy-tailed columns.
    """
    mean_r, s2_r = moment_replicates(
        key, n, total, mean, s2, replicates, kurtosis=kurtosis
    )
    active = (n > 0) & (total > 0)
    w = jnp.where(active, total, 0.0)
    covered = jnp.maximum(_reduce(w, grp, num_groups), 1.0)
    sum_r = _reduce(w * mean_r, grp, num_groups)
    ey2_r = _reduce(w * (s2_r + mean_r * mean_r), grp, num_groups)
    mean_g_r = sum_r / covered
    var_r = jnp.maximum(ey2_r / covered - mean_g_r * mean_g_r, 0.0)
    lo, hi = percentile_interval(var_r, confidence)
    lo = jnp.maximum(lo, 0.0)
    if unidentified is not None:
        lo = jnp.where(unidentified, 0.0, lo)
        hi = jnp.where(unidentified, jnp.inf, hi)
    return lo, hi


# Poisson-rate smoothing of occupied bins: a sparse bin's observed count c
# systematically understates the uncertainty its true rate λ contributes to
# the resample (the tail the sample barely saw is exactly where λ̂ = c is
# least trustworthy).  Resampling at the Gamma posterior-mean rate c+1
# (exponential prior on occupied bins) is the standard smoothing fix; it
# restores heavy-tail coverage and vanishes under the fpc at full fraction.
SKETCH_PSEUDO_COUNT = 1.0


def _skewed_unit(eps: jnp.ndarray, skew: jnp.ndarray) -> jnp.ndarray:
    """Zero-mean unit-variance draws with target skewness (Wilson-Hilferty).

    Maps standard normals through the WH cube approximation of a gamma with
    shape ``α = 4/γ²`` and standardizes — smooth, vectorized, and exactly
    normal in the γ → 0 limit.  Matching the third moment matters: tail
    bins hold few, heavily-HT-weighted counts, and a symmetric perturbation
    clips their upper reach, under-covering right-skewed columns.
    """
    alpha = jnp.where(skew > 1e-6, 4.0 / jnp.maximum(skew * skew, 1e-12), 1e12)
    g = alpha * (1.0 - 1.0 / (9.0 * alpha) + eps / (3.0 * jnp.sqrt(alpha))) ** 3
    return (g - alpha) / jnp.sqrt(alpha)


def collapsed_replicates(
    key,
    bins: jnp.ndarray,
    n: jnp.ndarray,
    total: jnp.ndarray,
    replicates: int,
    grp: jnp.ndarray | None = None,
    num_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The collapsed stratified bootstrap engine over sketch bin rows.

    Returns ``(wb, wb_r)``: the per-group HT-weighted histogram
    ``(..., B)`` and ``replicates`` perturbed copies ``(R, ..., B)`` whose
    per-bin mean/variance/skew match the Poissonized multinomial resample
    collapsed across strata (variance ``Σ_k w_k²(1-f_k)(c_kb + 1)``, third
    moment ``Σ_k w_k³(1-f_k)(c_kb + 1)``, pseudo-count on occupied bins).
    """
    w = jnp.where(n > 0, total / jnp.maximum(n, 1.0), 0.0)
    fpc = jnp.where(total > 0, jnp.maximum(1.0 - n / jnp.maximum(total, 1.0), 0.0), 0.0)
    cb = bins + SKETCH_PSEUDO_COUNT * (bins > 0)
    wb = _reduce((w[:, None] * bins).swapaxes(-1, -2), grp, num_groups)
    v = _reduce(((w * w * fpc)[:, None] * cb).swapaxes(-1, -2), grp, num_groups)
    m3 = _reduce(((w * w * w * fpc)[:, None] * cb).swapaxes(-1, -2), grp, num_groups)
    # _reduce consumed the stratum axis; bins axis is now leading — restore
    wb = jnp.moveaxis(wb, 0, -1)  # (B,) or (B, G) -> (..., B)
    v = jnp.moveaxis(v, 0, -1)
    m3 = jnp.moveaxis(m3, 0, -1)
    skew = m3 / jnp.maximum(v, 1e-30) ** 1.5
    eps = jax.random.normal(key, (replicates,) + wb.shape)
    wb_r = jnp.maximum(wb + jnp.sqrt(v) * _skewed_unit(eps, skew), 0.0)
    return wb, wb_r


def quantile_interval(
    key,
    bins: jnp.ndarray,
    n: jnp.ndarray,
    total: jnp.ndarray,
    q: float,
    confidence: float,
    replicates: int,
    grp: jnp.ndarray | None = None,
    num_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bootstrap CI for the HT-expanded sketch quantile, per group.

    ``bins`` is the merged (S+1, B) sampled-count histogram; each collapsed
    replicate (see :func:`collapsed_replicates`) re-inverts its CDF.
    """
    _, wb_r = collapsed_replicates(
        key, bins, n, total, replicates, grp=grp, num_groups=num_groups
    )
    q_r = sketch_quantile(wb_r, q)
    return percentile_interval(q_r, confidence)


def _hist_var(wb: jnp.ndarray) -> jnp.ndarray:
    """Population variance of a (..., B) weighted histogram."""
    vals = sketch_bin_values()
    tot = jnp.maximum(jnp.sum(wb, axis=-1), 1e-30)
    m1 = jnp.sum(wb * vals, axis=-1) / tot
    m2 = jnp.sum(wb * vals * vals, axis=-1) / tot
    return jnp.maximum(m2 - m1 * m1, 0.0)


def var_sketch_interval(
    key,
    bins: jnp.ndarray,
    n: jnp.ndarray,
    total: jnp.ndarray,
    confidence: float,
    replicates: int,
    center: jnp.ndarray,
    grp: jnp.ndarray | None = None,
    num_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Nonparametric var CI from an already-shipped sketch, per group.

    Each collapsed replicate re-evaluates the population variance of its
    weighted histogram — a full bootstrap of the plug-in functional at bin
    resolution, so third/fourth-moment sampling error is captured without
    distributional assumptions.  The interval is re-centered on ``center``
    (the exact moment-based plug-in estimate), which cancels the constant
    ~bin-resolution bias between the binned and exact statistics.
    """
    wb, wb_r = collapsed_replicates(
        key, bins, n, total, replicates, grp=grp, num_groups=num_groups
    )
    var_0 = _hist_var(wb)
    lo, hi = percentile_interval(_hist_var(wb_r), confidence)
    return jnp.maximum(center + (lo - var_0), 0.0), center + (hi - var_0)


def _rank_slack(n: jnp.ndarray, total: jnp.ndarray, confidence: float) -> jnp.ndarray:
    """m_k: with prob >= confidence at most this many unsampled tuples of
    stratum k lie beyond the sample extreme (0 when fully sampled)."""
    f = jnp.where(total > 0, n / jnp.maximum(total, 1.0), 1.0)
    log_miss = jnp.log(jnp.maximum(1.0 - f, 1e-30))
    m = jnp.ceil(jnp.log(1.0 - confidence) / jnp.minimum(log_miss, -1e-30))
    return jnp.clip(m, 0.0, jnp.maximum(total - n, 0.0))


def extrema_interval(
    side: str,
    ext_value: jnp.ndarray,
    n: jnp.ndarray,
    total: jnp.ndarray,
    mean: jnp.ndarray,
    s2: jnp.ndarray,
    confidence: float,
    grp: jnp.ndarray | None = None,
    num_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Order-statistic + Cantelli bound for ``min``/``max``, per group.

    Returns (lo, hi): for ``max`` the population extreme lies in
    ``[sample_max, hi]``; for ``min`` in ``[lo, sample_min]``.  The open
    side is +/-inf for strata whose spread is unobservable (n_k < 2 while
    under-sampled, including sampled-empty populated strata).
    """
    sign = 1.0 if side == "max" else -1.0
    m = _rank_slack(n, total, confidence)
    d = jnp.sqrt(s2 * jnp.maximum(total / jnp.maximum(m, 1.0) - 1.0, 0.0))
    # work in signed space (negate for min) so both sides are maxima
    witnessed = jnp.where(total > 0, sign * ext_value, -jnp.inf)
    bound = jnp.where(m > 0, sign * mean + d, witnessed)
    # spread unobservable: an under-sampled stratum with n_k < 2 admits no
    # Cantelli bound — its population extreme is honestly unbounded
    bound = jnp.where((m > 0) & (n < 2), jnp.inf, bound)
    # the bound can never undercut the witnessed sample extreme, and empty
    # populations contribute the lattice identity
    bound = jnp.where(total > 0, jnp.maximum(bound, witnessed), -jnp.inf)
    if grp is None:
        far = jnp.max(bound)
        near = jnp.max(witnessed)
    else:
        far = jax.ops.segment_max(bound, grp, num_segments=num_groups + 1)[:num_groups]
        near = jax.ops.segment_max(witnessed, grp, num_segments=num_groups + 1)[:num_groups]
    if side == "max":
        return near, far
    return -far, -near
