"""EdgeApproxGeo query engine (paper Algorithm 2 + the declarative layer).

The pipeline executes declarative :class:`~.query.Query` specs over stream
windows.  A query is lowered (``query.lower``) into the two halves of the
edge-cloud split:

Edge tier  = the mesh shards along the data axes: each shard independently
             stratifies + EdgeSOS-samples its local window (no cross-shard
             communication in the sampling path) and reduces every column
             the query references to its plan-declared set of mergeable
             per-stratum accumulator states (``{kind: state}`` registry
             pytrees: moments / extrema / quantile sketch / anything
             registered) — the *edge partial-aggregation program*.  The
             moment reductions run on a configurable backend
             (``PipelineConfig.backend``):

               * ``"segment"`` — per-column ``jax.ops.segment_*`` (the
                 portable path and the parity oracle);
               * ``"pallas"``  — ONE fused multi-column edge-reduce pass
                 (``kernels/edge_reduce``): all fusion-group columns'
                 moment rows contract against the one-hot stratum tile in
                 a single MXU sweep per window; off-TPU this lowers to the
                 equivalent single-pass stacked segment reduce.
Cloud tier = the post-collective computation: consolidate shard partials
             and finalize each aggregate into an ``AggEstimate`` with error
             bounds, optionally grouped by stratum / neighborhood — the
             *consolidation query*.  The QoS feedback controller closes the
             loop on the reported relative error.

Two transmission modes (paper §3.6.4), chosen per query:
  * 'preagg' — shards reduce to per-stratum accumulators; one psum of the
    moment vectors plus a pmin/pmax of the extrema, O(S · columns) floats,
    crosses the interconnect.  The default and the paper's bandwidth-saving
    mode.
  * 'raw'    — shards compact kept tuples (stratum id + every referenced
    column) into a padded buffer and all-gather it.  Collective bytes scale
    with the kept sample, not with strata.

Both modes produce identical estimates for the same sample, for every
aggregate kind (tested).

Entry points:
  * ``execute(query, key, window, fraction)`` — the one-shot query engine;
    accepts a ``WindowBatch`` (multi-column) or a mapping of arrays.
  * ``session.StreamSession`` — the continuous-query engine: registered
    QuerySets share one sampling pass per pane via plan fusion; its edge
    half is this pipeline's ``_pass_fn`` (the same program as ``execute``
    minus finalize).  ``run_stream`` is a thin shim over a single-query
    session.
  * ``process_window(key, lat, lon, value, valid, fraction)`` — legacy
    single-estimate API, kept as a thin shim over the canonical
    ``SUM/MEAN(value)`` query; bit-compatible with the pre-query pipeline.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Mapping, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import codec as wirecodec
from . import estimators, feedback, sampling
from . import query as aqp

from ..sharding.compat import compat_shard_map as _shard_map

from .estimators import Estimate, StratumStats
from .query import AggEstimate, AggSpec, Plan, Query, QueryResult
from .sampling import SampleResult
from .stratify import StratumTable
from .windows import WindowBatch


BACKENDS = ("segment", "pallas", "fused")

STAGING_DTYPES = ("float32", "bfloat16")

# registry kinds the megakernel emits stat rows for in one pass; plans
# referencing any other kind keep the per-kind accumulate path for it
_FUSED_STAT_KINDS = frozenset({"moments", "extrema", "sketch"})


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Deployment-level defaults; per-query settings live on ``Query``.

    ``backend`` selects the edge reduction implementation:

    * ``"segment"`` — per-column segment ops, the portable parity oracle;
    * ``"pallas"`` — fused multi-column edge-reduce (the Pallas MXU kernel
      on TPU, its single-pass stacked-segment equivalent elsewhere);
      sampling co-dispatches geohash encoding and Bernoulli selection
      through their kernels on TPU;
    * ``"fused"`` — the single-traversal edge megakernel
      (``kernels/edge_megakernel``): geohash + stratify + threshold
      sampling + moments/extrema/sketch stat rows in ONE Pallas pass per
      pane — the intermediate ``sidx``/``mask``/one-hot arrays never
      reach HBM (SRS keeps its rank sort outside, stats still fuse).
      Off-TPU it lowers to the equivalent stacked segment program.

    ``staging_dtype`` (fused backend only) is the dtype value columns are
    *staged* in on their way into the kernel — ``"bfloat16"`` halves the
    value-column VMEM/HBM traffic; every kernel accumulator stays f32
    (EDG004's contract), so only the input rounding differs.

    ``uplink_codec`` selects the preagg wire format (:mod:`.codec`):
    ``None`` ships the dense analytic payload; ``"sparse"`` /
    ``"topk<k>"`` / ``"quantize16"`` / ``"quantize8"`` / ``"delta"``
    route every preagg uplink frame through the named codec — estimates
    then consolidate from the *decoded* states and the session/runtime
    byte accounting reports the measured encoded bytes instead of the
    dense model.  Raw-mode queries are untouched (their compacted tuple
    buffer is already sample-proportional).
    """

    method: str = "srs"  # srs | bernoulli | neyman  (legacy-API default)
    mode: str = "preagg"  # preagg | raw              (legacy-API default)
    confidence: float = 0.95
    raw_capacity: int | None = None  # static per-shard buffer for raw mode
    backend: str = "segment"  # segment | pallas | fused (edge reduction)
    staging_dtype: str = "float32"  # float32 | bfloat16 (fused kernel inputs)
    uplink_codec: str | None = None  # None | sparse | topk<k> | quantize{8,16} | delta

    def __post_init__(self):
        wirecodec.resolve_codec(self.uplink_codec)  # fail fast on bad specs
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}; got {self.backend!r}")
        if self.staging_dtype not in STAGING_DTYPES:
            raise ValueError(
                f"staging_dtype must be one of {STAGING_DTYPES}; got {self.staging_dtype!r}"
            )
        if self.staging_dtype != "float32" and self.backend != "fused":
            raise ValueError(
                "staging_dtype is a fused-backend knob: reduced-precision "
                "staging requires backend='fused' (accumulation stays f32 "
                "on every backend)"
            )


class WindowResult(NamedTuple):
    estimate: Estimate
    stats: StratumStats
    n_sampled: jnp.ndarray
    n_valid: jnp.ndarray
    n_overflow: jnp.ndarray  # tuples outside the region of interest
    comm_bytes: jnp.ndarray  # analytic edge->cloud payload size of this mode


# remove the out-of-region slot from estimation (kept in aux only);
# canonical implementation lives with the accumulators in estimators.py
_zero_overflow = estimators.zero_overflow_stats


def edge_sample(
    key,
    table: StratumTable,
    lat: jnp.ndarray,
    lon: jnp.ndarray,
    valid: jnp.ndarray,
    fraction,
    method: str,
    stddev: jnp.ndarray | None = None,
    backend: str = "segment",
) -> tuple[jnp.ndarray, SampleResult]:
    """Edge-local half of Algorithm 2: stratify + EdgeSOS sample."""
    sidx = table.assign(lat, lon, backend=backend)
    sidx = jnp.where(valid, sidx, table.num_strata)  # padding -> overflow
    result = sampling.edgesos(
        key, sidx, table.num_slots, fraction, method=method, stddev=stddev,
        backend=backend,
    )
    mask = result.mask & valid
    weight = jnp.where(valid, result.weight, 0.0)
    # population counts must also exclude padding
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), sidx, num_segments=table.num_slots
    )
    n_k = jax.ops.segment_sum(mask.astype(jnp.int32), sidx, num_segments=table.num_slots)
    return sidx, SampleResult(mask=mask, weight=weight, n_k=n_k, counts=counts)


def _accumulate_columns(
    plan: Plan,
    cfg: PipelineConfig,
    cols: Mapping[str, jnp.ndarray],
    sidx,
    mask,
    num_slots: int,
    counts,
) -> dict:
    """Reduce every referenced column to its plan-declared registry states.

    The moment states of ALL columns come from one fused multi-column
    edge-reduce pass when ``cfg.backend == "pallas"`` (the MXU kernel on
    TPU, the stacked single-pass segment reduce elsewhere) — one window
    traversal for the whole fusion group — or from per-column segment ops
    on the ``"segment"`` oracle backend.  Non-moment kinds (extrema
    lattices, quantile sketches) accumulate via their registry entries.
    """
    kinds_map = plan.column_kind_map
    stats: dict = {c: {} for c in plan.columns}
    if cfg.backend == "pallas":
        from ..kernels.edge_reduce import edge_reduce

        stacked = jnp.stack([cols[c].astype(jnp.float32) for c in plan.columns])
        cnt, s1, s2 = edge_reduce(sidx, stacked, mask, num_slots)
        for i, c in enumerate(plan.columns):
            stats[c]["moments"] = estimators.stats_from_raw_moments(
                cnt, s1[i], s2[i], counts
            )
    elif cfg.backend == "fused":
        # a given sample's moment/extrema/sketch rows in one megakernel
        # sweep (sidx mode, keep == mask via the zero-score/one-threshold
        # degenerate compare); kinds outside the fused set fall through to
        # the registry loop below
        from ..kernels.edge_megakernel import edge_megakernel

        ext_idx, sk_idx = _kernel_layout(plan.columns, kinds_map)
        res = edge_megakernel(
            _stack_staged(cfg, plan.columns, cols),
            mask.astype(jnp.float32)[None],
            jnp.zeros((1,) + mask.shape, jnp.float32),
            jnp.ones((1, num_slots), jnp.float32),
            num_slots,
            sidx=sidx[None],
            ext_idx=ext_idx,
            sk_idx=sk_idx,
        )
        stats = _stats_from_mega(
            plan.columns, kinds_map, res, 0, res.keep[0], counts,
            plan.columns, ext_idx, sk_idx,
        )
    else:
        for c in plan.columns:
            stats[c]["moments"] = estimators.MOMENTS.accumulate(
                cols[c], sidx, mask, num_slots, counts=counts
            )
    for c in plan.columns:
        for kind in kinds_map[c]:
            if kind not in stats[c]:
                stats[c][kind] = estimators.accumulator(kind).accumulate(
                    cols[c], sidx, mask, num_slots, counts=counts
                )
    return stats


def _plan_fusable(plan: Plan) -> bool:
    """True when every referenced kind has megakernel stat rows — the
    condition for serving the plan from the single-traversal pass (other
    kinds need the materialized ``sidx``/``mask`` the megakernel skips)."""
    kinds_map = plan.column_kind_map
    return all(set(kinds_map[c]) <= _FUSED_STAT_KINDS for c in plan.columns)


def _kernel_layout(columns, kinds_map) -> tuple[tuple, tuple]:
    """Column positions that get extrema / sketch rows in the megakernel."""
    ext_idx = tuple(i for i, c in enumerate(columns) if "extrema" in kinds_map.get(c, ()))
    sk_idx = tuple(i for i, c in enumerate(columns) if "sketch" in kinds_map.get(c, ()))
    return ext_idx, sk_idx


def _stack_staged(cfg: PipelineConfig, columns, cols) -> jnp.ndarray:
    """Stack value columns in the configured staging dtype (fused backend):
    bf16 staging halves the kernel's value-column traffic; accumulation is
    f32 on every path, so only input rounding differs."""
    dt = jnp.bfloat16 if cfg.staging_dtype == "bfloat16" else jnp.float32
    return jnp.stack([cols[c] for c in columns]).astype(dt)


def _stats_from_mega(
    columns, kinds_map, res, m, keep, counts, union_cols, ext_idx, sk_idx
) -> dict:
    """Adopt member ``m``'s megakernel stat rows into registry states.

    ``columns`` is the member's own column list; positions resolve against
    ``union_cols`` (the kernel's value-column layout, a superset for refined
    fused groups).  ``keep`` is the per-slot kept-count row to use as the
    moment count (callers patch latlon-mode overflow residuals in first).
    """
    pos = {c: i for i, c in enumerate(union_cols)}
    e_pos = {i: e for e, i in enumerate(ext_idx)}
    k_pos = {i: k for k, i in enumerate(sk_idx)}
    stats: dict = {}
    for c in columns:
        i = pos[c]
        d = {
            "moments": estimators.MOMENTS.from_kernel_rows(
                keep, res.s1[m, i], res.s2[m, i], counts
            )
        }
        for kind in kinds_map.get(c, ()):
            if kind == "extrema":
                d[kind] = estimators.EXTREMA.from_kernel_rows(
                    res.mins[m, e_pos[i]], res.maxs[m, e_pos[i]]
                )
            elif kind == "sketch":
                d[kind] = estimators.SKETCH.from_kernel_rows(res.bins[m, k_pos[i]])
        stats[c] = d
    return stats


def _edge_program(
    plan: Plan,
    table: StratumTable,
    cfg: PipelineConfig,
    key,
    lat,
    lon,
    cols: Mapping[str, jnp.ndarray],
    valid,
    fraction,
    axes=None,
):
    """The lowered edge half of a plan (+ the consolidating collective).

    Returns ``(stats, n_sampled, n_valid, n_overflow, n_truncated,
    comm_bytes)`` where ``stats`` maps column -> globally merged
    ``{kind: state}`` accumulator dict.  With ``axes`` set this runs inside
    shard_map and consolidation is a collective; otherwise it is the
    single-edge-node program.
    """
    q = plan.query
    if axes is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axes))
    ok = valid & aqp.roi_mask(plan, table, lat, lon)
    if (
        cfg.backend == "fused"
        and q.mode != "raw"
        and _plan_fusable(plan)
        and q.method in ("srs", "bernoulli")
        # latlon-mode overflow residuals need a scalar threshold; a
        # per-stratum Bernoulli fraction falls back to the two-pass path
        and (q.method == "srs" or jnp.ndim(fraction) == 0)
    ):
        stats, n_sampled, n_valid, n_overflow = _fused_member_program(
            plan, table, cfg, key, lat, lon, cols, ok, valid, fraction, axes
        )
        comm = jnp.int32(aqp.preagg_bytes(plan, table.num_slots))
        return stats, n_sampled, n_valid, n_overflow, jnp.int32(0), comm
    sidx, sample = edge_sample(
        key, table, lat, lon, ok, fraction, q.method, backend=cfg.backend
    )
    if q.mode == "raw":
        cap = cfg.raw_capacity or lat.shape[0]
        packed = sampling.compact(
            sample.mask, cap, sidx, *[cols[c] for c in plan.columns]
        )
        # kept tuples beyond the static buffer are silently shed by
        # compact(); account for them so QueryResult can surface the loss
        kept = jnp.sum(sample.mask.astype(jnp.int32))
        n_truncated = jnp.maximum(kept - jnp.int32(min(cap, lat.shape[0])), 0)
        counts = sample.counts
        if axes is not None:
            packed = tuple(jax.lax.all_gather(p, axes, tiled=True) for p in packed)
            counts = jax.lax.psum(counts, axes)
        v_ok, v_sidx = packed[0], packed[1]
        gathered = {c: packed[2 + i] for i, c in enumerate(plan.columns)}
        stats = _accumulate_columns(
            plan, cfg, gathered, v_sidx, v_ok, table.num_slots, counts
        )
        comm = jnp.int32(aqp.raw_bytes(plan, cap))
        n_sampled = jnp.sum(sample.mask.astype(jnp.int32))
        n_valid = jnp.sum(ok.astype(jnp.int32))
        n_overflow = sample.counts[-1] + jnp.sum((valid & ~ok).astype(jnp.int32))
        if axes is not None:
            n_sampled = jax.lax.psum(n_sampled, axes)
            n_valid = jax.lax.psum(n_valid, axes)
            n_overflow = jax.lax.psum(n_overflow, axes)
            n_truncated = jax.lax.psum(n_truncated, axes)
    else:
        stats, n_sampled, n_valid, n_overflow = _member_reduce(
            plan, table, cfg, cols, sidx, sample.mask, ok, valid, sample.counts, axes
        )
        n_truncated = jnp.int32(0)
        comm = jnp.int32(aqp.preagg_bytes(plan, table.num_slots))
    return stats, n_sampled, n_valid, n_overflow, n_truncated, comm


def _member_reduce(
    plan: Plan, table: StratumTable, cfg: PipelineConfig, cols, sidx, mask, ok,
    valid, counts, axes,
):
    """One plan's preagg reduce + consolidate + counters for a given sample.

    The canonical implementation shared by :func:`_edge_program`'s preagg
    branch and the refined fused pass (:func:`_fused_edge_program`): a
    refined member whose mask equals its independent draw gets bit-identical
    states *by construction*, because both paths run this exact program."""
    stats = _accumulate_columns(plan, cfg, cols, sidx, mask, table.num_slots, counts)
    n_sampled = jnp.sum(mask.astype(jnp.int32))
    return _consolidate(plan, stats, n_sampled, ok, valid, counts, axes)


def _consolidate(plan: Plan, stats, n_sampled, ok, valid, counts, axes):
    """Shared tail of every preagg path: the consolidating collective over
    accumulator states plus the sample/validity/overflow counters."""
    if axes is not None:
        merged: dict = {}
        shared = None
        for c in plan.columns:
            merged[c] = estimators.psum_accs(stats[c], axes, shared=shared)
            shared = shared if shared is not None else merged[c]["moments"]
        stats = merged
    n_valid = jnp.sum(ok.astype(jnp.int32))
    n_overflow = counts[-1] + jnp.sum((valid & ~ok).astype(jnp.int32))
    if axes is not None:
        n_sampled = jax.lax.psum(n_sampled, axes)
        n_valid = jax.lax.psum(n_valid, axes)
        n_overflow = jax.lax.psum(n_overflow, axes)
    return stats, n_sampled, n_valid, n_overflow


def _fused_member_program(
    plan: Plan, table: StratumTable, cfg: PipelineConfig, key, lat, lon, cols,
    ok, valid, fraction, axes,
):
    """One plan's preagg reduce as a SINGLE megakernel traversal.

    The megakernel's unified threshold compare reproduces EdgeSOS sampling
    bit-identically while emitting every fused stat row in the same pass:

      * ``bernoulli`` — the same unsplit-key uniforms
        :func:`~.sampling.bernoulli_sample` draws become the scores and the
        scalar fraction the per-slot threshold; membership resolves
        *in-kernel* from lat/lon against the code table (latlon mode), so
        no ``sidx``/``mask`` array ever materializes.  Tuples outside the
        table land in no slot — their stat rows stay zero (the query layer
        zeroes overflow before estimating) and the overflow *counts* are
        reconstructed as residuals against direct sums.
      * ``srs`` — exact ranks need the per-stratum sort, so stratify +
        :func:`~.sampling.srs_ranks` run outside; ranks vs ``n_k`` is the
        in-kernel compare (exact below 2**24) and sidx mode covers every
        slot, overflow included, exactly.
    """
    from ..kernels.edge_megakernel import edge_megakernel

    q = plan.query
    slots = table.num_slots
    kinds_map = plan.column_kind_map
    ext_idx, sk_idx = _kernel_layout(plan.columns, kinds_map)
    vals = _stack_staged(cfg, plan.columns, cols)
    okf = ok.astype(jnp.float32)[None]
    if q.method == "bernoulli":
        u = jax.random.uniform(key, lat.shape)
        thr = jnp.broadcast_to(jnp.asarray(fraction, jnp.float32), (1, slots))
        res = edge_megakernel(
            vals, okf, u[None], thr, slots,
            lat=lat, lon=lon, codes=table.codes, precision=table.precision,
            ext_idx=ext_idx, sk_idx=sk_idx,
        )
        n_sampled = jnp.sum((ok & (u < fraction)).astype(jnp.int32))
        counts = res.pop[0].astype(jnp.int32)
        counts = counts.at[-1].add(jnp.sum(ok.astype(jnp.int32)) - jnp.sum(counts))
        keep = res.keep[0].at[-1].add(
            n_sampled.astype(jnp.float32) - jnp.sum(res.keep[0])
        )
    else:
        sidx = jnp.where(
            ok, table.assign(lat, lon, backend=cfg.backend), table.num_strata
        )
        ranks, counts_all = sampling.srs_ranks(key, sidx, slots)
        n_k = sampling.allocate_proportional(counts_all, fraction)
        res = edge_megakernel(
            vals, okf,
            ranks.astype(jnp.float32)[None], n_k.astype(jnp.float32)[None],
            slots, sidx=sidx[None], ext_idx=ext_idx, sk_idx=sk_idx,
        )
        counts = res.pop[0].astype(jnp.int32)
        keep = res.keep[0]
        n_sampled = jnp.sum(keep).astype(jnp.int32)
    stats = _stats_from_mega(
        plan.columns, kinds_map, res, 0, keep, counts,
        plan.columns, ext_idx, sk_idx,
    )
    return _consolidate(plan, stats, n_sampled, ok, valid, counts, axes)


def _fused_edge_program(
    fused: aqp.FusedPlan,
    table: StratumTable,
    cfg: PipelineConfig,
    key,
    lat,
    lon,
    cols: Mapping[str, jnp.ndarray],
    valid,
    fractions,
    axes=None,
):
    """The *refined* fused edge pass: per-member nested samples from ONE
    shared stratify + randomness draw (preagg mode only).

    Where :func:`_edge_program` serves a whole fusion group from a single
    union accumulation at the group-max fraction, this program thins the
    shared sample to each member's **own** fraction — and, for Bernoulli
    groups, applies each member's **own** ROI as an accumulation mask —
    producing one ``{column: {kind: state}}`` pytree per member:

      * ``srs`` groups share the per-stratum random ranks
        (:func:`~.sampling.srs_ranks`): member m keeps
        ``ranks < n_k(fractions[m])``, which is *exactly* the SRS its
        independent ``execute`` would draw for the same key, and a subset
        of the group-max sample (nested Horvitz-Thompson subsampling — the
        estimators and :mod:`.bounds` intervals then reflect the member's
        effective fraction through the realized ``n_k``).  ``neyman`` is
        refused (its variance-optimal allocation needs per-stratum stddev
        threading; silently substituting proportional allocation would
        change the sampling design) — neyman groups stay on the shared
        group-max pass.
      * ``bernoulli`` groups share one per-tuple uniform draw: member m
        keeps ``u < fractions[m]`` within its own ROI.  Uniforms are
        stratum- and fraction-independent, so differing-ROI members fuse
        into this one pass (cross-signature fusion) and every member's
        sample is bit-identical to its independent draw.

    Returns ``(members_out, comm)`` with ``members_out[m] = (stats,
    n_sampled, n_valid, n_overflow)``.
    """
    shared = fused.shared
    q = shared.query
    if q.method not in ("srs", "bernoulli"):
        raise NotImplementedError(
            f"refined fused pass supports srs|bernoulli members, not "
            f"{q.method!r}; neyman allocation needs per-stratum stddev "
            "threading (its group keeps the shared group-max pass)"
        )
    if axes is not None:
        key = jax.random.fold_in(key, jax.lax.axis_index(axes))
    if cfg.backend == "fused" and all(_plan_fusable(p) for p in fused.members):
        return _fused_refined_mega(
            fused, table, cfg, key, lat, lon, cols, valid, fractions, axes
        )
    slots = table.num_slots
    sidx_raw = table.assign(lat, lon, backend=cfg.backend)
    members_out = []
    if q.method == "bernoulli":
        u = jax.random.uniform(key, lat.shape)
        for m, plan_m in enumerate(fused.members):
            ok = valid & aqp.roi_mask(plan_m, table, lat, lon)
            sidx = jnp.where(ok, sidx_raw, table.num_strata)
            mask = (u < fractions[m]) & ok
            counts = jax.ops.segment_sum(
                ok.astype(jnp.int32), sidx, num_segments=slots
            )
            members_out.append(
                _member_reduce(plan_m, table, cfg, cols, sidx, mask, ok, valid, counts, axes)
            )
    else:
        ok = valid & aqp.roi_mask(shared, table, lat, lon)
        sidx = jnp.where(ok, sidx_raw, table.num_strata)
        ranks, counts_all = sampling.srs_ranks(key, sidx, slots)
        counts = jax.ops.segment_sum(ok.astype(jnp.int32), sidx, num_segments=slots)
        for m, plan_m in enumerate(fused.members):
            # allocation over the raw per-slot counts, as edgesos does
            n_k = sampling.allocate_proportional(counts_all, fractions[m])
            mask = (ranks < n_k[sidx]) & ok
            members_out.append(
                _member_reduce(plan_m, table, cfg, cols, sidx, mask, ok, valid, counts, axes)
            )
    comm = jnp.int32(aqp.refined_preagg_bytes(fused, slots))
    return tuple(members_out), comm


def _fused_refined_mega(
    fused: aqp.FusedPlan, table: StratumTable, cfg: PipelineConfig, key,
    lat, lon, cols, valid, fractions, axes,
):
    """The refined fused pass as ONE megakernel traversal for ALL members.

    The kernel's member axis carries the per-member thresholds (Bernoulli:
    each member's fraction; SRS: each member's ``n_k`` allocation) and, for
    Bernoulli groups, each member's own ROI mask — so the window's value
    columns are read once for the whole fusion group instead of once per
    member.  Sampling semantics match :func:`_fused_edge_program`'s segment
    body decision-for-decision (same uniforms / ranks, same threshold
    compare); Bernoulli runs in latlon mode with the overflow-residual
    reconstruction documented on :func:`_fused_member_program`.
    """
    from ..kernels.edge_megakernel import edge_megakernel

    shared = fused.shared
    q = shared.query
    slots = table.num_slots
    members = fused.members
    m_count = len(members)
    fractions = jnp.asarray(fractions, jnp.float32)
    # union value-column layout: every member's stats slice out of one pass
    union_cols: list = []
    union_kinds: dict = {}
    for p in members:
        km = p.column_kind_map
        for c in p.columns:
            if c not in union_kinds:
                union_cols.append(c)
                union_kinds[c] = set()
            union_kinds[c] |= set(km[c])
    ext_idx, sk_idx = _kernel_layout(union_cols, union_kinds)
    vals = _stack_staged(cfg, union_cols, cols)
    members_out = []
    if q.method == "bernoulli":
        u = jax.random.uniform(key, lat.shape)
        ok_m = jnp.stack([valid & aqp.roi_mask(p, table, lat, lon) for p in members])
        scores = jnp.broadcast_to(u[None], (m_count,) + u.shape)
        thr = jnp.broadcast_to(fractions[:, None], (m_count, slots))
        res = edge_megakernel(
            vals, ok_m.astype(jnp.float32), scores, thr, slots,
            lat=lat, lon=lon, codes=table.codes, precision=table.precision,
            ext_idx=ext_idx, sk_idx=sk_idx,
        )
        for m, plan_m in enumerate(members):
            ok = ok_m[m]
            n_sampled = jnp.sum((ok & (u < fractions[m])).astype(jnp.int32))
            counts = res.pop[m].astype(jnp.int32)
            counts = counts.at[-1].add(jnp.sum(ok.astype(jnp.int32)) - jnp.sum(counts))
            keep = res.keep[m].at[-1].add(
                n_sampled.astype(jnp.float32) - jnp.sum(res.keep[m])
            )
            stats = _stats_from_mega(
                plan_m.columns, plan_m.column_kind_map, res, m, keep, counts,
                union_cols, ext_idx, sk_idx,
            )
            members_out.append(
                _consolidate(plan_m, stats, n_sampled, ok, valid, counts, axes)
            )
    else:  # srs: shared ROI + stratify + ranks, per-member n_k thresholds
        ok = valid & aqp.roi_mask(shared, table, lat, lon)
        sidx = jnp.where(
            ok, table.assign(lat, lon, backend=cfg.backend), table.num_strata
        )
        ranks, counts_all = sampling.srs_ranks(key, sidx, slots)
        thr = jnp.stack(
            [
                sampling.allocate_proportional(counts_all, fractions[m]).astype(jnp.float32)
                for m in range(m_count)
            ]
        )
        res = edge_megakernel(
            vals,
            jnp.broadcast_to(ok.astype(jnp.float32)[None], (m_count,) + ok.shape),
            jnp.broadcast_to(ranks.astype(jnp.float32)[None], (m_count,) + ranks.shape),
            thr, slots,
            sidx=jnp.broadcast_to(sidx[None], (m_count,) + sidx.shape),
            ext_idx=ext_idx, sk_idx=sk_idx,
        )
        for m, plan_m in enumerate(members):
            counts = res.pop[m].astype(jnp.int32)
            n_sampled = jnp.sum(res.keep[m]).astype(jnp.int32)
            stats = _stats_from_mega(
                plan_m.columns, plan_m.column_kind_map, res, m, res.keep[m],
                counts, union_cols, ext_idx, sk_idx,
            )
            members_out.append(
                _consolidate(plan_m, stats, n_sampled, ok, valid, counts, axes)
            )
    comm = jnp.int32(aqp.refined_preagg_bytes(fused, slots))
    return tuple(members_out), comm


def _stats_template(plan: Plan) -> dict:
    """Structure-only column -> {kind: state} tree for out_specs."""
    kinds_map = plan.column_kind_map
    return {c: estimators.accs_template(kinds_map[c]) for c in plan.columns}


def _result_template(plan: Plan) -> QueryResult:
    """Structure-only QueryResult (for shard_map out_specs trees)."""
    return QueryResult(
        estimates={a.key: AggEstimate(*(0,) * 7) for a in plan.query.aggs},
        stats=_stats_template(plan),
        n_sampled=0,
        n_valid=0,
        n_overflow=0,
        n_truncated=0,
        comm_bytes=0,
    )


class EdgeCloudPipeline:
    """Single-program query engine; optionally distributed over mesh axes."""

    def __init__(
        self,
        table: StratumTable,
        config: PipelineConfig = PipelineConfig(),
        mesh=None,
        axis_names: tuple[str, ...] = ("data",),
    ):
        self.table = table
        self.config = config
        self.mesh = mesh
        self.axis_names = axis_names
        # resolved uplink wire codec (None = dense analytic payload);
        # stateful codecs (delta) hand out per-stream instances via
        # for_stream(), so this is the *spec*, never a live DPCM state
        self.codec_spec = wirecodec.resolve_codec(config.uplink_codec)
        self._plans: dict[Query, Plan] = {}
        self._execs: dict[tuple[Query, bool], callable] = {}
        self._passes: dict[tuple[Plan, bool], callable] = {}
        self._refined_passes: dict[tuple, callable] = {}
        # jitted session emit paths, keyed by *finalize signature* (not by
        # query: two queries differing only in ROI/method/mode share one
        # compiled finalize) plus pane count / batch width: sessions share
        # these like _passes, so a fresh session over a warmed pipeline
        # pays no first-pane compile
        self._finalizers: dict[tuple, callable] = {}
        # compiled-program cache accounting, per cache family.  A "miss"
        # is a new trace+compile (or a fresh lowering for "plan"); during
        # steady-state tenant churn every family must hit — the
        # multitenant bench gates the miss delta at zero.
        self.cache_stats: dict[str, dict[str, int]] = {
            f: {"hits": 0, "misses": 0}
            for f in ("plan", "exec", "pass", "refined_pass", "finalize")
        }

    def _cache_event(self, family: str, hit: bool) -> None:
        self.cache_stats[family]["hits" if hit else "misses"] += 1

    @property
    def compile_count(self) -> int:
        """Total compiled-program cache misses across the jitted families
        (``plan`` lowerings are host-side and excluded).  The steady-state
        churn contract: this must not move while tenants register and
        unregister structurally-seen queries."""
        return sum(
            v["misses"] for f, v in self.cache_stats.items() if f != "plan"
        )

    def cache_snapshot(self) -> dict:
        """Copy of the per-family hit/miss counters plus the aggregate
        ``compile_count`` (surfaced through ``RuntimeStats``)."""
        return {
            "families": {f: dict(v) for f, v in self.cache_stats.items()},
            "compile_count": self.compile_count,
        }

    # -- declarative query API ----------------------------------------------

    def plan(self, query: Query) -> Plan:
        """Lower (and cache) a query against this pipeline's stratum table."""
        p = self._plans.get(query)
        self._cache_event("plan", p is not None)
        if p is None:
            p = aqp.lower(query, self.table)
            self._plans[query] = p
        return p

    def _compiled(self, plan: Plan, body, out_template, sharded: bool):
        """Jit ``body(key, lat, lon, cols, valid, fraction, axes=None)`` —
        directly, or wrapped in shard_map over the data axes (shards = edge
        nodes, replicated outputs shaped like ``out_template``)."""
        if not sharded:
            return jax.jit(body)
        axes = self.axis_names
        spec = P(axes)
        mapped = _shard_map(
            partial(body, axes=axes),
            mesh=self.mesh,
            in_specs=(P(), spec, spec, {c: spec for c in plan.columns}, spec, P()),
            out_specs=jax.tree.map(lambda _: P(), out_template),
            check_vma=False,
        )
        return jax.jit(mapped)

    def _query_fn(self, query: Query, sharded: bool):
        fn = self._execs.get((query, sharded))
        self._cache_event("exec", fn is not None)
        if fn is not None:
            return fn
        plan = self.plan(query)
        table, cfg = self.table, self.config

        def run(key, lat, lon, cols, valid, fraction, axes=None):
            stats, n_sampled, n_valid, n_overflow, n_truncated, comm = _edge_program(
                plan, table, cfg, key, lat, lon, cols, valid, fraction, axes=axes
            )
            return QueryResult(
                # bounds are deterministic in the window key: fused sessions
                # finalize the same stats with the same key bit-identically
                estimates=aqp.finalize(plan, table, stats, key=key),
                stats=stats,
                n_sampled=n_sampled,
                n_valid=n_valid,
                n_overflow=n_overflow,
                n_truncated=n_truncated,
                comm_bytes=comm,
            )

        fn = self._compiled(plan, run, _result_template(plan), sharded)
        self._execs[(query, sharded)] = fn
        return fn

    def _pass_fn(self, plan: Plan, sharded: bool):
        """Jitted *edge pass* for a lowered plan: stratify + EdgeSOS +
        accumulate + consolidating collective, **without** finalize.

        This is the shared half a :class:`~.session.StreamSession` runs once
        per fusion group and per pane: the returned per-column ``ColumnStats``
        feed any number of per-query finalizes (and pane merges) cloud-side.
        ``execute`` is the degenerate composition pass+finalize in one
        program.
        """
        fn = self._passes.get((plan, sharded))
        self._cache_event("pass", fn is not None)
        if fn is not None:
            return fn
        table, cfg = self.table, self.config

        def run(key, lat, lon, cols, valid, fraction, axes=None):
            return _edge_program(
                plan, table, cfg, key, lat, lon, cols, valid, fraction, axes=axes
            )

        template = (_stats_template(plan), 0, 0, 0, 0, 0)
        fn = self._compiled(plan, run, template, sharded)
        self._passes[(plan, sharded)] = fn
        return fn

    def _refined_pass_fn(self, fused: aqp.FusedPlan, sharded: bool):
        """Jitted *refined* fused pass: per-member nested/ROI-masked
        accumulator states from one shared stratify + randomness draw (see
        :func:`_fused_edge_program`).  Takes a ``(M,)`` per-member fraction
        vector in the fraction slot, so controller-driven fraction drift
        never recompiles.
        """
        cache_key = (fused.members, sharded)
        fn = self._refined_passes.get(cache_key)
        self._cache_event("refined_pass", fn is not None)
        if fn is not None:
            return fn
        table, cfg = self.table, self.config

        def run(key, lat, lon, cols, valid, fractions, axes=None):
            return _fused_edge_program(
                fused, table, cfg, key, lat, lon, cols, valid, fractions, axes=axes
            )

        template = (tuple((_stats_template(p), 0, 0, 0) for p in fused.members), 0)
        fn = self._compiled(fused.shared, run, template, sharded)
        self._refined_passes[cache_key] = fn
        return fn

    def _finalize_body(self, plan: Plan, num_panes: int):
        """``(stats, key) -> (estimates, merged)`` for one query's window:
        merge ``num_panes`` stacked pane accumulators (pass-through when the
        window is one pane, preserving bit-compatibility with ``execute``)
        and finalize."""
        table = self.table

        if num_panes == 1:

            def run(stats, bkey):
                return aqp.finalize(plan, table, stats, key=bkey), stats

        else:

            def run(stacked, bkey):
                merged = {
                    c: estimators.merge_accs_panes(stacked[c]) for c in plan.columns
                }
                return aqp.finalize(plan, table, merged, key=bkey), merged

        return run

    def finalize_fn(self, plan: Plan, num_panes: int):
        """Jitted cloud-side emit for one registration, cached by *finalize
        signature*: queries that differ only in sampling method / mode /
        ROI share one compiled program (finalize never reads those — see
        :func:`~.query.finalize_signature`)."""
        key = ("single", aqp.finalize_signature(plan), num_panes)
        fn = self._finalizers.get(key)
        self._cache_event("finalize", fn is not None)
        if fn is not None:
            return fn
        fn = jax.jit(self._finalize_body(plan, num_panes))
        self._finalizers[key] = fn
        return fn

    def batched_finalize_fn(self, plan: Plan, num_panes: int, batch: int):
        """Jitted *vmapped* finalize: one dispatch emits ``batch`` queries
        sharing a finalize signature (key broadcast — each row computes
        exactly what its singleton finalize would, so batching preserves
        bit-parity).  Takes the *list* of ``batch`` member window-stats
        pytrees; the leading-axis stack happens inside the compiled
        program — stacking op-by-op on the host costs one dispatch per
        leaf per batch, which is exactly the per-query overhead batching
        exists to amortize.  ``batch`` is the padded width (sessions pad
        to the next power of two so tenant churn steps through O(log Q)
        compiled widths, not one per group size)."""
        key = ("batched", aqp.finalize_signature(plan), num_panes, batch)
        fn = self._finalizers.get(key)
        self._cache_event("finalize", fn is not None)
        if fn is not None:
            return fn
        body = jax.vmap(self._finalize_body(plan, num_panes), in_axes=(0, None))

        def run(member_stats, bkey):
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *member_stats)
            return body(stacked, bkey)

        fn = jax.jit(run)
        self._finalizers[key] = fn
        return fn

    def _window_arrays(self, window, plan: Plan):
        """Host-side: split a WindowBatch / mapping into device inputs."""
        if isinstance(window, WindowBatch):
            cols = window.columns
            lat, lon, valid = window.lat, window.lon, window.valid
        else:
            cols = {k: v for k, v in window.items() if k not in ("lat", "lon", "valid")}
            lat, lon = window["lat"], window["lon"]
            valid = window.get("valid")
        lat = jnp.asarray(lat, jnp.float32)
        lon = jnp.asarray(lon, jnp.float32)
        valid = jnp.ones(lat.shape, bool) if valid is None else jnp.asarray(valid, bool)
        missing = [c for c in plan.columns if c not in cols]
        if missing:
            raise KeyError(f"window has no column(s) {missing}; available: {sorted(cols)}")
        cols = {c: jnp.asarray(cols[c], jnp.float32) for c in plan.columns}
        return lat, lon, cols, valid

    def _codec_rebase(self, plan: Plan, res: QueryResult, key) -> QueryResult:
        """Ship a one-shot query's consolidated states through the uplink
        codec: estimates re-finalize from the *decoded* states (bit-identical
        for lossless codecs — the property tests' contract) and
        ``comm_bytes`` becomes the frame's measured encoded bytes instead of
        the analytic dense model.  One-shot executes open a fresh stream, so
        a delta codec degenerates to a keyframe here."""
        stats, nbytes = wirecodec.roundtrip(self.codec_spec.for_stream(), res.stats)
        estimates, stats = self.finalize_fn(plan, 1)(stats, key)
        return res._replace(estimates=estimates, stats=stats, comm_bytes=nbytes)

    def execute(self, query: Query, key, window, fraction=1.0) -> QueryResult:
        """Evaluate a declarative query over one window on one edge node.

        ``window`` is a :class:`WindowBatch` or a mapping with ``lat``,
        ``lon``, optional ``valid``, and one array per referenced column.
        """
        plan = self.plan(query)
        lat, lon, cols, valid = self._window_arrays(window, plan)
        fn = self._query_fn(query, sharded=False)
        res = fn(key, lat, lon, cols, valid, jnp.float32(fraction))
        if self.codec_spec is not None and plan.query.mode == "preagg":
            res = self._codec_rebase(plan, res, key)
        # upstream drop accounting is a host-side property of the window
        return res._replace(n_dropped=int(getattr(window, "n_dropped", 0)))

    def execute_sharded(self, query: Query, key, window, fraction=1.0) -> QueryResult:
        """Distributed execute: shards = edge nodes, collective = uplink."""
        if self.mesh is None:
            raise ValueError("pipeline constructed without a mesh")
        plan = self.plan(query)
        lat, lon, cols, valid = self._window_arrays(window, plan)
        fn = self._query_fn(query, sharded=True)
        res = fn(key, lat, lon, cols, valid, jnp.float32(fraction))
        if self.codec_spec is not None and plan.query.mode == "preagg":
            res = self._codec_rebase(plan, res, key)
        return res._replace(n_dropped=int(getattr(window, "n_dropped", 0)))

    # -- legacy single-estimate API (shim over the canonical query) ---------

    def _canonical_query(self, mode: str = "preagg") -> Query:
        """The fixed query the pre-redesign API answered: SUM/MEAN(value)."""
        return Query(
            aggs=(AggSpec("sum", "value"), AggSpec("mean", "value")),
            confidence=self.config.confidence,
            method=self.config.method,
            mode=mode,
        )

    @partial(jax.jit, static_argnums=(0,))
    def process_window(self, key, lat, lon, value, valid, fraction) -> WindowResult:
        plan = self.plan(self._canonical_query())
        stats, n_sampled, n_valid, n_overflow, _trunc, comm = _edge_program(
            plan, self.table, self.config, key, lat, lon, {"value": value}, valid, fraction
        )
        base = stats["value"]["moments"]
        est = estimators.estimate(_zero_overflow(base), self.config.confidence)
        # a moment-only single-column plan ships exactly the legacy payload
        return WindowResult(
            estimate=est,
            stats=base,
            n_sampled=n_sampled,
            n_valid=n_valid,
            n_overflow=n_overflow,
            comm_bytes=comm,
        )

    def process_window_sharded(self, key, lat, lon, value, valid, fraction) -> WindowResult:
        """Legacy distributed API: shim over the canonical query's sharded
        plan (one edge program for both paths), honoring ``config.mode``."""
        if self.mesh is None:
            raise ValueError("pipeline constructed without a mesh")
        fn = self._query_fn(self._canonical_query(mode=self.config.mode), sharded=True)
        res = fn(
            key, lat, lon, {"value": value}, jnp.asarray(valid), jnp.float32(fraction)
        )
        base = res.stats["value"]["moments"]
        est = estimators.estimate(_zero_overflow(base), self.config.confidence)
        # moment-only single-column plans ship the legacy payloads in both
        # modes (preagg 4 vectors, raw 9 bytes/slot), so comm passes through
        return WindowResult(
            estimate=est,
            stats=base,
            n_sampled=res.n_sampled,
            n_valid=res.n_valid,
            n_overflow=res.n_overflow,
            comm_bytes=res.comm_bytes,
        )

    # -- continuous query loop (Algorithm 2) ---------------------------------

    def run_stream(
        self,
        windows,
        slo: feedback.SLO | None = None,
        initial_fraction: float = 0.8,
        key=None,
        sharded: bool = False,
        query: Query | None = None,
    ):
        """Process a stream of WindowBatch under the QoS feedback loop.

        With ``query`` set this is a thin shim over a single-query
        :class:`~.session.StreamSession` (one registered tumbling
        one-pane query): the controller tracks the relative error of the
        query's first *error-bounded* aggregate (sum/mean/var/quantile —
        exact count and one-sided min/max bounds don't drive it).  Grouped queries
        are driven by the worst group with a finite RE (empty groups report
        inf).  A query with no sum/mean aggregate keeps the fraction fixed.
        Register several queries on a session directly to share one
        sampling pass across all of them.
        """
        slo = slo or feedback.SLO()
        key = key if key is not None else jax.random.key(0)  # edgelint: ignore[EDG001] fixed default seed for driverless runs
        if query is not None:
            from .session import StreamSession  # session sits above pipeline

            sess = StreamSession(self, sharded=sharded, initial_fraction=initial_fraction)
            reg = sess.register(query, slo=slo)
            history = []
            for w in windows:
                key, sub = jax.random.split(key)
                step = sess.step(sub, w)
                history.append((step.results[reg.qid], step.fractions[reg.qid]))
            return history, sess.controller_state(reg)
        state = feedback.init_state(initial_fraction)
        history = []
        for w in windows:
            key, sub = jax.random.split(key)
            fn = self.process_window_sharded if sharded else self.process_window
            res = fn(
                sub,
                jnp.asarray(w.lat, jnp.float32),
                jnp.asarray(w.lon, jnp.float32),
                jnp.asarray(w.value, jnp.float32),
                jnp.asarray(w.valid),
                state.fraction,
            )
            state = feedback.update(state, res.estimate.relative_error, res.n_valid, slo)
            # keep the controller fraction device-lazy: a float() here would
            # block every pane on the previous pane's device work
            history.append((res, state.fraction))
        # one host sync at the stream boundary instead of one per pane
        fracs = jax.device_get([f for _, f in history])  # edgelint: ignore[EDG002] single end-of-stream readback
        history = [(res, float(f)) for (res, _), f in zip(history, fracs)]  # edgelint: ignore[EDG002] floats already on host via device_get
        return history, state
