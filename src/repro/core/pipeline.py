"""EdgeApproxGeo end-to-end workflow (paper Algorithm 2).

Edge tier  = the mesh shards along the data axes: each shard independently
             stratifies + samples its local window (EdgeSOS — no cross-shard
             communication in the sampling path).
Cloud tier = the post-collective computation: stratified estimators with
             error bounds, plus the QoS feedback controller.

Two transmission modes (paper §3.6.4), chosen per query:
  * 'preagg' — shards reduce to per-stratum moments, one psum of O(S)
    floats crosses the interconnect.  This is the default and the paper's
    bandwidth-saving mode.
  * 'raw'    — shards compact kept tuples into a padded buffer and
    all-gather it (the "ship sampled raw tuples" mode).  Collective bytes
    scale with the kept sample, not with strata.

Both modes produce identical estimates for the same sample (tested).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import estimators, feedback, sampling
from .estimators import Estimate, StratumStats
from .sampling import SampleResult
from .stratify import StratumTable


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    method: str = "srs"  # srs | bernoulli | neyman
    mode: str = "preagg"  # preagg | raw
    confidence: float = 0.95
    raw_capacity: int | None = None  # static per-shard buffer for raw mode


class WindowResult(NamedTuple):
    estimate: Estimate
    stats: StratumStats
    n_sampled: jnp.ndarray
    n_valid: jnp.ndarray
    n_overflow: jnp.ndarray  # tuples outside the region of interest
    comm_bytes: jnp.ndarray  # analytic edge->cloud payload size of this mode


def _zero_overflow(stats: StratumStats) -> StratumStats:
    """Remove the out-of-region slot from estimation (kept in aux only)."""
    keep = jnp.arange(stats.n.shape[0]) < (stats.n.shape[0] - 1)

    def z(x):
        return jnp.where(keep, x, 0.0)

    return StratumStats(n=z(stats.n), total=z(stats.total), wsum=z(stats.wsum), m2=z(stats.m2), mean=z(stats.mean))


def edge_sample(
    key,
    table: StratumTable,
    lat: jnp.ndarray,
    lon: jnp.ndarray,
    valid: jnp.ndarray,
    fraction,
    method: str,
    stddev: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, SampleResult]:
    """Edge-local half of Algorithm 2: stratify + EdgeSOS sample."""
    sidx = table.assign(lat, lon)
    sidx = jnp.where(valid, sidx, table.num_strata)  # padding -> overflow
    result = sampling.edgesos(
        key, sidx, table.num_slots, fraction, method=method, stddev=stddev
    )
    mask = result.mask & valid
    weight = jnp.where(valid, result.weight, 0.0)
    # population counts must also exclude padding
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), sidx, num_segments=table.num_slots
    )
    n_k = jax.ops.segment_sum(mask.astype(jnp.int32), sidx, num_segments=table.num_slots)
    return sidx, SampleResult(mask=mask, weight=weight, n_k=n_k, counts=counts)


class EdgeCloudPipeline:
    """Single-program pipeline; optionally distributed over mesh data axes."""

    def __init__(
        self,
        table: StratumTable,
        config: PipelineConfig = PipelineConfig(),
        mesh=None,
        axis_names: tuple[str, ...] = ("data",),
    ):
        self.table = table
        self.config = config
        self.mesh = mesh
        self.axis_names = axis_names
        if mesh is not None:
            self._sharded = self._build_sharded()

    # -- single-shard ("one edge node") path --------------------------------

    @partial(jax.jit, static_argnums=(0,))
    def process_window(self, key, lat, lon, value, valid, fraction) -> WindowResult:
        table, cfg = self.table, self.config
        sidx, sample = edge_sample(key, table, lat, lon, valid, fraction, cfg.method)
        stats = estimators.sample_stats(
            value, sidx, sample.mask, table.num_slots, counts=sample.counts
        )
        est_stats = _zero_overflow(stats)
        est = estimators.estimate(est_stats, cfg.confidence)
        comm = jnp.int32(4 * 4 * table.num_slots)  # preagg payload (bytes)
        return WindowResult(
            estimate=est,
            stats=stats,
            n_sampled=jnp.sum(sample.mask.astype(jnp.int32)),
            n_valid=jnp.sum(valid.astype(jnp.int32)),
            n_overflow=sample.counts[-1],
            comm_bytes=comm,
        )

    # -- distributed path ----------------------------------------------------

    def _build_sharded(self):
        table, cfg, axes = self.table, self.config, self.axis_names
        spec = P(axes)

        def shard_fn(key, lat, lon, value, valid, fraction):
            # per-shard independent PRNG: fold in the shard's linear index
            idx = jax.lax.axis_index(axes)
            key = jax.random.fold_in(key, idx)
            sidx, sample = edge_sample(key, table, lat, lon, valid, fraction, cfg.method)
            if cfg.mode == "preagg":
                local = estimators.sample_stats(
                    value, sidx, sample.mask, table.num_slots, counts=sample.counts
                )
                stats = estimators.psum_stats(local, axes)
                comm = jnp.int32(4 * 4 * table.num_slots)
            else:
                cap = cfg.raw_capacity or lat.shape[0]
                v_ok, v_sidx, v_val = sampling.compact(sample.mask, cap, sidx, value)
                g_ok = jax.lax.all_gather(v_ok, axes, tiled=True)
                g_sidx = jax.lax.all_gather(v_sidx, axes, tiled=True)
                g_val = jax.lax.all_gather(v_val, axes, tiled=True)
                counts = jax.lax.psum(sample.counts, axes)
                stats = estimators.sample_stats(
                    g_val, g_sidx, g_ok, table.num_slots, counts=counts
                )
                comm = jnp.int32(cap * (4 + 4 + 1))
            est = estimators.estimate(_zero_overflow(stats), cfg.confidence)
            return WindowResult(
                estimate=est,
                stats=stats,
                n_sampled=jax.lax.psum(jnp.sum(sample.mask.astype(jnp.int32)), axes),
                n_valid=jax.lax.psum(jnp.sum(valid.astype(jnp.int32)), axes),
                n_overflow=jax.lax.psum(sample.counts[-1], axes),
                comm_bytes=comm,
            )

        mapped = jax.shard_map(
            shard_fn,
            mesh=self.mesh,
            in_specs=(P(), spec, spec, spec, spec, P()),
            out_specs=jax.tree.map(lambda _: P(), WindowResult(
                estimate=Estimate(*(0,) * 10), stats=StratumStats(*(0,) * 5),
                n_sampled=0, n_valid=0, n_overflow=0, comm_bytes=0)),
            check_vma=False,
        )
        return jax.jit(mapped)

    def process_window_sharded(self, key, lat, lon, value, valid, fraction) -> WindowResult:
        if self.mesh is None:
            raise ValueError("pipeline constructed without a mesh")
        return self._sharded(key, lat, lon, value, valid, jnp.float32(fraction))

    # -- continuous query loop (Algorithm 2) ---------------------------------

    def run_stream(
        self,
        windows,
        slo: feedback.SLO | None = None,
        initial_fraction: float = 0.8,
        key=None,
        sharded: bool = False,
    ):
        """Process a stream of WindowBatch under the QoS feedback loop."""
        slo = slo or feedback.SLO()
        key = key if key is not None else jax.random.key(0)
        state = feedback.init_state(initial_fraction)
        history = []
        for i, w in enumerate(windows):
            key, sub = jax.random.split(key)
            fn = self.process_window_sharded if sharded else self.process_window
            res = fn(
                sub,
                jnp.asarray(w.lat, jnp.float32),
                jnp.asarray(w.lon, jnp.float32),
                jnp.asarray(w.value, jnp.float32),
                jnp.asarray(w.valid),
                state.fraction,
            )
            state = feedback.update(state, res.estimate.relative_error, res.n_valid, slo)
            history.append((res, float(state.fraction)))
        return history, state
