"""EdgeSOS: decentralized, geohash-stratified online sampling (Algorithm 1).

Each edge node (here: each mesh shard) independently partitions its local
window into geohash strata, computes per-stratum target sizes, and draws a
Simple Random Sample within every stratum — no cross-node synchronization.

TPU adaptation.  The paper's Rust implementation groups tuples into per-
stratum Vecs (rayon-parallel hashmap grouping) and then subsamples each Vec.
Dynamic per-stratum buffers don't exist on TPU, so EdgeSOS is re-derived in
fixed-shape form:

  * exact SRS: draw one uniform per tuple, group tuples by stratum with a
    stable sort, rank tuples inside their stratum, keep ``rank < n_k``.
    This is *exactly* an SRS of size ``n_k`` within each stratum (every
    subset of size ``n_k`` equally likely) and costs one O(N log N) device
    sort — the analogue of rayon's parallel grouping, executed by the TPU's
    sort unit instead of a thread pool.
  * bernoulli: keep tuples iid with per-stratum probability ``f_k``; cheaper
    (no sort), sample sizes are random.  Horvitz-Thompson weights keep the
    estimators unbiased in both modes.

The sample is a fixed-shape (mask, weight) pair: downstream consumers either
use the mask directly (weighted reductions — zero extra memory traffic) or
``compact`` kept tuples to a padded buffer (the "raw transmission" mode).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SampleResult(NamedTuple):
    """Fixed-shape stratified sample.

    mask: (N,) bool — tuple kept?
    weight: (N,) f32 — Horvitz-Thompson weight (N_k/n_k or 1/f_k); 0 if dropped.
    n_k: (S+1,) i32 — realized per-stratum sample sizes.
    counts: (S+1,) i32 — per-stratum population sizes N_k of this window.
    """

    mask: jnp.ndarray
    weight: jnp.ndarray
    n_k: jnp.ndarray
    counts: jnp.ndarray


def stratum_counts(stratum_idx: jnp.ndarray, num_slots: int) -> jnp.ndarray:
    """Per-stratum population counts N_k (including overflow slot)."""
    return jax.ops.segment_sum(
        jnp.ones_like(stratum_idx, dtype=jnp.int32), stratum_idx, num_segments=num_slots
    )


def allocate_proportional(counts: jnp.ndarray, fraction) -> jnp.ndarray:
    """Paper's allocation: n_k = round(f * N_k), clipped to [0, N_k].

    ``fraction`` may be a scalar or a per-stratum vector (adaptive mode).
    """
    target = jnp.round(counts.astype(jnp.float32) * fraction)
    return jnp.clip(target.astype(jnp.int32), 0, counts)


def allocate_neyman(
    counts: jnp.ndarray, stddev: jnp.ndarray, fraction, min_per_stratum: int = 1
) -> jnp.ndarray:
    """Neyman (variance-optimal) allocation — beyond-paper option.

    n_k proportional to N_k * s_k at the same total budget f * N.  Falls back
    to proportional where variance info is degenerate.
    """
    counts_f = counts.astype(jnp.float32)
    total_budget = jnp.sum(counts_f) * fraction
    score = counts_f * jnp.maximum(stddev, 0.0)
    denom = jnp.sum(score)
    prop = jnp.where(denom > 0, score / jnp.maximum(denom, 1e-30), counts_f / jnp.maximum(jnp.sum(counts_f), 1.0))
    target = jnp.round(total_budget * prop).astype(jnp.int32)
    target = jnp.maximum(target, jnp.minimum(counts, min_per_stratum))
    return jnp.clip(target, 0, counts)


def _rank_within_stratum(key, stratum_idx: jnp.ndarray, num_slots: int):
    """Random rank of each tuple within its stratum.

    Returns (ranks, counts).  ranks[i] is uniform over {0..N_k-1} within
    stratum k — the order statistic that turns thresholding into exact SRS.
    """
    n = stratum_idx.shape[0]
    u = jax.random.uniform(key, (n,))
    # Stable sort by stratum after a random shuffle => random order inside
    # each stratum, strata contiguous.
    shuffle = jnp.argsort(u)
    s_shuffled = stratum_idx[shuffle]
    order = jnp.argsort(s_shuffled, stable=True)
    perm = shuffle[order]  # original indices, grouped by stratum
    s_sorted = stratum_idx[perm]
    counts = stratum_counts(stratum_idx, num_slots)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - starts[s_sorted]
    ranks = jnp.zeros((n,), dtype=jnp.int32).at[perm].set(ranks_sorted)
    return ranks, counts


def srs_ranks(key, stratum_idx: jnp.ndarray, num_slots: int):
    """The shared randomness of nested SRS: ``(ranks, counts)``.

    ``ranks`` depends only on ``(key, stratum_idx)`` — never on the
    fraction — so ``ranks < allocate_proportional(counts, f)[stratum_idx]``
    is *exactly* the sample :func:`srs_sample` draws at fraction ``f`` for
    the same key, and the keep-sets are nested in ``f`` (``n_k`` is
    monotone in the fraction).  One rank vector therefore serves every
    member of a fused pass at its *own* fraction: thinning the shared
    sample to a lower-fraction member's target is Horvitz-Thompson
    subsampling (the nested SRS of per-query fraction refinement), and the
    refined sample is bit-identical to the member's independent draw.
    """
    return _rank_within_stratum(key, stratum_idx, num_slots)


def srs_sample(
    key, stratum_idx: jnp.ndarray, num_slots: int, n_k: jnp.ndarray, counts: jnp.ndarray
) -> SampleResult:
    """Exact within-stratum SRS with target sizes n_k (fixed shapes)."""
    ranks, _ = _rank_within_stratum(key, stratum_idx, num_slots)
    mask = ranks < n_k[stratum_idx]
    w_k = jnp.where(n_k > 0, counts.astype(jnp.float32) / jnp.maximum(n_k, 1).astype(jnp.float32), 0.0)
    weight = jnp.where(mask, w_k[stratum_idx], 0.0)
    return SampleResult(mask=mask, weight=weight, n_k=n_k, counts=counts)


def bernoulli_sample(
    key, stratum_idx: jnp.ndarray, num_slots: int, fraction, backend: str = "segment"
) -> SampleResult:
    """Per-stratum Bernoulli(f_k) sampling (no sort; random n_k).

    The per-tuple uniforms depend only on ``(key, N)`` — not on stratum
    membership or the fraction — so one draw nests every fraction
    (``u < f'`` is a subset of ``u < f`` for ``f' <= f``) and is oblivious
    to ROI-induced stratum reassignment: the properties behind per-query
    fraction refinement and cross-signature Bernoulli fusion in the
    session layer.

    ``backend="pallas"`` routes the fused gather+threshold+weight step
    through the ``kernels/sample_mask`` one-hot MXU kernel on TPU (same
    uniforms, so the sampling decisions are bit-identical); elsewhere it
    falls back to this segment implementation.
    """
    counts = stratum_counts(stratum_idx, num_slots)
    frac_k = jnp.broadcast_to(jnp.asarray(fraction, jnp.float32), (num_slots,))
    u = jax.random.uniform(key, stratum_idx.shape)
    if backend == "pallas" and jax.default_backend() == "tpu":
        from ..kernels.sample_mask import sample_mask as _kernel

        mask, weight = _kernel(stratum_idx, u, frac_k)
    else:
        mask = u < frac_k[stratum_idx]
        weight = jnp.where(mask, 1.0 / jnp.maximum(frac_k[stratum_idx], 1e-9), 0.0)
    n_k = jax.ops.segment_sum(mask.astype(jnp.int32), stratum_idx, num_segments=num_slots)
    return SampleResult(mask=mask, weight=weight, n_k=n_k, counts=counts)


def edgesos(
    key,
    stratum_idx: jnp.ndarray,
    num_slots: int,
    fraction,
    *,
    method: str = "srs",
    stddev: jnp.ndarray | None = None,
    min_per_stratum: int = 1,
    backend: str = "segment",
) -> SampleResult:
    """Algorithm 1 (EdgeSOS): stratified sample of one window.

    Args:
      key: PRNG key (per edge node / per window — never shared across nodes).
      stratum_idx: (N,) int32 stratum of each tuple (from StratumTable.assign).
      num_slots: static S+1.
      fraction: scalar or per-stratum sampling fraction in (0, 1].
      method: 'srs' (paper-faithful exact SRS) | 'bernoulli' | 'neyman'.
      stddev: per-stratum std estimates (required for 'neyman').
      backend: 'segment' | 'pallas' (fused Bernoulli selection kernel on TPU).
    """
    if method == "bernoulli":
        return bernoulli_sample(key, stratum_idx, num_slots, fraction, backend=backend)
    counts = stratum_counts(stratum_idx, num_slots)
    if method == "srs":
        n_k = allocate_proportional(counts, fraction)
    elif method == "neyman":
        if stddev is None:
            raise ValueError("neyman allocation requires per-stratum stddev")
        n_k = allocate_neyman(counts, stddev, fraction, min_per_stratum)
    else:
        raise ValueError(f"unknown method {method!r}")
    return srs_sample(key, stratum_idx, num_slots, n_k, counts)


def compact(mask: jnp.ndarray, max_out: int, *arrays: jnp.ndarray):
    """Gather kept tuples to the front of a padded (max_out, ...) buffer.

    Implements the paper's "raw sampled data transmission" mode with static
    shapes: kept tuples first (original relative order), padding after.
    Returns (valid, gathered...) where valid is a (max_out,) bool mask.
    """
    n = mask.shape[0]
    take = min(max_out, n)
    order = jnp.argsort(~mask, stable=True)  # kept tuples first
    kept = jnp.sum(mask.astype(jnp.int32))
    idx = order[:take]
    valid = jnp.arange(max_out, dtype=jnp.int32) < jnp.minimum(kept, take)

    def gather(a):
        g = a[idx]
        if max_out > n:  # buffer larger than window: pad the tail
            g = jnp.concatenate(
                [g, jnp.zeros((max_out - n,) + a.shape[1:], a.dtype)], axis=0
            )
        return jnp.where(valid.reshape((max_out,) + (1,) * (a.ndim - 1)), g, jnp.zeros_like(g))

    return (valid,) + tuple(gather(a) for a in arrays)
