"""Stratum tables: the spatial model of the paper.

The area of interest is a regular grid of geohash cells ("strata").  The
paper's edge binary maps each tuple's geohash to a stratum and to a coarser
"neighborhood" via a precomputed inverted hashmap (O(1) FxHash lookup).

TPU adaptation: hash maps don't vectorize; we keep a *sorted* table of cell
codes and resolve membership with ``searchsorted`` (O(log S), fully
vectorized, MXU/VPU friendly), then express neighborhood lookup as a dense
O(1) gather from a precomputed ``stratum -> neighborhood`` int array — the
moral equivalent of the paper's inverted map, laid out for SIMD.

Out-of-region tuples map to a dedicated overflow stratum (index ``S``), so
every downstream segment op uses the static size ``S + 1``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from . import geohash


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StratumTable:
    """Static table of geohash strata covering a region of interest.

    Attributes:
      codes: (S,) uint64, sorted geohash codes of the in-region cells.
      neighborhood: (S + 1,) int32, neighborhood id per stratum; the final
        entry is the overflow stratum's neighborhood (``num_neighborhoods``,
        i.e. its own catch-all).
      precision: geohash precision of the strata (static).
      neighborhood_precision: coarser precision defining neighborhoods.
      num_neighborhoods: static count of distinct in-region neighborhoods.
    """

    codes: jnp.ndarray
    neighborhood: jnp.ndarray
    precision: int = dataclasses.field(metadata=dict(static=True))
    neighborhood_precision: int = dataclasses.field(metadata=dict(static=True))
    num_neighborhoods: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_strata(self) -> int:
        return int(self.codes.shape[0])

    @property
    def num_slots(self) -> int:
        """Strata + 1 overflow slot; the static segment count downstream."""
        return self.num_strata + 1

    def lookup(self, codes: jnp.ndarray) -> jnp.ndarray:
        """Map geohash codes -> stratum index in [0, S]; S = out-of-region."""
        idx = jnp.searchsorted(self.codes, codes)
        idx = jnp.clip(idx, 0, self.num_strata - 1)
        hit = self.codes[idx] == codes
        return jnp.where(hit, idx, self.num_strata).astype(jnp.int32)

    def assign(
        self, lat: jnp.ndarray, lon: jnp.ndarray, backend: str = "segment"
    ) -> jnp.ndarray:
        """Coordinates -> stratum index (encode + table lookup).

        ``backend="pallas"`` routes the geohash encode through the fused
        quantize+Morton Pallas kernel on TPU (bit-identical to the jnp
        encoder, which remains the path everywhere else).
        """
        if backend == "pallas" and jax.default_backend() == "tpu":
            from ..kernels.geohash import geohash_encode

            codes = geohash_encode(lat, lon, self.precision)
        else:
            codes = geohash.encode(lat, lon, self.precision)
        return self.lookup(codes)

    def neighborhood_of(self, stratum_idx: jnp.ndarray) -> jnp.ndarray:
        """O(1) gather: stratum index -> neighborhood id."""
        return self.neighborhood[stratum_idx]


def make_table(
    lat_range: tuple[float, float],
    lon_range: tuple[float, float],
    precision: int,
    neighborhood_precision: int | None = None,
) -> StratumTable:
    """Enumerate the geohash cells covering a bounding box (host side).

    This is the paper's "area of interest divided into a regular grid of
    fixed-sized adjacent non-overlapping cells".  Built once at launch, then
    used read-only on device.
    """
    if neighborhood_precision is None:
        neighborhood_precision = max(1, precision - 2)
    if neighborhood_precision > precision:
        raise ValueError("neighborhood_precision must be <= precision")
    lat_lo, lat_hi = lat_range
    lon_lo, lon_hi = lon_range
    lon_bits, lat_bits = geohash.split_bits(precision)
    lat_cell = (geohash.LAT_MAX - geohash.LAT_MIN) / (1 << lat_bits)
    lon_cell = (geohash.LON_MAX - geohash.LON_MIN) / (1 << lon_bits)
    lat_i0 = int(np.floor((lat_lo - geohash.LAT_MIN) / lat_cell))
    lat_i1 = int(np.floor((lat_hi - geohash.LAT_MIN) / lat_cell - 1e-12))
    lon_i0 = int(np.floor((lon_lo - geohash.LON_MIN) / lon_cell))
    lon_i1 = int(np.floor((lon_hi - geohash.LON_MIN) / lon_cell - 1e-12))
    lat_idx = np.arange(lat_i0, lat_i1 + 1, dtype=np.uint32)
    lon_idx = np.arange(lon_i0, lon_i1 + 1, dtype=np.uint32)
    lon_grid, lat_grid = np.meshgrid(lon_idx, lat_idx)
    codes = np.asarray(
        geohash.interleave(jnp.asarray(lon_grid.reshape(-1)), jnp.asarray(lat_grid.reshape(-1)), precision)
    )
    codes = np.sort(codes.astype(np.uint32))
    parents = np.asarray(geohash.parent(jnp.asarray(codes), precision, neighborhood_precision))
    uniq, inv = np.unique(parents, return_inverse=True)
    neighborhood = np.concatenate([inv.astype(np.int32), np.array([len(uniq)], dtype=np.int32)])
    return StratumTable(
        codes=jnp.asarray(codes),
        neighborhood=jnp.asarray(neighborhood),
        precision=precision,
        neighborhood_precision=neighborhood_precision,
        num_neighborhoods=int(len(uniq)),
    )


def make_table_from_codes(
    codes: Sequence[int] | np.ndarray,
    precision: int,
    neighborhood_precision: int | None = None,
) -> StratumTable:
    """Build a table from an explicit set of geohash codes (e.g. observed)."""
    if neighborhood_precision is None:
        neighborhood_precision = max(1, precision - 2)
    codes = np.unique(np.asarray(codes, dtype=np.uint32))
    parents = np.asarray(geohash.parent(jnp.asarray(codes), precision, neighborhood_precision))
    uniq, inv = np.unique(parents, return_inverse=True)
    neighborhood = np.concatenate([inv.astype(np.int32), np.array([len(uniq)], dtype=np.int32)])
    return StratumTable(
        codes=jnp.asarray(codes),
        neighborhood=jnp.asarray(neighborhood),
        precision=precision,
        neighborhood_precision=neighborhood_precision,
        num_neighborhoods=int(len(uniq)),
    )


# Bounding boxes used across examples/benchmarks (approximate city extents).
SHENZHEN_BBOX = ((22.44, 22.87), (113.75, 114.65))
CHICAGO_BBOX = ((41.62, 42.05), (-87.95, -87.50))
