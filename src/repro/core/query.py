"""Declarative AQP query layer: specs lowered to edge/cloud plans.

This is the repo's analogue of EdgeLake's distributed-query split (remote
query -> per-operator partial aggregates -> local consolidation query) and
of StreamApprox-style approximate stream analytics: a :class:`Query` is a
declarative bundle of aggregates over named value columns, and
:func:`lower` turns it into a :class:`Plan` with two halves:

  * an **edge partial-aggregation program** — stratify + EdgeSOS sample the
    local window, then reduce each referenced column to a mergeable
    :class:`~.estimators.ColumnStats` accumulator (per stratum).  Every
    accumulator field merges exactly across shards: additive moments via the
    Chan-et-al. decomposition, extrema via min/max lattices.
  * a **cloud consolidation/finalize step** — combine shard partials (one
    ``psum``/``pmin``/``pmax`` in ``preagg`` mode, or re-aggregation of
    all-gathered raw tuples in ``raw`` mode) and evaluate each
    :class:`AggSpec` into an :class:`AggEstimate`, optionally grouped by
    stratum or neighborhood.

Both transmission modes produce identical estimates for the same sample,
per aggregate kind (tested in ``tests/test_query.py``).

Supported aggregate kinds and their error semantics (every kind reports a
``(ci_low, ci_high, relative_error)`` sampling-error interval, derived
cloud-side from the shipped sufficient statistics — see :mod:`.bounds`):

  sum / mean   stratified estimators with eq 5-10 variance / CI / MoE
               (lonely-singleton strata borrow spread, see
               :func:`~.estimators.guarded_s2`);
  count        in-region population count — exact per window (population
               counts are observed, not sampled), MoE 0;
  var          plug-in population variance (within + between stratum
               decomposition over the sample); CI from the stratified
               parametric bootstrap over the merged moment rows;
  min / max    sample extrema with one-sided order-statistic + Cantelli
               bounds (a sample extreme bounds the population extreme
               from inside; the rank slack of the per-stratum sampling
               fractions bounds it from outside);
  p<q>         quantiles (``p50``, ``p99``, ``p99.9`` …) from the mergeable
               per-stratum log-histogram sketch, Horvitz-Thompson-expanded
               per stratum at finalize (~4% relative value accuracy); CI
               from the collapsed stratified multinomial bootstrap over
               the bin rows.

Bounds of the resampling families are deterministic in the finalize PRNG
key and sized by ``Query.bootstrap_replicates`` (0 disables them, falling
back to zero-width point estimates).

Each aggregate kind lowers to a set of **accumulator kinds** from the
registry in :mod:`.estimators` (``moments`` | ``extrema`` | ``sketch`` |
anything registered later); a plan carries, per referenced column, the
union of the kinds its aggregates need — the edge accumulates exactly
those states, nothing more.
"""

from __future__ import annotations

import dataclasses
import re
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import estimators, geohash
from .estimators import ColumnStats, z_value
from .stratify import StratumTable

KINDS = ("sum", "mean", "count", "min", "max", "var")
GROUP_KEYS = (None, "stratum", "neighborhood")
METHODS = ("srs", "bernoulli", "neyman")

# Registry accumulator kinds each aggregate kind needs on the edge.  Every
# column carries "moments" (n/total back coverage accounting and the
# Horvitz-Thompson expansion of the other kinds' finalizes); extrema ride on
# the min/max lattices, quantiles on the mergeable log-histogram sketch.
ACCUMULATOR_KINDS: dict[str, tuple[str, ...]] = {
    "sum": ("moments",),
    "mean": ("moments",),
    "var": ("moments",),
    "count": ("moments",),
    "min": ("moments", "extrema"),
    "max": ("moments", "extrema"),
}

_QUANTILE_RE = re.compile(r"p(\d{1,2}(?:\.\d+)?)")


def quantile_of(kind: str) -> float | None:
    """The quantile in (0, 1) of a ``p<q>`` aggregate kind, else None."""
    m = _QUANTILE_RE.fullmatch(kind)
    if not m:
        return None
    q = float(m.group(1)) / 100.0
    return q if 0.0 < q < 1.0 else None


def agg_accumulator_kinds(kind: str) -> tuple[str, ...]:
    """Registry kinds an aggregate kind's edge program must accumulate."""
    if quantile_of(kind) is not None:
        return ("moments", "sketch")
    return ACCUMULATOR_KINDS[kind]


class AggSpec(NamedTuple):
    """One aggregate: ``kind`` over a named value column.

    ``name`` keys the result dict; defaults to ``"<kind>_<column>"``.
    """

    kind: str
    column: str = "value"
    name: str | None = None

    @property
    def key(self) -> str:
        return self.name or f"{self.kind}_{self.column}"


@dataclasses.dataclass(frozen=True)
class Query:
    """Declarative AQP query over one stream window.

    Attributes:
      aggs: the aggregates to evaluate (tuple of :class:`AggSpec`).
      group_by: ``None`` (one global answer), ``"stratum"`` or
        ``"neighborhood"`` (one answer per group, vector-shaped results).
      roi: optional region-of-interest filter — a bbox
        ``((lat_lo, lat_hi), (lon_lo, lon_hi))`` or a geohash prefix string;
        tuples outside the ROI are excluded from every aggregate (they land
        in the overflow slot and are reported as ``n_overflow``).
      confidence: CI level for the stratified estimators.
      method: EdgeSOS sampling method (``srs | bernoulli | neyman``).
      mode: edge->cloud transmission mode (``preagg | raw``).
      bootstrap_replicates: replicate count of the stratified bootstrap
        backing ``var``/``p<q>`` confidence intervals (0 disables the
        bootstrap: those kinds report zero-width point estimates).

    Frozen and hashable, so a Query can key a compiled-executable cache.
    """

    aggs: tuple[AggSpec, ...]
    group_by: str | None = None
    roi: tuple | str | None = None
    confidence: float = 0.95
    method: str = "srs"
    mode: str = "preagg"
    bootstrap_replicates: int = 200

    def __post_init__(self):
        aggs = tuple(
            a if isinstance(a, AggSpec) else AggSpec(*a) for a in self.aggs
        )
        if not aggs:
            raise ValueError("Query needs at least one AggSpec")
        for a in aggs:
            if a.kind not in KINDS and quantile_of(a.kind) is None:
                raise ValueError(
                    f"unknown aggregate kind {a.kind!r}; choose from {KINDS} "
                    "or a quantile like 'p50'/'p99'"
                )
        keys = [a.key for a in aggs]
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate aggregate keys: {keys}")
        object.__setattr__(self, "aggs", aggs)
        if self.group_by not in GROUP_KEYS:
            raise ValueError(f"group_by must be one of {GROUP_KEYS}")
        if self.method not in METHODS:
            raise ValueError(
                f"unknown sampling method {self.method!r}; choose from {'|'.join(METHODS)}"
            )
        if self.mode not in ("preagg", "raw"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if not isinstance(self.bootstrap_replicates, int) or self.bootstrap_replicates < 0:
            raise ValueError(
                f"bootstrap_replicates must be a non-negative int; got "
                f"{self.bootstrap_replicates!r}"
            )
        if isinstance(self.roi, (list, tuple)):
            try:
                (a, b), (c, d) = self.roi
            except (TypeError, ValueError) as e:
                raise ValueError(
                    "roi bbox must be ((lat_lo, lat_hi), (lon_lo, lon_hi)); "
                    f"got {self.roi!r}"
                ) from e
            object.__setattr__(
                self, "roi", ((float(a), float(b)), (float(c), float(d)))
            )
        elif self.roi is not None and not isinstance(self.roi, str):
            raise ValueError(
                "roi must be None, a geohash-prefix string, or a bbox "
                f"((lat_lo, lat_hi), (lon_lo, lon_hi)); got {type(self.roi).__name__}"
            )


@dataclasses.dataclass(frozen=True)
class Plan:
    """A lowered Query: what the edge computes and how the cloud finalizes.

    Attributes:
      query: the source spec.
      columns: distinct value columns needing edge accumulators.
      accumulators: per aggregate key, the registry accumulator *kinds* its
        finalize reads — the "expected accumulator set" of the lowering.
      column_kinds: per referenced column, the union of registry kinds its
        aggregates need; the edge accumulates exactly these states and the
        collective ships exactly their payloads.
      num_groups: static result width (1 when ``group_by`` is None).
      roi_prefix_code: pre-parsed geohash code when ``roi`` is a prefix.
    """

    query: Query
    columns: tuple[str, ...]
    accumulators: tuple[tuple[str, tuple[str, ...]], ...]
    column_kinds: tuple[tuple[str, tuple[str, ...]], ...] = ()
    num_groups: int = 1
    roi_prefix_code: int | None = None

    @property
    def accumulator_map(self) -> dict[str, tuple[str, ...]]:
        return dict(self.accumulators)

    @property
    def column_kind_map(self) -> dict[str, tuple[str, ...]]:
        return dict(self.column_kinds)

    @property
    def extrema_columns(self) -> tuple[str, ...]:
        """Columns some min/max aggregate reads (derived view)."""
        return tuple(c for c, kinds in self.column_kinds if "extrema" in kinds)

    @property
    def sketch_columns(self) -> tuple[str, ...]:
        """Columns some quantile aggregate reads (derived view)."""
        return tuple(c for c, kinds in self.column_kinds if "sketch" in kinds)


def lower(query: Query, table: StratumTable) -> Plan:
    """Lower a declarative Query against a stratum table into a Plan."""
    columns = tuple(dict.fromkeys(a.column for a in query.aggs))
    accs = tuple((a.key, agg_accumulator_kinds(a.kind)) for a in query.aggs)
    column_kinds = tuple(
        (
            c,
            tuple(
                dict.fromkeys(
                    k
                    for a in query.aggs
                    if a.column == c
                    for k in agg_accumulator_kinds(a.kind)
                )
            ),
        )
        for c in columns
    )
    if query.group_by == "stratum":
        num_groups = table.num_strata
    elif query.group_by == "neighborhood":
        num_groups = table.num_neighborhoods
    else:
        num_groups = 1
    prefix_code = None
    if isinstance(query.roi, str):
        if len(query.roi) > table.precision:
            raise ValueError(
                f"roi prefix {query.roi!r} is finer than the stratum grid "
                f"(precision {table.precision})"
            )
        prefix_code = int(geohash.from_strings([query.roi])[0])
    return Plan(
        query=query,
        columns=columns,
        accumulators=accs,
        column_kinds=column_kinds,
        num_groups=num_groups,
        roi_prefix_code=prefix_code,
    )


def fusion_key(plan: Plan) -> tuple:
    """Hashable sampling signature of a plan.

    Two plans with equal fusion keys draw *identical* sampling decisions for
    the same PRNG key and fraction: the EdgeSOS mask depends only on the
    stratum membership of eligible tuples (method + ROI), and the collective
    program on the transmission mode.  Plans that agree here can share one
    stratify+sample pass and one collective — the precondition of
    :func:`fuse`.  Aggregates, columns, group-by, and confidence are *not*
    part of the key; they only shape accumulation and finalize, which fuse
    freely.

    Bernoulli is special: its keep-decisions are per-tuple uniforms,
    independent of stratum membership and hence of any ROI-induced stratum
    reassignment — so *differing-ROI* Bernoulli queries can share one pass
    with per-member accumulation masks (cross-signature fusion).  The ROI
    therefore drops out of the key for ``bernoulli``+``preagg`` plans; raw
    mode keeps it (the compacted uplink buffer is ROI-filtered, so members
    must agree on the filter).
    """
    q = plan.query
    if q.method == "bernoulli" and q.mode == "preagg":
        return (q.method, q.mode)
    return (q.method, q.mode, q.roi)


def finalize_signature(plan: Plan) -> tuple:
    """Hashable *finalize* signature of a plan: exactly the inputs
    :func:`finalize` reads.

    ``finalize`` consumes the aggregate specs, grouping, confidence,
    bootstrap configuration, and the plan's column/kind layout — never the
    sampling method, transmission mode, or ROI (those only shape which
    *stats* arrive).  Two plans with equal finalize signatures therefore
    run the *same* cloud-side consolidation program over same-shaped
    accumulator pytrees, which is what lets a :class:`~.session.StreamSession`
    vmap one jitted finalize across every due query sharing a signature —
    one compiled program per signature, not per registered query — even
    when the members live in different fusion groups (e.g. same aggregates
    over disjoint ROIs).
    """
    q = plan.query
    return (
        q.aggs,
        q.group_by,
        q.confidence,
        q.bootstrap_replicates,
        plan.columns,
        plan.column_kinds,
        plan.num_groups,
    )


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    """A set of lowered queries served by one shared edge pass.

    ``shared`` is a synthetic carrier plan whose column / accumulator-kind
    sets are the unions over ``members``: executing its edge program
    produces every per-stratum accumulator state any member's finalize
    reads.  Each member then carves its own estimates out of the shared
    merged ``ColumnStats`` (``finalize(member, table, stats)``) — N queries,
    one stratify+EdgeSOS pass, one collective.
    """

    members: tuple[Plan, ...]
    shared: Plan

    @property
    def columns(self) -> tuple[str, ...]:
        return self.shared.columns

    @property
    def extrema_columns(self) -> tuple[str, ...]:
        return self.shared.extrema_columns

    @property
    def mode(self) -> str:
        return self.shared.query.mode

    @property
    def cross_roi(self) -> bool:
        """True when members carry differing ROIs (Bernoulli cross-signature
        fusion): the shared carrier samples unfiltered and each member
        applies its own ROI as a per-member accumulation mask, so the group
        must run the *refined* per-member edge program, never the shared
        union-accumulation one."""
        return len({p.query.roi for p in self.members}) > 1


def fuse(plans) -> FusedPlan:
    """Fuse lowered plans that share a sampling signature into one pass.

    Unions the referenced columns (order-preserving across members), the
    per-aggregate accumulator-kind sets, and the per-column kind sets; the
    fusion keys are required to agree (:func:`fusion_key`) so the shared
    sample is elementwise-identical to each member's independent sample —
    callers (``StreamSession``) partition heterogeneous query sets into
    fusable groups first.  Raises ``ValueError`` on a signature mismatch.
    Bernoulli ``preagg`` members may carry *differing ROIs*
    (:attr:`FusedPlan.cross_roi`): such groups must be executed through the
    refined per-member edge program, which applies each member's ROI as an
    accumulation mask over the shared uniform draw.
    """
    plans = tuple(plans)
    if not plans:
        raise ValueError("fuse needs at least one plan")
    keys = {fusion_key(p) for p in plans}
    if len(keys) != 1:
        raise ValueError(
            "cannot fuse plans with differing sampling signatures "
            f"(method, mode, roi): {sorted(keys, key=repr)}"
        )
    columns = tuple(dict.fromkeys(c for p in plans for c in p.columns))
    col_kinds: dict[str, tuple[str, ...]] = {c: () for c in columns}
    accs: dict[str, tuple[str, ...]] = {}
    for p in plans:
        for agg_key, kinds in p.accumulators:
            accs[agg_key] = tuple(dict.fromkeys(accs.get(agg_key, ()) + tuple(kinds)))
        for c, kinds in p.column_kinds:
            col_kinds[c] = tuple(dict.fromkeys(col_kinds[c] + tuple(kinds)))
    q0 = plans[0].query
    # a cross-ROI (Bernoulli) group's carrier samples unfiltered: each
    # member's ROI becomes a per-member accumulation mask in the refined
    # edge program rather than a shared pre-filter
    rois = {p.query.roi for p in plans}
    shared_roi, prefix_code = (
        (q0.roi, plans[0].roi_prefix_code) if len(rois) == 1 else (None, None)
    )
    carrier = Query(
        aggs=tuple(AggSpec("mean", c) for c in columns),
        roi=shared_roi,
        confidence=q0.confidence,
        method=q0.method,
        mode=q0.mode,
    )
    shared = Plan(
        query=carrier,
        columns=columns,
        accumulators=tuple(accs.items()),
        column_kinds=tuple(col_kinds.items()),
        num_groups=1,
        roi_prefix_code=prefix_code,
    )
    return FusedPlan(members=plans, shared=shared)


def roi_mask(plan: Plan, table: StratumTable, lat: jnp.ndarray, lon: jnp.ndarray) -> jnp.ndarray:
    """Boolean in-region mask for the plan's ROI (all-True when unset)."""
    roi = plan.query.roi
    if roi is None:
        return jnp.ones(lat.shape, bool)
    if isinstance(roi, str):
        code = geohash.encode(lat, lon, table.precision)
        par = geohash.parent(code, table.precision, len(roi))
        return par == jnp.asarray(plan.roi_prefix_code, par.dtype)
    (lat_lo, lat_hi), (lon_lo, lon_hi) = roi
    return (lat >= lat_lo) & (lat <= lat_hi) & (lon >= lon_lo) & (lon <= lon_hi)


class AggEstimate(NamedTuple):
    """One finalized aggregate; scalars, or (num_groups,) when grouped.

    ``moe``/``ci_low``/``ci_high``/``relative_error`` are the eq 9-10 error
    bounds for sum/mean; zero-width for the exact/point-estimate kinds.
    ``n`` is the realized sample size and ``population`` the in-region
    window population backing the estimate.
    """

    value: jnp.ndarray
    moe: jnp.ndarray
    ci_low: jnp.ndarray
    ci_high: jnp.ndarray
    relative_error: jnp.ndarray
    n: jnp.ndarray
    population: jnp.ndarray


class QueryResult(NamedTuple):
    """pipeline.execute output: per-aggregate estimates + diagnostics."""

    estimates: dict  # agg key -> AggEstimate
    stats: dict  # column -> {kind: state} registry pytree (overflow slot kept)
    n_sampled: jnp.ndarray
    n_valid: jnp.ndarray
    n_overflow: jnp.ndarray
    n_truncated: jnp.ndarray  # raw-mode kept tuples shed by the static buffer
    comm_bytes: jnp.ndarray  # analytic edge->cloud payload of the plan's mode
    n_dropped: int = 0  # tuples the window(s) shed upstream (bounded buffers)


def zero_overflow_column(stats: ColumnStats) -> ColumnStats:
    """Neutralize the overflow slot: additive fields -> 0 (shared
    :func:`estimators.zero_overflow_stats` rule), extrema -> ±inf."""
    base = estimators.zero_overflow_stats(stats.base)
    keep = jnp.arange(stats.n.shape[0]) < (stats.n.shape[0] - 1)
    return ColumnStats(
        n=base.n, total=base.total, wsum=base.wsum, m2=base.m2, mean=base.mean,
        min=jnp.where(keep, stats.min, jnp.inf),
        max=jnp.where(keep, stats.max, -jnp.inf),
    )


def _group_index(plan: Plan, table: StratumTable) -> jnp.ndarray:
    """stratum slot -> group id; overflow maps to an extra discarded group."""
    s = table.num_strata
    if plan.query.group_by == "stratum":
        grp = jnp.arange(s, dtype=jnp.int32)
    else:
        grp = table.neighborhood[:s]
    return jnp.concatenate([grp, jnp.asarray([plan.num_groups], jnp.int32)])


def _gsum(x: jnp.ndarray, grp: jnp.ndarray, num: int) -> jnp.ndarray:
    return jax.ops.segment_sum(x, grp, num_segments=num + 1)[:num]


def _bounded_estimate(value, lo, hi, n_g, pop_g) -> AggEstimate:
    """Assemble an AggEstimate from a point estimate and a (lo, hi) CI.

    The interval is clamped to contain the point estimate; ``moe`` is the
    larger half-width and ``relative_error`` its ratio to |value| (0 for an
    exact zero-width interval, inf for an unbounded one or a zero value).
    Infinite values (empty-group extrema identities) keep zero-width
    intervals well-defined instead of producing inf - inf NaNs.  A group
    with no sampled evidence (``n == 0``) reports an *infinite* relative
    error — a zero-width interval around a vacuous point estimate is not
    certainty, and a finite-looking RE of 0 would collapse the QoS
    fraction exactly when the stream goes quiet.  A NaN point estimate
    (e.g. a quantile of an *empty* histogram) means "no evidence", not
    zero: the NaN is surfaced as the value, the interval pinned to
    (-inf, inf) with infinite moe/relative error, instead of letting the
    NaN poison the bound arithmetic."""
    novalue = jnp.isnan(value)
    safe = jnp.where(novalue, 0.0, value)
    lo = jnp.minimum(jnp.where(novalue, -jnp.inf, lo), safe)
    hi = jnp.maximum(jnp.where(novalue, jnp.inf, hi), safe)
    up = jnp.where(hi == safe, 0.0, hi - safe)
    down = jnp.where(lo == safe, 0.0, safe - lo)
    moe = jnp.maximum(up, down)
    rel = jnp.where(
        moe > 0,
        jnp.where(
            jnp.isfinite(safe) & (jnp.abs(safe) > 0),
            moe / jnp.maximum(jnp.abs(safe), 1e-30),
            jnp.inf,
        ),
        jnp.zeros_like(moe),
    )
    rel = jnp.where((n_g > 0) & ~novalue, rel, jnp.inf)
    return AggEstimate(
        value=value, moe=moe, ci_low=lo, ci_high=hi,
        relative_error=rel, n=n_g, population=pop_g,
    )


def finalize(plan: Plan, table: StratumTable, stats: dict[str, dict], key=None) -> dict:
    """Cloud-side consolidation: merged accumulator states -> AggEstimates.

    This is the "local consolidation query" half of the split: it sees only
    per-stratum accumulator states (never raw tuples) — ``stats`` maps each
    column to its ``{kind: state}`` registry dict — and evaluates every
    AggSpec, grouping strata into the plan's result groups.

    ``key`` seeds the stratified bootstrap behind ``var``/``p<q>``
    confidence intervals (see :mod:`.bounds`); bounds are deterministic in
    it, and ``None`` falls back to a fixed key.  Each registered
    accumulator kind owns its bound logic via its ``interval`` hook, so
    every aggregate reports a ``(ci_low, ci_high, relative_error)`` triple
    with zero extra uplink bytes.

    For ``group_by=None`` the stratified sum/mean path evaluates
    :func:`estimators.estimate` on the moments state — the exact legacy
    computation, which keeps the ``process_window`` shim bit-compatible.
    """
    q = plan.query
    grouped = q.group_by is not None
    num = plan.num_groups
    z = z_value(q.confidence)
    grp = _group_index(plan, table) if grouped else None
    if key is None:
        # deterministic fallback for direct finalize() calls; engine paths
        # always thread the window key through
        key = jax.random.key(0)  # edgelint: ignore[EDG001] fixed fallback seed, not entropy
    bkey = jax.random.fold_in(key, 0x626E64)  # "bnd": decorrelate from sampling
    replicates = q.bootstrap_replicates

    out: dict[str, AggEstimate] = {}
    full_est: dict[str, estimators.Estimate] = {}
    zeroed = {c: estimators.zero_overflow_accs(stats[c]) for c in plan.columns}
    for i, spec in enumerate(q.aggs):
        accs = zeroed[spec.column]
        cs = accs["moments"]
        akey = jax.random.fold_in(bkey, i)
        n, N = cs.n, cs.total
        active = (n > 0) & (N > 0)
        if grouped:
            n_g = _gsum(n, grp, num)
            pop_g = _gsum(N, grp, num)
            covered_g = jnp.maximum(_gsum(jnp.where(active, N, 0.0), grp, num), 0.0)
        else:
            n_g = jnp.sum(n)
            pop_g = jnp.sum(N)
            covered_g = jnp.sum(jnp.where(active, N, 0.0))

        if spec.kind == "count":
            val = pop_g
            zero = jnp.zeros_like(val)
            out[spec.key] = AggEstimate(
                value=val, moe=zero, ci_low=val, ci_high=val,
                relative_error=zero, n=n_g, population=pop_g,
            )
            continue

        qv = quantile_of(spec.kind)
        if qv is not None:
            # Horvitz-Thompson expansion: within a stratum every sampled
            # tuple carries the same weight N_k/n_k (SRS/Bernoulli/Neyman),
            # so scaling stratum rows expands the sample histogram to a
            # population histogram exactly as per-tuple weighting would.
            w_k = jnp.where(n > 0, N / jnp.maximum(n, 1.0), 0.0)
            wb = w_k[:, None] * accs["sketch"].bins  # (S+1, NUM_BINS)
            if grouped:
                wb_g = jax.ops.segment_sum(wb, grp, num_segments=num + 1)[:num]
            else:
                wb_g = jnp.sum(wb, axis=0)
            val = estimators.sketch_quantile(wb_g, qv)
            ci = estimators.accumulator("sketch").interval(
                accs["sketch"], spec.kind, cs, q=qv, confidence=q.confidence,
                key=akey, replicates=replicates, grp=grp, num_groups=num,
            )
            if ci is None:
                ci = (val, val)
            out[spec.key] = _bounded_estimate(val, ci[0], ci[1], n_g, pop_g)
            continue

        if spec.kind in ("min", "max"):
            ext = accs["extrema"]
            field = ext.min if spec.kind == "min" else ext.max
            if grouped:
                seg = jax.ops.segment_min if spec.kind == "min" else jax.ops.segment_max
                val = seg(field, grp, num_segments=num + 1)[:num]
            else:
                val = jnp.min(field) if spec.kind == "min" else jnp.max(field)
            ci = estimators.accumulator("extrema").interval(
                ext, spec.kind, cs, confidence=q.confidence, key=akey,
                replicates=replicates, grp=grp, num_groups=num,
            )
            if ci is None:
                ci = (val, val)
            out[spec.key] = _bounded_estimate(val, ci[0], ci[1], n_g, pop_g)
            continue

        if not grouped and spec.kind in ("sum", "mean"):
            # exact legacy path (bit-compatible with the pre-query pipeline)
            est = full_est.get(spec.column)
            if est is None:
                est = estimators.estimate(cs, q.confidence)
                full_est[spec.column] = est
            if spec.kind == "sum":
                moe_s = z * jnp.sqrt(jnp.maximum(est.var_sum, 0.0))
                rel_s = jnp.where(
                    jnp.abs(est.sum) > 0, moe_s / jnp.maximum(jnp.abs(est.sum), 1e-30), jnp.inf
                )
                out[spec.key] = AggEstimate(
                    value=est.sum, moe=moe_s, ci_low=est.sum - moe_s,
                    ci_high=est.sum + moe_s, relative_error=rel_s,
                    n=est.n_total, population=est.population,
                )
            else:
                out[spec.key] = AggEstimate(
                    value=est.mean, moe=est.moe, ci_low=est.ci_low,
                    ci_high=est.ci_high, relative_error=est.relative_error,
                    n=est.n_total, population=est.population,
                )
            continue

        # grouped sum/mean/var and global var: per-stratum eq 4-7 terms,
        # segment-summed into groups (stratification is preserved inside
        # each group — a group is just a sub-population of strata).
        s2_k = jnp.where(n > 1, cs.m2 / jnp.maximum(n - 1.0, 1.0), 0.0)
        # uncertainty terms use the singleton-guarded s² (lonely strata
        # borrow their group's average spread; groups with no identified
        # stratum report an infinite half-width instead of false-zero)
        s2_eff, unident = estimators.guarded_s2(
            n, N, cs.m2, grp=grp if grouped else None, num_groups=num
        )
        fpc = jnp.where(N > 0, 1.0 - n / jnp.maximum(N, 1.0), 0.0)
        t_k = jnp.where(active, N * cs.mean, 0.0)  # per-stratum sum term
        v_k = jnp.where(active, N * N * fpc * s2_eff / jnp.maximum(n, 1.0), 0.0)
        if grouped:
            sum_g = _gsum(t_k, grp, num)
            var_sum_g = _gsum(v_k, grp, num)
        else:
            sum_g = jnp.sum(t_k)
            var_sum_g = jnp.sum(v_k)
        var_sum_g = jnp.where(unident, jnp.inf, var_sum_g)
        mean_g = sum_g / jnp.maximum(covered_g, 1.0)

        if spec.kind == "var":
            # plug-in population variance: E[y^2] - mean^2 with s2_k as the
            # within-stratum second moment around the stratum mean (raw,
            # not imputed: the guard shapes the CI, never the estimate).
            ey2_k = jnp.where(active, N * (s2_k + cs.mean * cs.mean), 0.0)
            ey2_g = _gsum(ey2_k, grp, num) if grouped else jnp.sum(ey2_k)
            val = jnp.maximum(ey2_g / jnp.maximum(covered_g, 1.0) - mean_g * mean_g, 0.0)
            # a sketch already shipped for this column (any quantile agg on
            # it) sharpens the CI for free: kurtosis-widened s² spread plus
            # a nonparametric bin-replicate channel, union'd conservatively
            ci = estimators.accumulator("moments").interval(
                cs, "var", cs, confidence=q.confidence, key=akey,
                replicates=replicates, grp=grp, num_groups=num,
                sketch=accs.get("sketch"), center=val,
            )
            if ci is None:
                ci = (val, val)
            out[spec.key] = _bounded_estimate(val, ci[0], ci[1], n_g, pop_g)
            continue

        if spec.kind == "sum":
            val = sum_g
            moe_g = z * jnp.sqrt(jnp.maximum(var_sum_g, 0.0))
        else:  # mean
            val = mean_g
            var_mean_g = var_sum_g / jnp.maximum(covered_g, 1.0) ** 2
            moe_g = z * jnp.sqrt(jnp.maximum(var_mean_g, 0.0))
        rel = jnp.where(jnp.abs(val) > 0, moe_g / jnp.maximum(jnp.abs(val), 1e-30), jnp.inf)
        out[spec.key] = AggEstimate(
            value=val, moe=moe_g, ci_low=val - moe_g, ci_high=val + moe_g,
            relative_error=rel, n=n_g, population=pop_g,
        )
    return out


def preagg_bytes(plan: Plan, num_slots: int) -> int:
    """Analytic *dense model* of the preagg uplink: n/total are shared
    across columns (psummed once); every other (S+1)-float vector is
    declared by the accumulator kinds the plan carries per column
    (moments: wsum/raw2, extrema: min/max, sketch: its bin rows).  4-byte
    floats.  A single moment-only column gives the legacy 4-vector
    payload.

    When ``PipelineConfig.uplink_codec`` is set, this dense figure is the
    *baseline* the codec's measured encoded bytes are compared against —
    result/session ``comm_bytes`` then report the measured truth, and the
    ratio dense/encoded is the compression the codec bought."""
    vectors = 2  # shared n/total
    for _c, kinds in plan.column_kinds:
        vectors += sum(estimators.accumulator(k).payload_vectors() for k in kinds)
    return 4 * num_slots * vectors


def raw_bytes(plan: Plan, capacity: int) -> int:
    """Analytic per-shard payload of raw mode: stratum id (4B) + validity
    (1B) + one f32 per referenced column, per buffer slot."""
    return capacity * (5 + 4 * len(plan.columns))


def refined_preagg_bytes(fused: FusedPlan, num_slots: int) -> int:
    """Analytic *dense model* of a *refined* fused pass's uplink
    (per-member thinned states instead of one union accumulation).  As
    with :func:`preagg_bytes`, a configured ``uplink_codec`` replaces this
    model with measured encoded bytes in ``comm_bytes`` accounting.

    Each member ships its own realized ``n`` vector (its nested subsample's
    per-stratum sizes) plus its plan-declared per-column accumulator
    payloads.  The window's population vector is shared across members of a
    same-ROI group (one ``total``); cross-ROI members count different
    populations and each ship their own."""
    per_member_totals = fused.cross_roi
    vectors = 0 if per_member_totals else 1  # shared total/counts
    for p in fused.members:
        vectors += 2 if per_member_totals else 1  # n (+ total when cross-ROI)
        for _c, kinds in p.column_kinds:
            vectors += sum(estimators.accumulator(k).payload_vectors() for k in kinds)
    return 4 * num_slots * vectors


def downstream_tuple_bytes(plan: Plan) -> int:
    """Bytes one kept tuple of this plan costs any downstream consumer
    (stratum id + validity + the referenced columns — the raw-mode tuple
    layout).  Scales a member's *refined* sample size into the
    downstream-volume accounting of the session layer: a 10%-fraction
    member of a fused group pays 10%, not the group max."""
    return 5 + 4 * len(plan.columns)
