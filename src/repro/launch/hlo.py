"""HLO-text cost analysis with loop-aware accounting.

Why this exists: ``compiled.cost_analysis()`` visits a while-loop body
*once*, so a scan-over-layers model under-reports FLOPs/bytes by ~L and a
grad-accumulation scan by another factor of n_micro.  The compiled text
does carry ``known_trip_count`` on while ops, so we parse the partitioned
module, build the computation call graph, and propagate multipliers:

  * while body/condition edges multiply by the trip count;
  * fusion/call/to_apply edges multiply by 1 — and ops inside *fused*
    computations contribute FLOPs but not memory bytes (fusion internals
    live in registers/VMEM; the fusion site's operands+result are the HBM
    traffic), matching XLA's own fusion cost model;
  * collectives contribute bytes-moved-per-device under a ring cost model:
      all-gather        R (g-1)/g     (R = result bytes, g = group size)
      reduce-scatter    R (g-1)
      all-reduce        2R (g-1)/g
      all-to-all        R (g-1)/g
      collective-permute R

The module is the per-device SPMD program, so all totals are per-device.
Validated against XLA cost_analysis on unrolled modules (tests/test_hlo.py).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_OPNAME_RE = re.compile(r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\\"={: ]+n[\\\"=: ]+\"?(\d+)')
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "abs", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "select", "and", "or", "xor", "not",
    "sign", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "clamp", "remainder", "atan2", "cbrt", "erf",
    "logistic", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"}


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out

def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _parse_def(line: str):
    """Parse '%name = TYPE op(args), attrs' robustly (tuple types contain
    /*index=N*/ comments and op_name metadata contains parens)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3 :]
    if rest.startswith("("):
        depth = 0
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rest[: i + 1]
        tail = rest[i + 1 :].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        tail = rest[sp + 1 :].lstrip()
    m = _OPNAME_RE.match(tail)
    if not m:
        return None
    op = m.group(1)
    args_rest = tail[m.end() :]
    return name, type_str, op, args_rest


def _collective_moved(op: str, result_bytes: float, g: int) -> float:
    if op == "all-gather":
        return result_bytes * (g - 1) / max(g, 1)
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / max(g, 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / max(g, 1)
    return float(result_bytes)  # collective-permute


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_moved: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_moved_tpu: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    # edges: (callee, factor, fused) — fused edges suppress callee bytes
    edges: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes_accessed: float
    collective_moved: float
    collective_moved_tpu: float
    collective_by_op: dict
    collective_counts: dict
    num_collectives: int

    def to_json(self):
        return {
            "flops": float(self.flops),
            "bytes_accessed": float(self.bytes_accessed),
            "collective_moved_bytes": float(self.collective_moved),
            "collective_moved_bytes_tpu": float(self.collective_moved_tpu),
            "collective_by_op": {k: float(v) for k, v in self.collective_by_op.items()},
            "collective_counts": dict(self.collective_counts),
            "num_collectives": int(self.num_collectives),
        }


def analyze_module(text: str, num_devices: int = 1) -> ModuleCost:
    # pass 1: symbol table (name -> type string) and computation membership
    sym: dict[str, str] = {}
    comps: dict[str, CompCost] = {}
    comp_lines: dict[str, list[str]] = defaultdict(list)
    entry = None
    current = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("=" not in line.split("(")[0]):
            current = mc.group("name")
            if line.lstrip().startswith("ENTRY"):
                entry = current
            comps.setdefault(current, CompCost())
            continue
        pd = _parse_def(line)
        if pd and current is not None:
            sym[pd[0]] = pd[1]
            comp_lines[current].append(line)

    # pass 1.5: per-computation def tables (for fused-param access analysis)
    # all_defs: global def map for bf16-origin chasing (XLA:CPU float
    # normalization turns bf16 dots into f32, so collectives that would
    # move bf16 on a TPU move f32 here; the "tpu" numbers undo that).
    all_defs: dict[str, tuple[str, str, list[str]]] = {}
    comp_defs: dict[str, dict[str, tuple[str, str, list[str]]]] = {}
    for cname, lines in comp_lines.items():
        defs = {}
        for line in lines:
            pd = _parse_def(line)
            if not pd:
                continue
            name, rtype, op, rest = pd
            cut = rest.find(")")
            args_part = rest[:cut] if cut >= 0 else rest
            defs[name] = (op, rtype, _OPERAND_RE.findall(args_part))
            all_defs[name] = defs[name]
        comp_defs[cname] = defs

    uses: dict[str, list[str]] = defaultdict(list)  # operand -> consumer names
    for nm, (_, _, ops) in list(all_defs.items()):
        for o in ops:
            uses[o].append(nm)

    def _bf16_on_tpu(name: str, depth: int = 4) -> bool:
        """Would this value be bf16 on the TPU target?  True when the f32
        chain originates from (producer side) or collapses back to
        (consumer side) bf16 — i.e. the f32 is CPU float-normalization."""
        seen = 0
        cur = name
        while cur in all_defs and seen < depth:
            op, rtype, operands = all_defs[cur]
            if "bf16[" in rtype:
                return True
            if not operands:
                break
            cur = operands[0]
            seen += 1
        if "bf16[" in sym.get(cur, ""):
            return True
        # consumer chase (2 hops): f32 values converted straight to bf16
        frontier = [name]
        for _ in range(2):
            nxt = []
            for nm in frontier:
                for c in uses.get(nm, []):
                    rt = sym.get(c, "")
                    if "bf16[" in rt:
                        return True
                    nxt.append(c)
            frontier = nxt[:8]
        return False

    def _fusion_operand_bytes(callee: str, arity: int) -> list[float] | None:
        """Effective read bytes per fusion parameter: parameters consumed
        only by (dynamic-)slice ops charge the slice sizes, not the full
        operand (XLA's fusion cost model does the same element-count walk)."""
        defs = comp_defs.get(callee)
        if defs is None:
            return None
        param_names = {}
        for nm, (op, rtype, _) in defs.items():
            if op == "parameter":
                # parameter index is in the original line; recover by order
                param_names[nm] = rtype
        out: list[float] = []
        # map parameter order by numeric suffix of parameter(i) is lost here;
        # conservative: analyze each param name independently and sum.
        per_param: dict[str, float] = {}
        for nm, rtype in param_names.items():
            consumers = [
                (op2, rt2) for (op2, rt2, ops2) in defs.values() if nm in ops2
            ]
            if consumers and all(op2 in ("slice", "dynamic-slice") for op2, _ in consumers):
                per_param[nm] = float(sum(_shape_bytes(rt2) for _, rt2 in consumers))
            else:
                per_param[nm] = float(_shape_bytes(rtype))
        return [per_param[nm] for nm in per_param]

    # pass 2: per-computation costs
    for cname, lines in comp_lines.items():
        cc = comps[cname]
        for line in lines:
            pd = _parse_def(line)
            if not pd:
                continue
            _, rtype, op, rest = pd
            cut = rest.find(")")
            args_part = rest[:cut] if cut >= 0 else rest
            operand_names = _OPERAND_RE.findall(args_part)
            rbytes = _shape_bytes(rtype)
            relems = _shape_elems(rtype)
            if op == "parameter" or op == "constant":
                continue
            if op == "while":
                trip = 1
                mt = _TRIP_RE.search(line)
                if mt:
                    trip = int(mt.group(1))
                for kind in ("body", "condition"):
                    mm = re.search(kind + r"=%?([\w.\-]+)", line)
                    if mm:
                        cc.edges.append((mm.group(1), float(trip), False))
                continue
            if op == "conditional":
                for mm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))", line):
                    for grp in mm.groups():
                        if grp:
                            for nm in re.findall(r"%?([\w.\-]+)", grp):
                                cc.edges.append((nm, 1.0, False))
                cc.bytes += rbytes + sum(_shape_bytes(sym.get(o, "")) for o in operand_names)
                continue
            called = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
            if op == "fusion":
                if called:
                    cc.edges.append((called.group(1), 1.0, True))
                    eff = _fusion_operand_bytes(called.group(1), len(operand_names))
                    if eff is not None:
                        cc.bytes += rbytes + sum(eff)
                    else:
                        cc.bytes += rbytes + sum(_shape_bytes(sym.get(o, "")) for o in operand_names)
                else:
                    cc.bytes += rbytes + sum(_shape_bytes(sym.get(o, "")) for o in operand_names)
                continue
            if op == "call":
                if called:
                    cc.edges.append((called.group(1), 1.0, False))
                continue
            # plain op: memory traffic with in-place/slice semantics
            if op in ("tuple", "get-tuple-element", "bitcast", "after-all", "reshape",
                      "copy-start", "copy-done", "optimization-barrier"):
                pass  # zero-cost plumbing
            elif op in ("dynamic-slice", "slice", "copy", "transpose", "concatenate",
                        "reverse", "pad"):
                cc.bytes += 2.0 * rbytes  # read slice + write result
            elif op == "dynamic-update-slice":
                upd = _shape_bytes(sym.get(operand_names[1], "")) if len(operand_names) > 1 else rbytes
                cc.bytes += 2.0 * upd  # in-place: read + write the update only
            elif op == "gather":
                idx = _shape_bytes(sym.get(operand_names[1], "")) if len(operand_names) > 1 else 0
                cc.bytes += 2.0 * rbytes + idx
            elif op == "scatter":
                upd = _shape_bytes(sym.get(operand_names[2], "")) if len(operand_names) > 2 else rbytes
                idx = _shape_bytes(sym.get(operand_names[1], "")) if len(operand_names) > 1 else 0
                cc.bytes += 3.0 * upd + idx  # read-modify-write touched rows
            elif op in ("broadcast", "iota"):
                cc.bytes += rbytes
            else:
                cc.bytes += rbytes + sum(_shape_bytes(sym.get(o, "")) for o in operand_names)
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                g = _group_size(line, num_devices)
                mv = _collective_moved(base, rbytes, g)
                cc.coll_moved[base] += mv
                # TPU-corrected: a f32 collective whose data is bf16-origin
                # (convert inserted by CPU float normalization) moves bf16
                # bytes on the target hardware.
                factor = 1.0
                if "f32[" in rtype and operand_names and _bf16_on_tpu(operand_names[0]):
                    factor = 0.5
                cc.coll_moved_tpu[base] += mv * factor
                cc.coll_counts[base] += 1
                continue
            if op == "dot":
                k = 1.0
                lhs_type = sym.get(operand_names[0], "") if operand_names else ""
                mdims = re.search(r"lhs_contracting_dims=\{([^}]*)\}", line)
                if lhs_type and mdims:
                    dims = _shape_dims(lhs_type)
                    if dims:
                        shape = dims[0][1]
                        for di in mdims.group(1).split(","):
                            di = di.strip()
                            if di and int(di) < len(shape):
                                k *= shape[int(di)]
                cc.flops += 2.0 * relems * k
            elif op == "convolution":
                cc.flops += 2.0 * relems  # lower bound; convs unused in repro
            elif op in ("reduce", "reduce-window"):
                in_elems = sum(
                    _shape_elems(sym.get(o, "")) for o in operand_names[: max(1, len(operand_names) // 2)]
                )
                cc.flops += float(in_elems)
                if called:
                    pass  # tiny scalar computation; ignore
            elif op in _ELEMENTWISE:
                cc.flops += float(relems)
            # everything else (reshape, transpose, slice, etc.): bytes only

    # pass 3: propagate multipliers from the entry (flops_mult, bytes_mult)
    mult: dict[str, tuple[float, float]] = defaultdict(lambda: (0.0, 0.0))
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return ModuleCost(0, 0, 0, {}, {}, 0)
    mult[entry] = (1.0, 1.0)
    # iterate to fixpoint over the DAG (bounded by #comps passes)
    for _ in range(len(comps) + 2):
        changed = False
        acc: dict[str, tuple[float, float]] = defaultdict(lambda: (0.0, 0.0))
        acc[entry] = (1.0, 1.0)
        for cname, cc in comps.items():
            fm, bm = mult[cname]
            if fm == 0 and bm == 0:
                continue
            for callee, factor, fused in cc.edges:
                if callee not in comps:
                    continue
                f0, b0 = acc[callee]
                add_f = fm * factor
                add_b = 0.0 if fused else bm * factor
                acc[callee] = (f0 + add_f, b0 + add_b)
        acc_final = {k: acc[k] for k in comps}
        if acc_final != {k: mult[k] for k in comps}:
            changed = True
            mult = defaultdict(lambda: (0.0, 0.0), acc_final)
        if not changed:
            break

    flops = 0.0
    bytes_acc = 0.0
    coll_by_op: defaultdict = defaultdict(float)
    coll_tpu: defaultdict = defaultdict(float)
    coll_counts: defaultdict = defaultdict(int)
    for cname, cc in comps.items():
        fm, bm = mult[cname]
        flops += fm * cc.flops
        bytes_acc += bm * cc.bytes
        m = bm if bm > 0 else fm
        for k, v in cc.coll_moved.items():
            coll_by_op[k] += m * v
        for k, v in cc.coll_moved_tpu.items():
            coll_tpu[k] += m * v
        for k, v in cc.coll_counts.items():
            coll_counts[k] += int(m * v)
    return ModuleCost(
        flops=flops,
        bytes_accessed=bytes_acc,
        collective_moved=sum(coll_by_op.values()),
        collective_moved_tpu=sum(coll_tpu.values()),
        collective_by_op=dict(coll_by_op),
        collective_counts=dict(coll_counts),
        num_collectives=sum(coll_counts.values()),
    )


def loop_trip_counts(text: str) -> list[int]:
    return [int(x) for x in _TRIP_RE.findall(text)]


def f32_shadow_bytes(text: str, min_bytes: int = 64 * 2**20) -> int:
    """Estimate of XLA:CPU's f32 shadow copies of bf16 state.

    The CPU backend has no bf16 compute units, so float normalization keeps
    f32 versions of large bf16 *loop-carried* tensors (KV caches, stacked
    params).  None of these exist on the TPU target (native bf16 MXU).  We
    count f32 entries of while-op carry tuples that (a) exceed min_bytes
    and (b) have a same-dims bf16 twin somewhere in the module — i.e. the
    value demonstrably exists in both precisions.  Deduplicated by dims.
    Subtracting from memory_analysis temps gives the TPU-corrected per-chip
    estimate reported next to the raw number.
    """
    bf16_dims: set[str] = set()
    while_f32: dict[str, int] = {}
    for line in text.splitlines():
        pd = _parse_def(line)
        if not pd:
            continue
        _, rtype, op, _ = pd
        for m in re.finditer(r"bf16\[([0-9,]*)\]", rtype):
            bf16_dims.add(m.group(1))
        if op == "while":
            for m in re.finditer(r"f32\[([0-9,]*)\]", rtype):
                dims = m.group(1)
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                b = n * 4
                if b >= min_bytes:
                    while_f32[dims] = b
    return int(sum(b for dims, b in while_f32.items() if dims in bf16_dims))
