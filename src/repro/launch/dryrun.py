import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Test hook: REPRO_DRYRUN_DEVICES overrides the placeholder device count
# (must happen before jax locks device state on first import).
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['REPRO_DRYRUN_DEVICES']}"
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON artifact with:
  * compile success, wall time, per-device memory_analysis numbers;
  * XLA cost_analysis (entry-level; loop bodies counted once) AND our
    loop-aware HLO analysis (FLOPs / bytes / collective bytes with
    known_trip_count multipliers — see launch/hlo.py);
  * roofline terms for TPU v5e (197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI);
  * MODEL_FLOPS (6ND / 2ND) and the useful-compute ratio.

Usage:
  python -m repro.launch.dryrun --arch mistral-large-123b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding

from .. import models
from ..configs import ARCH_NAMES, SHAPES, get_config, get_smoke_config, supports_cell
from ..configs.plans import get_plan
from ..models.base import ModelConfig
from ..sharding.logical import default_rules, use_rules
from ..train.optimizer import AdamWConfig
from ..train.train_loop import make_train_step
from . import hlo, specs as S
from .mesh import make_production_mesh, make_test_mesh, num_chips

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
ICI_BW = 50e9  # per-link; collective bytes are per-device ring-model totals


def cell_config(arch: str, shape_name: str, smoke: bool = False) -> ModelConfig:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if shape_name == "long_500k" and cfg.family == "hybrid":
        cfg = cfg.replace(attention_window=4096)  # windowed shared attention
    return cfg


def count_params(cfg: ModelConfig):
    spec = models.param_specs(cfg)
    total = emb = expert = 0
    def walk(tree, in_emb):
        nonlocal total, emb, expert
        if hasattr(tree, "shape") and hasattr(tree, "init"):
            n = 1
            for d in tree.shape:
                n *= d
            total += n
            if in_emb:
                emb += n
            if "experts" in (tree.axes or ()):
                expert += n
            return
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, in_emb or k == "embedding")
    walk(spec, False)
    if cfg.num_experts:
        active = total - emb - expert + expert * cfg.num_experts_per_tok / cfg.num_experts
    else:
        active = total - emb
    return {"total": total, "embedding": emb, "expert": expert, "active_nonemb": active}


def model_flops(cfg: ModelConfig, shape, chips: int, pcounts) -> float:
    n = pcounts["active_nonemb"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens *= 2  # encoder + decoder streams
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len / chips
    return 2.0 * n * shape.global_batch / chips


def lower_cell(arch: str, shape_name: str, mesh, smoke: bool = False):
    cfg = cell_config(arch, shape_name, smoke)
    shape = SHAPES[shape_name]
    plan = get_plan(arch, shape.kind)
    rules = default_rules(mesh, sequence_parallel=plan.sequence_parallel)
    with use_rules(rules):
        if shape.kind == "train":
            step = make_train_step(cfg, AdamWConfig(), plan)
            state_sds, state_ps = S.train_state_specs(cfg, rules)
            batch_sds, batch_ps = S.train_batch_specs(cfg, shape, mesh)
            batch_ps = jax.tree.map(lambda p: NamedSharding(mesh, p), batch_ps)
            lowered = jax.jit(
                step,
                in_shardings=(state_ps, batch_ps),
                out_shardings=(state_ps, None),
                donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
            return lowered, cfg, plan
        cfg_serve = cfg.replace(remat=plan.remat)
        p_sds, p_ps = S.serve_param_specs(cfg_serve, rules)
        if shape.kind == "prefill":
            in_sds, in_ps = S.prefill_specs(cfg_serve, shape, mesh)
            in_ps = jax.tree.map(lambda p: NamedSharding(mesh, p), in_ps)
            # the produced decode state must leave sharded like decode
            # consumes it (unsharded scan outputs were 100+ GiB/chip)
            state_sds_o, state_ps_o, _, _ = S.decode_specs(cfg_serve, shape, mesh, p_sds)
            state_ps_o = jax.tree.map(lambda p: NamedSharding(mesh, p), state_ps_o)
            if cfg.family == "encdec":
                from ..models import encdec

                def step(params, src_embeds, src_positions):
                    memory = encdec.encode(params, cfg_serve, src_embeds, src_positions)
                    return encdec.init_decode_state(params, cfg_serve, memory, shape.seq_len)

                lowered = jax.jit(
                    step,
                    in_shardings=(p_ps, in_ps["src_embeds"], in_ps["src_positions"]),
                    out_shardings=state_ps_o,
                ).lower(p_sds, in_sds["src_embeds"], in_sds["src_positions"])
            else:
                from ..models import transformer

                def step(params, tokens, positions):
                    return transformer.prefill(params, cfg_serve, tokens, positions)

                lowered = jax.jit(
                    step,
                    in_shardings=(p_ps, in_ps["tokens"], in_ps["positions"]),
                    out_shardings=(None, state_ps_o),
                ).lower(p_sds, in_sds["tokens"], in_sds["positions"])
            return lowered, cfg_serve, plan
        # decode
        state_sds, state_ps, tok_sds, tok_ps = S.decode_specs(cfg_serve, shape, mesh, p_sds)
        state_ps = jax.tree.map(lambda p: NamedSharding(mesh, p), state_ps)
        tok_ps = NamedSharding(mesh, tok_ps)

        def step(params, state, tokens):
            return models.decode_step(params, cfg_serve, state, tokens)

        lowered = jax.jit(
            step,
            in_shardings=(p_ps, state_ps, tok_ps),
            out_shardings=(None, state_ps),
            donate_argnums=(1,),
        ).lower(p_sds, state_sds, tok_sds)
        return lowered, cfg_serve, plan


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             smoke: bool = False, mesh=None, skip_existing: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if mesh is not None:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    out_path = os.path.join(outdir, f"{arch}__{shape_name}__{mesh_name}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    os.makedirs(outdir, exist_ok=True)
    cfg0 = cell_config(arch, shape_name, smoke)
    shape = SHAPES[shape_name]
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "family": cfg0.family,
    }
    ok, reason = supports_cell(cfg0.family, shape_name)
    if not ok:
        record.update({"status": "skipped", "reason": reason})
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        return record
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    try:
        t0 = time.time()
        lowered, cfg, plan = lower_cell(arch, shape_name, mesh, smoke)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        xla_cost = compiled.cost_analysis() or {}
        if isinstance(xla_cost, (list, tuple)):  # older jax: list of dicts
            xla_cost = xla_cost[0] if xla_cost else {}
        text = compiled.as_text()
        mine = hlo.analyze_module(text, chips)
        pcounts = count_params(cfg)
        mf = model_flops(cfg, shape, chips, pcounts)
        compute_s = mine.flops / PEAK_FLOPS
        memory_s = mine.bytes_accessed / HBM_BW
        # TPU-corrected collective bytes: XLA:CPU float-normalization turns
        # bf16 dots f32 *before* partitioning, inflating collective sizes
        # 2x vs the TPU target; hlo.py chases convert chains to undo it.
        coll_s = mine.collective_moved_tpu / ICI_BW
        dominant = max(
            ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
            key=lambda kv: kv[1],
        )[0]
        record.update(
            {
                "status": "ok",
                "plan": dataclasses.asdict(plan),
                "chips": chips,
                "lower_s": t_lower,
                "compile_s": t_compile,
                "memory": {
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                    "peak_estimate_bytes": mem.argument_size_in_bytes
                    + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes,
                    # minus XLA:CPU's f32 shadow copies of bf16 state
                    # (absent on the TPU target; see hlo.f32_shadow_bytes)
                    "f32_shadow_bytes": hlo.f32_shadow_bytes(text),
                    "peak_tpu_estimate_bytes": max(
                        0,
                        mem.argument_size_in_bytes
                        + mem.temp_size_in_bytes
                        + mem.output_size_in_bytes
                        - mem.alias_size_in_bytes
                        - hlo.f32_shadow_bytes(text),
                    ),
                },
                "xla_cost": {
                    "flops": xla_cost.get("flops"),
                    "bytes_accessed": xla_cost.get("bytes accessed"),
                },
                "hlo_cost": mine.to_json(),
                "params": pcounts,
                "model_flops_per_chip": mf,
                "useful_flops_ratio": mf / mine.flops if mine.flops else None,
                "roofline": {
                    "compute_s": compute_s,
                    "memory_s": memory_s,
                    "collective_s": coll_s,
                    "dominant": dominant,
                    "bound_s": max(compute_s, memory_s, coll_s),
                    "roofline_fraction": compute_s / max(compute_s, memory_s, coll_s)
                    if max(compute_s, memory_s, coll_s) > 0
                    else None,
                },
                "loop_trip_counts": hlo.loop_trip_counts(text)[:16],
            }
        )
    except Exception as e:  # record failures — they are bugs to fix
        record.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="reduced configs (tests)")
    ap.add_argument("--test-mesh", action="store_true", help="2x2x2 mesh (tests)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    mesh = make_test_mesh() if args.test_mesh else None
    cells = []
    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))
    if not (args.all or args.arch or args.shape):
        ap.error("specify --arch/--shape or --all")

    failures = 0
    for arch, shape, mp in cells:
        t0 = time.time()
        rec = run_cell(arch, shape, mp, args.out, smoke=args.smoke, mesh=mesh,
                       skip_existing=args.skip_existing)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (
                f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                f"coll={r['collective_s']:.3e}s dom={r['dominant']} "
                f"mem/chip={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                f"compile={rec['compile_s']:.0f}s"
            )
        elif status == "error":
            failures += 1
            extra = rec.get("error", "")[:160]
        print(f"[{status:7s}] {arch:24s} {shape:12s} mp={int(mp)} {extra} ({time.time()-t0:.0f}s)",
              flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
