"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
is pure data parallelism (gradient all-reduce over DCN), FSDP stays inside
a pod (ICI), TP stays on the model axis — the standard multi-slice layout.

Functions, not module constants: importing this module never touches jax
device state (required so tests/benches see 1 device).
"""

from __future__ import annotations

import jax

from ..sharding.compat import compat_make_mesh, compat_shard_map as compat_shard_map  # re-export


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    return compat_make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n
