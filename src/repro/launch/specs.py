"""Input specs (ShapeDtypeStruct stand-ins) + shardings per cell.

Every model input becomes a weak-type-correct ShapeDtypeStruct so the
dry-run lowers with zero allocation.  Modality stubs: [vlm]/[audio] archs
receive precomputed patch/frame embeddings here (the assignment's
``input_specs()`` contract).

Sharding of serving state uses a divisibility-aware heuristic:
  1. the batch-sized dim shards over the data axes,
  2. the kv-head dim shards over "model" when it divides it, else the
     largest model-divisible dim does (sequence-sharded flash-decode
     layout for GQA archs whose kv heads < model axis).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import models
from ..configs.shapes import ShapeSpec
from ..models.base import ModelConfig, abstract_params, spec_axes
from ..models.encdec import EncDecBatch
from ..models.transformer import Batch
from ..sharding.logical import LogicalRules, param_sharding
from ..train.optimizer import TrainState
from .mesh import data_axes


def _dp(mesh) -> tuple[str, ...]:
    return data_axes(mesh)


def _dp_size(mesh) -> int:
    n = 1
    for a in _dp(mesh):
        n *= mesh.shape[a]
    return n


def _model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def _batch_spec_entry(mesh, batch: int):
    dp = _dp(mesh)
    if not dp or batch % _dp_size(mesh) != 0:
        return None
    return dp if len(dp) > 1 else dp[0]


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Train batches
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    B, S = shape.global_batch, shape.seq_len
    ns = cfg.data_num_strata + 1
    bdim = _batch_spec_entry(mesh, B)
    if cfg.family == "encdec":
        batch = EncDecBatch(
            src_embeds=sds((B, S, cfg.d_model), jnp.bfloat16),
            tgt_tokens=sds((B, S), jnp.int32),
            targets=sds((B, S), jnp.int32),
            src_positions=sds((B, S), jnp.int32),
            tgt_positions=sds((B, S), jnp.int32),
            seq_weight=sds((B,), jnp.float32),
            stratum=sds((B,), jnp.int32),
            stratum_counts=sds((ns,), jnp.int32),
        )
        ps = EncDecBatch(
            src_embeds=P(bdim, None, None),
            tgt_tokens=P(bdim, None),
            targets=P(bdim, None),
            src_positions=P(bdim, None),
            tgt_positions=P(bdim, None),
            seq_weight=P(bdim),
            stratum=P(bdim),
            stratum_counts=P(None),
        )
        return batch, ps
    if cfg.embeddings_in:
        tokens = sds((B, S, cfg.d_model), jnp.bfloat16)
        tokens_ps = P(bdim, None, None)
    else:
        tokens = sds((B, S), jnp.int32)
        tokens_ps = P(bdim, None)
    if cfg.mrope_sections:
        positions = sds((3, B, S), jnp.int32)
        pos_ps = P(None, bdim, None)
    else:
        positions = sds((B, S), jnp.int32)
        pos_ps = P(bdim, None)
    batch = Batch(
        tokens=tokens,
        targets=sds((B, S), jnp.int32),
        positions=positions,
        seq_weight=sds((B,), jnp.float32),
        stratum=sds((B,), jnp.int32),
        stratum_counts=sds((ns,), jnp.int32),
    )
    ps = Batch(
        tokens=tokens_ps,
        targets=P(bdim, None),
        positions=pos_ps,
        seq_weight=P(bdim),
        stratum=P(bdim),
        stratum_counts=P(None),
    )
    return batch, ps


def train_state_specs(cfg: ModelConfig, rules: LogicalRules):
    specs = models.param_specs(cfg)
    p_sds = abstract_params(specs)
    axes = spec_axes(specs)
    p_ps = param_sharding(rules, axes, p_sds)
    state = TrainState(
        step=sds((), jnp.int32),
        params=p_sds,
        m=p_sds,
        v=p_sds,
    )
    ps = TrainState(
        step=NamedSharding(rules.mesh, P()),
        params=p_ps,
        m=p_ps,
        v=p_ps,
    )
    return state, ps


# ---------------------------------------------------------------------------
# Serving state
# ---------------------------------------------------------------------------


def _auto_state_spec(x: jax.ShapeDtypeStruct, mesh, batch: int, kv_heads: int):
    dims = x.shape
    if len(dims) == 0:
        return P()
    spec: list[Any] = [None] * len(dims)
    dp = _dp(mesh)
    dp_size = _dp_size(mesh)
    ms = _model_size(mesh)
    bdim = None
    for i, d in enumerate(dims):
        if d == batch and batch > 1 and dp and batch % dp_size == 0:
            spec[i] = dp if len(dp) > 1 else dp[0]
            bdim = i
            break
    if ms > 1:
        cand = [
            (d, i)
            for i, d in enumerate(dims)
            if i != bdim and i != 0 and d % ms == 0 and d >= ms
        ]
        # prefer the kv-heads dim when it divides the model axis
        kv = [(d, i) for d, i in cand if d == kv_heads]
        pick = kv[0] if kv else (max(cand) if cand else None)
        if pick is not None:
            spec[pick[1]] = "model"
    return P(*spec)


def decode_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, params_sds):
    """(state_sds, state_ps, tokens_sds, tokens_ps) for one serve_step."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        from ..models import encdec

        mem = sds((B, S, cfg.d_model), cfg.dtype)
        state_sds = jax.eval_shape(
            lambda p, m: encdec.init_decode_state(p, cfg, m, S), params_sds, mem
        )
    else:
        from ..models import transformer

        state_sds = jax.eval_shape(lambda: transformer.init_decode_state(cfg, B, S))
    state_ps = jax.tree.map(
        lambda x: _auto_state_spec(x, mesh, B, cfg.num_kv_heads), state_sds
    )
    bdim = _batch_spec_entry(mesh, B)
    if cfg.embeddings_in and cfg.family != "encdec":
        tokens = sds((B, cfg.d_model), jnp.bfloat16)
        tokens_ps = P(bdim, None)
    else:
        tokens = sds((B,), jnp.int32)
        tokens_ps = P(bdim)
    return state_sds, state_ps, tokens, tokens_ps


def prefill_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(inputs_sds, inputs_ps) for the prefill step."""
    B, S = shape.global_batch, shape.seq_len
    bdim = _batch_spec_entry(mesh, B)
    if cfg.family == "encdec":
        return (
            {"src_embeds": sds((B, S, cfg.d_model), jnp.bfloat16), "src_positions": sds((B, S), jnp.int32)},
            {"src_embeds": P(bdim, None, None), "src_positions": P(bdim, None)},
        )
    if cfg.embeddings_in:
        tokens, tokens_ps = sds((B, S, cfg.d_model), jnp.bfloat16), P(bdim, None, None)
    else:
        tokens, tokens_ps = sds((B, S), jnp.int32), P(bdim, None)
    if cfg.mrope_sections:
        positions, pos_ps = sds((3, B, S), jnp.int32), P(None, bdim, None)
    else:
        positions, pos_ps = sds((B, S), jnp.int32), P(bdim, None)
    return {"tokens": tokens, "positions": positions}, {"tokens": tokens_ps, "positions": pos_ps}


def serve_param_specs(cfg: ModelConfig, rules: LogicalRules):
    """Inference params in compute dtype (bf16) with the same sharding."""
    specs = models.param_specs(cfg)
    p_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, cfg.dtype if s.dtype == jnp.float32 and len(s.shape) > 1 else s.dtype),
        specs,
        is_leaf=lambda x: hasattr(x, "init"),
    )
    axes = spec_axes(specs)
    p_ps = param_sharding(rules, axes, p_sds)
    return p_sds, p_ps
