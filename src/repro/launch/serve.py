"""Batched serving driver: continuous request batching over prefill/decode.

A minimal vLLM-style loop scaled to this container: requests arrive with
prompts, get packed into a fixed decode batch, prefill fills each slot's
cache, and the decode step advances every active slot one token per tick;
finished slots are refilled from the queue (continuous batching).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 8 --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_smoke_config
from ..models import init_params, param_specs
from ..models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve driver covers decoder families")
    params = init_params(jax.random.key(0), param_specs(cfg))
    max_len = args.prompt_len + args.max_new

    rng = np.random.default_rng(0)
    queue = [
        rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]

    def make_tokens(prompts):
        if cfg.embeddings_in:
            t = rng.normal(0, 1, (len(prompts), args.prompt_len, cfg.d_model)).astype(np.float32)
            return jnp.asarray(t)
        return jnp.asarray(np.stack(prompts))

    if cfg.mrope_sections:
        positions = jnp.broadcast_to(jnp.arange(args.prompt_len), (3, args.batch, args.prompt_len))
    else:
        positions = jnp.broadcast_to(jnp.arange(args.prompt_len), (args.batch, args.prompt_len))

    prefill = jax.jit(lambda p, t, pos: T.prefill(p, cfg, t, pos, max_len=max_len))
    decode = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))

    done = 0
    total_tokens = 0
    t0 = time.time()
    while done < args.requests:
        batch_prompts = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        while len(batch_prompts) < args.batch:  # pad the last batch
            batch_prompts.append(batch_prompts[-1])
        logits, state = prefill(params, make_tokens(batch_prompts), positions)
        toks = jnp.argmax(logits, -1).astype(jnp.int32)
        outputs = [toks]
        for _ in range(args.max_new - 1):
            if cfg.embeddings_in:
                step_in = jnp.asarray(
                    rng.normal(0, 1, (args.batch, cfg.d_model)).astype(np.float32)
                )
            else:
                step_in = toks
            logits, state = decode(params, state, step_in)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
            outputs.append(toks)
        gen = jnp.stack(outputs, axis=1)
        done += len(batch_prompts)
        total_tokens += int(gen.size)
        print(f"[serve] batch done: generated {gen.shape} tokens; sample: {np.asarray(gen[0, :8])}")
    dt = time.time() - t0
    print(f"[serve] {done} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
