"""End-to-end training driver with fault tolerance and the EdgeSOS data
plane.  Runs real steps on whatever devices exist (CPU smoke configs in
this container; the same code lowers to the production mesh).

Features exercised here:
  * EdgeSOS-sampled batches with HT-weighted unbiased loss;
  * stratified loss telemetry (mean ± MoE) and the QoS feedback controller
    steering the data sampling fraction against --target-re;
  * sharded checkpointing (async, atomic, retention), resume on restart;
  * step-level fault tolerance: a failing step restores the last
    checkpoint and continues (use --inject-failure to see it work);
  * deterministic data resume from the window index.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --batch 16 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_config, get_smoke_config
from ..core import feedback
from ..data.batching import edgesos_batch
from ..data.tokens import StratifiedTokenStream
from ..models import init_params, param_specs
from ..train.checkpoint import CheckpointManager
from ..train.optimizer import AdamWConfig, adamw_init
from ..train.train_loop import StepPlan, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16, help="window size (sequences)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fraction", type=float, default=0.75)
    ap.add_argument("--target-re", type=float, default=0.2)
    ap.add_argument("--num-strata", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failure", type=int, default=0, help="fail at this step once")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(data_num_strata=args.num_strata)
    if cfg.family == "encdec":
        raise SystemExit("train driver covers decoder families; see examples for enc-dec")

    out_batch = max(2, int(round(args.batch * args.fraction)))
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=10, total_steps=args.steps)
    plan = StepPlan(num_microbatches=args.microbatches, remat="none" if args.smoke else "full")
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, plan), donate_argnums=(0,))

    params = init_params(jax.random.key(0), param_specs(cfg))
    state = adamw_init(params)
    start_step = 0
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if manager is not None:
        restored = manager.restore_latest(state)
        if restored is not None:
            state, start_step = restored
            state = jax.tree.map(jnp.asarray, state)
            print(f"[train] resumed from checkpoint step {start_step}")

    stream = StratifiedTokenStream(
        cfg.vocab_size, args.seq, num_strata=args.num_strata, seed=7
    )
    ctrl = feedback.init_state(args.fraction)
    slo = feedback.SLO(target_relative_error=args.target_re, min_fraction=0.2)

    windows = list(stream.batches(args.batch, args.steps + start_step + 1))
    key = jax.random.key(1)
    failed_once = False
    t0 = time.time()
    step = start_step
    while step < args.steps:
        window = windows[step]
        key, sub = jax.random.split(key)
        batch = edgesos_batch(sub, window, float(ctrl.fraction), args.num_strata, out_batch)
        try:
            if args.inject_failure and step == args.inject_failure and not failed_once:
                failed_once = True
                raise RuntimeError("injected node failure")
            new_state, metrics = step_fn(state, batch)
        except Exception as e:
            print(f"[train] step {step} failed ({e}); restoring last checkpoint")
            if manager is None:
                raise
            manager.wait()
            restored = manager.restore_latest(state)
            if restored is None:
                raise
            state, step = restored
            state = jax.tree.map(jnp.asarray, state)
            continue
        state = new_state
        ctrl = feedback.update(
            ctrl, metrics["stratified_loss_re"], jnp.int32(args.batch), slo
        )
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                f"strat_loss={float(metrics['stratified_loss_mean']):.4f}"
                f"±{float(metrics['stratified_loss_moe']):.4f} "
                f"re={float(metrics['stratified_loss_re']):.3f} "
                f"frac={float(ctrl.fraction):.2f} "
                f"gnorm={float(metrics['grad_norm']):.2f}",
                flush=True,
            )
        step += 1
        if manager is not None and step % args.ckpt_every == 0:
            manager.save(step, state)
    if manager is not None:
        manager.save(step, state)
        manager.wait()
    dt = time.time() - t0
    print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s)")
    return float(jax.device_get(jnp.asarray(0.0)))  # sync


if __name__ == "__main__":
    main()
