"""AdamW with cosine schedule, implemented directly on pytrees.

fp32 optimizer state sharded exactly like the (fp32) parameters — together
with FSDP param sharding this is ZeRO-3-style state partitioning: every
state tensor inherits the param's NamedSharding, so memory per chip is
params*(4+4+4)/|data x model| bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    end_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class TrainState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    params: Any  # fp32 master params
    m: Any
    v: Any


def adamw_init(params) -> TrainState:
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return TrainState(step=jnp.int32(0), params=params, m=zeros(params), v=zeros(params))


def cosine_schedule(step, cfg: AdamWConfig):
    warm = cfg.peak_lr * jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.end_lr_ratio + (1 - cfg.end_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(state: TrainState, grads, cfg: AdamWConfig):
    """One AdamW step; returns (new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(step, cfg)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p, m, v

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(step=step, params=new_p, m=new_m, v=new_v), metrics
