"""Training substrate: optimizer, train loop, checkpointing, compression."""

from .optimizer import AdamWConfig, TrainState, adamw_init, adamw_update, cosine_schedule
from .train_loop import StepPlan, make_train_step

__all__ = [
    "AdamWConfig",
    "StepPlan",
    "TrainState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "make_train_step",
]
