"""Sharded, fault-tolerant checkpointing with elastic restore.

Design (scaled-down from the multi-host layout, same invariants):
  * one file per pytree leaf + a JSON manifest (step, tree structure,
    shapes/dtypes, per-file SHA-256); leaves stream to disk via numpy;
  * atomic commit: write to ``step_N.tmp/`` then os.rename -> ``step_N/``;
    a crash mid-save never corrupts the latest checkpoint;
  * async save: a background thread serializes device arrays snapshotted
    at save() call time, so the train loop continues immediately;
  * retention: keep the newest ``max_to_keep`` checkpoints;
  * elastic restore: leaves are mmap'd and fed through
    ``jax.make_array_from_callback`` against the *target* sharding, so a
    checkpoint written on one mesh restores onto any other (different
    device count / layout) reading only the local slices;
  * corruption handling: hash mismatch or unreadable files fail that
    checkpoint and restore falls back to the next older one.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any

import numpy as np

import jax


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((name or "leaf", leaf))
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)
        self._queue: queue.Queue = queue.Queue()
        self._errors: list[str] = []
        self._async = async_save
        self._thread = None
        if async_save:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state) -> None:
        """Snapshot to host (blocking) then write async (or inline)."""
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if self._async:
            self._queue.put((step, host_state))
        else:
            self._write(step, host_state)

    def wait(self) -> None:
        if self._async:
            self._queue.join()
        if self._errors:
            raise RuntimeError("; ".join(self._errors))

    def _worker(self) -> None:
        while True:
            step, host_state = self._queue.get()
            try:
                self._write(step, host_state)
            except Exception as e:  # surfaced on wait()
                self._errors.append(f"save step {step}: {e}")
            finally:
                self._queue.task_done()

    def _write(self, step: int, host_state) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(host_state)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            fname = f"{i:05d}_{name[:128]}.npy"
            fpath = os.path.join(tmp, fname)
            np.save(fpath, leaf)
            manifest["leaves"].append(
                {
                    "name": name,
                    "file": fname,
                    "shape": list(np.shape(leaf)),
                    "dtype": str(np.asarray(leaf).dtype),
                    "sha256": _sha256(fpath),
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _load_one(self, step: int, target_tree, shardings=None, verify: bool = True):
        ckpt = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(ckpt, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten(target_tree)
        entries = manifest["leaves"]
        if len(entries) != len(leaves):
            raise ValueError(
                f"checkpoint step {step}: {len(entries)} leaves vs target {len(leaves)}"
            )
        shard_leaves = jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
        out = []
        for entry, target, shd in zip(entries, leaves, shard_leaves):
            fpath = os.path.join(ckpt, entry["file"])
            if verify and _sha256(fpath) != entry["sha256"]:
                raise IOError(f"hash mismatch in {fpath}")
            arr = np.load(fpath, mmap_mode="r")
            if tuple(arr.shape) != tuple(target.shape):
                raise ValueError(f"{entry['name']}: shape {arr.shape} vs {target.shape}")
            if shd is not None:
                out.append(
                    jax.make_array_from_callback(tuple(arr.shape), shd, lambda idx, a=arr: np.asarray(a[idx]))
                )
            else:
                out.append(np.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

    def restore_latest(self, target_tree, shardings=None, verify: bool = True):
        """Restore the newest intact checkpoint; falls back past corrupted
        ones. Returns (state, step) or None when nothing restorable."""
        for step in reversed(self.all_steps()):
            try:
                return self._load_one(step, target_tree, shardings, verify)
            except Exception:
                continue
        return None
