"""Gradient compression for cross-pod (DCN) reduction — the paper's
"pre-aggregate to save bandwidth" idea applied to gradients.

Two unbiased compressors with error feedback:
  * random-k sparsification: keep each coordinate with probability p using
    a PRNG key *shared across pods* (the mask is identical everywhere, so
    the compressed all-reduce is just a psum of masked values / p — no
    index exchange);
  * int8 quantization with stochastic rounding: per-tensor scale, E[q] = g.

Error feedback accumulates what compression dropped and re-injects it next
step (Karimireddy et al. 2019), keeping SGD/Adam convergence.

``cross_pod_mean_compressed`` is the shard_map collective used on the pod
axis; ``compress_tree``/``decompress`` are pure and reusable in-loop.  The
EdgeSOS telemetry analogy is exact: stratified pre-aggregation reduced
O(window) collective bytes to O(strata); random-k reduces O(params) DCN
bytes to O(k) with the same unbiasedness discipline.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # error-feedback memory, same tree as grads


def init_state(grads_like) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress_randomk(key, grads, p: float, state: CompressionState, *, unbiased: bool = False):
    """Random-k sparsification. Two disciplines (do not mix):

    * ``unbiased=True``: kept coordinates scaled by 1/p so E[out] = grads;
      no error feedback (the scaling already preserves expectation).
    * ``unbiased=False`` (default): unscaled kept values + error feedback —
      biased per step, exact in accumulation (Σ out_t = Σ grads_t ± r_T),
      the standard EF-SGD compressor.
    """
    leaves, treedef = jax.tree.flatten(grads)
    res = treedef.flatten_up_to(state.residual)
    keys = jax.random.split(key, len(leaves))
    outs, new_res = [], []
    for k, g, r in zip(keys, leaves, res):
        keep = jax.random.bernoulli(k, p, g.shape)
        if unbiased:
            c = jnp.where(keep, g.astype(jnp.float32) / p, 0.0)
            outs.append(c.astype(g.dtype))
            new_res.append(r)  # EF memory unused in unbiased mode
        else:
            corrected = g.astype(jnp.float32) + r
            c = jnp.where(keep, corrected, 0.0)
            outs.append(c.astype(g.dtype))
            new_res.append(corrected - c)
    return treedef.unflatten(outs), CompressionState(residual=treedef.unflatten(new_res))


def compress_int8(key, grads, state: CompressionState):
    """Stochastic-rounding int8: returns (q_tree, scales, new_state)."""
    leaves, treedef = jax.tree.flatten(grads)
    res = treedef.flatten_up_to(state.residual)
    keys = jax.random.split(key, len(leaves))
    qs, scales, new_res = [], [], []
    for k, g, r in zip(keys, leaves, res):
        corrected = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        noise = jax.random.uniform(k, corrected.shape) - 0.5
        q = jnp.clip(jnp.round(corrected / scale + noise), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        qs.append(q)
        scales.append(scale)
        new_res.append(corrected - deq)
    return treedef.unflatten(qs), scales, CompressionState(residual=treedef.unflatten(new_res))


def decompress_int8(q_tree, scales):
    leaves, treedef = jax.tree.flatten(q_tree)
    return treedef.unflatten(
        [q.astype(jnp.float32) * s for q, s in zip(leaves, scales)]
    )


def cross_pod_mean_compressed(grads, key, p: float, state: CompressionState, axis: str = "pod"):
    """shard_map collective: random-k compress, psum over the pod axis,
    rescale to the mean.  Used inside a shard_map over the pod axis; the
    shared key guarantees identical masks so the sparse psum is exact."""
    comp, new_state = compress_randomk(key, grads, p, state)
    n = jax.lax.psum(1, axis)
    reduced = jax.tree.map(lambda g: jax.lax.psum(g, axis) / n, comp)
    return reduced, new_state
