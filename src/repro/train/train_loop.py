"""Train-step factory: microbatched, remat'd, EdgeSOS-weighted training.

The step is one jit'd SPMD program: loss -> grads (optionally accumulated
over microbatches with a lax.scan so activation memory is one microbatch) ->
AdamW.  Gradient reduction across data axes is GSPMD-inserted (params are
FSDP-sharded, so gradients reduce-scatter rather than all-reduce — the
ZeRO trick falls out of sharding propagation).

Paper integration: batches carry EdgeSOS Horvitz-Thompson weights and
stratum tags; metrics include the stratified loss estimate with its margin
of error (eqs 5-10) so the QoS controller can steer the *data* sampling
fraction during training.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .. import models
from ..models.base import ModelConfig
from .optimizer import AdamWConfig, TrainState, adamw_update


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """Per-(arch x shape x mesh) execution plan — the perf knobs."""

    num_microbatches: int = 1
    sequence_parallel: bool = False
    remat: str = "full"  # none | full | dots | offload


def _split_microbatches(batch, n: int):
    def r(x):
        if x.ndim == 0 or x.shape[0] % n != 0:
            # replicated per-window arrays (e.g. stratum_counts): broadcast
            return jnp.broadcast_to(x, (n,) + x.shape)
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])

    # positions for M-RoPE are (3, B, S): split on axis 1
    fields = batch._asdict()
    out = {}
    for k, v in fields.items():
        if k == "positions" and v.ndim == 3 and v.shape[0] == 3:
            out[k] = jnp.moveaxis(v.reshape((3, n, v.shape[1] // n) + v.shape[2:]), 1, 0)
        elif k == "stratum_counts":
            out[k] = jnp.broadcast_to(v, (n,) + v.shape)
        else:
            out[k] = r(v)
    return type(batch)(**out)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, plan: StepPlan | None = None):
    plan = plan or StepPlan()
    cfg = cfg.replace(remat=plan.remat)

    def cast_for_compute(params):
        """One bf16 copy per step so FSDP all-gathers move bf16, not f32.

        Without this, GSPMD all-gathers the f32 master shards at every use
        site (2x collective bytes + f32-sized gathered temps).  Measured in
        EXPERIMENTS.md §Perf iteration 1.
        """
        cast = jax.tree.map(
            lambda p: p.astype(cfg.dtype)
            if (p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating))
            else p,
            params,
        )
        # materialize the bf16 copy *before* the layer loop — otherwise XLA
        # sinks the converts into the loop and the all-gathers stay f32
        return jax.lax.optimization_barrier(cast)

    def loss_and_grads(params, batch):
        def lf(p, b):
            loss, metrics = models.loss_fn(p, cfg, b)
            return loss, metrics

        if plan.num_microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
            return loss, metrics, grads

        n = plan.num_microbatches
        mbs = _split_microbatches(batch, n)

        def scan_body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, (losses, metricses) = jax.lax.scan(scan_body, zero, mbs)
        grads = jax.tree.map(lambda g: g / n, acc)
        metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), metricses)
        return jnp.mean(losses), metrics, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = loss_and_grads(cast_for_compute(state.params), batch)
        new_state, opt_metrics = adamw_update(state, grads, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_state, metrics

    return train_step
