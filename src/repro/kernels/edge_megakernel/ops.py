"""Public wrapper: fused Pallas megakernel on TPU, stacked segment elsewhere.

Off-TPU the auto mode (``interpret=None``) lowers to a jnp lowering with
the same contract — one ``segment_sum`` of the stacked stat rows plus
segment min/max and a flat-binned sketch scatter — so
``PipelineConfig(backend="fused")`` stays portable.  Pass
``interpret=True`` to force the interpreted Pallas kernel (parity tests,
``kernel_bench --dry``).

Both jnp implementations live here (not in ``ref.py``): refs are
jax-free numpy oracles (edgelint EDG006).

Contract notes shared by all three implementations (kernel / segment
lowering / numpy ref):

* sampling is the unified threshold compare ``keep = ok & (score <
  thr[slot])`` (Bernoulli: uniforms vs fractions; SRS: ranks vs ``n_k``;
  raw: zeros vs ones);
* ``latlon`` mode resolves membership against the sorted-unique code
  table; tuples whose code is absent (the overflow stratum) land in NO
  slot — overflow stat rows stay zero (+inf/-inf for extrema) and the
  caller reconstructs overflow *counts* as residuals.  Sound because the
  query layer zeroes overflow stats before estimating;
* ``sidx`` mode covers every slot, overflow included, exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.estimators import SKETCH_NUM_BINS, sketch_bin_index
from ...core.geohash import encode
from .edge_megakernel import MegaResult, edge_megakernel_pallas


def edge_megakernel(
    vals,
    ok,
    scores,
    thresholds,
    num_slots: int,
    *,
    sidx=None,
    lat=None,
    lon=None,
    codes=None,
    precision: int | None = None,
    ext_idx: tuple = (),
    sk_idx: tuple = (),
    interpret: bool | None = None,
    n_block: int | None = None,
    s_block: int | None = None,
) -> MegaResult:
    """Single-traversal fused edge pass -> :class:`MegaResult`.

    ``vals`` (C, N) value columns (any float dtype; f32 accumulation),
    ``ok`` (M, N) per-member validity & ROI, ``scores`` (M, N) non-negative
    sampling scores, ``thresholds`` (M, num_slots) per-slot keep
    thresholds.  Membership comes from ``sidx`` (M, N) or from
    ``lat``/``lon`` + ``codes``/``precision`` (see module docstring).
    ``ext_idx``/``sk_idx`` select the value columns that also get extrema
    / sketch stat rows.
    """
    ext_idx, sk_idx = tuple(ext_idx), tuple(sk_idx)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _edge_megakernel_segment(
                vals, ok, scores, thresholds, num_slots,
                sidx=sidx, lat=lat, lon=lon, codes=codes, precision=precision,
                ext_idx=ext_idx, sk_idx=sk_idx,
            )
        interpret = False
    return edge_megakernel_pallas(
        vals, ok, scores, thresholds, num_slots,
        sidx=sidx, lat=lat, lon=lon, codes=codes, precision=precision,
        ext_idx=ext_idx, sk_idx=sk_idx,
        n_block=n_block, s_block=s_block, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("num_slots", "precision", "ext_idx", "sk_idx")
)
def _edge_megakernel_segment(
    vals, ok, scores, thresholds, num_slots: int,
    *, sidx=None, lat=None, lon=None, codes=None, precision=None,
    ext_idx: tuple = (), sk_idx: tuple = (),
):
    """jnp lowering: stacked segment reduce with a trailing dump slot.

    Slot ``num_slots`` collects latlon-mode tuples outside the code table
    (and nothing in sidx mode) and is sliced off, matching the kernel's
    match-nothing behaviour.
    """
    c = vals.shape[0]
    vals = vals.astype(jnp.float32)
    if sidx is None:
        if lat is None or lon is None or codes is None or precision is None:
            raise ValueError("latlon mode needs lat, lon, codes and precision")
        code = encode(lat.astype(jnp.float32), lon.astype(jnp.float32), precision)
        pos = jnp.searchsorted(codes, code)
        pos_c = jnp.clip(pos, 0, codes.shape[0] - 1)
        found = codes[pos_c] == code
        sidx_ext = jnp.where(found, pos_c.astype(jnp.int32), num_slots)
        sidx_ext = jnp.broadcast_to(sidx_ext[None, :], ok.shape)
    else:
        sidx_ext = jnp.clip(sidx.astype(jnp.int32), 0, num_slots)
    okv = ok.astype(jnp.float32)
    thr_ext = jnp.pad(thresholds.astype(jnp.float32), ((0, 0), (0, 1)))
    t = jnp.take_along_axis(thr_ext, sidx_ext, axis=1)  # (M, N)
    keepv = okv * (scores.astype(jnp.float32) < t).astype(jnp.float32)

    def per_member(sidx_m, okv_m, keepv_m):
        kv = keepv_m[None, :] * vals  # (C, N)
        rows = jnp.concatenate([okv_m[None, :], keepv_m[None, :], kv, kv * vals], axis=0)
        out = jax.ops.segment_sum(rows.T, sidx_m, num_segments=num_slots + 1)  # (S+1, R)
        out = out[:num_slots]
        kept = keepv_m > 0.0
        # route non-kept tuples to the dump slot so empty strata keep the
        # +inf/-inf identities without a where over segments
        sidx_kept = jnp.where(kept, sidx_m, num_slots)
        mins = jnp.stack(
            [
                jax.ops.segment_min(vals[e], sidx_kept, num_segments=num_slots + 1)[:num_slots]
                for e in ext_idx
            ]
        ) if ext_idx else jnp.zeros((0, num_slots), jnp.float32)
        maxs = jnp.stack(
            [
                jax.ops.segment_max(vals[e], sidx_kept, num_segments=num_slots + 1)[:num_slots]
                for e in ext_idx
            ]
        ) if ext_idx else jnp.zeros((0, num_slots), jnp.float32)
        bins_l = []
        for k in sk_idx:
            b = sketch_bin_index(vals[k])
            flat = sidx_m * SKETCH_NUM_BINS + b
            bins_l.append(
                jax.ops.segment_sum(
                    keepv_m, flat, num_segments=(num_slots + 1) * SKETCH_NUM_BINS
                ).reshape(num_slots + 1, SKETCH_NUM_BINS)[:num_slots]
            )
        bins = (
            jnp.stack(bins_l)
            if sk_idx
            else jnp.zeros((0, num_slots, SKETCH_NUM_BINS), jnp.float32)
        )
        return (
            out[:, 0], out[:, 1],
            out[:, 2 : 2 + c].T, out[:, 2 + c : 2 + 2 * c].T,
            mins, maxs, bins,
        )

    pop, keep, s1, s2, mins, maxs, bins = jax.vmap(per_member)(sidx_ext, okv, keepv)
    # segment_min/max identities are finite dtype extremes; the kernel and
    # the accumulator protocol use +/-inf for empty strata
    if ext_idx:
        empty = keep[:, None, :] == 0.0
        mins = jnp.where(empty, jnp.inf, mins)
        maxs = jnp.where(empty, -jnp.inf, maxs)
    return MegaResult(pop=pop, keep=keep, s1=s1, s2=s2, mins=mins, maxs=maxs, bins=bins)
