from .edge_megakernel import MegaResult
from .ops import edge_megakernel

__all__ = ["MegaResult", "edge_megakernel"]
