"""Oracle: self-contained numpy single-traversal fused edge pass.

Jax-free by contract (edgelint EDG006) — an independent port of the
megakernel's semantics, not a delegation: its own Morton encoder (the
same bit-exact uint32 mask chain / single-multiply f32 quantize as the
geohash oracle), its own sketch bin index, and input-order f32
``np.add.at`` accumulation.

Returns a plain 7-tuple mirroring the kernel's ``MegaResult`` field
order: ``(pop, keep, s1, s2, mins, maxs, bins)`` with shapes
``(M, S)``, ``(M, S)``, ``(M, C, S)``, ``(M, C, S)``, ``(M, E, S)``,
``(M, E, S)``, ``(M, K, S, 513)``.

Contract mirrored from ops.py: unified threshold-compare sampling;
latlon-mode tuples with codes outside the table land in NO slot (the
caller owns overflow residuals); sidx mode covers all slots exactly;
empty-stratum extrema are the +/-inf identities.
"""

from __future__ import annotations

import numpy as np

LAT_MIN, LAT_MAX = -90.0, 90.0
LON_MIN, LON_MAX = -180.0, 180.0
MAX_PRECISION = 6  # 30 bits; uint32 codes

BINS_PER_SIDE = 256
LOG_GAMMA = 0.08
MIN_MAG = 1e-4
NUM_BINS = 2 * BINS_PER_SIDE + 1


def _part1by1(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32) & np.uint32(0x0000FFFF)
    x = (x | (x << np.uint32(8))) & np.uint32(0x00FF00FF)
    x = (x | (x << np.uint32(4))) & np.uint32(0x0F0F0F0F)
    x = (x | (x << np.uint32(2))) & np.uint32(0x33333333)
    x = (x | (x << np.uint32(1))) & np.uint32(0x55555555)
    return x


def _encode(lat, lon, precision: int) -> np.ndarray:
    """uint32 Morton geohash codes (bit-exact with the device encoder)."""
    if not 1 <= precision <= MAX_PRECISION:
        raise ValueError(f"precision must be in [1, {MAX_PRECISION}], got {precision}")
    lat = np.asarray(lat, dtype=np.float32)
    lon = np.asarray(lon, dtype=np.float32)
    total = 5 * precision
    lon_bits, lat_bits = (total + 1) // 2, total // 2
    lat_scale = np.float32((1 << lat_bits) / (LAT_MAX - LAT_MIN))
    lon_scale = np.float32((1 << lon_bits) / (LON_MAX - LON_MIN))
    lat_i = np.clip(
        ((lat - np.float32(LAT_MIN)) * lat_scale).astype(np.int32), 0, (1 << lat_bits) - 1
    ).astype(np.uint32)
    lon_i = np.clip(
        ((lon - np.float32(LON_MIN)) * lon_scale).astype(np.int32), 0, (1 << lon_bits) - 1
    ).astype(np.uint32)
    if total % 2 == 0:
        return (_part1by1(lon_i) << np.uint32(1)) | _part1by1(lat_i)
    return _part1by1(lon_i) | (_part1by1(lat_i) << np.uint32(1))


def _bin_index(v: np.ndarray) -> np.ndarray:
    """Value -> sketch bin index, the fixed 513-bin log layout."""
    v = v.astype(np.float32)
    mag = np.abs(v)
    k = np.floor(
        np.log(np.maximum(mag, np.float32(MIN_MAG)) / np.float32(MIN_MAG))
        / np.float32(LOG_GAMMA)
    )
    k = np.clip(k, 0, BINS_PER_SIDE - 1).astype(np.int32)
    zero = BINS_PER_SIDE
    return np.where(
        v > np.float32(MIN_MAG), zero + 1 + k,
        np.where(v < -np.float32(MIN_MAG), zero - 1 - k, zero),
    ).astype(np.int32)


def edge_megakernel_ref(
    vals,
    ok,
    scores,
    thresholds,
    num_slots: int,
    *,
    sidx=None,
    lat=None,
    lon=None,
    codes=None,
    precision=None,
    ext_idx=(),
    sk_idx=(),
):
    """Numpy oracle for the fused pass (see module docstring for layout)."""
    vals = np.asarray(vals, dtype=np.float32)
    ok = np.asarray(ok, dtype=np.float32)
    scores = np.asarray(scores, dtype=np.float32)
    thresholds = np.asarray(thresholds, dtype=np.float32)
    c, n = vals.shape
    m = ok.shape[0]
    ext_idx, sk_idx = tuple(ext_idx), tuple(sk_idx)

    if sidx is None:
        if lat is None or lon is None or codes is None or precision is None:
            raise ValueError("latlon mode needs lat, lon, codes and precision")
        codes = np.asarray(codes, dtype=np.uint32)
        code = _encode(lat, lon, precision)
        pos = np.clip(np.searchsorted(codes, code), 0, len(codes) - 1)
        found = codes[pos] == code
        # unmatched codes land in a dump slot that is sliced off
        sidx_m = np.where(found, pos.astype(np.int64), num_slots)
        sidx_all = np.broadcast_to(sidx_m[None, :], (m, n))
    else:
        sidx_all = np.clip(np.asarray(sidx, dtype=np.int64), 0, num_slots)

    pop = np.zeros((m, num_slots), np.float32)
    keep_ct = np.zeros((m, num_slots), np.float32)
    s1 = np.zeros((m, c, num_slots), np.float32)
    s2 = np.zeros((m, c, num_slots), np.float32)
    e = len(ext_idx)
    mins = np.full((m, e, num_slots), np.inf, np.float32)
    maxs = np.full((m, e, num_slots), -np.inf, np.float32)
    bins = np.zeros((m, len(sk_idx), num_slots, NUM_BINS), np.float32)

    thr_ext = np.concatenate([thresholds, np.zeros((m, 1), np.float32)], axis=1)
    for j in range(m):
        s = sidx_all[j]
        in_range = s < num_slots
        t = thr_ext[j, s]
        keep = ok[j] * (scores[j] < t).astype(np.float32)
        sl = s[in_range]
        np.add.at(pop[j], sl, ok[j][in_range])
        np.add.at(keep_ct[j], sl, keep[in_range])
        for ci in range(c):
            np.add.at(s1[j, ci], sl, (keep * vals[ci])[in_range])
            np.add.at(s2[j, ci], sl, (keep * vals[ci] * vals[ci])[in_range])
        kept = in_range & (keep > 0.0)
        for ei, col in enumerate(ext_idx):
            np.minimum.at(mins[j, ei], s[kept], vals[col][kept])
            np.maximum.at(maxs[j, ei], s[kept], vals[col][kept])
        for ki, col in enumerate(sk_idx):
            b = _bin_index(vals[col])
            flat = s[in_range] * NUM_BINS + b[in_range]
            np.add.at(bins[j, ki].reshape(-1), flat, keep[in_range])
    return pop, keep_ct, s1, s2, mins, maxs, bins
