"""Pallas kernel: single-traversal fused edge pass.

The edge program used to walk a pane through four kernels — ``geohash``
encode, stratify ``assign``, ``sample_mask``, ``edge_reduce`` — with the
quantile-sketch binning done outside any kernel, re-touching HBM between
every stage.  This kernel fuses the whole per-tuple pipeline into ONE
pass: raw tuples go in, per-stratum sufficient-stat rows come out, and
the intermediate ``code``/``sidx``/``mask``/one-hot arrays never exist
outside VMEM.

Per (member × strata-block × points-block) grid cell:

    code    = morton(lat, lon)                      (latlon mode, in-VMEM)
    member  = code[:, None] == codes_tile[None, :]  -- or sidx == iota
    t_i     = Σ_s member · thr_tile                 (per-tuple threshold)
    keep_i  = ok_i · (score_i < t_i)
    rows    = [ok; keep; keep·y_c; keep·y_c²]       (2+2C, N_blk)
    out    += rows @ member                          (MXU, f32 accumulate)
    mins/maxs over where(member·keep, y, ±inf)       (extrema columns)
    bins   += (member·keep)ᵀ @ binhot                (sketch columns)

Sampling is a unified threshold compare: Bernoulli passes uniform scores
and per-stratum fraction thresholds; SRS passes within-stratum ranks and
allotted counts ``n_k`` (exact in f32 below 2²⁴); raw keep-all passes
zeros against ones.  Scores are non-negative, so the zero threshold a
tuple gathers in every strata block it is *not* a member of can never
produce a spurious keep.

Two membership modes:

* ``latlon`` — full fusion: the Morton encode of :mod:`...core.geohash`
  runs inside the kernel and membership is an equality test against the
  (sorted, unique) stratum code table tile.  Codes absent from the table
  (the overflow stratum) match nothing; the wrapper in ``ops.py``
  reconstructs overflow counts as residuals and leaves overflow *stat*
  rows zero — sound because the query layer zeroes overflow stats before
  estimating.
* ``sidx`` — a precomputed stratum index per tuple (SRS needs the sort
  for ranks anyway); all ``num_slots`` slots, overflow included, are
  covered exactly.

Inputs may arrive in a reduced-precision staging dtype (the pipeline
stages bf16 when configured); the kernel immediately casts value blocks
to f32 — every accumulator, dot and compare is f32.  This file never
names a reduced dtype: staging is the caller's choice, accumulation is
not (EDG004).

BlockSpec tiling: N_BLOCK×S_BLOCK from kernels/tiling.py (default
512×512).  VMEM per cell ≈ member + keep-weighted member (2 MiB) +
per-sketch-column binhot/out tiles (~2.6 MiB each); for many sketch
columns shrink S_BLOCK via ``tiling.set_block_override``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.estimators import SKETCH_NUM_BINS, sketch_bin_index
from ...core.geohash import encode
from ..tiling import ROW_ALIGN, kernel_blocks

# Sketch bin axis padded to the TPU lane width for the (S_blk, B_PAD)
# MXU output tile; the zero pad bins are sliced off host-side.
BINS_PAD = 128 * (-(-SKETCH_NUM_BINS // 128))

# Code-table pad sentinel: real geohash Morton codes fit in 30 bits
# (precision <= 6), so an all-ones uint32 can never match an encode.
CODE_SENTINEL = 0xFFFFFFFF


class MegaResult(NamedTuple):
    """Per-member per-stratum sufficient stats from one fused traversal.

    ``pop``/``keep`` are ok-tuple and kept-tuple counts per slot; ``s1``/
    ``s2`` are kept-tuple power sums per value column; ``mins``/``maxs``
    cover the extrema columns (identity ±inf where no tuple was kept);
    ``bins`` the sketch columns' kept-count log-histograms.
    """

    pop: jnp.ndarray  # (M, S) f32
    keep: jnp.ndarray  # (M, S) f32
    s1: jnp.ndarray  # (M, C, S) f32
    s2: jnp.ndarray  # (M, C, S) f32
    mins: jnp.ndarray  # (M, E, S) f32
    maxs: jnp.ndarray  # (M, E, S) f32
    bins: jnp.ndarray  # (M, K, S, SKETCH_NUM_BINS) f32


def _fused_body(
    n_step,
    member,
    vals,
    okv,
    keepv,
    out_refs,
    *,
    num_ext: int,
    num_sk: int,
    ext_idx: tuple,
    sk_idx: tuple,
    r_pad: int,
):
    """Shared stat emission given the (N_blk, S_blk) membership tile."""
    c = vals.shape[0]
    kv = keepv[None, :] * vals  # (C, N_blk)
    rows = jnp.concatenate([okv[None, :], keepv[None, :], kv, kv * vals], axis=0)
    r = rows.shape[0]
    if r_pad > r:
        rows = jnp.concatenate(
            [rows, jnp.zeros((r_pad - r, rows.shape[1]), jnp.float32)], axis=0
        )
    part = jax.lax.dot_general(
        rows, member, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (r_pad, S_blk)

    rows_ref = out_refs[0]
    nxt = 1
    mk = member * keepv[:, None]  # (N_blk, S_blk) kept membership
    if num_ext:
        mins_ref, maxs_ref = out_refs[1:3]
        nxt = 3
        kept = mk > 0.0
        mins_part = jnp.stack(
            [jnp.min(jnp.where(kept, vals[e][:, None], jnp.inf), axis=0) for e in ext_idx]
        )
        maxs_part = jnp.stack(
            [jnp.max(jnp.where(kept, vals[e][:, None], -jnp.inf), axis=0) for e in ext_idx]
        )
    bins_parts = []
    for k in sk_idx:
        b = sketch_bin_index(vals[k])  # (N_blk,) int32
        iota_b = jax.lax.broadcasted_iota(jnp.int32, (b.shape[0], BINS_PAD), 1)
        binhot = (b[:, None] == iota_b).astype(jnp.float32)
        bins_parts.append(
            jax.lax.dot_general(
                mk, binhot, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )  # (S_blk, BINS_PAD)
        )

    @pl.when(n_step == 0)
    def _init():
        rows_ref[...] = part[None]
        if num_ext:
            mins_ref[...] = mins_part[None]
            maxs_ref[...] = maxs_part[None]
        for i in range(num_sk):
            out_refs[nxt + i][...] = bins_parts[i][None]

    @pl.when(n_step != 0)
    def _acc():
        rows_ref[...] += part[None]
        if num_ext:
            mins_ref[...] = jnp.minimum(mins_ref[...], mins_part[None])
            maxs_ref[...] = jnp.maximum(maxs_ref[...], maxs_part[None])
        for i in range(num_sk):
            out_refs[nxt + i][...] += bins_parts[i][None]


def _threshold_keep(member, okv, scores, thr_tile):
    """Per-tuple gathered threshold -> keep weights (N_blk,) f32."""
    t = jnp.sum(member * thr_tile[None, :], axis=1)  # 0 off-membership
    return okv * (scores < t).astype(jnp.float32)


def _mega_kernel_latlon(
    lat_ref, lon_ref, codes_ref, vals_ref, ok_ref, scores_ref, thr_ref, *out_refs, spec
):
    n_step = pl.program_id(2)
    code = encode(lat_ref[...].astype(jnp.float32), lon_ref[...].astype(jnp.float32), spec["precision"])
    member = (code[:, None] == codes_ref[...][None, :]).astype(jnp.float32)
    vals = vals_ref[...].astype(jnp.float32)
    okv = ok_ref[...][0].astype(jnp.float32)
    keepv = _threshold_keep(member, okv, scores_ref[...][0].astype(jnp.float32), thr_ref[...][0])
    _fused_body(
        n_step, member, vals, okv, keepv, out_refs,
        num_ext=spec["num_ext"], num_sk=spec["num_sk"],
        ext_idx=spec["ext_idx"], sk_idx=spec["sk_idx"], r_pad=spec["r_pad"],
    )


def _mega_kernel_sidx(
    sidx_ref, vals_ref, ok_ref, scores_ref, thr_ref, *out_refs, spec
):
    n_step = pl.program_id(2)
    sidx = sidx_ref[...][0]  # (N_blk,) int32
    s_base = pl.program_id(1) * spec["s_block"]
    cols = s_base + jax.lax.broadcasted_iota(jnp.int32, (sidx.shape[0], spec["s_block"]), 1)
    member = (sidx[:, None] == cols).astype(jnp.float32)
    vals = vals_ref[...].astype(jnp.float32)
    okv = ok_ref[...][0].astype(jnp.float32)
    keepv = _threshold_keep(member, okv, scores_ref[...][0].astype(jnp.float32), thr_ref[...][0])
    _fused_body(
        n_step, member, vals, okv, keepv, out_refs,
        num_ext=spec["num_ext"], num_sk=spec["num_sk"],
        ext_idx=spec["ext_idx"], sk_idx=spec["sk_idx"], r_pad=spec["r_pad"],
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_slots", "precision", "ext_idx", "sk_idx", "n_block", "s_block", "interpret",
    ),
)
def edge_megakernel_pallas(
    vals: jnp.ndarray,  # (C, N) any float dtype (bf16 staging allowed)
    ok: jnp.ndarray,  # (M, N) validity & ROI, 0/1
    scores: jnp.ndarray,  # (M, N) f32, >= 0
    thresholds: jnp.ndarray,  # (M, num_slots) f32 per-slot thresholds
    num_slots: int,
    *,
    sidx: jnp.ndarray | None = None,  # (M, N) int32 ("sidx" mode)
    lat: jnp.ndarray | None = None,  # (N,) ("latlon" mode)
    lon: jnp.ndarray | None = None,
    codes: jnp.ndarray | None = None,  # (num_strata,) sorted uint32 table
    precision: int | None = None,
    ext_idx: tuple = (),
    sk_idx: tuple = (),
    n_block: int | None = None,
    s_block: int | None = None,
    interpret: bool = False,
) -> MegaResult:
    """One fused traversal -> :class:`MegaResult` (see module docstring).

    In latlon mode the code table covers ``codes.shape[0]`` strata; slots
    ``>= codes.shape[0]`` of the output (the overflow slot among them)
    stay zero / ±inf and the caller owns the residual overflow counts.
    """
    if n_block is None or s_block is None:
        dn, ds = kernel_blocks("edge_megakernel")
        n_block = n_block or dn
        s_block = s_block or ds
    c, n = vals.shape
    m = ok.shape[0]
    r = 2 + 2 * c
    r_pad = ((r + ROW_ALIGN - 1) // ROW_ALIGN) * ROW_ALIGN
    num_ext, num_sk = len(ext_idx), len(sk_idx)

    pad_n = (-n) % n_block
    s_pad = ((num_slots + s_block - 1) // s_block) * s_block
    vals_p = jnp.pad(vals, ((0, 0), (0, pad_n)))
    ok_p = jnp.pad(ok.astype(jnp.float32), ((0, 0), (0, pad_n)))
    scores_p = jnp.pad(scores.astype(jnp.float32), ((0, 0), (0, pad_n)))
    thr_p = jnp.pad(thresholds.astype(jnp.float32), ((0, 0), (0, s_pad - num_slots)))
    n_tot = n + pad_n
    grid = (m, s_pad // s_block, n_tot // n_block)

    spec = dict(
        precision=precision, num_ext=num_ext, num_sk=num_sk,
        ext_idx=tuple(ext_idx), sk_idx=tuple(sk_idx), r_pad=r_pad, s_block=s_block,
    )
    if sidx is not None:
        kern = functools.partial(_mega_kernel_sidx, spec=spec)
        ins = [
            jnp.pad(sidx.astype(jnp.int32), ((0, 0), (0, pad_n)), constant_values=-1),
            vals_p, ok_p, scores_p, thr_p,
        ]
        in_specs = [
            pl.BlockSpec((1, n_block), lambda m_, s, i: (m_, i)),
            pl.BlockSpec((c, n_block), lambda m_, s, i: (0, i)),
            pl.BlockSpec((1, n_block), lambda m_, s, i: (m_, i)),
            pl.BlockSpec((1, n_block), lambda m_, s, i: (m_, i)),
            pl.BlockSpec((1, s_block), lambda m_, s, i: (m_, s)),
        ]
    else:
        if lat is None or lon is None or codes is None or precision is None:
            raise ValueError("latlon mode needs lat, lon, codes and precision")
        kern = functools.partial(_mega_kernel_latlon, spec=spec)
        codes_p = jnp.pad(
            codes.astype(jnp.uint32), (0, s_pad - codes.shape[0]),
            constant_values=jnp.asarray(CODE_SENTINEL, jnp.uint32),
        )
        ins = [
            jnp.pad(lat.astype(jnp.float32), (0, pad_n)),
            jnp.pad(lon.astype(jnp.float32), (0, pad_n)),
            codes_p, vals_p, ok_p, scores_p, thr_p,
        ]
        in_specs = [
            pl.BlockSpec((n_block,), lambda m_, s, i: (i,)),
            pl.BlockSpec((n_block,), lambda m_, s, i: (i,)),
            pl.BlockSpec((s_block,), lambda m_, s, i: (s,)),
            pl.BlockSpec((c, n_block), lambda m_, s, i: (0, i)),
            pl.BlockSpec((1, n_block), lambda m_, s, i: (m_, i)),
            pl.BlockSpec((1, n_block), lambda m_, s, i: (m_, i)),
            pl.BlockSpec((1, s_block), lambda m_, s, i: (m_, s)),
        ]

    out_shape = [jax.ShapeDtypeStruct((m, r_pad, s_pad), jnp.float32)]
    out_specs = [pl.BlockSpec((1, r_pad, s_block), lambda m_, s, i: (m_, 0, s))]
    if num_ext:
        for _ in range(2):
            out_shape.append(jax.ShapeDtypeStruct((m, num_ext, s_pad), jnp.float32))
            out_specs.append(pl.BlockSpec((1, num_ext, s_block), lambda m_, s, i: (m_, 0, s)))
    for _ in range(num_sk):
        out_shape.append(jax.ShapeDtypeStruct((m, s_pad, BINS_PAD), jnp.float32))
        out_specs.append(pl.BlockSpec((1, s_block, BINS_PAD), lambda m_, s, i: (m_, s, 0)))

    outs = pl.pallas_call(
        kern,
        out_shape=tuple(out_shape),
        grid=grid,
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        interpret=interpret,
    )(*ins)

    rows = outs[0]
    nxt = 1
    if num_ext:
        mins = outs[1][:, :, :num_slots]
        maxs = outs[2][:, :, :num_slots]
        nxt = 3
    else:
        mins = jnp.zeros((m, 0, num_slots), jnp.float32)
        maxs = jnp.zeros((m, 0, num_slots), jnp.float32)
    if num_sk:
        bins = jnp.stack(
            [outs[nxt + i][:, :num_slots, :SKETCH_NUM_BINS] for i in range(num_sk)],
            axis=1,
        )
    else:
        bins = jnp.zeros((m, 0, num_slots, SKETCH_NUM_BINS), jnp.float32)
    return MegaResult(
        pop=rows[:, 0, :num_slots],
        keep=rows[:, 1, :num_slots],
        s1=rows[:, 2 : 2 + c, :num_slots],
        s2=rows[:, 2 + c : 2 + 2 * c, :num_slots],
        mins=mins,
        maxs=maxs,
        bins=bins,
    )
