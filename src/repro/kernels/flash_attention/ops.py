"""Public wrapper: layout/GQA handling + padding + interpret fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import KV_BLOCK, Q_BLOCK, flash_attention_pallas


def flash_attention(q, k, v):
    """q: (B, S, H, dh); k/v: (B, S, K, dh); causal. Returns (B, S, H, dh).

    Pads head_dim to a 128 multiple and seq to the block size; GQA is
    resolved inside the kernel's BlockSpec index maps.
    """
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / (dh**0.5)
    dh_p = ((dh + 127) // 128) * 128
    s_p = ((S + max(Q_BLOCK, KV_BLOCK) - 1) // max(Q_BLOCK, KV_BLOCK)) * max(Q_BLOCK, KV_BLOCK)

    def prep(x, heads):
        x = jnp.pad(x, ((0, 0), (0, s_p - S), (0, 0), (0, dh_p - dh)))
        return x.transpose(0, 2, 1, 3).reshape(B * heads, s_p, dh_p)

    qf = prep(q, H)
    kf = prep(k, K)
    vf = prep(v, K)
    interpret = jax.default_backend() != "tpu"
    o = flash_attention_pallas(qf, kf, vf, groups=G, scale=scale, interpret=interpret)
    o = o.reshape(B, H, s_p, dh_p).transpose(0, 2, 1, 3)
    return o[:, :S, :, :dh]
