"""Pallas kernel: blocked causal flash attention (forward).

Grid (BH, n_q, n_kv) with the KV dimension innermost; running
(max, normalizer, accumulator) live in VMEM scratch across sequential KV
steps.  Upper-triangle KV blocks are skipped entirely with pl.when, so
compiled FLOPs track the causal optimum.  GQA is handled in the BlockSpec
index maps (kv block index = query-head block // group size) — no KV
repetition in HBM.

Block sizes are MXU-aligned (128 multiples); head_dim is padded to 128 in
the wrapper (zamba's dh=112).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 256
KV_BLOCK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki <= qi)  # causal: skip fully-masked KV blocks
    def _step():
        q = q_ref[0].astype(jnp.float32)  # (Qb, dh)
        k = k_ref[0].astype(jnp.float32)  # (Kb, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (Qb, Kb)
        qpos = qi * Q_BLOCK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * KV_BLOCK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
        acc_ref[...] = alpha[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "scale", "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,  # (BHq, S, dh) query heads flattened
    k: jnp.ndarray,  # (BHkv, S, dh)
    v: jnp.ndarray,
    groups: int,  # q heads per kv head (GQA)
    scale: float,
    interpret: bool = False,
) -> jnp.ndarray:
    bhq, s, dh = q.shape
    assert s % Q_BLOCK == 0 and s % KV_BLOCK == 0, s
    n_q = s // Q_BLOCK
    n_kv = s // KV_BLOCK
    grid = (bhq, n_q, n_kv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, n_kv=n_kv),
        out_shape=jax.ShapeDtypeStruct((bhq, s, dh), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q_BLOCK, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, KV_BLOCK, dh), lambda bh, qi, ki: (bh // groups, ki, 0)),
            pl.BlockSpec((1, KV_BLOCK, dh), lambda bh, qi, ki: (bh // groups, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_BLOCK, dh), lambda bh, qi, ki: (bh, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((Q_BLOCK,), jnp.float32),
            pltpu.VMEM((Q_BLOCK,), jnp.float32),
            pltpu.VMEM((Q_BLOCK, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
