"""Oracle: dense causal SDPA with GQA in pure numpy (f32 softmax).

Jax-free by contract (edgelint EDG006).  Inputs convert through
``np.asarray`` (low-precision jax arrays arrive as their ``ml_dtypes``
numpy dtypes); all math runs in f32, with the softmax weights rounded
through the value dtype — mirroring the kernel's ``w.astype(v.dtype)``
recombination — and the output cast back to the input dtype.
"""

from __future__ import annotations

import numpy as np


def flash_attention_ref(q, k, v):
    """q: (B, S, H, dh); k/v: (B, S, K, dh); H = K * G. Causal."""
    q_np, k_np, v_np = np.asarray(q), np.asarray(k), np.asarray(v)
    in_dtype = v_np.dtype
    qf = q_np.astype(np.float32)
    kf = k_np.astype(np.float32)
    vf = v_np.astype(np.float32)
    B, S, H, dh = qf.shape
    K = kf.shape[2]
    G = H // K
    qg = qf.reshape(B, S, K, G, dh)
    s = np.einsum("bqkgd,btkd->bkgqt", qg, kf) / np.float32(dh**0.5)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask, s, np.float32(-1e30))
    s = s - s.max(axis=-1, keepdims=True)
    e = np.exp(s)
    w = e / e.sum(axis=-1, keepdims=True)
    # round weights through the kernel's recombination dtype, then back up
    w = w.astype(in_dtype).astype(np.float32)
    o = np.einsum("bkgqt,btkd->bqkgd", w, vf)
    return o.reshape(B, S, H, dh).astype(in_dtype)
