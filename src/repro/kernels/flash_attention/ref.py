"""Oracle: dense causal SDPA with GQA (pure jnp, f32 softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v):
    """q: (B, S, H, dh); k/v: (B, S, K, dh); H = K * G. Causal."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, dh)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) / (dh**0.5)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqt,btkd->bqkgd", w, v)
    return o.reshape(B, S, H, dh)
