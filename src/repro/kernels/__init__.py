"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

The paper's edge binary optimizes (a) geohash computation + neighborhood
lookup and (b) parallel per-stratum grouping/sampling — its rayon/FxHash
hot loop.  On TPU those become:

  geohash/          fused quantize + Morton interleave (VPU integer)
  stratified_stats/ per-stratum {count, Σy, Σy²} as blocked one-hot
                    matmuls on the MXU (hash-aggregation replacement)
  edge_reduce/      the multi-column generalization of stratified_stats:
                    one (1+2C, N_blk) @ onehot MXU pass yields every fused
                    query column's moments — the preagg hot path behind
                    ``PipelineConfig(backend="pallas")``
  sample_mask/      fused per-stratum threshold gather (one-hot MXU) +
                    Bernoulli keep mask + Horvitz-Thompson weights
  edge_megakernel/  the single-traversal fusion of the whole per-tuple
                    pipeline: in-kernel geohash + stratify + threshold
                    sampling + moments/extrema/sketch stat rows in ONE
                    pass — the hot path behind
                    ``PipelineConfig(backend="fused")``
  flash_attention/  blocked causal attention for the LM serving substrate

Every kernel has ops.py (jit'd wrapper with an interpret switch) and
ref.py (pure-jnp oracle); tests sweep shapes/dtypes in interpret mode and
assert allclose against the oracle.  Block tilings live in tiling.py
(single source, override hook for TPU tuning).
"""

from . import (
    edge_megakernel,
    edge_reduce,
    flash_attention,
    geohash,
    sample_mask,
    stratified_stats,
    tiling,
)

__all__ = [
    "edge_megakernel",
    "edge_reduce",
    "flash_attention",
    "geohash",
    "sample_mask",
    "stratified_stats",
    "tiling",
]
