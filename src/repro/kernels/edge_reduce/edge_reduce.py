"""Pallas kernel: fused multi-column per-stratum moment reduction.

Generalizes ``stratified_stats`` (one column, 3 moment rows) to an entire
fusion group: every fused query column's moment rows are stacked into one

    rows = [ m ; m·y₁ ; m·y₁² ; m·y₂ ; m·y₂² ; … ]        (R, N),  R = 1+2C

matrix and contracted against the one-hot stratum membership tile in a
single MXU pass per (strata-block × points-block) grid cell:

    out[R, S_blk] += rows (R, N_blk) @ onehot (N_blk, S_blk)

so ONE window traversal produces the raw power sums {n, Σy_c, Σy_c²} of
every column at once — the per-column ``jax.ops.segment_sum`` path touches
the window 3·C times.  The count row is shared across columns (it depends
only on the mask), which is where the fused win comes from.

The grid's N dimension revisits the same output block sequentially, so VMEM
holds one (R_pad, S_blk) accumulator plus the one-hot tile.  R is padded to
the f32 sublane multiple (8) so the accumulator tile is layout-aligned; the
zero padding rows contract to zeros and are sliced off host-side.

BlockSpec tiling: N_BLOCK=512 points × S_BLOCK=512 strata -> one-hot tile
512×512 f32 = 1 MiB in VMEM, MXU-aligned (multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sourced from the shared tiling table (kernels/tiling.py); re-exported
# here so existing `from ...edge_reduce import N_BLOCK` imports keep
# working.  ROW_ALIGN: f32 sublane multiple for the (R, S_blk) tile.
from ..tiling import ROW_ALIGN, kernel_blocks

N_BLOCK, S_BLOCK = kernel_blocks("edge_reduce")


def _moment_rows(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Stack [m, m·y_c, m·y_c²] rows for a (C, N) column block -> (1+2C, N).

    The single definition of the row layout shared by the Pallas kernel and
    the segment fast path in ``ops.py`` — the host-side slice offsets (rows
    1..C are Σy, rows C+1..2C are Σy²) depend on this ordering.  The numpy
    oracle in ``ref.py`` mirrors it independently (refs are jax-free).
    """
    m = mask.astype(jnp.float32)
    v = values.astype(jnp.float32)
    my = m[None, :] * v
    return jnp.concatenate([m[None, :], my, my * v], axis=0)


def _reduce_kernel(sidx_ref, rows_ref, out_ref):
    n_step = pl.program_id(1)
    sidx = sidx_ref[...]  # (N_blk,)
    s_base = pl.program_id(0) * S_BLOCK
    cols = s_base + jax.lax.broadcasted_iota(jnp.int32, (sidx.shape[0], S_BLOCK), 1)
    onehot = (sidx[:, None] == cols).astype(jnp.float32)
    part = jax.lax.dot_general(
        rows_ref[...], onehot, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (R_pad, S_blk)

    @pl.when(n_step == 0)
    def _init():
        out_ref[...] = part

    @pl.when(n_step != 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("num_slots", "interpret"))
def edge_reduce_pallas(
    stratum_idx: jnp.ndarray,
    values: jnp.ndarray,
    mask: jnp.ndarray,
    num_slots: int,
    interpret: bool = False,
):
    """(sidx (N,), values (C, N), mask (N,)) -> (count (S,), s1 (C, S), s2 (C, S)).

    Raw per-stratum power sums of the masked tuples for every column in one
    pass; masked-out points contribute nothing (their rows are zeroed), so
    sampling masks compose directly.  ``S = num_slots`` includes the
    overflow stratum.
    """
    c, n = values.shape
    rows = _moment_rows(values, mask)  # (1+2C, N)
    r = rows.shape[0]
    pad_n = (-n) % N_BLOCK
    pad_r = (-r) % ROW_ALIGN
    s_slots = ((num_slots + S_BLOCK - 1) // S_BLOCK) * S_BLOCK
    sidx = jnp.pad(stratum_idx.astype(jnp.int32), (0, pad_n), constant_values=-1)
    rows = jnp.pad(rows, ((0, pad_r), (0, pad_n)))
    r_pad = rows.shape[0]
    grid = (s_slots // S_BLOCK, sidx.shape[0] // N_BLOCK)
    out = pl.pallas_call(
        _reduce_kernel,
        out_shape=jax.ShapeDtypeStruct((r_pad, s_slots), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_BLOCK,), lambda s, i: (i,)),
            pl.BlockSpec((r_pad, N_BLOCK), lambda s, i: (0, i)),
        ],
        out_specs=pl.BlockSpec((r_pad, S_BLOCK), lambda s, i: (0, s)),
        interpret=interpret,
    )(sidx, rows)
    return out[0, :num_slots], out[1 : 1 + c, :num_slots], out[1 + c : 1 + 2 * c, :num_slots]
