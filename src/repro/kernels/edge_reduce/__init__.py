from .ops import edge_reduce

__all__ = ["edge_reduce"]
