"""Oracle: stacked per-stratum power sums in pure numpy.

Jax-free by contract (edgelint EDG006): the reference must not share code —
or bugs — with the ops side.  Accumulation is f32 in input order
(``np.add.at``), matching the kernel's accumulation dtype; order-of-summation
differences vs the device reductions are covered by the parity tolerances.
"""

from __future__ import annotations

import numpy as np


def _moment_rows_np(values: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Stack [m, m\u00b7y_c, m\u00b7y_c\u00b2] rows for a (C, N) column block -> (1+2C, N)."""
    m = np.asarray(mask).astype(np.float32)
    v = np.asarray(values).astype(np.float32)
    my = m[None, :] * v
    return np.concatenate([m[None, :], my, my * v], axis=0)


def edge_reduce_ref(stratum_idx, values, mask, num_slots: int):
    """-> (count (S,), s1 (C, S), s2 (C, S)) raw per-stratum power sums."""
    sidx = np.asarray(stratum_idx).astype(np.int64)
    c = np.asarray(values).shape[0]
    rows = _moment_rows_np(values, mask)  # (1+2C, N)
    out = np.zeros((num_slots, rows.shape[0]), np.float32)  # (S, R)
    np.add.at(out, sidx, rows.T)
    return out[:, 0], out[:, 1 : 1 + c].T, out[:, 1 + c : 1 + 2 * c].T
