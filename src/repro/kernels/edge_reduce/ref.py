"""Oracles: per-column segment sums (legacy) and one-pass stacked reduce.

``edge_reduce_ref`` is the bit-level oracle for the Pallas kernel *and* the
portable fused fast path: all 1+2C moment rows go through ONE
``segment_sum`` (a single sort/scatter pass over the window) instead of the
3·C independent segment reductions of the per-column path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _moment_rows(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Stack [m, m·y_c, m·y_c²] rows for a (C, N) column block -> (1+2C, N).

    The single definition of the row layout shared by the Pallas kernel and
    the oracles — the host-side slice offsets (rows 1..C are Σy, rows
    C+1..2C are Σy²) depend on this ordering.
    """
    m = mask.astype(jnp.float32)
    v = values.astype(jnp.float32)
    my = m[None, :] * v
    return jnp.concatenate([m[None, :], my, my * v], axis=0)


def edge_reduce_ref(stratum_idx, values, mask, num_slots: int):
    """Single-pass stacked oracle: one (N, R) segment_sum for all columns."""
    c = values.shape[0]
    rows = _moment_rows(values, mask)  # (1+2C, N)
    out = jax.ops.segment_sum(rows.T, stratum_idx, num_segments=num_slots)  # (S, R)
    return out[:, 0], out[:, 1 : 1 + c].T, out[:, 1 + c : 1 + 2 * c].T


def edge_reduce_percol(stratum_idx, values, mask, num_slots: int):
    """The per-column segment path (3 reductions per column) — the baseline
    the fused kernel is benchmarked against."""
    m = mask.astype(jnp.float32)
    count = jax.ops.segment_sum(m, stratum_idx, num_segments=num_slots)
    s1, s2 = [], []
    for col in values:
        y = col.astype(jnp.float32)
        s1.append(jax.ops.segment_sum(m * y, stratum_idx, num_segments=num_slots))
        s2.append(jax.ops.segment_sum(m * y * y, stratum_idx, num_segments=num_slots))
    return count, jnp.stack(s1), jnp.stack(s2)
