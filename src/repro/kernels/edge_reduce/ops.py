"""Public wrapper: Pallas on TPU, one-pass stacked segment reduce elsewhere.

Off-TPU the Pallas interpreter is a correctness tool, not a perf path, so
the auto mode (``interpret=None``) lowers to the fused single-pass
``segment_sum`` path instead — the pipeline's ``backend="pallas"`` stays
portable (and still beats the per-column segment path by running one
sort/scatter for the whole fusion group).  Pass ``interpret=True`` to force
the interpreted kernel (parity tests).

Both jnp implementations live here (not in ``ref.py``): references are
jax-free numpy oracles (edgelint EDG006), so anything jitted or used as a
device fast path belongs on the ops side.  ``edge_reduce_percol`` is the
per-column baseline the fused kernel is benchmarked against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .edge_reduce import _moment_rows, edge_reduce_pallas


def edge_reduce(stratum_idx, values, mask, num_slots: int, interpret: bool | None = None):
    """-> (count (S,), s1 (C, S), s2 (C, S)) raw per-stratum power sums."""
    if interpret is None:
        if jax.default_backend() != "tpu":
            return _edge_reduce_segment(stratum_idx, values, mask, num_slots)
        interpret = False
    return edge_reduce_pallas(stratum_idx, values, mask, num_slots, interpret=interpret)


def _edge_reduce_segment(stratum_idx, values, mask, num_slots: int):
    """Single-pass stacked fast path: one (N, R) segment_sum for all columns."""
    c = values.shape[0]
    rows = _moment_rows(values, mask)  # (1+2C, N)
    out = jax.ops.segment_sum(rows.T, stratum_idx, num_segments=num_slots)  # (S, R)
    return out[:, 0], out[:, 1 : 1 + c].T, out[:, 1 + c : 1 + 2 * c].T


def edge_reduce_percol(stratum_idx, values, mask, num_slots: int):
    """The per-column segment path (3 reductions per column) — the baseline
    the fused kernel is benchmarked against."""
    m = mask.astype(jnp.float32)
    count = jax.ops.segment_sum(m, stratum_idx, num_segments=num_slots)
    s1, s2 = [], []
    for col in values:
        y = col.astype(jnp.float32)
        s1.append(jax.ops.segment_sum(m * y, stratum_idx, num_segments=num_slots))
        s2.append(jax.ops.segment_sum(m * y * y, stratum_idx, num_segments=num_slots))
    return count, jnp.stack(s1), jnp.stack(s2)
