"""Public wrapper: Pallas on TPU, one-pass stacked segment reduce elsewhere.

Off-TPU the Pallas interpreter is a correctness tool, not a perf path, so
the auto mode (``interpret=None``) lowers to the fused single-pass
``segment_sum`` oracle instead — the pipeline's ``backend="pallas"`` stays
portable (and still beats the per-column segment path by running one
sort/scatter for the whole fusion group).  Pass ``interpret=True`` to force
the interpreted kernel (parity tests).
"""

from __future__ import annotations

import jax

from .edge_reduce import edge_reduce_pallas
from .ref import edge_reduce_ref


def edge_reduce(stratum_idx, values, mask, num_slots: int, interpret: bool | None = None):
    """-> (count (S,), s1 (C, S), s2 (C, S)) raw per-stratum power sums."""
    if interpret is None:
        if jax.default_backend() != "tpu":
            return edge_reduce_ref(stratum_idx, values, mask, num_slots)
        interpret = False
    return edge_reduce_pallas(stratum_idx, values, mask, num_slots, interpret=interpret)
