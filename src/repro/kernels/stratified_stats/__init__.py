from .ops import stratified_stats

__all__ = ["stratified_stats"]
