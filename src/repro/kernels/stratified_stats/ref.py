"""Oracle: segment-sum per-stratum moments (pure jnp)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stratified_stats_ref(stratum_idx, values, mask, num_slots: int):
    m = mask.astype(jnp.float32)
    y = values.astype(jnp.float32)
    count = jax.ops.segment_sum(m, stratum_idx, num_segments=num_slots)
    s1 = jax.ops.segment_sum(m * y, stratum_idx, num_segments=num_slots)
    s2 = jax.ops.segment_sum(m * y * y, stratum_idx, num_segments=num_slots)
    return count, s1, s2
