"""Oracle: per-stratum moments in pure numpy.

Jax-free by contract (edgelint EDG006).  Accumulation is f32 in input order
(``np.add.at``), matching the kernel's accumulation dtype; out-of-range
stratum indices are dropped, mirroring ``jax.ops.segment_sum`` semantics.
"""

from __future__ import annotations

import numpy as np


def stratified_stats_ref(stratum_idx, values, mask, num_slots: int):
    sidx = np.asarray(stratum_idx).astype(np.int64)
    m = np.asarray(mask).astype(np.float32)
    y = np.asarray(values).astype(np.float32)
    ok = (sidx >= 0) & (sidx < num_slots)
    sidx, m, y = sidx[ok], m[ok], y[ok]
    count = np.zeros(num_slots, np.float32)
    s1 = np.zeros(num_slots, np.float32)
    s2 = np.zeros(num_slots, np.float32)
    np.add.at(count, sidx, m)
    np.add.at(s1, sidx, m * y)
    np.add.at(s2, sidx, m * y * y)
    return count, s1, s2
