"""Jit'd public wrapper with off-TPU interpret fallback."""

from __future__ import annotations

import jax

from .stratified_stats import stratified_stats_pallas


def stratified_stats(stratum_idx, values, mask, num_slots: int):
    interpret = jax.default_backend() != "tpu"
    return stratified_stats_pallas(stratum_idx, values, mask, num_slots, interpret=interpret)
