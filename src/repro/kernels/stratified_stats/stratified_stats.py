"""Pallas kernel: per-stratum {count, Σy, Σy²} via one-hot MXU matmuls.

TPU adaptation of the paper's hash-map aggregation: instead of scattering
into per-stratum buckets (no efficient dynamic scatter on the VPU), each
(points-block × strata-block) grid cell builds a one-hot membership tile
and contracts it against [1, y, y²] rows on the MXU:

    moments[3, S_blk] += [ones; y; y*y] (3, N_blk) @ onehot (N_blk, S_blk)

The grid's N dimension accumulates into the same output block (sequential
revisiting), so VMEM holds one (3, S_blk) accumulator + one one-hot tile.

BlockSpec tiling: N_BLOCK=512 points x S_BLOCK=512 strata -> one-hot tile
512x512 f32 = 1 MiB in VMEM, MXU-aligned (multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sourced from the shared tiling table (kernels/tiling.py); re-exported
# so existing imports of these constants keep working.
from ..tiling import kernel_blocks

N_BLOCK, S_BLOCK = kernel_blocks("stratified_stats")


def _stats_kernel(sidx_ref, val_ref, mask_ref, out_ref):
    n_step = pl.program_id(1)
    sidx = sidx_ref[...]  # (N_blk,)
    y = val_ref[...].astype(jnp.float32)
    m = mask_ref[...].astype(jnp.float32)
    s_base = pl.program_id(0) * S_BLOCK
    cols = s_base + jax.lax.broadcasted_iota(jnp.int32, (sidx.shape[0], S_BLOCK), 1)
    onehot = (sidx[:, None] == cols).astype(jnp.float32)
    rows = jnp.stack([m, m * y, m * y * y], axis=0)  # (3, N_blk)
    part = jax.lax.dot_general(
        rows, onehot, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (3, S_blk)
    @pl.when(n_step == 0)
    def _init():
        out_ref[...] = part

    @pl.when(n_step != 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("num_slots", "interpret"))
def stratified_stats_pallas(
    stratum_idx: jnp.ndarray,
    values: jnp.ndarray,
    mask: jnp.ndarray,
    num_slots: int,
    interpret: bool = False,
):
    """-> (count, sum, sumsq) each (num_slots,) f32.

    Masked-out points contribute nothing (their one-hot row is zeroed via
    the mask factor), so sampling masks compose directly.
    """
    n = stratum_idx.shape[0]
    pad_n = (-n) % N_BLOCK
    s_slots = ((num_slots + S_BLOCK - 1) // S_BLOCK) * S_BLOCK
    sidx = jnp.pad(stratum_idx.astype(jnp.int32), (0, pad_n), constant_values=-1)
    vals = jnp.pad(values.astype(jnp.float32), (0, pad_n))
    msk = jnp.pad(mask.astype(jnp.float32), (0, pad_n))
    grid = (s_slots // S_BLOCK, sidx.shape[0] // N_BLOCK)
    out = pl.pallas_call(
        _stats_kernel,
        out_shape=jax.ShapeDtypeStruct((3, s_slots), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_BLOCK,), lambda s, i: (i,)),
            pl.BlockSpec((N_BLOCK,), lambda s, i: (i,)),
            pl.BlockSpec((N_BLOCK,), lambda s, i: (i,)),
        ],
        out_specs=pl.BlockSpec((3, S_BLOCK), lambda s, i: (0, s)),
        interpret=interpret,
    )(sidx, vals, msk)
    return out[0, :num_slots], out[1, :num_slots], out[2, :num_slots]
