"""Single source of kernel tiling constants.

Every Pallas kernel in this package tiles its inputs into ``(N_BLOCK,)``
point blocks and ``(S_BLOCK,)`` stratum-slot blocks.  The per-kernel
defaults used to be duplicated literals in each kernel module; they now
live here so a TPU tuning pass edits one table (or installs a runtime
override) instead of chasing copies.

``ROW_ALIGN`` is the row-count alignment for stacked stat-row matrices
fed to the MXU (pad ``R`` up to a multiple of 8 so the ``(R, N)`` operand
tiles cleanly).

Overrides are process-wide and must be installed *before* the first call
of the kernel they target: the jitted wrappers resolve block sizes at
trace time, so a kernel that has already traced keeps its old blocks
until its jit cache is dropped.  This is a process-start tuning knob
(e.g. a TPU sweep harness), not a per-call parameter — per-call control
is the ``block``/``n_block``/``s_block`` arguments the wrappers already
take.

Stdlib-only on purpose: this module sits inside the EDG001-checked
import closure of ``repro.kernels``.
"""

from __future__ import annotations

ROW_ALIGN = 8

# kernel name -> (N_BLOCK, S_BLOCK)
_DEFAULT_BLOCKS: dict[str, tuple[int, int]] = {
    "stratified_stats": (512, 512),
    "edge_reduce": (512, 512),
    "sample_mask": (1024, 512),
    "edge_megakernel": (512, 512),
    # geohash is 1-D (no stratum axis); S_BLOCK is unused but kept for
    # table uniformity.
    "geohash": (2048, 1),
}

_overrides: dict[str, tuple[int, int]] = {}


def kernel_blocks(kernel: str) -> tuple[int, int]:
    """Return ``(n_block, s_block)`` for ``kernel`` (override-aware)."""
    if kernel in _overrides:
        return _overrides[kernel]
    try:
        return _DEFAULT_BLOCKS[kernel]
    except KeyError:
        raise KeyError(
            f"unknown kernel {kernel!r}; known: {sorted(_DEFAULT_BLOCKS)}"
        ) from None


def set_block_override(
    kernel: str, *, n_block: int | None = None, s_block: int | None = None
) -> None:
    """Install a process-wide block-size override for one kernel.

    Must run before the kernel's first trace (see module docstring).
    Blocks should stay multiples of the TPU lane width (128); that is
    the caller's responsibility — this table does not validate against
    a particular generation's tile shapes.
    """
    cur_n, cur_s = kernel_blocks(kernel)
    _overrides[kernel] = (
        int(n_block) if n_block is not None else cur_n,
        int(s_block) if s_block is not None else cur_s,
    )


def clear_block_overrides() -> None:
    """Drop all overrides (tests / tuning sweeps)."""
    _overrides.clear()
