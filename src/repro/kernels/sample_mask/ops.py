"""Jit'd public wrapper with off-TPU interpret fallback."""

from __future__ import annotations

import jax

from .sample_mask import sample_mask_pallas


def sample_mask(stratum_idx, uniforms, fractions):
    interpret = jax.default_backend() != "tpu"
    return sample_mask_pallas(stratum_idx, uniforms, fractions, interpret=interpret)
