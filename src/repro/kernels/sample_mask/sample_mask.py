"""Pallas kernel: fused EdgeSOS Bernoulli selection.

Fuses the per-tuple hot loop of Algorithm 1 (bernoulli mode): gather each
tuple's per-stratum fraction f_k, draw keep = (u < f_k), emit the
Horvitz-Thompson weight 1/f_k.  The gather is expressed as a one-hot MXU
contraction (frac[sidx] = onehot(sidx) @ frac) — dynamic VMEM gathers
don't vectorize on the TPU, one-hot matmuls do.

Grid: (N blocks x S blocks); the fraction gather accumulates over the
strata dimension into the (N_blk,) gather row, and the final strata step
applies the threshold + weight.  Uniforms are drawn outside the kernel
(jax.random, counter-based) so the kernel stays deterministic per input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Sourced from the shared tiling table (kernels/tiling.py); re-exported
# so existing imports of these constants keep working.
from ..tiling import kernel_blocks

N_BLOCK, S_BLOCK = kernel_blocks("sample_mask")


def _select_kernel(sidx_ref, u_ref, frac_ref, mask_ref, w_ref, acc_ref, *, s_steps: int):
    s_step = pl.program_id(1)
    sidx = sidx_ref[...]
    s_base = s_step * S_BLOCK
    cols = s_base + jax.lax.broadcasted_iota(jnp.int32, (sidx.shape[0], S_BLOCK), 1)
    onehot = (sidx[:, None] == cols).astype(jnp.float32)
    part = jax.lax.dot_general(
        onehot, frac_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (N_blk,) gathered fractions from this strata block

    @pl.when(s_step == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(s_step != 0)
    def _acc():
        acc_ref[...] += part

    @pl.when(s_step == s_steps - 1)
    def _emit():
        f = acc_ref[...]
        keep = u_ref[...] < f
        mask_ref[...] = keep
        w_ref[...] = jnp.where(keep, 1.0 / jnp.maximum(f, 1e-9), 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sample_mask_pallas(
    stratum_idx: jnp.ndarray,
    uniforms: jnp.ndarray,
    fractions: jnp.ndarray,
    interpret: bool = False,
):
    """(sidx (N,), u (N,), f_k (S,)) -> (mask (N,) bool, weight (N,) f32)."""
    n = stratum_idx.shape[0]
    s = fractions.shape[0]
    pad_n = (-n) % N_BLOCK
    pad_s = (-s) % S_BLOCK
    sidx = jnp.pad(stratum_idx.astype(jnp.int32), (0, pad_n), constant_values=-1)
    u = jnp.pad(uniforms.astype(jnp.float32), (0, pad_n), constant_values=2.0)
    frac = jnp.pad(fractions.astype(jnp.float32), (0, pad_s))
    s_steps = frac.shape[0] // S_BLOCK
    grid = (sidx.shape[0] // N_BLOCK, s_steps)
    mask, w = pl.pallas_call(
        functools.partial(_select_kernel, s_steps=s_steps),
        out_shape=(
            jax.ShapeDtypeStruct(sidx.shape, jnp.bool_),
            jax.ShapeDtypeStruct(sidx.shape, jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_BLOCK,), lambda i, s_: (i,)),
            pl.BlockSpec((N_BLOCK,), lambda i, s_: (i,)),
            pl.BlockSpec((S_BLOCK,), lambda i, s_: (s_,)),
        ],
        out_specs=(
            pl.BlockSpec((N_BLOCK,), lambda i, s_: (i,)),
            pl.BlockSpec((N_BLOCK,), lambda i, s_: (i,)),
        ),
        scratch_shapes=[pltpu.VMEM((N_BLOCK,), jnp.float32)],
        interpret=interpret,
    )(sidx, u, frac)
    return mask[:n], w[:n]
