"""Oracle: gather + threshold Bernoulli mask in pure numpy.

Jax-free by contract (edgelint EDG006); all arithmetic is f32 to match the
device path's dtype discipline.
"""

from __future__ import annotations

import numpy as np


def sample_mask_ref(stratum_idx, uniforms, fractions):
    sidx = np.asarray(stratum_idx)
    u = np.asarray(uniforms).astype(np.float32)
    f = np.asarray(fractions).astype(np.float32)[sidx]
    keep = u < f
    w = np.where(keep, np.float32(1.0) / np.maximum(f, np.float32(1e-9)), np.float32(0.0))
    return keep, w
