"""Oracle: gather + threshold (pure jnp)."""

from __future__ import annotations

import jax.numpy as jnp


def sample_mask_ref(stratum_idx, uniforms, fractions):
    f = fractions[stratum_idx]
    keep = uniforms < f
    w = jnp.where(keep, 1.0 / jnp.maximum(f, 1e-9), 0.0)
    return keep, w
