from .ops import sample_mask

__all__ = ["sample_mask"]
