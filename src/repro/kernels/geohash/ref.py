"""Oracle: self-contained numpy Morton geohash encoder.

Jax-free by contract (edgelint EDG006) — this is an independent port of the
device encoder in ``repro.core.geohash``, not a delegation to it, so the
parity test actually compares two implementations.  It must stay BIT-EXACT
with the jnp path: quantization is the same single-multiply form (f32
subtract, f32 precomputed scale, truncating int32 cast, clip) and the bit
spread is the same uint32 mask chain, all of which are IEEE/bitwise
identical between numpy and XLA.
"""

from __future__ import annotations

import numpy as np

LAT_MIN, LAT_MAX = -90.0, 90.0
LON_MIN, LON_MAX = -180.0, 180.0

MAX_PRECISION = 6  # 30 bits; uint32 codes


def _split_bits(precision: int) -> tuple[int, int]:
    """(lon_bits, lat_bits): longitude gets the extra bit at odd width."""
    total = 5 * precision
    return (total + 1) // 2, total // 2


def _part1by1(x: np.ndarray) -> np.ndarray:
    """Spread the low 16 bits of ``x`` to even bit positions (Morton)."""
    x = x.astype(np.uint32) & np.uint32(0x0000FFFF)
    x = (x | (x << np.uint32(8))) & np.uint32(0x00FF00FF)
    x = (x | (x << np.uint32(4))) & np.uint32(0x0F0F0F0F)
    x = (x | (x << np.uint32(2))) & np.uint32(0x33333333)
    x = (x | (x << np.uint32(1))) & np.uint32(0x55555555)
    return x


def encode_ref(lat, lon, precision: int):
    """Encode coordinates to uint32 geohash codes (numpy, vectorized)."""
    if not 1 <= precision <= MAX_PRECISION:
        raise ValueError(f"precision must be in [1, {MAX_PRECISION}], got {precision}")
    lat = np.asarray(lat, dtype=np.float32)
    lon = np.asarray(lon, dtype=np.float32)
    lon_bits, lat_bits = _split_bits(precision)
    lat_scale = np.float32((1 << lat_bits) / (LAT_MAX - LAT_MIN))
    lon_scale = np.float32((1 << lon_bits) / (LON_MAX - LON_MIN))
    lat_i = np.clip(
        ((lat - np.float32(LAT_MIN)) * lat_scale).astype(np.int32), 0, (1 << lat_bits) - 1
    ).astype(np.uint32)
    lon_i = np.clip(
        ((lon - np.float32(LON_MIN)) * lon_scale).astype(np.int32), 0, (1 << lon_bits) - 1
    ).astype(np.uint32)
    if (5 * precision) % 2 == 0:
        # MSB (odd positions) = lon, even positions = lat.
        return (_part1by1(lon_i) << np.uint32(1)) | _part1by1(lat_i)
    # odd width: lon on even positions (incl. MSB), lat on odd.
    return _part1by1(lon_i) | (_part1by1(lat_i) << np.uint32(1))
