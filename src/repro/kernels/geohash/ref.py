"""Oracle: the core library's (pure jnp) geohash encoder."""

from ...core import geohash as _g


def encode_ref(lat, lon, precision: int):
    return _g.encode(lat, lon, precision)
