"""Jit'd public wrapper for the geohash kernel.

Falls back to interpret mode automatically off-TPU so the same call site
works everywhere; neighborhood/stratum lookup stays outside the kernel
(vectorized searchsorted — dynamic VMEM gathers are not TPU-friendly).
"""

from __future__ import annotations

import jax

from .geohash import encode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def geohash_encode(lat, lon, precision: int, block: int = 2048):
    return encode_pallas(lat, lon, precision, block=block, interpret=not _on_tpu())
