from .ops import geohash_encode

__all__ = ["geohash_encode"]
