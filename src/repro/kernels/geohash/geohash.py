"""Pallas kernel: fused lat/lon quantize + Morton interleave.

Pure VPU integer arithmetic, one block of points per grid step.  The
paper's per-tuple geohash string computation (base32, branchy) becomes ~20
vector ops producing the uint32 Morton code directly.

BlockSpec: 1-D blocks of BLOCK points in VMEM (lat, lon in, code out).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.geohash import LAT_MAX, LAT_MIN, LON_MAX, LON_MIN, split_bits

BLOCK = 2048


def _u32(x: int) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def _part1by1(x):
    x = x & _u32(0x0000FFFF)
    x = (x | (x << 8)) & _u32(0x00FF00FF)
    x = (x | (x << 4)) & _u32(0x0F0F0F0F)
    x = (x | (x << 2)) & _u32(0x33333333)
    x = (x | (x << 1)) & _u32(0x55555555)
    return x


def _encode_kernel(lat_ref, lon_ref, out_ref, *, precision: int):
    import numpy as np

    lat = lat_ref[...].astype(jnp.float32)
    lon = lon_ref[...].astype(jnp.float32)
    lon_bits, lat_bits = split_bits(precision)
    # single-multiply quantize, same constants as core.geohash.quantize
    lat_scale = np.float32((1 << lat_bits) / (LAT_MAX - LAT_MIN))
    lon_scale = np.float32((1 << lon_bits) / (LON_MAX - LON_MIN))
    lat_i = jnp.clip(((lat - LAT_MIN) * lat_scale).astype(jnp.int32), 0, (1 << lat_bits) - 1).astype(jnp.uint32)
    lon_i = jnp.clip(((lon - LON_MIN) * lon_scale).astype(jnp.int32), 0, (1 << lon_bits) - 1).astype(jnp.uint32)
    if (5 * precision) % 2 == 0:
        code = (_part1by1(lon_i) << _u32(1)) | _part1by1(lat_i)
    else:
        code = _part1by1(lon_i) | (_part1by1(lat_i) << _u32(1))
    out_ref[...] = code


@functools.partial(jax.jit, static_argnames=("precision", "block", "interpret"))
def encode_pallas(
    lat: jnp.ndarray, lon: jnp.ndarray, precision: int, block: int = BLOCK, interpret: bool = False
) -> jnp.ndarray:
    """lat/lon (N,) f32 -> geohash Morton codes (N,) uint32."""
    n = lat.shape[0]
    pad = (-n) % block
    if pad:
        lat = jnp.pad(lat, (0, pad))
        lon = jnp.pad(lon, (0, pad))
    grid = (lat.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_encode_kernel, precision=precision),
        out_shape=jax.ShapeDtypeStruct(lat.shape, jnp.uint32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=interpret,
    )(lat, lon)
    return out[:n]
