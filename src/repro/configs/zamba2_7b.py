"""zamba2-7b [hybrid]: 81 Mamba-2 layers + a shared attention block applied
every 6 layers, d=3584 32H kv=32 d_ff=14336 ssm_state=64 v=32000
[arXiv:2411.15242].

Simplifications vs the HF checkpoint (documented in DESIGN.md): one shared
attention+MLP block without per-invocation LoRA deltas, and no embedding
concat at shared-block inputs.  For long_500k decode the shared attention
runs a 4096-token ring-buffer window (set by the launcher) so state stays
O(window) — the Mamba backbone carries the long-range channel.
"""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_groups=2,
    ssm_expand=2,
    shared_attn_every=6,
    conv_width=4,
    # GLA chunk: intra-chunk score blocks scale with C^2 x ssm_heads (112);
    # 128 keeps the fwd+bwd transient set inside HBM (§Perf iteration 3).
    chunk_size=128,
)

SMOKE = CONFIG.replace(
    num_layers=7,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_groups=2,
    shared_attn_every=3,
    chunk_size=32,
    remat="none",
)
