"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

import importlib

from ..models.base import ModelConfig
from .shapes import LONG_CONTEXT_FAMILIES, SHAPES, ShapeSpec, supports_cell

ARCH_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "mistral-large-123b": "mistral_large_123b",
    "deepseek-67b": "deepseek_67b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-7b": "zamba2_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "olmoe-1b-7b": "olmoe_1b_7b",
}

ARCH_NAMES = tuple(ARCH_MODULES)


def _module(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f".{ARCH_MODULES[name]}", __package__)


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


__all__ = [
    "ARCH_NAMES",
    "LONG_CONTEXT_FAMILIES",
    "SHAPES",
    "ShapeSpec",
    "get_config",
    "get_smoke_config",
    "supports_cell",
]
