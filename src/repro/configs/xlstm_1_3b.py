"""xlstm-1.3b [ssm-family]: sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H d_ff=0 (blocks carry their own up/down projections)
vocab=50304.  7:1 mLSTM:sLSTM cadence (xLSTM[7:1] from the paper); the
assignment's "GQA kv=4" maps to 4 mLSTM heads (dk = dv = 1024).
"""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    conv_width=4,
    chunk_size=256,
)

SMOKE = CONFIG.replace(
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    vocab_size=512,
    slstm_every=4,
    chunk_size=32,
    remat="none",
)
