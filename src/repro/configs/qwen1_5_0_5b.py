"""qwen1.5-0.5b [dense]: 24L d=1024 16H MHA(kv=16) d_ff=2816 v=151936,
QKV bias, tied embeddings [hf:Qwen/Qwen1.5-0.5B]."""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    remat="none",
)
