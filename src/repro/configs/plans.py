"""Per-(arch x shape) execution plans: the perf knobs used by the launcher.

Defaults were derived from napkin math on v5e (16 GB HBM/chip): the scan
carry saved for backward is B_local*S*d_model*2 bytes per layer, so large-d
archs need sequence-parallel carries and/or microbatching to fit; the perf
log in EXPERIMENTS.md §Perf records the iterations that produced these.
"""

from __future__ import annotations

from ..train.train_loop import StepPlan

_DEFAULT = StepPlan(num_microbatches=1, sequence_parallel=False, remat="full")

# train_4k plans keyed by arch
TRAIN_PLANS: dict[str, StepPlan] = {
    "xlstm-1.3b": StepPlan(num_microbatches=4, sequence_parallel=False, remat="full"),
    "mistral-large-123b": StepPlan(num_microbatches=8, sequence_parallel=True, remat="full"),
    "deepseek-67b": StepPlan(num_microbatches=4, sequence_parallel=True, remat="full"),
    "internlm2-1.8b": StepPlan(num_microbatches=2, sequence_parallel=False, remat="full"),
    "qwen1.5-0.5b": StepPlan(num_microbatches=1, sequence_parallel=False, remat="full"),
    "qwen2-vl-72b": StepPlan(num_microbatches=8, sequence_parallel=True, remat="full"),
    "seamless-m4t-large-v2": StepPlan(num_microbatches=2, sequence_parallel=False, remat="full"),
    "zamba2-7b": StepPlan(num_microbatches=8, sequence_parallel=False, remat="full"),
    "granite-moe-3b-a800m": StepPlan(num_microbatches=8, sequence_parallel=False, remat="full"),
    "olmoe-1b-7b": StepPlan(num_microbatches=4, sequence_parallel=False, remat="full"),
}

# serving plans (prefill/decode): SP toggles carry sharding during prefill
SERVE_PLANS: dict[str, StepPlan] = {
    "mistral-large-123b": StepPlan(sequence_parallel=True, remat="none"),
    "deepseek-67b": StepPlan(sequence_parallel=True, remat="none"),
    "qwen2-vl-72b": StepPlan(sequence_parallel=True, remat="none"),
}


def get_plan(arch: str, kind: str) -> StepPlan:
    if kind == "train":
        return TRAIN_PLANS.get(arch, _DEFAULT)
    return SERVE_PLANS.get(arch, StepPlan(remat="none"))
