"""Assigned input shapes. Each cell = (architecture, shape)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Families able to decode at 500K context (sub-quadratic / O(1) state).
LONG_CONTEXT_FAMILIES = ("ssm", "xlstm", "hybrid")


def supports_cell(family: str, shape: str) -> tuple[bool, str]:
    """(supported, reason-if-not). Documented skips per the assignment."""
    if shape == "long_500k" and family not in LONG_CONTEXT_FAMILIES:
        return False, (
            "long_500k requires sub-quadratic attention; this arch is pure "
            "full-attention (see DESIGN.md §Arch-applicability)"
        )
    return True, ""
