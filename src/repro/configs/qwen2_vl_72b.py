"""qwen2-vl-72b [vlm]: 80L d=8192 64H GQA kv=8 d_ff=29568 v=152064,
M-RoPE (t/h/w rotary sections), dynamic-resolution vision frontend as a
STUB: input_specs feeds precomputed patch embeddings [arXiv:2409.12191]."""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    embeddings_in=True,
    mrope_sections=(16, 24, 24),  # sums to head_dim/2 = 64
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mrope_sections=(4, 2, 2),  # head_dim 16 -> half 8
    remat="none",
)
