"""seamless-m4t-large-v2 [audio]: enc-dec, 24L enc + 24L dec, d=1024 16H
kv=16 d_ff=8192 v=256206 [arXiv:2308.11596].

The speech frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings to the encoder; the decoder is a text decoder
with cross-attention.  The assignment's "24L" is read as 24 encoder + 24
decoder layers (the m4t-large text-to-text stack); see DESIGN.md.
"""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    decoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    embeddings_in=True,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    encoder_layers=2,
    decoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    remat="none",
)
