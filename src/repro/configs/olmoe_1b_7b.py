"""olmoe-1b-7b [moe]: 16L d=2048 16H kv=16 d_ff=1024/expert, 64 experts
top-8, v=50304 [arXiv:2409.02060]."""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    num_experts_per_tok=8,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=32,
    vocab_size=512,
    num_experts=8,
    num_experts_per_tok=2,
    remat="none",
)
