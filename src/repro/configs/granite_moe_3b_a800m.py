"""granite-moe-3b-a800m [moe]: 32L d=1536 24H GQA kv=8 d_ff=512/expert,
40 experts top-8, v=49155 [hf:ibm-granite/granite-3.0 family].

Note: the assignment line reads "MoE 40e top-8 — 32 experts top-8"; we take
the config field (40 experts).  40 doesn't divide the 16-way model axis, so
the rules layer replicates the expert dim and shards the per-expert mlp dim
instead (d_ff=512 -> 32 per shard) — see DESIGN.md §Arch-applicability.
"""

from ..models.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    num_experts_per_tok=8,
)

SMOKE = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=512,
    num_experts=8,
    num_experts_per_tok=2,
    remat="none",
)
