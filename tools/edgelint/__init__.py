"""edgelint: repo-native static analysis for the edge-cloud AQP stack.

The system's headline guarantees are bit-identity guarantees — fused
sessions match independent execution, checkpoint/resume is bit-identical
mid-window, refined members reproduce their own independent draws.  Each
one is an *invariant of the source*, mechanically checkable from the AST,
and each dies silently under an innocent-looking edit.  edgelint is the
executable spec of those invariants:

  EDG001  determinism        — no wall-clock / host randomness in the core
                               closure; randomness flows through threaded
                               jax.random keys
  EDG002  host-sync hygiene  — no silent device->host syncs in jitted /
                               pallas / shard_map functions or pane loops
  EDG003  accumulator        — registered kinds implement the full
          protocol             mergeable Accumulator surface
  EDG004  kernel triad       — ops.py / ref.py exist with matching public
                               signatures; f32 accumulation literals
  EDG005  collective axes    — psum/pmin/pmax axis literals agree with the
                               mesh axes declared in sharding/

Run it::

    python -m tools.edgelint src/ tests/ benchmarks/ [--format=json]

Suppress one finding, with a reason::

    frac = jax.device_get(f)  # edgelint: ignore[EDG002] controller readback

Library entry point: :func:`lint_paths`.
"""

from __future__ import annotations

from pathlib import Path

from . import rules as _rules  # noqa: F401  (importing registers the battery)
from .framework import (
    RULES,
    Finding,
    LintResult,
    Project,
    Rule,
    load_project,
    render_human,
    render_json,
    run_rules,
)

__all__ = [
    "RULES",
    "Finding",
    "LintResult",
    "Project",
    "Rule",
    "lint_paths",
    "load_project",
    "render_human",
    "render_json",
    "run_rules",
]


def lint_paths(paths, root=None, rules=None) -> LintResult:
    """Lint ``paths`` (files/dirs, relative to ``root``; default cwd)."""
    root = Path(root) if root is not None else Path.cwd()
    project = load_project(root, [Path(p) for p in paths])
    return run_rules(project, rules)
