"""edgelint checker framework: project model, rule registry, suppressions.

A :class:`Rule` inspects a :class:`Project` (every scanned module, parsed
once) and yields :class:`Finding`s.  Findings landing on a line carrying a
``# edgelint: ignore[CODE]`` (or ``ignore[CODE1,CODE2]``) comment — on the
offending line itself or on the line of its enclosing statement — are
*suppressed*: recorded, counted, but not fatal.  Suppressions should carry
a trailing reason (``# edgelint: ignore[EDG002] checkpoint save boundary``)
so every escape hatch documents why the invariant may bend there.

Rules are cross-file by design (protocol completeness, kernel triads, and
mesh-axis agreement all need the whole tree), so the framework hands each
rule the full project rather than one module at a time.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Iterator


SUPPRESS_RE = re.compile(
    r"#\s*edgelint:\s*ignore\[(?P<codes>[A-Z0-9_,\s]+)\]\s*(?P<reason>.*)$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    code: str
    message: str
    path: str  # project-root-relative posix path
    line: int
    col: int = 0
    suppressed: bool = False
    suppress_reason: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col + 1}: {self.code}{tag} {self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One parsed ``# edgelint: ignore[...]`` comment."""

    line: int
    codes: frozenset[str]
    reason: str

    def covers(self, code: str) -> bool:
        return code in self.codes or "*" in self.codes


class Module:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: Path, relpath: str, source: str, tree: ast.Module):
        self.path = path
        self.relpath = relpath  # posix, relative to the project root
        self.source = source
        self.tree = tree
        self.suppressions: dict[int, Suppression] = {}
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = SUPPRESS_RE.search(text)
            if m:
                codes = frozenset(
                    c.strip() for c in m.group("codes").split(",") if c.strip()
                )
                self.suppressions[lineno] = Suppression(
                    line=lineno, codes=codes, reason=m.group("reason").strip()
                )
        # map every line spanned by a multi-line statement back to lines
        # carrying a suppression, so the comment can sit on any line of the
        # statement it excuses (in practice: the first or the offending one)
        self._stmt_lines: dict[int, set[int]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.stmt) and hasattr(node, "end_lineno"):
                span = set(range(node.lineno, (node.end_lineno or node.lineno) + 1))
                hit = span & set(self.suppressions)
                for ln in span:
                    if hit:
                        self._stmt_lines.setdefault(ln, set()).update(hit)

    def suppression_for(self, code: str, line: int) -> Suppression | None:
        candidates = {line} | self._stmt_lines.get(line, set())
        for ln in sorted(candidates):
            sup = self.suppressions.get(ln)
            if sup is not None and sup.covers(code):
                return sup
        return None


class Project:
    """Every scanned module, addressable by root-relative posix path."""

    def __init__(self, root: Path, modules: list[Module], errors: list[str]):
        self.root = root
        self.modules = modules
        self.errors = errors  # unparseable files (reported, exit code 2)
        self.by_relpath = {m.relpath: m for m in modules}

    def under(self, *prefixes: str) -> list[Module]:
        """Modules whose root-relative path starts with any prefix."""
        return [
            m
            for m in self.modules
            if any(m.relpath == p or m.relpath.startswith(p.rstrip("/") + "/") for p in prefixes)
        ]


class Rule:
    """One checker: a rule code, the guarantee it protects, and a visitor."""

    code: str = "EDG000"
    name: str = "?"
    guarantee: str = "?"

    def check(self, project: Project) -> Iterator[Finding]:
        raise NotImplementedError


RULES: list[Rule] = []


def register_rule(rule: Rule) -> Rule:
    RULES.append(rule)
    return rule


def load_project(root: Path, paths: Iterable[Path]) -> Project:
    """Parse every ``*.py`` under ``paths`` (files or directories)."""
    root = root.resolve()
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if not p.is_absolute():
            p = root / p
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    modules, errors = [], []
    seen: set[Path] = set()
    for f in files:
        f = f.resolve()
        if f in seen or "__pycache__" in f.parts:
            continue
        seen.add(f)
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            source = f.read_text(encoding="utf-8")
            tree = ast.parse(source, filename=str(f))
        except (SyntaxError, UnicodeDecodeError) as exc:
            errors.append(f"{rel}: {exc}")
            continue
        modules.append(Module(f, rel, source, tree))
    return Project(root, modules, errors)


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]  # active (fatal) findings
    suppressed: list[Finding]  # findings excused by an ignore comment
    errors: list[str]

    @property
    def exit_code(self) -> int:
        if self.errors:
            return 2
        return 1 if self.findings else 0

    def as_dict(self) -> dict:
        counts: dict[str, int] = {}
        for f in self.findings:
            counts[f.code] = counts.get(f.code, 0) + 1
        return {
            "findings": [f.as_dict() for f in self.findings],
            "suppressed": [f.as_dict() for f in self.suppressed],
            "errors": self.errors,
            "counts": counts,
            "n_findings": len(self.findings),
            "n_suppressed": len(self.suppressed),
        }


def run_rules(project: Project, rules: Iterable[Rule] | None = None) -> LintResult:
    active: list[Finding] = []
    suppressed: list[Finding] = []
    for rule in rules if rules is not None else RULES:
        for finding in rule.check(project):
            mod = project.by_relpath.get(finding.path)
            sup = mod.suppression_for(finding.code, finding.line) if mod else None
            if sup is not None:
                suppressed.append(
                    dataclasses.replace(
                        finding, suppressed=True, suppress_reason=sup.reason
                    )
                )
            else:
                active.append(finding)
    active.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    suppressed.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return LintResult(findings=active, suppressed=suppressed, errors=project.errors)


def render_human(result: LintResult) -> str:
    lines = [f.render() for f in result.findings]
    lines += [f.render() for f in result.suppressed]
    lines += [f"edgelint: parse error: {e}" for e in result.errors]
    n_f, n_s = len(result.findings), len(result.suppressed)
    lines.append(f"edgelint: {n_f} finding(s), {n_s} suppressed")
    return "\n".join(lines)


def render_json(result: LintResult) -> str:
    return json.dumps(result.as_dict(), indent=2, sort_keys=True)


# ---------------------------------------------------------------------------
# Shared AST helpers used by several rules
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` attribute/name chains as a dotted string, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def is_constant(node: ast.AST) -> bool:
    """Literal constants (incl. negated numbers and literal tuples)."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        return is_constant(node.operand)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(is_constant(e) for e in node.elts)
    return False


def functions(tree: ast.Module) -> Iterator[ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
