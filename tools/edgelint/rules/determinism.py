"""EDG001 — determinism: the sampling core must be a pure function of its keys.

Every bit-identity guarantee in this system (fused sessions == independent
execution, checkpoint/resume mid-window, nested-HT refinement reproducing a
member's own draw) assumes the edge programs are deterministic in the
threaded ``jax.random`` key.  One ``time.time()`` or ``np.random`` call in
that closure and the guarantees die silently — the property tests would
still pass on their own fixed seeds.

Two scopes:

* **core closure** (``src/repro/core`` + ``src/repro/kernels`` plus every
  in-repo module they transitively import): wall-clock reads, OS entropy,
  and *any* host-side randomness (numpy or stdlib, seeded or not) are
  banned — randomness must flow through ``jax.random`` with an explicitly
  threaded key, and key *construction* from a literal seed inside the
  closure is flagged too (keys belong to the driver).
* **everywhere scanned** (tests, benchmarks, examples, the rest of src):
  only *unseeded / global-state* randomness is flagged — the process-global
  ``np.random.*`` functions, the stdlib ``random`` module, ``os.urandom``,
  ``uuid.uuid1/uuid4``, ``secrets``, and ``np.random.default_rng()``
  without a seed.  ``np.random.default_rng(0)`` is deterministic and fine
  outside the core closure.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import (
    Finding,
    Module,
    Project,
    Rule,
    call_name,
    is_constant,
    register_rule,
)

CORE_ROOTS = ("src/repro/core", "src/repro/kernels")

# wall-clock / entropy reads banned inside the core closure
CLOCK_CALLS = {
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "datetime.now",
    "datetime.utcnow",
    "datetime.today",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.datetime.today",
    "os.urandom",
    "uuid.uuid1",
    "uuid.uuid4",
}

# process-global randomness banned everywhere (deterministic runs can't
# share state with whoever else touched the global generator)
GLOBAL_RNG_PREFIXES = ("np.random.", "numpy.random.", "random.", "secrets.")
GLOBAL_RNG_ALLOWED = {
    "np.random.default_rng",
    "numpy.random.default_rng",
    "np.random.Generator",
    "numpy.random.Generator",
    "np.random.SeedSequence",
    "numpy.random.SeedSequence",
    "random.Random",  # instance-scoped stdlib generator (seedable)
}

# jax.random attributes that are *not* draws (key plumbing / introspection)
JAX_RANDOM_NONDRAWS = {"key", "PRNGKey", "wrap_key_data", "key_data", "key_impl"}


def _import_closure(project: Project) -> set[str]:
    """Root-relative paths of core/kernels modules plus everything under
    ``src/`` they transitively import (resolved textually, best-effort)."""
    src_mods: dict[str, str] = {}  # module dotted path -> relpath
    for mod in project.under("src"):
        rel = mod.relpath
        dotted = rel[len("src/") :].removesuffix(".py").replace("/", ".")
        dotted = dotted.removesuffix(".__init__")
        src_mods[dotted] = rel

    def imports_of(mod: Module) -> set[str]:
        """Dotted in-repo module names this module imports."""
        pkg_parts = mod.relpath[len("src/") :].removesuffix(".py").split("/")
        if pkg_parts[-1] == "__init__":
            pkg_parts = pkg_parts[:-1]
        out: set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                out.update(alias.name for alias in node.names)
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative: resolve against this module's package
                    base = pkg_parts[: len(pkg_parts) - node.level]
                    stem = ".".join(base + ([node.module] if node.module else []))
                else:
                    stem = node.module or ""
                out.add(stem)
                out.update(f"{stem}.{alias.name}" for alias in node.names)
        return {name for name in out if name in src_mods}

    queue = [m for root in CORE_ROOTS for m in project.under(root)]
    closure = {m.relpath for m in queue}
    while queue:
        mod = queue.pop()
        for name in imports_of(mod):
            rel = src_mods[name]
            if rel not in closure:
                closure.add(rel)
                nxt = project.by_relpath.get(rel)
                if nxt is not None:
                    queue.append(nxt)
    return closure


class DeterminismRule(Rule):
    code = "EDG001"
    name = "determinism"
    guarantee = (
        "edge programs are pure functions of their threaded jax.random keys; "
        "no wall-clock, OS-entropy, or host-global randomness in the core closure"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        closure = _import_closure(project)
        for mod in project.modules:
            in_core = mod.relpath in closure
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name is None:
                    continue
                yield from self._check_call(mod, node, name, in_core)

    def _check_call(
        self, mod: Module, node: ast.Call, name: str, in_core: bool
    ) -> Iterator[Finding]:
        def finding(msg: str) -> Finding:
            return Finding(self.code, msg, mod.relpath, node.lineno, node.col_offset)

        if in_core and name in CLOCK_CALLS:
            yield finding(
                f"`{name}()` in the deterministic core closure: edge programs "
                "must be pure functions of their inputs (thread timestamps in "
                "as data if the logic needs them)"
            )
            return
        if name.startswith(GLOBAL_RNG_PREFIXES) and name not in GLOBAL_RNG_ALLOWED:
            if in_core:
                yield finding(
                    f"`{name}()` in the deterministic core closure: randomness "
                    "must flow through jax.random with an explicitly threaded key"
                )
            else:
                yield finding(
                    f"`{name}()` uses process-global random state; use "
                    "`np.random.default_rng(seed)` (or a threaded jax.random key)"
                )
            return
        if name in ("np.random.default_rng", "numpy.random.default_rng"):
            if in_core:
                yield finding(
                    "host-side numpy RNG in the deterministic core closure: "
                    "randomness must flow through jax.random with a threaded key"
                )
            elif not node.args and not node.keywords:
                yield finding(
                    "`default_rng()` without a seed draws OS entropy; pass an "
                    "explicit seed so runs are reproducible"
                )
            return
        if in_core and name.startswith("jax.random."):
            attr = name[len("jax.random.") :]
            if attr in ("key", "PRNGKey") and node.args and is_constant(node.args[0]):
                yield finding(
                    f"`{name}` built from a literal seed inside the core closure: "
                    "keys belong to the driver and must be threaded in as arguments"
                )
            elif (
                attr not in JAX_RANDOM_NONDRAWS
                and node.args
                and is_constant(node.args[0])
            ):
                yield finding(
                    f"`{name}` called with a literal key: the key must be an "
                    "explicitly threaded argument, not a constant"
                )


register_rule(DeterminismRule())
