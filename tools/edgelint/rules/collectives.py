"""EDG005 — collective-axis consistency with the declared mesh axes.

``jax.lax.psum(x, "modle")`` typechecks, jits, and fails only at runtime
inside a mesh — or, worse, a collective over the *wrong* valid axis
produces numerically plausible garbage (a psum over ``"model"`` where the
data axis was meant merges the wrong shards' sufficient stats).  The mesh
axis vocabulary is declared once, in ``sharding/`` (``MESH_AXIS_NAMES``);
every collective axis-name **string literal** anywhere in the tree must be
drawn from it.  Collectives whose axis is a variable (the pipeline threads
``axes`` through shard_map'd programs) are out of scope by design — their
consistency is enforced where the variable is bound.

Also checked: the ``axis_name``/``axis_names`` keyword form, and literal
tuples of axis names (each element must be declared).
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import Finding, Project, Rule, call_name, register_rule

COLLECTIVES = {
    "psum",
    "pmin",
    "pmax",
    "pmean",
    "all_gather",
    "all_to_all",
    "axis_index",
    "ppermute",
    "psum_scatter",
}

DECLARATION = "MESH_AXIS_NAMES"


def declared_axes(project: Project) -> tuple[set[str], str | None]:
    """The axis vocabulary: a ``MESH_AXIS_NAMES`` tuple/set assignment in a
    ``sharding/`` module.  Returns (axes, declaring-relpath)."""
    for mod in project.modules:
        if "sharding/" not in mod.relpath and not mod.relpath.startswith("sharding"):
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign):
                continue
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if DECLARATION not in names:
                continue
            if isinstance(node.value, (ast.Tuple, ast.List, ast.Set)):
                elems = node.value.elts
                if all(
                    isinstance(e, ast.Constant) and isinstance(e.value, str)
                    for e in elems
                ):
                    return {e.value for e in elems}, mod.relpath
    return set(), None


def _axis_literals(node: ast.Call) -> list[tuple[str, ast.AST]]:
    """Axis-name string literals of a collective call (positional arg 1 or
    the axis_name/axis_names keyword; tuples yield each element)."""
    candidates: list[ast.AST] = []
    if len(node.args) >= 2:
        candidates.append(node.args[1])
    candidates.extend(
        kw.value for kw in node.keywords if kw.arg in ("axis_name", "axis_names")
    )
    out: list[tuple[str, ast.AST]] = []
    for c in candidates:
        if isinstance(c, ast.Constant) and isinstance(c.value, str):
            out.append((c.value, c))
        elif isinstance(c, (ast.Tuple, ast.List)):
            out.extend(
                (e.value, e)
                for e in c.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            )
    return out


class CollectiveAxisRule(Rule):
    code = "EDG005"
    name = "collective-axes"
    guarantee = (
        "every psum/pmin/pmax/... axis-name literal is a mesh axis declared "
        "in sharding/ (MESH_AXIS_NAMES) — no typo'd or undeclared axes"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        axes, where = declared_axes(project)
        if not axes:
            # nothing declared: the vocabulary check has no source of truth.
            # Only enforce when the project carries a sharding/ declaration —
            # but if a sharding/ tree exists without one, that is the finding.
            for mod in project.modules:
                if "/sharding/" in f"/{mod.relpath}" and mod.relpath.endswith(
                    "__init__.py"
                ):
                    yield Finding(
                        self.code,
                        f"sharding package declares no {DECLARATION} tuple: the "
                        "collective-axis vocabulary must have one source of truth",
                        mod.relpath,
                        1,
                    )
            return
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name is None or name.rsplit(".", 1)[-1] not in COLLECTIVES:
                    continue
                for axis, site in _axis_literals(node):
                    if axis not in axes:
                        yield Finding(
                            self.code,
                            f"collective over axis {axis!r} which is not a "
                            f"declared mesh axis {sorted(axes)} (see "
                            f"{DECLARATION} in {where}); typo'd axes fail at "
                            "runtime or silently reduce over the wrong shards",
                            mod.relpath,
                            site.lineno,
                            site.col_offset,
                        )


register_rule(CollectiveAxisRule())
