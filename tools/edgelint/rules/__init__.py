"""edgelint rule battery — importing a rule module registers its checker."""

from . import accumulators, collectives, determinism, host_sync, kernel_triad, ref_purity

__all__ = [
    "accumulators",
    "collectives",
    "determinism",
    "host_sync",
    "kernel_triad",
    "ref_purity",
]
