"""EDG006 — ref purity: kernel oracles are jax-free, self-contained numpy.

``kernels/<name>/ref.py`` is the oracle the parity suite diffs the Pallas
kernel against.  An oracle that imports jax shares a compiler — and a bug —
with the thing it is supposed to check: an XLA miscompile, a dtype-promotion
change, or a shared helper rewrite moves both sides in lockstep and the
parity test stays green through a real regression.  An oracle that imports
from elsewhere in the repo (``from ...core import geohash``) is worse: it can
*delegate* to the very device path under test, making parity tautological.

The contract, per ``ref.py`` module:

* no jax import in any form (``import jax``, ``import jax.numpy as jnp``,
  ``from jax...`` — including indirect jax frontends like flax/optax);
* no relative import (``from . import ...``, ``from ...core import ...``)
  and no absolute in-repo import (``repro.*``): refs must be self-contained;
* numpy, ``ml_dtypes`` (for low-precision rounding fidelity — it is a
  plain-numpy dtype package, not a compiler), and the stdlib are the whole
  allowed surface.

The rule is import-level, not call-level: a jax *call* without an import
cannot typecheck anyway, and import-level scanning keeps findings anchored
to the one line a reviewer must delete.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import Finding, Module, Project, Rule, register_rule

BANNED_ROOTS = {"jax", "jaxlib", "flax", "optax", "chex"}
REPO_ROOTS = {"repro", "src"}


def _root(name: str) -> str:
    return name.split(".", 1)[0]


class RefPurityRule(Rule):
    code = "EDG006"
    name = "ref-purity"
    guarantee = (
        "kernels/*/ref.py oracles are jax-free, self-contained numpy — no "
        "jax imports, no relative or in-repo imports"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for mod in project.modules:
            parts = mod.relpath.split("/")
            if parts[-1] != "ref.py" or "kernels" not in parts[:-1]:
                continue
            yield from self._check_module(mod)

    def _check_module(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    yield from self._check_name(mod, node, alias.name)
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # any relative import reaches back into the repo
                    dots = "." * node.level
                    yield Finding(
                        self.code,
                        f"relative import `from {dots}{node.module or ''} "
                        "import ...` in a kernel ref: oracles must be "
                        "self-contained (no in-repo imports — a ref that "
                        "delegates to the tree under test proves nothing)",
                        mod.relpath,
                        node.lineno,
                        node.col_offset,
                    )
                elif node.module and node.module != "__future__":
                    yield from self._check_name(mod, node, node.module)

    def _check_name(self, mod: Module, node: ast.stmt, name: str) -> Iterator[Finding]:
        root = _root(name)
        if root in BANNED_ROOTS:
            yield Finding(
                self.code,
                f"`{name}` import in a kernel ref: oracles must be jax-free "
                "numpy so parity failures implicate exactly one side",
                mod.relpath,
                node.lineno,
                node.col_offset,
            )
        elif root in REPO_ROOTS:
            yield Finding(
                self.code,
                f"in-repo import `{name}` in a kernel ref: oracles must be "
                "self-contained (no repro.* imports)",
                mod.relpath,
                node.lineno,
                node.col_offset,
            )


register_rule(RefPurityRule())
