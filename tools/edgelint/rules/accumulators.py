"""EDG003 — accumulator-protocol completeness for registered kinds.

The query engine reduces windows into registry accumulators and assumes
every registered kind is *fully mergeable*: it must accumulate on the
edge, pairwise-merge, vector-merge across pane rings, cross shards in one
collective, drop its overflow slot, declare its uplink payload, and own
its error-bound logic.  A drop-in kind that implements ``accumulate`` and
``merge`` but not ``merge_panes`` works in tumbling one-pane tests and
silently breaks the first sliding window — exactly the half-implemented
mergeability this rule makes impossible.

Mechanics: every class whose instance (or class object) is passed to a
call of ``register_accumulator`` must provide the full surface —
``accumulate / merge / merge_panes / psum / zero_overflow /
payload_vectors / payload_flatten / payload_unflatten / interval`` —
either in its own body or inherited from an
ancestor *with a real implementation* (a body that is only
``raise NotImplementedError`` does not count; default implementations like
the base ``interval -> None`` do).
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import Finding, Project, Rule, call_name, register_rule

REQUIRED_METHODS = (
    "accumulate",
    "merge",
    "merge_panes",
    "psum",
    "zero_overflow",
    "payload_vectors",
    # wire-format hooks: the uplink codec (core/codec.py) can only skip,
    # quantize, or delta-encode a kind that declares its row view and its
    # exact inverse — a kind without them silently falls off the encoded
    # uplink path the moment a codec is configured
    "payload_flatten",
    "payload_unflatten",
    "interval",
)


def _is_stub(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    """Body is only a docstring + ``raise NotImplementedError`` (or pass)."""
    body = list(fn.body)
    if body and isinstance(body[0], ast.Expr) and isinstance(body[0].value, ast.Constant):
        body = body[1:]  # drop docstring
    if not body:
        return True
    if len(body) != 1:
        return False
    stmt = body[0]
    if isinstance(stmt, ast.Pass):
        return True
    if isinstance(stmt, ast.Raise):
        exc = stmt.exc
        target = exc.func if isinstance(exc, ast.Call) else exc
        return isinstance(target, ast.Name) and target.id == "NotImplementedError"
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
        return stmt.value.value is Ellipsis
    return False


class AccumulatorProtocolRule(Rule):
    code = "EDG003"
    name = "accumulator-protocol"
    guarantee = (
        "every register_accumulator kind implements the full mergeable surface "
        "(accumulate/merge/merge_panes/psum/zero_overflow/payload_vectors/"
        "payload_flatten/payload_unflatten/interval)"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        # class name -> (module relpath, ClassDef), across the whole tree
        classes: dict[str, tuple[str, ast.ClassDef]] = {}
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    classes.setdefault(node.name, (mod.relpath, node))

        def implemented(cls: ast.ClassDef, method: str, seen: set[str]) -> bool:
            if cls.name in seen:
                return False
            seen.add(cls.name)
            for item in cls.body:
                if (
                    isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and item.name == method
                ):
                    return not _is_stub(item)
            for base in cls.bases:
                base_name = base.id if isinstance(base, ast.Name) else None
                if base_name and base_name in classes:
                    if implemented(classes[base_name][1], method, seen):
                        return True
            return False

        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if not (
                    isinstance(node, ast.Call)
                    and (call_name(node) or "").rsplit(".", 1)[-1]
                    == "register_accumulator"
                    and node.args
                ):
                    continue
                arg = node.args[0]
                # register_accumulator(Kind()) or register_accumulator(Kind)
                target = arg.func if isinstance(arg, ast.Call) else arg
                if not isinstance(target, ast.Name) or target.id not in classes:
                    continue
                cls = classes[target.id][1]
                missing = [
                    m for m in REQUIRED_METHODS if not implemented(cls, m, set())
                ]
                if missing:
                    yield Finding(
                        self.code,
                        f"registered accumulator `{target.id}` is missing "
                        f"{', '.join(missing)}: a partial kind half-implements "
                        "mergeability (breaks pane rings / collectives / bounds "
                        "the moment that path runs)",
                        mod.relpath,
                        node.lineno,
                        node.col_offset,
                    )


register_rule(AccumulatorProtocolRule())
