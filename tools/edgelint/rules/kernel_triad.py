"""EDG004 — kernel-triad contract: every kernel ships ops + ref, in sync.

Each ``kernels/<name>/`` package is a triad: the Pallas implementation,
``ops.py`` (the public dispatching wrapper), and ``ref.py`` (the oracle the
parity suite diffs the kernel against).  The whole parity methodology
assumes (a) both halves exist and (b) they take the same inputs — an ops
function that grows a required argument without its ref growing the same
one makes the parity test vacuous or wrong.  And because the MXU contracts
in low precision internally, kernel *accumulation* dtypes must be written
as f32 literals — a ``float16``/``bfloat16`` accumulator literal halves
the mantissa of every merged moment and silently breaks the
bit-identity-with-oracle contract (bf16 belongs on kernel *inputs*, with
f32 accumulation, per the roadmap).

Mechanics, per kernel directory (a directory under ``kernels/`` containing
``__init__.py``):

* ``ops.py`` and ``ref.py`` must both exist;
* every public top-level function ``f`` in ``ops.py`` must have a ref
  counterpart: ``<f>_ref`` by name, else any public ``*_ref`` function
  whose *required* (no-default) parameter names match ``f``'s in order
  (extra defaulted knobs like ``interpret=``/``block=`` are allowed to
  differ — they select implementations, not semantics);
* no ``float16`` / ``bfloat16`` / ``float64`` dtype literal anywhere in
  the kernel package (f32 accumulation is the contract; f64 doesn't exist
  on TPU and diverges the oracle).
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..framework import Finding, Module, Project, Rule, dotted_name, register_rule

BANNED_DTYPES = {
    "jnp.float16",
    "jnp.bfloat16",
    "jnp.float64",
    "np.float16",
    "np.float64",
    "numpy.float16",
    "numpy.float64",
    "jax.numpy.float16",
    "jax.numpy.bfloat16",
    "jax.numpy.float64",
}
BANNED_DTYPE_STRINGS = {"float16", "bfloat16", "float64", "f16", "bf16", "f64"}


def _required_params(fn: ast.FunctionDef) -> tuple[str, ...]:
    args = fn.args
    n_required = len(args.args) - len(args.defaults)
    positional = args.posonlyargs + args.args
    return tuple(a.arg for a in positional[: len(args.posonlyargs) + n_required])


def _public_functions(tree: ast.Module) -> list[ast.FunctionDef]:
    return [
        node
        for node in tree.body
        if isinstance(node, ast.FunctionDef) and not node.name.startswith("_")
    ]


class KernelTriadRule(Rule):
    code = "EDG004"
    name = "kernel-triad"
    guarantee = (
        "every kernels/<name>/ ships ops.py + ref.py with matching public "
        "signatures, and kernel accumulation dtypes are f32 literals"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        # group kernel-package modules by their directory
        dirs: dict[str, dict[str, Module]] = {}
        for mod in project.modules:
            parts = mod.relpath.split("/")
            if "kernels" in parts[:-1]:
                k = parts.index("kernels")
                if len(parts) >= k + 3:  # kernels/<name>/<file>.py
                    dirs.setdefault("/".join(parts[: k + 2]), {})[parts[-1]] = mod

        for dirname, files in sorted(dirs.items()):
            if "__init__.py" not in files:
                continue
            init = files["__init__.py"]
            for required in ("ops.py", "ref.py"):
                if required not in files:
                    yield Finding(
                        self.code,
                        f"kernel package `{dirname}/` has no {required}: the "
                        "ops/ref triad is the parity contract",
                        init.relpath,
                        1,
                    )
            if "ops.py" in files and "ref.py" in files:
                yield from self._check_signatures(files["ops.py"], files["ref.py"])
            for mod in files.values():
                yield from self._check_dtypes(mod)

    def _check_signatures(self, ops: Module, ref: Module) -> Iterator[Finding]:
        ref_fns = {
            fn.name: fn for fn in _public_functions(ref.tree) if fn.name.endswith("_ref")
        }
        for fn in _public_functions(ops.tree):
            want = _required_params(fn)
            match = ref_fns.get(f"{fn.name}_ref")
            if match is None:
                match = next(
                    (r for r in ref_fns.values() if _required_params(r) == want), None
                )
            if match is None:
                yield Finding(
                    self.code,
                    f"ops function `{fn.name}{want}` has no ref counterpart: "
                    f"expected `{fn.name}_ref` (or a `*_ref` with the same "
                    "required params) in ref.py — without it the parity suite "
                    "cannot oracle this kernel",
                    ops.relpath,
                    fn.lineno,
                )
            elif _required_params(match) != want:
                yield Finding(
                    self.code,
                    f"ops `{fn.name}` required params {want} != ref "
                    f"`{match.name}` required params {_required_params(match)}: "
                    "ops and oracle must take the same inputs",
                    ops.relpath,
                    fn.lineno,
                )

    def _check_dtypes(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            name = dotted_name(node) if isinstance(node, ast.Attribute) else None
            if name in BANNED_DTYPES:
                yield Finding(
                    self.code,
                    f"`{name}` dtype literal in a kernel package: accumulation "
                    "dtypes must be f32 literals (jnp.float32)",
                    mod.relpath,
                    node.lineno,
                    node.col_offset,
                )
            elif (
                isinstance(node, ast.Call)
                and any(
                    kw.arg in ("dtype", "preferred_element_type")
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value in BANNED_DTYPE_STRINGS
                    for kw in node.keywords
                )
            ):
                yield Finding(
                    self.code,
                    "non-f32 dtype string in a kernel package: accumulation "
                    "dtypes must be f32 literals",
                    mod.relpath,
                    node.lineno,
                    node.col_offset,
                )


register_rule(KernelTriadRule())
