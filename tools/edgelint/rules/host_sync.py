"""EDG002 — tracer/host-sync hygiene in device contexts and pane loops.

A ``.item()``, ``float()``/``int()``/``bool()``, ``np.asarray``, or
``jax.block_until_ready`` applied to a jnp-derived value is a silent
device→host synchronization: inside a jitted/pallas/shard_map function it
either fails at trace time or (worse) forces a constant-fold; inside the
per-pane host loop it serializes the stream — every pane blocks on the
previous pane's device work, killing async dispatch.

Device contexts are detected structurally:

* functions decorated with ``jit`` / ``pallas_call`` / ``shard_map``
  (including ``partial(jax.jit, ...)`` forms);
* functions passed by name to a jit-wrapping call in the same module
  (``jax.jit(run)``, ``self._compiled(plan, run, ...)``, ``shard_map`` /
  ``compat_shard_map``);
* the repo's pane-loop hot paths (``StreamSession.step/run/_emit``,
  ``EdgeCloudPipeline.run_stream``) plus any function whose ``def`` line
  carries a ``# edgelint: pane-loop`` marker.

``float(...)``/``int(...)``/``bool(...)`` over host-side expressions —
literals, ``getattr(...)`` window attributes, ``len()``, pure-python
``min``/``max``/``sum`` — are exempt; everything else in a device context
is assumed jnp-derived (the conservative default for a hot path).
Intentional sync boundaries (checkpoint saves, controller readback) get an
inline ``# edgelint: ignore[EDG002] <reason>``.
"""

from __future__ import annotations

import ast
import re
from typing import Iterator

from ..framework import (
    Finding,
    Module,
    Project,
    Rule,
    call_name,
    dotted_name,
    is_constant,
    register_rule,
)

# callables that turn a function into (or wrap it for) device execution
JIT_WRAPPERS = {
    "jit",
    "pallas_call",
    "shard_map",
    "compat_shard_map",
    "_shard_map",
    "_compiled",  # EdgeCloudPipeline._compiled: jit or shard_map+jit
}

# repo pane-loop hot paths: the host side of the continuous-query stream
PANE_LOOP_FUNCTIONS = {
    "src/repro/core/session.py": {
        "step",
        "run",
        "_emit",
        "_emit_due",
        "_emit_batch",
        "emit_all",
    },
    "src/repro/core/pipeline.py": {"run_stream"},
    # the async runtime's dispatch path must stay sync-free un-suppressed;
    # its one blocking boundary (_retire) and the deferred event readback
    # (_read_score) are deliberately *not* pane-loop functions
    "src/repro/core/runtime.py": {
        "run",
        "process",
        "_consume",
        "_stage",
        "_dispatch",
        "flush",
        "_pump",
        "offer",
    },
}

PANE_LOOP_MARK = re.compile(r"#\s*edgelint:\s*pane-loop\b")

SYNC_CALLS = {
    "np.asarray",
    "np.array",
    "numpy.asarray",
    "numpy.array",
    "jax.device_get",
    "jax.block_until_ready",
}

CASTS = {"float", "int", "bool"}

# host-side expressions a cast may consume without touching the device
HOST_CALLS = {"getattr", "len", "min", "max", "sum", "abs", "round", "time.time"}


def _base_callable(node: ast.AST) -> str | None:
    """Last dotted component of a call target (``jax.jit`` -> ``jit``)."""
    name = dotted_name(node)
    if name is None:
        return None
    return name.rsplit(".", 1)[-1]


def _decorated_device(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _base_callable(target) in JIT_WRAPPERS:
            return True
        # @partial(jax.jit, ...) and friends
        if isinstance(dec, ast.Call) and _base_callable(dec.func) == "partial":
            if dec.args and _base_callable(dec.args[0]) in JIT_WRAPPERS:
                return True
    return False


def _names_passed_to_wrappers(tree: ast.Module) -> set[str]:
    """Function names handed (directly or via ``partial``) to a jit wrapper."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _base_callable(node.func) in JIT_WRAPPERS):
            continue
        args = list(node.args) + [kw.value for kw in node.keywords]
        for arg in args:
            if isinstance(arg, ast.Name):
                out.add(arg.id)
            elif isinstance(arg, ast.Call) and _base_callable(arg.func) == "partial":
                if arg.args and isinstance(arg.args[0], ast.Name):
                    out.add(arg.args[0].id)
    return out


def _is_host_expr(node: ast.AST) -> bool:
    """Expressions that provably never hold a device value."""
    if is_constant(node):
        return True
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in HOST_CALLS:
            return True
        # pure-python reductions over host containers, e.g. sum(genexpr)
        if name in ("min", "max", "sum"):
            return True
    if isinstance(node, ast.BinOp):
        return _is_host_expr(node.left) and _is_host_expr(node.right)
    if isinstance(node, (ast.GeneratorExp, ast.ListComp)):
        return True
    return False


class HostSyncRule(Rule):
    code = "EDG002"
    name = "host-sync-hygiene"
    guarantee = (
        "no silent device->host syncs inside jitted/pallas/shard_map functions "
        "or the per-pane hot loop; sync boundaries are explicit and justified"
    )

    def check(self, project: Project) -> Iterator[Finding]:
        for mod in project.modules:
            wrapped = _names_passed_to_wrappers(mod.tree)
            pane_names = PANE_LOOP_FUNCTIONS.get(mod.relpath, set())
            lines = mod.source.splitlines()
            # collect device-context functions, then scan their bodies
            # (including nested defs — a closure inside a jitted fn traces)
            contexts = []
            for node in ast.walk(mod.tree):
                if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                marked = node.lineno <= len(lines) and PANE_LOOP_MARK.search(
                    lines[node.lineno - 1]
                )
                if (
                    _decorated_device(node)
                    or node.name in wrapped
                    or node.name in pane_names
                    or marked
                ):
                    contexts.append(node)
            seen: set[int] = set()
            for fn in contexts:
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) and id(node) not in seen:
                        seen.add(id(node))
                        yield from self._check_call(mod, fn, node)

    def _check_call(
        self, mod: Module, fn: ast.AST, node: ast.Call
    ) -> Iterator[Finding]:
        def finding(msg: str) -> Finding:
            return Finding(
                self.code,
                f"{msg} (inside device context/pane loop `{fn.name}`)",
                mod.relpath,
                node.lineno,
                node.col_offset,
            )

        name = call_name(node)
        if isinstance(node.func, ast.Attribute):
            if node.func.attr == "item" and not node.args:
                yield finding("`.item()` forces a device->host sync")
                return
            if node.func.attr == "block_until_ready" and not node.args:
                yield finding("`.block_until_ready()` blocks the dispatch stream")
                return
        if name in SYNC_CALLS:
            yield finding(f"`{name}` materializes device values on the host")
            return
        if name in CASTS and len(node.args) == 1 and not _is_host_expr(node.args[0]):
            yield finding(
                f"`{name}(...)` on a (possibly) jnp-derived value is a silent "
                "host sync; keep the value on device or sync once at the "
                "window/checkpoint boundary"
            )


register_rule(HostSyncRule())
