"""CLI: ``python -m tools.edgelint [--format=human|json] [--root=DIR] paths...``

Exit codes: 0 clean (suppressed findings allowed), 1 active findings,
2 unparseable input or usage error.
"""

from __future__ import annotations

import argparse
import sys

from . import lint_paths, render_human, render_json


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.edgelint",
        description="repo-native static analysis: determinism, tracer "
        "hygiene, and mergeability contracts (EDG001-EDG005)",
    )
    parser.add_argument("paths", nargs="+", help="files or directories to lint")
    parser.add_argument(
        "--format",
        choices=("human", "json"),
        default="human",
        help="output format (default: human)",
    )
    parser.add_argument(
        "--root",
        default=None,
        help="project root for scope-sensitive rules (default: cwd)",
    )
    args = parser.parse_args(argv)
    result = lint_paths(args.paths, root=args.root)
    out = render_json(result) if args.format == "json" else render_human(result)
    print(out)
    return result.exit_code


if __name__ == "__main__":
    sys.exit(main())
