"""CI benchmark regression gate.

Compares measured benchmark JSONs (written by ``python -m
benchmarks.query_bench --json ...`` / ``python -m benchmarks.kernel_bench
--json ...``) against the committed ``benchmarks/baselines.json`` and fails
when a gated metric regresses beyond tolerance.

Only *ratio* metrics are gated (fused-vs-independent speedups): absolute
wall times vary with runner hardware, but a speedup is a same-machine
A/B — if the fused session stops beating N independent executes, a
regression slipped into the fusion path.  Raw wall/byte numbers still land
in the uploaded artifacts for trend eyeballing.

Usage:
    python -m benchmarks.regression BENCH_query.json BENCH_kernel.json \
        [--baseline benchmarks/baselines.json] [--tolerance 0.2]

``baselines.json`` format — per measured-file-basename sections of gated
metrics, plus an optional default tolerance::

    {
      "tolerance": 0.2,
      "BENCH_query.json":  {"fused_speedup_n4": 3.5},
      "BENCH_kernel.json": {"edge_reduce_fused_speedup_c8": 4.0},
      "BENCH_ingest.json": {"runtime_speedup": {"min": 1.3},
                            "p99_pane_latency_ms": {"max": 400}}
    }

Gate forms:

* a bare number is a *tolerance floor*: pass when ``measured >= (1 -
  tolerance) * baseline`` (ratio metrics that drift with runner noise);
* ``{"min": x}`` is an *absolute floor*: ``measured >= x``, no tolerance —
  for contractual minima (the pipelined runtime must beat the synchronous
  loop by >= 1.3x, not "by 1.3x minus slack");
* ``{"max": x}`` is an *absolute ceiling*: ``measured <= x`` — for latency
  metrics where only growth is a regression.

Gated keys missing from a measured file fail loudly (a renamed metric must
be re-baselined, not silently ungated); so does a malformed gate object.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def check(measured_paths, baseline_path, tolerance=None):
    """Returns (failures, report_lines); failures is a list of strings."""
    with open(baseline_path) as f:
        baselines = json.load(f)
    tol = tolerance if tolerance is not None else float(baselines.get("tolerance", 0.2))
    failures: list[str] = []
    report: list[str] = []
    for path in measured_paths:
        name = os.path.basename(path)
        gates = baselines.get(name)
        if gates is None:
            report.append(f"{name}: no gates in baseline (artifact only)")
            continue
        with open(path) as f:
            measured = json.load(f)
        repeats = measured.get("repeats")
        if repeats is not None:
            # benches record their repeat count next to the metrics (the
            # gated values are medians of that many re-measurements), so
            # the uploaded artifacts and trend history stay comparable
            # across noise-hardening changes
            report.append(f"{name}: gated metrics are medians of {repeats} repeats")
        for key, base in gates.items():
            got = measured.get(key)
            if got is None:
                failures.append(f"{name}:{key} missing from measured output")
                continue
            got = float(got)
            if isinstance(base, dict):
                kind = sorted(base.keys() & {"min", "max"})
                if len(kind) != 1 or base.keys() - {"min", "max"}:
                    failures.append(
                        f"{name}:{key} malformed gate {base!r}: expected "
                        '{"min": x} or {"max": x}'
                    )
                    continue
                bound = float(base[kind[0]])
                if kind[0] == "min":
                    ok, op, word = got >= bound, ">=", "floor"
                else:
                    ok, op, word = got <= bound, "<=", "ceiling"
                report.append(
                    f"{name}:{key} measured={got:.3f} {word}={bound:.3f} "
                    f"(absolute) {'OK' if ok else 'REGRESSED'}"
                )
                if not ok:
                    failures.append(
                        f"{name}:{key} regressed: {got:.3f} violates "
                        f"absolute {word} {op} {bound:.3f}"
                    )
                continue
            floor = (1.0 - tol) * float(base)
            ok = got >= floor
            report.append(
                f"{name}:{key} measured={got:.3f} baseline={float(base):.3f} "
                f"floor={floor:.3f} {'OK' if ok else 'REGRESSED'}"
            )
            if not ok:
                failures.append(
                    f"{name}:{key} regressed: {got:.3f} < {floor:.3f} "
                    f"(= (1-{tol})·{float(base):.3f})"
                )
    return failures, report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("measured", nargs="+", help="measured BENCH_*.json files")
    ap.add_argument("--baseline", default="benchmarks/baselines.json")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="override the baseline file's tolerance")
    args = ap.parse_args()
    failures, report = check(args.measured, args.baseline, args.tolerance)
    for line in report:
        print(line)
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        raise SystemExit(1)
    print("benchmark regression gate: PASS")


if __name__ == "__main__":
    main()
