"""Paper Fig 9-11: EdgeSOS sampling latency vs window size.

Claims validated: near-linear scaling with window size; latency nearly
independent of the sampling fraction (cost dominated by grouping, not by
kept volume).  TPU analogue of the rayon-parallel result: the device sort
and segment ops are window-size driven.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import make_table, sampling, SHENZHEN_BBOX

from .common import csv_line, time_call


def run(sizes=(1_000, 10_000, 50_000, 100_000), precision: int = 6):
    table = make_table(*SHENZHEN_BBOX, precision=precision)
    rng = np.random.default_rng(0)
    lines = []

    @jax.jit
    def sample(key, sidx, frac):
        return sampling.edgesos(key, sidx, table.num_slots, frac, method="srs").mask

    @jax.jit
    def sample_bern(key, sidx, frac):
        return sampling.edgesos(key, sidx, table.num_slots, frac, method="bernoulli").mask

    key = jax.random.key(0)
    base_frac = None
    for n in sizes:
        lat = jnp.asarray(rng.uniform(22.45, 22.86, n), jnp.float32)
        lon = jnp.asarray(rng.uniform(113.76, 114.64, n), jnp.float32)
        sidx = table.assign(lat, lon)
        us20 = time_call(sample, key, sidx, jnp.float32(0.2))
        us80 = time_call(sample, key, sidx, jnp.float32(0.8))
        usb = time_call(sample_bern, key, sidx, jnp.float32(0.8))
        ratio = us80 / max(us20, 1e-9)
        if n == sizes[0]:
            base_frac = ratio
        lines.append(csv_line(f"edgesos_srs_n{n}_f80", us80,
                              f"f20_us={us20:.1f};f80_over_f20={ratio:.3f};bernoulli_us={usb:.1f}"))
    lines.append(csv_line("edgesos_fraction_independence", 0.0,
                          f"latency_ratio_f80_vs_f20~1.0_observed={base_frac:.3f}"))
    return lines
