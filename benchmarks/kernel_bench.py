"""Kernel microbenchmarks: Pallas (interpret off-TPU) vs jnp oracle.

Off-TPU the interpret-mode timing is not meaningful as TPU perf; the bench
records correctness deltas + oracle timing so regressions are visible, and
runs the real kernels when a TPU backend is present.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.geohash import geohash_encode
from repro.kernels.geohash.ref import encode_ref
from repro.kernels.sample_mask import sample_mask
from repro.kernels.sample_mask.ref import sample_mask_ref
from repro.kernels.stratified_stats import stratified_stats
from repro.kernels.stratified_stats.ref import stratified_stats_ref

from .common import csv_line, time_call


def run():
    rng = np.random.default_rng(0)
    lines = []
    n = 50_000
    lat = jnp.asarray(rng.uniform(-89, 89, n), jnp.float32)
    lon = jnp.asarray(rng.uniform(-179, 179, n), jnp.float32)
    ref_us = time_call(lambda a, b: encode_ref(a, b, 6), lat, lon)
    got = geohash_encode(lat, lon, 6)
    exact = bool(jnp.all(got == encode_ref(lat, lon, 6)))
    lines.append(csv_line("kernel_geohash_ref", ref_us, f"n={n};kernel_exact={exact}"))

    sidx = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    vals = jnp.asarray(rng.normal(10, 3, n), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.8)
    ref_us = time_call(lambda s, v, m: stratified_stats_ref(s, v, m, 1000), sidx, vals, mask)
    g = stratified_stats(sidx, vals, mask, 1000)
    r = stratified_stats_ref(sidx, vals, mask, 1000)
    ok = all(bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r))
    lines.append(csv_line("kernel_stratified_stats_ref", ref_us, f"n={n};allclose={ok}"))

    frac = jnp.asarray(rng.uniform(0.1, 1.0, 1000), jnp.float32)
    u = jnp.asarray(rng.random(n), jnp.float32)
    ref_us = time_call(sample_mask_ref, sidx, u, frac)
    gm, gw = sample_mask(sidx, u, frac)
    rm, rw = sample_mask_ref(sidx, u, frac)
    ok = bool(jnp.all(gm == rm)) and bool(jnp.allclose(gw, rw, rtol=1e-5))
    lines.append(csv_line("kernel_sample_mask_ref", ref_us, f"n={n};match={ok}"))

    B, S, H, K, dh = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), jnp.bfloat16)
    ref_us = time_call(flash_attention_ref, q, k, v)
    o = flash_attention(q, k, v)
    r = flash_attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    lines.append(csv_line("kernel_flash_attention_ref", ref_us,
                          f"S={S};H={H};K={K};max_err={err:.4f};backend={jax.default_backend()}"))
    return lines
