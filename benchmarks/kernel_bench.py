"""Kernel microbenchmarks: Pallas (interpret off-TPU) vs jnp oracle.

Off-TPU the interpret-mode timing is not meaningful as TPU perf; the bench
records correctness deltas + oracle timing so regressions are visible, and
runs the real kernels when a TPU backend is present.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.stratify import make_table
from repro.kernels.edge_megakernel import edge_megakernel
from repro.kernels.edge_megakernel.ref import edge_megakernel_ref
from repro.kernels.edge_reduce import edge_reduce
from repro.kernels.edge_reduce.ops import edge_reduce_percol
from repro.kernels.edge_reduce.ref import edge_reduce_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.geohash import geohash_encode
from repro.kernels.geohash.ref import encode_ref
from repro.kernels.sample_mask import sample_mask
from repro.kernels.sample_mask.ref import sample_mask_ref
from repro.kernels.stratified_stats import stratified_stats
from repro.kernels.stratified_stats.ref import stratified_stats_ref

from .common import REPEATS, csv_line, median_of_k, time_call


def run():
    rng = np.random.default_rng(0)
    lines = []
    n = 50_000
    lat = jnp.asarray(rng.uniform(-89, 89, n), jnp.float32)
    lon = jnp.asarray(rng.uniform(-179, 179, n), jnp.float32)
    ref_us = time_call(lambda a, b: encode_ref(a, b, 6), lat, lon)
    got = geohash_encode(lat, lon, 6)
    exact = bool(jnp.all(got == encode_ref(lat, lon, 6)))
    lines.append(csv_line("kernel_geohash_ref", ref_us, f"n={n};kernel_exact={exact}"))

    sidx = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    vals = jnp.asarray(rng.normal(10, 3, n), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.8)
    ref_us = time_call(lambda s, v, m: stratified_stats_ref(s, v, m, 1000), sidx, vals, mask)
    g = stratified_stats(sidx, vals, mask, 1000)
    r = stratified_stats_ref(sidx, vals, mask, 1000)
    ok = all(bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r))
    lines.append(csv_line("kernel_stratified_stats_ref", ref_us, f"n={n};allclose={ok}"))

    frac = jnp.asarray(rng.uniform(0.1, 1.0, 1000), jnp.float32)
    u = jnp.asarray(rng.random(n), jnp.float32)
    ref_us = time_call(sample_mask_ref, sidx, u, frac)
    gm, gw = sample_mask(sidx, u, frac)
    rm, rw = sample_mask_ref(sidx, u, frac)
    ok = bool(jnp.all(gm == rm)) and bool(jnp.allclose(gw, rw, rtol=1e-5))
    lines.append(csv_line("kernel_sample_mask_ref", ref_us, f"n={n};match={ok}"))

    # fused multi-column edge reduce: one pass for a whole fusion group's
    # moment rows vs the per-column segment baseline (3·C reductions)
    for c in (4, 8):
        cols = jnp.asarray(rng.normal(10, 3, (c, n)), jnp.float32)
        fused = jax.jit(lambda s, v, m: edge_reduce(s, v, m, 1000))
        percol = jax.jit(lambda s, v, m: edge_reduce_percol(s, v, m, 1000))
        fused_us = time_call(fused, sidx, cols, mask)
        percol_us = time_call(percol, sidx, cols, mask)
        g = edge_reduce(sidx, cols, mask, 1000)
        r = edge_reduce_ref(sidx, cols, mask, 1000)
        ok = all(bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r))
        lines.append(csv_line(
            f"kernel_edge_reduce_fused_c{c}", fused_us,
            f"n={n};strata=1000;cols={c};allclose={ok};backend={jax.default_backend()}"))
        lines.append(csv_line(
            f"kernel_edge_reduce_percol_c{c}", percol_us,
            f"n={n};strata=1000;cols={c};fused_speedup={percol_us / max(fused_us, 1e-9):.2f}x"))

    mk = megakernel_metrics(n=n)
    lines.append(csv_line(
        "kernel_edge_megakernel", mk["megakernel_us"],
        f"n={n};chain_us={mk['megakernel_chain_us']:.1f};"
        f"speedup={mk['megakernel_speedup']:.2f}x;"
        f"traversal_ratio={mk['megakernel_traversal_ratio']:.2f}x;"
        f"parity={mk['megakernel_parity']};backend={jax.default_backend()}"))

    B, S, H, K, dh = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), jnp.bfloat16)
    ref_us = time_call(flash_attention_ref, q, k, v)
    o = flash_attention(q, k, v)
    r = flash_attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    lines.append(csv_line("kernel_flash_attention_ref", ref_us,
                          f"S={S};H={H};K={K};max_err={err:.4f};backend={jax.default_backend()}"))
    return lines


def _megakernel_bytes_model(c: int, e: int, k: int, staging_bytes: int = 4):
    """Analytic HBM bytes-touched per tuple: chained stages vs megakernel.

    The model counts only (N,)-sized reads/writes — per-slot outputs are
    O(S) and negligible at bench shapes.  f32/int32 = 4 B, bool mask = 1 B.
    Each *chain stage* is a separate dispatch, so its inputs re-read and
    its per-tuple products (``sidx``, ``mask``) round-trip through HBM:

      assign   r(lat, lon) + w(sidx)            = 12
      sample   r(sidx, u, ok) + w(mask)         = 10
      moments  r(sidx, mask) + r(4·C cols)      = 5 + 4C
      extrema  r(sidx, mask) + r(4·E cols)      = 5 + 4E   (if E)
      sketch   r(sidx, mask) + r(4·K cols)      = 5 + 4K   (if K)

    The megakernel reads each input exactly once and materializes nothing
    per-tuple: r(lat, lon, u, ok) + staging_bytes·C = 13 + b·C (b = 4 for
    f32 staging, 2 for bf16).  Returns (chain_bytes, fused_bytes) per
    tuple; their ratio is the ``megakernel_traversal_ratio`` gate —
    machine-independent by construction.
    """
    chain = 12 + 10 + (5 + 4 * c)
    if e:
        chain += 5 + 4 * e
    if k:
        chain += 5 + 4 * k
    fused = 4 + 4 + 4 + 1 + staging_bytes * c
    return chain, fused


def megakernel_metrics(n: int = 20_000, precision: int = 5, c: int = 4) -> dict:
    """Single-traversal megakernel vs the separately-dispatched kernel
    chain (assign -> sample -> per-column moments -> extrema -> sketch) on
    one Bernoulli pane: wall-time speedup, parity, and the analytic
    bytes-touched advantage.  Off-TPU both sides run their portable
    lowerings, so the speedup is a same-machine A/B of one fused dispatch
    vs five chained ones over identical math."""
    rng = np.random.default_rng(0)
    table = make_table((0.0, 1.0), (0.0, 1.0), precision=precision)  # 529 cells at p=5
    slots = table.num_slots
    ext_idx, sk_idx = (0,), (1,)
    lat = jnp.asarray(rng.uniform(-0.05, 1.05, n), jnp.float32)  # ~9% overflow
    lon = jnp.asarray(rng.uniform(-0.05, 1.05, n), jnp.float32)
    u = jnp.asarray(rng.random(n), jnp.float32)
    ok = jnp.asarray(rng.random(n) < 0.9)
    cols = jnp.asarray(rng.normal(10, 3, (c, n)), jnp.float32)
    thr = jnp.full((1, slots), 0.5, jnp.float32)

    # -- the chain: five independently jitted stages, per-tuple
    # intermediates (sidx, mask) crossing HBM between dispatches
    stage_assign = jax.jit(lambda la, lo: table.assign(la, lo))
    stage_sample = jax.jit(lambda s, uu, o: o & (uu < 0.5))
    stage_moments = jax.jit(lambda s, v, m: edge_reduce_percol(s, v, m, slots))
    stage_extrema = jax.jit(
        lambda s, v, m: tuple(
            (jax.ops.segment_min(jnp.where(m, v[e], jnp.inf), s, num_segments=slots),
             jax.ops.segment_max(jnp.where(m, v[e], -jnp.inf), s, num_segments=slots))
            for e in ext_idx
        )
    )

    def _sketch(s, v, m):
        from repro.core.estimators import SKETCH_NUM_BINS, sketch_bin_index

        out = []
        for kk in sk_idx:
            flat = s * SKETCH_NUM_BINS + sketch_bin_index(v[kk])
            out.append(
                jax.ops.segment_sum(
                    m.astype(jnp.float32), flat, num_segments=slots * SKETCH_NUM_BINS
                ).reshape(slots, SKETCH_NUM_BINS)
            )
        return tuple(out)

    stage_sketch = jax.jit(_sketch)

    def chain(la, lo, uu, o, v):
        s = stage_assign(la, lo)
        m = stage_sample(s, uu, o)
        return (
            stage_moments(s, v, m),
            stage_extrema(s, v, m),
            stage_sketch(s, v, m),
        )

    def mega(la, lo, uu, o, v):
        return edge_megakernel(
            v, o.astype(jnp.float32)[None], uu[None], thr, slots,
            lat=la, lon=lo, codes=table.codes, precision=table.precision,
            ext_idx=ext_idx, sk_idx=sk_idx,
        )

    # gated speedup: median of REPEATS paired (chain, mega) re-measurements
    chain_walls: list[float] = []
    mega_walls: list[float] = []

    def paired_speedup() -> float:
        cw = time_call(chain, lat, lon, u, ok, cols)
        mw = time_call(mega, lat, lon, u, ok, cols)
        chain_walls.append(cw)
        mega_walls.append(mw)
        return cw / max(mw, 1e-9)

    speedup = median_of_k(paired_speedup, REPEATS)
    chain_us = float(np.median(chain_walls))
    mega_us = float(np.median(mega_walls))
    mega_bf16_us = time_call(mega, lat, lon, u, ok, cols.astype(jnp.bfloat16))

    # parity over real strata (the chain's overflow slot collects tuples
    # the latlon-mode kernel deliberately drops; its stat rows stay zero
    # and the pipeline reconstructs overflow *counts* as residuals)
    s_real = table.num_strata
    res = mega(lat, lon, u, ok, cols)
    (cnt, s1, s2), ext, sk = chain(lat, lon, u, ok, cols)
    parity = (
        bool(jnp.allclose(res.keep[0][:s_real], cnt[:s_real]))
        and all(
            bool(jnp.allclose(a[0][:, :s_real], b[:, :s_real], rtol=1e-5, atol=1e-2))
            for a, b in zip((res.s1, res.s2), (s1, s2))
        )
        and bool(jnp.allclose(res.mins[0, 0][:s_real], ext[0][0][:s_real]))
        and bool(jnp.allclose(res.maxs[0, 0][:s_real], ext[0][1][:s_real]))
        and bool(jnp.allclose(res.bins[0, 0][:s_real], sk[0][:s_real]))
    )

    chain_b, fused_b = _megakernel_bytes_model(c, len(ext_idx), len(sk_idx))
    _, fused_b16 = _megakernel_bytes_model(c, len(ext_idx), len(sk_idx), staging_bytes=2)
    return {
        "megakernel_us": mega_us,
        "megakernel_bf16_us": mega_bf16_us,
        "megakernel_chain_us": chain_us,
        "megakernel_speedup": speedup,
        "megakernel_chain_bytes_per_tuple": chain_b,
        "megakernel_fused_bytes_per_tuple": fused_b,
        "megakernel_traversal_ratio": chain_b / fused_b,
        "megakernel_traversal_ratio_bf16": chain_b / fused_b16,
        "megakernel_parity": parity,
    }


def small_metrics(n: int = 20_000, strata: int = 500) -> dict:
    """Fixed small-configuration kernel metrics for CI regression tracking:
    fused multi-column edge-reduce vs the per-column segment baseline
    (wall us + speedup at 4 and 8 columns, with parity checks)."""
    rng = np.random.default_rng(0)
    sidx = jnp.asarray(rng.integers(0, strata, n), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.8)
    out: dict = {
        "config": {"n": n, "strata": strata, "backend": jax.default_backend()},
        "repeats": REPEATS,
    }
    for c in (4, 8):
        cols = jnp.asarray(rng.normal(10, 3, (c, n)), jnp.float32)
        fused = jax.jit(lambda s, v, m: edge_reduce(s, v, m, strata))
        percol = jax.jit(lambda s, v, m: edge_reduce_percol(s, v, m, strata))
        fused_walls: list[float] = []
        percol_walls: list[float] = []

        def paired_speedup() -> float:
            f = time_call(fused, sidx, cols, mask)
            p = time_call(percol, sidx, cols, mask)
            fused_walls.append(f)
            percol_walls.append(p)
            return p / max(f, 1e-9)

        speedup = median_of_k(paired_speedup, REPEATS)
        g = edge_reduce(sidx, cols, mask, strata)
        r = edge_reduce_ref(sidx, cols, mask, strata)
        out[f"edge_reduce_fused_c{c}_us"] = float(np.median(fused_walls))
        out[f"edge_reduce_percol_c{c}_us"] = float(np.median(percol_walls))
        out[f"edge_reduce_fused_speedup_c{c}"] = speedup
        out[f"edge_reduce_parity_c{c}"] = all(
            bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r)
        )
    out.update(megakernel_metrics(n=n))
    return out


def main() -> None:
    """Standalone entry (CI smoke): ``python -m benchmarks.kernel_bench
    [--dry] [--json PATH]``.

    ``--dry`` runs every kernel once on tiny shapes (interpret-mode parity
    included off-TPU) without the timing loops.  ``--json PATH`` runs the
    fixed small CI configuration and writes the edge-reduce metrics dict
    to PATH (see ``benchmarks.regression`` for the gate).
    """
    import sys

    from .common import json_flag_path, write_metrics_json

    path = json_flag_path(sys.argv[1:])
    if path is not None:
        metrics = small_metrics()
        write_metrics_json(path, metrics, "kernel_bench")
        bad = [
            k for k, v in metrics.items()
            if (k.startswith("edge_reduce_parity") or k == "megakernel_parity")
            and v is False
        ]
        if bad:
            raise SystemExit(f"kernel parity failed in bench config: {bad}")
        return
    print("name,us_per_call,derived")
    if "--dry" in sys.argv[1:]:
        rng = np.random.default_rng(0)
        n, s, c = 300, 20, 3
        sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (c, n)), jnp.float32)
        mask = jnp.asarray(rng.random(n) < 0.5)
        checks = {
            "geohash": bool(jnp.all(
                geohash_encode(vals[0, :64], vals[1, :64], 5)
                == encode_ref(vals[0, :64], vals[1, :64], 5))),
            "stratified_stats": all(bool(jnp.allclose(a, b, rtol=1e-4, atol=1e-2)) for a, b in zip(
                stratified_stats(sidx, vals[0], mask, s),
                stratified_stats_ref(sidx, vals[0], mask, s))),
            # interpret=True forces the Pallas kernel (auto mode would lower
            # to the oracle itself off-TPU, making the check tautological)
            "edge_reduce": all(bool(jnp.allclose(a, b, rtol=1e-4, atol=1e-2)) for a, b in zip(
                edge_reduce(sidx, vals, mask, s, interpret=True),
                edge_reduce_ref(sidx, vals, mask, s))),
            "sample_mask": bool(jnp.all(
                sample_mask(sidx, jnp.abs(vals[1]) % 1.0, jnp.full((s,), 0.5))[0]
                == sample_mask_ref(sidx, jnp.abs(vals[1]) % 1.0, jnp.full((s,), 0.5))[0])),
        }
        # megakernel: interpreted Pallas (latlon mode, in-kernel geohash +
        # threshold sampling + all stat families) vs the numpy oracle
        la = jnp.asarray(rng.uniform(0.0, 1.0, n), jnp.float32)
        lo = jnp.asarray(rng.uniform(0.0, 1.0, n), jnp.float32)
        codes = jnp.asarray(
            np.unique(np.asarray(encode_ref(la, lo, 4)))[::2]  # every other cell -> overflow exercised
        )
        mg_slots = int(codes.shape[0])
        u = jnp.asarray(rng.random(n), jnp.float32)
        okf = jnp.asarray(rng.random(n) < 0.8, jnp.float32)[None]
        thr = jnp.full((1, mg_slots), 0.5, jnp.float32)
        got_mg = edge_megakernel(
            vals, okf, u[None], thr, mg_slots,
            lat=la, lon=lo, codes=codes, precision=4,
            ext_idx=(0,), sk_idx=(1,), interpret=True,
        )
        ref_mg = edge_megakernel_ref(
            np.asarray(vals), np.asarray(okf), np.asarray(u)[None],
            np.asarray(thr), mg_slots,
            lat=np.asarray(la), lon=np.asarray(lo), codes=np.asarray(codes),
            precision=4, ext_idx=(0,), sk_idx=(1,),
        )
        checks["edge_megakernel"] = all(
            bool(jnp.allclose(jnp.asarray(a), jnp.asarray(b), rtol=1e-4, atol=1e-2))
            for a, b in zip(tuple(got_mg), ref_mg)
        )
        bad = [k for k, ok in checks.items() if not ok]
        for k, ok in checks.items():
            print(f"kernel_bench/{k},0,{'DRY-OK' if ok else 'DRY-MISMATCH'}")
        if bad:
            raise SystemExit(f"kernel dry-run parity failed: {bad}")
        return
    for line in run():
        print(line, flush=True)


if __name__ == "__main__":
    main()
