"""Kernel microbenchmarks: Pallas (interpret off-TPU) vs jnp oracle.

Off-TPU the interpret-mode timing is not meaningful as TPU perf; the bench
records correctness deltas + oracle timing so regressions are visible, and
runs the real kernels when a TPU backend is present.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.edge_reduce import edge_reduce
from repro.kernels.edge_reduce.ops import edge_reduce_percol
from repro.kernels.edge_reduce.ref import edge_reduce_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.geohash import geohash_encode
from repro.kernels.geohash.ref import encode_ref
from repro.kernels.sample_mask import sample_mask
from repro.kernels.sample_mask.ref import sample_mask_ref
from repro.kernels.stratified_stats import stratified_stats
from repro.kernels.stratified_stats.ref import stratified_stats_ref

from .common import csv_line, time_call


def run():
    rng = np.random.default_rng(0)
    lines = []
    n = 50_000
    lat = jnp.asarray(rng.uniform(-89, 89, n), jnp.float32)
    lon = jnp.asarray(rng.uniform(-179, 179, n), jnp.float32)
    ref_us = time_call(lambda a, b: encode_ref(a, b, 6), lat, lon)
    got = geohash_encode(lat, lon, 6)
    exact = bool(jnp.all(got == encode_ref(lat, lon, 6)))
    lines.append(csv_line("kernel_geohash_ref", ref_us, f"n={n};kernel_exact={exact}"))

    sidx = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    vals = jnp.asarray(rng.normal(10, 3, n), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.8)
    ref_us = time_call(lambda s, v, m: stratified_stats_ref(s, v, m, 1000), sidx, vals, mask)
    g = stratified_stats(sidx, vals, mask, 1000)
    r = stratified_stats_ref(sidx, vals, mask, 1000)
    ok = all(bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r))
    lines.append(csv_line("kernel_stratified_stats_ref", ref_us, f"n={n};allclose={ok}"))

    frac = jnp.asarray(rng.uniform(0.1, 1.0, 1000), jnp.float32)
    u = jnp.asarray(rng.random(n), jnp.float32)
    ref_us = time_call(sample_mask_ref, sidx, u, frac)
    gm, gw = sample_mask(sidx, u, frac)
    rm, rw = sample_mask_ref(sidx, u, frac)
    ok = bool(jnp.all(gm == rm)) and bool(jnp.allclose(gw, rw, rtol=1e-5))
    lines.append(csv_line("kernel_sample_mask_ref", ref_us, f"n={n};match={ok}"))

    # fused multi-column edge reduce: one pass for a whole fusion group's
    # moment rows vs the per-column segment baseline (3·C reductions)
    for c in (4, 8):
        cols = jnp.asarray(rng.normal(10, 3, (c, n)), jnp.float32)
        fused = jax.jit(lambda s, v, m: edge_reduce(s, v, m, 1000))
        percol = jax.jit(lambda s, v, m: edge_reduce_percol(s, v, m, 1000))
        fused_us = time_call(fused, sidx, cols, mask)
        percol_us = time_call(percol, sidx, cols, mask)
        g = edge_reduce(sidx, cols, mask, 1000)
        r = edge_reduce_ref(sidx, cols, mask, 1000)
        ok = all(bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r))
        lines.append(csv_line(
            f"kernel_edge_reduce_fused_c{c}", fused_us,
            f"n={n};strata=1000;cols={c};allclose={ok};backend={jax.default_backend()}"))
        lines.append(csv_line(
            f"kernel_edge_reduce_percol_c{c}", percol_us,
            f"n={n};strata=1000;cols={c};fused_speedup={percol_us / max(fused_us, 1e-9):.2f}x"))

    B, S, H, K, dh = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), jnp.bfloat16)
    ref_us = time_call(flash_attention_ref, q, k, v)
    o = flash_attention(q, k, v)
    r = flash_attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    lines.append(csv_line("kernel_flash_attention_ref", ref_us,
                          f"S={S};H={H};K={K};max_err={err:.4f};backend={jax.default_backend()}"))
    return lines


def small_metrics(n: int = 20_000, strata: int = 500) -> dict:
    """Fixed small-configuration kernel metrics for CI regression tracking:
    fused multi-column edge-reduce vs the per-column segment baseline
    (wall us + speedup at 4 and 8 columns, with parity checks)."""
    rng = np.random.default_rng(0)
    sidx = jnp.asarray(rng.integers(0, strata, n), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.8)
    out: dict = {"config": {"n": n, "strata": strata, "backend": jax.default_backend()}}
    for c in (4, 8):
        cols = jnp.asarray(rng.normal(10, 3, (c, n)), jnp.float32)
        fused = jax.jit(lambda s, v, m: edge_reduce(s, v, m, strata))
        percol = jax.jit(lambda s, v, m: edge_reduce_percol(s, v, m, strata))
        fused_us = time_call(fused, sidx, cols, mask)
        percol_us = time_call(percol, sidx, cols, mask)
        g = edge_reduce(sidx, cols, mask, strata)
        r = edge_reduce_ref(sidx, cols, mask, strata)
        out[f"edge_reduce_fused_c{c}_us"] = fused_us
        out[f"edge_reduce_percol_c{c}_us"] = percol_us
        out[f"edge_reduce_fused_speedup_c{c}"] = percol_us / max(fused_us, 1e-9)
        out[f"edge_reduce_parity_c{c}"] = all(
            bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-2)) for a, b in zip(g, r)
        )
    return out


def main() -> None:
    """Standalone entry (CI smoke): ``python -m benchmarks.kernel_bench
    [--dry] [--json PATH]``.

    ``--dry`` runs every kernel once on tiny shapes (interpret-mode parity
    included off-TPU) without the timing loops.  ``--json PATH`` runs the
    fixed small CI configuration and writes the edge-reduce metrics dict
    to PATH (see ``benchmarks.regression`` for the gate).
    """
    import sys

    from .common import json_flag_path, write_metrics_json

    path = json_flag_path(sys.argv[1:])
    if path is not None:
        metrics = small_metrics()
        write_metrics_json(path, metrics, "kernel_bench")
        bad = [
            k for k, v in metrics.items()
            if k.startswith("edge_reduce_parity") and v is False
        ]
        if bad:
            raise SystemExit(f"kernel parity failed in bench config: {bad}")
        return
    print("name,us_per_call,derived")
    if "--dry" in sys.argv[1:]:
        rng = np.random.default_rng(0)
        n, s, c = 300, 20, 3
        sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (c, n)), jnp.float32)
        mask = jnp.asarray(rng.random(n) < 0.5)
        checks = {
            "geohash": bool(jnp.all(
                geohash_encode(vals[0, :64], vals[1, :64], 5)
                == encode_ref(vals[0, :64], vals[1, :64], 5))),
            "stratified_stats": all(bool(jnp.allclose(a, b, rtol=1e-4, atol=1e-2)) for a, b in zip(
                stratified_stats(sidx, vals[0], mask, s),
                stratified_stats_ref(sidx, vals[0], mask, s))),
            # interpret=True forces the Pallas kernel (auto mode would lower
            # to the oracle itself off-TPU, making the check tautological)
            "edge_reduce": all(bool(jnp.allclose(a, b, rtol=1e-4, atol=1e-2)) for a, b in zip(
                edge_reduce(sidx, vals, mask, s, interpret=True),
                edge_reduce_ref(sidx, vals, mask, s))),
            "sample_mask": bool(jnp.all(
                sample_mask(sidx, jnp.abs(vals[1]) % 1.0, jnp.full((s,), 0.5))[0]
                == sample_mask_ref(sidx, jnp.abs(vals[1]) % 1.0, jnp.full((s,), 0.5))[0])),
        }
        bad = [k for k, ok in checks.items() if not ok]
        for k, ok in checks.items():
            print(f"kernel_bench/{k},0,{'DRY-OK' if ok else 'DRY-MISMATCH'}")
        if bad:
            raise SystemExit(f"kernel dry-run parity failed: {bad}")
        return
    for line in run():
        print(line, flush=True)


if __name__ == "__main__":
    main()
