"""CI benchmark trend history: append each run's metrics to a persisted
JSON series.

The regression gate (``benchmarks/regression.py``) is a *point* check
against committed baselines; this module turns the same measured JSONs
into a *trend*: every CI run on ``main`` appends one entry — commit sha,
run id, wall-clock, and the full metrics dict of each ``BENCH_*.json`` —
to a history file that lives on the ``gh-pages`` branch (see the
``bench`` job in ``.github/workflows/ci.yml``).  The file is plain JSON
(``{"version": 1, "runs": [...]}``, newest last), so a static chart page
or a one-liner ``jq`` can plot any gated ratio over time.

Usage:
    python -m benchmarks.trend BENCH_query.json BENCH_kernel.json \
        --history bench-history.json [--sha SHA] [--run RUN_ID] \
        [--max-runs 2000]

Append is idempotent per (sha, run): re-running the same CI job replaces
its own entry instead of duplicating it.
"""

from __future__ import annotations

import argparse
import json
import os
import time

HISTORY_VERSION = 1
DEFAULT_MAX_RUNS = 2000


def _load_history(path: str) -> dict:
    if not os.path.exists(path):
        return {"version": HISTORY_VERSION, "runs": []}
    with open(path) as f:
        history = json.load(f)
    version = history.get("version")
    if version != HISTORY_VERSION:
        raise SystemExit(
            f"{path}: unsupported trend-history version {version!r} "
            f"(this tool writes version {HISTORY_VERSION})"
        )
    return history


def append(
    measured_paths,
    history_path: str,
    sha: str = "",
    run_id: str = "",
    timestamp: float | None = None,
    max_runs: int = DEFAULT_MAX_RUNS,
) -> dict:
    """Append one run's measured JSONs to the history file; returns the
    updated history dict.  Keeps at most ``max_runs`` newest entries so the
    gh-pages artifact stays bounded."""
    history = _load_history(history_path)
    entry = {
        "sha": sha,
        "run": run_id,
        "timestamp": time.time() if timestamp is None else float(timestamp),
        "metrics": {},
    }
    for path in measured_paths:
        with open(path) as f:
            entry["metrics"][os.path.basename(path)] = json.load(f)
    runs = [r for r in history["runs"] if not (sha and r.get("sha") == sha and r.get("run") == run_id)]
    runs.append(entry)
    history["runs"] = runs[-max_runs:]
    with open(history_path, "w") as f:
        json.dump(history, f, indent=1, sort_keys=True)
    return history


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("measured", nargs="+", help="measured BENCH_*.json files")
    ap.add_argument("--history", required=True, help="trend-history JSON to append to")
    ap.add_argument("--sha", default=os.environ.get("GITHUB_SHA", ""))
    ap.add_argument("--run", default=os.environ.get("GITHUB_RUN_ID", ""))
    ap.add_argument("--max-runs", type=int, default=DEFAULT_MAX_RUNS)
    args = ap.parse_args()
    history = append(
        args.measured, args.history, sha=args.sha, run_id=args.run, max_runs=args.max_runs
    )
    print(
        f"{args.history}: {len(history['runs'])} run(s), appended "
        f"{args.sha[:12] or '<local>'} with {sorted(history['runs'][-1]['metrics'])}"
    )


if __name__ == "__main__":
    main()
