"""Paper Fig 19: cloud-side aggregation batch time vs sampling fraction.

The paper observes only an 11-12% runtime delta between 20% and 100%
samples because fixed per-batch overheads dominate the Spark job.  We
measure the jitted cloud aggregation (group-by-stratum + estimators) over
compacted samples of each fraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import estimators, make_table, sampling, SHENZHEN_BBOX
from repro.data.streams import materialize, shenzhen_taxi_stream

from .common import csv_line, time_call


def run(fractions=(0.2, 0.4, 0.6, 0.8, 1.0), num_chunks=8):
    data = materialize(shenzhen_taxi_stream(num_chunks=num_chunks, seed=5))
    table = make_table(*SHENZHEN_BBOX, precision=6)
    lat = jnp.asarray(data["lat"])
    lon = jnp.asarray(data["lon"])
    val = jnp.asarray(data["value"])
    sidx = table.assign(lat, lon)
    n = val.shape[0]

    @jax.jit
    def cloud_agg(v, s, m, counts):
        stats = estimators.sample_stats(v, s, m, table.num_slots, counts=counts)
        return estimators.estimate(stats)

    lines = []
    times = {}
    for f in fractions:
        res = sampling.edgesos(jax.random.key(1), sidx, table.num_slots, f)
        cap = int(n * f) + 1024
        valid, s_c, v_c = sampling.compact(res.mask, cap, sidx, val)
        us = time_call(cloud_agg, v_c, s_c, valid, res.counts)
        times[f] = us
        est = cloud_agg(v_c, s_c, valid, res.counts)
        lines.append(csv_line(f"cloud_batch_f{int(f*100)}", us,
                              f"mean={float(est.mean):.3f};re={float(est.relative_error):.5f}"))
    delta = 100.0 * (times[1.0] - times[0.2]) / max(times[1.0], 1e-9)
    lines.append(csv_line("cloud_batch_delta_20_vs_100", 0.0,
                          f"time_reduction_pct={delta:.1f};paper~11-12"))
    return lines
