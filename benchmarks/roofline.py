"""Roofline reporting: reads the dry-run artifacts and emits the per-cell
three-term table (also consumed to build EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import glob
import json
import os

from .common import csv_line

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(dryrun_dir: str = DRYRUN_DIR):
    lines = []
    recs = load_records(dryrun_dir)
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    errors = [r for r in recs if r.get("status") == "error"]
    for r in ok:
        rf = r["roofline"]
        mem = r["memory"]
        mem_gib = mem.get("peak_tpu_estimate_bytes", mem["peak_estimate_bytes"]) / 2**30
        lines.append(
            csv_line(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                rf["bound_s"] * 1e6,
                f"compute_s={rf['compute_s']:.3e};memory_s={rf['memory_s']:.3e};"
                f"collective_s={rf['collective_s']:.3e};dominant={rf['dominant']};"
                f"roofline_fraction={rf['roofline_fraction']:.4f};"
                f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
                f"mem_gib_per_chip_tpu={mem_gib:.2f}",
            )
        )
    lines.append(csv_line("dryrun_summary", 0.0,
                          f"ok={len(ok)};skipped={len(skipped)};errors={len(errors)}"))
    return lines
