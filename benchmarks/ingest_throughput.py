"""Paper Fig 8 + §5.2: ingestion throughput and pipelined-runtime overlap.

Two benchmarks share this module:

* :func:`run` (CSV, ``python -m benchmarks.run ingest_throughput``) — the
  original Fig 8 sweep: jitted assign+route+count throughput vs batch size,
  showing the fixed-overhead knee (~20K msgs/batch in the paper).

* :func:`small_metrics` (``--json PATH``) — the streaming-runtime A/B the
  CI regression gate consumes: the same paced pane source driven through a
  synchronous ``session.step`` loop (ingest then compute, serially) vs
  :class:`~repro.core.runtime.StreamRuntime` (producer thread + bounded
  queue + double-buffered staging).  With pane arrival time ≈ per-pane
  compute time the pipelined driver should approach 2× the synchronous
  wall; ``runtime_speedup`` is floor-gated (≥ 1.3× after tolerance) and
  ``p99_pane_latency_ms`` is ceiling-gated in ``benchmarks/baselines.json``
  so a host sync sneaking into the pane loop fails CI, not a reviewer.

Both drivers consume identical panes with identical ``fold_in`` key
discipline, so the A/B is also a parity check: ``parity_ok`` in the JSON
asserts the final estimates agree bit-for-bit.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    RuntimeConfig,
    StreamRuntime,
    StreamSession,
    contiguous_plan,
    make_table,
    windows,
)
from repro.data.sources import PacedSource
from repro.data.streams import shenzhen_taxi_stream

from .common import REPEATS, csv_line, time_call


def run(sizes=(2_000, 5_000, 10_000, 20_000, 50_000)):
    table = make_table(*SHENZHEN_BBOX, precision=6, neighborhood_precision=4)
    plan = contiguous_plan(table, num_shards=8)
    rng = np.random.default_rng(0)

    @jax.jit
    def ingest(lat, lon):
        sidx = table.assign(lat, lon)
        dest = plan.route_stratum(sidx)
        counts = jax.ops.segment_sum(
            jnp.ones_like(dest, dtype=jnp.int32), dest, num_segments=plan.num_shards
        )
        return sidx, dest, counts

    lines = []
    best = (0.0, 0)
    for n in sizes:
        lat = jnp.asarray(rng.uniform(22.45, 22.86, n), jnp.float32)
        lon = jnp.asarray(rng.uniform(113.76, 114.64, n), jnp.float32)
        us = time_call(ingest, lat, lon)
        rate = n / (us / 1e6)
        if rate > best[0]:
            best = (rate, n)
        lines.append(csv_line(f"ingest_route_n{n}", us, f"msgs_per_s={rate:.0f}"))
    lines.append(csv_line("ingest_best_batch", 0.0, f"best_batch={best[1]};rate={best[0]:.0f}"))
    return lines


# ---------------------------------------------------------------------------
# Streaming-runtime A/B (CI ``--json`` mode)
# ---------------------------------------------------------------------------


def _query_set():
    return [
        Query(aggs=(AggSpec("mean", "value"), AggSpec("var", "value"))),
        Query(aggs=(AggSpec("mean", "occupancy", name="occ"),)),
    ]


def _fresh_session(pipe, fraction):
    sess = StreamSession(pipe, initial_fraction=fraction)
    for q in _query_set():
        sess.register(q)
    return sess


def _last_estimates(history):
    """Flattened numpy copy of the final step's per-query estimates."""
    out = {}
    for qid, res in history[-1].results.items():
        out[qid] = {k: np.asarray(v) for k, v in res.estimates.items()}
    return out


def small_metrics(
    n_panes: int = 24, pane_tuples: int = 8_000, fraction: float = 0.8,
    backend: str = "segment",
) -> dict:
    """Fixed small-configuration sync-vs-runtime metrics for CI gating.

    ``backend`` selects the edge reduction implementation
    (``segment | pallas | fused`` — see :class:`PipelineConfig`); the
    CI-gated configuration stays on the ``segment`` default, ``--backend
    fused`` A/Bs the single-traversal megakernel path under the same
    paced-pane driver."""
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(
        table, PipelineConfig(raw_capacity=pane_tuples, backend=backend)
    )
    stream = shenzhen_taxi_stream(chunk_size=pane_tuples, num_chunks=n_panes, seed=0)
    panes = list(windows.count_windows(stream, pane_tuples))[:n_panes]
    root = jax.random.key(7)

    # Warm every jit cache through a throwaway session sharing the pipe's
    # compiled-pass cache, so neither timed driver pays compilation.
    warm = _fresh_session(pipe, fraction)

    def warm_step():
        step = warm.step(jax.random.fold_in(root, warm.pane_index), panes[0])
        return [r.estimates for r in step.results.values()]

    step_us = time_call(warm_step)
    # pace arrivals at ~1.5x the per-pane compute time: comfortably inside
    # the regime where the pipelined driver hides the whole arrival delay
    # (runtime wall ~= pacing, sync wall ~= pacing + compute), and bounded
    # so CI stays fast on any machine
    delay_s = min(max(1.5 * step_us / 1e6, 0.004), 0.060)

    def one_trial():
        """One paired sync-vs-runtime A/B over the same panes and keys."""
        # A: synchronous loop — ingest (paced source) then compute, serially
        sess_sync = _fresh_session(pipe, fraction)
        sync_steps = []
        t0 = time.perf_counter()
        for i, pane in enumerate(PacedSource(panes, delay_s)):
            step = sess_sync.step(jax.random.fold_in(root, i), pane)
            jax.block_until_ready([r.estimates for r in step.results.values()])
            sync_steps.append(step)
        sync_wall = time.perf_counter() - t0

        # B: pipelined runtime — producer thread + double-buffered staging.
        # "block" policy: lossless, so the A/B is also a bit-parity check.
        sess_rt = _fresh_session(pipe, fraction)
        rt = StreamRuntime(
            sess_rt, key=root, config=RuntimeConfig(queue_capacity=8, policy="block")
        )
        t0 = time.perf_counter()
        rt.run(PacedSource(panes, delay_s))
        rt_wall = time.perf_counter() - t0

        st = rt.stats()
        a, b = _last_estimates(sync_steps), _last_estimates(rt.history)
        parity_ok = all(
            np.array_equal(a[q][k], b[q][k]) for q in a for k in a[q]
        ) and a.keys() == b.keys()
        return sync_wall, rt_wall, st, parity_ok

    # gated metrics are medians over REPEATS paired trials (a noisy-runner
    # burst skews one trial, not the gate); detail keys come from the last
    trials = [one_trial() for _ in range(REPEATS)]
    sync_wall, rt_wall, st, _ = trials[-1]
    parity_ok = all(t[3] for t in trials)

    return {
        "config": {
            "panes": n_panes,
            "pane_tuples": pane_tuples,
            "fraction": fraction,
            "pacing_ms": delay_s * 1e3,
            "precision": 5,
            "backend": backend,
        },
        "repeats": REPEATS,
        "sync_wall_s": sync_wall,
        "runtime_wall_s": rt_wall,
        "runtime_speedup": float(
            np.median([s / max(r, 1e-9) for s, r, _, _ in trials])
        ),
        "overlap_efficiency": float(
            np.median([t[2].overlap_efficiency for t in trials])
        ),
        "p99_pane_latency_ms": float(
            np.median([t[2].pane_latency["p99_ms"] for t in trials])
        ),
        "p50_pane_latency_ms": st.pane_latency["p50_ms"],
        "queue_depth_high_water": st.queue_depth_high_water,
        "panes_processed": st.panes_processed,
        "tuples_processed": st.tuples_processed,
        "dropped_tuples": st.dropped_tuples,
        "runtime_msgs_per_s": st.tuples_processed / max(rt_wall, 1e-9),
        "parity_ok": bool(parity_ok),
    }


def main() -> None:
    """Standalone entry: ``python -m benchmarks.ingest_throughput [--json
    PATH] [--backend segment|pallas|fused]``.

    ``--json PATH`` runs the fixed sync-vs-runtime configuration and writes
    the gated metrics to PATH; without it the Fig 8 CSV sweep streams to
    stdout.  ``--backend`` selects the pipeline's edge reduction backend
    for the JSON configuration (default ``segment``, the gated baseline;
    ``fused`` drives every pane through the single-traversal megakernel).
    """
    import sys

    from repro.core.pipeline import BACKENDS

    from .common import json_flag_path, write_metrics_json

    argv = sys.argv[1:]
    backend = "segment"
    if "--backend" in argv:
        i = argv.index("--backend") + 1
        if i >= len(argv) or argv[i] not in BACKENDS:
            raise SystemExit(f"usage: --backend {{{'|'.join(BACKENDS)}}}")
        backend = argv[i]
    path = json_flag_path(argv)
    if path is not None:
        metrics = small_metrics(backend=backend)
        if not metrics["parity_ok"]:
            raise SystemExit("runtime/sync estimate parity failed")
        write_metrics_json(path, metrics, "ingest_throughput")
        return
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)


if __name__ == "__main__":
    main()
