"""Paper Fig 8: ingestion/routing throughput vs window (batch) size.

The paper finds fixed per-batch overheads dominate below ~20K messages and
a knee at ~20K msgs/batch (~200K msg/s ceiling with kafka-rust).  Here the
"ingest" is the jitted assign+route+count step; the same fixed-overhead
knee appears as dispatch overhead amortization.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import contiguous_plan, make_table, routing, SHENZHEN_BBOX

from .common import csv_line, time_call


def run(sizes=(2_000, 5_000, 10_000, 20_000, 50_000)):
    table = make_table(*SHENZHEN_BBOX, precision=6, neighborhood_precision=4)
    plan = contiguous_plan(table, num_shards=8)
    rng = np.random.default_rng(0)

    @jax.jit
    def ingest(lat, lon):
        sidx = table.assign(lat, lon)
        dest = plan.route_stratum(sidx)
        counts = jax.ops.segment_sum(
            jnp.ones_like(dest, dtype=jnp.int32), dest, num_segments=plan.num_shards
        )
        return sidx, dest, counts

    lines = []
    best = (0.0, 0)
    for n in sizes:
        lat = jnp.asarray(rng.uniform(22.45, 22.86, n), jnp.float32)
        lon = jnp.asarray(rng.uniform(113.76, 114.64, n), jnp.float32)
        us = time_call(ingest, lat, lon)
        rate = n / (us / 1e6)
        if rate > best[0]:
            best = (rate, n)
        lines.append(csv_line(f"ingest_route_n{n}", us, f"msgs_per_s={rate:.0f}"))
    lines.append(csv_line("ingest_best_batch", 0.0, f"best_batch={best[1]};rate={best[0]:.0f}"))
    return lines
