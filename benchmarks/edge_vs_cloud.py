"""Paper Fig 20-21 + Table: EdgeApproxGeo vs cloud-only SpatialSSJP.

SpatialSSJP baseline (implemented here, per the paper's description): all
raw tuples ship to the cloud, which performs geohashing, neighborhood
categorization, stratified sampling and aggregation centrally in one pass.

EdgeApproxGeo: E edge shards independently geohash + EdgeSOS-sample their
local substreams (decentralized, no coordination), ship sampled tuples
(raw mode) or per-stratum moments (pre-agg mode); the cloud only merges
pre-partitioned data.

Reported (Chicago-AQ-like stream, per the paper's §5.4 protocol):
  * per-neighborhood absolute percentage error vs the full-data baseline
    for both systems (paper: no significant difference; edge slightly
    wider tail from windowed sampling);
  * cloud-side work time: centralized assign+sample+aggregate vs
    merge-only (the paper's 15-20% reduction is end-to-end on Azure; we
    report the cloud-compute component measured here);
  * upstream bytes: raw vs sampled vs pre-aggregated.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import CHICAGO_BBOX, estimators, make_table, sampling
from repro.data.streams import chicago_aq_stream, materialize

from .common import csv_line, time_call

TUPLE_BYTES = 4 + 8 + 4 + 4 + 4  # id, ts, lat, lon, value


def _nbhd_means(table, stats):
    """Aggregate stratum stats to neighborhood means."""
    nb = np.asarray(table.neighborhood)[:-1]
    n = np.asarray(stats.n)[:-1]
    s = np.asarray(stats.wsum)[:-1]
    out_n = np.zeros(table.num_neighborhoods)
    out_s = np.zeros(table.num_neighborhoods)
    np.add.at(out_n, nb, n)
    np.add.at(out_s, nb, s)
    with np.errstate(invalid="ignore", divide="ignore"):
        return out_s / out_n, out_n


def run(fraction=0.8, num_edges=8, num_chunks=13):
    data = materialize(chicago_aq_stream(num_chunks=num_chunks, seed=11))
    table = make_table(*CHICAGO_BBOX, precision=6, neighborhood_precision=4)
    lat = jnp.asarray(data["lat"])
    lon = jnp.asarray(data["lon"])
    val = jnp.asarray(data["value"])
    n = val.shape[0]

    # ---------------- ground truth (100% of the data) -----------------------
    sidx_full = table.assign(lat, lon)
    full_stats = estimators.sample_stats(val, sidx_full, jnp.ones(n, bool), table.num_slots)
    true_means, true_n = _nbhd_means(table, full_stats)

    # ---------------- SpatialSSJP: centralized one-pass ---------------------
    @jax.jit
    def cloud_only(lat, lon, val, key):
        sidx = table.assign(lat, lon)  # spatial join in the cloud
        res = sampling.edgesos(key, sidx, table.num_slots, fraction)
        stats = estimators.sample_stats(val, sidx, res.mask, table.num_slots, counts=res.counts)
        return stats

    cloud_stats = cloud_only(lat, lon, val, jax.random.key(42))
    cloud_means, _ = _nbhd_means(table, cloud_stats)
    cloud_us = time_call(cloud_only, lat, lon, val, jax.random.key(42))

    # ---------------- EdgeApproxGeo: decentralized + pre-agg ----------------
    # edge side: each shard samples its substream independently
    splits = np.array_split(np.arange(n), num_edges)

    @jax.jit
    def edge_step(lat_s, lon_s, val_s, key):
        sidx = table.assign(lat_s, lon_s)
        res = sampling.edgesos(key, sidx, table.num_slots, fraction)
        return estimators.sample_stats(val_s, sidx, res.mask, table.num_slots, counts=res.counts)

    edge_stats = []
    edge_us = []
    for i, idx in enumerate(splits):
        idxj = jnp.asarray(idx)
        a = (lat[idxj], lon[idxj], val[idxj], jax.random.key(100 + i))
        edge_stats.append(edge_step(*a))
        edge_us.append(time_call(edge_step, *a))

    # cloud side: merge pre-aggregated per-stratum moments only
    @jax.jit
    def cloud_merge(stats_list):
        return estimators.merge_all(stats_list)

    merged = cloud_merge(edge_stats)
    edge_means, _ = _nbhd_means(table, merged)
    merge_us = time_call(cloud_merge, edge_stats)

    # ---------------- error comparison (Fig 20) -----------------------------
    ok = true_n >= 20
    ape_cloud = np.abs(cloud_means[ok] - true_means[ok]) / np.abs(true_means[ok]) * 100
    ape_edge = np.abs(edge_means[ok] - true_means[ok]) / np.abs(true_means[ok]) * 100

    # ---------------- bytes shipped upstream --------------------------------
    bytes_raw = n * TUPLE_BYTES
    bytes_sampled = int(n * fraction) * (TUPLE_BYTES + 4 + 4)  # +geohash+nbhd
    bytes_preagg = num_edges * 4 * 4 * table.num_slots

    reduction = 100.0 * (cloud_us - merge_us) / max(cloud_us, 1e-9)
    lines = [
        csv_line("evc_cloud_only_us", cloud_us,
                 f"mean_ape_pct={ape_cloud.mean():.4f};p95_ape={np.percentile(ape_cloud,95):.4f}"),
        csv_line("evc_edge_total_us", float(np.max(edge_us)),
                 f"parallel_edge_max_shard_us={np.max(edge_us):.0f};mean_ape_pct={ape_edge.mean():.4f};p95_ape={np.percentile(ape_edge,95):.4f}"),
        csv_line("evc_cloud_merge_us", merge_us,
                 f"cloud_work_reduction_pct={reduction:.1f};paper_endtoend~15-20"),
        csv_line("evc_bytes_upstream", 0.0,
                 f"raw={bytes_raw};sampled={bytes_sampled};preagg={bytes_preagg};"
                 f"preagg_vs_raw_x={bytes_raw/max(bytes_preagg,1):.0f}"),
        csv_line("evc_error_parity", 0.0,
                 f"edge_minus_cloud_mean_ape={ape_edge.mean()-ape_cloud.mean():.4f};paper=no_significant_difference"),
    ]
    return lines
