"""Paper Fig 15-18: approximation accuracy vs sampling fraction and
geohash granularity (MAE / MAPE of per-cell mean speed vs 100% baseline).

Claims validated:
  * MAPE < 10% at 80% sampling, Geohash-6 (Fig 16);
  * MAE decreases ~linearly with fraction (Fig 15);
  * Geohash-5 reduces error ~30% vs Geohash-6 at the same fraction
    (Fig 17-18) — larger cells => more samples per stratum => stabler means.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import estimators, make_table, sampling, SHENZHEN_BBOX
from repro.data.streams import materialize, shenzhen_taxi_stream

from .common import csv_line, mape_mae


def _per_stratum_accuracy(table, lat, lon, val, fraction, key):
    sidx = table.assign(lat, lon)
    res = sampling.edgesos(key, sidx, table.num_slots, fraction, method="srs")
    stats = estimators.sample_stats(val, sidx, res.mask, table.num_slots, counts=res.counts)
    full = estimators.sample_stats(val, sidx, jnp.ones_like(res.mask), table.num_slots)
    counts = np.asarray(res.counts)[:-1]
    est = np.asarray(stats.mean)[:-1]
    true = np.asarray(full.mean)[:-1]
    return est, true, counts


def run(fractions=(0.2, 0.4, 0.6, 0.8, 1.0), num_chunks=12, min_count=20):
    data = materialize(shenzhen_taxi_stream(num_chunks=num_chunks, seed=3))
    lat = jnp.asarray(data["lat"])
    lon = jnp.asarray(data["lon"])
    val = jnp.asarray(data["value"])
    lines = []
    results = {}
    for precision in (5, 6):
        table = make_table(*SHENZHEN_BBOX, precision=precision)
        for f in fractions:
            est, true, counts = _per_stratum_accuracy(
                table, lat, lon, val, f, jax.random.key(int(f * 100) + precision)
            )
            mape, mae = mape_mae(est, true, counts, min_count=min_count)
            results[(precision, f)] = (mape, mae)
            lines.append(
                csv_line(f"accuracy_g{precision}_f{int(f*100)}", 0.0,
                         f"mape_pct={mape:.3f};mae={mae:.4f};n_strata={int((counts>=min_count).sum())}")
            )
    m6, m5 = results[(6, 0.8)][0], results[(5, 0.8)][0]
    improve = 100.0 * (m6 - m5) / max(m6, 1e-9)
    lines.append(csv_line("accuracy_gate_mape80_g6", 0.0,
                          f"mape_pct={m6:.3f};paper_gate=<10;pass={m6 < 10.0}"))
    lines.append(csv_line("accuracy_g5_vs_g6_at80", 0.0,
                          f"g5={m5:.3f};g6={m6:.3f};reduction_pct={improve:.1f};paper~30"))
    lines.extend(bounds_coverage(lat, lon, val))
    return lines


def bounds_coverage(lat, lon, val, trials=30, fractions=(0.4, 0.8)):
    """Observed CI coverage + relative error of the error-bounded aggregate
    families (mean: eq 5-10; var/p99: stratified bootstrap) against the
    fraction-1 truth — the paper's error-bounded claim, extended beyond
    MEAN by the bounds subsystem."""
    from repro.core import AggSpec, EdgeCloudPipeline, Query

    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table)
    n = min(40_000, int(lat.shape[0]))
    win = {"lat": lat[:n], "lon": lon[:n], "value": val[:n]}
    q = Query(aggs=(AggSpec("mean", "value"), AggSpec("var", "value"),
                    AggSpec("p99", "value")))
    truth = pipe.execute(q, jax.random.key(0), win, 1.0).estimates
    keys = ("mean_value", "var_value", "p99_value")
    lines = []
    for f in fractions:
        cover = dict.fromkeys(keys, 0)
        rels = {k: [] for k in keys}
        for t in range(trials):
            est = pipe.execute(q, jax.random.key(1_000 + t), win, f).estimates
            for k in keys:
                tv = float(truth[k].value)
                if float(est[k].ci_low) - 1e-6 <= tv <= float(est[k].ci_high) + 1e-6:
                    cover[k] += 1
                rels[k].append(float(est[k].relative_error))
        for k in keys:
            lines.append(csv_line(
                f"accuracy_bounds_{k}_f{int(f * 100)}", 0.0,
                f"coverage={cover[k] / trials:.3f};nominal=0.95;"
                f"median_rel_err={np.median(rels[k]):.5f};trials={trials}"))
    return lines
