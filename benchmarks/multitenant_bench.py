"""Multi-tenant serving scale: Q registered queries on one StreamSession.

The paper's deployment story is many independent consumers — dashboards,
alerts, per-district monitors — each registering a slice of the same
geospatial stream.  This bench drives Q ∈ {16, 256, 1024} registered
queries (tenants split across bbox ROIs, confidences, and value columns,
so the session holds several fusion groups and several *finalize
signatures*) through paned streams and measures the three serving-layer
contracts of the multi-tenant session:

  * **per-pane finalize wall** — the batched signature-vmapped emit
    (``emit_all`` / due-window emit) vs the per-query Python finalize loop
    (``batched_finalize=False``), same session, same rings.  Gated as
    ``multitenant_finalize_speedup`` (median of paired repeats) at Q=256.
  * **register-churn latency** — median microseconds for one
    register+unregister round trip against a full tenant population; the
    incremental planner touches exactly one fusion group.
  * **compile counts** — a churn storm over structurally-seen queries must
    perform **zero** recompiles: every pipeline jit family (exec, pass,
    refined pass, finalize) is value-keyed and caches hit.  Gated
    absolute as ``churn_compile_count`` with ``{"max": 0}``.

``--q N`` restricts the CSV run to one population size (the nightly soak
runs ``--q 1024``).  ``--json PATH`` runs the fixed small CI configuration
and writes the metrics ``benchmarks/regression.py`` gates.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    StreamSession,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

from .common import REPEATS, csv_line, median_of_k, time_call

WINDOW = 20_000
FRACTION = 0.8
# Shenzhen split into south/north halves: two sampling signatures (fusion
# groups) per method, while finalize signatures ignore ROI entirely — the
# batched emit spans groups
ROI_SOUTH = ((22.45, 22.66), (113.76, 114.64))
ROI_NORTH = ((22.64, 22.86), (113.76, 114.64))


def _tenants(q: int) -> list[Query]:
    """Q tenant queries: mean-over-column dashboards fanned across 2 ROIs,
    2 confidences, and 2 columns.

    That yields up to 4 fusion groups (method x ROI... here srs x 2 ROIs,
    with the ROI inside the sampling signature) but only up to 4 *finalize*
    signatures (confidence x column — ROI drops out), so Q tenants emit
    through <= 4 vmapped finalize dispatches.  Analytic eq-10 error bounds
    (no bootstrap) keep the QoS controller fed without per-tenant replicate
    work — the dashboard-fleet configuration.
    """
    cols = ("value", "occupancy")
    rois = (ROI_SOUTH, ROI_NORTH)
    confs = (0.95, 0.99)
    return [
        Query(
            aggs=(AggSpec("mean", cols[i % 2]),),
            confidence=confs[(i // 2) % 2],
            roi=rois[(i // 4) % 2],
            bootstrap_replicates=0,
        )
        for i in range(q)
    ]


def _pane(window: int = WINDOW, chunks: int = 2) -> dict:
    w = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=chunks, seed=0), window))
    return {
        "lat": jnp.asarray(w.lat, jnp.float32),
        "lon": jnp.asarray(w.lon, jnp.float32),
        "valid": jnp.asarray(w.valid),
        "value": jnp.asarray(w.value, jnp.float32),
        "occupancy": jnp.asarray(w.extra["occupancy"], jnp.float32),
    }


def _serving_session(pipe, q: int, win, key) -> StreamSession:
    """A warmed Q-tenant session: registered, one pane stepped (rings
    filled), both emit paths compiled."""
    sess = StreamSession(pipe, initial_fraction=FRACTION)
    for query in _tenants(q):
        sess.register(query)
    sess.step(key, win)
    return sess


def _emit_walls(sess, key) -> tuple[float, float]:
    """(batched_us, loop_us) for one full-population serving read, same
    session and rings for both arms."""

    def batched():
        out = sess.emit_all(key)
        # time the dispatches, not per-row materialization: a serving read
        # returns the stacked estimates; per-tenant views slice lazily
        return [b.estimates for b in out._batches] or [
            r.estimates for r in out.values()
        ]

    def loop():
        sess.batched_finalize = False
        try:
            return [r.estimates for r in sess.emit_all(key).values()]
        finally:
            sess.batched_finalize = True

    return time_call(batched), time_call(loop)


def _churn(sess, probe: Query, rounds: int = 50) -> float:
    """Median microseconds for one register+unregister round trip (the
    incremental planner touches exactly one fusion group)."""
    samples = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        reg = sess.register(probe)
        sess.unregister(reg)
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(samples))


def run(only_q: int | None = None):
    table = make_table(*SHENZHEN_BBOX, precision=4)
    pipe = EdgeCloudPipeline(table, PipelineConfig())
    win = _pane(chunks=3)
    key = jax.random.key(0)
    for q in (16, 256, 1024):
        if only_q is not None and q != only_q:
            continue
        sess = _serving_session(pipe, q, win, key)
        batched_us, loop_us = _emit_walls(sess, key)
        base = pipe.compile_count
        churn_us = _churn(sess, _tenants(1)[0])
        sess.step(key, win)
        jax.block_until_ready([b.estimates for b in sess.emit_all(key)._batches])
        compiles = pipe.compile_count - base
        yield csv_line(
            f"multitenant_bench/finalize_batched_q{q}", batched_us,
            f"window={WINDOW};tenants={q};groups={len(sess._groups())};"
            f"speedup={loop_us / max(batched_us, 1e-9):.2f}x",
        )
        yield csv_line(
            f"multitenant_bench/finalize_loop_q{q}", loop_us,
            f"window={WINDOW};tenants={q}",
        )
        yield csv_line(
            f"multitenant_bench/register_churn_q{q}", churn_us,
            f"tenants={q};churn_compiles={compiles};"
            f"plan_decisions={len(sess.plan_log)}",
        )


def small_metrics(q: int = 256, window: int = WINDOW, fraction: float = FRACTION) -> dict:
    """Fixed small-configuration metrics for CI regression tracking.

    The two acceptance gates of the multi-tenant serving layer
    (``benchmarks/baselines.json``): batched-finalize speedup over the
    per-query loop at Q=256 (median of paired repeats), and a zero
    compile count under register/unregister churn at steady state.
    """
    table = make_table(*SHENZHEN_BBOX, precision=4)
    pipe = EdgeCloudPipeline(table, PipelineConfig())
    win = _pane(window)
    key = jax.random.key(0)
    sess = _serving_session(pipe, q, win, key)

    # parity first: the batched emit must agree with the per-query loop
    batched = {qid: r.estimates for qid, r in sess.emit_all(key).items()}
    sess.batched_finalize = False
    looped = {qid: r.estimates for qid, r in sess.emit_all(key).items()}
    sess.batched_finalize = True
    for qid, est in looped.items():
        for k, ref in est.items():
            np.testing.assert_allclose(
                np.asarray(batched[qid][k].value), np.asarray(ref.value),
                rtol=1e-5, err_msg=f"batched finalize diverged: qid={qid} {k}",
            )

    walls: list[tuple[float, float]] = []

    def paired_speedup() -> float:
        b, lo = _emit_walls(sess, key)
        walls.append((b, lo))
        return lo / max(b, 1e-9)

    speedup = median_of_k(paired_speedup, REPEATS)
    batched_us = float(np.median([b for b, _ in walls]))
    loop_us = float(np.median([lo for _, lo in walls]))

    base = pipe.compile_count
    churn_us = _churn(sess, _tenants(1)[0])
    sess.step(key, win)
    jax.block_until_ready([b.estimates for b in sess.emit_all(key)._batches])

    return {
        "config": {
            "window": window,
            "tenants": q,
            "fraction": fraction,
            "precision": 4,
            "fusion_groups": len(sess._groups()),
        },
        "repeats": REPEATS,
        "multitenant_finalize_batched_us": batched_us,
        "multitenant_finalize_loop_us": loop_us,
        "multitenant_finalize_speedup": speedup,
        "register_unregister_us": churn_us,
        "churn_compile_count": pipe.compile_count - base,
        "plan_decisions": len(sess.plan_log),
    }


def main() -> None:
    """Standalone entry: ``python -m benchmarks.multitenant_bench
    [--q N] [--json PATH]``."""
    import sys

    from .common import json_flag_path, write_metrics_json

    path = json_flag_path(sys.argv[1:])
    if path is not None:
        write_metrics_json(path, small_metrics(), "multitenant_bench")
        return
    only_q = None
    if "--q" in sys.argv:
        only_q = int(sys.argv[sys.argv.index("--q") + 1])
    print("name,us_per_call,derived")
    for line in run(only_q):
        print(line, flush=True)


if __name__ == "__main__":
    main()
