"""Generate EXPERIMENTS.md from dry-run artifacts + the perf-iteration log.

Usage: PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md
"""

from __future__ import annotations

import json
import os

from .roofline import load_records

V1_DIR = "experiments/dryrun_v1_snapshot"
V2_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")

ARCH_ORDER = [
    "xlstm-1.3b", "mistral-large-123b", "deepseek-67b", "internlm2-1.8b",
    "qwen1.5-0.5b", "qwen2-vl-72b", "seamless-m4t-large-v2", "zamba2-7b",
    "granite-moe-3b-a800m", "olmoe-1b-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# ---------------------------------------------------------------------------
# Perf iteration log (hypothesis -> change -> before -> after -> verdict).
# Numbers are filled from artifacts where available; the narrative is the
# experiment journal.
# ---------------------------------------------------------------------------

PERF_LOG = [
    {
        "cell": "olmoe-1b-7b x train_4k (single pod)",
        "iter": 1,
        "hypothesis": (
            "The jit/GSPMD lowering of scatter-based MoE dispatch replicates "
            "the (E*C, d) buffer per chip (napkin: 1M tokens * 8 slots * 1.25 "
            "cf * 2048 d * bf16 = 43 GiB unsharded); an explicit shard_map "
            "dispatch with activations replicated over the model axis needs "
            "zero all-to-all and only a psum of (B,S,d) per layer."
        ),
        "change": "models/moe.py: shard_map expert-parallel dispatch (EP when E%%tp==0, per-expert FFN-dim sharding otherwise)",
        "before": "211.5 GiB/chip (compile-OOM vs 16 GiB HBM), collective 292 s",
        "after": "13.1 GiB/chip, collective 2.9 s",
        "verdict": "CONFIRMED (100x collective reduction; fits)",
    },
    {
        "cell": "mistral-large-123b x train_4k (single pod)",
        "iter": 2,
        "hypothesis": (
            "f32 master params are all-gathered at every use (FSDP): casting "
            "to bf16 once per step before the layer loop should halve gather "
            "bytes and gathered-weight temps."
        ),
        "change": "train_loop.cast_for_compute (bf16 copy + optimization_barrier)",
        "before": "collective 473 s, 28.6 GiB/chip",
        "after": "collective 919 s at mb=16 (WORSE)",
        "verdict": (
            "REFUTED as stated: XLA sank the converts into the loop (gathers "
            "stayed f32) and doubling microbatches doubled gather traffic. "
            "Led to iteration 3."
        ),
    },
    {
        "cell": "mistral-large-123b x train_4k (single pod)",
        "iter": 3,
        "hypothesis": (
            "HLO shows f32[12288,28672] FULL-weight gathers: with sequence "
            "parallelism the seq sharding propagated INTO the matmuls, so "
            "GSPMD replicated the weights instead of Megatron-style "
            "gather-activations-at-block-entry. Interior constraints on "
            "q/k/v (heads@model) and MLP hidden (mlp@model) + an "
            "optimization_barrier on the bf16 cast should restore TP."
        ),
        "change": "layers.py interior activation constraints; barrier on cast; mb back to 8",
        "before": "collective 919 s, memory 230 s",
        "after": "collective 142 s, memory 69 s (raw); 109 s TPU-corrected",
        "verdict": "CONFIRMED (6.5x collective, 3.3x memory)",
    },
    {
        "cell": "all bf16 cells (analysis layer)",
        "iter": 4,
        "hypothesis": (
            "Remaining f32 collectives at bf16 dot sites are an XLA:CPU "
            "artifact: float normalization rewrites bf16 dots to f32 BEFORE "
            "SPMD partitioning, so the CPU-lowered module moves 2x the bytes "
            "a TPU would. Verified on a minimal einsum (StableHLO dot is "
            "bf16; partitioned HLO gathers f32)."
        ),
        "change": "launch/hlo.py: dtype-corrected collective accounting (producer/consumer convert-chase); roofline uses corrected bytes",
        "before": "mistral train collective 7.09e12 B/chip (raw parse)",
        "after": "5.44e12 B/chip corrected (measured f32-origin fraction)",
        "verdict": "CONFIRMED (correction applied; raw numbers retained in artifacts)",
    },
    {
        "cell": "zamba2-7b x train_4k",
        "iter": 5,
        "hypothesis": (
            "Chunked-GLA intra-chunk blocks (B,NC,H,C,C) dominate temps "
            "(~1 GiB f32 per tensor at C=256, H=112); halving C quarters "
            "them at ~2x more inter-chunk scan steps (cheap: state is "
            "(H,64,64))."
        ),
        "change": "zamba2 config chunk_size 256 -> 128",
        "before": "23.6 GiB/chip",
        "after": "22.8 GiB raw / 21.8 TPU-corrected",
        "verdict": (
            "PARTIAL: intra-chunk scores shrank as predicted but the "
            "backward pass keeps several (B,NC,H,C,C) decay/score tensors "
            "live regardless of C (count grows as NC does). Next: a Pallas "
            "chunked-GLA kernel with recomputed decay masks (the masks are "
            "rank-1 outer products — never worth materializing)."
        ),
    },
    {
        "cell": "mistral-large-123b x decode_32k (single pod)",
        "iter": 6,
        "hypothesis": (
            "With kv_heads(8) < model axis(16) the KV cache is sequence-"
            "sharded and GSPMD all-gathers B_loc*32K*8*128 bf16 (~2.1 GiB "
            "k+v) per layer at every decode step; a shard_map flash-decode "
            "(local LSE + one psum of (B,H,dh)+normalizers) removes the "
            "gather entirely."
        ),
        "change": "layers.sharded_decode_attention + dispatch in _attn_decode",
        "before": "22.6 GiB/chip, memory term 1.58 s, collective 0.375 s",
        "after": "16.7 GiB TPU-corrected, collective 0.230 s",
        "verdict": (
            "CONFIRMED — and the integration test for this path "
            "(tests/test_sharded_exec.py) caught a real math bug in the "
            "first version: sharding q-heads AND cache-seq over the same "
            "axis computes only diagonal (heads_i x chunk_i) blocks. Fixed "
            "by replicating q over the model axis (one token — tiny); "
            "exact vs the dense reference to 1e-7 on a real 8-device mesh."
        ),
    },
    {
        "cell": "prefill cells (seamless, zamba, xlstm, mistral)",
        "iter": 7,
        "hypothesis": (
            "Prefill lowerings returned decode states with XLA-chosen "
            "(unsharded) output layouts: seamless 112 GiB/chip, zamba 218 "
            "GiB/chip are the unsharded cross-KV / window caches; passing "
            "decode-layout out_shardings fixes fit with zero compute change."
        ),
        "change": "dryrun.py prefill out_shardings = decode state specs",
        "before": "seamless prefill 112.7 GiB/chip; zamba prefill 218.6 GiB/chip",
        "after": "seamless 17.1 GiB; zamba 19.5 GiB (v2 sweep)",
        "verdict": "CONFIRMED (6.6x / 11.2x)",
    },
    {
        "cell": "mistral-large-123b x decode_32k (single pod)",
        "iter": 8,
        "hypothesis": (
            "22.6 GiB/chip despite the flash-decode: the HLO shows (a) "
            "GSPMD's dynamic-update-slice on the seq-sharded cache and (b) "
            "f32[88,8,2048,8,128] shadow copies (5.5 GiB each) of the bf16 "
            "cache — XLA:CPU has no bf16 dot units, so float normalization "
            "keeps loop-carried f32 twins. Fuse the cache update into the "
            "flash-decode shard_map; use preferred_element_type=f32 "
            "(bf16 operands, f32 accumulate — MXU-native) so no operand "
            "converts exist; account residual CPU-only shadows explicitly."
        ),
        "change": (
            "fused update in sharded_decode_attention; mixed-precision "
            "einsums in all attention/GLA paths; hlo.f32_shadow_bytes "
            "(loop-carried f32 twins of bf16 tensors) reported as "
            "peak_tpu_estimate"
        ),
        "before": "22.6 GiB/chip raw; collective 0.375 s",
        "after": "15.3 GiB/chip TPU-corrected (7.3 GiB identified as CPU-only f32 shadows); collective 0.189 s",
        "verdict": "CONFIRMED (fits 16 GiB on target; 2x decode collective cut)",
    },
    {
        "cell": "mistral-large-123b x prefill_32k (single pod)",
        "iter": 9,
        "hypothesis": (
            "HLO shows a 24 GiB all-gather of the attention probability "
            "tensor f32[2,8,12,1024,32768]: the KV-cache's seq@model output "
            "constraint back-propagated into the attention operands, so "
            "scores were kv-seq-sharded and the p@v matmul forced a full "
            "gather. Constraining q/k/v to the TP layout right before "
            "attention decouples compute layout from cache layout."
        ),
        "change": "transformer.prefill: explicit pre-attention constraints (q heads@model, kv replicated)",
        "before": "collective 71.1 s, 24 GiB probability gather",
        "after": "collective 24.5 s (2.9x); remaining 53 GiB temps identified as ~14 live f32 residual-stream copies (CPU materialization of fused-on-TPU norm intermediates) — next step: chunked prefill (Sarathi-style) bounds them structurally",
        "verdict": "CONFIRMED for collectives; memory gap root-caused + next step scoped",
    },
    {
        "cell": "granite-moe-3b-a800m x prefill_32k (regression caught)",
        "iter": 10,
        "hypothesis": (
            "Iteration 9's pre-attention TP constraints are safe everywhere "
            "because the divisibility fallback replicates non-dividing dims."
        ),
        "change": "(the iteration-9 constraints, swept over all archs)",
        "before": "granite prefill 17.6 GiB/chip",
        "after": "205.7 GiB/chip — REGRESSION: granite has 24 heads on a "
        "16-way model axis; the fallback produced an *explicit replicated* "
        "constraint, pinning the full probability tensor on every chip. "
        "Fixed by skipping the constraint when heads %% tp != 0 "
        "(constraining-to-replicated is worse than not constraining). "
        "Re-swept: 17.5 GiB.",
        "verdict": "REFUTED then FIXED — fallback semantics now documented "
        "in layers.py; every arch re-verified",
    },
]

# The three hillclimbed cells (per the assignment: worst roofline fraction,
# most collective-bound, most representative of the paper's technique):
HILLCLIMB_SUMMARY = """
### Hillclimbed cells (final v3 numbers, single-pod)

1. **olmoe-1b-7b x train_4k** (most representative of the paper's technique:
   MoE dispatch IS stratified routing — experts = strata, capacity =
   allocation; and the paper's 'pre-partitioned delivery => shuffle-free
   aggregation' maps to EP): iteration 1.
   211.5 GiB -> **3.8 GiB/chip** TPU-corrected; collective 292 s -> **2.7 s**
   (zero all-to-all EP dispatch via shard_map). Now memory-dominated.
2. **mistral-large-123b x train_4k** (worst roofline fraction among the
   big trains): iterations 2-4. collective 473 s -> **98 s** (4.8x), memory
   230 s -> **69 s** (3.3x), per-chip 28.6 -> **16.2 GiB** TPU-corrected;
   roofline fraction 0.042 -> **0.232**. Residual bottleneck: Megatron TP
   activation all-reduces (2/layer/microbatch) — structural at global
   batch 256 on a 16-way TP axis; the next levers are comm/compute overlap
   (latency hiding, not bytes) and fp8/int8 TP activation compression.
3. **mistral-large-123b x decode_32k + prefill_32k** (most collective-bound
   serving cells): iterations 6, 8, 9. Flash-decode shard_map (no cache
   gather) + fused sharded cache update + decoupled attention/cache
   layouts: decode collective 0.375 -> **0.230 s** and fits (16.7 GiB
   TPU-corrected); prefill collective 71.1 -> **24.5 s** (2.9x) with the
   24 GiB probability gather eliminated.

Paper-faithful baseline vs beyond-paper: the paper's technique (EdgeSOS +
routing + estimators) is the data plane and is unchanged throughout — its
own numbers are in the benchmark suite (MAPE gates, mode equivalence,
bandwidth table). The §Perf iterations above are the beyond-paper systems
work on the surrounding framework; v1 artifacts
(`experiments/dryrun_v1_snapshot/`) hold the pre-optimization baselines,
v2 (`experiments/dryrun_v2_snapshot/`) the midpoint, `experiments/dryrun/`
the final state.
"""


def _fmt_seconds(x):
    return f"{x:.3e}"


def _mem_gib(r):
    m = r["memory"]
    return m.get("peak_tpu_estimate_bytes", m["peak_estimate_bytes"]) / 2**30


def _roofline_table(recs):
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | roofline frac | useful FLOPs | GiB/chip* | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod16x16", "pod2x16x16"):
                r = by_key.get((arch, shape, mesh))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    if mesh == "pod16x16":
                        skips.append((arch, shape, r["reason"]))
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | ERROR: {r.get('error','')[:60]} | | | | | | | |")
                    continue
                rf = r["roofline"]
                mem = _mem_gib(r)
                note = _bottleneck_note(r)
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {_fmt_seconds(rf['compute_s'])} | "
                    f"{_fmt_seconds(rf['memory_s'])} | {_fmt_seconds(rf['collective_s'])} | "
                    f"{rf['dominant']} | {rf['roofline_fraction']:.3f} | "
                    f"{r['useful_flops_ratio']:.2f} | {mem:.1f} | {note} |"
                )
    return lines, skips


def _bottleneck_note(r):
    rf = r["roofline"]
    dom = rf["dominant"]
    fam = r.get("family", "")
    shape = r["shape"]
    if dom == "collective":
        if fam in ("moe",):
            return "EP psum of (B,S,d) per layer; next: reduce-scatter combine"
        if shape == "train_4k":
            return "TP act all-reduce + FSDP gathers; next: fewer microbatches / comm overlap"
        return "SP boundary gathers; next: fuse with attention"
    if dom == "memory":
        if shape.startswith("decode"):
            return "cache-read bound (decode is bandwidth-limited by design)"
        if shape == "long_500k":
            return "recurrent state streaming; tiny absolute time"
        return "activation traffic; next: larger fused blocks / Pallas attention"
    return "compute-bound (good)"


def _dryrun_section(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    err = [r for r in recs if r["status"] == "error"]
    skip = [r for r in recs if r["status"] == "skipped"]
    lines = [
        "## §Dry-run",
        "",
        f"Cells attempted: {len(recs)} = 10 archs x 4 shapes x 2 meshes "
        f"(single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512 chips).",
        f"**{len(ok)} compiled**, {len(skip)} documented skips, {len(err)} errors.",
        "",
        "Every cell lowers with `jax.jit(step, in_shardings=..., "
        "out_shardings=...).lower(*input_specs).compile()`; artifacts "
        "(`experiments/dryrun/*.json`) record memory_analysis, XLA "
        "cost_analysis, our loop-aware HLO analysis (FLOPs / bytes / "
        "collective bytes with `known_trip_count` multipliers), and the "
        "collective schedule per op type.",
        "",
        "Documented skips (assignment rule: long_500k only for sub-quadratic "
        "archs):",
        "",
    ]
    seen = set()
    for r in skip:
        k = (r["arch"], r["shape"])
        if k in seen:
            continue
        seen.add(k)
        lines.append(f"* `{r['arch']} x {r['shape']}`: {r['reason']}")
    lines.append("")
    # memory fit summary (TPU-corrected: minus XLA:CPU's f32 shadow copies
    # of bf16 loop state, which don't exist on the bf16-native target)
    over = [r for r in ok if _mem_gib(r) > 16]
    lines.append(
        f"Per-chip memory (args+temps+outs-aliased, TPU-corrected — see "
        f"§Roofline note) vs the 16 GiB v5e HBM: {len(ok) - len(over)}/"
        f"{len(ok)} cells fit; the rest are called out in §Perf with "
        "root causes and next steps."
    )
    if over:
        lines.append("")
        lines.append("Over 16 GiB (TPU-corrected): " + ", ".join(
            f"`{r['arch']}x{r['shape']}@{r['mesh']}` ({_mem_gib(r):.1f} GiB)"
            for r in sorted(over, key=lambda x: -_mem_gib(x))))
    lines.append("")
    return lines


def _collective_summary(recs):
    lines = ["### Collective schedule summary (per-device bytes, ring model)", ""]
    lines.append("| cell | all-gather | all-reduce | reduce-scatter | all-to-all | permute | #ops |")
    lines.append("|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != "pod2x16x16":
            continue
        c = r["hlo_cost"]["collective_by_op"]
        lines.append(
            f"| {r['arch']} {r['shape']} | "
            + " | ".join(
                f"{c.get(op, 0):.2e}" for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
            )
            + f" | {r['hlo_cost']['num_collectives']} |"
        )
    lines.append("")
    return lines


def main():
    recs = load_records(V2_DIR)
    out = []
    out.append("# EXPERIMENTS")
    out.append("")
    out.append(
        "All numbers from the dry-run methodology (CPU host, 512 placeholder "
        "devices, TPU v5e hardware model: 197 TF/s bf16, 819 GB/s HBM, 50 "
        "GB/s ICI per chip). FLOPs/bytes/collective-bytes come from our "
        "loop-aware HLO analyzer (launch/hlo.py; validated against XLA "
        "cost_analysis on unrolled modules — tests/test_hlo_analysis.py). "
        "Collective bytes are dtype-corrected for XLA:CPU's bf16->f32 float "
        "normalization (artifact of the host backend, verified absent in "
        "the pre-partitioning StableHLO; both raw and corrected numbers are "
        "in the artifacts)."
    )
    out.append("")
    out.extend(_dryrun_section(recs))
    out.append("## §Roofline")
    out.append("")
    out.append(
        "Terms per cell (per-device): compute = FLOPs/197e12, memory = "
        "bytes/819e9, collective = moved-bytes/50e9. `roofline frac` = "
        "compute / max(all terms) — how close the cell is to being "
        "compute-bound; `useful FLOPs` = MODEL_FLOPS (6ND train / 2ND "
        "serve, active non-embedding params) / compiled HLO FLOPs — the "
        "remat/dispatch overhead factor."
    )
    out.append("")
    table, _ = _roofline_table(recs)
    out.extend(table)
    out.append("")
    out.append(
        "*GiB/chip is the TPU-corrected estimate: memory_analysis peak "
        "minus XLA:CPU's f32 shadow copies of bf16 loop-carried state "
        "(`hlo.f32_shadow_bytes`; the CPU backend has no bf16 compute units "
        "and keeps f32 twins that a TPU never materializes). Raw values "
        "are in the artifacts."
    )
    out.append("")
    okm = [r for r in recs if r["status"] == "ok" and r["mesh"] == "pod2x16x16"]
    if okm:
        worst = min(okm, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(okm, key=lambda r: r["roofline"]["collective_s"])
        out.append(
            f"Worst roofline fraction: `{worst['arch']} x {worst['shape']}` "
            f"({worst['roofline']['roofline_fraction']:.4f}); most collective-"
            f"bound: `{coll['arch']} x {coll['shape']}` "
            f"({coll['roofline']['collective_s']:.2e} s)."
        )
    out.append("")
    out.extend(_collective_summary(recs))
    out.append("## §Perf — hypothesis -> change -> measure log")
    out.append("")
    out.append(
        "Methodology: napkin-math hypothesis, implement, re-lower, re-analyze "
        "(the 'profile' is the partitioned HLO + analyzer, per the dry-run "
        "protocol). The paper-faithful baseline (v1 artifacts: "
        "`experiments/dryrun_v1_snapshot/`) is preserved separately from the "
        "optimized v2 sweep so the reproduction and the beyond-paper gains "
        "are both visible."
    )
    out.append("")
    for e in PERF_LOG:
        out.append(f"### Iteration {e['iter']} — {e['cell']}")
        out.append("")
        out.append(f"* **Hypothesis:** {e['hypothesis']}")
        out.append(f"* **Change:** {e['change']}")
        out.append(f"* **Before:** {e['before']}")
        out.append(f"* **After:** {e['after']}")
        out.append(f"* **Verdict:** {e['verdict']}")
        out.append("")
    out.append(HILLCLIMB_SUMMARY)
    print("\n".join(out))


if __name__ == "__main__":
    main()
