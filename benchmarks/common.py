"""Shared benchmark utilities: timing + the paper's error metrics."""

from __future__ import annotations

import time

import numpy as np

import jax


# CI-gated metrics re-measure this many times and gate on the median (see
# median_of_k); single-shot walls on shared runners are too noisy to gate
REPEATS = 3


def median_of_k(measure, k: int = REPEATS) -> float:
    """Median of ``k`` independent runs of ``measure()`` (a zero-arg callable
    returning one scalar metric, e.g. a paired speedup ratio).

    Re-measuring the *whole* metric — both arms of a ratio inside one
    ``measure`` call — keeps paired comparisons paired, so a noisy-neighbor
    burst on a CI runner skews one repeat, not the gate.
    """
    return float(np.median([measure() for _ in range(k)]))


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of fn(*args) in microseconds (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def mape_mae(est_means: np.ndarray, true_means: np.ndarray, counts: np.ndarray,
             min_count: int = 1):
    """Paper's per-stratum error metrics vs the 100%-sampling ground truth.

    MAPE/MAE over strata with >= min_count tuples (the paper's charts
    exclude near-empty cells' extreme outliers from the main figures).
    """
    ok = (counts >= min_count) & np.isfinite(true_means) & (np.abs(true_means) > 1e-9)
    e = est_means[ok]
    t = true_means[ok]
    ape = np.abs(e - t) / np.abs(t)
    return float(np.mean(ape) * 100.0), float(np.mean(np.abs(e - t)))


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def json_flag_path(argv) -> str | None:
    """The PATH following ``--json`` in argv, or None when the flag is
    absent; exits with a usage message instead of an IndexError when the
    flag is given without a path."""
    if "--json" not in argv:
        return None
    i = argv.index("--json") + 1
    if i >= len(argv) or argv[i].startswith("-"):
        raise SystemExit("usage: --json PATH")
    return argv[i]


def write_metrics_json(path: str, metrics: dict, prefix: str) -> None:
    """Dump a small-config metrics dict to ``path`` and echo the non-config
    entries as ``prefix/key,value`` lines (the CI log's human view)."""
    import json

    with open(path, "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
    print(f"wrote {path}")
    for k, v in sorted(metrics.items()):
        if k != "config":
            print(f"{prefix}/{k},{v}")
