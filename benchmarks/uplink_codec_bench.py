"""Uplink codec compression on the sparse-strata sketch workload.

The paper's bandwidth claim is that sufficient statistics beat tuples —
but the *dense* preagg frame still ships every sketch bin of every
stratum.  This bench builds the workload where that hurts most: a
Geohash-5 stratum table over the full Shenzhen bbox with the taxi fleet
confined to a small downtown sub-bbox (a handful of occupied strata out
of thousands), queried by a 4-column sketch query (p50/p99 over four
value columns — each column drags a full ``(S+1, 513)`` bin grid onto
the dense uplink).

Measured per codec: encoded frame bytes vs the analytic dense model
(:func:`repro.core.query.preagg_bytes`), the encode+decode round-trip
wall, and — for the lossless sparse codec — bit-exact estimate parity
against the dense uplink.  CI gates (``benchmarks/baselines.json``,
absolute):

  * ``uplink_codec_ratio`` >= 3.0 — the sparse codec must cut the
    sketch-heavy uplink by at least 3x (median of REPEATS re-measures);
  * ``codec_lossless_parity`` == 1 — every estimate field from the
    sparse-codec pipeline is bit-identical to the dense pipeline.

``--json PATH`` runs the fixed small CI configuration; the bare CSV mode
sweeps all codecs (sparse / delta / topk / quantize) across Geohash-5
and the ~32x-denser Geohash-6 table for the README's worked example.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    make_table,
    query as aqp,
    windows,
)
from repro.core import codec as wirecodec
from repro.data.streams import shenzhen_taxi_stream

from .common import REPEATS, csv_line, median_of_k

WINDOW = 20_000
FRACTION = 0.8
# the fleet stays downtown: a ~0.05 x 0.08 degree sub-bbox of Shenzhen,
# so a precision-5 table of the full city sees a handful of occupied strata
DOWNTOWN = ((22.53, 22.58), (114.05, 114.13))

CODECS = ("sparse", "delta", "topk16", "quantize16", "quantize8")

EXACT_FIELDS = ("value", "moe", "ci_low", "ci_high", "relative_error", "n", "population")


def _query() -> Query:
    """The 4-column sketch query: every column carries a bin grid."""
    return Query(
        aggs=(
            AggSpec("p50", "value"),
            AggSpec("p99", "value"),
            AggSpec("p50", "occupancy", name="p50_occ"),
            AggSpec("p99", "occupancy", name="p99_occ"),
            AggSpec("p50", "speed_sq", name="p50_sq"),
            AggSpec("p50", "wait", name="p50_wait"),
        )
    )


def _pane(window: int = WINDOW) -> dict:
    """One downtown pane with four value columns (two derived)."""
    w = next(
        windows.count_windows(
            shenzhen_taxi_stream(num_chunks=2, seed=0, bbox=DOWNTOWN), window
        )
    )
    value = np.asarray(w.value, np.float32)
    occ = np.asarray(w.extra["occupancy"], np.float32)
    return {
        "lat": jnp.asarray(w.lat, jnp.float32),
        "lon": jnp.asarray(w.lon, jnp.float32),
        "valid": jnp.asarray(w.valid),
        "value": jnp.asarray(value),
        "occupancy": jnp.asarray(occ),
        "speed_sq": jnp.asarray(value * value),
        "wait": jnp.asarray((1.0 - occ) * value),
    }


def _consolidated(pipe, win, key):
    """One dense execute: (plan, consolidated states, dense model bytes)."""
    q = _query()
    res = pipe.execute(q, key, win, fraction=FRACTION)
    plan = pipe.plan(q)
    return plan, res, aqp.preagg_bytes(plan, pipe.table.num_slots)


def _roundtrip_wall_us(codec_spec: str, stats) -> tuple[int, float, float]:
    """(encoded_bytes, encode_us, decode_us) for one frame (medians)."""
    codec = wirecodec.resolve_codec(codec_spec).for_stream()
    rows = wirecodec.flatten_stats(stats)
    enc_t, dec_t = [], []
    payload = codec.encode(rows)
    for _ in range(5):
        c = wirecodec.resolve_codec(codec_spec).for_stream()
        t0 = time.perf_counter()
        p = c.encode(rows)
        enc_t.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        c.decode(p)
        dec_t.append((time.perf_counter() - t0) * 1e6)
    return payload.nbytes, float(np.median(enc_t)), float(np.median(dec_t))


def run():
    key = jax.random.key(0)
    win = _pane()
    for precision in (5, 6):
        table = make_table(*SHENZHEN_BBOX, precision=precision)
        pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=WINDOW))
        _plan, res, dense = _consolidated(pipe, win, key)
        for spec in CODECS:
            nbytes, enc_us, dec_us = _roundtrip_wall_us(spec, res.stats)
            yield csv_line(
                f"uplink_codec_bench/{spec}_gh{precision}",
                enc_us + dec_us,
                f"window={WINDOW};strata={table.num_strata};dense={dense};"
                f"encoded={nbytes};ratio={dense / nbytes:.1f}x",
            )


def small_metrics(window: int = WINDOW, fraction: float = FRACTION) -> dict:
    """Fixed small-configuration metrics for CI regression tracking.

    The two acceptance gates of the uplink codec layer (absolute, see
    ``benchmarks/baselines.json``): a >= 3x sparse-codec byte reduction on
    the sparse-strata sketch workload, and bit-exact estimate parity
    between the sparse-codec and dense pipelines.
    """
    key = jax.random.key(0)
    win = _pane(window)
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=window))
    _plan, res, dense = _consolidated(pipe, win, key)

    def measured_ratio() -> float:
        nbytes, _enc, _dec = _roundtrip_wall_us("sparse", res.stats)
        return dense / nbytes

    ratio = median_of_k(measured_ratio, REPEATS)
    nbytes, enc_us, dec_us = _roundtrip_wall_us("sparse", res.stats)

    # parity: the sparse-codec pipeline's estimates must be bit-identical
    pipe_c = EdgeCloudPipeline(
        table, PipelineConfig(raw_capacity=window, uplink_codec="sparse")
    )
    res_c = pipe_c.execute(_query(), key, win, fraction=fraction)
    parity = 1
    for k in res.estimates:
        for field in EXACT_FIELDS:
            a = np.asarray(getattr(res.estimates[k], field))
            b = np.asarray(getattr(res_c.estimates[k], field))
            if not np.array_equal(a, b, equal_nan=True):
                parity = 0
    topk_bytes, _, _ = _roundtrip_wall_us("topk16", res.stats)
    q8_bytes, _, _ = _roundtrip_wall_us("quantize8", res.stats)

    return {
        "config": {
            "window": window,
            "fraction": fraction,
            "precision": 5,
            "strata": int(table.num_strata),
            "columns": 4,
            "sub_bbox": "downtown",
        },
        "repeats": REPEATS,
        "dense_bytes": int(dense),
        "encoded_bytes": int(nbytes),
        "uplink_codec_ratio": ratio,
        "codec_lossless_parity": parity,
        "codec_encode_us": enc_us,
        "codec_decode_us": dec_us,
        "topk16_ratio": dense / topk_bytes,
        "quantize8_ratio": dense / q8_bytes,
    }


def main() -> None:
    """Standalone entry: ``python -m benchmarks.uplink_codec_bench
    [--json PATH]``."""
    import sys

    from .common import json_flag_path, write_metrics_json

    path = json_flag_path(sys.argv[1:])
    if path is not None:
        write_metrics_json(path, small_metrics(), "uplink_codec_bench")
        return
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)


if __name__ == "__main__":
    main()
