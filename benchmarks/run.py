"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  Fig 8      -> ingest_throughput
  Fig 9-11   -> edgesos_latency
  Fig 15-16  -> accuracy (fraction sweep, MAPE gate)
  Fig 17-18  -> accuracy (geohash-5 vs -6)
  Fig 19     -> cloud_batch
  Fig 20-21  -> edge_vs_cloud (SpatialSSJP baseline implemented)
  kernels    -> kernel_bench
  query API  -> query_bench (grouped 3-aggregate query vs legacy path)
  serving    -> multitenant_bench (Q-tenant batched finalize + churn)
  §Roofline  -> roofline (reads experiments/dryrun artifacts)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        accuracy,
        cloud_batch,
        edge_vs_cloud,
        edgesos_latency,
        ingest_throughput,
        kernel_bench,
        multitenant_bench,
        query_bench,
        roofline,
        uplink_codec_bench,
    )

    modules = [
        ("ingest_throughput", ingest_throughput),
        ("edgesos_latency", edgesos_latency),
        ("accuracy", accuracy),
        ("cloud_batch", cloud_batch),
        ("edge_vs_cloud", edge_vs_cloud),
        ("kernel_bench", kernel_bench),
        ("query_bench", query_bench),
        ("multitenant_bench", multitenant_bench),
        ("uplink_codec_bench", uplink_codec_bench),
        ("roofline", roofline),
    ]
    args = sys.argv[1:]
    dry = "--dry" in args
    only = next((a for a in args if not a.startswith("-")), None)
    print("name,us_per_call,derived")
    failures = 0
    if dry:
        # smoke mode (CI): importing the modules above already exercises
        # their top-level code; just verify each still exposes a runner.
        for name, mod in modules:
            if only and name != only:
                continue
            if callable(getattr(mod, "run", None)):
                print(f"{name},0,DRY-OK")
            else:
                failures += 1
                print(f"{name},0,ERROR:no run() callable")
        if failures:
            raise SystemExit(f"{failures} benchmark modules failed the dry check")
        return
    for name, mod in modules:
        if only and name != only:
            continue
        try:
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
