"""Query-layer latency: 3-aggregate grouped query vs legacy single estimate.

Measures per-window device latency of (a) the legacy `process_window`
single SUM/MEAN path, (b) a 3-aggregate neighborhood-grouped declarative
query, and (c) the same query ungrouped — the cost of the API redesign's
generality on the hot path.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

from .common import csv_line, time_call

WINDOW = 50_000
FRACTION = 0.8


def run():
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=WINDOW))
    w = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=3, seed=0), WINDOW))
    lat = jnp.asarray(w.lat, jnp.float32)
    lon = jnp.asarray(w.lon, jnp.float32)
    val = jnp.asarray(w.value, jnp.float32)
    occ = jnp.asarray(w.extra["occupancy"], jnp.float32)
    valid = jnp.asarray(w.valid)
    key = jax.random.key(0)
    frac = jnp.float32(FRACTION)

    us = time_call(pipe.process_window, key, lat, lon, val, valid, frac)
    yield csv_line("query_bench/legacy_single_estimate", us, f"window={WINDOW}")

    aggs3 = (AggSpec("mean", "value"), AggSpec("max", "value"), AggSpec("mean", "occupancy"))
    win = {"lat": lat, "lon": lon, "valid": valid, "value": val, "occupancy": occ}
    for name, query in (
        ("query3_global", Query(aggs=aggs3)),
        ("query3_grouped_neighborhood", Query(aggs=aggs3, group_by="neighborhood")),
        ("query3_grouped_raw_mode", Query(aggs=aggs3, group_by="neighborhood", mode="raw")),
    ):
        us_q = time_call(pipe.execute, query, key, win, FRACTION)
        yield csv_line(
            f"query_bench/{name}", us_q,
            f"window={WINDOW};aggs={len(aggs3)};vs_legacy={us_q / max(us, 1e-9):.2f}x",
        )
