"""Query-layer latency: fused sessions, grouped queries, legacy estimate.

Measures per-window device latency of (a) the legacy `process_window`
single SUM/MEAN path, (b) a 3-aggregate neighborhood-grouped declarative
query, (c) the same query ungrouped — the cost of the API redesign's
generality on the hot path — (d) the headline of the session redesign:
a fused `StreamSession` answering N registered queries with ONE
stratify+EdgeSOS pass vs N independent `execute` calls, for
N ∈ {1, 4, 16}, in wall time and edge->cloud collective bytes — and
(e) the edge-reduce backend on a wide fusion group: the single-pass
multi-column reduction (`backend="pallas"`) vs the per-column segment
path, for 4- and 8-column groups, plus the quantile-sketch query cost and
the bootstrap error-bounds finalize overhead — and (f) the session
refinements: a mixed-fraction fusion group's downstream-bytes reduction
(the low-fraction member pays its own nested subsample, not the group
max) and the one-pass speedup of cross-signature Bernoulli fusion over
the one-pass-per-ROI-group behavior it replaces.

``--json PATH`` runs a fixed small configuration and writes the metrics
CI's regression gate consumes (``benchmarks/regression.py``).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    StreamSession,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

from .common import REPEATS, csv_line, median_of_k, time_call

WINDOW = 50_000
FRACTION = 0.8


def _query_set(n: int) -> list[Query]:
    """n distinct single-aggregate queries sharing one sampling signature
    (so the whole set is one fusion group)."""
    kinds = ("mean", "sum", "var", "count", "min", "max")
    cols = ("value", "occupancy")
    return [
        Query(
            aggs=(AggSpec(kinds[i % len(kinds)], cols[i % len(cols)], name=f"a{i}"),),
            confidence=0.95 if i % 2 == 0 else 0.99,
        )
        for i in range(n)
    ]


def run():
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=WINDOW))
    w = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=3, seed=0), WINDOW))
    lat = jnp.asarray(w.lat, jnp.float32)
    lon = jnp.asarray(w.lon, jnp.float32)
    val = jnp.asarray(w.value, jnp.float32)
    occ = jnp.asarray(w.extra["occupancy"], jnp.float32)
    valid = jnp.asarray(w.valid)
    key = jax.random.key(0)
    frac = jnp.float32(FRACTION)

    us = time_call(pipe.process_window, key, lat, lon, val, valid, frac)
    yield csv_line("query_bench/legacy_single_estimate", us, f"window={WINDOW}")

    aggs3 = (AggSpec("mean", "value"), AggSpec("max", "value"), AggSpec("mean", "occupancy"))
    win = {"lat": lat, "lon": lon, "valid": valid, "value": val, "occupancy": occ}
    for name, query in (
        ("query3_global", Query(aggs=aggs3)),
        ("query3_grouped_neighborhood", Query(aggs=aggs3, group_by="neighborhood")),
        ("query3_grouped_raw_mode", Query(aggs=aggs3, group_by="neighborhood", mode="raw")),
    ):
        us_q = time_call(pipe.execute, query, key, win, FRACTION)
        yield csv_line(
            f"query_bench/{name}", us_q,
            f"window={WINDOW};aggs={len(aggs3)};vs_legacy={us_q / max(us, 1e-9):.2f}x",
        )

    # fused session vs N independent executes (the multi-query fusion win);
    # both arms consume the same device-resident column mapping
    for n in (1, 4, 16):
        queries = _query_set(n)
        sess = StreamSession(pipe, initial_fraction=FRACTION)
        for q in queries:
            sess.register(q)

        def fused_step():
            step = sess.step(key, win)
            return [r.estimates for r in step.results.values()]

        def independent():
            return [pipe.execute(q, key, win, FRACTION).estimates for q in queries]

        us_fused = time_call(fused_step)
        us_indep = time_call(independent)
        fused_bytes = sess.step(key, win).comm_bytes
        indep_bytes = sum(
            int(pipe.execute(q, key, win, FRACTION).comm_bytes) for q in queries
        )
        yield csv_line(
            f"query_bench/session_fused_n{n}", us_fused,
            f"window={WINDOW};queries={n};bytes={fused_bytes}",
        )
        yield csv_line(
            f"query_bench/independent_n{n}", us_indep,
            f"window={WINDOW};queries={n};bytes={indep_bytes};"
            f"fused_speedup={us_indep / max(us_fused, 1e-9):.2f}x;"
            f"bytes_ratio={indep_bytes / max(fused_bytes, 1):.2f}x",
        )

    # wide fusion groups: single-pass multi-column edge reduction vs the
    # per-column segment path (same plan, same sample, different backend)
    rng = np.random.default_rng(1)
    extras = ("speed", "heading", "accel", "altitude", "battery", "signal")
    wide = dict(win)
    for extra in extras:
        wide[extra] = jnp.asarray(rng.normal(30, 10, WINDOW), jnp.float32)
    for ncols in (4, 8):
        cols = (["value", "occupancy"] + list(extras))[:ncols]
        q_wide = Query(aggs=tuple(AggSpec("mean", c) for c in cols))
        backends = {}
        for backend in ("segment", "pallas"):
            p = EdgeCloudPipeline(table, PipelineConfig(backend=backend))
            backends[backend] = time_call(p.execute, q_wide, key, wide, FRACTION)
        yield csv_line(
            f"query_bench/edge_reduce_fused_c{ncols}", backends["pallas"],
            f"window={WINDOW};cols={ncols};"
            f"vs_percol={backends['segment'] / max(backends['pallas'], 1e-9):.2f}x",
        )
        yield csv_line(
            f"query_bench/edge_reduce_percol_c{ncols}", backends["segment"],
            f"window={WINDOW};cols={ncols}",
        )

    # per-query fraction refinement: a mixed-fraction fusion group refines
    # each member to its own fraction — the low-fraction member's downstream
    # volume shrinks by ~f_hi/f_lo instead of paying the group max
    for name, (f_lo, f_hi) in (("mixed_10_80", (0.1, 0.8)), ("shared_80_80", (0.8, 0.8))):
        sess_mix = StreamSession(pipe)
        r_lo = sess_mix.register(
            Query(aggs=(AggSpec("mean", "value"),)), initial_fraction=f_lo
        )
        r_hi = sess_mix.register(
            Query(aggs=(AggSpec("mean", "occupancy", name="occ"),)), initial_fraction=f_hi
        )
        us_mix = time_call(sess_mix.step, key, win)
        lo_b, hi_b = r_lo.downstream_bytes, r_hi.downstream_bytes
        yield csv_line(
            f"query_bench/refined_{name}", us_mix,
            f"window={WINDOW};fractions={f_lo}/{f_hi};"
            f"downstream_lo={lo_b};downstream_hi={hi_b};"
            f"lo_reduction={hi_b / max(lo_b, 1):.2f}x",
        )

    # cross-signature Bernoulli fusion: two differing-ROI Bernoulli queries
    # share ONE edge pass vs the PR4 behavior of one pass per ROI group
    roi_s = ((22.45, 22.66), (113.76, 114.64))
    roi_n = ((22.64, 22.86), (113.76, 114.64))
    qb = [
        Query(aggs=(AggSpec("mean", "value", name=f"b{i}"),), method="bernoulli", roi=roi)
        for i, roi in enumerate((roi_s, roi_n))
    ]
    sess_x = StreamSession(pipe, initial_fraction=FRACTION)
    for q in qb:
        sess_x.register(q)
    separate = [StreamSession(pipe, initial_fraction=FRACTION) for _ in qb]
    for s, q in zip(separate, qb):
        s.register(q)

    def one_pass():
        return sess_x.step(key, win)

    def two_passes():
        return [s.step(key, win) for s in separate]

    us_one = time_call(one_pass)
    us_two = time_call(two_passes)
    yield csv_line(
        "query_bench/bernoulli_cross_roi_fused", us_one,
        f"window={WINDOW};rois=2;passes={len(sess_x._groups())};"
        f"vs_separate_groups={us_two / max(us_one, 1e-9):.2f}x",
    )

    # quantile aggregates: the sketch's accumulate+finalize cost on top of
    # the same pass (p50/p99 over one column)
    q_quant = Query(aggs=(AggSpec("mean", "value"), AggSpec("p50", "value"), AggSpec("p99", "value")))
    us_quant = time_call(pipe.execute, q_quant, key, win, FRACTION)
    yield csv_line(
        "query_bench/quantile_p50_p99", us_quant,
        f"window={WINDOW};vs_query3={us_quant / max(us, 1e-9):.2f}x",
    )

    # error-bounds finalize cost: the bootstrap (var + p99 CIs, default 200
    # replicates) against the same query with bounds disabled
    aggs_b = (AggSpec("var", "value"), AggSpec("p99", "value"))
    us_bounds = time_call(pipe.execute, Query(aggs=aggs_b), key, win, FRACTION)
    us_nobounds = time_call(
        pipe.execute, Query(aggs=aggs_b, bootstrap_replicates=0), key, win, FRACTION
    )
    yield csv_line(
        "query_bench/bounds_var_p99", us_bounds,
        f"window={WINDOW};replicates=200;"
        f"vs_disabled={us_bounds / max(us_nobounds, 1e-9):.2f}x",
    )


def small_metrics(window: int = 20_000, n_queries: int = 4, fraction: float = FRACTION) -> dict:
    """Fixed small-configuration metrics for CI regression tracking.

    Wall microseconds, uplink bytes, and the fused-vs-independent speedup of
    an ``n_queries`` fusion group — the numbers ``benchmarks/baselines.json``
    gates (see ``benchmarks.regression``).
    """
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=window))
    w = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=2, seed=0), window))
    win = {
        "lat": jnp.asarray(w.lat, jnp.float32),
        "lon": jnp.asarray(w.lon, jnp.float32),
        "valid": jnp.asarray(w.valid),
        "value": jnp.asarray(w.value, jnp.float32),
        "occupancy": jnp.asarray(w.extra["occupancy"], jnp.float32),
    }
    key = jax.random.key(0)
    queries = _query_set(n_queries)
    sess = StreamSession(pipe, initial_fraction=fraction)
    for q in queries:
        sess.register(q)

    def fused_step():
        step = sess.step(key, win)
        return [r.estimates for r in step.results.values()]

    def independent():
        return [pipe.execute(q, key, win, fraction).estimates for q in queries]

    # the gated speedup is the median of REPEATS paired re-measurements
    # (both arms per repeat), not a single-shot wall — see common.median_of_k
    fused_walls: list[float] = []
    indep_walls: list[float] = []

    def paired_speedup() -> float:
        f = time_call(fused_step)
        i = time_call(independent)
        fused_walls.append(f)
        indep_walls.append(i)
        return i / max(f, 1e-9)

    fused_speedup = median_of_k(paired_speedup, REPEATS)
    us_fused = float(np.median(fused_walls))
    us_indep = float(np.median(indep_walls))
    fused_bytes = int(sess.step(key, win).comm_bytes)
    indep_bytes = sum(
        int(pipe.execute(q, key, win, fraction).comm_bytes) for q in queries
    )
    q_bounds = Query(aggs=(AggSpec("var", "value"), AggSpec("p99", "value")))
    us_bounds = time_call(pipe.execute, q_bounds, key, win, fraction)

    # per-query fraction refinement: the low-fraction member of a 0.1/0.8
    # group pays ~1/8 the downstream volume of the max member (PR4 charged
    # both the max) — a near-deterministic ratio, gated in baselines.json
    sess_mix = StreamSession(pipe)
    r_lo = sess_mix.register(
        Query(aggs=(AggSpec("mean", "value"),)), initial_fraction=0.1
    )
    r_hi = sess_mix.register(
        Query(aggs=(AggSpec("mean", "occupancy", name="occ"),)), initial_fraction=0.8
    )
    sess_mix.step(key, win)
    refined_ratio = r_hi.downstream_bytes / max(r_lo.downstream_bytes, 1)

    # cross-signature Bernoulli fusion: one pass for two differing ROIs vs
    # the PR4 one-pass-per-ROI-group behavior (same-machine A/B speedup)
    roi_s = ((22.45, 22.66), (113.76, 114.64))
    roi_n = ((22.64, 22.86), (113.76, 114.64))
    qb = [
        Query(aggs=(AggSpec("mean", "value", name=f"b{i}"),), method="bernoulli", roi=roi)
        for i, roi in enumerate((roi_s, roi_n))
    ]
    sess_x = StreamSession(pipe, initial_fraction=fraction)
    for q in qb:
        sess_x.register(q)
    separate = [StreamSession(pipe, initial_fraction=fraction) for _ in qb]
    for s, q in zip(separate, qb):
        s.register(q)
    us_one = time_call(lambda: sess_x.step(key, win))
    us_two = time_call(lambda: [s.step(key, win) for s in separate])

    return {
        "config": {
            "window": window,
            "queries": n_queries,
            "fraction": fraction,
            "precision": 5,
        },
        "repeats": REPEATS,
        f"session_fused_n{n_queries}_us": us_fused,
        f"independent_n{n_queries}_us": us_indep,
        f"fused_speedup_n{n_queries}": fused_speedup,
        f"fused_uplink_bytes_n{n_queries}": fused_bytes,
        f"independent_uplink_bytes_n{n_queries}": indep_bytes,
        f"uplink_ratio_n{n_queries}": indep_bytes / max(fused_bytes, 1),
        "bounds_var_p99_us": us_bounds,
        "refined_downstream_ratio": refined_ratio,
        "refined_downstream_bytes_lo": r_lo.downstream_bytes,
        "refined_downstream_bytes_hi": r_hi.downstream_bytes,
        "bernoulli_cross_roi_fused_us": us_one,
        "bernoulli_cross_roi_separate_us": us_two,
        "bernoulli_cross_roi_speedup": us_two / max(us_one, 1e-9),
    }


def main() -> None:
    """Standalone entry: ``python -m benchmarks.query_bench [--json PATH]``.

    ``--json PATH`` runs the fixed small CI configuration and writes the
    metrics dict (wall us, uplink bytes, fused speedup) to PATH; without it
    the full CSV benchmark suite streams to stdout.
    """
    import sys

    from .common import json_flag_path, write_metrics_json

    path = json_flag_path(sys.argv[1:])
    if path is not None:
        write_metrics_json(path, small_metrics(), "query_bench")
        return
    print("name,us_per_call,derived")
    for line in run():
        print(line, flush=True)


if __name__ == "__main__":
    main()
