"""Test config: CPU, single device (dry-run tests spawn subprocesses)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
