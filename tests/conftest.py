"""Test config: CPU, single device (dry-run tests spawn subprocesses).

Hypothesis profile selection (``HYPOTHESIS_PROFILE`` env var):

  * ``ci`` — derandomized (fixed seed, so a red PR is red for the author
    too) with ``print_blob=True``: a failing property test prints a
    copy-pasteable ``@reproduce_failure`` blob in the CI log.
  * ``nightly`` — randomized search at 10x ``max_examples``, no deadline;
    the long-tail sweep PRs shouldn't pay for.
  * unset — hypothesis defaults: randomized local search.

``tests/_hypothesis_fallback.py`` honors the same env var when hypothesis
isn't installed (the container's tier-1 path).
"""

import os

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci",
        derandomize=True,
        print_blob=True,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile(
        "nightly",
        max_examples=1000,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
except ImportError:  # local runs use tests/_hypothesis_fallback.py
    pass


def pytest_configure(config):
    # registered here (not pytest.ini) so runs without pytest-xdist —
    # the container's tier-1 — don't warn on the sharding annotations
    config.addinivalue_line(
        "markers",
        "xdist_group(name): tests that must share one pytest-xdist worker "
        "(subprocess spawners, global-hook mutators)",
    )


@pytest.fixture
def rng():
    return np.random.default_rng(0)
