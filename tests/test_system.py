"""End-to-end behaviour tests: paper-claim gates + pipeline equivalences.

These validate EXPERIMENTS.md claims against the paper's own numbers:
  * MAPE < 10% at 80% sampling (Geohash-6)           [paper Fig 16]
  * Geohash-5 error < Geohash-6 error at 80%          [paper Fig 17-18]
  * error decreases monotonically with fraction       [paper Fig 15]
  * edge-decentralized == cloud-centralized accuracy  [paper Fig 20]
  * preagg and raw transmission agree exactly         [paper §3.6.4]
"""

import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    Query,
    StreamSession,
    estimators,
    make_table,
    sampling,
    windows,
)
from repro.core.pipeline import EdgeCloudPipeline, PipelineConfig
from repro.data.streams import materialize, shenzhen_taxi_stream


@pytest.fixture(scope="module")
def stream_data():
    return materialize(shenzhen_taxi_stream(num_chunks=10, seed=3))


def _stratum_accuracy(data, precision, fraction, key, min_count=20):
    table = make_table(*SHENZHEN_BBOX, precision=precision)
    lat = jnp.asarray(data["lat"])
    lon = jnp.asarray(data["lon"])
    val = jnp.asarray(data["value"])
    sidx = table.assign(lat, lon)
    res = sampling.edgesos(key, sidx, table.num_slots, fraction)
    stats = estimators.sample_stats(val, sidx, res.mask, table.num_slots, counts=res.counts)
    full = estimators.sample_stats(val, sidx, jnp.ones_like(res.mask), table.num_slots)
    counts = np.asarray(res.counts)[:-1]
    est = np.asarray(stats.mean)[:-1]
    true = np.asarray(full.mean)[:-1]
    ok = (counts >= min_count) & (np.abs(true) > 1e-9)
    return float(np.mean(np.abs(est[ok] - true[ok]) / np.abs(true[ok])) * 100)


def test_paper_gate_mape_below_10_at_80(stream_data):
    mape = _stratum_accuracy(stream_data, 6, 0.8, jax.random.key(0))
    assert mape < 10.0, f"MAPE@80%={mape}"


def test_paper_geohash5_beats_geohash6(stream_data):
    m6 = _stratum_accuracy(stream_data, 6, 0.8, jax.random.key(1))
    m5 = _stratum_accuracy(stream_data, 5, 0.8, jax.random.key(1))
    assert m5 < m6, (m5, m6)


def test_paper_error_monotone_in_fraction(stream_data):
    mapes = [
        _stratum_accuracy(stream_data, 6, f, jax.random.key(2)) for f in (0.2, 0.5, 0.8)
    ]
    assert mapes[0] > mapes[1] > mapes[2], mapes


def test_edge_decentralized_matches_centralized(stream_data):
    """Paper Fig 20: decentralized (per-edge) sampling vs one-pass
    centralized sampling — no significant accuracy difference."""
    table = make_table(*SHENZHEN_BBOX, precision=5)
    lat = jnp.asarray(stream_data["lat"])
    lon = jnp.asarray(stream_data["lon"])
    val = jnp.asarray(stream_data["value"])
    sidx = table.assign(lat, lon)
    full = estimators.estimate(
        estimators.sample_stats(val, sidx, jnp.ones_like(sidx, bool), table.num_slots)
    )
    # centralized
    res_c = sampling.edgesos(jax.random.key(0), sidx, table.num_slots, 0.8)
    est_c = estimators.estimate(
        estimators.sample_stats(val, sidx, res_c.mask, table.num_slots, counts=res_c.counts)
    )
    # decentralized: 8 edges, independent sampling, merged stats
    parts = []
    for i, chunk in enumerate(np.array_split(np.arange(val.shape[0]), 8)):
        c = jnp.asarray(chunk)
        r = sampling.edgesos(jax.random.key(100 + i), sidx[c], table.num_slots, 0.8)
        parts.append(
            estimators.sample_stats(val[c], sidx[c], r.mask, table.num_slots, counts=r.counts)
        )
    est_e = estimators.estimate(estimators.merge_all(parts))
    true = float(full.mean)
    ape_c = abs(float(est_c.mean) - true) / abs(true)
    ape_e = abs(float(est_e.mean) - true) / abs(true)
    assert ape_c < 0.01 and ape_e < 0.01
    assert abs(ape_e - ape_c) < 0.005  # parity


def test_preagg_equals_raw_single_device(stream_data):
    """§3.6.4: both transmission modes give identical estimates."""
    table = make_table(*SHENZHEN_BBOX, precision=5)
    n = 40_000
    lat = jnp.asarray(stream_data["lat"][:n])
    lon = jnp.asarray(stream_data["lon"][:n])
    val = jnp.asarray(stream_data["value"][:n])
    pipe = EdgeCloudPipeline(table, PipelineConfig(mode="preagg"))
    wr = pipe.process_window(jax.random.key(3), lat, lon, val, jnp.ones(n, bool), jnp.float32(0.7))
    sidx = table.assign(lat, lon)
    res = sampling.edgesos(jax.random.key(3), sidx, table.num_slots, 0.7)
    # "raw mode": recompute stats from the kept tuples directly
    stats_raw = estimators.sample_stats(val, sidx, res.mask, table.num_slots, counts=res.counts)
    est_raw = estimators.estimate(stats_raw)
    assert float(wr.estimate.mean) == pytest.approx(float(est_raw.mean), rel=1e-5)


@pytest.mark.xdist_group("subprocess-heavy")
def test_sharded_pipeline_modes_agree_subprocess():
    """preagg == raw on an 8-device mesh (runs in a subprocess so the
    device-count env var doesn't leak into this process's jax; grouped with
    the other subprocess spawners on one xdist worker)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import make_table, SHENZHEN_BBOX
from repro.core.pipeline import EdgeCloudPipeline, PipelineConfig
from repro.sharding.compat import compat_make_mesh
mesh = compat_make_mesh((8,), ("data",))
t = make_table(*SHENZHEN_BBOX, precision=5)
rng = np.random.default_rng(0)
N = 64_000
lat = jnp.asarray(rng.uniform(22.45, 22.86, N), jnp.float32)
lon = jnp.asarray(rng.uniform(113.76, 114.64, N), jnp.float32)
val = jnp.asarray(rng.normal(40, 8, N), jnp.float32)
outs = []
for mode in ("preagg", "raw"):
    pipe = EdgeCloudPipeline(t, PipelineConfig(mode=mode, raw_capacity=8000), mesh=mesh)
    wr = pipe.process_window_sharded(jax.random.key(1), lat, lon, val, jnp.ones(N, bool), 0.8)
    outs.append((float(wr.estimate.mean), float(wr.estimate.moe)))
assert abs(outs[0][0] - outs[1][0]) < 1e-4, outs
assert abs(outs[0][1] - outs[1][1]) < 1e-5, outs
print("MODES_AGREE", outs[0])
"""
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MODES_AGREE" in r.stdout


def test_session_soak_mixed_methods_50_windows():
    """Soak the continuous-query engine: a 3-query mixed-method session
    (one SRS query + two *differing-ROI* Bernoulli queries) over 50+ panes
    of a synthetic mobility stream at the paper's headline 80% fraction.

    Gates: per-query MAPE vs the full-population per-pane truth stays
    under the paper's 10% figure, every query answers every pane, and
    cross-signature fusion serves the two Bernoulli ROIs with exactly ONE
    edge pass per pane (two passes per pane total: srs group + bernoulli
    group)."""
    roi_south = ((22.45, 22.66), (113.76, 114.64))
    roi_north = ((22.64, 22.86), (113.76, 114.64))
    q_srs = Query(aggs=(AggSpec("mean", "value"),))
    q_south = Query(aggs=(AggSpec("mean", "value", name="south"),),
                    method="bernoulli", roi=roi_south)
    q_north = Query(aggs=(AggSpec("mean", "occupancy", name="north"),),
                    method="bernoulli", roi=roi_north)

    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table)
    sess = StreamSession(pipe, initial_fraction=0.8)
    regs = [sess.register(q) for q in (q_srs, q_south, q_north)]
    assert len(sess._groups()) == 2  # srs + ONE fused cross-ROI bernoulli group

    def in_roi(pane, roi):
        (a, b), (c, d) = roi
        lat, lon = np.asarray(pane.lat), np.asarray(pane.lon)
        return np.asarray(pane.valid) & (lat >= a) & (lat <= b) & (lon >= c) & (lon <= d)

    stream = shenzhen_taxi_stream(num_chunks=11, chunk_size=20_000, seed=17)
    panes = list(windows.count_windows(stream, 4_000))
    assert len(panes) >= 50
    apes = {r.qid: [] for r in regs}
    truth_cols = (("value", None), ("value", roi_south), ("occupancy", roi_north))
    for i, pane in enumerate(panes):
        step = sess.step(jax.random.fold_in(jax.random.key(99), i), pane)
        assert set(step.results) == {r.qid for r in regs}  # every query, every pane
        for reg, (col, roi) in zip(regs, truth_cols):
            sel = np.asarray(pane.valid) if roi is None else in_roi(pane, roi)
            truth = float(np.mean(np.asarray(pane.columns[col])[sel]))
            est = float(np.asarray(
                next(iter(step.results[reg.qid].estimates.values())).value
            ))
            apes[reg.qid].append(abs(est - truth) / abs(truth))
    for reg in regs:
        mape = 100.0 * float(np.mean(apes[reg.qid]))
        assert mape < 10.0, f"qid={reg.qid} MAPE@80%={mape:.2f}"
    # exactly one edge pass per fusion group per pane, soak-long
    assert sess.total_passes == 2 * len(panes)
    assert sess.pane_index == len(panes)


def test_train_driver_end_to_end(tmp_path):
    """Loss decreases + failure recovery works through the real driver."""
    from repro.launch.train import main

    main([
        "--arch", "qwen1.5-0.5b", "--steps", "12", "--batch", "8", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5", "--inject-failure", "7",
        "--log-every", "50",
    ])
    import os

    assert any(d.startswith("step_") for d in os.listdir(tmp_path))
