"""EdgeSOS LM data plane: unbiased weighted loss + stream generators."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.batching import edgesos_batch, full_batch
from repro.data.streams import chicago_aq_stream, materialize, shenzhen_taxi_stream
from repro.data.tokens import StratifiedTokenStream


def test_stream_generators_schema():
    for gen in (shenzhen_taxi_stream(num_chunks=2), chicago_aq_stream(num_chunks=2)):
        chunk = next(iter(gen))
        n = len(chunk["lat"])
        assert n > 0
        for k in ("sensor_id", "timestamp", "lat", "lon", "value"):
            assert len(chunk[k]) == n
        assert np.all(np.diff(chunk["timestamp"]) >= 0)


def test_streams_spatially_skewed():
    data = materialize(shenzhen_taxi_stream(num_chunks=4, seed=0))
    from repro.core import SHENZHEN_BBOX, make_table

    t = make_table(*SHENZHEN_BBOX, precision=5)
    sidx = np.asarray(t.assign(jnp.asarray(data["lat"]), jnp.asarray(data["lon"])))
    counts = np.bincount(sidx, minlength=t.num_slots)[:-1]
    nz = counts[counts > 0]
    # heavy skew: the top decile of occupied cells holds a large share
    top = np.sort(nz)[-max(1, len(nz) // 10):].sum()
    assert top / nz.sum() > 0.3
    # and the median cell is far below the mean (long tail)
    assert np.median(nz) < 0.5 * nz.mean()


def test_edgesos_batch_weights_unbiased():
    stream = StratifiedTokenStream(vocab_size=128, seq_len=8, num_strata=8, seed=0)
    window = next(iter(stream.batches(64, 1)))
    full = full_batch(window, 8)
    assert float(jnp.sum(full.seq_weight)) == pytest.approx(64.0)
    # HT weights: E[sum of weights] == window size
    sums = []
    for t in range(50):
        b = edgesos_batch(jax.random.key(t), window, 0.5, 8, out_batch=48)
        sums.append(float(jnp.sum(b.seq_weight)))
        assert b.tokens.shape == (48, 8)
        kept = int(jnp.sum(b.seq_weight > 0))
        assert kept <= 48
    assert np.mean(sums) == pytest.approx(64.0, rel=0.05)


def test_edgesos_batch_stratum_counts_are_window_population():
    stream = StratifiedTokenStream(vocab_size=64, seq_len=4, num_strata=5, seed=1)
    window = next(iter(stream.batches(32, 1)))
    b = edgesos_batch(jax.random.key(0), window, 0.75, 5, out_batch=28)
    assert int(jnp.sum(b.stratum_counts)) == 32
    expected = np.bincount(window.stratum, minlength=6)
    np.testing.assert_array_equal(np.asarray(b.stratum_counts), expected)
