"""Dry-run machinery on a tiny 2x2x2 mesh (subprocess; reduced configs).

The production 512-device sweep runs via ``python -m repro.launch.dryrun
--all --both-meshes`` (artifacts in experiments/dryrun); this test keeps
the launcher honest in CI-scale time: one train, one prefill, one decode,
one MoE, one recurrent cell must lower + compile + analyze on 8 devices.
"""

import json
import os
import subprocess
import sys

import pytest

# every cell spawns an 8-device jax subprocess; keep the whole sweep on one
# xdist worker so parallel shards don't oversubscribe the CPU
pytestmark = pytest.mark.xdist_group("subprocess-heavy")

CELLS = [
    ("qwen1.5-0.5b", "train_4k"),
    ("olmoe-1b-7b", "train_4k"),
    ("xlstm-1.3b", "decode_32k"),
    ("zamba2-7b", "long_500k"),
    ("seamless-m4t-large-v2", "prefill_32k"),
    ("mistral-large-123b", "decode_32k"),
]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_dryrun_cell_smoke_mesh(arch, shape, tmp_path):
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "REPRO_DRYRUN_DEVICES": "8",
    }
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--smoke", "--test-mesh",
            "--out", str(tmp_path),
        ],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 1
    rec = json.load(open(os.path.join(tmp_path, files[0])))
    assert rec["status"] == "ok", rec.get("error")
    assert rec["hlo_cost"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
