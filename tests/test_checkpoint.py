"""Checkpointing: roundtrip, retention, corruption fallback, async."""

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adamw_init


def _state(seed=0):
    params = {
        "layers": {"w": jnp.asarray(np.random.default_rng(seed).normal(0, 1, (4, 8, 8)), jnp.float32)},
        "embed": jnp.asarray(np.random.default_rng(seed + 1).normal(0, 1, (16, 8)), jnp.float32),
    }
    return adamw_init(params)


def test_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    m.save(7, st)
    restored = m.restore_latest(st)
    assert restored is not None
    st2, step = restored
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), max_to_keep=2, async_save=True)
    st = _state()
    for step in (1, 2, 3, 4):
        m.save(step, st)
    m.wait()
    assert m.all_steps() == [3, 4]


def test_corrupted_checkpoint_falls_back(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    m.save(1, st)
    m.save(2, st)
    # corrupt the newest checkpoint
    d = os.path.join(str(tmp_path), "step_0000000002")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef" * 8)
    restored = m.restore_latest(st)
    assert restored is not None
    _, step = restored
    assert step == 1  # fell back past the corrupted step 2


def test_restore_reshards_to_different_mesh(tmp_path):
    """Elasticity: a checkpoint restores against new shardings via
    make_array_from_callback (here: host -> 1-device NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding.compat import compat_make_mesh

    m = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    m.save(3, st)
    mesh = compat_make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    restored = m.restore_latest(st, shardings=shardings)
    assert restored is not None
    st2, _ = restored
    leaf = jax.tree.leaves(st2)[1]
    assert isinstance(leaf, jax.Array)
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(st)[1]), np.asarray(leaf))


def test_shape_mismatch_rejected(tmp_path):
    m = CheckpointManager(str(tmp_path), async_save=False)
    st = _state()
    m.save(5, st)
    bad = jax.tree.map(lambda x: jnp.zeros((3,) + x.shape[1:], x.dtype) if x.ndim else x, st)
    assert m.restore_latest(bad) is None
