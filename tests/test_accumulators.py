"""Accumulator registry laws: merge associativity/commutativity per kind,
vectorized pane merges vs sequential folds, overflow neutralization, the
quantile sketch against a sorted-sample oracle, and pluggability."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.estimators import (
    ACCUMULATORS,
    SKETCH_NUM_BINS,
    Accumulator,
    accumulate_column,
    accumulator,
    merge_accs,
    merge_accs_panes,
    register_accumulator,
    sketch_bin_values,
    sketch_quantile,
    zero_overflow_accs,
)

ALL_KINDS = ("moments", "extrema", "sketch")


def _parts(rng, n=6_000, s=12, shards=3, kinds=ALL_KINDS):
    """Shard-split registry states plus the global single-pass state."""
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    vals = jnp.asarray(rng.normal(40, 12, n), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.6)
    parts = []
    for c in np.array_split(np.arange(n), shards):
        c = jnp.asarray(c)
        parts.append(accumulate_column(kinds, vals[c], sidx[c], mask[c], s + 1))
    glob = accumulate_column(kinds, vals, sidx, mask, s + 1)
    return parts, glob


def _assert_state_close(kind, a, b, msg=""):
    exact = kind in ("extrema", "sketch")  # lattice / integer-count merges
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb), err_msg=msg)
        else:
            np.testing.assert_allclose(
                np.asarray(la), np.asarray(lb), rtol=2e-4, atol=2e-2, err_msg=msg
            )


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_merge_equals_global_per_kind(rng, kind):
    """Folding shard states reproduces the single-pass global state."""
    parts, glob = _parts(rng, kinds=(kind,))
    merged = parts[0]
    for p in parts[1:]:
        merged = merge_accs(merged, p)
    _assert_state_close(kind, merged[kind], glob[kind])


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_merge_associative_commutative(rng, kind):
    parts, _ = _parts(rng, kinds=(kind,))
    a, b, c = parts
    acc = accumulator(kind)
    left = acc.merge(acc.merge(a[kind], b[kind]), c[kind])
    right = acc.merge(a[kind], acc.merge(b[kind], c[kind]))
    flipped = acc.merge(b[kind], a[kind])
    _assert_state_close(kind, left, right, msg="associativity")
    _assert_state_close(kind, acc.merge(a[kind], b[kind]), flipped, msg="commutativity")


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_pane_merge_matches_sequential_fold(rng, kind):
    """merge_panes over a stacked (P, ...) state == P-1 sequential merges."""
    parts, _ = _parts(rng, shards=4, kinds=(kind,))
    acc = accumulator(kind)
    seq = parts[0][kind]
    for p in parts[1:]:
        seq = acc.merge(seq, p[kind])
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *[p[kind] for p in parts])
    vec = merge_accs_panes({kind: stacked})[kind]
    _assert_state_close(kind, vec, seq)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_zero_overflow_neutralizes(rng, kind):
    """After zero_overflow the overflow slot carries merge identities, so it
    contributes nothing when merged into another state."""
    parts, _ = _parts(rng, kinds=(kind,))
    acc = accumulator(kind)
    z = zero_overflow_accs(parts[0])[kind]
    merged = acc.merge(z, parts[1][kind])
    # overflow slot of the merge == partner's overflow slot untouched
    for lm, lp in zip(jax.tree.leaves(merged), jax.tree.leaves(parts[1][kind])):
        np.testing.assert_allclose(
            np.asarray(lm)[-1], np.asarray(lp)[-1], rtol=1e-6, atol=1e-6
        )


# -- quantile sketch vs sorted-sample oracle ----------------------------------


@given(seed=st.integers(0, 2**30), q=st.floats(0.05, 0.99), scale=st.floats(0.1, 300.0))
@settings(max_examples=30, deadline=None)
def test_sketch_quantile_within_relative_accuracy(seed, q, scale):
    """A sketch inverted at q lands within its documented ~4-5% relative
    value accuracy of the exact sorted-sample quantile."""
    rng = np.random.default_rng(seed)
    v = rng.normal(0, scale, 4_000).astype(np.float32)
    sk = accumulator("sketch").accumulate(
        jnp.asarray(v), jnp.zeros(len(v), jnp.int32), jnp.ones(len(v), bool), 1
    )
    got = float(sketch_quantile(sk.bins[0], q))
    true = float(np.quantile(v, q))
    assert got == pytest.approx(true, rel=0.05, abs=2e-4)


@given(seed=st.integers(0, 2**30), splits=st.integers(2, 6))
@settings(max_examples=25, deadline=None)
def test_sketch_merge_associativity_vs_sorted_oracle(seed, splits):
    """Property: any shard split + any merge order yields the *identical*
    sketch (bin counts are exact f32 integers), and its quantiles agree with
    the sorted oracle of the concatenated sample."""
    rng = np.random.default_rng(seed)
    v = rng.lognormal(1.0, 1.2, 3_000).astype(np.float32)
    acc = accumulator("sketch")
    chunks = np.array_split(v, splits)
    states = [
        acc.accumulate(jnp.asarray(c), jnp.zeros(len(c), jnp.int32), jnp.ones(len(c), bool), 1)
        for c in chunks
    ]
    fold_lr = states[0]
    for s in states[1:]:
        fold_lr = acc.merge(fold_lr, s)
    fold_rl = states[-1]
    for s in states[-2::-1]:
        fold_rl = acc.merge(s, fold_rl)
    np.testing.assert_array_equal(np.asarray(fold_lr.bins), np.asarray(fold_rl.bins))
    whole = acc.accumulate(
        jnp.asarray(v), jnp.zeros(len(v), jnp.int32), jnp.ones(len(v), bool), 1
    )
    np.testing.assert_array_equal(np.asarray(fold_lr.bins), np.asarray(whole.bins))
    for q in (0.5, 0.9, 0.99):
        got = float(sketch_quantile(fold_lr.bins[0], q))
        assert got == pytest.approx(float(np.quantile(v, q)), rel=0.05, abs=2e-4)


def test_sketch_ht_expansion_matches_weighted_oracle(rng):
    """Two strata sampled at different rates: the N_k/n_k row expansion must
    equal the quantile of the explicitly HT-weighted (repeated) sample."""
    lo = rng.normal(10, 1, 2_000).astype(np.float32)
    hi = rng.normal(100, 5, 2_000).astype(np.float32)
    keep_lo = rng.random(2_000) < 1.0  # stratum 0 fully sampled
    keep_hi = rng.random(2_000) < 0.25  # stratum 1 at a quarter
    v = np.concatenate([lo, hi])
    sidx = jnp.asarray(np.repeat([0, 1], 2_000), jnp.int32)
    mask = jnp.asarray(np.concatenate([keep_lo, keep_hi]))
    sk = accumulator("sketch").accumulate(jnp.asarray(v), sidx, mask, 2)
    n_k = np.array([keep_lo.sum(), keep_hi.sum()], np.float64)
    w_k = 2_000.0 / n_k
    weighted = jnp.asarray((w_k[:, None] * np.asarray(sk.bins)).sum(axis=0), jnp.float32)
    # q=0.25 sits inside the lo cluster, q=0.75 inside the hi cluster; the
    # unweighted sketch would give the under-sampled hi cluster only ~20% of
    # the mass and miss p75 badly — HT expansion restores the 50/50 split
    for q in (0.25, 0.75):
        got = float(sketch_quantile(weighted, q))
        true = float(np.quantile(v, q))
        assert got == pytest.approx(true, rel=0.08), q
    # and the weighted histogram total equals the HT-estimated population
    assert float(jnp.sum(weighted)) == pytest.approx(4_000.0, rel=1e-5)


def test_sketch_payload_and_shape(rng):
    sk = accumulator("sketch").accumulate(
        jnp.asarray(rng.normal(0, 1, 100), jnp.float32),
        jnp.zeros(100, jnp.int32),
        jnp.ones(100, bool),
        3,
    )
    assert sk.bins.shape == (3, SKETCH_NUM_BINS)
    assert accumulator("sketch").payload_vectors() == SKETCH_NUM_BINS
    assert float(jnp.sum(sk.bins)) == 100.0
    assert sketch_bin_values().shape == (SKETCH_NUM_BINS,)
    # bin representatives are strictly ordered (CDF inversion precondition)
    assert bool(jnp.all(jnp.diff(sketch_bin_values()) >= 0))


# -- registry pluggability -----------------------------------------------------


def test_register_custom_accumulator_end_to_end(rng):
    """A new kind plugs into accumulate/merge/pane-merge/zero_overflow with
    no engine changes — the tentpole's extensibility contract."""

    class AbsSum(Accumulator):
        kind = "_test_abssum"

        def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
            return jax.ops.segment_sum(
                mask.astype(jnp.float32) * jnp.abs(values), stratum_idx, num_segments=num_slots
            )

        def merge(self, a, b):
            return a + b

        def merge_panes(self, stacked):
            return jnp.sum(stacked, axis=0)

        def psum(self, state, axis_names, shared=None):
            return jax.lax.psum(state, axis_names)

        def zero_overflow(self, state):
            keep = jnp.arange(state.shape[0]) < (state.shape[0] - 1)
            return jnp.where(keep, state, 0.0)

        def payload_vectors(self):
            return 1

        def payload_flatten(self, state):
            return (("abs", state, True, 0.0),)

        def payload_unflatten(self, rows):
            return rows["abs"]

        def template(self):
            return 0

    register_accumulator(AbsSum())
    try:
        sidx = jnp.asarray(rng.integers(0, 4, 500), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 5, 500), jnp.float32)
        mask = jnp.asarray(rng.random(500) < 0.5)
        halves = [
            accumulate_column(("_test_abssum",), vals[s], sidx[s], mask[s], 5)
            for s in (slice(0, 250), slice(250, 500))
        ]
        merged = merge_accs(halves[0], halves[1])
        whole = accumulate_column(("_test_abssum",), vals, sidx, mask, 5)
        np.testing.assert_allclose(
            np.asarray(merged["_test_abssum"]), np.asarray(whole["_test_abssum"]), rtol=1e-5
        )
        z = zero_overflow_accs(whole)
        assert float(np.asarray(z["_test_abssum"])[-1]) == 0.0
    finally:
        del ACCUMULATORS["_test_abssum"]
    with pytest.raises(KeyError, match="unknown accumulator kind"):
        accumulator("_test_abssum")
