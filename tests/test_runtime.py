"""Streaming runtime: bounded-queue backpressure, pipelined parity, soak.

Covers the execution layer introduced with ``core/runtime.py``:

  * :class:`~repro.core.qdisc.BoundedPaneQueue` unit semantics — policies,
    decimation, close/drain, and the drop-ledger accounting chain;
  * **bit-parity**: with the lossless ``block`` policy and the shared
    ``fold_in(root, pane_index)`` key discipline, the pipelined runtime's
    emitted estimates are identical to a synchronous ``session.step`` loop,
    in preagg and raw modes, across sliding windows;
  * a **bursty soak**: >= 50 panes through a saturated 2-deep queue with
    mixed-method queries — the run completes, every shed tuple is accounted
    by cause end-to-end (queue ledger == session counters), and the
    estimates the runtime *did* emit stay within 10% MAPE of the exact
    per-pane answers at fraction 0.8;
  * **checkpoint with a non-empty ingest queue**: drain-then-snapshot makes
    the restored run bit-identical to one that never stopped;
  * count-triggered windows report an explicit ``n_dropped=0`` so drop
    counts sum cleanly across sources and causes;
  * event-driven sampling (decay / change trigger / heartbeat) and
    load-shedding hysteresis (enter high-water, exit low-water, fraction
    restore, deterministic decimation).
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    RuntimeConfig,
    StreamRuntime,
    StreamSession,
    WindowSpec,
    feedback,
    make_table,
    windows,
)
from repro.core import runtime as rtm
from repro.core.qdisc import (
    CAUSE_QUEUE_FULL,
    CAUSE_SHED,
    BoundedPaneQueue,
    DropLedger,
)
from repro.data.sources import BurstySource, PacedSource
from repro.data.streams import shenzhen_taxi_stream

PANE = 2_000
N_PANES = 8

EXACT_FIELDS = ("value", "moe", "ci_low", "ci_high", "relative_error", "n", "population")

Q_MEANVAR = Query(aggs=(AggSpec("mean", "value"), AggSpec("var", "value")))
Q_OCC = Query(aggs=(AggSpec("mean", "occupancy", name="occ"),))
Q_RAW = Query(aggs=(AggSpec("mean", "value"),), mode="raw")
Q_BERNOULLI = Query(aggs=(AggSpec("mean", "value"),), method="bernoulli")


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def pipe(table):
    return EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))


@pytest.fixture(scope="module")
def panes():
    stream = shenzhen_taxi_stream(chunk_size=PANE, num_chunks=N_PANES, seed=0)
    return list(windows.count_windows(stream, PANE))[:N_PANES]


def _assert_steps_identical(expected, got):
    assert len(expected) == len(got)
    for e, g in zip(expected, got):
        assert e.pane_index == g.pane_index
        assert set(e.results) == set(g.results)
        assert e.fractions == g.fractions
        assert e.n_dropped == g.n_dropped
        assert e.drop_causes == g.drop_causes
        assert e.comm_bytes == g.comm_bytes
        for qid in e.results:
            re_, rg = e.results[qid], g.results[qid]
            assert set(re_.estimates) == set(rg.estimates)
            for k in re_.estimates:
                for field in EXACT_FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(re_.estimates[k], field)),
                        np.asarray(getattr(rg.estimates[k], field)),
                        err_msg=f"qid={qid} {k}.{field}",
                    )
            assert int(re_.n_sampled) == int(rg.n_sampled)
            assert int(re_.n_valid) == int(rg.n_valid)
            assert int(re_.n_dropped) == int(rg.n_dropped)


# -- qdisc: BoundedPaneQueue / DropLedger -------------------------------------


class _FakePane:
    """Host-only stand-in pane: just a size and upstream drop causes."""

    def __init__(self, size, drop_causes=None, tag=None):
        self.size = size
        self.drop_causes = drop_causes or {}
        self.tag = tag


def test_queue_validates_capacity_and_policy():
    with pytest.raises(ValueError, match="capacity"):
        BoundedPaneQueue(capacity=0)
    with pytest.raises(ValueError, match="policy"):
        BoundedPaneQueue(policy="drop-random")


def test_drop_newest_sheds_arrival_and_keeps_fifo_order():
    q = BoundedPaneQueue(capacity=2, policy="drop-newest")
    assert q.put(_FakePane(10, tag="a"))
    assert q.put(_FakePane(20, tag="b"))
    assert not q.put(_FakePane(30, tag="c"))  # full: arrival shed
    assert q.ledger.tuples == {CAUSE_QUEUE_FULL: 30}
    assert q.ledger.panes == {CAUSE_QUEUE_FULL: 1}
    assert [q.get(timeout=0).tag for _ in range(2)] == ["a", "b"]
    assert q.get(timeout=0) is None
    assert q.high_water == 2 and q.total_put == 2


def test_drop_oldest_evicts_head_to_admit_arrival():
    q = BoundedPaneQueue(capacity=2, policy="drop-oldest")
    q.put(_FakePane(10, tag="a"))
    q.put(_FakePane(20, tag="b"))
    assert q.put(_FakePane(30, tag="c"))  # evicts "a"
    assert q.ledger.tuples == {CAUSE_QUEUE_FULL: 10}
    assert [q.get(timeout=0).tag for _ in range(2)] == ["b", "c"]


def test_block_policy_times_out_into_a_counted_drop():
    q = BoundedPaneQueue(capacity=1, policy="block")
    assert q.put(_FakePane(5))
    assert not q.put(_FakePane(7), timeout=0.01)
    assert q.ledger.tuples == {CAUSE_QUEUE_FULL: 7}


def test_evicted_pane_upstream_drops_survive():
    """A shed pane's own ``late`` count must not vanish with it."""
    q = BoundedPaneQueue(capacity=1, policy="drop-newest")
    q.put(_FakePane(10))
    assert not q.put(_FakePane(30, drop_causes={"late": 7}))
    assert q.ledger.tuples == {CAUSE_QUEUE_FULL: 30, "late": 7}
    pending = q.take_drops()
    assert pending.tuples == {CAUSE_QUEUE_FULL: 30, "late": 7}
    assert not q.take_drops()  # drained


def test_decimation_admits_one_in_k_deterministically():
    q = BoundedPaneQueue(capacity=8, policy="drop-newest")
    q.set_decimation(3)
    admitted = [q.put(_FakePane(1, tag=i)) for i in range(9)]
    assert admitted == [True, False, False] * 3
    assert q.ledger.panes == {CAUSE_SHED: 6}
    q.set_decimation(0)
    assert q.put(_FakePane(1))


def test_close_drains_then_returns_none_and_rejects_puts():
    q = BoundedPaneQueue(capacity=4)
    q.put(_FakePane(1, tag="a"))
    q.close()
    assert q.get(timeout=0).tag == "a"  # queued panes still drain
    assert q.get(timeout=0) is None
    with pytest.raises(RuntimeError, match="closed"):
        q.put(_FakePane(2))


def test_drop_ledger_merge_and_totals():
    led = DropLedger()
    assert not led
    led.add("queue_full", 10)
    led.add("queue_full", 5, n_panes=2)
    led.merge_causes({"late": 3})
    assert led.tuples == {"queue_full": 15, "late": 3}
    assert led.panes == {"queue_full": 3}
    assert led.total_tuples == 18
    assert led


# -- runtime parity: pipelined == synchronous (lossless policy) ---------------


def _register_parity(sess):
    sess.register(Q_MEANVAR, window=WindowSpec("sliding", size=3))
    sess.register(Q_OCC)
    sess.register(Q_RAW, window=WindowSpec("tumbling", size=2))


def test_runtime_matches_synchronous_loop_bit_for_bit(pipe, panes):
    """Block policy + fold_in key discipline: the double-buffered, async
    runtime must emit exactly what a serial ``session.step`` loop does, in
    preagg and raw modes, across multi-pane windows."""
    root = jax.random.key(11)

    sess_sync = StreamSession(pipe, initial_fraction=0.8)
    _register_parity(sess_sync)
    sync = [
        sess_sync.step(jax.random.fold_in(root, i), p) for i, p in enumerate(panes)
    ]

    sess_rt = StreamSession(pipe, initial_fraction=0.8)
    _register_parity(sess_rt)
    rt = StreamRuntime(
        sess_rt, key=root, config=RuntimeConfig(queue_capacity=4, policy="block")
    )
    history = rt.run(panes)  # any iterable of panes is a Source

    _assert_steps_identical(sync, history)
    st = rt.stats()
    assert st.panes_processed == len(panes)
    assert st.panes_enqueued == len(panes)
    assert st.tuples_processed == sum(p.size for p in panes)
    assert st.dropped_tuples == 0 and st.dropped_tuples_by_cause == {}
    assert 0.0 < st.overlap_efficiency <= 1.0
    assert st.pane_latency["p99_ms"] >= st.pane_latency["p50_ms"] >= 0.0


def test_runtime_parity_under_paced_arrivals(pipe, panes):
    """Arrival timing must never leak into the answers: a jittered paced
    source produces the same history as back-to-back offers."""
    root = jax.random.key(12)

    sess_a = StreamSession(pipe, initial_fraction=0.8)
    sess_a.register(Q_MEANVAR)
    rt_a = StreamRuntime(sess_a, key=root, config=RuntimeConfig(policy="block"))
    hist_a = rt_a.run(panes[:4])

    sess_b = StreamSession(pipe, initial_fraction=0.8)
    sess_b.register(Q_MEANVAR)
    rt_b = StreamRuntime(sess_b, key=root, config=RuntimeConfig(policy="block"))
    hist_b = rt_b.run(PacedSource(panes[:4], mean_delay_s=0.002, jitter=0.5, seed=3))

    _assert_steps_identical(hist_a, hist_b)


def test_run_without_key_raises(pipe, panes):
    sess = StreamSession(pipe)
    sess.register(Q_MEANVAR)
    with pytest.raises(ValueError, match="PRNG key"):
        StreamRuntime(sess).run(panes[:1])


def test_offer_process_drain_are_incremental_and_bounded(pipe, panes):
    """Single-threaded driving: ``offer`` enqueues, ``process`` consumes
    what is queued *now*, ``drain`` is a full pipeline barrier."""
    sess = StreamSession(pipe, initial_fraction=0.8)
    sess.register(Q_MEANVAR)
    rt = StreamRuntime(
        sess, key=jax.random.key(13), config=RuntimeConfig(queue_capacity=8)
    )
    for p in panes[:3]:
        assert rt.offer(p)
    assert rt.queue.depth == 3
    steps = rt.process()
    assert len(steps) == 3 and rt.queue.depth == 0
    assert rt.process() == []  # nothing queued: no waiting, no new steps
    rt.drain()
    assert len(rt.history) == 3
    assert rt.stats().panes_processed == 3


# -- bursty soak: saturation, shed accounting, answer quality -----------------


def test_bursty_soak_completes_with_cause_accounted_drops(pipe, panes):
    """>= 50 bursty panes through a 2-deep drop-newest queue with mixed-
    method queries (SRS preagg, Bernoulli, raw): the run completes, every
    dropped tuple is accounted by cause through the whole chain (queue
    ledger -> step reports -> session counters), and the per-pane mean
    estimates that *were* emitted stay within 10% MAPE of exact.

    ``SOAK_REPEAT`` scales the run: PRs offer 60 panes (repeat=10); the
    nightly workflow sets 84 for a ~500-pane soak."""
    import os

    repeat = int(os.environ.get("SOAK_REPEAT", "10"))
    source = BurstySource(panes[:6], burst=10, gap_s=0.001, seed=2, repeat=repeat)
    n_offered = len(source.panes)
    assert n_offered >= 50

    sess = StreamSession(pipe, initial_fraction=0.8)
    q_mean = sess.register(Q_MEANVAR)
    sess.register(Q_BERNOULLI)
    sess.register(Q_RAW)

    processed = []  # exact ground truth: the panes the session really saw
    orig_step = sess.step

    def recording_step(key, pane):
        processed.append(pane)
        return orig_step(key, pane)

    sess.step = recording_step

    rt = StreamRuntime(
        sess,
        key=jax.random.key(21),
        config=RuntimeConfig(queue_capacity=2, policy="drop-newest"),
    )
    history = rt.run(source)
    st = rt.stats()

    # the run completed: every admitted pane was processed, and admissions
    # plus per-cause pane drops account for every arrival
    assert len(history) == len(processed) == st.panes_enqueued
    dropped_panes = sum(st.dropped_panes_by_cause.values())
    assert st.panes_enqueued + dropped_panes == n_offered
    assert st.dropped_panes_by_cause.get(CAUSE_QUEUE_FULL, 0) > 0  # saturated

    # tuple accounting chain: ledger == stats == session == per-step sums,
    # modulo drops still pending attachment after the final admitted pane
    assert st.dropped_tuples_by_cause == rt.queue.ledger.tuples
    assert sum(s.n_dropped for s in history) == sess.total_dropped
    remaining = rt.queue.take_drops()
    for cause, n in rt.queue.ledger.tuples.items():
        attached = sess.total_dropped_by_cause.get(cause, 0)
        assert attached + remaining.tuples.get(cause, 0) == n, cause
    assert sess.total_dropped == sum(sess.total_dropped_by_cause.values())

    # answer quality on what was emitted: exact per-pane means vs estimates
    errs = []
    for step, pane in zip(history, processed):
        exact = float(np.asarray(pane.value)[np.asarray(pane.valid)].mean())
        est = float(np.asarray(step.results[q_mean.qid].estimates["mean_value"].value))
        errs.append(abs(est - exact) / abs(exact))
    assert errs and float(np.mean(errs)) < 0.10


# -- checkpoint with a non-empty ingest queue ---------------------------------


def _register_ckpt(sess, mode):
    if mode == "preagg":
        sess.register(Q_MEANVAR, window=WindowSpec("sliding", size=3))
        sess.register(Q_OCC)
    else:
        sess.register(Q_RAW, window=WindowSpec("tumbling", size=2))


@pytest.mark.parametrize("mode", ["preagg", "raw"])
def test_checkpoint_with_queued_panes_is_bit_identical(pipe, panes, mode):
    """Drain-then-snapshot: checkpointing while panes sit in the ingest
    queue, restoring into a fresh session/runtime, and replaying the rest
    reproduces the uninterrupted run bit-for-bit (preagg AND raw)."""
    root = jax.random.key(33)
    cut = 5

    sess_full = StreamSession(pipe, initial_fraction=0.8)
    _register_ckpt(sess_full, mode)
    full = [
        sess_full.step(jax.random.fold_in(root, i), p) for i, p in enumerate(panes)
    ]

    sess_a = StreamSession(pipe, initial_fraction=0.8)
    _register_ckpt(sess_a, mode)
    rt_a = StreamRuntime(
        sess_a, key=root, config=RuntimeConfig(queue_capacity=8, policy="block")
    )
    for p in panes[:cut]:
        assert rt_a.offer(p)
    rt_a.process(max_panes=2)
    assert rt_a.queue.depth == 3  # the point of the test: queue is non-empty
    snap = rt_a.checkpoint()
    assert rt_a.queue.depth == 0 and sess_a.pane_index == cut

    sess_b = StreamSession(pipe, initial_fraction=0.8)
    _register_ckpt(sess_b, mode)
    sess_b.restore(snap)
    rt_b = StreamRuntime(
        sess_b, key=root, config=RuntimeConfig(queue_capacity=8, policy="block")
    )
    resumed = rt_b.run(panes[cut:])

    _assert_steps_identical(full, rt_a.history + resumed)


# -- drop accounting across sources and causes --------------------------------


def test_count_windows_report_explicit_zero_drops():
    stream = shenzhen_taxi_stream(chunk_size=PANE, num_chunks=2, seed=4)
    got = list(windows.count_windows(stream, PANE))
    assert got
    for pane in got:
        assert pane.n_dropped == 0
        assert pane.drop_causes == {}


def test_drops_sum_across_sources_and_causes(pipe, panes):
    """A pane carrying upstream ``late`` drops shed at a full queue: both
    its tuples (``queue_full``) and its prior ``late`` count must land in
    the session totals via the next admitted pane — and count-window panes
    contribute an explicit zero, so the totals are pure drop mass."""
    late_pane = dataclasses.replace(panes[1], n_dropped=7, drop_causes={"late": 7})
    sess = StreamSession(pipe, initial_fraction=0.8)
    sess.register(Q_MEANVAR)
    rt = StreamRuntime(
        sess,
        key=jax.random.key(5),
        config=RuntimeConfig(queue_capacity=1, policy="drop-newest"),
    )
    assert rt.offer(panes[0])
    assert not rt.offer(late_pane)  # shed at the full queue
    rt.process()
    rt.drain()
    assert sess.total_dropped == late_pane.size + 7
    assert sess.total_dropped_by_cause == {
        CAUSE_QUEUE_FULL: late_pane.size,
        "late": 7,
    }
    assert rt.history[0].n_dropped == sess.total_dropped


# -- event-driven sampling ----------------------------------------------------


def test_event_fraction_decays_boosts_and_heartbeats():
    pol = feedback.EventPolicy(
        heartbeat_panes=3, change_threshold=0.25, hot_fraction=0.8,
        idle_fraction=0.1, idle_decay=0.5,
    )
    state = feedback.EventState()
    # quiet panes decay geometrically toward the idle floor
    f = feedback.event_fraction(state, 0.01, 0.8, pol)
    assert f == pytest.approx(0.4) and state.quiet_panes == 1
    f = feedback.event_fraction(state, 0.01, f, pol)
    assert f == pytest.approx(0.2)
    # third quiet pane trips the heartbeat: probe hot, counters reset
    f = feedback.event_fraction(state, 0.01, f, pol)
    assert f == pol.hot_fraction and state.since_heartbeat == 0
    assert state.hot_panes == 1 and state.quiet_panes == 0
    # a change-score crossing boosts immediately; so does an inf score
    assert feedback.event_fraction(state, 0.30, 0.1, pol) == pol.hot_fraction
    assert feedback.event_fraction(state, float("inf"), 0.1, pol) == pol.hot_fraction
    # decay never undershoots the idle floor
    assert feedback.event_fraction(state, 0.0, 0.11, pol) == pytest.approx(0.1)


def test_change_score_semantics():
    same = feedback.change_score(np.array([1.0, 2.0]), np.array([1.0, 2.0]))
    assert float(same) == 0.0
    shift = feedback.change_score(np.array([1.0, 2.0]), np.array([1.5, 2.0]))
    assert float(shift) == pytest.approx(0.5)
    # nothing comparable -> inf: an unobservable stream must fail hot
    blind = feedback.change_score(np.array([np.nan]), np.array([1.0]))
    assert not np.isfinite(float(blind))


def test_watched_registration_decays_while_stream_is_quiet(pipe, panes):
    """With an unreachable change threshold and no heartbeat due, the
    watched fraction decays deterministically — scores are computed lazily
    on-device and applied one pane late, never stalling the loop."""
    sess = StreamSession(pipe, initial_fraction=0.8)
    reg = sess.register(Q_MEANVAR)
    rt = StreamRuntime(
        sess, key=jax.random.key(6), config=RuntimeConfig(policy="block")
    )
    pol = feedback.EventPolicy(
        heartbeat_panes=100, change_threshold=float("inf"), idle_decay=0.5,
        idle_fraction=0.1,
    )
    rt.watch(reg, policy=pol)
    rt.run(panes[:6])
    # scores mature one pane late: panes 1..4 produce the applied events
    state = rt._watches[reg.qid][3]
    assert state.hot_panes == 0 and state.quiet_panes == 4
    assert reg.fraction == pytest.approx(max(0.1, 0.8 * 0.5**4))


def test_watched_registration_heartbeats_back_to_hot(pipe, panes):
    sess = StreamSession(pipe, initial_fraction=0.8)
    reg = sess.register(Q_MEANVAR)
    rt = StreamRuntime(
        sess, key=jax.random.key(7), config=RuntimeConfig(policy="block")
    )
    pol = feedback.EventPolicy(
        heartbeat_panes=2, change_threshold=float("inf"), hot_fraction=0.7,
        idle_decay=0.5, idle_fraction=0.1,
    )
    rt.watch(reg, policy=pol)
    rt.run(panes[:6])
    # 4 applied events, every 2nd a heartbeat probe: quiet, hot, quiet, hot
    state = rt._watches[reg.qid][3]
    assert state.hot_panes == 2
    assert reg.fraction == pytest.approx(pol.hot_fraction)


# -- load shedding ------------------------------------------------------------


def test_load_shedding_hysteresis_and_fraction_restore(pipe, panes):
    """Depth >= high-water scales fractions down; depth <= low-water
    restores them — to ``max(current, saved)`` so a controller boost made
    *during* shedding survives the exit."""
    sess = StreamSession(pipe, initial_fraction=0.8)
    reg = sess.register(Q_MEANVAR)
    cfg = RuntimeConfig(
        queue_capacity=4, policy="block", load_shedding=True,
        shed_highwater=0.75, shed_lowwater=0.25, shed_fraction_scale=0.5,
    )
    rt = StreamRuntime(sess, key=jax.random.key(8), config=cfg)
    for p in panes[:4]:
        assert rt.offer(p)
    rt.process(max_panes=1)  # dispatch with depth 3 >= ceil(0.75*4): enter
    assert rt.shedding and rt.shed_panes >= 1
    assert reg.fraction == pytest.approx(0.4)
    reg.fraction = 0.9  # a controller raising the fraction mid-shed
    rt.drain()  # depth falls to the low-water mark: exit shed mode
    assert not rt.shedding
    assert reg.fraction == pytest.approx(0.9)  # max(current, saved) kept it
    assert len(rt.history) == 4


def test_load_shedding_decimation_drops_flow_as_shed_cause(pipe, panes):
    sess = StreamSession(pipe, initial_fraction=0.8)
    sess.register(Q_MEANVAR)
    cfg = RuntimeConfig(
        queue_capacity=2, policy="drop-newest", load_shedding=True,
        shed_highwater=0.5, shed_lowwater=0.0, shed_decimate=3,
    )
    rt = StreamRuntime(sess, key=jax.random.key(9), config=cfg)
    assert rt.offer(panes[0]) and rt.offer(panes[1])
    rt.process(max_panes=1)  # dispatch with depth 1 >= ceil(0.5*2): enter
    assert rt.shedding
    admitted = [rt.offer(p) for p in panes[2:8]]
    assert not all(admitted)  # decimation shed some arrivals
    assert rt.queue.ledger.tuples.get(CAUSE_SHED, 0) > 0
    rt.drain()  # empties the queue: low-water 0 exits shed mode
    assert not rt.shedding
    # shed tuples reached the session accounting via the next admitted pane
    assert sess.total_dropped_by_cause.get(CAUSE_SHED, 0) > 0
    # decimation was reset on exit: arrivals admit normally again
    assert rt.offer(panes[0]) and rt.offer(panes[1])


# -- stats helpers ------------------------------------------------------------


def _timing(t_dispatch, t_retired):
    return rtm.PaneTiming(
        pane_index=0, ingest_s=0.0, queue_wait_s=0.0, stage_s=0.0,
        dispatch_s=0.0, latency_s=t_retired - t_dispatch,
        t_dispatch=t_dispatch, t_retired=t_retired,
    )


def test_overlap_efficiency_interval_union():
    assert rtm._overlap_efficiency([]) == 0.0
    # back-to-back intervals: busy the whole wall
    assert rtm._overlap_efficiency([_timing(0, 1), _timing(1, 3)]) == pytest.approx(1.0)
    # a 1s gap in a 3s wall: 2/3 busy
    assert rtm._overlap_efficiency([_timing(0, 1), _timing(2, 3)]) == pytest.approx(2 / 3)
    # overlapping intervals never double-count
    assert rtm._overlap_efficiency([_timing(0, 2), _timing(1, 4)]) == pytest.approx(1.0)


def test_latency_percentiles_and_histogram():
    assert rtm._percentiles([]) == {
        "p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0, "max_ms": 0.0
    }
    pct = rtm._percentiles([0.001, 0.002, 0.004])
    assert pct["p50_ms"] == pytest.approx(2.0)
    assert pct["max_ms"] == pytest.approx(4.0)
    hist = rtm._histogram_ms([0.0001, 0.0002, 0.5, 100.0])
    assert hist["0.25"] == 2  # both sub-quarter-ms samples
    assert sum(hist.values()) == 4
    assert hist["inf"] == 1  # 100s falls past the last edge
