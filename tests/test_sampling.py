"""EdgeSOS sampler: exact SRS sizes, uniformity, weights, compaction."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import sampling


def _random_strata(rng, n, s):
    return jnp.asarray(rng.integers(0, s, n), jnp.int32)


def test_exact_per_stratum_sizes(rng):
    sidx = _random_strata(rng, 20_000, 50)
    res = sampling.edgesos(jax.random.key(0), sidx, 51, 0.37)
    expected = np.round(0.37 * np.asarray(res.counts)).clip(0, np.asarray(res.counts))
    assert (np.asarray(res.n_k) == expected).all()
    # realized mask matches n_k per stratum
    realized = np.zeros(51, np.int64)
    np.add.at(realized, np.asarray(sidx)[np.asarray(res.mask)], 1)
    assert (realized == np.asarray(res.n_k)).all()


@given(frac=st.floats(0.05, 1.0), s=st.integers(1, 40), seed=st.integers(0, 2**30))
@settings(max_examples=50, deadline=None)
def test_fraction_one_keeps_everything(frac, s, seed):
    rng = np.random.default_rng(seed)
    sidx = _random_strata(rng, 2_000, s)
    res = sampling.edgesos(jax.random.key(seed), sidx, s + 1, 1.0)
    assert bool(jnp.all(res.mask))
    assert bool(jnp.allclose(res.weight, 1.0))
    res_f = sampling.edgesos(jax.random.key(seed), sidx, s + 1, frac)
    kept = int(jnp.sum(res_f.mask))
    assert abs(kept - frac * 2000) <= s + 1  # rounding per stratum


def test_srs_uniformity_within_stratum(rng):
    """Every tuple of a stratum has inclusion probability n_k/N_k."""
    n = 4_000
    sidx = jnp.zeros(n, jnp.int32)
    counts = np.zeros(n)
    trials = 200
    for t in range(trials):
        res = sampling.edgesos(jax.random.key(t), sidx, 1 + 1, 0.3)
        counts += np.asarray(res.mask)
    p = counts / trials
    # inclusion prob should be 0.3 for every position; binomial CI
    se = np.sqrt(0.3 * 0.7 / trials)
    assert abs(p.mean() - 0.3) < 3 * se / np.sqrt(n) + 1e-3
    assert (np.abs(p - 0.3) < 6 * se).all()


def test_ht_weights_unbiased_sum(rng):
    """Horvitz-Thompson weighted sum is unbiased for the population sum."""
    n, s = 30_000, 30
    sidx = _random_strata(rng, n, s)
    vals = jnp.asarray(rng.normal(50, 12, n), jnp.float32)
    true_sum = float(jnp.sum(vals))
    ests = []
    for t in range(30):
        res = sampling.edgesos(jax.random.key(t), sidx, s + 1, 0.4)
        ests.append(float(jnp.sum(vals * res.weight)))
    rel = abs(np.mean(ests) - true_sum) / abs(true_sum)
    assert rel < 0.01


def test_bernoulli_mode(rng):
    sidx = _random_strata(rng, 50_000, 20)
    res = sampling.edgesos(jax.random.key(1), sidx, 21, 0.25, method="bernoulli")
    kept = int(jnp.sum(res.mask))
    assert abs(kept - 12_500) < 600  # ~4 sigma
    w = np.asarray(res.weight)
    assert np.allclose(w[np.asarray(res.mask)], 4.0)


def test_neyman_allocates_more_to_high_variance(rng):
    n = 20_000
    sidx = jnp.asarray((np.arange(n) % 2), jnp.int32)
    stddev = jnp.asarray([1.0, 10.0, 0.0], jnp.float32)
    res = sampling.edgesos(jax.random.key(0), sidx, 3, 0.3, method="neyman", stddev=stddev)
    nk = np.asarray(res.n_k)
    assert nk[1] > 3 * nk[0]
    assert nk[0] + nk[1] == pytest.approx(0.3 * n, rel=0.05)


def test_compact(rng):
    sidx = _random_strata(rng, 1_000, 10)
    vals = jnp.asarray(rng.normal(0, 1, 1_000), jnp.float32)
    res = sampling.edgesos(jax.random.key(0), sidx, 11, 0.5)
    kept = int(jnp.sum(res.mask))
    valid, s_c, v_c = sampling.compact(res.mask, 600, sidx, vals)
    assert int(valid.sum()) == min(kept, 600)
    # the kept values appear in order
    ref = np.asarray(vals)[np.asarray(res.mask)][:600]
    assert np.allclose(np.asarray(v_c)[np.asarray(valid)], ref)
    # capacity larger than input is fine
    valid2, v2 = sampling.compact(res.mask, 1_500, vals)
    assert int(valid2.sum()) == kept


def test_decentralized_equals_shard_independent(rng):
    """Sampling a shard's window is independent of other shards: the same
    per-shard key gives the same sample whether or not other shards exist
    (the paper's synchronization-free property)."""
    n = 4_000
    sidx = _random_strata(rng, n, 16)
    local = sampling.edgesos(jax.random.fold_in(jax.random.key(7), 3), sidx, 17, 0.5)
    again = sampling.edgesos(jax.random.fold_in(jax.random.key(7), 3), sidx, 17, 0.5)
    assert bool(jnp.all(local.mask == again.mask))
