"""HLO cost analyzer: FLOPs/bytes vs XLA on unrolled modules, loop scaling."""

import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo import analyze_module, loop_trip_counts


def _xla_cost(compiled) -> dict:
    """Normalize Compiled.cost_analysis() across jax versions: older
    releases return a one-element list of dicts, newer return the dict."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


@pytest.fixture(scope="module")
def compiled_pair():
    D = 256
    w = jax.ShapeDtypeStruct((8, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)

    def scanned(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None

        return jax.lax.scan(body, x, w)[0]

    def unrolled(w, x):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    cu = jax.jit(unrolled).lower(w, x).compile()
    cs = jax.jit(scanned).lower(w, x).compile()
    return cu, cs


def test_flops_match_xla_on_unrolled(compiled_pair):
    cu, _ = compiled_pair
    xla = _xla_cost(cu)
    mine = analyze_module(cu.as_text(), 1)
    assert mine.flops == pytest.approx(xla["flops"], rel=0.02)


def test_bytes_close_to_xla_on_unrolled(compiled_pair):
    cu, _ = compiled_pair
    xla = _xla_cost(cu)
    mine = analyze_module(cu.as_text(), 1)
    assert mine.bytes_accessed == pytest.approx(xla["bytes accessed"], rel=0.5)


def test_loop_multiplier_applied(compiled_pair):
    """Scanned module FLOPs == unrolled (XLA itself undercounts loops 8x)."""
    cu, cs = compiled_pair
    mu = analyze_module(cu.as_text(), 1)
    ms = analyze_module(cs.as_text(), 1)
    assert ms.flops == pytest.approx(mu.flops, rel=0.02)
    assert 8 in loop_trip_counts(cs.as_text())
    # XLA's own count misses the trip multiplier
    assert _xla_cost(cs)["flops"] < mu.flops / 4


def test_collective_model_constants():
    """Ring cost model sanity on a synthetic module."""
    txt = """
HloModule m

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    m = analyze_module(txt, 8)
    # all-reduce of 4096 bytes in groups of 4: 2*R*(g-1)/g = 6144
    assert m.collective_moved == pytest.approx(2 * 4096 * 3 / 4)
    assert m.collective_counts.get("all-reduce") == 1
