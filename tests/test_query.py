"""Declarative query layer: plan lowering, accumulator merge exactness,
preagg == raw per aggregate kind, grouped/ROI correctness, legacy shim."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    estimators,
    geohash,
    lower,
    make_table,
    windows,
)
from repro.core.pipeline import _zero_overflow
from repro.core.query import KINDS, agg_accumulator_kinds, quantile_of
from repro.data.streams import shenzhen_taxi_stream


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=3, seed=0)
    return next(windows.count_windows(stream, 30_000))


# -- plan lowering -----------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS + ("p50", "p99", "p99.9"))
def test_lowering_accumulator_sets(table, kind):
    """Each AggSpec lowers to its documented registry accumulator-kind set,
    and the plan's per-column kind union covers exactly those kinds."""
    q = Query(aggs=(AggSpec(kind, "value"),))
    plan = lower(q, table)
    assert plan.columns == ("value",)
    kinds = agg_accumulator_kinds(kind)
    assert plan.accumulator_map[f"{kind}_value"] == kinds
    assert plan.column_kind_map["value"] == kinds
    # every kind leans on moments (coverage accounting / HT expansion);
    # min/max add the extrema lattice, quantiles the mergeable sketch
    assert "moments" in kinds
    assert ("extrema" in kinds) == (kind in ("min", "max"))
    assert ("sketch" in kinds) == (quantile_of(kind) is not None)


def test_lowering_column_kind_union(table):
    """A column referenced by several aggregates carries the kind union."""
    q = Query(
        aggs=(AggSpec("mean", "value"), AggSpec("max", "value"), AggSpec("p99", "value"))
    )
    plan = lower(q, table)
    assert plan.column_kind_map["value"] == ("moments", "extrema", "sketch")
    assert plan.extrema_columns == ("value",)
    assert plan.sketch_columns == ("value",)


def test_lowering_columns_and_groups(table):
    q = Query(
        aggs=(AggSpec("mean", "value"), AggSpec("max", "occupancy"), AggSpec("count", "value")),
        group_by="neighborhood",
    )
    plan = lower(q, table)
    assert plan.columns == ("value", "occupancy")  # deduped, order-preserving
    assert plan.num_groups == table.num_neighborhoods
    plan_s = lower(Query(aggs=q.aggs, group_by="stratum"), table)
    assert plan_s.num_groups == table.num_strata


def test_query_validation(table):
    with pytest.raises(ValueError):
        Query(aggs=())
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("median", "value"),))
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("p0", "value"),))  # quantile must be in (0, 1)
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("p100", "value"),))
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("sum", "value"),), group_by="city")
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("sum", "value"), AggSpec("sum", "value")))
    with pytest.raises(ValueError):
        lower(Query(aggs=(AggSpec("sum", "value"),), roi="wx4g0e1"), table)  # finer than grid
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("sum", "value"),), roi=123)  # not a bbox/prefix
    with pytest.raises(ValueError):
        Query(aggs=(AggSpec("sum", "value"),), roi=(1, 2, 3))  # malformed bbox


# -- generalized accumulator merges ------------------------------------------


def _column_parts(rng, n=12_000, s=20, shards=5):
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    vals = jnp.asarray(rng.normal(40, 12, n), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.6)
    parts = []
    for c in np.array_split(np.arange(n), shards):
        c = jnp.asarray(c)
        parts.append(estimators.column_stats(vals[c], sidx[c], mask[c], s + 1))
    glob = estimators.column_stats(vals, sidx, mask, s + 1)
    return parts, glob


def test_column_stats_merge_exact_across_shards(rng):
    """Simulated shard split: pairwise merges reproduce the global
    accumulator — exactly for count/min/max, to fp tolerance for moments."""
    parts, glob = _column_parts(rng)
    merged = estimators.merge_all_columns(parts)
    np.testing.assert_array_equal(np.asarray(merged.n), np.asarray(glob.n))
    np.testing.assert_array_equal(np.asarray(merged.total), np.asarray(glob.total))
    np.testing.assert_array_equal(np.asarray(merged.min), np.asarray(glob.min))
    np.testing.assert_array_equal(np.asarray(merged.max), np.asarray(glob.max))
    np.testing.assert_allclose(np.asarray(merged.wsum), np.asarray(glob.wsum), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(merged.mean), np.asarray(glob.mean), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(merged.m2), np.asarray(glob.m2), rtol=2e-4, atol=2e-2)


def test_column_stats_merge_associative(rng):
    parts, _ = _column_parts(rng, shards=3)
    a, b, c = parts
    left = estimators.merge_column_stats(estimators.merge_column_stats(a, b), c)
    right = estimators.merge_column_stats(a, estimators.merge_column_stats(b, c))
    np.testing.assert_array_equal(np.asarray(left.min), np.asarray(right.min))
    np.testing.assert_array_equal(np.asarray(left.max), np.asarray(right.max))
    np.testing.assert_allclose(np.asarray(left.m2), np.asarray(right.m2), rtol=2e-4, atol=2e-2)
    np.testing.assert_allclose(np.asarray(left.wsum), np.asarray(right.wsum), rtol=2e-5)


def test_empty_stratum_identities(rng):
    """Strata with no sampled tuples carry merge identities (0 / ±inf)."""
    sidx = jnp.zeros(100, jnp.int32)  # everything in stratum 0 of 4
    vals = jnp.asarray(rng.normal(0, 1, 100), jnp.float32)
    cs = estimators.column_stats(vals, sidx, jnp.ones(100, bool), 4)
    assert float(cs.n[2]) == 0.0
    assert np.isposinf(float(cs.min[2])) and np.isneginf(float(cs.max[2]))
    # merging an empty accumulator is a no-op
    merged = estimators.merge_column_stats(cs, jax.tree.map(lambda x: x, cs)._replace(
        n=jnp.zeros_like(cs.n), total=jnp.zeros_like(cs.total),
        wsum=jnp.zeros_like(cs.wsum), m2=jnp.zeros_like(cs.m2),
        mean=jnp.zeros_like(cs.mean),
        min=jnp.full_like(cs.min, jnp.inf), max=jnp.full_like(cs.max, -jnp.inf)))
    np.testing.assert_allclose(np.asarray(merged.mean), np.asarray(cs.mean), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(merged.min), np.asarray(cs.min))


# -- preagg vs raw agreement, per aggregate kind -----------------------------


ALL_AGGS = tuple(AggSpec(k, "value") for k in KINDS) + (
    AggSpec("mean", "occupancy"),
    AggSpec("max", "occupancy"),
    AggSpec("p50", "value"),
    AggSpec("p99", "value"),
)


@pytest.mark.parametrize("group_by", [None, "neighborhood"])
def test_preagg_equals_raw_per_kind(table, window, group_by):
    """Both transmission modes give identical estimates for the same sample,
    for every aggregate kind (the §3.6.4 property, lifted to the query layer)."""
    pipe = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=30_000))
    res = {}
    for mode in ("preagg", "raw"):
        q = Query(aggs=ALL_AGGS, mode=mode, group_by=group_by)
        res[mode] = pipe.execute(q, jax.random.key(7), window, fraction=0.7)
    for spec in ALL_AGGS:
        a = np.asarray(res["preagg"].estimates[spec.key].value)
        b = np.asarray(res["raw"].estimates[spec.key].value)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6, err_msg=spec.key)
        ma = np.asarray(res["preagg"].estimates[spec.key].moe)
        mb = np.asarray(res["raw"].estimates[spec.key].moe)
        np.testing.assert_allclose(ma, mb, rtol=1e-4, atol=1e-6, err_msg=spec.key)


# -- aggregate correctness ----------------------------------------------------


def test_full_fraction_matches_numpy_oracle(table, window):
    """At fraction=1.0 every kind must equal its exact numpy groupby value."""
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=tuple(AggSpec(k, "value") for k in KINDS))
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
    v = window.value[sidx < table.num_strata]  # in-region tuples only
    assert float(r.estimates["count_value"].value) == len(v)
    assert float(r.estimates["sum_value"].value) == pytest.approx(v.sum(), rel=1e-4)
    assert float(r.estimates["mean_value"].value) == pytest.approx(v.mean(), rel=1e-5)
    assert float(r.estimates["min_value"].value) == pytest.approx(v.min(), abs=1e-6)
    assert float(r.estimates["max_value"].value) == pytest.approx(v.max(), abs=1e-6)
    # var: within+between decomposition over strata == population variance
    assert float(r.estimates["var_value"].value) == pytest.approx(v.var(), rel=2e-2)
    # full sample -> zero-width intervals for the error-bounded kinds
    assert float(r.estimates["mean_value"].moe) == pytest.approx(0.0, abs=1e-5)


def test_count_exact_under_sampling(table, window):
    """Population counts are observed, not sampled: COUNT is exact at any
    fraction and the sampled mean stays near the truth."""
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("count", "value"), AggSpec("mean", "value")))
    r_lo = pipe.execute(q, jax.random.key(1), window, fraction=0.2)
    r_hi = pipe.execute(q, jax.random.key(2), window, fraction=1.0)
    assert float(r_lo.estimates["count_value"].value) == float(
        r_hi.estimates["count_value"].value
    )
    true = float(r_hi.estimates["mean_value"].value)
    assert float(r_lo.estimates["mean_value"].value) == pytest.approx(true, rel=0.02)


def test_grouped_neighborhood_matches_oracle(table, window):
    """group_by=neighborhood at full fraction == numpy per-group means."""
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("mean", "value"), AggSpec("count", "value")), group_by="neighborhood")
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    mean_g = np.asarray(r.estimates["mean_value"].value)
    count_g = np.asarray(r.estimates["count_value"].value)
    assert mean_g.shape == (table.num_neighborhoods,)
    sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
    nb = np.asarray(table.neighborhood)[sidx]
    for g in range(table.num_neighborhoods):
        sel = (nb == g) & (sidx < table.num_strata)
        assert count_g[g] == sel.sum()
        if sel.sum():
            assert mean_g[g] == pytest.approx(window.value[sel].mean(), rel=1e-4)


def test_roi_bbox_and_prefix(table, window):
    """bbox ROI == numpy mask; geohash-prefix ROI == parent-code mask."""
    pipe = EdgeCloudPipeline(table)
    lat_lo, lat_hi = np.quantile(window.lat, [0.25, 0.75])
    lon_lo, lon_hi = np.quantile(window.lon, [0.25, 0.75])
    bbox = ((float(lat_lo), float(lat_hi)), (float(lon_lo), float(lon_hi)))
    q = Query(aggs=(AggSpec("count", "value"), AggSpec("mean", "value")), roi=bbox)
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
    in_roi = (
        (window.lat >= lat_lo) & (window.lat <= lat_hi)
        & (window.lon >= lon_lo) & (window.lon <= lon_hi)
        & (sidx < table.num_strata)
    )
    assert int(r.estimates["count_value"].value) == int(in_roi.sum())
    assert float(r.estimates["mean_value"].value) == pytest.approx(
        window.value[in_roi].mean(), rel=1e-4
    )
    # geohash-prefix ROI: the densest precision-3 cell
    codes3 = np.asarray(
        geohash.encode(jnp.asarray(window.lat), jnp.asarray(window.lon), 3)
    )
    top = np.bincount(codes3 % (1 << 15)).argmax()  # pick a frequent cell
    code = codes3[codes3 % (1 << 15) == top][0]
    prefix = geohash.to_strings(np.asarray([code], np.uint64), 3)[0]
    qp = Query(aggs=(AggSpec("count", "value"),), roi=prefix)
    rp = pipe.execute(qp, jax.random.key(0), window, fraction=1.0)
    in_cell = (codes3 == code) & (sidx < table.num_strata)
    assert int(rp.estimates["count_value"].value) == int(in_cell.sum())
    assert int(rp.n_overflow) == window.capacity - int(in_cell.sum())


def test_multi_column_window(table, window):
    """One window answers aggregates over several named columns at once."""
    assert "occupancy" in window.columns
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("mean", "value"), AggSpec("mean", "occupancy")))
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    assert float(r.estimates["mean_occupancy"].value) == pytest.approx(
        float(window.extra["occupancy"].mean()), rel=1e-3
    )
    with pytest.raises(KeyError):
        pipe.execute(
            Query(aggs=(AggSpec("mean", "humidity"),)), jax.random.key(0), window
        )


def test_quantiles_match_numpy_oracle(table, window):
    """p50/p99 at fraction=1.0 land within the sketch's documented relative
    value accuracy (~4%) of the exact numpy quantiles."""
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("p50", "value"), AggSpec("p99", "value")))
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
    v = window.value[sidx < table.num_strata]
    for key, quant in (("p50_value", 0.5), ("p99_value", 0.99)):
        true = float(np.quantile(v, quant))
        got = float(r.estimates[key].value)
        assert got == pytest.approx(true, rel=0.05, abs=1e-3), key
        # quantiles are point estimates: zero-width intervals
        assert float(r.estimates[key].moe) == 0.0


def test_quantiles_under_sampling_stay_close(table, window):
    """The HT-expanded sketch quantile tracks the truth at fraction<1."""
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("p50", "value"),))
    truth = float(
        pipe.execute(q, jax.random.key(0), window, 1.0).estimates["p50_value"].value
    )
    got = float(
        pipe.execute(q, jax.random.key(3), window, 0.3).estimates["p50_value"].value
    )
    assert got == pytest.approx(truth, rel=0.1)


def test_grouped_quantiles_match_numpy(table, window):
    """group_by=neighborhood p50 at full fraction == per-group numpy medians
    (within sketch accuracy)."""
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("p50", "value"),), group_by="neighborhood")
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    vals = np.asarray(r.estimates["p50_value"].value)
    assert vals.shape == (table.num_neighborhoods,)
    sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
    nb = np.asarray(table.neighborhood)[sidx]
    for g in range(table.num_neighborhoods):
        sel = (nb == g) & (sidx < table.num_strata)
        if sel.sum() > 50:
            assert vals[g] == pytest.approx(
                float(np.quantile(window.value[sel], 0.5)), rel=0.05, abs=1e-3
            ), g


# -- raw-mode buffer overflow accounting --------------------------------------


def test_raw_truncation_surfaced_and_boundary(table, window):
    """Kept tuples beyond the static raw buffer are counted in
    ``n_truncated`` (previously shed silently); at or under capacity the
    count is zero and the estimates are unaffected."""
    q = Query(aggs=(AggSpec("mean", "value"),), mode="raw")
    key = jax.random.key(2)
    # generous buffer: nothing truncated
    pipe_ok = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=window.capacity))
    r_ok = pipe_ok.execute(q, key, window, fraction=0.5)
    kept = int(r_ok.n_sampled)
    assert int(r_ok.n_truncated) == 0
    # boundary: capacity exactly == kept sample -> still zero
    pipe_edge = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=kept))
    r_edge = pipe_edge.execute(q, key, window, fraction=0.5)
    assert int(r_edge.n_truncated) == 0
    assert float(r_edge.estimates["mean_value"].value) == pytest.approx(
        float(r_ok.estimates["mean_value"].value), rel=1e-6
    )
    # one short: exactly one kept tuple is shed, and the loss is surfaced
    pipe_tight = EdgeCloudPipeline(table, PipelineConfig(raw_capacity=kept - 1))
    r_tight = pipe_tight.execute(q, key, window, fraction=0.5)
    assert int(r_tight.n_truncated) == 1
    # preagg mode never truncates
    r_pre = pipe_ok.execute(
        Query(aggs=(AggSpec("mean", "value"),)), key, window, 0.5
    )
    assert int(r_pre.n_truncated) == 0


def test_moe_shrinks_with_fraction(table, window):
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("mean", "value"),))
    moes = [
        float(pipe.execute(q, jax.random.key(5), window, fraction=f).estimates["mean_value"].moe)
        for f in (0.1, 0.4, 0.9)
    ]
    assert moes[0] > moes[1] > moes[2]


# -- legacy shim --------------------------------------------------------------


def test_process_window_shim_matches_legacy_path(table, window):
    """The shim reproduces the pre-redesign computation: edge_sample +
    sample_stats + estimate, same key, same ops."""
    n = window.capacity
    lat = jnp.asarray(window.lat)
    lon = jnp.asarray(window.lon)
    val = jnp.asarray(window.value)
    valid = jnp.asarray(window.valid)
    pipe = EdgeCloudPipeline(table, PipelineConfig(mode="preagg"))
    wr = pipe.process_window(jax.random.key(3), lat, lon, val, valid, jnp.float32(0.7))
    # pre-redesign reference, computed by hand
    from repro.core.pipeline import edge_sample

    sidx, sample = edge_sample(jax.random.key(3), table, lat, lon, valid, 0.7, "srs")
    stats = estimators.sample_stats(val, sidx, sample.mask, table.num_slots, counts=sample.counts)
    ref = estimators.estimate(_zero_overflow(stats), 0.95)
    assert float(wr.estimate.mean) == pytest.approx(float(ref.mean), rel=1e-6)
    assert float(wr.estimate.sum) == pytest.approx(float(ref.sum), rel=1e-6)
    assert float(wr.estimate.moe) == pytest.approx(float(ref.moe), rel=1e-5)
    assert int(wr.n_sampled) == int(jnp.sum(sample.mask))
    assert int(wr.n_valid) == int(jnp.sum(valid))
    assert int(wr.comm_bytes) == 4 * 4 * table.num_slots  # legacy payload


def test_execute_canonical_query_agrees_with_shim(table, window):
    """execute() on the canonical SUM/MEAN query == process_window."""
    pipe = EdgeCloudPipeline(table)
    lat, lon = jnp.asarray(window.lat), jnp.asarray(window.lon)
    val, valid = jnp.asarray(window.value), jnp.asarray(window.valid)
    wr = pipe.process_window(jax.random.key(9), lat, lon, val, valid, jnp.float32(0.6))
    q = Query(aggs=(AggSpec("sum", "value"), AggSpec("mean", "value")))
    r = pipe.execute(
        q, jax.random.key(9), {"lat": lat, "lon": lon, "valid": valid, "value": val}, 0.6
    )
    assert float(r.estimates["mean_value"].value) == pytest.approx(float(wr.estimate.mean), rel=1e-6)
    assert float(r.estimates["sum_value"].value) == pytest.approx(float(wr.estimate.sum), rel=1e-6)
    assert float(r.estimates["mean_value"].moe) == pytest.approx(float(wr.estimate.moe), rel=1e-5)


def test_preagg_payload_shares_counts_and_prunes_extrema(table, window):
    """n/total cross the uplink once, not once per column; min/max vectors
    only cross for columns an extrema aggregate actually reads."""
    pipe = EdgeCloudPipeline(table)
    one = pipe.execute(Query(aggs=(AggSpec("mean", "value"),)), jax.random.key(0), window, 0.5)
    two = pipe.execute(
        Query(aggs=(AggSpec("mean", "value"), AggSpec("mean", "occupancy"))),
        jax.random.key(0), window, 0.5,
    )
    ext = pipe.execute(
        Query(aggs=(AggSpec("mean", "value"), AggSpec("max", "value"))),
        jax.random.key(0), window, 0.5,
    )
    # a moment-only column ships the legacy 4-vector payload
    assert int(one.comm_bytes) == 4 * 4 * table.num_slots
    # each extra moment-only column adds wsum/raw2 vectors only
    assert int(two.comm_bytes) - int(one.comm_bytes) == 4 * 2 * table.num_slots
    # an extrema aggregate adds the min/max pair for its column
    assert int(ext.comm_bytes) - int(one.comm_bytes) == 4 * 2 * table.num_slots
    plan = lower(Query(aggs=(AggSpec("mean", "value"), AggSpec("max", "value"))), table)
    assert plan.extrema_columns == ("value",)
    assert lower(Query(aggs=(AggSpec("mean", "value"),)), table).extrema_columns == ()


def test_stream_chunk_key_drift_rejected(table):
    """Chunks with inconsistent columns raise instead of dropping data."""
    def drifting():
        yield dict(sensor_id=np.zeros(5, np.int32), timestamp=np.arange(5.0),
                   lat=np.zeros(5, np.float32), lon=np.zeros(5, np.float32),
                   value=np.ones(5, np.float32))
        yield dict(sensor_id=np.zeros(5, np.int32), timestamp=np.arange(5.0) + 5,
                   lat=np.zeros(5, np.float32), lon=np.zeros(5, np.float32),
                   value=np.ones(5, np.float32), occupancy=np.ones(5, np.float32))

    with pytest.raises(ValueError, match="chunk keys"):
        list(windows.count_windows(drifting(), 10))


def test_run_stream_point_estimate_query_keeps_fraction(table):
    """A query with no error-bounded aggregate cannot drive the QoS loop;
    the fraction must stay fixed instead of collapsing to min_fraction."""
    stream = shenzhen_taxi_stream(num_chunks=3, seed=4)
    wnds = list(windows.count_windows(stream, 15_000))
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("count", "value"), AggSpec("max", "value")))
    history, state = pipe.run_stream(wnds, initial_fraction=0.5, key=jax.random.key(0), query=q)
    assert [frac for _, frac in history] == [0.5] * len(wnds)


def test_run_stream_grouped_query_adapts(table):
    """Empty groups report RE=inf; the controller must track the worst
    *finite* group instead of freezing on inf."""
    from repro.core.feedback import SLO

    stream = shenzhen_taxi_stream(num_chunks=3, seed=5)
    wnds = list(windows.count_windows(stream, 15_000))
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("mean", "value"),), group_by="stratum")  # many empty strata
    history, state = pipe.run_stream(
        wnds, slo=SLO(target_relative_error=0.5), initial_fraction=0.9,
        key=jax.random.key(0), query=q,
    )
    # a loose SLO and a finite worst-group RE must let the fraction drop
    assert float(state.fraction) < 0.9


def test_run_stream_all_groups_empty_holds_fraction(table):
    """ROI with no coverage -> every group RE is inf; the controller must
    hold the fraction steady, not collapse it to min_fraction."""
    stream = shenzhen_taxi_stream(num_chunks=2, seed=6)
    wnds = list(windows.count_windows(stream, 10_000))
    pipe = EdgeCloudPipeline(table)
    q = Query(
        aggs=(AggSpec("mean", "value"),), group_by="neighborhood",
        roi=((0.0, 1.0), (0.0, 1.0)),  # far outside the city
    )
    history, state = pipe.run_stream(wnds, initial_fraction=0.5, key=jax.random.key(0), query=q)
    assert [frac for _, frac in history] == pytest.approx([0.5] * len(wnds))


def test_run_stream_with_query(table):
    """The QoS loop drives a declarative query end-to-end."""
    from repro.core.feedback import SLO

    stream = shenzhen_taxi_stream(num_chunks=4, seed=2)
    wnds = list(windows.count_windows(stream, 15_000))
    pipe = EdgeCloudPipeline(table)
    q = Query(aggs=(AggSpec("mean", "value"), AggSpec("count", "value")))
    history, state = pipe.run_stream(
        wnds, slo=SLO(target_relative_error=0.01), initial_fraction=0.5,
        key=jax.random.key(0), query=q,
    )
    assert len(history) == len(wnds)
    for res, frac in history:
        assert float(res.estimates["mean_value"].value) > 0
        assert 0.0 < frac <= 1.0
