"""edgelint fixture suite: every rule fires on a seeded violation, stays
quiet on a clean twin, and the real tree is clean (suppressions bounded).

Each EDG rule gets one known-bad and one known-clean snippet laid out in a
tmp mini-tree mirroring the repo layout (``src/repro/core``, ``kernels/``,
``sharding/``) so the scope-sensitive rules see realistic paths.  The
final tests pin the production contract: ``lint_paths`` over the actual
``src/ tests/ benchmarks/`` tree reports zero active findings, and every
suppression carries a written reason.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.edgelint import lint_paths  # noqa: E402


def lint_tree(tmp_path, files: dict[str, str]):
    for rel, source in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(source)
    return lint_paths(["."], root=tmp_path)


def codes(result) -> set[str]:
    return {f.code for f in result.findings}


# ---------------------------------------------------------------------------
# EDG001 — determinism
# ---------------------------------------------------------------------------


def test_edg001_fires_on_host_randomness_in_core(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/core/bad.py": (
                "import numpy as np\n"
                "import time\n"
                "def sample(n):\n"
                "    t = time.time()\n"
                "    return np.random.rand(n) + t\n"
            )
        },
    )
    assert "EDG001" in codes(res)
    assert len([f for f in res.findings if f.code == "EDG001"]) == 2  # clock + rng


def test_edg001_fires_transitively_through_core_imports(tmp_path):
    """A helper module imported by core is inside the deterministic closure."""
    res = lint_tree(
        tmp_path,
        {
            "src/repro/core/engine.py": "from ..util import helper\n",
            "src/repro/util.py": (
                "import time\n\ndef helper():\n    return time.time()\n"
            ),
        },
    )
    assert any(
        f.code == "EDG001" and f.path == "src/repro/util.py" for f in res.findings
    )


def test_edg001_clean_on_threaded_jax_keys_and_seeded_rng(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/core/good.py": (
                "import jax\n"
                "def sample(key, n):\n"
                "    k1, k2 = jax.random.split(key)\n"
                "    return jax.random.uniform(k1, (n,))\n"
            ),
            # outside the core closure, *seeded* host RNG is fine...
            "benchmarks/good_bench.py": (
                "import numpy as np\nrng = np.random.default_rng(0)\n"
            ),
        },
    )
    assert "EDG001" not in codes(res)


def test_edg001_fires_on_unseeded_rng_outside_core(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "benchmarks/bad_bench.py": (
                "import numpy as np\nrng = np.random.default_rng()\n"
            )
        },
    )
    assert "EDG001" in codes(res)


# ---------------------------------------------------------------------------
# EDG002 — host-sync hygiene
# ---------------------------------------------------------------------------

EDG002_BAD = """
import jax
import numpy as np

@jax.jit
def edge_pass(x):
    scale = float(x.sum())
    return np.asarray(x) * scale

def pane_loop(panes):  # edgelint: pane-loop
    return [p.item() for p in panes]
"""

EDG002_CLEAN = """
import jax
import jax.numpy as jnp

@jax.jit
def edge_pass(x, n_dropped):
    host = int(getattr(x, "n_dropped", 0))  # host attribute, not a sync
    return jnp.sum(x) * jnp.float32(host)
"""


def test_edg002_fires_in_jitted_and_pane_loop_functions(tmp_path):
    res = lint_tree(tmp_path, {"src/repro/core/bad_sync.py": EDG002_BAD})
    found = [f for f in res.findings if f.code == "EDG002"]
    assert len(found) >= 3  # float(), np.asarray, .item()
    assert any(".item()" in f.message for f in found)


def test_edg002_clean_on_host_side_casts(tmp_path):
    res = lint_tree(tmp_path, {"src/repro/core/good_sync.py": EDG002_CLEAN})
    assert "EDG002" not in codes(res)


def test_edg002_suppression_requires_the_code(tmp_path):
    sup = EDG002_BAD.replace(
        "scale = float(x.sum())",
        "scale = float(x.sum())  # edgelint: ignore[EDG002] trace boundary",
    )
    res = lint_tree(tmp_path, {"src/repro/core/bad_sync.py": sup})
    assert all("float" not in f.message for f in res.findings if f.code == "EDG002")
    assert any("float" in f.message for f in res.suppressed)
    assert all(s.suppress_reason for s in res.suppressed)


# ---------------------------------------------------------------------------
# EDG003 — accumulator-protocol completeness
# ---------------------------------------------------------------------------

EDG003_BAD = """
from repro.core.estimators import Accumulator, register_accumulator

class HalfKind(Accumulator):
    kind = "half"
    def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
        return values
    def merge(self, a, b):
        return a + b
    # no merge_panes / psum / zero_overflow / payload_vectors

register_accumulator(HalfKind())
"""

EDG003_CLEAN = """
from repro.core.estimators import Accumulator, register_accumulator

class FullKind(Accumulator):
    kind = "full"
    def accumulate(self, values, stratum_idx, mask, num_slots, counts=None):
        return values
    def merge(self, a, b):
        return a + b
    def merge_panes(self, stacked):
        return stacked.sum(0)
    def psum(self, state, axis_names, shared=None):
        return state
    def zero_overflow(self, state):
        return state
    def payload_vectors(self):
        return 1
    def payload_flatten(self, state):
        return (("v", state, True, 0.0),)
    def payload_unflatten(self, rows):
        return rows["v"]
    def interval(self, state, n, confidence):
        return (0.0, 0.0)

class Derived(FullKind):
    kind = "derived"  # inherits the full surface: still complete

register_accumulator(FullKind())
register_accumulator(Derived())
"""


def test_edg003_fires_on_partial_accumulator(tmp_path):
    res = lint_tree(tmp_path, {"src/repro/core/plugin.py": EDG003_BAD})
    found = [f for f in res.findings if f.code == "EDG003"]
    assert len(found) == 1
    for missing in (
        "merge_panes",
        "psum",
        "zero_overflow",
        "payload_vectors",
        "payload_flatten",
        "payload_unflatten",
    ):
        assert missing in found[0].message


def test_edg003_clean_on_full_and_inherited_surfaces(tmp_path):
    res = lint_tree(tmp_path, {"src/repro/core/plugin.py": EDG003_CLEAN})
    assert "EDG003" not in codes(res)


# ---------------------------------------------------------------------------
# EDG004 — kernel-triad contract
# ---------------------------------------------------------------------------

KERNEL_OPS = """
def fused_reduce(stratum_idx, values, mask, num_slots, interpret=None):
    return stratum_idx
"""

KERNEL_REF_OK = """
def fused_reduce_ref(stratum_idx, values, mask, num_slots):
    return stratum_idx
"""

KERNEL_REF_DRIFTED = """
def fused_reduce_ref(stratum_idx, values, num_slots):
    return stratum_idx
"""


def test_edg004_fires_on_missing_ref_and_signature_drift(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/noref/__init__.py": "",
            "src/repro/kernels/noref/ops.py": KERNEL_OPS,
            "src/repro/kernels/drift/__init__.py": "",
            "src/repro/kernels/drift/ops.py": KERNEL_OPS,
            "src/repro/kernels/drift/ref.py": KERNEL_REF_DRIFTED,
        },
    )
    found = [f for f in res.findings if f.code == "EDG004"]
    assert any("no ref.py" in f.message for f in found)
    assert any("required params" in f.message for f in found)


def test_edg004_fires_on_non_f32_accumulation_dtype(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/lowp/__init__.py": "",
            "src/repro/kernels/lowp/ops.py": KERNEL_OPS,
            "src/repro/kernels/lowp/ref.py": KERNEL_REF_OK,
            "src/repro/kernels/lowp/lowp.py": (
                "import jax.numpy as jnp\n"
                "def k(x):\n"
                "    return jnp.zeros((8,), jnp.float16) + x\n"
            ),
        },
    )
    assert any(
        f.code == "EDG004" and "float16" in f.message for f in res.findings
    )


# Megakernel-shaped triad: many keyword-only mode/layout params with
# defaults (sidx / lat / lon / codes / ext_idx / sk_idx) around a short
# required prefix — the shape PR 8's fused kernel actually ships.
MEGA_OPS = """
def edge_mega(vals, ok, scores, thresholds, num_slots, *,
              sidx=None, lat=None, lon=None, codes=None, precision=None,
              ext_idx=(), sk_idx=(), interpret=None):
    return vals
"""

MEGA_REF_OK = """
import numpy as np

def edge_mega_ref(vals, ok, scores, thresholds, num_slots, *,
                  sidx=None, lat=None, lon=None, codes=None, precision=None,
                  ext_idx=(), sk_idx=()):
    return np.asarray(vals)
"""

MEGA_REF_DRIFTED = """
import numpy as np

def edge_mega_ref(vals, ok, thresholds, num_slots):
    return np.asarray(vals)
"""


def test_edg004_megakernel_shaped_bad_triad(tmp_path):
    """Drifted required prefix fires; a bf16 *accumulator* literal in the
    kernel body fires (staging is the caller's dtype choice, accumulation
    must stay f32)."""
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/mega/__init__.py": "",
            "src/repro/kernels/mega/ops.py": MEGA_OPS,
            "src/repro/kernels/mega/ref.py": MEGA_REF_DRIFTED,
            "src/repro/kernels/mega/mega.py": (
                "import jax.numpy as jnp\n"
                "def k(rows, member):\n"
                "    acc = jnp.zeros((8, 8), jnp.bfloat16)\n"
                "    return acc + rows @ member\n"
            ),
        },
    )
    found = [f for f in res.findings if f.code == "EDG004"]
    assert any("required params" in f.message for f in found)
    assert any("bfloat16" in f.message for f in found)


def test_edg004_edg006_clean_on_megakernel_shaped_triad(tmp_path):
    """The clean twin: keyword-only optional mode params do not count as
    drift, and a numpy oracle with its own encoder helpers is EDG006-pure."""
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/mega/__init__.py": "",
            "src/repro/kernels/mega/ops.py": MEGA_OPS,
            "src/repro/kernels/mega/ref.py": MEGA_REF_OK,
            "src/repro/kernels/mega/mega.py": (
                "import jax.numpy as jnp\n"
                "def k(rows, member):\n"
                "    # staging cast: inputs may arrive reduced, math is f32\n"
                "    return rows.astype(jnp.float32) @ member\n"
            ),
        },
    )
    assert "EDG004" not in codes(res)
    assert "EDG006" not in codes(res)


def test_edg004_clean_on_matching_triad(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/good/__init__.py": "",
            "src/repro/kernels/good/ops.py": KERNEL_OPS,
            "src/repro/kernels/good/ref.py": KERNEL_REF_OK,
        },
    )
    assert "EDG004" not in codes(res)


# ---------------------------------------------------------------------------
# EDG005 — collective-axis consistency
# ---------------------------------------------------------------------------

SHARDING_DECL = 'MESH_AXIS_NAMES = ("pod", "data", "model")\n'


def test_edg005_fires_on_undeclared_axis_literal(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/sharding/__init__.py": SHARDING_DECL,
            "src/repro/core/reduce.py": (
                "import jax\n"
                "def combine(x):\n"
                '    return jax.lax.psum(x, "modle")\n'  # typo'd axis
            ),
        },
    )
    found = [f for f in res.findings if f.code == "EDG005"]
    assert len(found) == 1 and "'modle'" in found[0].message


def test_edg005_clean_on_declared_axes_and_threaded_axis_vars(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/sharding/__init__.py": SHARDING_DECL,
            "src/repro/core/reduce.py": (
                "import jax\n"
                "def combine(x, axes):\n"
                '    a = jax.lax.psum(x, "data")\n'
                '    b = jax.lax.pmax(x, ("pod", "data"))\n'
                "    return jax.lax.psum(a + b, axes)\n"  # variable: out of scope
            ),
        },
    )
    assert "EDG005" not in codes(res)


def test_edg005_fires_when_sharding_declares_no_vocabulary(tmp_path):
    res = lint_tree(
        tmp_path,
        {"src/repro/sharding/__init__.py": "rules = {}\n"},
    )
    assert any(
        f.code == "EDG005" and "MESH_AXIS_NAMES" in f.message for f in res.findings
    )


# ---------------------------------------------------------------------------
# EDG006 — ref purity (oracles are jax-free, self-contained numpy)
# ---------------------------------------------------------------------------


def test_edg006_fires_on_jax_import_in_ref(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/jaxy/__init__.py": "",
            "src/repro/kernels/jaxy/ops.py": KERNEL_OPS,
            "src/repro/kernels/jaxy/ref.py": (
                "import jax\n"
                "import jax.numpy as jnp\n"
                "def fused_reduce_ref(stratum_idx, values, mask, num_slots):\n"
                "    return jnp.asarray(stratum_idx)\n"
            ),
        },
    )
    found = [f for f in res.findings if f.code == "EDG006"]
    assert len(found) == 2  # one per jax import line
    assert all("jax-free" in f.message for f in found)


def test_edg006_fires_on_relative_and_in_repo_imports(tmp_path):
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/deleg/__init__.py": "",
            "src/repro/kernels/deleg/ops.py": KERNEL_OPS,
            "src/repro/kernels/deleg/ref.py": (
                "from ...core import geohash as _g\n"
                "import repro.core.estimators\n"
                "def fused_reduce_ref(stratum_idx, values, mask, num_slots):\n"
                "    return _g.encode(values, values, 5)\n"
            ),
        },
    )
    found = [f for f in res.findings if f.code == "EDG006"]
    assert any("relative import" in f.message for f in found)
    assert any("in-repo import" in f.message for f in found)


def test_edg006_clean_on_numpy_ref_and_non_ref_jax(tmp_path):
    """numpy/ml_dtypes/stdlib refs pass; jax in ops.py is not EDG006's business."""
    res = lint_tree(
        tmp_path,
        {
            "src/repro/kernels/pure/__init__.py": "",
            "src/repro/kernels/pure/ops.py": "import jax\n" + KERNEL_OPS,
            "src/repro/kernels/pure/ref.py": (
                "from __future__ import annotations\n"
                "import math\n"
                "import numpy as np\n"
                "import ml_dtypes\n"
                "def fused_reduce_ref(stratum_idx, values, mask, num_slots):\n"
                "    return np.asarray(stratum_idx) * math.pi\n"
            ),
            # a ref.py outside kernels/ is out of scope too
            "src/repro/core/ref.py": "import jax\n",
        },
    )
    assert "EDG006" not in codes(res)


# ---------------------------------------------------------------------------
# The production contract: the real tree is clean, suppressions bounded
# ---------------------------------------------------------------------------


def test_megakernel_triad_lints_clean_unsuppressed():
    """PR 8 acceptance: the fused megakernel triad passes edgelint with no
    findings AND no suppression comments anywhere in its directory."""
    res = lint_paths(["src/repro/kernels/edge_megakernel"], root=REPO_ROOT)
    assert res.errors == []
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
    assert res.suppressed == [], [s.render() for s in res.suppressed]


def test_real_tree_is_clean_with_bounded_suppressions():
    res = lint_paths(["src", "tests", "benchmarks"], root=REPO_ROOT)
    assert res.errors == []
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
    # every escape hatch is rare, deliberate, and documents why
    assert 0 < len(res.suppressed) <= 12
    assert all(s.suppress_reason for s in res.suppressed)


def test_cli_json_contract(tmp_path):
    """The CI job's exact interface: JSON output, exit 1 on a violation
    (a reintroduced np.random in src/repro/core), exit 0 once fixed."""
    bad = tmp_path / "src" / "repro" / "core" / "regress.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import numpy as np\nx = np.random.rand(3)\n")
    env = {**os.environ, "PYTHONPATH": str(REPO_ROOT)}
    proc = subprocess.run(
        [sys.executable, "-m", "tools.edgelint", "--format=json", "--root", str(tmp_path), "src"],
        capture_output=True,
        text=True,
        cwd=REPO_ROOT,
        env=env,
    )
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["counts"].get("EDG001") == 1
    assert payload["findings"][0]["path"] == "src/repro/core/regress.py"

    bad.write_text("import jax\ndef f(key):\n    return jax.random.uniform(key, (3,))\n")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.edgelint", "--format=json", "--root", str(tmp_path), "src"],
        capture_output=True,
        text=True,
        cwd=REPO_ROOT,
        env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(proc.stdout)["n_findings"] == 0
