"""Crash/resume determinism: pane checkpoint/restore for StreamSession.

Checkpoint a session mid-sliding-window, restore into a *fresh* session
(re-registered queries, fresh compile caches), and assert the resumed run
is **bit-identical** to one that never restarted: every emitted estimate,
interval, fraction trajectory, and ``n_dropped`` accounting — in preagg
and raw modes, through SLO-driven controllers, and across the npz
file round-trip.
"""

import numpy as np
import pytest

import jax

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    SLO,
    StreamSession,
    WindowSpec,
    checkpoint,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

PANE = 4_000
N_PANES = 6
CUT = 3  # checkpoint after this many panes: mid-sliding AND mid-tumbling

EXACT_FIELDS = ("value", "moe", "ci_low", "ci_high", "relative_error", "n", "population")


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def pipe(table):
    return EdgeCloudPipeline(table, PipelineConfig(raw_capacity=PANE))


@pytest.fixture(scope="module")
def panes():
    stream = shenzhen_taxi_stream(num_chunks=2, seed=5)
    return list(windows.count_windows(stream, PANE))[:N_PANES]


# the registered workload: an SLO-driven sliding window (controller state +
# open multi-pane ring), a mid-flight tumbling window, and a quantile query
# (sketch states in the ring) — registration order matters and is part of
# the restore contract
def _register(sess):
    r_slide = sess.register(
        Query(aggs=(AggSpec("mean", "value"), AggSpec("max", "value"))),
        slo=SLO(target_relative_error=0.02),
        window=WindowSpec("sliding", size=3),
    )
    r_tumble = sess.register(
        Query(aggs=(AggSpec("var", "occupancy"),)),
        window=WindowSpec("tumbling", size=2),
    )
    r_quant = sess.register(Query(aggs=(AggSpec("p50", "value"), AggSpec("p99", "value"))))
    return r_slide, r_tumble, r_quant


def _drive(sess, panes, start, root):
    return [
        sess.step(jax.random.fold_in(root, start + i), p) for i, p in enumerate(panes)
    ]


def _assert_steps_identical(expected, got):
    assert len(expected) == len(got)
    for e, g in zip(expected, got):
        assert set(e.results) == set(g.results)
        assert e.fractions == g.fractions
        assert e.n_dropped == g.n_dropped
        assert e.comm_bytes == g.comm_bytes
        for qid in e.results:
            re_, rg = e.results[qid], g.results[qid]
            for k in re_.estimates:
                for field in EXACT_FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(re_.estimates[k], field)),
                        np.asarray(getattr(rg.estimates[k], field)),
                        err_msg=f"qid={qid} {k}.{field}",
                    )
            assert int(re_.n_sampled) == int(rg.n_sampled)
            assert int(re_.n_valid) == int(rg.n_valid)
            assert int(re_.n_dropped) == int(rg.n_dropped)


def _uninterrupted(pipe, panes, root, initial_fraction=0.8):
    sess = StreamSession(pipe, initial_fraction=initial_fraction)
    _register(sess)
    return sess, _drive(sess, panes, 0, root)


def test_restore_resumes_bit_identically(pipe, panes):
    """In-memory snapshot taken mid-sliding-window: a fresh session resumes
    with bit-identical estimates, intervals, controller fractions, and drop
    accounting vs. the uninterrupted run."""
    root = jax.random.key(42)
    sess_full, full = _uninterrupted(pipe, panes, root)

    sess_a = StreamSession(pipe, initial_fraction=0.8)
    _register(sess_a)
    _drive(sess_a, panes[:CUT], 0, root)
    snap = sess_a.checkpoint()

    sess_b = StreamSession(pipe, initial_fraction=0.8)
    _register(sess_b)
    sess_b.restore(snap)
    assert sess_b.pane_index == CUT
    resumed = _drive(sess_b, panes[CUT:], CUT, root)
    _assert_steps_identical(full[CUT:], resumed)
    assert sess_b.total_comm_bytes == sess_full.total_comm_bytes
    assert sess_b.total_dropped == sess_full.total_dropped
    assert sess_b.total_passes == sess_full.total_passes
    for ra, rb in zip(sess_full.registrations, sess_b.registrations):
        assert ra.fraction == rb.fraction
        assert ra.re_ema == rb.re_ema
        assert ra.steps == rb.steps
        assert ra.downstream_bytes == rb.downstream_bytes


def test_restore_file_roundtrip(pipe, panes, tmp_path):
    """The npz round-trip preserves bit-identity (f32 leaves and controller
    floats survive serialization exactly)."""
    root = jax.random.key(7)
    _, full = _uninterrupted(pipe, panes, root)

    sess_a = StreamSession(pipe, initial_fraction=0.8)
    _register(sess_a)
    _drive(sess_a, panes[:CUT], 0, root)
    path = tmp_path / "session.npz"
    snap = sess_a.checkpoint(path)
    assert path.exists()
    loaded = checkpoint.load(path)
    assert loaded["version"] == checkpoint.SNAPSHOT_VERSION
    assert loaded["pane_index"] == snap["pane_index"]

    sess_b = StreamSession(pipe, initial_fraction=0.8)
    _register(sess_b)
    sess_b.restore(path)
    resumed = _drive(sess_b, panes[CUT:], CUT, root)
    _assert_steps_identical(full[CUT:], resumed)


def test_raw_and_preagg_parity_across_restore(pipe, panes):
    """Preagg-vs-raw agreement survives a restore boundary: both modes,
    each interrupted and restored mid-window, keep producing identical
    estimates for the same sample (and each is bit-identical to its own
    uninterrupted run)."""
    root = jax.random.key(13)
    results = {}
    for mode in ("preagg", "raw"):
        q = Query(aggs=(AggSpec("mean", "value"), AggSpec("sum", "value")), mode=mode)
        sess_full = StreamSession(pipe, initial_fraction=0.7)
        reg_f = sess_full.register(q, window=WindowSpec("sliding", size=2))
        full = _drive(sess_full, panes, 0, root)

        sess_a = StreamSession(pipe, initial_fraction=0.7)
        sess_a.register(q, window=WindowSpec("sliding", size=2))
        _drive(sess_a, panes[:CUT], 0, root)
        snap = sess_a.checkpoint()
        sess_b = StreamSession(pipe, initial_fraction=0.7)
        reg_b = sess_b.register(q, window=WindowSpec("sliding", size=2))
        sess_b.restore(snap)
        resumed = _drive(sess_b, panes[CUT:], CUT, root)
        _assert_steps_identical(full[CUT:], resumed)
        results[mode] = [s.results[reg_b.qid] for s in resumed]
        assert reg_b.qid == reg_f.qid

    for res_p, res_r in zip(results["preagg"], results["raw"]):
        for k in res_p.estimates:
            a = float(np.asarray(res_p.estimates[k].value))
            b = float(np.asarray(res_r.estimates[k].value))
            assert b == pytest.approx(a, rel=1e-5), k


def test_n_dropped_survives_restore(pipe):
    """Regression (the restore-boundary accounting fix): bounded-capacity
    panes shed tuples before AND after the checkpoint; the restored
    session's ``total_dropped`` and every emitted window's ``n_dropped``
    match the uninterrupted run exactly."""
    stream = shenzhen_taxi_stream(num_chunks=3, chunk_size=5_000, seed=3)
    droppy = list(windows.pane_windows(stream, pane_seconds=60.0, capacity=2_000))
    assert sum(p.n_dropped for p in droppy) > 0
    cut = len(droppy) // 2
    assert sum(p.n_dropped for p in droppy[:cut]) > 0  # drops on both sides
    assert sum(p.n_dropped for p in droppy[cut:]) > 0
    root = jax.random.key(21)
    q = Query(aggs=(AggSpec("mean", "value"),))

    sess_full = StreamSession(pipe, initial_fraction=0.5)
    reg_full = sess_full.register(q, window=WindowSpec("tumbling", size=2))
    full = _drive(sess_full, droppy, 0, root)

    sess_a = StreamSession(pipe, initial_fraction=0.5)
    sess_a.register(q, window=WindowSpec("tumbling", size=2))
    _drive(sess_a, droppy[:cut], 0, root)
    sess_b = StreamSession(pipe, initial_fraction=0.5)
    reg_b = sess_b.register(q, window=WindowSpec("tumbling", size=2))
    sess_b.restore(sess_a.checkpoint())
    # the snapshot carries the pre-cut drop total ...
    assert sess_b.total_dropped == sum(p.n_dropped for p in droppy[:cut])
    resumed = _drive(sess_b, droppy[cut:], cut, root)
    # ... and the resumed run folds post-cut drops on top, exactly
    assert sess_b.total_dropped == sess_full.total_dropped
    assert sess_b.total_dropped == sum(p.n_dropped for p in droppy)
    emitted_full = [
        int(s.results[reg_full.qid].n_dropped) for s in full[cut:] if s.results
    ]
    emitted_resumed = [
        int(s.results[reg_b.qid].n_dropped) for s in resumed if s.results
    ]
    assert emitted_full == emitted_resumed
    # a window whose ring spans the restore boundary still counts both sides
    spanning = next(
        (s for s in resumed if s.results and int(next(iter(s.results.values())).n_dropped) > 0),
        None,
    )
    assert spanning is not None


def test_restore_validation_guards(pipe, panes):
    """Version, registration-set, and order mismatches are rejected before
    any state is touched."""
    sess = StreamSession(pipe, initial_fraction=0.8)
    _register(sess)
    _drive(sess, panes[:2], 0, jax.random.key(0))
    snap = sess.checkpoint()

    bad_version = dict(snap, version=checkpoint.SNAPSHOT_VERSION + 1)
    fresh = StreamSession(pipe, initial_fraction=0.8)
    _register(fresh)
    with pytest.raises(ValueError, match="version"):
        fresh.restore(bad_version)

    missing = StreamSession(pipe, initial_fraction=0.8)
    missing.register(Query(aggs=(AggSpec("mean", "value"),)))
    with pytest.raises(ValueError, match="re-register"):
        missing.restore(snap)

    wrong_query = StreamSession(pipe, initial_fraction=0.8)
    r1, r2, r3 = _register(wrong_query)
    wrong_query.unregister(r3)
    wrong_query.register(Query(aggs=(AggSpec("sum", "value"),)))  # not the original
    with pytest.raises(ValueError, match="does not match"):
        wrong_query.restore(snap)
    # the failed restores left the fresh sessions untouched
    assert fresh.pane_index == 0 and not fresh.registrations[0].ring


def test_refined_group_state_checkpoints(pipe, panes):
    """Divergent-fraction (refined) groups restore bit-identically too: the
    per-member thinned ring states and downstream counters round-trip."""
    root = jax.random.key(33)
    q_lo = Query(aggs=(AggSpec("mean", "value"),))
    q_hi = Query(aggs=(AggSpec("mean", "occupancy", name="o"),))

    def build():
        sess = StreamSession(pipe)
        regs = (
            sess.register(q_lo, initial_fraction=0.2, window=WindowSpec("sliding", size=2)),
            sess.register(q_hi, initial_fraction=0.9, window=WindowSpec("sliding", size=2)),
        )
        return sess, regs

    sess_full, regs_full = build()
    full = _drive(sess_full, panes[:4], 0, root)

    sess_a, _ = build()
    _drive(sess_a, panes[:2], 0, root)
    sess_b, regs_b = build()
    sess_b.restore(sess_a.checkpoint())
    resumed = _drive(sess_b, panes[2:4], 2, root)
    _assert_steps_identical(full[2:], resumed)
    for rf, rb in zip(regs_full, regs_b):
        assert rf.downstream_bytes == rb.downstream_bytes
    assert regs_b[0].downstream_bytes < regs_b[1].downstream_bytes


# ---------------------------------------------------------------------------
# keep-last-K snapshot rotation
# ---------------------------------------------------------------------------


def _mini_snap(pane_index):
    """The smallest valid snapshot: distinct pane_index tags each save."""
    return {
        "version": checkpoint.SNAPSHOT_VERSION,
        "pane_index": pane_index,
        "total_comm_bytes": 0,
        "total_dropped": 0,
        "total_passes": 0,
        "registrations": [],
    }


def test_checkpoint_rotation_keeps_last_k(tmp_path):
    path = tmp_path / "sess.npz"
    for i in range(5):
        checkpoint.save(_mini_snap(i), path, keep_last=3)
    # newest at path, older generations at .1/.2, nothing beyond the budget
    for age, expected in ((0, 4), (1, 3), (2, 2)):
        rotated = checkpoint.rotation_path(path, age)
        assert checkpoint.load(rotated)["pane_index"] == expected
    assert not (tmp_path / "sess.npz.3").exists()


def test_checkpoint_rotation_budget_shrink_prunes(tmp_path):
    path = tmp_path / "sess.npz"
    for i in range(4):
        checkpoint.save(_mini_snap(i), path, keep_last=4)
    assert (tmp_path / "sess.npz.3").exists()
    # shrinking the budget prunes the tail on the next save
    checkpoint.save(_mini_snap(4), path, keep_last=2)
    assert checkpoint.load(path)["pane_index"] == 4
    assert checkpoint.load(checkpoint.rotation_path(path, 1))["pane_index"] == 3
    assert not (tmp_path / "sess.npz.2").exists()
    assert not (tmp_path / "sess.npz.3").exists()


def test_checkpoint_rotation_default_is_single_file(tmp_path):
    path = tmp_path / "sess.npz"
    for i in range(3):
        checkpoint.save(_mini_snap(i), path)  # keep_last=None: no rotation
    assert checkpoint.load(path)["pane_index"] == 2
    assert not (tmp_path / "sess.npz.1").exists()
    with pytest.raises(ValueError, match="keep_last"):
        checkpoint.save(_mini_snap(9), path, keep_last=0)


def test_session_checkpoint_rotation_restorable(pipe, panes, tmp_path):
    """Session-level integration: checkpointing every pane with keep_last=2
    leaves the previous pane's snapshot restorable at rotation age 1."""
    path = tmp_path / "rot.npz"
    root = jax.random.key(11)
    sess = StreamSession(pipe)
    _register(sess)
    for i, pane in enumerate(panes[:3]):
        sess.step(jax.random.fold_in(root, i), pane)
        sess.checkpoint(path, keep_last=2)
    prev = checkpoint.load(checkpoint.rotation_path(path, 1))
    assert prev["pane_index"] == sess.pane_index - 1
    fresh = StreamSession(pipe)
    _register(fresh)
    fresh.restore(prev)
    assert fresh.pane_index == sess.pane_index - 1
    assert not (tmp_path / "rot.npz.2").exists()
