"""Stratified estimators (eqs 1-10): exactness, unbiasedness, CI coverage,
merge associativity, raw == pre-aggregated equivalence."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import estimators, sampling


def _make(rng, n=20_000, s=25, mean=40.0, sd=8.0):
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    offsets = rng.normal(0, 10, s + 1)
    vals = jnp.asarray(mean + offsets[np.asarray(sidx)] + rng.normal(0, sd, n), jnp.float32)
    return sidx, vals, s + 1


def test_full_sample_is_exact(rng):
    sidx, vals, ns = _make(rng)
    stats = estimators.sample_stats(vals, sidx, jnp.ones_like(sidx, bool), ns)
    est = estimators.estimate(stats)
    assert float(est.mean) == pytest.approx(float(vals.mean()), rel=1e-5)
    assert float(est.sum) == pytest.approx(float(vals.sum()), rel=1e-5)
    assert float(est.var_mean) == pytest.approx(0.0, abs=1e-10)  # fpc = 0


def test_unbiased_over_repeats(rng):
    sidx, vals, ns = _make(rng)
    true = float(vals.mean())
    means = []
    for t in range(40):
        res = sampling.edgesos(jax.random.key(t), sidx, ns, 0.3)
        stats = estimators.sample_stats(vals, sidx, res.mask, ns, counts=res.counts)
        means.append(float(estimators.estimate(stats).mean))
    assert np.mean(means) == pytest.approx(true, rel=2e-3)


def test_ci_coverage(rng):
    """95% CIs cover the true mean ~95% of the time."""
    sidx, vals, ns = _make(rng, n=8_000)
    true = float(vals.mean())
    cover = 0
    trials = 120
    for t in range(trials):
        res = sampling.edgesos(jax.random.key(t + 1000), sidx, ns, 0.25)
        stats = estimators.sample_stats(vals, sidx, res.mask, ns, counts=res.counts)
        est = estimators.estimate(stats, confidence=0.95)
        if float(est.ci_low) <= true <= float(est.ci_high):
            cover += 1
    rate = cover / trials
    assert 0.88 <= rate <= 1.0, f"coverage {rate}"


def test_variance_formula_against_numpy_oracle(rng):
    """Eq 6 evaluated directly in numpy matches the jitted implementation."""
    sidx, vals, ns = _make(rng, n=5_000, s=8)
    res = sampling.edgesos(jax.random.key(5), sidx, ns, 0.5)
    stats = estimators.sample_stats(vals, sidx, res.mask, ns, counts=res.counts)
    est = estimators.estimate(stats)
    sid = np.asarray(sidx)
    m = np.asarray(res.mask)
    v = np.asarray(vals)
    var_sum = 0.0
    for k in range(ns):
        Nk = (sid == k).sum()
        sel = v[(sid == k) & m]
        nk = len(sel)
        if nk > 1 and Nk > 0:
            s2 = sel.var(ddof=1)
            var_sum += Nk**2 * (1 - nk / Nk) * s2 / nk
    assert float(est.var_sum) == pytest.approx(var_sum, rel=1e-3)


def test_merge_equals_global(rng):
    """Pre-aggregated mode: merging per-edge stats == stats of the union
    (the paper's two transmission modes agree)."""
    sidx, vals, ns = _make(rng, n=12_000)
    mask = jnp.asarray(rng.random(12_000) < 0.6)
    chunks = np.array_split(np.arange(12_000), 5)
    parts = [
        estimators.sample_stats(vals[jnp.asarray(c)], sidx[jnp.asarray(c)], mask[jnp.asarray(c)], ns)
        for c in chunks
    ]
    merged = estimators.merge_all(parts)
    glob = estimators.sample_stats(vals, sidx, mask, ns)
    for a, b in zip(merged, glob):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-2)
    em, eg = estimators.estimate(merged), estimators.estimate(glob)
    assert float(em.mean) == pytest.approx(float(eg.mean), rel=1e-5)
    assert float(em.var_mean) == pytest.approx(float(eg.var_mean), rel=1e-3, abs=1e-10)


@given(perm_seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_permutation_invariance(perm_seed):
    """Estimates don't depend on tuple order."""
    rng = np.random.default_rng(42)
    sidx, vals, ns = _make(rng, n=3_000, s=6)
    mask = jnp.asarray(rng.random(3_000) < 0.5)
    perm = np.random.default_rng(perm_seed).permutation(3_000)
    pj = jnp.asarray(perm)
    a = estimators.estimate(estimators.sample_stats(vals, sidx, mask, ns))
    b = estimators.estimate(estimators.sample_stats(vals[pj], sidx[pj], mask[pj], ns))
    assert float(a.mean) == pytest.approx(float(b.mean), rel=1e-5)
    assert float(a.var_mean) == pytest.approx(float(b.var_mean), rel=1e-4, abs=1e-12)


def test_substream_sums_eq_1_2(rng):
    """Eqs (1)-(2): per-substream estimated sums add up to the global sum
    estimate when substreams cover disjoint strata."""
    s = 12
    sidx_a = jnp.asarray(rng.integers(0, 6, 4_000), jnp.int32)
    sidx_b = jnp.asarray(rng.integers(6, 12, 4_000), jnp.int32)
    vals_a = jnp.asarray(rng.normal(20, 3, 4_000), jnp.float32)
    vals_b = jnp.asarray(rng.normal(60, 3, 4_000), jnp.float32)
    ra = sampling.edgesos(jax.random.key(0), sidx_a, s + 1, 0.5)
    rb = sampling.edgesos(jax.random.key(1), sidx_b, s + 1, 0.5)
    sa = estimators.sample_stats(vals_a, sidx_a, ra.mask, s + 1, counts=ra.counts)
    sb = estimators.sample_stats(vals_b, sidx_b, rb.mask, s + 1, counts=rb.counts)
    t_hats = estimators.substream_sums([sa, sb])
    merged = estimators.merge_stats(sa, sb)
    est = estimators.estimate(merged)
    assert float(jnp.sum(t_hats)) == pytest.approx(float(est.sum), rel=1e-5)


def test_paper_toy_example():
    """Paper §3.5 toy: A samples (10,7,8) of 6 tuples, B samples (6,11) of 4;
    sums 25 and 17, grand total 42... with the HT expansion the paper
    describes: N_k * ȳ_k per node. Node A: 6 * mean(10,7,8)=50? The paper's
    arithmetic treats the *sample sums* directly (25+17=42, mean 8.4 over 5
    sampled tuples); our estimator reproduces that when N_k == n_k."""
    sidx = jnp.asarray([0, 0, 0, 1, 1], jnp.int32)
    vals = jnp.asarray([10.0, 7.0, 8.0, 6.0, 11.0], jnp.float32)
    stats = estimators.sample_stats(vals, sidx, jnp.ones(5, bool), 3)
    est = estimators.estimate(stats)
    assert float(est.sum) == pytest.approx(42.0)
    assert float(est.mean) == pytest.approx(8.4)
