"""Per-arch smoke tests (reduced configs) + model-level invariants."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs, models
from repro.models import encdec as E, transformer as T
from repro.models.encdec import EncDecBatch
from repro.models.transformer import Batch
from repro.models.linear_attention import chunked_gla, gla_step


def make_batch(cfg, rng, B=2, S=64):
    ns = cfg.data_num_strata + 1
    strata = rng.integers(0, 4, B).astype(np.int32)
    counts = np.bincount(strata, minlength=ns).astype(np.int32)
    common = dict(
        seq_weight=jnp.ones(B, jnp.float32),
        stratum=jnp.asarray(strata),
        stratum_counts=jnp.asarray(counts),
    )
    if cfg.family == "encdec":
        return EncDecBatch(
            src_embeds=jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32),
            tgt_tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            src_positions=jnp.broadcast_to(jnp.arange(S), (B, S)),
            tgt_positions=jnp.broadcast_to(jnp.arange(S), (B, S)),
            **common,
        )
    tokens = (
        jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
        if cfg.embeddings_in
        else jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    )
    positions = (
        jnp.broadcast_to(jnp.arange(S), (3, B, S))
        if cfg.mrope_sections
        else jnp.broadcast_to(jnp.arange(S), (B, S))
    )
    return Batch(
        tokens=tokens,
        targets=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        positions=positions,
        **common,
    )


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_smoke_forward_and_decode(arch, rng):
    """One loss eval + one decode step per arch: shapes + finiteness."""
    cfg = configs.get_smoke_config(arch)
    params = models.init_params(jax.random.key(0), models.param_specs(cfg))
    batch = make_batch(cfg, rng)
    loss, metrics = jax.jit(lambda p, b: models.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) < 2.0 * np.log(cfg.vocab_size)
    assert np.isfinite(float(metrics["stratified_loss_mean"]))
    if cfg.family == "encdec":
        mem = E.encode(params, cfg, batch.src_embeds, batch.src_positions)
        st = E.init_decode_state(params, cfg, mem, max_len=8)
        logits, st2 = E.decode_step(params, cfg, st, jnp.zeros(2, jnp.int32))
    else:
        st = T.init_decode_state(cfg, 2, 8)
        toks = (
            jnp.zeros((2, cfg.d_model), jnp.float32) if cfg.embeddings_in else jnp.zeros(2, jnp.int32)
        )
        logits, st2 = T.decode_step(params, cfg, st, toks)
        assert int(st2.pos) == 1
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[:, : cfg.vocab_size])))


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "xlstm-1.3b", "zamba2-7b"])
def test_prefill_matches_stepwise_decode(arch, rng):
    """Decoding token-by-token equals the parallel (chunked) forward:
    logits at position t from prefill(t tokens) == decode chain.
    f32 so recurrent-accumulation noise doesn't mask real bugs."""
    cfg = configs.get_smoke_config(arch).replace(chunk_size=8, dtype=jnp.float32)
    params = models.init_params(jax.random.key(0), models.param_specs(cfg))
    S = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S)), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S), (1, S))
    logits_p, _ = T.prefill(params, cfg, toks, pos)
    st = T.init_decode_state(cfg, 1, S)
    logits_d = None
    for t in range(S):
        logits_d, st = T.decode_step(params, cfg, st, toks[:, t])
    np.testing.assert_allclose(
        np.asarray(logits_p[:, : cfg.vocab_size]),
        np.asarray(logits_d[:, : cfg.vocab_size]),
        rtol=1e-4,
        atol=1e-4,
    )


def test_chunked_gla_matches_step_recurrence(rng):
    """Chunked parallel form == sequential recurrence (oracle)."""
    B, S, H, dk, dv = 2, 64, 3, 8, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dv)), jnp.float32)
    g = jnp.asarray(-np.abs(rng.normal(0.3, 0.3, (B, S, H))), jnp.float32)
    y_chunk, s_chunk = chunked_gla(q, k, v, g, chunk_size=16)
    state = jnp.zeros((B, H, dk, dv))
    ys = []
    for t in range(S):
        y_t, state = gla_step(state, q[:, t], k[:, t], v[:, t], g[:, t])
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(state), rtol=2e-4, atol=2e-4)


def test_chunked_gla_normalized_mode(rng):
    B, S, H, d = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, d)), jnp.float32)
    g = jnp.asarray(-np.abs(rng.normal(0.2, 0.2, (B, S, H))), jnp.float32)
    y_chunk, s_c = chunked_gla(q, k, v, g, chunk_size=8, normalize=True)
    state = jnp.zeros((B, H, d, d + 1))
    ys = []
    for t in range(S):
        y_t, state = gla_step(state, q[:, t], k[:, t], v[:, t], g[:, t], normalize=True)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(jnp.stack(ys, 1)), rtol=3e-4, atol=3e-4)


def test_weighted_loss_reduces_to_plain_ce(rng):
    """With unit weights the HT-weighted loss equals plain mean CE."""
    from repro.models.layers import weighted_ce

    logits = jnp.asarray(rng.normal(0, 1, (4, 16, 64)), jnp.float32)
    targets = jnp.asarray(rng.integers(0, 64, (4, 16)), jnp.int32)
    loss_w, _ = weighted_ce(logits, targets, jnp.ones(4), None)
    lse = jax.scipy.special.logsumexp(logits, -1)
    tgt = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    assert float(loss_w) == pytest.approx(float(jnp.mean(lse - tgt)), rel=1e-6)


def test_param_counts_match_arch_names():
    """Full configs land near their nameplate parameter counts."""
    from repro.launch.dryrun import count_params

    expect = {
        "mistral-large-123b": (110e9, 135e9),
        "deepseek-67b": (60e9, 72e9),
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "zamba2-7b": (6e9, 9e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "seamless-m4t-large-v2": (1.2e9, 2.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(configs.get_config(arch))["total"]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
