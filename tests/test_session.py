"""Continuous-query sessions: fusion exactness (property-tested), pane-based
sliding/hopping windows, vectorized per-query QoS, and drop accounting."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    SLO,
    StreamSession,
    WindowSpec,
    estimators,
    feedback,
    fuse,
    fusion_key,
    make_table,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

WINDOW = 16_000
PANE = 8_000


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def pipe(table):
    return EdgeCloudPipeline(table, PipelineConfig(raw_capacity=WINDOW))


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=1, seed=0)
    return next(windows.count_windows(stream, WINDOW))


@pytest.fixture(scope="module")
def panes():
    stream = shenzhen_taxi_stream(num_chunks=3, seed=1)
    return list(windows.count_windows(stream, PANE))[:6]


# A workload of concurrent queries: indices 0-3 and 6 share the default
# sampling signature (one fusion group); 4 (raw mode) and 5 (bernoulli) each
# get their own group.  Distinct aggs/group-by/confidence fuse freely — the
# quantile query (6) rides the same pass, adding only its sketch states.
POOL = (
    Query(aggs=(AggSpec("mean", "value"), AggSpec("max", "value"))),
    Query(aggs=(AggSpec("sum", "value"), AggSpec("var", "value")), confidence=0.9),
    Query(
        aggs=(AggSpec("mean", "occupancy"), AggSpec("count", "value")),
        group_by="neighborhood",
    ),
    Query(aggs=(AggSpec("min", "occupancy"),), group_by="stratum"),
    Query(aggs=(AggSpec("mean", "value"),), mode="raw"),
    Query(aggs=(AggSpec("mean", "value"), AggSpec("count", "value")), method="bernoulli"),
    Query(aggs=(AggSpec("p99", "value"), AggSpec("p50", "occupancy"))),
)


# -- fusion correctness -------------------------------------------------------


@settings(deadline=None, max_examples=10)
@given(mask=st.integers(min_value=1, max_value=2 ** len(POOL) - 1))
def test_fusion_matches_independent_execute(pipe, window, mask):
    """For any registered QuerySet, session estimates are elementwise-
    identical (same PRNG key) to executing each query independently — in
    preagg and raw modes, grouped and global, across sampling methods."""
    queries = [q for i, q in enumerate(POOL) if mask >> i & 1]
    sess = StreamSession(pipe, initial_fraction=0.6)
    regs = [sess.register(q) for q in queries]
    key = jax.random.key(11)
    step = sess.step(key, window)
    for q, reg in zip(queries, regs):
        ind = pipe.execute(q, key, window, 0.6)
        got = step.results[reg.qid]
        for spec in q.aggs:
            for field in ("value", "moe", "ci_low", "ci_high", "n", "population"):
                a = np.asarray(getattr(ind.estimates[spec.key], field))
                b = np.asarray(getattr(got.estimates[spec.key], field))
                np.testing.assert_array_equal(a, b, err_msg=f"{spec.key}.{field}")
        assert int(got.n_sampled) == int(ind.n_sampled)
        assert int(got.n_valid) == int(ind.n_valid)
        assert int(got.n_overflow) == int(ind.n_overflow)


def test_fusion_shares_one_pass_and_uplink(pipe, window):
    """Signature-compatible queries form ONE fusion group: a single pass
    whose uplink payload is far below the N independent payloads."""
    queries = POOL[:4]
    sess = StreamSession(pipe, initial_fraction=0.6)
    for q in queries:
        sess.register(q)
    assert len(sess._groups()) == 1
    key = jax.random.key(0)
    step = sess.step(key, window)
    independent = sum(
        int(pipe.execute(q, key, window, 0.6).comm_bytes) for q in queries
    )
    assert step.comm_bytes < independent
    # the full pool spans three sampling signatures -> three groups
    sess_all = StreamSession(pipe, initial_fraction=0.6)
    for q in POOL:
        sess_all.register(q)
    assert len(sess_all._groups()) == 3


def test_fuse_unions_and_rejects_mismatch(pipe, table):
    plans = [pipe.plan(q) for q in POOL[:4]]
    fused = fuse(plans)
    assert fused.columns == ("value", "occupancy")
    assert set(fused.extrema_columns) == {"value", "occupancy"}
    assert fused.shared.query.mode == "preagg"
    # accumulator-field union covers every member's finalize inputs
    acc = dict(fused.shared.accumulators)
    for p in plans:
        for k, fields in p.accumulators:
            assert set(fields) <= set(acc[k])
    with pytest.raises(ValueError, match="sampling signatures"):
        fuse([pipe.plan(POOL[0]), pipe.plan(POOL[5])])
    assert fusion_key(pipe.plan(POOL[0])) == fusion_key(pipe.plan(POOL[1]))
    assert fusion_key(pipe.plan(POOL[0])) != fusion_key(pipe.plan(POOL[4]))


def test_register_unregister_lifecycle(pipe, window):
    sess = StreamSession(pipe, initial_fraction=0.5)
    r1 = sess.register(POOL[0])
    r2 = sess.register(POOL[2])
    step = sess.step(jax.random.key(0), window)
    assert set(step.results) == {r1.qid, r2.qid}
    sess.unregister(r1)
    step = sess.step(jax.random.key(1), window)
    assert set(step.results) == {r2.qid}
    sess.unregister(r2)
    with pytest.raises(ValueError, match="no registered queries"):
        sess.step(jax.random.key(2), window)


# -- pane-based sliding / hopping windows -------------------------------------


def _concat(panes):
    cat = {
        f: np.concatenate([getattr(p, f) for p in panes])
        for f in ("sensor_id", "timestamp", "lat", "lon", "value", "valid")
    }
    extra = {k: np.concatenate([p.extra[k] for p in panes]) for k in panes[0].extra}
    return windows.WindowBatch(**cat, extra=extra)


def test_sliding_window_equals_tumbling_span(pipe, panes):
    """Pane-merge exactness: at full fraction a sliding window's estimate
    equals the tumbling estimate over the same tuple span."""
    q = Query(
        aggs=(AggSpec("mean", "value"), AggSpec("max", "value"), AggSpec("count", "value"))
    )
    sess = StreamSession(pipe, initial_fraction=1.0)
    reg = sess.register(q, window=WindowSpec("sliding", size=3))
    history = sess.run(panes[:3], key=jax.random.key(0))
    assert all(reg.qid in s.results for s in history)  # sliding emits every pane
    res = history[-1].results[reg.qid]
    ind = pipe.execute(q, jax.random.key(9), _concat(panes[:3]), 1.0)
    for spec in q.aggs:
        a = float(np.asarray(ind.estimates[spec.key].value))
        b = float(np.asarray(res.estimates[spec.key].value))
        assert b == pytest.approx(a, rel=1e-5), spec.key
    assert int(res.n_valid) == int(ind.n_valid)
    # partial windows at the start cover only the panes seen so far
    assert int(history[0].results[reg.qid].n_valid) == PANE


def test_sliding_quantile_equals_tumbling_span(pipe, panes):
    """Quantile panes merge exactly: summed sketch bins across a sliding
    window's panes equal one accumulation over the concatenated span, so the
    sliding p50/p99 match the one-shot execute bit-for-bit at full fraction."""
    q = Query(aggs=(AggSpec("p50", "value"), AggSpec("p99", "value")))
    sess = StreamSession(pipe, initial_fraction=1.0)
    reg = sess.register(q, window=WindowSpec("sliding", size=3))
    history = sess.run(panes[:3], key=jax.random.key(0))
    res = history[-1].results[reg.qid]
    ind = pipe.execute(q, jax.random.key(9), _concat(panes[:3]), 1.0)
    for key in ("p50_value", "p99_value"):
        a = float(np.asarray(ind.estimates[key].value))
        b = float(np.asarray(res.estimates[key].value))
        assert b == pytest.approx(a, rel=1e-6), key


def test_vectorized_pane_merge_matches_sequential(rng):
    """merge_column_stats_panes == folding merge_column_stats, exactly for
    count/extrema and to fp tolerance for the moments."""
    parts = []
    for _ in range(4):
        sidx = jnp.asarray(rng.integers(0, 12, 3_000), jnp.int32)
        vals = jnp.asarray(rng.normal(30, 9, 3_000), jnp.float32)
        mask = jnp.asarray(rng.random(3_000) < 0.5)
        parts.append(estimators.column_stats(vals, sidx, mask, 13))
    seq = estimators.merge_all_columns(parts)
    vec = estimators.merge_column_stats_panes(estimators.stack_column_stats(parts))
    np.testing.assert_array_equal(np.asarray(vec.n), np.asarray(seq.n))
    np.testing.assert_array_equal(np.asarray(vec.total), np.asarray(seq.total))
    np.testing.assert_array_equal(np.asarray(vec.min), np.asarray(seq.min))
    np.testing.assert_array_equal(np.asarray(vec.max), np.asarray(seq.max))
    np.testing.assert_allclose(np.asarray(vec.wsum), np.asarray(seq.wsum), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(vec.mean), np.asarray(seq.mean), rtol=2e-5)
    np.testing.assert_allclose(np.asarray(vec.m2), np.asarray(seq.m2), rtol=2e-4, atol=2e-2)


def test_hopping_emission_cadence(pipe, panes):
    """size=3 stride=2: emit on panes 2,4,6; each window spans the last
    min(3, seen) panes."""
    q = Query(aggs=(AggSpec("mean", "value"),))
    sess = StreamSession(pipe, initial_fraction=0.5)
    reg = sess.register(q, window=WindowSpec("hopping", size=3, stride=2))
    history = sess.run(panes, key=jax.random.key(4))
    assert [reg.qid in s.results for s in history] == [False, True] * 3
    spans = [2, 3, 3]  # panes covered at emits 2, 4, 6
    emitted = [s.results[reg.qid] for s in history if reg.qid in s.results]
    for res, span in zip(emitted, spans):
        assert int(res.n_valid) == span * PANE


def test_tumbling_multi_pane(pipe, panes):
    q = Query(aggs=(AggSpec("mean", "value"),))
    sess = StreamSession(pipe, initial_fraction=0.5)
    reg = sess.register(q, window=WindowSpec("tumbling", size=2))
    history = sess.run(panes[:4], key=jax.random.key(5))
    assert [reg.qid in s.results for s in history] == [False, True, False, True]
    for s in history:
        if reg.qid in s.results:
            assert int(s.results[reg.qid].n_valid) == 2 * PANE


def test_window_spec_validation():
    assert WindowSpec().stride == 1  # tumbling 1-pane default
    assert WindowSpec("tumbling", size=3).stride == 3
    assert WindowSpec("sliding", size=4).stride == 1
    with pytest.raises(ValueError, match="kind"):
        WindowSpec("session", size=2)
    with pytest.raises(ValueError, match="size"):
        WindowSpec(size=0)
    with pytest.raises(ValueError, match="stride"):
        WindowSpec("hopping", size=4)  # hopping needs explicit stride
    with pytest.raises(ValueError, match="stride == size"):
        WindowSpec("tumbling", size=3, stride=1)
    with pytest.raises(ValueError, match="stride == 1"):
        WindowSpec("sliding", size=3, stride=2)
    with pytest.raises(ValueError, match="skip panes"):
        WindowSpec("hopping", size=2, stride=5)


def test_query_method_validation():
    """Unknown Query.method fails at construction with the allowed set, not
    deep inside sampling.edgesos at trace time."""
    with pytest.raises(ValueError, match="srs|bernoulli|neyman"):
        Query(aggs=(AggSpec("mean", "value"),), method="reservoir")


# -- vectorized per-query QoS -------------------------------------------------


def test_per_query_fractions_diverge_and_refine_to_own_fraction(pipe, panes):
    """One fraction per registered query: a tight-SLO query's fraction stays
    above a loose-SLO query's, and once the fractions diverge the shared
    pass *refines* each member to its own fraction (nested subsampling) —
    the loose query's realized sample shrinks to what its controller asked
    for instead of free-riding the group max."""
    q_loose = Query(aggs=(AggSpec("mean", "value"),))
    q_tight = Query(aggs=(AggSpec("mean", "value", name="tight_mean"),))
    sess = StreamSession(pipe, initial_fraction=0.6)
    r_loose = sess.register(q_loose, slo=SLO(target_relative_error=0.5, min_fraction=0.02))
    r_tight = sess.register(q_tight, slo=SLO(target_relative_error=0.001))
    history = sess.run(panes[:4], key=jax.random.key(6))
    assert r_loose.fraction < 0.6  # loose SLO released its fraction
    assert r_tight.fraction > r_loose.fraction
    last = history[-1]
    n_loose = int(last.results[r_loose.qid].n_sampled)
    n_tight = int(last.results[r_tight.qid].n_sampled)
    n_valid = int(last.results[r_tight.qid].n_valid)
    # still ONE fusion group (one pass per pane), but per-member samples
    assert len(sess._groups()) == 1
    assert n_loose < n_tight
    # each member's realized sample tracks its own controller fraction (the
    # fractions recorded in the step are post-update; compare against a
    # loose proportional band)
    assert n_loose <= 0.5 * n_tight
    assert n_tight == pytest.approx(n_valid * max(r.fraction for r in (r_loose, r_tight)), rel=0.1)
    # nested: the loose member's downstream volume shrank accordingly
    assert r_loose.downstream_tuples < r_tight.downstream_tuples


def test_latency_budget_caps_session_fraction(pipe, panes):
    """SLO.max_downstream_tuples caps f·N through the vectorized controller:
    even an impossible error target cannot push the fraction past cap/N."""
    q = Query(aggs=(AggSpec("mean", "value"),))
    sess = StreamSession(pipe, initial_fraction=0.9)
    reg = sess.register(
        q, slo=SLO(target_relative_error=1e-5, max_downstream_tuples=1_000, min_fraction=0.01)
    )
    sess.run(panes[:2], key=jax.random.key(7))
    assert reg.fraction <= 1_000 / PANE + 1e-6


def test_update_vector_matches_scalar_and_masks_inactive():
    """The vectorized controller is elementwise the scalar controller; the
    latency-budget cap applies per entry and inactive entries are frozen."""
    slos = [
        SLO(target_relative_error=0.1),
        SLO(target_relative_error=0.01, max_downstream_tuples=2_000),
        SLO(target_relative_error=0.05),
    ]
    state = feedback.init_vector_state([0.5, 0.5, 0.5])
    re = jnp.asarray([0.02, 0.2, 0.05], jnp.float32)
    n = jnp.asarray([10_000.0, 20_000.0, 10_000.0], jnp.float32)
    new = feedback.update_vector(
        state, re, n, feedback.stack_slos(slos), jnp.asarray([True, True, False])
    )
    # entry 0 == scalar controller on the same observation
    s0 = feedback.update(
        feedback.init_state(0.5), jnp.float32(0.02), jnp.int32(10_000), slos[0]
    )
    assert float(new.fraction[0]) == pytest.approx(float(s0.fraction), abs=1e-7)
    # entry 1: analytic raise capped by the downstream budget 2000/20000
    assert float(new.fraction[1]) == pytest.approx(0.1, abs=1e-6)
    # entry 2 inactive: untouched
    assert float(new.fraction[2]) == 0.5
    assert int(new.steps[2]) == 0 and int(new.steps[0]) == 1


def test_session_no_error_bounded_agg_holds_fraction(pipe, panes):
    """A registered query with only point-estimate aggregates cannot drive
    QoS even with an SLO attached — its fraction must stay fixed."""
    q = Query(aggs=(AggSpec("count", "value"), AggSpec("max", "value")))
    sess = StreamSession(pipe, initial_fraction=0.4)
    reg = sess.register(q, slo=SLO(target_relative_error=0.01))
    history = sess.run(panes[:3], key=jax.random.key(8))
    assert [s.fractions[reg.qid] for s in history] == [0.4] * 3
    assert reg.steps == 0


def test_session_all_groups_empty_roi_holds_fraction(pipe, panes):
    """Grouped query whose ROI covers no data: every group's RE is inf and
    the controller holds the fraction (the all-infinite branch)."""
    q = Query(
        aggs=(AggSpec("mean", "value"),),
        group_by="neighborhood",
        roi=((0.0, 1.0), (0.0, 1.0)),  # far outside the city
    )
    sess = StreamSession(pipe, initial_fraction=0.5)
    reg = sess.register(q, slo=SLO(target_relative_error=0.1))
    history = sess.run(panes[:2], key=jax.random.key(9))
    assert [s.fractions[reg.qid] for s in history] == pytest.approx([0.5, 0.5])


# -- drop accounting ----------------------------------------------------------


def test_time_pane_drop_accounting(pipe):
    """Bounded-capacity time panes surface their shed-tuple count, and the
    session accumulates it into its diagnostics."""
    stream = shenzhen_taxi_stream(num_chunks=3, chunk_size=5_000, seed=3)
    panes = list(windows.pane_windows(stream, pane_seconds=60.0, capacity=2_000))
    assert panes and all(p.capacity == 2_000 for p in panes)
    assert sum(p.n_dropped for p in panes) > 0  # 60s of stream >> 2000 tuples
    sess = StreamSession(pipe, initial_fraction=0.5)
    sess.register(Query(aggs=(AggSpec("mean", "value"),)))
    history = sess.run(panes, key=jax.random.key(1))
    assert [s.n_dropped for s in history] == [p.n_dropped for p in panes]
    assert sess.total_dropped == sum(p.n_dropped for p in panes)


def test_count_windows_never_drop():
    stream = shenzhen_taxi_stream(num_chunks=1, chunk_size=6_000, seed=0)
    for w in windows.count_windows(stream, 3_000):
        assert w.n_dropped == 0


def test_pane_windows_validation():
    with pytest.raises(ValueError, match="exactly one"):
        windows.pane_windows(iter(()), pane_tuples=10, pane_seconds=1.0)
    with pytest.raises(ValueError, match="capacity"):
        windows.pane_windows(iter(()), pane_seconds=1.0)
