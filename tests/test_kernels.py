"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels.edge_reduce.edge_reduce import edge_reduce_pallas
from repro.kernels.edge_reduce.ops import edge_reduce_percol
from repro.kernels.edge_reduce.ref import edge_reduce_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.geohash import geohash_encode
from repro.kernels.geohash.ref import encode_ref
from repro.kernels.sample_mask import sample_mask
from repro.kernels.sample_mask.ref import sample_mask_ref
from repro.kernels.stratified_stats import stratified_stats
from repro.kernels.stratified_stats.ref import stratified_stats_ref


@pytest.mark.parametrize("n", [17, 2048, 5000])
@pytest.mark.parametrize("precision", [4, 5, 6])
def test_geohash_kernel(rng, n, precision):
    lat = jnp.asarray(rng.uniform(-89, 89, n), jnp.float32)
    lon = jnp.asarray(rng.uniform(-179, 179, n), jnp.float32)
    got = geohash_encode(lat, lon, precision)
    ref = encode_ref(lat, lon, precision)
    assert got.dtype == jnp.uint32
    assert bool(jnp.all(got == ref))


@pytest.mark.parametrize("n,s", [(100, 7), (4096, 512), (20000, 1300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stratified_stats_kernel(rng, n, s, dtype):
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    vals = jnp.asarray(rng.normal(10, 3, n), dtype)
    mask = jnp.asarray(rng.random(n) < 0.7)
    got = stratified_stats(sidx, vals, mask, s)
    ref = stratified_stats_ref(sidx, vals, mask, s)
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=2e-3, atol=0.3)


@pytest.mark.parametrize("n,s", [(100, 9), (10000, 600), (30000, 1024)])
def test_sample_mask_kernel(rng, n, s):
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    frac = jnp.asarray(rng.uniform(0.05, 1.0, s), jnp.float32)
    u = jnp.asarray(rng.random(n), jnp.float32)
    gm, gw = sample_mask(sidx, u, frac)
    rm, rw = sample_mask_ref(sidx, u, frac)
    assert bool(jnp.all(gm == rm))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5)


def _edge_reduce_case(n, c, s, seed, mask_mode):
    rng = np.random.default_rng(seed)
    # always hit the overflow stratum s-1 when there is room for it
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    if s > 1 and n > 1:
        sidx = sidx.at[0].set(s - 1)
    vals = jnp.asarray(rng.normal(25, 8, (c, n)), jnp.float32)
    if mask_mode == "all":
        mask = jnp.ones(n, bool)
    elif mask_mode == "none":
        mask = jnp.zeros(n, bool)  # all-masked window: every output zero
    else:
        mask = jnp.asarray(rng.random(n) < 0.6)
    return sidx, vals, mask


@given(
    n=st.integers(1, 1300),  # straddles the 512-point block boundary
    c=st.integers(1, 5),
    s=st.integers(1, 40),
    seed=st.integers(0, 2**30),
    mask_mode=st.sampled_from(["random", "all", "none"]),
)
@settings(max_examples=20, deadline=None)
def test_edge_reduce_kernel_parity(n, c, s, seed, mask_mode):
    """Fused multi-column kernel (interpret mode) == the single-pass
    segment oracle, across non-block-multiple N, the overflow stratum, and
    all-masked windows."""
    sidx, vals, mask = _edge_reduce_case(n, c, s, seed, mask_mode)
    got = edge_reduce_pallas(sidx, vals, mask, s, interpret=True)
    ref = edge_reduce_ref(sidx, vals, mask, s)
    for g, r, name in zip(got, ref, ("count", "s1", "s2")):
        assert g.shape == r.shape, name
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(r), rtol=2e-6, atol=1e-3, err_msg=name
        )
    if mask_mode == "none":
        for g in got:
            assert not np.asarray(g).any()


def test_edge_reduce_multi_block_strata(rng):
    """S > S_BLOCK exercises the strata grid dimension of the kernel."""
    n, c, s = 5_000, 3, 1_300
    sidx = jnp.asarray(rng.integers(0, s, n), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 50, (c, n)), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.5)
    got = edge_reduce_pallas(sidx, vals, mask, s, interpret=True)
    ref = edge_reduce_ref(sidx, vals, mask, s)
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=2e-6, atol=5e-2)


def test_edge_reduce_ref_equals_percol(rng):
    """The stacked single-pass oracle reproduces the per-column segment
    path — the fused backend changes the schedule, not the sums."""
    sidx = jnp.asarray(rng.integers(0, 37, 8_000), jnp.int32)
    vals = jnp.asarray(rng.normal(10, 3, (4, 8_000)), jnp.float32)
    mask = jnp.asarray(rng.random(8_000) < 0.7)
    a = edge_reduce_ref(sidx, vals, mask, 37)
    b = edge_reduce_percol(sidx, vals, mask, 37)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-4)


def test_edge_reduce_generalizes_stratified_stats(rng):
    """C=1 edge_reduce == the original single-column stratified_stats."""
    sidx = jnp.asarray(rng.integers(0, 50, 4_096), jnp.int32)
    vals = jnp.asarray(rng.normal(5, 2, 4_096), jnp.float32)
    mask = jnp.asarray(rng.random(4_096) < 0.8)
    cnt, s1, s2 = edge_reduce_pallas(sidx, vals[None, :], mask, 50, interpret=True)
    r_cnt, r_s1, r_s2 = stratified_stats_ref(sidx, vals, mask, 50)
    np.testing.assert_allclose(np.asarray(cnt), np.asarray(r_cnt), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1[0]), np.asarray(r_s1), rtol=2e-6, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s2[0]), np.asarray(r_s2), rtol=2e-6, atol=1e-2)


@pytest.mark.parametrize(
    "B,S,H,K,dh",
    [
        (1, 256, 4, 4, 64),  # MHA
        (2, 512, 8, 2, 64),  # GQA
        (1, 512, 8, 1, 128),  # MQA
        (1, 256, 4, 4, 112),  # zamba head_dim (padded to 128 internally)
        (1, 300, 4, 2, 64),  # ragged seq (padded internally)
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(rng, B, S, H, K, dh, dtype):
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, K, dh)), dtype)
    got = flash_attention(q, k, v).astype(jnp.float32)
    ref = flash_attention_ref(q, k, v).astype(jnp.float32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=tol, rtol=tol)


def test_flash_attention_matches_model_layer(rng):
    """Kernel agrees with the model's chunked-causal attention path."""
    from repro.models.layers import chunked_causal_attention

    q = jnp.asarray(rng.normal(0, 1, (2, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 512, 2, 64)), jnp.float32)
    a = flash_attention(q, k, v)
    b = chunked_causal_attention(q, k, v, q_chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5, rtol=3e-5)
