"""Gradient compression: unbiasedness + error feedback + convergence."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.train import compression


def test_randomk_unbiased(rng):
    g = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)}
    st = compression.init_state(g)
    acc = jnp.zeros((64, 64))
    trials = 400
    for t in range(trials):
        c, _ = compression.compress_randomk(jax.random.key(t), g, 0.25, st, unbiased=True)
        acc = acc + c["w"]
    np.testing.assert_allclose(np.asarray(acc / trials), np.asarray(g["w"]), atol=0.75)
    # mean absolute deviation well below a null (zero) estimator's
    mad = float(jnp.mean(jnp.abs(acc / trials - g["w"])))
    assert mad < 0.2


def test_error_feedback_recovers_dropped_mass(rng):
    """Sum of compressed outputs over steps approaches the sum of inputs
    (residual reinjection)."""
    g = {"w": jnp.asarray(rng.normal(0, 1, (32, 32)), jnp.float32)}
    st = compression.init_state(g)
    total = jnp.zeros((32, 32))
    steps = 200
    for t in range(steps):
        c, st = compression.compress_randomk(jax.random.key(t), g, 0.2, st)
        total = total + c["w"]
    # with EF, total == steps*g - r_T exactly; residual is bounded (~g/p)
    err = np.asarray(total / steps) - np.asarray(g["w"])
    np.testing.assert_allclose(err, np.asarray(st.residual["w"]) / -steps, atol=1e-4)
    assert np.abs(err).max() < 0.4


def test_int8_roundtrip_error_bounded(rng):
    g = {"w": jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32)}
    st = compression.init_state(g)
    q, scales, st2 = compression.compress_int8(jax.random.key(0), g, st)
    deq = compression.decompress_int8(q, scales)
    scale = float(scales[0])
    assert np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max() <= scale * 1.01
    # residual holds the rounding error
    np.testing.assert_allclose(
        np.asarray(st2.residual["w"]), np.asarray(g["w"]) - np.asarray(deq["w"]), rtol=1e-5
    )


def test_sgd_with_compression_converges(rng):
    """Toy quadratic: compressed-gradient SGD with EF reaches the optimum."""
    target = jnp.asarray(rng.normal(0, 1, (16,)), jnp.float32)
    x = jnp.zeros(16)
    st = compression.init_state({"x": x})
    for t in range(300):
        grad = {"x": 2 * (x - target)}
        c, st = compression.compress_randomk(jax.random.key(t), grad, 0.3, st)
        x = x - 0.05 * c["x"]
    assert float(jnp.max(jnp.abs(x - target))) < 0.05
