"""Feedback controller convergence, window semantics, spatial routing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    SLO,
    contiguous_plan,
    balanced_plan,
    feedback,
    make_table,
    routing,
    windows,
    SHENZHEN_BBOX,
)
from repro.core.pipeline import EdgeCloudPipeline
from repro.data.streams import shenzhen_taxi_stream


def test_controller_closed_loop_converges(rng):
    """Running the real pipeline under the controller drives RE to the SLO
    (or the fraction to a bound)."""
    table = make_table(*SHENZHEN_BBOX, precision=5)
    pipe = EdgeCloudPipeline(table)
    slo = SLO(target_relative_error=0.002, min_fraction=0.02, max_fraction=1.0)
    wnds = list(windows.count_windows(shenzhen_taxi_stream(num_chunks=8, seed=2), 20_000))
    history, state = pipe.run_stream(wnds, slo=slo, initial_fraction=0.5)
    res = [float(h[0].estimate.relative_error) for h in history]
    fr = [h[1] for h in history]
    # controller should move fraction and keep late-window RE near target
    late = np.mean(res[-3:])
    assert late < 0.004 or fr[-1] == pytest.approx(1.0)
    assert not np.allclose(fr, fr[0])


def test_controller_lowers_fraction_when_easy():
    st = feedback.init_state(0.9)
    slo = SLO(target_relative_error=0.1, min_fraction=0.05)
    for _ in range(6):
        st = feedback.update(st, jnp.float32(0.001), jnp.int32(10_000), slo)
    assert float(st.fraction) < 0.3


def test_controller_raises_fraction_when_hard():
    st = feedback.init_state(0.2)
    slo = SLO(target_relative_error=0.01)
    for _ in range(6):
        st = feedback.update(st, jnp.float32(0.2), jnp.int32(10_000), slo)
    assert float(st.fraction) > 0.6


def test_latency_budget_caps_fraction():
    st = feedback.init_state(0.9)
    slo = SLO(target_relative_error=0.0001, max_downstream_tuples=1_000)
    st = feedback.update(st, jnp.float32(0.5), jnp.int32(20_000), slo)
    assert float(st.fraction) <= 0.05 + 1e-6


def test_count_windows_exact_sizes():
    wnds = list(windows.count_windows(shenzhen_taxi_stream(num_chunks=3, chunk_size=7_000), 10_000))
    assert len(wnds) == 2
    assert all(w.capacity == 10_000 and w.size == 10_000 for w in wnds)


def test_time_windows_padding():
    wnds = list(
        windows.time_windows(shenzhen_taxi_stream(num_chunks=4, chunk_size=5_000), 60.0, capacity=6_000)
    )
    assert len(wnds) >= 3
    for w in wnds:
        assert w.capacity == 6_000
        assert w.size <= 6_000
        assert np.all(w.valid[: w.size])


def test_routing_contiguous_and_balanced(rng):
    table = make_table(*SHENZHEN_BBOX, precision=5, neighborhood_precision=3)
    plan = contiguous_plan(table, num_shards=4)
    assert int(plan.dest_of_stratum.max()) <= 3
    sidx = jnp.asarray(rng.integers(0, table.num_strata, 10_000), jnp.int32)
    counts = routing.route_counts(plan, sidx)
    assert int(counts.sum()) == 10_000
    # balanced plan should not be worse than contiguous on skewed load
    load = np.zeros(table.num_neighborhoods)
    load[0] = 1000.0
    load[1] = 900.0
    bplan = balanced_plan(table, 4, load)
    d0 = int(bplan.dest_of_neighborhood[0])
    d1 = int(bplan.dest_of_neighborhood[1])
    assert d0 != d1  # heaviest two neighborhoods on different shards


def test_neighborhood_is_geohash_prefix():
    table = make_table(*SHENZHEN_BBOX, precision=6, neighborhood_precision=4)
    from repro.core import geohash as G

    codes = np.asarray(table.codes)
    parents = np.asarray(G.parent(jnp.asarray(codes), 6, 4))
    nb = np.asarray(table.neighborhood)[:-1]
    # same parent <=> same neighborhood id
    for p in np.unique(parents)[:10]:
        ids = nb[parents == p]
        assert (ids == ids[0]).all()
