"""Minimal stand-in for `hypothesis` when it is not installed.

Provides just enough of the `given/settings/strategies` surface for this
repo's property tests to run as deterministic parameter sweeps: each
strategy yields boundary values plus seeded-random draws, and ``@given``
runs the test once per drawn example.  Far weaker than real hypothesis (no
shrinking, no adaptive search) — install `hypothesis` for the real thing;
CI does.
"""

from __future__ import annotations

import inspect
import os

import numpy as np

_EXAMPLES = 10  # examples per @given when falling back


def _profile_examples() -> int:
    """Examples per @given, honoring the same ``HYPOTHESIS_PROFILE`` env
    var the real-hypothesis profiles in ``conftest.py`` use: the nightly
    soak sweeps 10x."""
    if os.environ.get("HYPOTHESIS_PROFILE") == "nightly":
        return _EXAMPLES * 10
    return _EXAMPLES


class _Strategy:
    def __init__(self, sampler):
        self._sampler = sampler

    def sample(self, rng, i):
        return self._sampler(rng, i)


class strategies:  # noqa: N801 - mimics `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        bounds = [min_value, max_value, min_value + (max_value - min_value) // 2]

        def sampler(rng, i):
            if i < len(bounds):
                return int(bounds[i])
            return int(rng.integers(min_value, max_value + 1))

        return _Strategy(sampler)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)

        def sampler(rng, i):
            if i < len(elements):
                return elements[i]
            return elements[int(rng.integers(0, len(elements)))]

        return _Strategy(sampler)

    @staticmethod
    def booleans():
        return strategies.sampled_from([False, True])

    @staticmethod
    def floats(min_value, max_value, allow_nan=False, width=64):
        bounds = [min_value, max_value, (min_value + max_value) / 2.0]

        def sampler(rng, i):
            x = bounds[i] if i < len(bounds) else float(rng.uniform(min_value, max_value))
            if width == 32:
                x = float(np.float32(x))
                # float32 rounding may step outside the closed interval
                x = min(max(x, float(np.float32(min_value))), float(np.float32(max_value)))
            return float(x)

        return _Strategy(sampler)


st = strategies


def given(**strats):
    def deco(fn):
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(0)
            limit = getattr(
                wrapper,
                "_fallback_max_examples",
                getattr(fn, "_fallback_max_examples", _profile_examples()),
            )
            for i in range(limit):
                drawn = {k: s.sample(rng, i) for k, s in strats.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception:
                    # the fallback's analogue of hypothesis print_blob: a
                    # copy-pasteable reproduction of the failing example
                    args_repr = ", ".join(f"{k}={v!r}" for k, v in drawn.items())
                    print(
                        f"\nFalsifying example (fallback, deterministic): "
                        f"{fn.__name__}({args_repr})"
                    )
                    raise

        # present a signature WITHOUT the strategy params, so pytest does
        # not go looking for fixtures named after them
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper

    return deco


def settings(*args, **kwargs):
    # honor max_examples so expensive property tests (e.g. Monte-Carlo
    # coverage sweeps) don't run the default 10 examples in fallback mode;
    # works whether @settings sits above or below @given
    max_examples = kwargs.get("max_examples")

    def deco(fn):
        if max_examples is not None:
            fn._fallback_max_examples = max_examples
        return fn

    return deco
