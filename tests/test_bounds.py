"""Error-bounds subsystem: every aggregate kind reports a (lo, hi, rel)
sampling-error interval from the shipped sufficient statistics — bootstrap
coverage (property-tested), determinism, preagg/raw and session parity,
zero width at full fraction, and graceful SLO degradation."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    SLO,
    StreamSession,
    WindowSpec,
    estimators,
    feedback,
    make_table,
    sampling,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

ALL_KINDS = ("mean", "sum", "count", "var", "min", "max", "p50", "p99")
ALL_AGGS = tuple(AggSpec(k, "value") for k in ALL_KINDS)


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def pipe(table):
    return EdgeCloudPipeline(table, PipelineConfig(raw_capacity=20_000))


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=2, seed=0)
    return next(windows.count_windows(stream, 20_000))


def _check_interval(est, key):
    lo = np.asarray(est.ci_low)
    hi = np.asarray(est.ci_high)
    val = np.asarray(est.value)
    rel = np.asarray(est.relative_error)
    moe = np.asarray(est.moe)
    assert not np.isnan(lo).any(), f"{key}: NaN ci_low"
    assert not np.isnan(hi).any(), f"{key}: NaN ci_high"
    assert not np.isnan(rel).any(), f"{key}: NaN relative_error"
    assert not np.isnan(moe).any(), f"{key}: NaN moe"
    # a NaN value is the explicit no-evidence marker (empty quantile
    # histogram): its interval is pinned to (-inf, inf) with rel = inf,
    # so containment only applies where there is a point estimate
    nan_val = np.isnan(val)
    assert np.isinf(np.asarray(rel)[nan_val]).all(), f"{key}: NaN value w/ finite rel"
    assert np.all((lo <= val + 1e-6) | nan_val), key
    assert np.all((val <= hi + 1e-6) | nan_val), key


# -- every kind, both execution paths -----------------------------------------


def test_every_kind_bounded_through_execute(pipe, window):
    """All eight aggregate kinds return a finite or explicitly-infinite
    (lo, hi, rel) triple through one-shot execute; the error-bounded
    families are finite at a healthy fraction."""
    q = Query(aggs=ALL_AGGS)
    r = pipe.execute(q, jax.random.key(3), window, fraction=0.6)
    for k in ALL_KINDS:
        _check_interval(r.estimates[f"{k}_value"], k)
    for k in ("mean", "sum", "var", "p50", "p99"):
        rel = float(r.estimates[f"{k}_value"].relative_error)
        assert np.isfinite(rel) and rel > 0, k
    assert float(r.estimates["count_value"].moe) == 0.0


def test_every_kind_bounded_through_session_panes(pipe, window):
    """The same triples flow through fused session pane emission — including
    a multi-pane sliding window (the pane-merge finalize path)."""
    sess = StreamSession(pipe, initial_fraction=0.6)
    reg1 = sess.register(Query(aggs=ALL_AGGS))
    reg2 = sess.register(
        Query(aggs=(AggSpec("var", "value"), AggSpec("p99", "value"))),
        window=WindowSpec("sliding", size=2),
    )
    steps = sess.run([window, window], key=jax.random.key(4))
    for k in ALL_KINDS:
        _check_interval(steps[-1].results[reg1.qid].estimates[f"{k}_value"], k)
    two_pane = steps[-1].results[reg2.qid]
    for key in ("var_value", "p99_value"):
        _check_interval(two_pane.estimates[key], key)
        assert np.isfinite(float(two_pane.estimates[key].relative_error)), key


def test_grouped_bounds_shapes_and_sanity(pipe, window, table):
    """Grouped queries report per-group intervals; empty groups degrade to
    explicit infinite intervals (quantiles surface a NaN *value* as the
    no-evidence marker, never a silent 0), and bound arithmetic never
    yields NaN lo/hi/rel/moe."""
    q = Query(aggs=(AggSpec("var", "value"), AggSpec("p50", "value"),
                    AggSpec("max", "value")), group_by="neighborhood")
    r = pipe.execute(q, jax.random.key(5), window, fraction=0.5)
    for key in ("var_value", "p50_value", "max_value"):
        est = r.estimates[key]
        assert np.asarray(est.value).shape == (table.num_neighborhoods,)
        _check_interval(est, key)


def test_full_fraction_zero_width(pipe, window):
    """At fraction 1 every bound collapses: the fpc/rank-slack terms vanish
    (no sampling error left to bound)."""
    q = Query(aggs=ALL_AGGS)
    r = pipe.execute(q, jax.random.key(0), window, fraction=1.0)
    for k in ALL_KINDS:
        assert float(r.estimates[f"{k}_value"].moe) == 0.0, k


def test_bounds_shrink_with_fraction(pipe, window):
    """var and quantile CI widths shrink as the fraction grows."""
    q = Query(aggs=(AggSpec("var", "value"), AggSpec("p50", "value")))
    widths = {k: [] for k in ("var_value", "p50_value")}
    for f in (0.2, 0.5, 0.9):
        r = pipe.execute(q, jax.random.key(11), window, fraction=f)
        for k in widths:
            widths[k].append(float(r.estimates[k].moe))
    for k, ws in widths.items():
        assert ws[0] > ws[1] > ws[2] > 0, (k, ws)


def test_extrema_bounds_are_one_sided_and_contain_truth(pipe, window, table):
    """min/max: the sample extreme is one endpoint, the order-statistic +
    Cantelli bound the other; the full-population extreme lies inside
    whenever the bound is finite."""
    q = Query(aggs=(AggSpec("min", "value"), AggSpec("max", "value")))
    r = pipe.execute(q, jax.random.key(6), window, fraction=0.8)
    sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
    v = window.value[sidx < table.num_strata]
    mx = r.estimates["max_value"]
    assert float(mx.ci_low) == pytest.approx(float(mx.value))
    assert float(mx.ci_high) >= v.max() - 1e-5
    mn = r.estimates["min_value"]
    assert float(mn.ci_high) == pytest.approx(float(mn.value))
    assert float(mn.ci_low) <= v.min() + 1e-5


def test_replicates_zero_disables_bootstrap(pipe, window):
    """bootstrap_replicates=0 falls back to zero-width var/quantile
    intervals (the pre-bounds behavior) without touching the values."""
    q_on = Query(aggs=(AggSpec("var", "value"), AggSpec("p50", "value")))
    q_off = Query(
        aggs=(AggSpec("var", "value"), AggSpec("p50", "value")),
        bootstrap_replicates=0,
    )
    r_on = pipe.execute(q_on, jax.random.key(2), window, fraction=0.5)
    r_off = pipe.execute(q_off, jax.random.key(2), window, fraction=0.5)
    for k in ("var_value", "p50_value"):
        assert float(r_off.estimates[k].moe) == 0.0
        assert float(r_on.estimates[k].moe) > 0.0
        assert float(r_off.estimates[k].value) == pytest.approx(
            float(r_on.estimates[k].value), rel=1e-6
        )
    with pytest.raises(ValueError, match="bootstrap_replicates"):
        Query(aggs=(AggSpec("var", "value"),), bootstrap_replicates=-1)


# -- determinism ---------------------------------------------------------------


def test_bounds_deterministic_in_key(table, window):
    """Same PRNG key => bit-identical bounds, across pipeline instances;
    a different key moves the bootstrap intervals."""
    q = Query(aggs=(AggSpec("var", "value"), AggSpec("p99", "value")))
    r1 = EdgeCloudPipeline(table).execute(q, jax.random.key(9), window, 0.5)
    r2 = EdgeCloudPipeline(table).execute(q, jax.random.key(9), window, 0.5)
    r3 = EdgeCloudPipeline(table).execute(q, jax.random.key(10), window, 0.5)
    moved = False
    for k in ("var_value", "p99_value"):
        for field in ("ci_low", "ci_high", "moe", "relative_error"):
            a = np.asarray(getattr(r1.estimates[k], field))
            b = np.asarray(getattr(r2.estimates[k], field))
            np.testing.assert_array_equal(a, b, err_msg=f"{k}.{field}")
        moved |= float(r1.estimates[k].ci_low) != float(r3.estimates[k].ci_low)
    assert moved  # the key actually seeds the bootstrap


# -- transmission-mode / session parity ---------------------------------------


def test_preagg_raw_bounds_parity_through_session(pipe, window):
    """One session, the same aggregates registered in preagg and raw modes
    (two fusion groups, same step key => identical samples): the bounds
    agree — exactly for sketch quantiles (bin counts merge exactly), to fp
    tolerance for the moment-derived families."""
    aggs = (AggSpec("var", "value"), AggSpec("p50", "value"),
            AggSpec("max", "value"), AggSpec("mean", "value"))
    sess = StreamSession(pipe, initial_fraction=0.6)
    r_pre = sess.register(Query(aggs=aggs))
    r_raw = sess.register(Query(aggs=aggs, mode="raw"))
    step = sess.step(jax.random.key(21), window)
    pre = step.results[r_pre.qid].estimates
    raw = step.results[r_raw.qid].estimates
    for spec in aggs:
        for field in ("value", "ci_low", "ci_high", "relative_error"):
            a = np.asarray(getattr(pre[spec.key], field))
            b = np.asarray(getattr(raw[spec.key], field))
            np.testing.assert_allclose(
                a, b, rtol=1e-4, atol=1e-5, err_msg=f"{spec.key}.{field}"
            )
    np.testing.assert_array_equal(
        np.asarray(pre["p50_value"].ci_low), np.asarray(raw["p50_value"].ci_low)
    )
    # and the session path reproduces one-shot execute bit-for-bit
    ind = pipe.execute(Query(aggs=aggs), jax.random.key(21), window, 0.6)
    for spec in aggs:
        for field in ("ci_low", "ci_high"):
            np.testing.assert_array_equal(
                np.asarray(getattr(pre[spec.key], field)),
                np.asarray(getattr(ind.estimates[spec.key], field)),
                err_msg=f"{spec.key}.{field}",
            )


# -- bootstrap coverage (the property the subsystem exists for) ----------------


def _skewed_population(seed, n=3_000, s=4):
    """A skewed (lognormal-mixture) stream over a few strata."""
    rng = np.random.default_rng(seed)
    sidx = rng.integers(0, s, n)
    scale = 1.0 + 0.8 * sidx
    v = rng.lognormal(mean=1.0, sigma=0.6, size=n) * scale + 0.5
    return jnp.asarray(sidx, jnp.int32), jnp.asarray(v, jnp.float32), s


@settings(deadline=None, max_examples=2)
@given(seed=st.integers(0, 10_000))
def test_bootstrap_coverage_var_and_p50(seed):
    """Empirical coverage of the 95% bootstrap CIs stays within ±5pp of
    nominal for var and p50 on skewed synthetic streams.  Truth is the
    full-population plug-in variance / sketch quantile (the estimators'
    own fraction-1 values), so only *sampling* error is scored."""
    sidx, v, s = _skewed_population(seed)
    slots = s + 1
    full = jnp.ones(v.shape, bool)
    counts = jax.ops.segment_sum(jnp.ones_like(sidx), sidx, num_segments=slots)
    mom_full = estimators.sample_stats(v, sidx, full, slots, counts=counts)
    n_f, N_f = mom_full.n, mom_full.total
    s2_f = jnp.where(n_f > 1, mom_full.m2 / jnp.maximum(n_f - 1.0, 1.0), 0.0)
    active = (n_f > 0) & (N_f > 0)
    covered = jnp.sum(jnp.where(active, N_f, 0.0))
    ey2 = jnp.sum(jnp.where(active, N_f * (s2_f + mom_full.mean**2), 0.0))
    mean_full = jnp.sum(jnp.where(active, N_f * mom_full.mean, 0.0)) / covered
    var_true = float(ey2 / covered - mean_full**2)
    bins_full = estimators.SKETCH.accumulate(v, sidx, full, slots)
    p50_true = float(estimators.sketch_quantile(jnp.sum(bins_full.bins, axis=0), 0.5))

    fraction = 0.4
    replicates = 300

    @jax.jit
    def trial(key):
        # the finalize path for a var+p50 query: moments + sketch states,
        # union'd var channels, both interval hooks
        k_samp, k_var, k_q = jax.random.split(key, 3)
        res = sampling.edgesos(k_samp, sidx, slots, fraction)
        mom = estimators.sample_stats(v, sidx, res.mask, slots, counts=res.counts)
        sk = estimators.SKETCH.accumulate(v, sidx, res.mask, slots)
        s2 = jnp.where(mom.n > 1, mom.m2 / jnp.maximum(mom.n - 1.0, 1.0), 0.0)
        act = (mom.n > 0) & (mom.total > 0)
        cov = jnp.maximum(jnp.sum(jnp.where(act, mom.total, 0.0)), 1.0)
        ey2_t = jnp.sum(jnp.where(act, mom.total * (s2 + mom.mean**2), 0.0))
        m_t = jnp.sum(jnp.where(act, mom.total * mom.mean, 0.0)) / cov
        vhat = jnp.maximum(ey2_t / cov - m_t * m_t, 0.0)  # finalize's plug-in
        vlo, vhi = estimators.MOMENTS.interval(
            mom, "var", mom, confidence=0.95, key=k_var, replicates=replicates,
            sketch=sk, center=vhat,
        )
        qlo, qhi = estimators.SKETCH.interval(
            sk, "p50", mom, q=0.5, confidence=0.95, key=k_q, replicates=replicates
        )
        return vlo, vhi, qlo, qhi

    trials = 250
    keys = jax.random.split(jax.random.key(seed), trials)
    cover_var = cover_q = 0
    for t in range(trials):
        vlo, vhi, qlo, qhi = (float(x) for x in trial(keys[t]))
        cover_var += vlo <= var_true <= vhi
        cover_q += qlo <= p50_true <= qhi
    assert 0.90 <= cover_var / trials <= 1.0, f"var coverage {cover_var / trials}"
    assert 0.90 <= cover_q / trials <= 1.0, f"p50 coverage {cover_q / trials}"


# -- singleton guard + graceful SLO degradation --------------------------------


def test_singleton_stratum_reports_infinite_not_false_zero():
    """A window whose only sampled evidence is singletons must report an
    infinite relative error (previously: moe 0 / rel 0 — false certainty
    that collapses the QoS fraction to its floor)."""
    # two strata, one sampled tuple each, populations of 5
    sidx = jnp.asarray([0, 0, 0, 0, 0, 1, 1, 1, 1, 1], jnp.int32)
    v = jnp.asarray([1.0, 2, 3, 4, 5, 10, 20, 30, 40, 50], jnp.float32)
    mask = jnp.asarray([True] + [False] * 4 + [True] + [False] * 4)
    stats = estimators.sample_stats(v, sidx, mask, 3)
    est = estimators.estimate(stats)
    assert np.isinf(float(est.moe)) and np.isinf(float(est.relative_error))
    assert not np.isnan(float(est.moe))
    # the controller holds the fraction on the non-finite observation
    state = feedback.update(
        feedback.init_state(0.5), est.relative_error, jnp.int32(10), SLO()
    )
    assert np.isfinite(float(state.fraction)) and float(state.fraction) > 0.05
    vec = feedback.update_vector(
        feedback.init_vector_state([0.5]),
        jnp.asarray([float(est.relative_error)], jnp.float32),
        jnp.asarray([10.0], jnp.float32),
        feedback.stack_slos([SLO()]),
    )
    assert np.isfinite(float(vec.fraction[0]))


def test_lonely_stratum_borrows_spread_keeps_global_finite():
    """With identified strata present, a lonely singleton borrows their
    average s² instead of zero (moe grows, stays finite) — the survey
    lonely-PSU 'average' adjustment."""
    rng = np.random.default_rng(0)
    sidx = jnp.asarray(np.concatenate([np.zeros(100), np.ones(100), [2] * 10]), jnp.int32)
    v = jnp.asarray(rng.normal(50, 10, 210), jnp.float32)
    mask = np.ones(210, bool)
    mask[100:] = rng.random(110) < 0.5
    mask[200:] = False
    mask[200] = True  # stratum 2: singleton of population 10
    stats = estimators.sample_stats(v, sidx, jnp.asarray(mask), 4)
    assert float(stats.n[2]) == 1.0
    est = estimators.estimate(stats)
    assert np.isfinite(float(est.moe)) and float(est.moe) > 0
    # removing the singleton's population lowers the variance: the guard
    # added real (borrowed) spread for stratum 2 rather than zero
    no_lonely = estimators.sample_stats(
        v[:200], sidx[:200], jnp.asarray(mask[:200]), 4
    )
    assert float(est.moe) > float(estimators.estimate(no_lonely).moe)


def test_per_stratum_means_singleton_infinite():
    """per_stratum_means: an under-sampled singleton stratum reports an
    infinite half-width; fully-sampled and n>=2 strata stay finite."""
    sidx = jnp.asarray([0, 0, 1, 2], jnp.int32)
    v = jnp.asarray([1.0, 3.0, 7.0, 9.0], jnp.float32)
    mask = jnp.asarray([True, True, True, True])
    counts = jnp.asarray([2, 5, 1, 0])  # stratum 1 under-sampled singleton
    stats = estimators.sample_stats(v, sidx, mask, 4, counts=counts)
    _, moe_k = estimators.per_stratum_means(stats)
    moe = np.asarray(moe_k)
    assert np.isfinite(moe[0])  # n=2
    assert np.isinf(moe[1])  # n=1 < N=5: unidentified, was false-zero
    assert moe[2] == 0.0  # n=1 == N=1: exact (fpc)
    assert np.isinf(moe[3])  # unsampled
    assert not np.isnan(moe).any()


def test_empty_window_var_quantile_report_infinite_rel(pipe, table):
    """A window with no sampled evidence must report RE = inf for var and
    quantiles (like mean), not a false-perfect 0 that would collapse the
    newly var/quantile-driven QoS fraction when the stream goes quiet."""
    n = 512
    win = {
        "lat": jnp.zeros(n, jnp.float32),
        "lon": jnp.zeros(n, jnp.float32),
        "valid": jnp.zeros(n, bool),  # all invalid
        "value": jnp.ones(n, jnp.float32),
    }
    q = Query(aggs=(AggSpec("mean", "value"), AggSpec("var", "value"),
                    AggSpec("p99", "value")))
    r = pipe.execute(q, jax.random.key(0), win, fraction=0.5)
    for k in ("mean_value", "var_value", "p99_value"):
        assert np.isinf(float(r.estimates[k].relative_error)), k
    # the controller holds the fraction on the non-finite observation
    sess = StreamSession(pipe, initial_fraction=0.5)
    reg = sess.register(Query(aggs=(AggSpec("p99", "value"),)),
                        slo=SLO(target_relative_error=0.05, min_fraction=0.02))
    steps = sess.run([win, win], key=jax.random.key(1))
    assert [s.fractions[reg.qid] for s in steps] == pytest.approx([0.5, 0.5])


def test_replicates_zero_query_cannot_drive_qos(pipe, window):
    """bootstrap_replicates=0 disables var/quantile bounds, so such a query
    must not drive the controller (its zero-width RE=0 would collapse the
    fraction to the floor)."""
    q = Query(aggs=(AggSpec("var", "value"),), bootstrap_replicates=0)
    sess = StreamSession(pipe, initial_fraction=0.4)
    reg = sess.register(q, slo=SLO(target_relative_error=0.01, min_fraction=0.02))
    steps = sess.run([window, window], key=jax.random.key(3))
    assert [s.fractions[reg.qid] for s in steps] == [0.4, 0.4]
    assert reg.steps == 0


def test_session_var_query_drives_qos(pipe):
    """A var-only continuous query now carries an observed RE, so its SLO
    can adapt the fraction (previously var was treated as unbounded and the
    fraction froze)."""
    stream = shenzhen_taxi_stream(num_chunks=3, seed=9)
    panes = list(windows.count_windows(stream, 8_000))[:4]
    sess = StreamSession(pipe, initial_fraction=0.9)
    reg = sess.register(
        Query(aggs=(AggSpec("var", "value"),)),
        slo=SLO(target_relative_error=0.5, min_fraction=0.02),
    )
    sess.run(panes, key=jax.random.key(1))
    assert reg.steps == len(panes)
    assert reg.fraction < 0.9  # loose SLO released the fraction
    # and a quantile query advances its controller too
    sess2 = StreamSession(pipe, initial_fraction=0.7)
    reg2 = sess2.register(
        Query(aggs=(AggSpec("p50", "value"),)),
        slo=SLO(target_relative_error=0.2, min_fraction=0.02),
    )
    sess2.run(panes, key=jax.random.key(2))
    assert reg2.steps == len(panes)
    assert reg2.fraction < 0.7
