"""Property tests for the single-traversal edge megakernel triad.

The interpreted Pallas kernel and the portable segment lowering are both
checked against the jax-free numpy oracle (``ref.py``) across both
membership modes:

* ``sidx`` mode — precomputed stratum indices, every slot (overflow
  included) covered exactly;
* ``latlon`` mode — geohash encode + sorted-code-table membership resolve
  *inside* the kernel; tuples whose cell is absent from the table land in
  no slot (their stat rows stay zero — the wrapper layer reconstructs
  overflow counts as residuals).

Sweeps cover non-block-multiple N, the overflow stratum, all-masked
windows, multi-member thresholds, ext/sketch column subsets, and bf16
value staging (f32 accumulation; parity against the pre-rounded oracle).
"""

import os

import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core.estimators import SKETCH_NUM_BINS
from repro.kernels.edge_megakernel import edge_megakernel
from repro.kernels.edge_megakernel.edge_megakernel import edge_megakernel_pallas
from repro.kernels.edge_megakernel.ops import _edge_megakernel_segment
from repro.kernels.geohash.ref import encode_ref

FIELDS = ("pop", "keep", "s1", "s2", "mins", "maxs", "bins")


def _assert_matches(got, ref, label, rtol=2e-6, atol=1e-3):
    for g, r, name in zip(tuple(got), ref, FIELDS):
        g = np.asarray(g)
        assert g.shape == np.asarray(r).shape, f"{label}:{name}"
        np.testing.assert_allclose(
            g, r, rtol=rtol, atol=atol, err_msg=f"{label}:{name}"
        )


def _sidx_case(n, m, c, s, seed, ok_mode):
    rng = np.random.default_rng(seed)
    sidx = rng.integers(0, s, (m, n)).astype(np.int32)
    if s > 1 and n > 1:
        sidx[:, 0] = s - 1  # always hit the overflow slot when possible
    vals = rng.normal(25, 8, (c, n)).astype(np.float32)
    if ok_mode == "all":
        ok = np.ones((m, n), np.float32)
    elif ok_mode == "none":
        ok = np.zeros((m, n), np.float32)  # all-masked window
    else:
        ok = (rng.random((m, n)) < 0.7).astype(np.float32)
    scores = rng.random((m, n)).astype(np.float32)
    thr = rng.uniform(0.0, 1.0, (m, s)).astype(np.float32)
    return sidx, vals, ok, scores, thr


@given(
    n=st.integers(1, 700),  # straddles the 512-point block boundary
    m=st.integers(1, 3),
    c=st.integers(1, 4),
    s=st.integers(1, 40),
    seed=st.integers(0, 2**30),
    ok_mode=st.sampled_from(["random", "all", "none"]),
)
@settings(max_examples=10, deadline=None)
def test_megakernel_sidx_parity(n, m, c, s, seed, ok_mode):
    """Interpreted kernel == numpy oracle in sidx mode across member
    counts, non-block-multiple N, the overflow stratum, and all-masked
    windows, with extrema+sketch rows on a column subset."""
    sidx, vals, ok, scores, thr = _sidx_case(n, m, c, s, seed, ok_mode)
    ext_idx = (0,) if c >= 1 else ()
    sk_idx = (c - 1,) if c >= 1 else ()
    got = edge_megakernel_pallas(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        s, sidx=jnp.asarray(sidx), ext_idx=ext_idx, sk_idx=sk_idx, interpret=True,
    )
    from repro.kernels.edge_megakernel.ref import edge_megakernel_ref

    ref = edge_megakernel_ref(
        vals, ok, scores, thr, s, sidx=sidx, ext_idx=ext_idx, sk_idx=sk_idx
    )
    _assert_matches(got, ref, f"sidx[{n},{m},{c},{s},{ok_mode}]")
    if ok_mode == "none":
        assert not np.asarray(got.keep).any()
        assert np.all(np.asarray(got.mins) == np.inf)
        assert np.all(np.asarray(got.maxs) == -np.inf)


def _latlon_case(n, m, seed, *, drop_every_other=True):
    rng = np.random.default_rng(seed)
    lat = rng.uniform(0.0, 1.0, n).astype(np.float32)
    lon = rng.uniform(0.0, 1.0, n).astype(np.float32)
    codes = np.unique(np.asarray(encode_ref(lat, lon, 4)))
    if drop_every_other and codes.shape[0] > 1:
        codes = codes[::2]  # absent cells exercise the match-nothing path
    s = int(codes.shape[0])
    vals = rng.normal(5, 3, (2, n)).astype(np.float32)
    ok = (rng.random((m, n)) < 0.8).astype(np.float32)
    scores = rng.random((m, n)).astype(np.float32)
    thr = np.broadcast_to(
        rng.uniform(0.2, 0.9, (m, 1)).astype(np.float32), (m, s)
    ).copy()
    return lat, lon, codes, s, vals, ok, scores, thr


@given(n=st.integers(1, 600), m=st.integers(1, 2), seed=st.integers(0, 2**30))
@settings(max_examples=8, deadline=None)
def test_megakernel_latlon_parity(n, m, seed):
    """Interpreted kernel == numpy oracle in latlon mode: in-kernel geohash
    encode + code-table membership, absent cells matching no slot."""
    lat, lon, codes, s, vals, ok, scores, thr = _latlon_case(n, m, seed)
    got = edge_megakernel_pallas(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        s, lat=jnp.asarray(lat), lon=jnp.asarray(lon), codes=jnp.asarray(codes),
        precision=4, ext_idx=(0,), sk_idx=(1,), interpret=True,
    )
    from repro.kernels.edge_megakernel.ref import edge_megakernel_ref

    ref = edge_megakernel_ref(
        vals, ok, scores, thr, s, lat=lat, lon=lon, codes=codes,
        precision=4, ext_idx=(0,), sk_idx=(1,),
    )
    _assert_matches(got, ref, f"latlon[{n},{m}]")


@given(seed=st.integers(0, 2**30))
@settings(max_examples=6, deadline=None)
def test_megakernel_segment_lowering_parity(seed):
    """The portable jnp lowering (what backend='fused' runs off-TPU)
    matches the oracle in both membership modes."""
    from repro.kernels.edge_megakernel.ref import edge_megakernel_ref

    sidx, vals, ok, scores, thr = _sidx_case(900, 2, 3, 25, seed, "random")
    got = _edge_megakernel_segment(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        25, sidx=jnp.asarray(sidx), ext_idx=(1,), sk_idx=(0, 2),
    )
    ref = edge_megakernel_ref(
        vals, ok, scores, thr, 25, sidx=sidx, ext_idx=(1,), sk_idx=(0, 2)
    )
    _assert_matches(got, ref, "segment/sidx")

    lat, lon, codes, s, vals, ok, scores, thr = _latlon_case(800, 2, seed)
    got = _edge_megakernel_segment(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        s, lat=jnp.asarray(lat), lon=jnp.asarray(lon), codes=jnp.asarray(codes),
        precision=4, ext_idx=(0,), sk_idx=(1,),
    )
    ref = edge_megakernel_ref(
        vals, ok, scores, thr, s, lat=lat, lon=lon, codes=codes,
        precision=4, ext_idx=(0,), sk_idx=(1,),
    )
    _assert_matches(got, ref, "segment/latlon")


def test_megakernel_bf16_staging_parity():
    """bf16-staged values accumulate in f32: the kernel matches the oracle
    fed the *pre-rounded* values exactly (staging only rounds inputs), and
    the sampling lanes (ok/scores/thresholds) are untouched by staging."""
    sidx, vals, ok, scores, thr = _sidx_case(640, 1, 3, 20, 7, "random")
    vals16 = jnp.asarray(vals).astype(jnp.bfloat16)
    got = edge_megakernel_pallas(
        vals16, jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        20, sidx=jnp.asarray(sidx), ext_idx=(0,), sk_idx=(1,), interpret=True,
    )
    from repro.kernels.edge_megakernel.ref import edge_megakernel_ref

    ref = edge_megakernel_ref(
        np.asarray(vals16.astype(jnp.float32)), ok, scores, thr, 20,
        sidx=sidx, ext_idx=(0,), sk_idx=(1,),
    )
    _assert_matches(got, ref, "bf16", rtol=1e-6, atol=1e-4)
    # keep decisions identical to the f32-staged run: staging never
    # touches the sampling compare
    got32 = edge_megakernel_pallas(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        20, sidx=jnp.asarray(sidx), ext_idx=(0,), sk_idx=(1,), interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got.keep), np.asarray(got32.keep))


def test_megakernel_sketch_rows_shape():
    """Sketch rows carry the full (S, NUM_BINS) log-histogram per sketch
    column — the in-kernel binning contract behind
    ``QuantileSketchAccumulator.from_kernel_rows``."""
    sidx, vals, ok, scores, thr = _sidx_case(100, 1, 2, 5, 1, "random")
    res = edge_megakernel(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        5, sidx=jnp.asarray(sidx), sk_idx=(0, 1), interpret=True,
    )
    assert res.bins.shape == (1, 2, 5, SKETCH_NUM_BINS)
    # every kept tuple lands in exactly one bin
    np.testing.assert_allclose(
        np.asarray(res.bins).sum(axis=(1, 3)) / 2.0, np.asarray(res.keep), atol=1e-5
    )


@pytest.mark.xdist_group("tiling-overrides")
def test_megakernel_block_override_hook():
    """kernels/tiling.py overrides reshape the grid without changing
    results (the TPU block-tuning knob); pinned to one xdist worker — the
    override table is process-global state."""
    from repro.kernels import tiling

    sidx, vals, ok, scores, thr = _sidx_case(700, 1, 2, 30, 3, "random")
    args = (
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr)
    )
    base = edge_megakernel_pallas(
        *args, 30, sidx=jnp.asarray(sidx), ext_idx=(0,), sk_idx=(1,), interpret=True
    )
    try:
        tiling.set_block_override("edge_megakernel", n_block=256, s_block=256)
        small = edge_megakernel_pallas(
            *args, 30, sidx=jnp.asarray(sidx), ext_idx=(0,), sk_idx=(1,),
            n_block=256, s_block=256, interpret=True,
        )
    finally:
        tiling.clear_block_overrides()
    for a, b in zip(tuple(base), tuple(small)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-4)


@pytest.mark.skipif(
    "NIGHTLY_MEGA_N" not in os.environ,
    reason="nightly-only: set NIGHTLY_MEGA_N (e.g. 65536) to run",
)
def test_megakernel_sidx_parity_nightly_large_n():
    """Interpret-mode parity at nightly scale: N from ``NIGHTLY_MEGA_N``
    (far past the PR sweep's 700-point ceiling, many block boundaries),
    wide stratum count, mixed masking.  The nightly workflow runs this at
    N=65536; PR runs skip it."""
    n = int(os.environ["NIGHTLY_MEGA_N"])
    sidx, vals, ok, scores, thr = _sidx_case(n, 2, 3, 96, 7, "random")
    got = edge_megakernel_pallas(
        jnp.asarray(vals), jnp.asarray(ok), jnp.asarray(scores), jnp.asarray(thr),
        96, sidx=jnp.asarray(sidx), ext_idx=(0,), sk_idx=(2,), interpret=True,
    )
    from repro.kernels.edge_megakernel.ref import edge_megakernel_ref

    ref = edge_megakernel_ref(
        vals, ok, scores, thr, 96, sidx=sidx, ext_idx=(0,), sk_idx=(2,)
    )
    _assert_matches(got, ref, f"nightly-sidx[{n}]")
