"""Multi-device integration (subprocess, 8 host devices): the routed
all_to_all exchange and the sharded flash-decode agree with references."""

import os
import subprocess
import sys

import pytest

# each test spawns a full 8-device jax subprocess; serialize them onto one
# xdist worker so parallel shards don't oversubscribe the CPU
pytestmark = pytest.mark.xdist_group("subprocess-heavy")


def _run(code: str, timeout=900):
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-2000:])
    return r.stdout


def test_routed_exchange_delivers_to_owner_shards():
    out = _run(
        """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import make_table, contiguous_plan, SHENZHEN_BBOX
from repro.core.routing import exchange
from repro.launch.mesh import compat_make_mesh, compat_shard_map

mesh = compat_make_mesh((8,), ("data",))
table = make_table(*SHENZHEN_BBOX, precision=5, neighborhood_precision=3)
plan = contiguous_plan(table, num_shards=8)
rng = np.random.default_rng(0)
N = 8 * 512
sidx = jnp.asarray(rng.integers(0, table.num_strata, N), jnp.int32)
payload = jnp.asarray(rng.normal(0, 1, N), jnp.float32)

def shard_fn(s, p):
    valid, rx_s, rx_p, dropped = exchange(plan, s, p, "data", capacity=256)
    return valid, rx_s, rx_p, dropped[None]

mapped = jax.jit(compat_shard_map(shard_fn, mesh=mesh,
    in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data"), P("data"), P("data")),
    check_vma=False))
valid, rx_s, rx_p, dropped = mapped(sidx, payload)
valid, rx_s = np.asarray(valid), np.asarray(rx_s)
dest_of = np.asarray(plan.dest_of_stratum)
# every received tuple on shard d must be destined for d
per_shard = rx_s.reshape(8, -1)
per_valid = valid.reshape(8, -1)
for d in range(8):
    got = per_shard[d][per_valid[d]]
    assert (dest_of[got] == d).all(), d
# conservation: valid received == sent (minus drops)
sent = N - int(np.asarray(dropped).sum())
assert per_valid.sum() == sent
print("EXCHANGE_OK", per_valid.sum(), int(np.asarray(dropped).sum()))
"""
    )
    assert "EXCHANGE_OK" in out


def test_sharded_flash_decode_matches_reference():
    out = _run(
        """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import compat_make_mesh
from repro.sharding.logical import default_rules, use_rules
from repro.models.layers import decode_attention, sharded_decode_attention

mesh = compat_make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, T, H, K, dh = 4, 64, 8, 2, 16
q = jnp.asarray(rng.normal(0, 1, (B, 1, H, dh)), jnp.float32)
kc = jnp.asarray(rng.normal(0, 1, (B, T, K, dh)), jnp.float32)
vc = jnp.asarray(rng.normal(0, 1, (B, T, K, dh)), jnp.float32)
kn = jnp.asarray(rng.normal(0, 1, (B, 1, K, dh)), jnp.float32)
vn = jnp.asarray(rng.normal(0, 1, (B, 1, K, dh)), jnp.float32)
pos = 37
rules = default_rules(mesh)
with use_rules(rules):
    o_sh, kc2, vc2 = jax.jit(lambda *a: sharded_decode_attention(*a))(
        q, kc, vc, pos + 1, kn, vn, pos)
kc_ref = kc.at[:, pos:pos+1].set(kn)
vc_ref = vc.at[:, pos:pos+1].set(vn)
o_ref = decode_attention(q, kc_ref, vc_ref, pos + 1)
np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_ref), rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(kc2), np.asarray(kc_ref), rtol=1e-6, atol=1e-6)
print("FLASH_DECODE_OK")
"""
    )
    assert "FLASH_DECODE_OK" in out


def test_grad_compression_cross_pod_collective():
    out = _run(
        """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train import compression
from repro.launch.mesh import compat_make_mesh, compat_shard_map

mesh = compat_make_mesh((8,), ("pod",))
rng = np.random.default_rng(0)
g_global = jnp.asarray(rng.normal(0, 1, (8, 64)), jnp.float32)  # per-pod grads

def shard_fn(g):
    st = compression.init_state({"g": g})
    red, _ = compression.cross_pod_mean_compressed(
        {"g": g}, jax.random.key(0), 0.5, st, axis="pod")
    return red["g"]

mapped = jax.jit(compat_shard_map(shard_fn, mesh=mesh, in_specs=(P("pod"),),
                 out_specs=P("pod"), check_vma=False))
out = np.asarray(mapped(g_global)).reshape(8, -1)
# identical masks (shared key): every pod holds the same reduced value
for d in range(1, 8):
    np.testing.assert_allclose(out[0], out[d], rtol=1e-6)
# kept coordinates equal the true mean (unscaled EF compressor keeps exact values)
mean = np.asarray(g_global).mean(axis=0)
kept = out[0] != 0
assert kept.sum() > 5
np.testing.assert_allclose(out[0][kept], mean[kept], rtol=1e-5)
print("COMPRESSED_REDUCE_OK", int(kept.sum()))
"""
    )
    assert "COMPRESSED_REDUCE_OK" in out


def test_sharded_quantiles_and_backend_parity():
    """execute_sharded answers p50/p99 end-to-end over 8 host-mesh edge
    shards (sketch psum across the uplink), and the fused edge-reduce
    backend matches the per-column segment backend shard-for-shard."""
    out = _run(
        """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import (
    SHENZHEN_BBOX, AggSpec, EdgeCloudPipeline, PipelineConfig, Query,
    make_table, windows,
)
from repro.data.streams import shenzhen_taxi_stream
from repro.launch.mesh import compat_make_mesh

mesh = compat_make_mesh((8,), ("data",))
table = make_table(*SHENZHEN_BBOX, precision=5)
window = next(windows.count_windows(shenzhen_taxi_stream(num_chunks=2, seed=0), 32_768))
q = Query(aggs=(AggSpec("mean", "value"), AggSpec("p50", "value"), AggSpec("p99", "value")))
res = {}
for backend in ("segment", "pallas"):
    pipe = EdgeCloudPipeline(table, PipelineConfig(backend=backend), mesh=mesh)
    res[backend] = pipe.execute_sharded(q, jax.random.key(1), window, fraction=1.0)

# full fraction: the merged sketch must hit the exact numpy quantiles
sidx = np.asarray(table.assign(jnp.asarray(window.lat), jnp.asarray(window.lon)))
v = window.value[sidx < table.num_strata]
for key, quant in (("p50_value", 0.5), ("p99_value", 0.99)):
    got = float(res["segment"].estimates[key].value)
    true = float(np.quantile(v, quant))
    assert abs(got - true) <= 0.05 * abs(true) + 1e-3, (key, got, true)

# backend parity on the same shard split: sketch bins identical, moments
# within the documented fp32 centering tolerance
for key in ("mean_value", "p50_value", "p99_value"):
    a = float(res["segment"].estimates[key].value)
    b = float(res["pallas"].estimates[key].value)
    assert abs(a - b) <= 1e-4 * max(1.0, abs(a)), (key, a, b)
assert int(res["segment"].n_sampled) == int(res["pallas"].n_sampled)
bins_a = np.asarray(res["segment"].stats["value"]["sketch"].bins)
bins_b = np.asarray(res["pallas"].stats["value"]["sketch"].bins)
np.testing.assert_array_equal(bins_a, bins_b)
print("SHARDED_QUANTILE_OK", float(res["segment"].estimates["p99_value"].value))
"""
    )
    assert "SHARDED_QUANTILE_OK" in out
