"""Per-query fraction refinement + cross-signature Bernoulli fusion.

The session layer's nested Horvitz-Thompson subsampling contract,
property-tested:

  * a refined member of a fused preagg group is **elementwise-identical**
    to running its query through ``pipeline.execute`` independently at its
    *own* fraction (the strongest form of "unbiased vs. independent
    execute": the nested subsample IS the independent draw);
  * nested masks are genuine subsets (a lower-fraction member's sample is
    contained in a higher-fraction member's);
  * refined estimates are unbiased against the full-population truth;
  * reported confidence intervals widen monotonically as the refined
    fraction shrinks (the ``bounds.py`` intervals see the *effective*
    fraction through the realized per-stratum ``n_k``);
  * differing-ROI Bernoulli queries fuse into ONE preagg pass
    (cross-signature fusion), while raw mode keeps them separate.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    SHENZHEN_BBOX,
    AggSpec,
    EdgeCloudPipeline,
    PipelineConfig,
    Query,
    StreamSession,
    make_table,
    query as aqp,
    sampling,
    windows,
)
from repro.data.streams import shenzhen_taxi_stream

WINDOW = 8_000

ROI_SOUTH = ((22.45, 22.65), (113.76, 114.64))
ROI_NORTH = ((22.60, 22.86), (113.76, 114.64))  # overlaps ROI_SOUTH

EXACT_FIELDS = ("value", "moe", "ci_low", "ci_high", "relative_error", "n", "population")


@pytest.fixture(scope="module")
def table():
    return make_table(*SHENZHEN_BBOX, precision=5)


@pytest.fixture(scope="module")
def pipe(table):
    return EdgeCloudPipeline(table, PipelineConfig(raw_capacity=WINDOW))


@pytest.fixture(scope="module")
def window():
    stream = shenzhen_taxi_stream(num_chunks=1, seed=0)
    return next(windows.count_windows(stream, WINDOW))


def _assert_estimates_equal(ind, got, aggs):
    for spec in aggs:
        for field in EXACT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(ind.estimates[spec.key], field)),
                np.asarray(getattr(got.estimates[spec.key], field)),
                err_msg=f"{spec.key}.{field}",
            )


# -- refined members == independent execute at their own fraction -------------


@settings(deadline=None, max_examples=8)
@given(
    f_lo=st.floats(min_value=0.1, max_value=0.5, width=32),
    f_hi=st.floats(min_value=0.55, max_value=1.0, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_srs_refined_members_match_independent_execute(pipe, window, f_lo, f_hi, seed):
    """A divergent-fraction SRS fusion group refines each member to its own
    fraction, and the refined estimates (every field, including the bounds)
    are bit-identical to independent ``execute`` at that fraction — nested
    subsampling via shared ranks draws *the same sample* the member's own
    pass would."""
    q_lo = Query(aggs=(AggSpec("mean", "value"), AggSpec("var", "value")))
    q_hi = Query(
        aggs=(AggSpec("mean", "occupancy", name="occ"), AggSpec("p50", "value", name="med"))
    )
    sess = StreamSession(pipe)
    r_lo = sess.register(q_lo, initial_fraction=f_lo)
    r_hi = sess.register(q_hi, initial_fraction=f_hi)
    assert len(sess._groups()) == 1
    key = jax.random.key(seed)
    step = sess.step(key, window)
    for q, reg, f in ((q_lo, r_lo, f_lo), (q_hi, r_hi, f_hi)):
        ind = pipe.execute(q, key, window, f)
        got = step.results[reg.qid]
        _assert_estimates_equal(ind, got, q.aggs)
        assert int(got.n_sampled) == int(ind.n_sampled)
        assert int(got.n_valid) == int(ind.n_valid)
        assert int(got.n_overflow) == int(ind.n_overflow)


@settings(deadline=None, max_examples=8)
@given(
    f_a=st.floats(min_value=0.1, max_value=0.9, width=32),
    f_b=st.floats(min_value=0.1, max_value=0.9, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bernoulli_cross_roi_members_match_independent_execute(pipe, window, f_a, f_b, seed):
    """Differing-ROI Bernoulli queries share ONE preagg pass; each member's
    per-query accumulation mask reproduces its independent ROI-filtered
    draw bit-for-bit (uniforms are stratum- and ROI-oblivious), at each
    member's own fraction."""
    q_a = Query(aggs=(AggSpec("mean", "value"), AggSpec("count", "value")),
                method="bernoulli", roi=ROI_SOUTH)
    q_b = Query(aggs=(AggSpec("sum", "occupancy", name="s_occ"),),
                method="bernoulli", roi=ROI_NORTH)
    sess = StreamSession(pipe)
    r_a = sess.register(q_a, initial_fraction=f_a)
    r_b = sess.register(q_b, initial_fraction=f_b)
    assert len(sess._groups()) == 1  # cross-signature fusion: one group
    key = jax.random.key(seed)
    step = sess.step(key, window)
    assert sess.total_passes == 1  # ... and one edge pass for both ROIs
    for q, reg, f in ((q_a, r_a, f_a), (q_b, r_b, f_b)):
        ind = pipe.execute(q, key, window, f)
        got = step.results[reg.qid]
        _assert_estimates_equal(ind, got, q.aggs)
        assert int(got.n_sampled) == int(ind.n_sampled)
        assert int(got.n_overflow) == int(ind.n_overflow)


def test_neyman_groups_never_refine(pipe):
    """Neyman members must stay on the shared group-max pass: refined
    thinning would silently swap the variance-optimal allocation for a
    proportional one (the refined program refuses the method outright)."""
    from repro.core import pipeline as pipeline_mod

    q1 = Query(aggs=(AggSpec("mean", "value"),), method="neyman")
    q2 = Query(aggs=(AggSpec("mean", "value", name="b"),), method="neyman")
    fused = aqp.fuse([pipe.plan(q1), pipe.plan(q2)])
    assert not StreamSession._refines(fused, [0.2, 0.8])
    with pytest.raises(NotImplementedError, match="neyman"):
        pipeline_mod._fused_edge_program(
            fused, pipe.table, pipe.config, jax.random.key(0),
            None, None, {}, None, None,
        )


def test_bernoulli_raw_mode_keeps_separate_groups(pipe):
    """Raw mode ships one ROI-filtered compact buffer, so differing-ROI
    Bernoulli queries must NOT fuse there (the ROI stays in the raw fusion
    key)."""
    q_a = Query(aggs=(AggSpec("mean", "value"),), method="bernoulli", roi=ROI_SOUTH, mode="raw")
    q_b = Query(aggs=(AggSpec("mean", "value"),), method="bernoulli", roi=ROI_NORTH, mode="raw")
    sess = StreamSession(pipe)
    sess.register(q_a)
    sess.register(q_b)
    assert len(sess._groups()) == 2
    # ... while the preagg twins fuse
    p_a = pipe.plan(Query(aggs=(AggSpec("mean", "value"),), method="bernoulli", roi=ROI_SOUTH))
    p_b = pipe.plan(Query(aggs=(AggSpec("mean", "value"),), method="bernoulli", roi=ROI_NORTH))
    assert aqp.fusion_key(p_a) == aqp.fusion_key(p_b)
    fused = aqp.fuse([p_a, p_b])
    assert fused.cross_roi and fused.shared.query.roi is None


# -- nesting ------------------------------------------------------------------


@settings(deadline=None, max_examples=8)
@given(
    f_lo=st.floats(min_value=0.05, max_value=0.95, width=32),
    f_hi=st.floats(min_value=0.05, max_value=0.95, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_nested_masks_are_subsets(rng, f_lo, f_hi, seed):
    """The shared-randomness masks are nested in the fraction: the
    lower-fraction sample is contained in the higher-fraction one, for both
    SRS ranks and Bernoulli uniforms — the property that lets one edge pass
    serve every member fraction."""
    f_lo, f_hi = sorted((f_lo, f_hi))
    sidx = jnp.asarray(rng.integers(0, 12, 4_000), jnp.int32)
    key = jax.random.key(seed)
    ranks, counts = sampling.srs_ranks(key, sidx, 13)
    masks = []
    for f in (f_lo, f_hi):
        n_k = sampling.allocate_proportional(counts, f)
        masks.append(np.asarray(ranks < n_k[sidx]))
    assert not np.any(masks[0] & ~masks[1])  # lo ⊆ hi
    # and each mask is exactly the srs_sample draw at that fraction
    for f, m in zip((f_lo, f_hi), masks):
        n_k = sampling.allocate_proportional(counts, f)
        ref = sampling.srs_sample(key, sidx, 13, n_k, counts)
        np.testing.assert_array_equal(m, np.asarray(ref.mask))
    u = jax.random.uniform(key, sidx.shape)
    assert not np.any(np.asarray((u < f_lo) & ~(u < f_hi)))


def test_session_refined_samples_are_nested(pipe, window):
    """End-to-end nesting: the refined low-fraction member's per-stratum
    sample sizes never exceed the high-fraction member's."""
    q_lo = Query(aggs=(AggSpec("mean", "value"),))
    q_hi = Query(aggs=(AggSpec("mean", "value", name="hi"),))
    sess = StreamSession(pipe)
    r_lo = sess.register(q_lo, initial_fraction=0.15)
    r_hi = sess.register(q_hi, initial_fraction=0.85)
    sess.step(jax.random.key(2), window)
    n_lo = np.asarray(r_lo.ring[-1].stats["value"]["moments"].n)
    n_hi = np.asarray(r_hi.ring[-1].stats["value"]["moments"].n)
    assert np.all(n_lo <= n_hi)
    assert n_lo.sum() < n_hi.sum()
    # downstream accounting follows the refined samples, not the group max
    assert r_lo.downstream_bytes < r_hi.downstream_bytes


# -- unbiasedness -------------------------------------------------------------


def test_refined_estimates_unbiased_against_truth(pipe):
    """Across independent windows/keys, the refined 25%-fraction member's
    mean estimate is unbiased for the full-population window mean (bias
    well inside the Monte-Carlo standard error band)."""
    q_lo = Query(aggs=(AggSpec("mean", "value"),))
    q_hi = Query(aggs=(AggSpec("mean", "value", name="hi"),))
    stream = shenzhen_taxi_stream(num_chunks=8, seed=11)
    errs = []
    for i, w in enumerate(windows.count_windows(stream, WINDOW)):
        sess = StreamSession(pipe)
        r_lo = sess.register(q_lo, initial_fraction=0.25)
        sess.register(q_hi, initial_fraction=0.9)
        step = sess.step(jax.random.key(100 + i), w)
        truth = float(np.mean(np.asarray(w.value)[np.asarray(w.valid)]))
        est = float(np.asarray(step.results[r_lo.qid].estimates["mean_value"].value))
        errs.append(est - truth)
    errs = np.asarray(errs)
    se = errs.std(ddof=1) / np.sqrt(len(errs))
    assert abs(errs.mean()) < 4.0 * se + 1e-3, (errs.mean(), se)


# -- CI width monotone in the refined fraction --------------------------------


@settings(deadline=None, max_examples=8)
@given(
    f_lo=st.floats(min_value=0.1, max_value=0.45, width=32),
    f_hi=st.floats(min_value=0.65, max_value=0.98, width=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ci_widens_as_refined_fraction_shrinks(pipe, window, f_lo, f_hi, seed):
    """Identical queries fused at divergent fractions: the refined
    low-fraction member reports strictly wider mean intervals — its bounds
    see the effective (thinned) per-stratum sample, not the group max."""
    q_lo = Query(aggs=(AggSpec("mean", "value"),))
    q_mid = Query(aggs=(AggSpec("mean", "value", name="mid"),))
    q_hi = Query(aggs=(AggSpec("mean", "value", name="hi"),))
    f_mid = (f_lo + f_hi) / 2.0
    sess = StreamSession(pipe)
    regs = [
        sess.register(q, initial_fraction=f)
        for q, f in ((q_lo, f_lo), (q_mid, f_mid), (q_hi, f_hi))
    ]
    step = sess.step(jax.random.key(seed), window)
    moes = [
        float(np.asarray(next(iter(step.results[r.qid].estimates.values())).moe))
        for r in regs
    ]
    assert moes[0] > moes[1] > moes[2], (moes, (f_lo, f_mid, f_hi))


# -- determinism & cost accounting --------------------------------------------


def test_refined_step_deterministic_in_key(pipe, window):
    """Two fresh sessions over the same pane and key produce bit-identical
    refined results (the thinning randomness is keyed on the step key)."""
    q_lo = Query(aggs=(AggSpec("mean", "value"), AggSpec("p99", "value")))
    q_hi = Query(aggs=(AggSpec("var", "occupancy", name="v"),))

    def run(key):
        sess = StreamSession(pipe)
        r_lo = sess.register(q_lo, initial_fraction=0.3)
        r_hi = sess.register(q_hi, initial_fraction=0.8)
        step = sess.step(key, window)
        return step.results[r_lo.qid], step.results[r_hi.qid]

    a = run(jax.random.key(5))
    b = run(jax.random.key(5))
    for res_a, res_b in zip(a, b):
        for k in res_a.estimates:
            for field in EXACT_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(res_a.estimates[k], field)),
                    np.asarray(getattr(res_b.estimates[k], field)),
                )
    c = run(jax.random.key(6))
    assert int(c[0].n_sampled) != 0  # different key still samples


def test_uniform_fraction_group_keeps_shared_pass_cost(pipe, window, table):
    """Equal member fractions keep the PR2 shared pass: one union
    accumulation whose uplink is the shared plan's payload, strictly below
    the refined per-member payload the divergent case ships."""
    q1 = Query(aggs=(AggSpec("mean", "value"),))
    q2 = Query(aggs=(AggSpec("mean", "occupancy", name="o"),))
    fused = aqp.fuse([pipe.plan(q1), pipe.plan(q2)])
    shared_bytes = aqp.preagg_bytes(fused.shared, table.num_slots)
    refined_bytes = aqp.refined_preagg_bytes(fused, table.num_slots)
    assert shared_bytes < refined_bytes

    sess_eq = StreamSession(pipe, initial_fraction=0.6)
    for q in (q1, q2):
        sess_eq.register(q)
    assert sess_eq.step(jax.random.key(0), window).comm_bytes == shared_bytes

    sess_div = StreamSession(pipe)
    sess_div.register(q1, initial_fraction=0.2)
    sess_div.register(q2, initial_fraction=0.8)
    assert sess_div.step(jax.random.key(0), window).comm_bytes == refined_bytes
